"""Streaming SVD maintenance — the paper's motivating big-data scenario.

A rank-r sketch of a user x item interaction matrix is maintained under a
stream of rank-1 observations (each event adds w * e_u v_item^T). Every
event is one ``api.update`` on a truncated ``SvdState`` (Brand augmentation
+ the paper's diagonal-plus-rank-1 core — geometry picks the truncated
route; no method name threading). We compare against periodically
recomputing a fresh SVD — dominant singular values track to ~1e-8 relative
(truncation inherently discards rank-(r+1) mass, so exact equality is
impossible for any streaming method) while the per-event cost is
O((m+n) r + r^2 p) instead of O(m n min(m,n)).

Part 2 runs the same workload shape through the production front end:
``serve.SvdService`` micro-batches events across several streams into
batched engine flushes (async, double-buffered), snapshots itself to disk
mid-stream, and a *restored* service finishes the run with bitwise the
same factors as the one that never stopped — the DESIGN §9 contract.

Part 4 re-runs the serving shape with the ``repro.obs`` telemetry layer
on (DESIGN §15): span tracing around every flush, numerical-health
probes on a sampling cadence, and an end-of-run metrics summary.

Run:  PYTHONPATH=src python examples/streaming_svd.py
"""

import tempfile
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro import api

M_USERS, N_ITEMS, RANK, EVENTS = 600, 400, 12, 200


def main():
    rng = np.random.default_rng(0)

    # ground truth low-rank preference structure + noise stream
    u_true = rng.normal(size=(M_USERS, 4))
    v_true = rng.normal(size=(N_ITEMS, 4))

    dense = np.zeros((M_USERS, N_ITEMS))
    t = api.SvdState.from_factors(
        np.linalg.qr(rng.normal(size=(M_USERS, RANK)))[0],
        np.zeros((RANK,)),
        np.linalg.qr(rng.normal(size=(N_ITEMS, RANK)))[0],
    )

    policy = api.UpdatePolicy()            # auto: the (r+1)-sized core runs direct
    t0 = time.perf_counter()
    for step in range(EVENTS):
        # one "interaction batch": a user factor bumps an item direction
        a = u_true @ rng.normal(size=4) + 0.1 * rng.normal(size=M_USERS)
        b = v_true @ rng.normal(size=4) + 0.1 * rng.normal(size=N_ITEMS)
        dense += np.outer(a, b)
        t = api.update(t, jnp.asarray(a), jnp.asarray(b), policy)
    dt = time.perf_counter() - t0

    sv_stream = np.asarray(t.s)
    sv_true = np.linalg.svd(dense, compute_uv=False)[:RANK]
    rel = np.abs(sv_stream - sv_true) / sv_true[0]
    print(f"{EVENTS} rank-1 events in {dt:.2f}s "
          f"({dt / EVENTS * 1e3:.2f} ms/event, plan-cached engine, CPU)")
    print("top-5 singular values (streamed) :", np.round(sv_stream[:5], 6))
    print("top-5 singular values (recompute):", np.round(sv_true[:5], 6))
    print(f"max relative deviation over rank-{RANK}: {rel.max():.2e}")
    assert rel[:3].max() < 1e-6  # dominant structure tracked


def service_demo():
    """Checkpointable streaming through ``serve.SvdService`` (DESIGN §9)."""
    from repro.serve import SvdService

    rng = np.random.default_rng(1)
    m, n, r, streams, events = 48, 32, 4, 3, 18

    def fresh_sketch():
        return api.SvdState.from_factors(
            np.linalg.qr(rng.normal(size=(m, r)))[0],
            np.zeros((r,)),
            np.linalg.qr(rng.normal(size=(n, r)))[0],
        )

    sketches = [fresh_sketch() for _ in range(streams)]
    traffic = [
        (f"tenant-{i % streams}",
         jnp.asarray(rng.normal(size=m)), jnp.asarray(rng.normal(size=n)))
        for i in range(events)
    ]

    def run(svc, evts):
        for sid, a, b in evts:
            svc.enqueue(sid, a, b)
        svc.drain()                      # barrier: all flushes retired

    # uninterrupted reference run
    ref = SvdService(max_batch=streams, max_in_flight=2)
    for i, sk in enumerate(sketches):
        ref.register(f"tenant-{i}", sk)
    run(ref, traffic)

    # the same run, killed in the middle: snapshot -> fresh service -> resume
    svc = SvdService(max_batch=streams, max_in_flight=2)
    for i, sk in enumerate(sketches):
        svc.register(f"tenant-{i}", sk)
    split = events // 2
    run(svc, traffic[:split])
    with tempfile.TemporaryDirectory() as ckpt_dir:
        svc.save(ckpt_dir, step=split)
        _, resumed = SvdService.restore(ckpt_dir)
    run(resumed, traffic[split:])

    for i in range(streams):
        a = np.asarray(ref.state(f"tenant-{i}").s)
        b = np.asarray(resumed.state(f"tenant-{i}").s)
        np.testing.assert_array_equal(a, b)   # bitwise restore-exactness
    print(f"service: {events} events over {streams} streams, "
          f"{ref.stats.rounds} batched flush rounds, "
          f"snapshot+resume bitwise-identical")


def structured_demo():
    """Structured perturbations through ``api.apply`` (DESIGN §10): a
    mini-batch rank-k absorb, a forgetting factor, and a growing matrix —
    one planned schedule each, checked against the dense reference."""
    from repro.updates import AppendRows, Compose, Decay, RankK

    rng = np.random.default_rng(2)
    m, n, r, k = 24, 32, 6, 3
    base = rng.normal(size=(m, 2)) @ rng.normal(size=(2, n))   # rank-2 data
    state = api.SvdState.from_dense(jnp.asarray(base), rank=r)

    op = Compose((
        Decay(0.95),                                           # forget a little
        RankK(jnp.asarray(rng.normal(size=(m, k)) / 10),
              jnp.asarray(rng.normal(size=(n, k)) / 10)),      # minibatch sketch
        AppendRows(jnp.asarray(rng.normal(size=(2, 2)) / 10
                               @ rng.normal(size=(2, n)))),    # two new users
    ))
    state = api.apply(state, op)

    dense = np.asarray(op.apply_dense(base))
    u, s, vt = np.linalg.svd(dense, full_matrices=False)
    ref = (u[:, :r] * s[:r]) @ vt[:r]
    err = np.abs(np.asarray(state.materialize()) - ref).max()
    print(f"structured: decay+rank-{k}+append -> shape {state.shape}, "
          f"parity vs dense SVD {err:.2e}")
    assert state.shape == (m + 2, n)
    assert err < 1e-8


def deletion_demo():
    """Downdates through the service tier (DESIGN §14): a GDPR-style user
    deletion and a sliding retention window, both enqueued as first-class
    ops — the sketch never rebuilds from dense, yet matches the SVD of the
    matrix with those rows actually gone."""
    from repro.serve import SvdService
    from repro.updates import RemoveRows, Window

    rng = np.random.default_rng(3)
    m, n, r, events = 40, 32, 5, 12
    dense = rng.normal(size=(m, 2)) @ rng.normal(size=(2, n))   # rank-2 data

    svc = SvdService(max_batch=4)
    svc.register("tenant-0", api.SvdState.from_dense(jnp.asarray(dense), rank=r))
    for _ in range(events):
        a = dense @ rng.normal(size=n)        # in-span traffic: rank stays 2
        b = dense.T @ rng.normal(size=m)
        svc.enqueue("tenant-0", jnp.asarray(a * 0.02), jnp.asarray(b * 0.02))
        dense = dense + 0.02 * 0.02 * np.outer(a, b)

    erased = (3, 17)                          # two users invoke erasure
    svc.enqueue_op("tenant-0", RemoveRows(erased))
    dense = np.delete(dense, erased, axis=0)

    keep = 30                                 # retention: newest 30 rows only
    svc.enqueue_op("tenant-0", Window(keep, lam=0.97))
    dense = 0.97 * dense[-keep:]

    svc.drain()
    state = svc.state("tenant-0")
    u, s, vt = np.linalg.svd(dense, full_matrices=False)
    ref = (u[:, :r] * s[:r]) @ vt[:r]
    err = np.abs(np.asarray(state.materialize()) - ref).max()
    print(f"deletion: {events} events + erase {erased} + window {keep} "
          f"-> shape {state.shape}, parity vs dense SVD of deleted matrix "
          f"{err:.2e}")
    assert state.shape == (keep, n)
    assert err < 1e-8


def obs_demo():
    """Part 4 — the telemetry layer (DESIGN §15): the same streaming
    workload with ``repro.obs`` metrics, span tracing and numerical-health
    monitors on, ending with the end-of-run metrics summary an operator
    would scrape."""
    import json

    from repro import obs
    from repro.serve import SvdService

    rng = np.random.default_rng(4)
    m, n, r, streams, events = 48, 32, 4, 3, 18

    obs.enable()
    obs.start_tracing()
    svc = SvdService(
        max_batch=streams,
        policy=api.UpdatePolicy(health_every=2),   # probe every 2nd flush
    )
    for i in range(streams):
        svc.register(f"tenant-{i}", api.SvdState.from_factors(
            np.linalg.qr(rng.normal(size=(m, r)))[0],
            np.zeros((r,)),
            np.linalg.qr(rng.normal(size=(n, r)))[0],
        ))
    for i in range(events):
        svc.enqueue(f"tenant-{i % streams}",
                    jnp.asarray(rng.normal(size=m)),
                    jnp.asarray(rng.normal(size=n)))
    svc.drain()
    obs.stop_tracing()

    # the trace is a valid Chrome trace_event document with flush spans
    doc = json.loads(obs.chrome_trace())
    spans = sorted({e["name"] for e in doc["traceEvents"]})
    assert "flush_round" in spans and "dispatch" in spans

    # end-of-run metrics summary: throughput counters + health gauges
    reg = obs.registry()
    drift = reg.get("health_ortho_drift").value
    assert reg.get("serve_applied").value == events
    assert drift < 1e-6                       # factors stayed orthonormal
    assert "# TYPE serve_applied gauge" in reg.to_prometheus()
    print(f"obs: {len(doc['traceEvents'])} spans {spans}, "
          f"applied={reg.get('serve_applied').value:.0f} "
          f"flush_rounds={reg.get('serve_rounds').value:.0f} "
          f"ortho_drift={drift:.1e}")
    obs.disable()
    obs.clear_trace()


if __name__ == "__main__":
    main()
    service_demo()
    structured_demo()
    deletion_demo()
    obs_demo()
    print("OK")
