"""Elastic scaling: re-mesh (training) and re-shard (serving) on restart.

Checkpoints store full (host-gathered) arrays, so they are mesh-independent.
On restart, ``plan_mesh`` inspects the devices that are actually alive and
chooses the largest (data, model) factorization consistent with the model's
TP divisibility constraints; ``reshard`` places a restored pytree onto the
new mesh. At 1000+-node scale this is the recover-with-fewer-pods path: a
dead pod shrinks the data axis, training continues at reduced global batch.

The serving tier has the same failover shape at a different granularity:
a fleet snapshot (``repro.fleet.FleetSnapshot``) is shard-count-independent
the way a training checkpoint is mesh-independent, so
``SvdFleet.restore(..., num_shards="auto")`` asks ``plan_shard_count`` to
size the restored fleet to the devices that actually came back; the
per-stream state regroup (``FleetSnapshot.regrouped``) is the serving
analogue of ``reshard`` — pure data movement, bitwise.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding

from repro.dist import sharding as sh

__all__ = ["plan_mesh", "plan_shard_count", "reshard", "largest_factorization"]


def largest_factorization(n: int, max_model: int = 16) -> tuple[int, int]:
    """(data, model) with model as large as possible, model | n, model <= max."""
    for m in range(min(max_model, n), 0, -1):
        if n % m == 0:
            return n // m, m
    return n, 1


def plan_mesh(max_model: int = 16):
    n = jax.device_count()
    data, model = largest_factorization(n, max_model)
    return jax.make_mesh((data, model), ("data", "model"))


def plan_shard_count(max_shards: int | None = None, *, devices=None) -> int:
    """Fleet shard count for the devices actually alive: one service shard
    per device (each shard's flush rounds pin to its own device,
    ``fleet.placement.plan_devices``), optionally capped.  The serving twin
    of ``plan_mesh`` — called by ``SvdFleet.restore(num_shards="auto")``."""
    n = len(devices) if devices is not None else jax.device_count()
    if n < 1:
        raise ValueError("no live devices to plan shards for")
    return min(n, max_shards) if max_shards is not None else n


def reshard(tree, mesh):
    """Place a host pytree onto ``mesh`` per the standard param rules."""
    specs = sh.param_pspecs(tree)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs
    )
