"""Distributed-semantics tests on 8 fake CPU devices (subprocess: the device
count must be forced before jax initializes, and only for these tests)."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run(code: str) -> dict:
    script = textwrap.dedent(code)
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        timeout=420,
        env={
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
            "PYTHONPATH": str(REPO / "src"),
            "PATH": "/usr/bin:/bin",
            "JAX_PLATFORMS": "cpu",
            "HOME": "/tmp",
        },
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-4000:]}"
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_sharded_train_step_matches_single_device():
    """One sharded train step on a 4x2 mesh == the unsharded step."""
    out = _run("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.models.registry import build_model
        from repro.dist import sharding as sh
        from repro.optim.adamw import adamw_init, adamw_update, AdamWState
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = configs.get_smoke("nemotron-4-15b").replace(vocab_pad_to=16)
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
        }

        def step(params, opt, batch):
            loss, grads = jax.value_and_grad(api.train_loss)(params, batch)
            p2, o2, g = adamw_update(grads, opt, params, lr=1e-3)
            return p2, o2, loss

        p_ref, o_ref, loss_ref = jax.jit(step)(params, opt, batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        p_specs = sh.param_pspecs(params)
        b_specs = sh.batch_pspecs(batch, multi_pod=False)
        ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                    is_leaf=lambda x: isinstance(x, P))
        o_specs = AdamWState(step=P(), m=p_specs, v=p_specs)
        with mesh:
            p_sh, o_sh, loss_sh = jax.jit(
                step, in_shardings=(ns(p_specs), ns(o_specs), ns(b_specs))
            )(params, opt, batch)

        dl = abs(float(loss_ref) - float(loss_sh))
        dp = max(float(jnp.max(jnp.abs(a - b)))
                 for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)))
        print(json.dumps({"dloss": dl, "dparams": dp,
                          "devices": jax.device_count()}))
    """)
    assert out["devices"] == 8
    assert out["dloss"] < 1e-5
    assert out["dparams"] < 1e-4


def test_compressed_allreduce_under_shard_map():
    """Compressed DP all-reduce == dense pmean for rank<r gradients, and the
    HLO carries only the small factors across the wire."""
    out = _run("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim.compression import (CompressionState, compression_init,
                                             compress_decompress)
        from repro.api import SvdState

        mesh = jax.make_mesh((8,), ("data",))
        m, n, r = 16, 12, 4
        rng = np.random.default_rng(0)
        # per-shard gradients share a rank-2 structure + shard-specific coeffs
        u = rng.normal(size=(m, 2)); v = rng.normal(size=(n, 2))
        coeffs = rng.normal(size=(8, 2, 2))
        g_all = jnp.asarray(np.stack([u @ c @ v.T for c in coeffs]))  # (8, m, n)
        state = compression_init(jax.random.PRNGKey(0), m, n, r)

        def body(g_local, state):
            g_hat, st2 = compress_decompress(state, g_local[0], axis_name="data")
            # the error-feedback buffer is PER-WORKER (local residual); the
            # basis and tracker are replicated (built from psum'd factors)
            return g_hat[None], st2._replace(error=st2.error[None])

        out_state_specs = CompressionState(
            v_basis=P(), error=P("data"),
            tracker=SvdState(P(), P(), P()),   # api-era tracker container
        )
        fn = shard_map(body, mesh=mesh,
                       in_specs=(P("data"), P()),
                       out_specs=(P("data"), out_state_specs))
        g_hat, st = jax.jit(fn)(g_all, state)
        dense_mean = np.mean(np.asarray(g_all), axis=0)
        got = np.asarray(g_hat[0])  # pmean'd: every shard holds the mean
        rel = float(np.linalg.norm(got - dense_mean) / np.linalg.norm(dense_mean))
        print(json.dumps({"rel": rel, "err_shape": list(st.error.shape)}))
    """)
    assert out["rel"] < 1e-4


def test_sharded_engine_batch_matches_single_device():
    """SvdEngine mesh dispatch: batched updates sharded over an 8-device
    fake mesh == the single-device batched result (auto-padded B)."""
    out = _run("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.engine import SvdEngine
        from repro.core.svd_update import TruncatedSvd

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        B, m, n, r = 12, 8, 10, 4   # B % 8 != 0: exercises auto-pad
        u = np.stack([np.linalg.qr(rng.normal(size=(m, m)))[0] for _ in range(B)])
        v = np.stack([np.linalg.qr(rng.normal(size=(n, n)))[0] for _ in range(B)])
        s = np.abs(rng.normal(size=(B, m)))
        a = rng.normal(size=(B, m)); b = rng.normal(size=(B, n))
        args = tuple(jnp.asarray(x) for x in (u, s, v, a, b))

        eng = SvdEngine(method="direct")
        ref = eng.update_batch(*args)
        shd = eng.update_batch(*args, mesh=mesh, batch_axis="data")
        d_full = max(float(jnp.max(jnp.abs(x - y)))
                     for x, y in zip(jax.tree.leaves(ref), jax.tree.leaves(shd)))

        t = TruncatedSvd(args[0][:, :, :r], args[1][:, :r], args[2][:, :, :r])
        ref_t = eng.update_truncated_batch(t, args[3], args[4])
        shd_t = eng.update_truncated_batch(t, args[3], args[4],
                                           mesh=mesh, batch_axis="data")
        d_tr = max(float(jnp.max(jnp.abs(x - y)))
                   for x, y in zip(jax.tree.leaves(ref_t), jax.tree.leaves(shd_t)))
        print(json.dumps({"d_full": d_full, "d_trunc": d_tr,
                          "b_out": int(shd.u.shape[0]),
                          "devices": jax.device_count()}))
    """)
    assert out["devices"] == 8
    assert out["b_out"] == 12          # padding sliced off
    assert out["d_full"] <= 1e-4
    assert out["d_trunc"] <= 1e-4


def test_distributed_merge_and_basis_agreement():
    """dist.merge.distributed_merge under shard_map: 8 per-worker trackers
    all_gather their small factors and every worker reconstructs the SVD of
    the row-stacked matrix; compression.agree_basis lands the consensus V."""
    out = _run("""
        import json
        import jax
        jax.config.update("jax_enable_x64", True)  # suite-wide numerics default
        import jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core.svd_update import TruncatedSvd
        from repro.dist.merge import distributed_merge

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        m, n, r = 10, 12, 4
        M = rng.normal(size=(8 * m, 3)) @ rng.normal(size=(n, 3)).T  # rank 3

        us, ss, vs = [], [], []
        for w in range(8):
            uu, sv, vt = np.linalg.svd(M[w*m:(w+1)*m], full_matrices=False)
            us.append(uu[:, :r]); ss.append(sv[:r]); vs.append(vt[:r].T)
        local = TruncatedSvd(jnp.asarray(np.stack(us)), jnp.asarray(np.stack(ss)),
                             jnp.asarray(np.stack(vs)))

        def body(t):
            # every worker returns the SAME merged (8m, r) factors — the
            # all_gather inside distributed_merge is the only wire traffic
            return distributed_merge(jax.tree.map(lambda x: x[0], t), "data")

        fn = shard_map(body, mesh=mesh,
                       in_specs=(TruncatedSvd(P("data"), P("data"), P("data")),),
                       out_specs=TruncatedSvd(P(), P(), P()),
                       check_rep=False)
        merged = jax.jit(fn)(local)
        rec = (np.asarray(merged.u) * np.asarray(merged.s)) @ np.asarray(merged.v).T
        uu, sv, vt = np.linalg.svd(M)
        opt = (uu[:, :r] * sv[:r]) @ vt[:r]
        err = float(np.abs(rec - opt).max())

        # --- agree_basis: the consumer path. Per-worker CompressionStates
        # whose trackers hold the shard SVDs; after agreement every worker's
        # v_basis is the consensus right basis and its tracker is an
        # orthonormal truncated SVD of its OWN row block of the consensus.
        from repro.optim.compression import CompressionState, agree_basis, compression_init

        st0 = compression_init(jax.random.PRNGKey(0), m, n, r)
        states = CompressionState(
            v_basis=jnp.broadcast_to(st0.v_basis, (8, n, r)),
            error=jnp.zeros((8, m, n)),
            tracker=local,
        )

        def agree_body(st):
            out = agree_basis(jax.tree.map(lambda x: x[0], st), axis_name="data")
            return jax.tree.map(lambda x: x[None], out)

        per_worker = CompressionState(v_basis=P("data"), error=P("data"),
                                      tracker=TruncatedSvd(P("data"), P("data"), P("data")))
        agreed = jax.jit(shard_map(agree_body, mesh=mesh,
                                   in_specs=(per_worker,), out_specs=per_worker,
                                   check_rep=False))(states)
        # consensus: every worker holds the same v_basis (merged right basis)
        vb = np.asarray(agreed.v_basis)
        v_spread = float(np.abs(vb - vb[0]).max())
        # invariant: every worker's tracker.u is orthonormal again
        tu = np.asarray(agreed.tracker.u)
        orth = max(float(np.abs(tu[w].T @ tu[w] - np.eye(r)).max()) for w in range(8))
        # each tracker reconstructs its own row block of the global rank-r SVD
        block = max(
            float(np.abs((tu[w] * np.asarray(agreed.tracker.s[w]))
                         @ np.asarray(agreed.tracker.v[w]).T
                         - opt[w*m:(w+1)*m]).max())
            for w in range(8)
        )
        print(json.dumps({"err": err, "shape": list(merged.u.shape),
                          "v_spread": v_spread, "orth": orth, "block": block}))
    """)
    assert out["err"] < 1e-4
    assert out["shape"] == [80, 4]
    assert out["v_spread"] < 1e-8
    assert out["orth"] < 1e-8
    assert out["block"] < 1e-4


def test_param_specs_cover_all_archs():
    """Every arch's full-size param tree gets divisibility-consistent specs
    on the production mesh (the dry-run precondition)."""
    out = _run("""
        import json
        import jax
        from repro import configs
        from repro.models.registry import build_model
        from repro.dist import sharding as sh

        bad = []
        for arch in configs.ARCH_IDS:
            cfg = configs.get(arch)
            api = build_model(cfg)
            shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
            specs = sh.param_pspecs(shapes)
            flat_s, _ = jax.tree_util.tree_flatten_with_path(shapes)
            flat_p = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "_cls") or True)
            flat_p = jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, type(jax.sharding.PartitionSpec()))
            )[0]
            mesh_size = {"data": 16, "model": 16}
            for (path, shape), (_, spec) in zip(flat_s, flat_p):
                for dim, ax in zip(shape.shape, tuple(spec) + (None,) * 10):
                    if ax is None:
                        continue
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    total = 1
                    for a in axes:
                        total *= mesh_size[a]
                    if dim % total:
                        bad.append([arch, jax.tree_util.keystr(path), dim, str(ax)])
        print(json.dumps({"bad": bad}))
    """)
    assert out["bad"] == [], out["bad"]
