"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (kv=16) d_ff=1408
vocab=102400, MoE: 2 shared + 64 routed top-6, fine-grained experts.
[arXiv:2401.06066; hf]"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=102400,
        mlp_type="swiglu", norm_type="rmsnorm",
        moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_ff_expert=1408),
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="deepseek-moe-16b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, vocab_size=512, vocab_pad_to=64,
        moe=MoEConfig(n_routed=8, n_shared=1, top_k=2, d_ff_expert=96, capacity_factor=2.0),
        compute_dtype="float32", remat=False,
    )
