"""Framework-level benches: streaming-SVD optimizer primitives + compressed
DP payloads + per-arch smoke step times (CPU; TPU numbers come from the
dry-run roofline, EXPERIMENTS.md)."""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro import configs
from repro.core.engine import default_engine
from repro.core.svd_update import TruncatedSvd
from repro.models.registry import build_model
from repro.optim.compression import compression_init, compress_decompress, wire_bytes
from repro.optim.spectral import spectral_init, spectral_update_basis


def run() -> None:
    rng = np.random.default_rng(0)

    # streaming truncated SVD update (the optimizer-state primitive)
    for (m, n, r) in [(1024, 1024, 16), (4096, 1024, 32), (8192, 8192, 64)]:
        u0 = jnp.asarray(np.linalg.qr(rng.normal(size=(m, r)))[0])
        v0 = jnp.asarray(np.linalg.qr(rng.normal(size=(n, r)))[0])
        t = TruncatedSvd(u0, jnp.asarray(rng.uniform(1, 2, r)), v0)
        a = jnp.asarray(rng.normal(size=m))
        b = jnp.asarray(rng.normal(size=n))
        us = time_fn(default_engine("direct").update_truncated, t, a, b)
        emit(f"framework/truncated_update/m={m}_n={n}_r={r}", us,
             "Brand + Algorithm 6.1 inner solve")

    # spectral basis maintenance per step
    st = spectral_init(jax.random.PRNGKey(0), 2048, 2048, 32)
    g = jnp.asarray(rng.normal(size=(2048, 2048)), jnp.float32)
    us = time_fn(spectral_update_basis, st, g)
    emit("framework/spectral_update/2048x2048_r32", us, "power-iter + rank-1 SVD update")

    # compression payloads
    for (m, n, r) in [(5120, 5120, 32), (8192, 29568, 64)]:
        wb = wire_bytes(m, n, r)
        cs = compression_init(jax.random.PRNGKey(0), m, n, r)
        g = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
        us = time_fn(jax.jit(lambda s, gg: compress_decompress(s, gg)[0]), cs, g)
        emit(f"framework/compress/m={m}_n={n}_r={r}", us,
             f"wire_ratio={wb['ratio']:.1f}x")

    # per-arch smoke train step (CPU wall time; correctness-level signal only)
    from repro.configs.base import ShapeConfig

    shape = ShapeConfig("bench", 32, 2, "train")
    for arch in configs.ARCH_IDS:
        cfg = configs.get_smoke(arch)
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        batch = {
            k: jnp.asarray(rng.integers(0, cfg.vocab_size, v.shape), jnp.int32)
            if v.dtype == jnp.int32
            else jnp.asarray(rng.normal(size=v.shape) * 0.02, v.dtype)
            for k, v in api.input_specs(shape)["batch"].items()
        }
        fn = jax.jit(jax.value_and_grad(api.train_loss))
        us = time_fn(lambda p, bb: fn(p, bb)[0], params, batch)
        emit(f"framework/smoke_step/{arch}", us, "reduced config, CPU")


if __name__ == "__main__":
    run()
