"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["cauchy_matmul_ref", "secular_solve_ref", "nearfield_ref"]


def cauchy_matmul_ref(w, src, anchor_vals, tau, tgt_mask):
    """Oracle for kernels.cauchy_matmul.cauchy_matmul_pallas."""
    denom = (src[:, None] - anchor_vals[None, :]) - tau[None, :]
    safe = jnp.where(denom == 0.0, 1.0, denom)
    c = jnp.where(denom != 0.0, 1.0 / safe, 0.0) * tgt_mask.astype(w.dtype)[None, :]
    return w @ c


def secular_solve_ref(dc, zc2, rho, anchor_vals, lo, hi, *, n_bisect=58, n_newton=4):
    """Oracle for kernels.secular_newton.secular_solve_pallas."""
    dt = dc.dtype
    diff = dc[:, None] - anchor_vals[None, :]

    def w_of(tau):
        delta = diff - tau[None, :]
        safe = jnp.where(delta == 0.0, 1.0, delta)
        inv = jnp.where(delta != 0.0, 1.0 / safe, 0.0)
        w = 1.0 + rho * jnp.sum(zc2[:, None] * inv, axis=0)
        wp = rho * jnp.sum(zc2[:, None] * inv * inv, axis=0)
        return w, wp

    def bis(_, carry):
        lo_c, hi_c = carry
        mid = 0.5 * (lo_c + hi_c)
        w, _ = w_of(mid)
        right = w < 0.0
        return jnp.where(right, mid, lo_c), jnp.where(right, hi_c, mid)

    lo_f, hi_f = lax.fori_loop(0, n_bisect, bis, (lo, hi))
    tau = 0.5 * (lo_f + hi_f)

    def newton(_, t):
        w, wp = w_of(t)
        return jnp.clip(t - w / jnp.maximum(wp, jnp.finfo(dt).tiny), lo_f, hi_f)

    return lax.fori_loop(0, n_newton, newton, tau)


def nearfield_ref(w_near, x_near, av_b, tau_b, tgt_mask):
    """Oracle for kernels.nearfield.nearfield_pallas."""
    denom = (av_b[:, None, :] - x_near[:, :, None]) + tau_b[:, None, :]
    safe = jnp.where(denom == 0.0, 1.0, denom)
    c = jnp.where(denom != 0.0, 1.0 / safe, 0.0) * tgt_mask.astype(w_near.dtype)[:, None, :]
    return jnp.einsum("rbc,bct->rbt", w_near, c)
