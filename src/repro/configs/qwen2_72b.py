"""qwen2-72b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — GQA, QKV bias. [arXiv:2407.10671; hf]

Largest assigned model: 2-D weight sharding (FSDP x TP) is required for the
f32 params + Adam moments to fit 16 GB/chip (DESIGN.md §5)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b", family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab_size=152064, qkv_bias=True,
        mlp_type="swiglu", norm_type="rmsnorm",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="qwen2-72b-smoke", n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab_size=512, vocab_pad_to=64,
        compute_dtype="float32", remat=False,
    )
