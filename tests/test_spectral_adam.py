"""Spectral AdamW (paper-technique optimizer policy) behaviour."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.spectral_adam import (
    moment_memory_ratio,
    spectral_adam_init,
    spectral_adam_update,
)


def test_spectral_adam_optimizes_low_rank_quadratic():
    rng = np.random.default_rng(0)
    m, n, r = 128, 96, 8
    w_true = rng.normal(size=(m, 4)) @ rng.normal(size=(4, n))
    x = jnp.asarray(rng.normal(size=(64, m)))
    y = x @ jnp.asarray(w_true)
    params = {"w": jnp.zeros((m, n)), "b": jnp.zeros((n,))}

    def loss(p):
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    state = spectral_adam_init(jax.random.PRNGKey(0), params, rank=r)
    l0 = float(loss(params))
    grad = jax.jit(jax.grad(loss))
    step = jax.jit(lambda g, s, p: spectral_adam_update(g, s, p, lr=3e-1, weight_decay=0.0))
    for _ in range(60):
        params, state = step(grad(params), state, params)
    l1 = float(loss(params))
    assert l1 < 0.2 * l0, f"{l0} -> {l1}"


def test_moment_memory_shrinks():
    params = {"w": jnp.zeros((4096, 4096)), "ln": jnp.zeros((4096,))}
    assert moment_memory_ratio(params, rank=32) > 20


def test_small_params_fall_through_dense():
    params = {"tiny": jnp.zeros((8, 8))}
    state = spectral_adam_init(jax.random.PRNGKey(0), params, rank=8)
    leaf = jax.tree.leaves(state.leaves, is_leaf=lambda x: hasattr(x, "spectral"))[0]
    assert leaf.spectral is None


def test_basis_refresh_every_keeps_tracker_orthonormal_and_descends():
    """OptimizerConfig.basis_refresh_every wiring: on the refresh cadence the
    tracker goes through compression.agree_tracker (single-worker: local
    re-factorization) — optimization still descends and the orthonormal-basis
    invariant the Brand update needs is restored every cadence."""
    rng = np.random.default_rng(1)
    m, n, r = 96, 64, 4
    w_true = rng.normal(size=(m, 3)) @ rng.normal(size=(3, n))
    x = jnp.asarray(rng.normal(size=(48, m)))
    y = x @ jnp.asarray(w_true)
    params = {"w": jnp.zeros((m, n))}

    def loss(p):
        return jnp.mean((x @ p["w"] - y) ** 2)

    state = spectral_adam_init(jax.random.PRNGKey(0), params, rank=r)
    l0 = float(loss(params))
    grad = jax.jit(jax.grad(loss))
    step = jax.jit(lambda g, s, p: spectral_adam_update(
        g, s, p, lr=3e-1, weight_decay=0.0, basis_refresh_every=5))
    for _ in range(40):  # refresh fires at steps 5, 10, ..., 40 (the last step)
        params, state = step(grad(params), state, params)
    l1 = float(loss(params))
    assert l1 < 0.3 * l0, f"{l0} -> {l1}"

    leaf = jax.tree.leaves(
        state.leaves, is_leaf=lambda t: hasattr(t, "spectral"))[0]
    u = np.asarray(leaf.spectral.tracker.u)
    v = np.asarray(leaf.spectral.tracker.v)
    # the final step was a refresh: agree_tracker re-orthonormalized the
    # (float32) bases to QR/SVD accuracy, erasing accumulated Brand drift
    np.testing.assert_allclose(u.T @ u, np.eye(r), atol=1e-4)
    np.testing.assert_allclose(v.T @ v, np.eye(r), atol=1e-4)
