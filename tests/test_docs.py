"""Docs are part of the product surface (ISSUE 4): the README exists, its
quickstart block runs VERBATIM, and every DESIGN-section reference (§N) in
the top-level docs resolves to a real DESIGN.md heading."""

import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _first_python_block(md: str) -> str:
    m = re.search(r"```python\n(.*?)```", md, re.S)
    assert m, "no ```python block found"
    return m.group(1)


def test_readme_exists_with_required_sections():
    readme = (REPO / "README.md").read_text()
    for needle in ("Quickstart", "Subsystem map", "python -m pytest -x -q",
                   "DESIGN.md", "repro.api"):
        assert needle in readme, f"README.md is missing {needle!r}"


def test_readme_quickstart_runs_verbatim():
    """The acceptance criterion: the quickstart block is executed verbatim
    (same check CI runs as a dedicated step)."""
    code = _first_python_block((REPO / "README.md").read_text())
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=600,
        env={
            "PYTHONPATH": str(REPO / "src"),
            "PATH": "/usr/bin:/bin",
            "JAX_PLATFORMS": "cpu",
            "HOME": "/tmp",
        },
    )
    assert proc.returncode == 0, f"quickstart failed:\n{proc.stderr[-4000:]}"
    assert "sigma_max after update" in proc.stdout
    assert "sketch rank: 8" in proc.stdout


def test_design_section_references_resolve():
    """Every §N referenced from README/ISSUE/CHANGES must be a real
    ``## §N`` heading in DESIGN.md (the docs-link check)."""
    design = (REPO / "DESIGN.md").read_text()
    headings = {int(h) for h in re.findall(r"^## §(\d+)", design, re.M)}
    assert headings, "DESIGN.md has no §N headings?"
    for name in ("README.md", "ISSUE.md", "CHANGES.md"):
        path = REPO / name
        if not path.exists():
            continue
        refs = {int(r) for r in re.findall(r"§(\d+)", path.read_text())}
        missing = refs - headings
        assert not missing, (
            f"{name} references DESIGN.md section(s) {sorted(missing)} "
            f"but DESIGN.md only defines {sorted(headings)}"
        )


def test_design_documents_serving_layer():
    """§9 (the serving layer) must cover the contract pieces ISSUE 4 names."""
    design = (REPO / "DESIGN.md").read_text()
    sec9 = design.split("## §9", 1)[1]
    for needle in ("ServiceSnapshot", "version", "backpressure",
                   "max_in_flight", "bitwise", "restore-after-partial-flush"):
        assert needle.lower() in sec9.lower(), f"DESIGN §9 is missing {needle!r}"
