"""Lowering structured-perturbation ops onto the rank-1 engine (DESIGN.md §10).

``apply(state, op, policy)`` compiles any ``repro.updates.ops`` op into a
minimal *schedule* of existing ``repro.api`` calls and executes it:

* ``RankK``      -> k plan-cached rank-1 ``api.update`` dispatches;
* ``DenseDelta`` -> top-``rank`` randomized sketch of the delta
  (``updates.sketch.sketch_svd``, O(m·n·rank) — no LAPACK SVD anywhere),
  then rank-1 steps;
* ``Sparse``     -> top-``rank`` sketch through the COO projection kernel
  (``sketch.sparse_sketch_svd`` + ``kernels.sparse_proj``) at
  O((m+n)·rank² + nnz·rank) — the delta is never densified;
* ``AppendRows`` / ``AppendCols`` -> zero-pad the state's geometry, then one
  rank-1 step per component of the appended block (dense blocks sketch at
  their full block rank — exact; pre-factored blocks bind directly);
* ``Decay``      -> folded into the singular values for FREE — zero engine
  dispatches;
* ``RemoveRows`` / ``RemoveCols`` -> one rank-1 step per deleted index that
  zeroes the slice (``A - (A e_j) e_j^T``; the pair binds from the CURRENT
  state's factors — zeroing one row never touches another, so a long
  deletion list still precomputes all pairs at once and scans), then a free
  geometry shrink dropping the zeroed factor rows;
* ``Window``     -> decay fold + RemoveRows of everything before the last
  ``size`` rows (no engine dispatch when the state already fits);
* ``Compose``    -> children's schedules concatenated in order, geometry
  threaded through appends and removes.

All low-rank extraction funnels through ``op_low_rank_factors`` — the ONE
sketch entry point (``serve.svd_service`` lowers its op events through the
same helper, so planner and serve can never drift).  The policy's
``sketch_oversample`` / ``sketch_power_iters`` knobs fold into the schedule
cache key, and ``warmup_plan`` AOT-warms the jitted sketch executables
alongside the engine geometries — no sketch compile on the hot path.

``apply_many(states, ops, policy)`` executes many (state, op) pairs in
lockstep waves: at each wave, every op's next rank-1 step is batched with all
same-geometry steps of the *other* ops into ONE ``api.update_many`` engine
dispatch — a planned rank-k update of B streams costs k batched calls, not
B*k singles (``benchmarks/bench_updates.py`` measures the gap).

Schedules are cached by ``(op.spec(), state geometry)`` — the schedule cache
mirrors the engine's plan cache one level up: re-applying a same-shaped op
never re-plans (``schedule_cache_info()``).
"""

from __future__ import annotations

import threading
from typing import NamedTuple, Sequence

import jax.numpy as jnp
from jax import lax

from repro import obs as _obs
from repro.api.policy import UpdatePolicy
from repro.api.state import SvdState, as_state
from repro.api.update import update, update_rank_k, warmup
from repro.updates.ops import (
    AppendCols,
    AppendRows,
    Compose,
    Decay,
    DenseDelta,
    RankK,
    Sparse,
    UpdateOp,
)
from repro.updates.sketch import sketch_svd, sparse_sketch_svd, warmup_sketch

__all__ = [
    "apply",
    "apply_many",
    "lower",
    "op_low_rank_factors",
    "schedule_cache_clear",
    "schedule_cache_info",
    "warmup_plan",
]

_DEFAULT_SKETCH = UpdatePolicy().sketch_params


def _sketch_params(policy: UpdatePolicy | None) -> tuple[int, int]:
    return _DEFAULT_SKETCH if policy is None else policy.sketch_params


class ScheduleCacheInfo(NamedTuple):
    hits: int
    misses: int
    entries: int


_cache: dict[tuple, tuple] = {}
_hits = 0
_misses = 0
_lock = threading.Lock()


def schedule_cache_info() -> ScheduleCacheInfo:
    with _lock:
        return ScheduleCacheInfo(_hits, _misses, len(_cache))


def schedule_cache_clear() -> None:
    global _hits, _misses
    with _lock:
        _cache.clear()
        _hits = 0
        _misses = 0


# ---------------------------------------------------------------------------
# Lowering: op spec -> schedule of abstract steps
#
#   ("decay", path)                 s *= lam            (free)
#   ("pad_rows", p) / ("pad_cols", p)                   (free)
#   ("drop_rows", idx) / ("drop_cols", idx)             (free shrink)
#   ("rank1", path, kind, i)        one engine dispatch
#   ("rank1_scan", path, kind, k)   k dispatches through ONE lax.scan
#
# ``path`` locates the source op inside Compose nesting; ``i`` names the
# component.  Steps are static (no array data) — data binds at execution.
# Downdate kinds (remove_rows / remove_cols / window_rows) bind their rank-1
# pairs from the CURRENT STATE's factors, not from op array data: zeroing
# slice j is ``A - (A e_j) e_j^T``, and since zeroing one slice leaves every
# other one untouched, all pairs of a step run precompute from the same
# factors — which is what lets long deletion lists lower to one scan and
# ``apply_many`` bind a whole same-plan group in one shot.
#
# Long component runs (k >= _SCAN_MIN) lower to a single scanned step
# (``api.update_rank_k``): trace/compile cost stays k-independent instead of
# unrolling k copies of the update body into the jaxpr.  Short runs stay
# unrolled — they interleave with other ops' steps in ``apply_many`` waves.
# ---------------------------------------------------------------------------

_SCAN_MIN = 17

# rank-1 kinds whose (a, b) pairs bind from the current state, not op data
_REMOVE_KINDS = ("remove_rows", "remove_cols", "window_rows")


def _step_policy(policy: UpdatePolicy | None, step: tuple) -> UpdatePolicy | None:
    """Engine policy for one lowered step.

    Downdate steps pin the phase-chain route when the method is ``auto``:
    zeroing a slice leaves every untouched direction's singular value exactly
    in place, so the post-step spectrum is structurally degenerate, and the
    fused kernel's independent left/right pole merges may pick inconsistent
    bases inside a degenerate group (correct spectrum, wrong u/v pairing —
    see the deflation-semantics note in ``kernels.fused_update``).  The
    phase-chain deflation pairs pass-through columns consistently, so remove
    kinds always lower there unless the caller forces a method explicitly.
    """
    if step[2] not in _REMOVE_KINDS:
        return policy
    if policy is None:
        return UpdatePolicy(method="direct")
    if policy.method == "auto":
        return policy.replace(method="direct")
    return policy


def _component_steps(path: tuple, kind: str, count: int) -> list:
    if count >= _SCAN_MIN:
        return [("rank1_scan", path, kind, count)]
    return [("rank1", path, kind, i) for i in range(count)]


def _build(spec: tuple, m: int, n: int, rank: int, is_full: bool, path: tuple):
    kind = spec[0]
    if kind == "rank_k":
        return _component_steps(path, kind, spec[1]), (m, n)
    if kind == "dense_delta":
        return _component_steps(path, kind, spec[1]), (m, n)
    if kind == "sparse":
        return _component_steps(path, kind, spec[2]), (m, n)
    if kind == "decay":
        return [("decay", path)], (m, n)
    if kind in ("append_rows", "append_cols"):
        if is_full:
            raise ValueError(
                f"{kind} requires a truncated state: a full (square-basis) "
                f"state cannot zero-pad its geometry — truncate first"
            )
        p, q = spec[1], spec[2]
        pad = ("pad_rows", p) if kind == "append_rows" else ("pad_cols", p)
        steps = [pad] + _component_steps(path, kind, q)
        out = (m + p, n) if kind == "append_rows" else (m, n + p)
        return steps, out
    if kind in ("remove_rows", "remove_cols"):
        if is_full:
            raise ValueError(
                f"{kind} requires a truncated state: a full (square-basis) "
                f"state cannot shrink its geometry — truncate first"
            )
        idx = spec[1]
        axis, dim = ("rows", m) if kind == "remove_rows" else ("cols", n)
        if idx[-1] >= dim:
            raise ValueError(
                f"{kind} index {idx[-1]} out of range for {dim} {axis}"
            )
        out = (m - len(idx), n) if kind == "remove_rows" else (m, n - len(idx))
        if rank > min(out):
            raise ValueError(
                f"{kind}{idx} shrinks the geometry to {out}, below the "
                f"state's rank {rank} — truncate first"
            )
        drop = ("drop_rows", idx) if kind == "remove_rows" else ("drop_cols", idx)
        return _component_steps(path, kind, len(idx)) + [drop], out
    if kind == "window":
        if is_full:
            raise ValueError(
                "window requires a truncated state: a full (square-basis) "
                "state cannot shrink its geometry — truncate first"
            )
        size = spec[1]
        cut = m - size
        steps = [("decay", path)]
        if cut <= 0:
            return steps, (m, n)
        out = (size, n)
        if rank > min(out):
            raise ValueError(
                f"window({size}) shrinks the geometry to {out}, below the "
                f"state's rank {rank} — truncate first"
            )
        steps += _component_steps(path, "window_rows", cut)
        steps.append(("drop_rows", tuple(range(cut))))
        return steps, out
    if kind == "compose":
        steps: list = []
        for j, child in enumerate(spec[1]):
            sub, (m, n) = _build(child, m, n, rank, is_full, path + (j,))
            steps.extend(sub)
        return steps, (m, n)
    raise ValueError(f"unknown op spec {spec!r}")


def lower(op: UpdateOp, state, policy: UpdatePolicy | None = None) -> tuple:
    """The cached schedule for ``op`` applied to ``state``'s geometry.

    The cache key folds the policy's ``sketch_params`` — sketch-knob changes
    can never serve a schedule planned under different accuracy settings.

    >>> import numpy as np
    >>> from repro.api import SvdState
    >>> from repro.updates.ops import Compose, Decay, RankK
    >>> st = SvdState.from_dense(np.eye(4, 6), rank=2)
    >>> op = Compose((Decay(0.9), RankK(np.zeros((4, 2)), np.zeros((6, 2)))))
    >>> lower(op, st)
    (('decay', (0,)), ('rank1', (1,), 'rank_k', 0), ('rank1', (1,), 'rank_k', 1))
    """
    global _hits, _misses
    st = as_state(state)
    key = (op.spec(), st.m, st.n, st.rank, st.is_full, _sketch_params(policy))
    with _lock:
        plan = _cache.get(key)
        if plan is not None:
            _hits += 1
        else:
            _misses += 1
    if plan is not None:
        if _obs.enabled():
            _obs.registry().counter("planner_schedule_cache_hits").inc()
        return plan
    if _obs.enabled():
        _obs.registry().counter("planner_schedule_cache_misses").inc()
    with _obs.span("schedule_compile", op=key[0][0], m=st.m, n=st.n,
                   rank=st.rank):
        steps, _ = _build(key[0], st.m, st.n, st.rank, st.is_full, ())
    plan = tuple(steps)
    with _lock:
        _cache[key] = plan
    return plan


# ---------------------------------------------------------------------------
# Execution: bind step data from the op, dispatch through repro.api
# ---------------------------------------------------------------------------


def _resolve(op: UpdateOp, path: tuple) -> UpdateOp:
    for j in path:
        op = op.ops[j]
    return op


def op_low_rank_factors(op, m: int, n: int,
                        policy: UpdatePolicy | None = None):
    """(u, s, v) rank-1 components of an op's low-rank block at geometry
    (m, n) — the ONE sketch entry point for planner AND serve (no dense
    ``jnp.linalg.svd`` anywhere on this path).

    ``DenseDelta`` sketches at its rank budget; ``Sparse`` sketches through
    the COO projection kernel; dense append blocks sketch at their full
    block rank (``l >= rank(block)`` — exact); pre-factored append blocks
    bind as carried.  Everything runs inside the jitted sketch executables,
    so ``warmup_plan`` / serve restore AOT-cover it and no per-op host work
    remains.
    """
    oversample, power_iters = _sketch_params(policy)
    if isinstance(op, DenseDelta):
        return sketch_svd(jnp.asarray(op.delta), op.rank,
                          oversample=oversample, power_iters=power_iters)
    if isinstance(op, Sparse):
        # single-pass two-sided sketch: no power_iters knob (sketch module doc)
        return sparse_sketch_svd(op.rows, op.cols, op.vals, m=m, n=n,
                                 k=op.rank, oversample=oversample)
    if isinstance(op, AppendRows) and op.rows is not None:
        return sketch_svd(jnp.asarray(op.rows), op.block_rank,
                          oversample=oversample, power_iters=power_iters)
    if isinstance(op, AppendCols) and op.cols is not None:
        return sketch_svd(jnp.asarray(op.cols), op.block_rank,
                          oversample=oversample, power_iters=power_iters)
    if isinstance(op, (AppendRows, AppendCols)):  # pre-factored block
        return (jnp.asarray(op.u), jnp.asarray(op.s), jnp.asarray(op.v))
    raise TypeError(f"{type(op).__name__} has no low-rank block to extract")


def _block_factors(op, ctx: dict, path: tuple, cur: SvdState,
                   policy: UpdatePolicy | None):
    """Per-apply memo over ``op_low_rank_factors`` (one sketch per block).

    ``Sparse`` needs the CURRENT geometry (appends earlier in a Compose may
    have grown it); appends use their own block shape, deltas their own.
    """
    key = (path, "factors")
    if key not in ctx:
        ctx[key] = op_low_rank_factors(op, cur.m, cur.n, policy)
    return ctx[key]


def _zeros_like_batch(ref, length: int):
    """Zero filler matching ``ref``'s leading (batch) dims with a trailing
    axis of ``length``."""
    return jnp.zeros(ref.shape[:-1] + (length,), ref.dtype)


def _col(x, i: int):
    """Column ``i`` off the last axis — a static slice (cheap on the hot
    path; ``x[..., :, i]`` would lower to a full gather)."""
    return lax.index_in_dim(x, i, axis=-1, keepdims=False)


def _row(x, i: int):
    """Row ``i`` off the second-to-last axis — a static slice."""
    return lax.index_in_dim(x, i, axis=-2, keepdims=False)


def _one_hot(cur: SvdState, dim: int, j: int):
    """``e_j`` of length ``dim`` broadcast over ``cur``'s batch dims."""
    z = jnp.zeros(cur.s.shape[:-1] + (dim,), cur.s.dtype)
    return z.at[..., j].set(1.0)


def _remove_index(src: UpdateOp, kind: str, i: int) -> int:
    """The matrix index zeroed by component ``i`` of a downdate step."""
    return i if kind == "window_rows" else src.idx[i]


def _bind_remove(cur: SvdState, src: UpdateOp, kind: str, i: int):
    """(a, b) zeroing one row/column of the CURRENT state.

    Column j:  A - (A e_j) e_j^T  with  A e_j   = U (s * V[j, :]);
    row i:     A - e_i (A^T e_i)^T with A^T e_i = V (s * U[i, :]).
    Batch-generic: binds correctly off a stacked ``cur`` too (the
    ``apply_many`` group path binds the whole group in one call).
    """
    j = _remove_index(src, kind, i)
    if kind == "remove_cols":
        a = -jnp.einsum("...mr,...r->...m", cur.u, cur.s * _row(cur.v, j))
        return a, _one_hot(cur, cur.n, j)
    b = -jnp.einsum("...nr,...r->...n", cur.v, cur.s * _row(cur.u, j))
    return _one_hot(cur, cur.m, j), b


def _bind_remove_block(cur: SvdState, src: UpdateOp, kind: str, count: int):
    """All ``count`` downdate pairs at once, shaped (…, k, m)/(…, k, n) for
    one scanned dispatch — valid because the slices being zeroed never
    overlap, so every pair reads the same (current) factors."""
    idx = tuple(range(count)) if kind == "window_rows" else src.idx
    take = jnp.asarray(idx)
    if kind == "remove_cols":
        vj = jnp.take(cur.v, take, axis=-2)                   # (..., k, r)
        a_blk = -jnp.einsum("...mr,...kr->...km", cur.u,
                            cur.s[..., None, :] * vj)
        eye = jnp.zeros((count, cur.n), cur.s.dtype)
        eye = eye.at[jnp.arange(count), take].set(1.0)
        b_blk = jnp.broadcast_to(eye, cur.s.shape[:-1] + (count, cur.n))
        return a_blk, b_blk
    uj = jnp.take(cur.u, take, axis=-2)
    b_blk = -jnp.einsum("...nr,...kr->...kn", cur.v,
                        cur.s[..., None, :] * uj)
    eye = jnp.zeros((count, cur.m), cur.s.dtype)
    eye = eye.at[jnp.arange(count), take].set(1.0)
    a_blk = jnp.broadcast_to(eye, cur.s.shape[:-1] + (count, cur.m))
    return a_blk, b_blk


def _bind(cur: SvdState, op: UpdateOp, step: tuple, ctx: dict,
          policy: UpdatePolicy | None = None):
    """The (a, b) pair of one rank-1 step, shaped for the CURRENT geometry."""
    _, path, kind, i = step
    src = _resolve(op, path)
    if kind in _REMOVE_KINDS:
        return _bind_remove(cur, src, kind, i)
    if kind == "rank_k":
        return _col(jnp.asarray(src.u), i), _col(jnp.asarray(src.v), i)
    if kind in ("dense_delta", "sparse"):
        u, s, v = _block_factors(src, ctx, path, cur, policy)
        return _col(u, i) * lax.index_in_dim(s, i, axis=-1), _col(v, i)
    u, s, v = _block_factors(src, ctx, path, cur, policy)
    comp = _col(u, i) * lax.index_in_dim(s, i, axis=-1)
    if kind == "append_rows":
        # the block's rows live at the bottom of the (already padded) state
        a = jnp.concatenate([_zeros_like_batch(comp, cur.m - src.p), comp], axis=-1)
        return a, _col(v, i)
    # append_cols: the block's columns live at the right edge
    v_i = _col(v, i)
    b = jnp.concatenate([_zeros_like_batch(v_i, cur.n - src.p), v_i], axis=-1)
    return comp, b


def _bind_block(cur: SvdState, op: UpdateOp, step: tuple, ctx: dict,
                policy: UpdatePolicy | None = None):
    """The full (k, m)/(k, n) pair blocks of one scanned rank-k step."""
    _, path, kind, _count = step
    src = _resolve(op, path)
    if kind in _REMOVE_KINDS:
        return _bind_remove_block(cur, src, kind, _count)
    if kind == "rank_k":
        return (jnp.swapaxes(jnp.asarray(src.u), -1, -2),
                jnp.swapaxes(jnp.asarray(src.v), -1, -2))
    u, s, v = _block_factors(src, ctx, path, cur, policy)
    comp = jnp.swapaxes(u * s[..., None, :], -1, -2)      # (..., k, rows)
    vt = jnp.swapaxes(v, -1, -2)                          # (..., k, cols)
    if kind in ("dense_delta", "sparse"):
        return comp, vt
    if kind == "append_rows":
        z = jnp.zeros(comp.shape[:-1] + (cur.m - src.p,), comp.dtype)
        return jnp.concatenate([z, comp], axis=-1), vt
    # append_cols
    z = jnp.zeros(vt.shape[:-1] + (cur.n - src.p,), vt.dtype)
    return comp, jnp.concatenate([z, vt], axis=-1)


def _pad_rows(cur: SvdState, p: int) -> SvdState:
    pad = jnp.zeros(cur.u.shape[:-2] + (p, cur.rank), cur.u.dtype)
    return cur.replace(u=jnp.concatenate([cur.u, pad], axis=-2))


def _pad_cols(cur: SvdState, p: int) -> SvdState:
    pad = jnp.zeros(cur.v.shape[:-2] + (p, cur.rank), cur.v.dtype)
    return cur.replace(v=jnp.concatenate([cur.v, pad], axis=-2))


def _drop_rows(cur: SvdState, idx: tuple) -> SvdState:
    """Shrink the geometry by deleting (already-zeroed) rows of ``u``."""
    return cur.replace(u=jnp.delete(cur.u, jnp.array(idx), axis=-2))


def _drop_cols(cur: SvdState, idx: tuple) -> SvdState:
    """Shrink the geometry by deleting (already-zeroed) rows of ``v``."""
    return cur.replace(v=jnp.delete(cur.v, jnp.array(idx), axis=-2))


def _exec_free(cur: SvdState, op: UpdateOp, step: tuple) -> SvdState:
    """Execute a zero-dispatch step (decay fold / geometry pad / shrink)."""
    if step[0] == "decay":
        lam = jnp.asarray(_resolve(op, step[1]).lam)
        return cur.replace(s=cur.s * lam)
    if step[0] == "pad_rows":
        return _pad_rows(cur, step[1])
    if step[0] == "pad_cols":
        return _pad_cols(cur, step[1])
    if step[0] == "drop_rows":
        return _drop_rows(cur, step[1])
    return _drop_cols(cur, step[1])


def apply(state, op: UpdateOp, policy: UpdatePolicy | None = None) -> SvdState:
    """SVD of ``op.apply_dense(state.materialize())`` by planned rank-1
    updates — the single structured entry point (also ``repro.api.apply``).

    ``state`` is any SVD container (full or truncated, single or stacked);
    geometry + policy pick the engine route of every lowered rank-1 step,
    exactly as in ``api.update``.  Geometry-changing ops (appends, removes,
    window) require a truncated state.

    >>> import numpy as np
    >>> from repro import api
    >>> from repro.updates import RankK
    >>> rng = np.random.default_rng(0)
    >>> x = rng.normal(size=(4, 6))
    >>> uk, vk = rng.normal(size=(4, 2)), rng.normal(size=(6, 2))
    >>> out = api.apply(api.SvdState.from_dense(x), RankK(uk, vk))
    >>> ref = np.linalg.svd(x + uk @ vk.T, compute_uv=False)
    >>> bool(np.allclose(out.s, ref, atol=1e-9))
    True
    """
    st = as_state(state)
    plan = lower(op, st, policy)
    ctx: dict = {}
    for step in plan:
        if step[0] == "rank1":
            a, b = _bind(st, op, step, ctx, policy)
            st = update(st, a, b, _step_policy(policy, step))
        elif step[0] == "rank1_scan":
            va, vb = _bind_block(st, op, step, ctx, policy)
            st = update_rank_k(st, va, vb, _step_policy(policy, step))
        else:
            st = _exec_free(st, op, step)
    return st


def apply_many(
    states: Sequence,
    ops: Sequence[UpdateOp],
    policy: UpdatePolicy | None = None,
) -> tuple[SvdState, ...]:
    """Apply ``ops[i]`` to ``states[i]`` with cross-op step batching.

    Execution runs in lockstep waves: free steps (decay folds, geometry
    pads) advance immediately; then every op's next rank-1 step joins one
    ``api.update_many`` dispatch, which groups same-geometry steps into
    single batched engine calls.  A rank-k update of B same-geometry streams
    therefore costs k batched dispatches instead of B*k sequential singles.

    >>> import numpy as np
    >>> from repro import api
    >>> from repro.updates import Decay, RankK
    >>> rng = np.random.default_rng(1)
    >>> sts = [api.SvdState.from_dense(rng.normal(size=(4, 5)), rank=3)
    ...        for _ in range(3)]
    >>> ops = [RankK(rng.normal(size=(4, 2)), rng.normal(size=(5, 2))),
    ...        RankK(rng.normal(size=(4, 2)), rng.normal(size=(5, 2))),
    ...        Decay(0.5)]
    >>> outs = api.apply_many(sts, ops)
    >>> len(outs), outs[2].rank
    (3, 3)
    >>> bool(np.allclose(outs[2].s, 0.5 * np.asarray(sts[2].s)))
    True
    """
    sts = [as_state(s) for s in states]
    if len(sts) != len(ops):
        raise ValueError(f"{len(sts)} states but {len(ops)} ops")
    for i, st in enumerate(sts):
        if st.is_batched:
            raise ValueError(
                f"apply_many takes unbatched states; state {i} is stacked "
                f"(u {st.u.shape}) — call apply() on it directly"
            )
    plans = [lower(op, st, policy) for op, st in zip(ops, sts)]

    out: list[SvdState | None] = [None] * len(sts)
    groups: dict[tuple, list[int]] = {}
    for i, (st, plan) in enumerate(zip(sts, plans)):
        groups.setdefault((st.geometry, plan), []).append(i)

    for (_, plan), idxs in groups.items():
        if len(idxs) == 1:
            i = idxs[0]
            out[i] = apply(sts[i], ops[i], policy)
            continue
        # same plan + geometry: stack ONCE, run the whole schedule batched —
        # every rank-1 step is one engine dispatch for the whole group, and
        # the stack/unstack cost is paid once, not once per step
        group_ops = [ops[i] for i in idxs]
        ctxs: list[dict] = [{} for _ in idxs]
        cur = SvdState(
            u=jnp.stack([sts[i].u for i in idxs]),
            s=jnp.stack([sts[i].s for i in idxs]),
            v=jnp.stack([sts[i].v for i in idxs]),
        )
        for step in plan:
            if step[0] == "rank1":
                if step[2] in _REMOVE_KINDS:
                    # downdate pairs bind from the STATE, not from op data;
                    # the plan embeds the indices (spec ⊂ plan key), so every
                    # group member shares them and ONE batch-generic bind off
                    # the stacked state yields the whole (B, ·) pair —
                    # per-member binds against ``cur`` would read B-fold data
                    a, b = _bind(cur, group_ops[0], step, ctxs[0], policy)
                else:
                    # _bind only reads the (shared) geometry off ``cur``, so
                    # the stacked state binds each member's unbatched vectors
                    pairs = [
                        _bind(cur, op, step, ctx, policy)
                        for op, ctx in zip(group_ops, ctxs)
                    ]
                    a = jnp.stack([p[0] for p in pairs])
                    b = jnp.stack([p[1] for p in pairs])
                cur = update(cur, a, b, _step_policy(policy, step))
            elif step[0] == "rank1_scan":
                if step[2] in _REMOVE_KINDS:
                    va, vb = _bind_block(cur, group_ops[0], step, ctxs[0],
                                         policy)
                else:
                    blocks = [
                        _bind_block(cur, op, step, ctx, policy)
                        for op, ctx in zip(group_ops, ctxs)
                    ]
                    va = jnp.stack([p[0] for p in blocks])
                    vb = jnp.stack([p[1] for p in blocks])
                cur = update_rank_k(cur, va, vb, _step_policy(policy, step))
            elif step[0] == "decay":
                lams = jnp.stack(
                    [jnp.asarray(_resolve(op, step[1]).lam) for op in group_ops]
                )
                cur = cur.replace(s=cur.s * lams[:, None])
            elif step[0] == "pad_rows":
                cur = _pad_rows(cur, step[1])
            elif step[0] == "pad_cols":
                cur = _pad_cols(cur, step[1])
            elif step[0] == "drop_rows":
                cur = _drop_rows(cur, step[1])
            else:
                cur = _drop_cols(cur, step[1])
        for j, i in enumerate(idxs):
            out[i] = SvdState(u=cur.u[j], s=cur.s[j], v=cur.v[j],
                              mesh=sts[i].mesh)
    return tuple(out)


def _sketch_sites(spec: tuple, m: int, n: int):
    """Sketch geometries ``(m, n, k, nnz-or-None)`` the schedule will run,
    threading geometry through appends exactly like ``_build``."""
    kind = spec[0]
    if kind == "dense_delta":
        return [(m, n, spec[1], None)], (m, n)
    if kind == "sparse":
        return [(m, n, spec[2], spec[1])], (m, n)
    if kind == "append_rows":
        sites = [(spec[1], n, spec[2], None)] if spec[3] == "dense" else []
        return sites, (m + spec[1], n)
    if kind == "append_cols":
        sites = [(m, spec[1], spec[2], None)] if spec[3] == "dense" else []
        return sites, (m, n + spec[1])
    if kind == "remove_rows":
        return [], (m - len(spec[1]), n)
    if kind == "remove_cols":
        return [], (m, n - len(spec[1]))
    if kind == "window":
        return [], (min(m, spec[1]), n)
    if kind == "compose":
        sites: list = []
        for child in spec[1]:
            sub, (m, n) = _sketch_sites(child, m, n)
            sites.extend(sub)
        return sites, (m, n)
    return [], (m, n)  # rank_k / decay: no extraction


def warmup_plan(
    policy: UpdatePolicy,
    op: UpdateOp,
    *,
    m: int,
    n: int,
    rank: int | None = None,
    batch: int | None = None,
    dtype=jnp.float64,
):
    """AOT-warm every engine geometry ``op``'s schedule will dispatch
    (appends shift the geometry mid-schedule; each distinct one is warmed),
    plus every jitted sketch executable the schedule's extractions run
    (dense-delta / sparse / dense append blocks, at the policy's sketch
    knobs) — no compile of any kind on the hot path.

    Returns the list of ``(m, n)`` geometries warmed.
    """
    r = rank if rank is not None else m
    spec = op.spec()
    oversample, power_iters = _sketch_params(policy)
    for sm, sn, sk, snnz in _sketch_sites(spec, m, n)[0]:
        warmup_sketch(m=sm, n=sn, k=sk, nnz=snnz, batch=batch,
                      oversample=oversample, power_iters=power_iters,
                      dtype=dtype)
    steps, _ = _build(spec, m, n, r, rank is None, ())
    geoms: list[tuple[int, int]] = []
    entries: dict[tuple[int, int, int | None], UpdatePolicy | None] = {}
    cur_m, cur_n = m, n
    for step in steps:
        if step[0] == "pad_rows":
            cur_m += step[1]
        elif step[0] == "pad_cols":
            cur_n += step[1]
        elif step[0] == "drop_rows":
            cur_m -= len(step[1])
        elif step[0] == "drop_cols":
            cur_n -= len(step[1])
        elif step[0] in ("rank1", "rank1_scan"):
            k = step[3] if step[0] == "rank1_scan" else None
            # remove steps execute under the step-pinned policy (see
            # _step_policy) — warm the route they will actually dispatch
            entries.setdefault((cur_m, cur_n, k), _step_policy(policy, step))
            if (cur_m, cur_n) not in geoms:
                geoms.append((cur_m, cur_n))
    for (gm, gn, k), pol in entries.items():
        warmup(pol, m=gm, n=gn, batch=batch, rank=rank, k=k, dtype=dtype)
    return geoms
