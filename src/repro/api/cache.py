"""Persistent AOT warmup: the XLA compilation cache as a serving feature.

The engine's plan cache (``core.engine``) makes the SECOND call to a
geometry free — within one process.  A restarted service still pays the XLA
compile for every geometry its warmed set replays, which is exactly the
cold-start window a failover is trying to close.  This module threads
``jax``'s persistent compilation cache (``jax_compilation_cache_dir`` — the
maxtext cold-start idiom) through the serving stack as an opt-in:

    api.enable_compilation_cache("/ckpts/xla-cache")   # once, before traffic
    api.warmup(policy, m=512, n=768, rank=16)          # compiles -> disk

    # ... process dies; a fresh one restores:
    SvdFleet.restore("/ckpts/fleet", cache_dir="/ckpts/xla-cache")
    # warmed-set replay hits the disk cache: ZERO XLA recompiles

Every compile is persisted (the min-compile-time and min-entry-size gates
are zeroed), so "no new cache entries after restore" is an observable
zero-recompile proof — pinned by the fresh-process test in
tests/test_fleet.py.  The cache key includes the XLA build and flags, so a
stale cache is never wrong, only cold.
"""

from __future__ import annotations

import os
from pathlib import Path

import jax

__all__ = ["enable_compilation_cache", "compilation_cache_entries"]


def enable_compilation_cache(cache_dir: str | Path) -> Path:
    """Opt this process into the persistent XLA compilation cache at
    ``cache_dir`` (created if missing).  Idempotent; returns the directory.

    Call it BEFORE the executables you want cached are built — in serving
    terms, before ``api.warmup`` / service ``restore`` replay the warmed
    geometry set.  Threaded through ``SvdService.restore(cache_dir=)`` and
    ``SvdFleet.restore(cache_dir=)`` so failover restores compile nothing
    that any previous process on this cache already compiled.
    """
    cache_dir = Path(cache_dir)
    cache_dir.mkdir(parents=True, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", str(cache_dir))
    # persist EVERY compile: the serving executables are small and the point
    # is a bitwise-observable "no new entries" zero-recompile contract
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    try:
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except AttributeError:  # older jax: no size gate — already persists all
        pass
    return cache_dir


def compilation_cache_entries(cache_dir: str | Path) -> int:
    """Number of persisted executables in a compilation cache directory
    (0 for a missing dir).  A warm restore adds none — the observable the
    zero-recompile test asserts on."""
    cache_dir = Path(cache_dir)
    if not cache_dir.is_dir():
        return 0
    return sum(1 for name in os.listdir(cache_dir)
               if not name.startswith("."))
