"""``kernels.sparse_proj`` — interpret-mode Pallas vs XLA fallback vs a
numpy loop oracle (DESIGN.md §12).

The sparse gather/scatter projection is the only dense contact the
``Sparse`` op's lowering makes with the matrix geometry, so the kernel is
pinned three ways: against a literal per-entry numpy loop, against the XLA
``segment_sum`` fallback the dispatcher uses off-TPU, and batched-vs-loop
(the custom_vmap batch-in-grid fold must equal B sequential calls).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.sparse_proj import (
    sparse_project,
    sparse_project_pallas,
    sparse_project_pallas_batched,
    sparse_project_xla,
)

RNG = np.random.default_rng(23)


def _coo(m, n, nnz, rng=RNG, dup=True):
    """Random COO with (by default) guaranteed duplicate coordinates — the
    scatter-accumulate path must sum collisions, not overwrite."""
    rows = rng.integers(0, m, nnz).astype(np.int32)
    cols = rng.integers(0, n, nnz).astype(np.int32)
    if dup and nnz >= 2:
        rows[1], cols[1] = rows[0], cols[0]
    vals = rng.normal(size=nnz)
    return rows, cols, vals


def _oracle(rows, cols, vals, mat, out_rows):
    out = np.zeros((out_rows, mat.shape[-1]), dtype=np.asarray(mat).dtype)
    for r, c, v in zip(rows, cols, vals):
        out[r, :] += v * np.asarray(mat)[c, :]
    return out


@pytest.mark.parametrize("m,n,nnz,k", [
    (16, 16, 7, 4),      # tiny, nnz < block floor
    (64, 48, 100, 8),    # duplicates, rectangular
    (128, 96, 512, 16),  # exactly one block
    (100, 90, 1300, 5),  # non-multiple of block_e -> padded tail block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_pallas_interpret_vs_oracle(m, n, nnz, k, dtype):
    rows, cols, vals = _coo(m, n, nnz)
    mat = RNG.normal(size=(n, k))
    out = sparse_project_pallas(
        jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals, dtype),
        jnp.asarray(mat, dtype), m, interpret=True)
    want = _oracle(rows, cols, vals, mat.astype(np.asarray(out).dtype), m)
    tol = 1e-4 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(out), want, rtol=tol, atol=tol)


@pytest.mark.parametrize("m,n,nnz,k", [(64, 48, 100, 8), (100, 90, 700, 5)])
def test_xla_fallback_vs_oracle(m, n, nnz, k):
    rows, cols, vals = _coo(m, n, nnz)
    mat = RNG.normal(size=(n, k))
    out = sparse_project_xla(rows, cols, jnp.asarray(vals),
                             jnp.asarray(mat), m)
    np.testing.assert_allclose(np.asarray(out), _oracle(rows, cols, vals, mat, m),
                               rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("batch_coords", [True, False])
def test_batched_kernel_equals_loop(batch_coords):
    """(B, nnz) batched launch == B sequential single launches; shared
    (unbatched) coordinates broadcast to the same answer."""
    m, n, nnz, k, B = 48, 40, 90, 6, 3
    rows, cols, _ = _coo(m, n, nnz)
    bvals = RNG.normal(size=(B, nnz))
    bmat = RNG.normal(size=(B, n, k))
    if batch_coords:
        brows = np.stack([rows] * B)
        bcols = np.stack([cols] * B)
    else:
        brows, bcols = rows, cols
    out = sparse_project(brows, bcols, jnp.asarray(bvals), jnp.asarray(bmat),
                         m, interpret=True)
    assert out.shape == (B, m, k)
    for i in range(B):
        single = sparse_project_pallas(
            jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(bvals[i]),
            jnp.asarray(bmat[i]), m, interpret=True)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(single),
                                   rtol=1e-12, atol=1e-12)


def test_padding_entries_are_noops():
    """Zero-valued entries at coordinate (0, 0) — the static-nnz padding
    convention — must leave the projection bitwise unchanged."""
    m, n, nnz, k = 32, 24, 40, 4
    rows, cols, vals = _coo(m, n, nnz)
    mat = jnp.asarray(RNG.normal(size=(n, k)))
    base = sparse_project_pallas(jnp.asarray(rows), jnp.asarray(cols),
                                 jnp.asarray(vals), mat, m, interpret=True)
    pad = 13
    padded = sparse_project_pallas(
        jnp.asarray(np.concatenate([rows, np.zeros(pad, np.int32)])),
        jnp.asarray(np.concatenate([cols, np.zeros(pad, np.int32)])),
        jnp.asarray(np.concatenate([vals, np.zeros(pad)])),
        mat, m, interpret=True)
    assert bool(jnp.all(base == padded))


def test_transpose_projection():
    """Swapping rows/cols projects S^T — the co-range pass of the sketch."""
    m, n, nnz, k = 40, 30, 60, 5
    rows, cols, vals = _coo(m, n, nnz)
    mat = RNG.normal(size=(m, k))
    out = sparse_project_pallas(jnp.asarray(cols), jnp.asarray(rows),
                                jnp.asarray(vals), jnp.asarray(mat), n,
                                interpret=True)
    S = np.zeros((m, n))
    for r, c, v in zip(rows, cols, vals):
        S[r, c] += v
    np.testing.assert_allclose(np.asarray(out), S.T @ mat, rtol=1e-12, atol=1e-12)


def test_dispatch_xla_off_tpu_jits_and_vmaps():
    """The public dispatcher off-TPU: jit-clean, vmap folds shared coords."""
    m, n, nnz, k, B = 32, 28, 50, 4, 2
    rows, cols, vals = _coo(m, n, nnz)
    bvals = jnp.asarray(np.stack([vals, 2.0 * vals]))
    mat = jnp.asarray(RNG.normal(size=(n, k)))

    f = jax.jit(lambda v: sparse_project(rows, cols, v, mat, m))
    single = f(jnp.asarray(vals))
    np.testing.assert_allclose(np.asarray(single),
                               _oracle(rows, cols, vals, np.asarray(mat), m),
                               rtol=1e-12, atol=1e-12)
    batched = sparse_project(rows, cols, bvals, mat, m)
    np.testing.assert_allclose(np.asarray(batched[1]), 2.0 * np.asarray(single),
                               rtol=1e-12, atol=1e-12)
