"""Serving: LM engine (prefill/decode) + the streaming SVD-update service.

``serve.engine``      — batched token generation over ModelApi caches.
``serve.svd_service`` — micro-batching rank-1 SVD-update service: many
                        streams enqueue (a, b) pairs, each flush is one
                        batched ``core.engine.SvdEngine`` call (batch axis
                        shardable over ``launch.mesh``).
"""

from repro.serve.svd_service import SvdService, SvdServiceStats  # noqa: F401
