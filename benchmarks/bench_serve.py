"""Async vs. synchronous flush throughput of ``serve.SvdService`` (DESIGN.md §9).

The service's double-buffered dispatch lets the host assemble and dispatch
round k+1 while the device still computes round k; the synchronous baseline
(``max_in_flight=0``) blocks on every round's outputs before returning.
This bench feeds identical traffic (STREAMS streams x ROUNDS events each,
auto-flushing batched rounds) through both modes and reports two numbers:

* end-to-end updates/s (feed + drain): the async mode overlaps round k's
  device compute with round k+1's host-side batch assembly. On this CPU
  container the two run within scheduler noise of each other (parity to
  ~1.2x run-to-run; modes are interleaved and best-of-REPEAT to damp
  drift) — the overlap window that makes the double buffer pay is an
  accelerator property, where device rounds are long and the host is free;
* worst-case enqueue stall, recorded for observability. On CPU it is
  dominated by the host-side ``jnp.stack`` batch assembly that both modes
  pay, so expect parity here; the sync-mode device wait it would expose
  only dominates on accelerator backends.

A third experiment reports the latency SLO view: Poisson open-loop arrivals
at LOAD x the async sustained rate through ``common.open_loop`` (the same
harness bench_fleet uses), with per-event enqueue-to-visible p50/p99.

CSV rows (benchmarks/run.py style):
  bench_serve/<mode>/B=<streams>,us,updates_per_s=... max_enqueue_us=...
  bench_serve/latency/<mode>,p99_us,p50_us=... rate_hz=...

and a machine-readable summary at benchmarks/BENCH_serve.json.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit, open_loop, poisson_arrivals
from repro.api import SvdState, UpdatePolicy
from repro.serve import SvdService

# Geometry where a flush round carries real device work (tall factors):
# below ~(256, 384) the CPU round is host-assembly-bound and async == sync.
M, N, RANK = 512, 768, 16
STREAMS = 16
ROUNDS = 8             # events per stream
REPEAT = 5

OPEN_EVENTS = 128      # open-loop latency experiment length
LOAD = 0.5             # offered rate as a fraction of async sustained rate

OUT = Path(__file__).parent / "BENCH_serve.json"


def _service(max_in_flight: int) -> SvdService:
    rng = np.random.default_rng(0)
    svc = SvdService(
        max_batch=STREAMS,
        max_in_flight=max_in_flight,
        policy=UpdatePolicy(method="direct"),
    )
    for i in range(STREAMS):
        svc.register(
            f"s{i}",
            SvdState.from_factors(
                np.linalg.qr(rng.normal(size=(M, RANK)))[0],
                np.sort(np.abs(rng.normal(size=RANK)))[::-1].copy(),
                np.linalg.qr(rng.normal(size=(N, RANK)))[0],
            ),
        )
    return svc


def _traffic():
    rng = np.random.default_rng(1)
    return [
        (f"s{i % STREAMS}",
         jnp.asarray(rng.normal(size=M)), jnp.asarray(rng.normal(size=N)))
        for i in range(STREAMS * ROUNDS)
    ]


def _one_pass(max_in_flight: int, traffic) -> tuple[float, float, SvdService]:
    """(wall seconds, worst single-enqueue seconds, service) for one feed+drain.

    A fresh service per pass (same initial streams), but the policy-derived
    default engine is process-shared — the plan cache stays warm across
    passes, so steady-state dispatch is what gets timed.
    """
    svc = _service(max_in_flight)
    stall = 0.0
    t0 = time.perf_counter()
    for sid, a, b in traffic:
        e0 = time.perf_counter()
        svc.enqueue(sid, a, b)
        stall = max(stall, time.perf_counter() - e0)
    svc.drain()
    return time.perf_counter() - t0, stall, svc


def _latency(max_in_flight: int, rate_hz: float, *, seed: int) -> dict:
    """Enqueue-to-visible p50/p99 under Poisson open-loop load at rate_hz."""
    svc = _service(max_in_flight)
    traffic = _traffic()[:OPEN_EVENTS]
    arrivals = poisson_arrivals(rate_hz, OPEN_EVENTS, seed=seed)
    return open_loop(
        lambda ev: svc.enqueue(*ev), svc.take_visible, svc.drain,
        traffic, arrivals,
    )


def run() -> dict:
    traffic = _traffic()
    _one_pass(0, traffic)      # warm the shared plan cache (compile round)

    # Interleave the modes so slow machine drift hits both equally; keep the
    # best pass per mode, with stats from that SAME pass so the JSON
    # artifact is internally consistent.
    best = {"sync": None, "async": None}
    for _ in range(REPEAT):
        for mode, mif in (("sync", 0), ("async", 2)):
            t, stall, svc = _one_pass(mif, traffic)
            if best[mode] is None or t < best[mode][0]:
                best[mode] = (t, stall, svc)

    results = {}
    runs = {"sync": best["sync"], "async": best["async"]}
    for mode, (t, stall, svc) in runs.items():
        ups = len(traffic) / t
        results[mode] = {
            "max_in_flight": svc.max_in_flight,
            "seconds": t,
            "updates_per_s": ups,
            "max_enqueue_stall_us": stall * 1e6,
            "flush_rounds": svc.stats.rounds,
            "backpressure_waits": svc.stats.backpressure_waits,
            "in_flight_peak": svc.stats.in_flight_peak,
        }
        emit(
            f"bench_serve/{mode}/B={STREAMS}",
            t * 1e6,
            f"updates_per_s={ups:.0f} max_enqueue_us={stall * 1e6:.0f}",
        )

    # open-loop latency columns (shared harness with bench_fleet)
    rate = LOAD * results["async"]["updates_per_s"]
    for mode, mif in (("sync", 0), ("async", 2)):
        _latency(mif, rate, seed=2)                 # warm the shapes
        lat = _latency(mif, rate, seed=3)           # measured
        results[mode]["latency"] = lat
        emit(f"bench_serve/latency/{mode}", lat["p99_us"],
             f"p50_us={lat['p50_us']:.0f} rate_hz={rate:.0f} "
             f"sustained_hz={lat['sustained_rate_hz']:.0f}")

    throughput_speedup = results["sync"]["seconds"] / results["async"]["seconds"]
    stall_ratio = (results["sync"]["max_enqueue_stall_us"]
                   / results["async"]["max_enqueue_stall_us"])
    emit(f"bench_serve/speedup/B={STREAMS}", results["async"]["seconds"] * 1e6,
         f"async_vs_sync={throughput_speedup:.2f}x "
         f"enqueue_stall_reduction={stall_ratio:.1f}x")
    summary = {
        "m": M,
        "n": N,
        "rank": RANK,
        "streams": STREAMS,
        "events": len(traffic),
        "sync": results["sync"],
        "async": results["async"],
        "async_vs_sync_throughput": throughput_speedup,
        "enqueue_stall_reduction": stall_ratio,
    }
    OUT.write_text(json.dumps(summary, indent=2) + "\n")
    print(f"wrote {OUT}")
    return summary


if __name__ == "__main__":
    run()
