import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> record.

Three cells (selected from the baseline roofline table — worst fraction /
most collective-bound / most technique-representative plumbing; see
EXPERIMENTS.md §Perf for the napkin math per hypothesis):

  A. qwen2-72b      x train_4k    (biggest dense; memory+collective bound)
  B. deepseek-v2-lite x prefill_32k (most collective-bound; MoE+MLA)
  C. qwen1.5-32b    x decode_32k  (worst fit: MHA cache replicates on model)

Each variant re-runs the dry-run cell with a method tag; JSONs land next to
the baselines for before/after diffing.
"""

import argparse
import traceback
from pathlib import Path

from repro import configs
from repro.launch.dryrun import run_cell

VARIANTS = {
    # ---- cell A: qwen2-72b train_4k
    ("qwen2-72b", "train_4k"): [
        # H1: remat recompute inflates HLO flops ~1.33x; saving matmul
        # outputs removes most recompute at modest memory cost.
        ("remat-dots", lambda c: c.replace(remat_policy="dots"), {}),
        # H2: the (s x s) score tensor dominates "bytes accessed" at seq 4k;
        # blockwise attention removes its HBM residency.
        ("flash1k", lambda c: c.replace(attn_block_k=1024), {}),
        # H3: both.
        ("flash1k+dots", lambda c: c.replace(attn_block_k=1024, remat_policy="dots"), {}),
        # H8: peak is only 3.4 GB of 16 — remat over-saves; dropping it
        # removes the recompute forward entirely (flops -~25%).
        ("no-remat", lambda c: c.replace(remat=False), {}),
        # H9: 9.6 TB/step of all-reduce = XLA reducing partial matmul
        # products over the FSDP-sharded contraction dim. Gather bf16 weights
        # at use instead (ZeRO-3): ~17 GB of all-gather replaces it.
        ("zero3-gather", lambda c: c.replace(fsdp_gather_params=True), {}),
        ("zero3+no-remat", lambda c: c.replace(fsdp_gather_params=True, remat=False), {}),
    ],
    # ---- cell B: deepseek-v2-lite prefill_32k
    ("deepseek-v2-lite-16b", "prefill_32k"): [
        # H4: GSPMD reshards the MoE dispatch tensors through all-gathers;
        # explicit EP constraints keep group on data / experts on model.
        ("moe-ep", lambda c: c.replace(moe_shard_constraints=True), {}),
        # H5: the absorbed-MLA (h, sq, sk) scores at 32k dominate memory;
        # query chunking shrinks them 16x.
        ("mla-qchunk", lambda c: c.replace(mla_q_chunk=2048), {}),
        ("moe-ep+qchunk", lambda c: c.replace(moe_shard_constraints=True,
                                              mla_q_chunk=2048), {}),
        # H9b: same contraction-dim AR pathology as cell A.
        ("zero3-gather", lambda c: c.replace(fsdp_gather_params=True), {}),
        ("zero3+qchunk", lambda c: c.replace(fsdp_gather_params=True,
                                             mla_q_chunk=2048), {}),
    ],
    # ---- cell C: qwen1.5-32b decode_32k
    ("qwen1.5-32b", "decode_32k"): [
        # H6: kv heads (40) don't divide model=16 -> cache replicated 16x;
        # shard the sequence dim over model instead.
        ("kv-seq-shard", lambda c: c, {"cache_seq_fallback": True}),
        # H7: int8 KV halves cache bytes again -> fits 16 GB.
        ("kv-seq-shard+int8", lambda c: c.replace(kv_cache_dtype="int8"),
         {"cache_seq_fallback": True}),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/dryrun")
    ap.add_argument("--cell", default=None, help="arch:shape filter")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    out_dir = Path(args.out)

    for (arch, shape), variants in VARIANTS.items():
        if args.cell and args.cell != f"{arch}:{shape}":
            continue
        for tag, mutate, kw in variants:
            try:
                cfg = mutate(configs.get(arch))
                # baseline comparability: cell C's baseline ran without the
                # seq-shard fallback; variants opt in explicitly
                kwargs = {"cache_seq_fallback": False}
                kwargs.update(kw)
                r = run_cell(arch, shape, multi_pod=args.multi_pod,
                             out_dir=out_dir, method_tag=tag,
                             cfg_override=cfg, **kwargs)
                rt = r["roofline"]
                print(f"OK {arch}/{shape}/{tag}: "
                      f"t_comp={rt['t_compute_s']*1e3:.1f}ms "
                      f"t_mem={rt['t_memory_s']*1e3:.1f}ms "
                      f"t_coll={rt['t_collective_s']*1e3:.1f}ms "
                      f"peak={r['memory']['peak_bytes'] and r['memory']['peak_bytes']/1e9:.1f}GB",
                      flush=True)
            except Exception as e:
                print(f"FAIL {arch}/{shape}/{tag}: {e}", flush=True)
                traceback.print_exc(limit=3)


if __name__ == "__main__":
    main()
