"""Pallas TPU kernel: on-the-fly Cauchy matrix product (Trummer hot spot).

Computes  out[r, i] = sum_j w[r, j] / ((src_j - anchor_i) - tau_i) * tmask_i

The Cauchy matrix is *generated in VMEM* from the pole/root vectors and fed
straight to the MXU — it never exists in HBM. Per (BR, BM) output tile the
HBM traffic is O(BR*BN + BN + BM) instead of O(BN*BM) for a materialized C:
this moves the dense update from memory-bound to compute-bound on TPU
(roofline analysis in EXPERIMENTS.md §Perf).

Tiling: grid (R/BR, M/BM, N/BN), accumulation over the innermost N axis via
output revisiting. Block sizes default to MXU-aligned 128/256/512.

Stable denominators: targets are passed in anchored form
(mu_i = anchor_vals_i + tau_i, anchor values gathered *outside*), matching
core.cauchy.cauchy_matmul_stable — near-pole accuracy is preserved.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["cauchy_matmul_pallas", "cauchy_matmul_pallas_batched"]


def _kernel(w_ref, src_ref, av_ref, tau_ref, tmask_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[...]            # (BR, BN)
    src = src_ref[...]        # (1, BN)
    av = av_ref[...]          # (1, BM)
    tau = tau_ref[...]        # (1, BM)
    tm = tmask_ref[...]       # (1, BM)

    # on-the-fly Cauchy tile: (BN, BM)
    denom = (src[0, :, None] - av[0, None, :]) - tau[0, None, :]
    safe = jnp.where(denom == 0.0, 1.0, denom)
    c = jnp.where(denom != 0.0, 1.0 / safe, 0.0) * tm[0, None, :]
    out_ref[...] += jnp.dot(w, c, preferred_element_type=out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_r", "block_m", "block_n", "interpret")
)
def cauchy_matmul_pallas(
    w: jax.Array,
    src: jax.Array,
    anchor_vals: jax.Array,
    tau: jax.Array,
    tgt_mask: jax.Array,
    *,
    block_r: int = 128,
    block_m: int = 256,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """out[r, i] = sum_j w[r, j] / ((src_j - anchor_vals_i) - tau_i).

    Invalid sources must be pre-zeroed in ``w`` (weights carry the mask);
    invalid targets are zeroed via ``tgt_mask``.
    """
    r, n = w.shape
    m = anchor_vals.shape[0]
    dt = w.dtype

    br = min(block_r, max(8, r))
    bm = min(block_m, max(8, m))
    bn = min(block_n, max(8, n))

    pad_r = (-r) % br
    pad_m = (-m) % bm
    pad_n = (-n) % bn

    # pad with values that cannot create zero denominators
    w_p = jnp.pad(w, ((0, pad_r), (0, pad_n)))
    src_p = jnp.pad(src, (0, pad_n), constant_values=jnp.asarray(1e30, dt))[None, :]
    av_p = jnp.pad(anchor_vals, (0, pad_m), constant_values=jnp.asarray(-1e30, dt))[None, :]
    tau_p = jnp.pad(tau, (0, pad_m))[None, :]
    tm_p = jnp.pad(tgt_mask.astype(dt), (0, pad_m))[None, :]

    rp, np_ = w_p.shape
    mp = av_p.shape[1]
    grid = (rp // br, mp // bm, np_ // bn)

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bn), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, k)),
            pl.BlockSpec((1, bm), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bm), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bm), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((br, bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, mp), dt),
        interpret=interpret,
    )(w_p, src_p, av_p, tau_p, tm_p)
    return out[:r, :m]


# ---------------------------------------------------------------------------
# Batched variant: the engine's per-update Cauchy geometries are independent,
# so the batch axis folds straight into the grid — one kernel launch covers
# B updates with the same VMEM tiling as the single-instance kernel.
# ---------------------------------------------------------------------------


def _kernel_batched(w_ref, src_ref, av_ref, tau_ref, tmask_ref, out_ref):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[0]              # (BR, BN)
    src = src_ref[0]          # (1, BN)
    av = av_ref[0]            # (1, BM)
    tau = tau_ref[0]          # (1, BM)
    tm = tmask_ref[0]         # (1, BM)

    denom = (src[0, :, None] - av[0, None, :]) - tau[0, None, :]
    safe = jnp.where(denom == 0.0, 1.0, denom)
    c = jnp.where(denom != 0.0, 1.0 / safe, 0.0) * tm[0, None, :]
    out_ref[0] += jnp.dot(w, c, preferred_element_type=out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_r", "block_m", "block_n", "interpret")
)
def cauchy_matmul_pallas_batched(
    w: jax.Array,
    src: jax.Array,
    anchor_vals: jax.Array,
    tau: jax.Array,
    tgt_mask: jax.Array,
    *,
    block_r: int = 128,
    block_m: int = 256,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """out[b, r, i] = sum_j w[b, r, j] / ((src_bj - anchor_vals_bi) - tau_bi).

    ``w``: (B, R, N); ``src``: (B, N); ``anchor_vals``/``tau``/``tgt_mask``:
    (B, M). Grid is (B, R/BR, M/BM, N/BN) — batch outermost, accumulation
    over N innermost (output revisiting), so per-batch tiling matches the
    single-instance kernel exactly.
    """
    bsz, r, n = w.shape
    m = anchor_vals.shape[1]
    dt = w.dtype

    br = min(block_r, max(8, r))
    bm = min(block_m, max(8, m))
    bn = min(block_n, max(8, n))

    pad_r = (-r) % br
    pad_m = (-m) % bm
    pad_n = (-n) % bn

    # pad with values that cannot create zero denominators
    w_p = jnp.pad(w, ((0, 0), (0, pad_r), (0, pad_n)))
    src_p = jnp.pad(src, ((0, 0), (0, pad_n)), constant_values=jnp.asarray(1e30, dt))[:, None, :]
    av_p = jnp.pad(anchor_vals, ((0, 0), (0, pad_m)), constant_values=jnp.asarray(-1e30, dt))[:, None, :]
    tau_p = jnp.pad(tau, ((0, 0), (0, pad_m)))[:, None, :]
    tm_p = jnp.pad(tgt_mask.astype(dt), ((0, 0), (0, pad_m)))[:, None, :]

    _, rp, np_ = w_p.shape
    mp = av_p.shape[2]
    grid = (bsz, rp // br, mp // bm, np_ // bn)

    out = pl.pallas_call(
        _kernel_batched,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, br, bn), lambda b, i, j, k: (b, i, k)),
            pl.BlockSpec((1, 1, bn), lambda b, i, j, k: (b, 0, k)),
            pl.BlockSpec((1, 1, bm), lambda b, i, j, k: (b, 0, j)),
            pl.BlockSpec((1, 1, bm), lambda b, i, j, k: (b, 0, j)),
            pl.BlockSpec((1, 1, bm), lambda b, i, j, k: (b, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, br, bm), lambda b, i, j, k: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, rp, mp), dt),
        interpret=interpret,
    )(w_p, src_p, av_p, tau_p, tm_p)
    return out[:, :r, :m]
