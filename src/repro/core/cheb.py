"""Chebyshev nodes and Lagrange interpolation operators (paper App. D.1).

Shared by the FMM (core/fmm.py) and its tests. Nodes follow the paper's
Eq. (D.1):  t_i = cos((2i-1)/p * pi/2), i = 1..p  (first-kind Chebyshev
nodes on [-1, 1]).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["cheb_nodes", "lagrange_eval", "lagrange_matrix"]


def cheb_nodes(p: int, dtype=jnp.float64) -> jax.Array:
    """First-kind Chebyshev nodes, paper Eq. (D.1), ascending."""
    i = jnp.arange(1, p + 1, dtype=dtype)
    t = jnp.cos((2.0 * i - 1.0) / (2.0 * p) * jnp.pi)
    return t[::-1]  # ascending


def lagrange_eval(t: jax.Array, x: jax.Array) -> jax.Array:
    """L[q, k] = u_q(x_k): Lagrange basis at nodes ``t`` evaluated at ``x``.

    Paper Eq. (D.2). Direct product form — stable for p <= ~40 in f64.
    x may be any shape; output is (p, *x.shape).
    """
    p = t.shape[0]
    xf = x.reshape(-1)
    # num[q, k] = prod_{j != q} (x_k - t_j); den[q] = prod_{j != q} (t_q - t_j)
    diff_x = xf[None, :] - t[:, None]  # (p=j, K)
    eye = jnp.eye(p, dtype=bool)
    # for each q: product over j != q of diff_x[j, k]
    diff_x_b = jnp.broadcast_to(diff_x[None, :, :], (p, p, xf.shape[0]))
    num = jnp.prod(jnp.where(eye[:, :, None], 1.0, diff_x_b), axis=1)  # (p=q, K)
    diff_t = t[:, None] - t[None, :]
    den = jnp.prod(jnp.where(eye, 1.0, diff_t), axis=1)  # (p,)
    out = num / den[:, None]
    return out.reshape((p,) + x.shape)


def lagrange_matrix(t: jax.Array, x: jax.Array) -> jax.Array:
    """Interpolation matrix P[k, q] = u_q(x_k): f(x) ≈ P @ f(t)."""
    return jnp.moveaxis(lagrange_eval(t, x), 0, -1)
