"""Fault-tolerant checkpointing: atomic writes, manifest, auto-resume.

Layout:
  <dir>/step_000123/
      arrays.npz          (flattened pytree leaves)
      treedef.json        (pytree structure + leaf names)
      aux.json            (optional caller-owned JSON payload, see ``aux=``)
      MANIFEST.json       (step, written_at, leaf checksums, COMPLETE flag)
  <dir>/latest            (text file with the last COMPLETE step)

Guarantees:
* torn writes never count: MANIFEST is written *after* arrays, and ``latest``
  is updated with os.replace (atomic on POSIX) only after the manifest.
* restore validates the manifest checksum set before loading.
* checkpoints are mesh-independent (full arrays gathered to host), so a
  restart may use a different device count — elastic scaling: training
  re-meshes via ``train.elastic.plan_mesh``/``reshard``; a serving fleet
  re-shards via ``SvdFleet.restore(num_shards=...)`` over the same
  mesh-independent leaves (``repro.fleet``).
* leaves round-trip **bitwise**: ``np.savez`` preserves dtype and bits, and
  a structure-free restore (``tree_like=None``) hands them back uncast — the
  foundation of the serving layer's restore-exactness contract (DESIGN §9).

Self-describing checkpoints: a caller that cannot know its pytree structure
ahead of restore (e.g. ``serve.SvdService`` — stream count and queue depths
are runtime state) saves a JSON ``aux`` spec alongside the arrays, then
restores with ``load_aux`` + ``restore(dir, None)`` and rebuilds the
structure from the spec.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from pathlib import Path

import jax
import numpy as np

__all__ = ["save", "restore", "load_aux", "latest_step", "available_steps"]


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(np.asarray(leaf))
    return names, leaves, jax.tree.structure(tree)


def save(ckpt_dir: str | Path, step: int, tree, *, keep: int = 3, aux=None) -> Path:
    """Atomically write ``tree`` (any pytree) as checkpoint ``step``.

    ``aux``: optional JSON-serializable payload written to ``aux.json`` and
    covered by the manifest checksum set — a structure spec, config dump, or
    any metadata the restoring process needs before it can rebuild the tree
    (read it back with ``load_aux``).
    """
    ckpt_dir = Path(ckpt_dir)
    step_dir = ckpt_dir / f"step_{step:09d}"
    tmp_dir = ckpt_dir / f".tmp_step_{step:09d}"
    if tmp_dir.exists():
        shutil.rmtree(tmp_dir)
    tmp_dir.mkdir(parents=True)

    names, leaves, _ = _flatten_with_names(tree)
    arrays = {f"leaf_{i}": leaf for i, leaf in enumerate(leaves)}
    np.savez(tmp_dir / "arrays.npz", **arrays)

    checksums = {}
    with open(tmp_dir / "arrays.npz", "rb") as f:
        checksums["arrays.npz"] = hashlib.sha256(f.read()).hexdigest()
    if aux is not None:
        aux_bytes = json.dumps(aux).encode()
        (tmp_dir / "aux.json").write_bytes(aux_bytes)
        checksums["aux.json"] = hashlib.sha256(aux_bytes).hexdigest()

    (tmp_dir / "treedef.json").write_text(json.dumps({"names": names}))
    manifest = {
        "step": step,
        "written_at": time.time(),
        "n_leaves": len(leaves),
        "checksums": checksums,
        "complete": True,
    }
    (tmp_dir / "MANIFEST.json").write_text(json.dumps(manifest))

    if step_dir.exists():
        shutil.rmtree(step_dir)
    os.replace(tmp_dir, step_dir)

    # atomic latest pointer
    latest_tmp = ckpt_dir / ".latest_tmp"
    latest_tmp.write_text(str(step))
    os.replace(latest_tmp, ckpt_dir / "latest")

    _gc(ckpt_dir, keep)
    return step_dir


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(available_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(ckpt_dir / f"step_{s:09d}", ignore_errors=True)


def available_steps(ckpt_dir: str | Path):
    ckpt_dir = Path(ckpt_dir)
    out = []
    if not ckpt_dir.exists():
        return out
    for d in ckpt_dir.iterdir():
        if d.is_dir() and d.name.startswith("step_") and (d / "MANIFEST.json").exists():
            try:
                m = json.loads((d / "MANIFEST.json").read_text())
                if m.get("complete"):
                    out.append(int(m["step"]))
            except (json.JSONDecodeError, KeyError, ValueError):
                continue
    return out


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    marker = ckpt_dir / "latest"
    if marker.exists():
        try:
            s = int(marker.read_text().strip())
            if (ckpt_dir / f"step_{s:09d}" / "MANIFEST.json").exists():
                return s
        except ValueError:
            pass
    steps = available_steps(ckpt_dir)
    return max(steps) if steps else None


def _resolve_step(ckpt_dir: Path, step: int | None) -> int:
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {ckpt_dir}")
    return step


def load_aux(ckpt_dir: str | Path, step: int | None = None):
    """Read back the checksum-validated ``aux`` payload of a checkpoint.

    Returns ``(step, aux)``; ``aux`` is ``None`` when the checkpoint was
    written without one."""
    ckpt_dir = Path(ckpt_dir)
    step = _resolve_step(ckpt_dir, step)
    step_dir = ckpt_dir / f"step_{step:09d}"
    manifest = json.loads((step_dir / "MANIFEST.json").read_text())
    expected = manifest["checksums"].get("aux.json")
    if expected is None:
        return step, None
    aux_bytes = (step_dir / "aux.json").read_bytes()
    if hashlib.sha256(aux_bytes).hexdigest() != expected:
        raise IOError(f"checkpoint {step_dir} failed aux.json checksum validation")
    return step, json.loads(aux_bytes)


def restore(ckpt_dir: str | Path, tree_like=None, step: int | None = None):
    """Load a checkpoint; returns ``(step, tree)``.

    With ``tree_like`` the leaves are unflattened into its structure (cast
    to each target leaf's dtype).  With ``tree_like=None`` the raw leaves
    come back as a flat list in saved order, **uncast and bitwise-exact** —
    the caller rebuilds structure itself (see ``load_aux`` / module doc).
    """
    ckpt_dir = Path(ckpt_dir)
    step = _resolve_step(ckpt_dir, step)
    step_dir = ckpt_dir / f"step_{step:09d}"

    manifest = json.loads((step_dir / "MANIFEST.json").read_text())
    with open(step_dir / "arrays.npz", "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()
    if digest != manifest["checksums"]["arrays.npz"]:
        raise IOError(f"checkpoint {step_dir} failed checksum validation")

    data = np.load(step_dir / "arrays.npz")
    leaves = [data[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
    if tree_like is None:
        return step, leaves
    flat_like, treedef = jax.tree.flatten(tree_like)
    if len(flat_like) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves; target structure has {len(flat_like)}"
        )
    restored = [
        np.asarray(leaf).astype(like.dtype) if hasattr(like, "dtype") else leaf
        for leaf, like in zip(leaves, flat_like)
    ]
    return step, jax.tree.unflatten(treedef, restored)
