"""Model / run configuration dataclasses.

One ``ModelConfig`` describes any architecture in the assigned pool; family-
specific blocks live in optional sub-configs. Exact production configs are in
``repro/configs/<arch>.py``; every arch also exposes ``smoke()`` — a reduced
same-family config for CPU tests.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    n_routed: int = 64
    n_shared: int = 2
    top_k: int = 6
    d_ff_expert: int = 1408      # fine-grained expert width
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # GShard dispatch group size: the one-hot dispatch tensor is
    # O(group_size * capacity) = O(group_size^2 * k / E) per group, so groups
    # are kept small and fixed regardless of global batch.
    group_size: int = 1024


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk: int = 128


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64
    chunk: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | audio | hybrid | vlm | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int | None = None    # default d_model // n_heads
    mlp_type: str = "swiglu"     # swiglu | relu2 | gelu
    qkv_bias: bool = False
    norm_type: str = "rmsnorm"   # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    use_rope: bool = True        # whisper uses sinusoidal/absolute positions
    tie_embeddings: bool = False
    vocab_pad_to: int = 256      # TP divisibility padding
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    rwkv: RWKVConfig | None = None
    attn_every: int = 0          # hybrid: shared attention block period
    encdec: bool = False
    dec_ratio: int = 4           # enc-dec: decoder length = seq // dec_ratio
    frontend: str | None = None  # audio | vision (STUB per assignment)
    n_frontend_tokens: int = 0   # vlm: patch tokens prepended to the stream
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True           # activation checkpointing across layers
    remat_policy: str = "full"   # full | dots (save matmul outputs)
    scan_layers: bool = True
    attn_block_k: int = 0        # >0: blockwise (flash) attention KV block
    kv_cache_dtype: str | None = None  # "int8": quantized decode cache (+scales)
    mla_q_chunk: int = 0         # >0: query-chunked MLA prefill/train
    moe_shard_constraints: bool = False  # explicit EP sharding annotations
    fsdp_gather_params: bool = False     # ZeRO-3 weight all-gather at use

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab_size + p - 1) // p) * p

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0
    # paper-technique features
    spectral_rank: int = 0       # >0: streaming-SVD low-rank moment projection
    compress_rank: int = 0       # >0: low-rank DP gradient compression
    basis_refresh_every: int = 0 # >0: agree/re-factorize spectral bases every N steps


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    seed: int = 0
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
