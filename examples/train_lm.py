"""End-to-end training driver: decoder LM on the deterministic token stream.

Defaults to a fast CPU-sized model so the example completes in minutes;
``--scale 100m`` selects a ~100M-parameter llama-style config (the assignment
driver — expect TPU/long CPU runtimes) and ``--arch`` picks any assigned
architecture's smoke config instead.

Demonstrates the full substrate: config -> model registry -> deterministic
data -> AdamW + schedule -> atomic checkpoints -> auto-resume (kill it midway
and rerun: it continues from the last complete checkpoint, bit-exact).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 60
"""

import argparse

import jax

from repro import configs
from repro.configs.base import ModelConfig, OptimizerConfig, RunConfig
from repro.train.loop import train


def model_for_scale(scale: str) -> ModelConfig:
    if scale == "100m":
        return ModelConfig(
            name="repro-100m", family="dense",
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
            d_ff=2048, vocab_size=32_000, vocab_pad_to=256,
            mlp_type="swiglu", norm_type="rmsnorm",
            compute_dtype="float32", remat=False,
        )
    return ModelConfig(
        name="repro-tiny", family="dense",
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
        d_ff=704, vocab_size=2_048, vocab_pad_to=64,
        mlp_type="swiglu", norm_type="rmsnorm",
        compute_dtype="float32", remat=False,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--scale", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--arch", default=None, help="assigned arch id (smoke config)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--spectral-rank", type=int, default=0,
                    help=">0: streaming-SVD low-rank moment projection")
    ap.add_argument("--basis-refresh-every", type=int, default=0,
                    help=">0: agree/re-factorize spectral bases every N steps")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.arch else model_for_scale(args.scale)
    run = RunConfig(
        model=cfg,
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=20, total_steps=max(args.steps, 100),
                                  spectral_rank=args.spectral_rank,
                                  basis_refresh_every=args.basis_refresh_every),
        steps=args.steps,
        log_every=10,
        checkpoint_every=25,
        checkpoint_dir=args.ckpt_dir,
        seed=0,
    )
    print(f"model={cfg.name} devices={jax.device_count()}")
    res = train(run, batch_size=args.batch, seq_len=args.seq)
    first, last = res.losses[0][1], res.losses[-1][1]
    print(f"\nloss {first:.3f} -> {last:.3f} over {res.final_step} steps"
          + (f" (resumed from {res.resumed_from})" if res.resumed_from else ""))
    assert last < first, "loss did not decrease"
    print("OK")


if __name__ == "__main__":
    main()
