"""Golden route pins for the ``repro.api`` surface.

Historically this module proved the api bit-identical to the four deprecated
pre-api call shapes (``svd_update``, ``svd_update_truncated``,
``svd_update_batch``, ``svd_update_truncated_batch``).  Those shims are now
DELETED; the goldens pin the api routes directly instead:

* every dispatch route is bitwise (allclose rtol=0 atol=0, f64) against the
  plan-cached ``core.engine`` executable it must resolve to — single,
  batched, truncated, truncated-batched, Pallas-kernel, and mesh-sharded on
  8 fake devices;
* the batched routes are additionally pinned against a loop of single
  ``api.update`` calls (vmap == loop, the original acceptance criterion);
* the four deprecated names are asserted GONE from every module that used
  to carry them.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.api import SvdState, UpdatePolicy
from repro.core.engine import default_engine
from repro.core.svd_update import TruncatedSvd

RNG = np.random.default_rng(3)
REPO = Path(__file__).resolve().parent.parent

# (policy method, engine method) pairs — "pallas" is the public name of the
# engine's "kernel" route
ROUTES = [("direct", "direct"), ("fmm", "fmm"), ("pallas", "kernel"),
          ("fused", "fused")]


def _problem(m, n):
    a_mat = RNG.uniform(1, 9, (m, n))
    u, s, vt = np.linalg.svd(a_mat)
    return (jnp.asarray(u), jnp.asarray(s), jnp.asarray(vt.T),
            jnp.asarray(RNG.normal(size=m)), jnp.asarray(RNG.normal(size=n)))


def _stacked_problem(b, m, n):
    cols = [[] for _ in range(5)]
    for _ in range(b):
        for c, x in zip(cols, _problem(m, n)):
            c.append(x)
    return tuple(jnp.stack(c) for c in cols)


def _trunc(m, n, r):
    return TruncatedSvd(
        jnp.asarray(np.linalg.qr(RNG.normal(size=(m, r)))[0]),
        jnp.asarray(np.sort(np.abs(RNG.normal(size=r)))[::-1].copy()),
        jnp.asarray(np.linalg.qr(RNG.normal(size=(n, r)))[0]),
    )


def _exact(x, y):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# the four dispatch routes, bitwise vs the engine executables they resolve to
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method,eng_method", ROUTES)
def test_single_full_route_exact(method, eng_method):
    u, s, v, a, b = _problem(12, 16)
    ref = default_engine(eng_method).update(u, s, v, a, b)
    out = api.update(SvdState.from_factors(u, s, v), a, b,
                     UpdatePolicy(method=method))
    _exact(out.u, ref.u)
    _exact(out.s, ref.s)
    _exact(out.v, ref.v)
    _exact(out.d_left, ref.d_left)
    _exact(out.d_right, ref.d_right)


@pytest.mark.parametrize("method,eng_method", ROUTES)
def test_batched_full_route_exact(method, eng_method):
    u, s, v, a, b = _stacked_problem(6, 10, 13)
    ref = default_engine(eng_method).update_batch(u, s, v, a, b)
    stacked = SvdState.from_factors(u, s, v)
    out = api.update(stacked, a, b, UpdatePolicy(method=method))
    _exact(out.u, ref.u)
    _exact(out.s, ref.s)
    _exact(out.v, ref.v)


def test_batched_full_route_matches_loop_of_singles():
    """vmap == loop through the SAME surface: the stacked dispatch must agree
    with per-item api.update calls (degenerate trailing v columns excluded —
    they are an arbitrary null-space basis across differently-compiled
    paths; compare u, s, and v[:, :m])."""
    b_sz, m, n = 5, 10, 13
    u, s, v, a, b = _stacked_problem(b_sz, m, n)
    pol = UpdatePolicy(method="direct")
    out = api.update(SvdState.from_factors(u, s, v), a, b, pol)
    for i in range(b_sz):
        ref = api.update(SvdState.from_factors(u[i], s[i], v[i]), a[i], b[i], pol)
        np.testing.assert_allclose(np.asarray(out.u[i]), np.asarray(ref.u), atol=1e-10)
        np.testing.assert_allclose(np.asarray(out.s[i]), np.asarray(ref.s), atol=1e-10)
        np.testing.assert_allclose(np.asarray(out.v[i][:, :m]),
                                   np.asarray(ref.v[:, :m]), atol=1e-10)


def test_truncated_single_route_exact():
    t = _trunc(14, 18, 4)
    a = jnp.asarray(RNG.normal(size=14))
    b = jnp.asarray(RNG.normal(size=18))
    ref = default_engine("direct").update_truncated(t, a, b)
    out = api.update(t, a, b, UpdatePolicy(method="direct"))
    _exact(out.u, ref.u)
    _exact(out.s, ref.s)
    _exact(out.v, ref.v)


def test_truncated_batched_route_exact():
    b_sz, m, n, r = 8, 14, 18, 4
    singles = [_trunc(m, n, r) for _ in range(b_sz)]
    t = jax.tree.map(lambda *xs: jnp.stack(xs), *singles)
    a = jnp.asarray(RNG.normal(size=(b_sz, m)))
    b = jnp.asarray(RNG.normal(size=(b_sz, n)))
    ref = default_engine("direct").update_truncated_batch(t, a, b)
    out = api.update(api.as_state(t), a, b, UpdatePolicy(method="direct"))
    _exact(out.u, ref.u)
    _exact(out.s, ref.s)
    _exact(out.v, ref.v)
    # and vmap == loop of truncated singles through the api
    pol = UpdatePolicy(method="direct")
    for i in range(b_sz):
        ref_i = api.update(singles[i], a[i], b[i], pol)
        np.testing.assert_allclose(np.asarray(out.s[i]), np.asarray(ref_i.s),
                                   atol=1e-10)
        np.testing.assert_allclose(np.asarray(out.u[i]), np.asarray(ref_i.u),
                                   atol=1e-10)


def test_mesh_sharded_route_exact_on_8_devices():
    """api.update with UpdatePolicy(mesh=...) == the engine mesh path,
    exactly, for full-batched and truncated-batched dispatch (8 fake CPU
    devices; subprocess because the device count must precede jax init)."""
    script = textwrap.dedent("""
        import json
        import jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp, numpy as np
        from repro import api
        from repro.core.engine import SvdEngine, default_engine
        from repro.core.svd_update import TruncatedSvd

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        B, m, n, r = 12, 8, 10, 3

        us = np.stack([np.linalg.qr(rng.normal(size=(m, m)))[0] for _ in range(B)])
        vs = np.stack([np.linalg.qr(rng.normal(size=(n, n)))[0] for _ in range(B)])
        ss = np.abs(rng.normal(size=(B, m)))
        a = rng.normal(size=(B, m)); b = rng.normal(size=(B, n))
        args = tuple(jnp.asarray(x) for x in (us, ss, vs, a, b))

        pol = api.UpdatePolicy(method="direct", mesh=mesh, batch_axis="data")
        eng = default_engine("direct")   # the engine the policy resolves to

        ref = eng.update_batch(*args, mesh=mesh, batch_axis="data")
        out = api.update(api.SvdState.from_factors(*args[:3]), args[3], args[4], pol)
        d_full = max(float(jnp.max(jnp.abs(x - y))) for x, y in
                     zip((out.u, out.s, out.v), (ref.u, ref.s, ref.v)))

        t = TruncatedSvd(args[0][:, :, :r], args[1][:, :r], args[2][:, :, :r])
        ref_t = eng.update_truncated_batch(t, args[3], args[4],
                                           mesh=mesh, batch_axis="data")
        out_t = api.update(api.as_state(t), args[3], args[4], pol)
        d_tr = max(float(jnp.max(jnp.abs(x - y))) for x, y in
                   zip((out_t.u, out_t.s, out_t.v), (ref_t.u, ref_t.s, ref_t.v)))
        print(json.dumps({"d_full": d_full, "d_trunc": d_tr,
                          "devices": jax.device_count()}))
    """)
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=420,
        env={
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
            "PYTHONPATH": str(REPO / "src"),
            "PATH": "/usr/bin:/bin",
            "JAX_PLATFORMS": "cpu",
            "HOME": "/tmp",
        },
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-4000:]}"
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    assert out["d_full"] == 0.0    # identical engine cache entry -> bitwise
    assert out["d_trunc"] == 0.0


# ---------------------------------------------------------------------------
# the deprecated surface is GONE; the api resolves to the shared engines
# ---------------------------------------------------------------------------


def test_deprecated_call_shapes_are_deleted():
    """The four pre-api shapes must not come back (ISSUE 4 acceptance)."""
    import types

    import repro.core as core
    import repro.core.engine as engine_mod
    import repro.core.svd_update as svd_mod

    for name in ("svd_update", "svd_update_truncated",
                 "svd_update_batch", "svd_update_truncated_batch"):
        for mod in (core, engine_mod, svd_mod):
            attr = getattr(mod, name, None)
            # repro.core.svd_update the *submodule* is fine; the callable is not
            assert attr is None or isinstance(attr, types.ModuleType), (
                f"{mod.__name__}.{name} resurfaced"
            )
        assert name not in core.__all__
    assert not hasattr(svd_mod, "_warn_deprecated")


def test_api_resolves_to_shared_engine():
    """Policy-equal configurations resolve to the SAME default engine — one
    plan cache across every caller."""
    st = api.as_state(_trunc(8, 10, 3))
    assert api.engine_for(UpdatePolicy(method="direct"), st) is default_engine("direct")
    assert api.engine_for(
        UpdatePolicy(method="pallas", fmm_p=20), st
    ) is default_engine("kernel")
