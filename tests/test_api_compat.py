"""Golden equivalence: the ``repro.api`` surface reproduces the deprecated
call shapes EXACTLY (allclose rtol=0 atol=0 in f64) on every dispatch route
— single, batched, truncated, truncated-batched, Pallas-kernel, and
mesh-sharded on 8 fake devices — and the old shapes warn.

This is the ONE test module that intentionally exercises the deprecated
surface (CI errors on DeprecationWarning raised from repro/examples code)."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.api import SvdState, UpdatePolicy
from repro.core.engine import svd_update_batch, svd_update_truncated_batch
from repro.core.svd_update import (
    TruncatedSvd,
    svd_update,
    svd_update_truncated,
)

RNG = np.random.default_rng(3)
REPO = Path(__file__).resolve().parent.parent

# (policy method, legacy engine method) pairs — "pallas" is the public name
# of the legacy "kernel" route
ROUTES = [("direct", "direct"), ("fmm", "fmm"), ("pallas", "kernel")]


def _problem(m, n):
    a_mat = RNG.uniform(1, 9, (m, n))
    u, s, vt = np.linalg.svd(a_mat)
    return (jnp.asarray(u), jnp.asarray(s), jnp.asarray(vt.T),
            jnp.asarray(RNG.normal(size=m)), jnp.asarray(RNG.normal(size=n)))


def _stacked_problem(b, m, n):
    cols = [[] for _ in range(5)]
    for _ in range(b):
        for c, x in zip(cols, _problem(m, n)):
            c.append(x)
    return tuple(jnp.stack(c) for c in cols)


def _trunc(m, n, r):
    return TruncatedSvd(
        jnp.asarray(np.linalg.qr(RNG.normal(size=(m, r)))[0]),
        jnp.asarray(np.sort(np.abs(RNG.normal(size=r)))[::-1].copy()),
        jnp.asarray(np.linalg.qr(RNG.normal(size=(n, r)))[0]),
    )


def _exact(x, y):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# the four dispatch routes, bitwise vs the old call shapes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method,legacy", ROUTES)
def test_single_full_route_exact(method, legacy):
    u, s, v, a, b = _problem(12, 16)
    with pytest.warns(DeprecationWarning, match="svd_update"):
        ref = svd_update(u, s, v, a, b, method=legacy)
    out = api.update(SvdState.from_factors(u, s, v), a, b,
                     UpdatePolicy(method=method))
    _exact(out.u, ref.u)
    _exact(out.s, ref.s)
    _exact(out.v, ref.v)
    _exact(out.d_left, ref.d_left)
    _exact(out.d_right, ref.d_right)


@pytest.mark.parametrize("method,legacy", ROUTES)
def test_batched_full_route_exact(method, legacy):
    u, s, v, a, b = _stacked_problem(6, 10, 13)
    with pytest.warns(DeprecationWarning, match="svd_update_batch"):
        ref = svd_update_batch(u, s, v, a, b, method=legacy)
    stacked = SvdState.from_factors(u, s, v)
    out = api.update(stacked, a, b, UpdatePolicy(method=method))
    _exact(out.u, ref.u)
    _exact(out.s, ref.s)
    _exact(out.v, ref.v)


def test_truncated_single_route_exact():
    t = _trunc(14, 18, 4)
    a = jnp.asarray(RNG.normal(size=14))
    b = jnp.asarray(RNG.normal(size=18))
    with pytest.warns(DeprecationWarning, match="svd_update_truncated"):
        ref = svd_update_truncated(t, a, b)
    out = api.update(t, a, b, UpdatePolicy(method="direct"))
    _exact(out.u, ref.u)
    _exact(out.s, ref.s)
    _exact(out.v, ref.v)


def test_truncated_batched_route_exact():
    b_sz, m, n, r = 8, 14, 18, 4
    singles = [_trunc(m, n, r) for _ in range(b_sz)]
    t = jax.tree.map(lambda *xs: jnp.stack(xs), *singles)
    a = jnp.asarray(RNG.normal(size=(b_sz, m)))
    b = jnp.asarray(RNG.normal(size=(b_sz, n)))
    with pytest.warns(DeprecationWarning, match="svd_update_truncated_batch"):
        ref = svd_update_truncated_batch(t, a, b)
    out = api.update(api.as_state(t), a, b, UpdatePolicy(method="direct"))
    _exact(out.u, ref.u)
    _exact(out.s, ref.s)
    _exact(out.v, ref.v)


def test_mesh_sharded_route_exact_on_8_devices():
    """api.update with UpdatePolicy(mesh=...) == the legacy engine mesh path,
    exactly, for full-batched and truncated-batched dispatch (8 fake CPU
    devices; subprocess because the device count must precede jax init)."""
    script = textwrap.dedent("""
        import json
        import jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp, numpy as np
        from repro import api
        from repro.core.engine import SvdEngine, default_engine
        from repro.core.svd_update import TruncatedSvd

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        B, m, n, r = 12, 8, 10, 3

        us = np.stack([np.linalg.qr(rng.normal(size=(m, m)))[0] for _ in range(B)])
        vs = np.stack([np.linalg.qr(rng.normal(size=(n, n)))[0] for _ in range(B)])
        ss = np.abs(rng.normal(size=(B, m)))
        a = rng.normal(size=(B, m)); b = rng.normal(size=(B, n))
        args = tuple(jnp.asarray(x) for x in (us, ss, vs, a, b))

        pol = api.UpdatePolicy(method="direct", mesh=mesh, batch_axis="data")
        eng = default_engine("direct")   # the engine the old path used

        ref = eng.update_batch(*args, mesh=mesh, batch_axis="data")
        out = api.update(api.SvdState.from_factors(*args[:3]), args[3], args[4], pol)
        d_full = max(float(jnp.max(jnp.abs(x - y))) for x, y in
                     zip((out.u, out.s, out.v), (ref.u, ref.s, ref.v)))

        t = TruncatedSvd(args[0][:, :, :r], args[1][:, :r], args[2][:, :, :r])
        ref_t = eng.update_truncated_batch(t, args[3], args[4],
                                           mesh=mesh, batch_axis="data")
        out_t = api.update(api.as_state(t), args[3], args[4], pol)
        d_tr = max(float(jnp.max(jnp.abs(x - y))) for x, y in
                   zip((out_t.u, out_t.s, out_t.v), (ref_t.u, ref_t.s, ref_t.v)))
        print(json.dumps({"d_full": d_full, "d_trunc": d_tr,
                          "devices": jax.device_count()}))
    """)
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=420,
        env={
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
            "PYTHONPATH": str(REPO / "src"),
            "PATH": "/usr/bin:/bin",
            "JAX_PLATFORMS": "cpu",
            "HOME": "/tmp",
        },
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-4000:]}"
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    assert out["d_full"] == 0.0    # identical engine cache entry -> bitwise
    assert out["d_trunc"] == 0.0


# ---------------------------------------------------------------------------
# shims: exist, warn, and share the api's engines (one plan cache)
# ---------------------------------------------------------------------------


def test_all_four_legacy_shapes_warn():
    u, s, v, a, b = _problem(8, 10)
    with pytest.warns(DeprecationWarning):
        svd_update(u, s, v, a, b)
    t = _trunc(8, 10, 3)
    with pytest.warns(DeprecationWarning):
        svd_update_truncated(t, a, b)
    ub, sb, vb, ab, bb = _stacked_problem(2, 8, 10)
    with pytest.warns(DeprecationWarning):
        svd_update_batch(ub, sb, vb, ab, bb)
    tb = jax.tree.map(lambda *xs: jnp.stack(xs), t, _trunc(8, 10, 3))
    with pytest.warns(DeprecationWarning):
        svd_update_truncated_batch(tb, jnp.stack([a, a]), jnp.stack([b, b]))


def test_legacy_and_api_share_one_engine():
    """The old facades and the api resolve policy-equal configurations to the
    SAME default engine — one plan cache across old and new callers."""
    from repro.core.engine import default_engine

    st = api.as_state(_trunc(8, 10, 3))
    assert api.engine_for(UpdatePolicy(method="direct"), st) is default_engine("direct")
    assert api.engine_for(
        UpdatePolicy(method="pallas", fmm_p=20), st
    ) is default_engine("kernel")
