"""Hierarchical distributed truncated-SVD merge (repro.dist.merge).

Row-partitioned shards, each reduced to its local truncated SVD, merged by
the log-depth rank-1-update tree — against ``jnp.linalg.svd`` of the
concatenated matrix.  Runs under the suite-wide x64 default (conftest)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.svd_update import TruncatedSvd
from repro.dist.merge import merge_pair, merge_tree

RANK = 4
N = 12


def _tsvd_of(mat: np.ndarray, r: int) -> TruncatedSvd:
    u, s, vt = np.linalg.svd(mat, full_matrices=False)
    return TruncatedSvd(jnp.asarray(u[:, :r]), jnp.asarray(s[:r]), jnp.asarray(vt[:r].T))


def _rank_r_reference(mat: np.ndarray, r: int):
    u, s, vt = np.linalg.svd(mat)
    return (u[:, :r] * s[:r]) @ vt[:r], s[:r]


@pytest.mark.parametrize("n_shards", [1, 2, 8])
def test_merge_matches_concatenated_svd(n_shards):
    """Globally rank-3 matrix, rank-4 shards: the merge is exact — it must
    reproduce the truncated SVD of the concatenation at every tree size."""
    rng = np.random.default_rng(0)
    m_total = 80
    M = rng.normal(size=(m_total, 3)) @ rng.normal(size=(N, 3)).T

    shards = [_tsvd_of(blk, RANK) for blk in np.array_split(M, n_shards)]
    merged = merge_tree(shards, rank=RANK)

    rec = np.asarray(merged.u) @ np.diag(np.asarray(merged.s)) @ np.asarray(merged.v).T
    opt, s_ref = _rank_r_reference(M, RANK)
    np.testing.assert_allclose(rec, opt, atol=1e-6)
    np.testing.assert_allclose(np.asarray(merged.s), s_ref, atol=1e-6)
    # factors are genuine singular vectors: orthonormal columns
    u = np.asarray(merged.u)
    v = np.asarray(merged.v)
    np.testing.assert_allclose(u[:, :3].T @ u[:, :3], np.eye(3), atol=1e-6)
    np.testing.assert_allclose(v[:, :3].T @ v[:, :3], np.eye(3), atol=1e-6)
    assert u.shape == (m_total, RANK)


def test_merge_odd_shard_count():
    rng = np.random.default_rng(1)
    M = rng.normal(size=(60, 3)) @ rng.normal(size=(N, 3)).T
    merged = merge_tree([_tsvd_of(b, RANK) for b in np.array_split(M, 3)], rank=RANK)
    rec = np.asarray(merged.u) @ np.diag(np.asarray(merged.s)) @ np.asarray(merged.v).T
    opt, _ = _rank_r_reference(M, RANK)
    np.testing.assert_allclose(rec, opt, atol=1e-6)


@pytest.mark.parametrize("n_shards", [3, 5, 6, 7])
def test_merge_non_pow2_stays_batched(n_shards, monkeypatch):
    """Equal-geometry shard lists of non-power-of-two length are padded with
    zero shards, so every level runs the batched path — the sequential
    ``merge_pair`` fallback must never fire — and the result (incl. the left
    factor's row count) is still exact."""
    from repro.dist import merge as merge_mod

    def _boom(*a, **kw):
        raise AssertionError("sequential merge_pair fallback fired")

    monkeypatch.setattr(merge_mod, "merge_pair", _boom)

    rng = np.random.default_rng(6)
    m_each = 12
    M = rng.normal(size=(n_shards * m_each, 3)) @ rng.normal(size=(N, 3)).T
    shards = [_tsvd_of(M[i * m_each:(i + 1) * m_each], RANK) for i in range(n_shards)]
    merged = merge_mod.merge_tree(shards, rank=RANK)

    assert merged.u.shape == (n_shards * m_each, RANK)  # padding rows sliced off
    rec = np.asarray(merged.u) @ np.diag(np.asarray(merged.s)) @ np.asarray(merged.v).T
    opt, s_ref = _rank_r_reference(M, RANK)
    np.testing.assert_allclose(rec, opt, atol=1e-6)
    np.testing.assert_allclose(np.asarray(merged.s), s_ref, atol=1e-6)


def test_merge_mixed_geometry_still_works():
    """Genuinely unequal shard heights keep the pairwise fallback path."""
    rng = np.random.default_rng(7)
    M = rng.normal(size=(50, 3)) @ rng.normal(size=(N, 3)).T
    blocks = [M[:10], M[10:30], M[30:50]]  # heights 10 / 20 / 20
    merged = merge_tree([_tsvd_of(b, RANK) for b in blocks], rank=RANK)
    rec = np.asarray(merged.u) @ np.diag(np.asarray(merged.s)) @ np.asarray(merged.v).T
    opt, _ = _rank_r_reference(M, RANK)
    np.testing.assert_allclose(rec, opt, atol=1e-6)


def test_merge_accepts_svdstate_and_preserves_container():
    """api-era shards: SvdState in -> SvdState out; legacy TruncatedSvd in ->
    TruncatedSvd out (pytree structure is caller-owned)."""
    from repro.api import SvdState, as_state

    rng = np.random.default_rng(8)
    M = rng.normal(size=(40, 3)) @ rng.normal(size=(N, 3)).T
    legacy = [_tsvd_of(b, RANK) for b in np.array_split(M, 4)]
    states = [as_state(t) for t in legacy]

    out_legacy = merge_tree(legacy, rank=RANK)
    out_state = merge_tree(states, rank=RANK)
    assert type(out_legacy).__name__ == "TruncatedSvd"
    assert isinstance(out_state, SvdState)
    np.testing.assert_allclose(np.asarray(out_legacy.u), np.asarray(out_state.u),
                               rtol=0, atol=0)


def test_merge_general_matrix_near_optimal():
    """Full-rank data: hierarchical merge error stays within a modest factor
    of the optimal rank-r error (Iwen–Ong guarantee shape)."""
    rng = np.random.default_rng(2)
    low = 10.0 * rng.normal(size=(80, RANK)) @ rng.normal(size=(N, RANK)).T
    M = low + rng.normal(size=(80, N))

    merged = merge_tree([_tsvd_of(b, RANK) for b in np.array_split(M, 8)], rank=RANK)
    rec = np.asarray(merged.u) @ np.diag(np.asarray(merged.s)) @ np.asarray(merged.v).T
    opt, s_ref = _rank_r_reference(M, RANK)
    err = np.linalg.norm(M - rec)
    err_opt = np.linalg.norm(M - opt)
    assert err <= 1.25 * err_opt, (err, err_opt)
    # dominant singular values recovered tightly
    np.testing.assert_allclose(np.asarray(merged.s)[:2], s_ref[:2], rtol=1e-3)


def test_merge_pair_rank_validation():
    rng = np.random.default_rng(3)
    a = _tsvd_of(rng.normal(size=(10, N)), 3)
    b = _tsvd_of(rng.normal(size=(10, N)), 3)
    with pytest.raises(ValueError, match="exceeds"):
        merge_pair(a, b, rank=5)
    with pytest.raises(ValueError, match="column space"):
        merge_pair(a, _tsvd_of(rng.normal(size=(10, N + 2)), 3))


def test_service_merge_streams():
    """serve.SvdService.merge_streams: per-worker shard streams (with pending
    pairs) combine into the truncated SVD of the row-stacked matrix."""
    from repro.serve.svd_service import SvdService

    rng = np.random.default_rng(4)
    m = 16
    M = rng.normal(size=(4 * m, 3)) @ rng.normal(size=(N, 3)).T

    svc = SvdService(max_batch=64)
    for w in range(4):
        blk = M[w * m : (w + 1) * m]
        svc.register(f"worker-{w}", _tsvd_of(blk, RANK))
    # one worker has a queued update the merge must fold in first
    a = rng.normal(size=(m,))
    b = rng.normal(size=(N,))
    svc.enqueue("worker-2", jnp.asarray(a), jnp.asarray(b))

    merged = svc.merge_streams([f"worker-{w}" for w in range(4)], target="global")
    M2 = M.copy()
    M2[2 * m : 3 * m] += np.outer(a, b)
    rec = np.asarray(merged.u) @ np.diag(np.asarray(merged.s)) @ np.asarray(merged.v).T
    opt, _ = _rank_r_reference(M2, RANK)
    np.testing.assert_allclose(rec, opt, atol=1e-5)
    assert svc.pending("worker-2") == 0
    assert svc.state("global").u.shape == (4 * m, RANK)


def test_agree_basis_single_worker():
    """axis_name=None degenerates to a local tracker re-factorization that
    preserves the represented matrix and the orthonormal-basis invariant."""
    from repro.optim.compression import agree_basis, compression_init

    st = compression_init(jax.random.PRNGKey(0), 10, N, RANK)
    tracker = _tsvd_of(np.random.default_rng(5).normal(size=(10, N)), RANK)
    st = st._replace(tracker=tracker)
    out = agree_basis(st, axis_name=None)
    np.testing.assert_allclose(np.asarray(out.v_basis), np.asarray(tracker.v))
    np.testing.assert_allclose(np.asarray(out.tracker.s), np.asarray(tracker.s),
                               rtol=1e-6)
    # invariant the Brand truncated update requires: orthonormal bases
    u = np.asarray(out.tracker.u)
    v = np.asarray(out.tracker.v)
    np.testing.assert_allclose(u.T @ u, np.eye(RANK), atol=1e-8)
    np.testing.assert_allclose(v.T @ v, np.eye(RANK), atol=1e-8)
    # same represented matrix (up to the re-factorization's sign freedom)
    rec0 = np.asarray(tracker.u) @ np.diag(np.asarray(tracker.s)) @ np.asarray(tracker.v).T
    rec1 = u @ np.diag(np.asarray(out.tracker.s)) @ v.T
    np.testing.assert_allclose(rec1, rec0, atol=1e-8)
