"""Pallas TPU kernel: sparse gather/scatter projection (DESIGN.md §12).

The sparse-update hot spot: given a static-nnz COO perturbation
``S[rows[e], cols[e]] += vals[e]`` and a dense ``(src, k)`` factor block,
compute the projected ``(dst, k)`` core

    out[rows[e], :] += vals[e] * mat[cols[e], :]        for every entry e

i.e. ``out = S @ mat``.  Swapping ``rows``/``cols`` gives ``S^T @ mat``.
This is the ONLY dense contact the ``Sparse`` op's lowering makes with the
matrix geometry — cost O(nnz * k) plus the O((m+n) * k) range-finder
matmuls, never the O(m * n) a densified delta would pay.

Kernel shape (a genuinely new one for ``kernels/``): the COO coordinate
vectors live whole in SMEM (scalar memory — indices drive control flow and
dynamic addressing), the dense factor block and the output live in VMEM,
and the grid walks nnz in blocks with output revisiting — each program
gathers ``block_e`` source rows at dynamic indices and scatter-accumulates
them at dynamic destinations (``ref[pl.ds(idx, 1), :]``).  Padding entries
(``vals == 0`` at coordinate (0, 0)) are harmless by construction: they add
zero.

Batching: ``sparse_project_pallas_batched`` folds the batch axis into the
grid exactly like ``cauchy_matmul_pallas_batched``; the ``custom_vmap``
rule on the dispatching ``sparse_project`` routes ``jax.vmap`` there — ONE
launch for B sparse projections, not B sequential calls.

Off-TPU the dispatch runs ``sparse_project_xla`` — a dense XLA
``segment_sum`` over the gathered/scaled rows, which vmaps natively and is
the reference the interpret-mode kernel is pinned against in
``tests/test_sparse_proj.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import custom_batching
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "sparse_project",
    "sparse_project_pallas",
    "sparse_project_pallas_batched",
    "sparse_project_xla",
]


# ---------------------------------------------------------------------------
# Reference / fallback: one XLA segment-sum, vmaps natively
# ---------------------------------------------------------------------------


def _broadcast_batch(rows, cols, vals, mat):
    """Broadcast all four operands to a common leading batch shape.

    ``vals`` (..., nnz) and ``mat`` (..., src, k) define the batch; shared
    (unbatched) coordinate vectors broadcast up to it — the common case of
    one COO pattern projected against a batch of factor blocks.
    """
    rows = jnp.asarray(rows, jnp.int32)
    cols = jnp.asarray(cols, jnp.int32)
    vals = jnp.asarray(vals)
    mat = jnp.asarray(mat)
    lead = jnp.broadcast_shapes(vals.shape[:-1], mat.shape[:-2])
    return (
        jnp.broadcast_to(rows, lead + rows.shape[-1:]),
        jnp.broadcast_to(cols, lead + cols.shape[-1:]),
        jnp.broadcast_to(vals, lead + vals.shape[-1:]),
        jnp.broadcast_to(mat, lead + mat.shape[-2:]),
    )


def sparse_project_xla(rows, cols, vals, mat, out_rows: int):
    """``out[r, :] = sum_e [rows[e] == r] * vals[e] * mat[cols[e], :]``.

    ``rows``/``cols``/``vals``: (..., nnz); ``mat``: (..., src, k).  Leading
    batch axes broadcast zip-wise (the XLA scatter-add vmaps natively);
    operands missing the batch axes (e.g. shared coordinates under batched
    values) broadcast up.
    """
    vals = jnp.asarray(vals)
    if vals.ndim > 1:
        rows, cols, vals, mat = _broadcast_batch(rows, cols, vals, mat)
        return jax.vmap(
            lambda r, c, v, m_: sparse_project_xla(r, c, v, m_, out_rows)
        )(rows, cols, vals, mat)
    mat = jnp.asarray(mat)
    gathered = vals[:, None] * mat[jnp.asarray(cols), :]        # (nnz, k)
    return jax.ops.segment_sum(gathered, jnp.asarray(rows),
                               num_segments=out_rows)


# ---------------------------------------------------------------------------
# Pallas kernels: COO coordinates in SMEM, factors in VMEM, nnz in the grid
# ---------------------------------------------------------------------------


def _kernel(rows_ref, cols_ref, vals_ref, mat_ref, out_ref, *, block_e: int):
    blk = pl.program_id(0)

    @pl.when(blk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    base = blk * block_e

    def body(e, carry):
        r = rows_ref[base + e]
        c = cols_ref[base + e]
        val = vals_ref[base + e]
        out_ref[pl.ds(r, 1), :] += val * mat_ref[pl.ds(c, 1), :]
        return carry

    jax.lax.fori_loop(0, block_e, body, 0)


@functools.partial(jax.jit, static_argnames=("out_rows", "block_e", "interpret"))
def sparse_project_pallas(
    rows: jax.Array,
    cols: jax.Array,
    vals: jax.Array,
    mat: jax.Array,
    out_rows: int,
    *,
    block_e: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Single-instance kernel: ``rows``/``cols``/``vals`` (nnz,), ``mat``
    (src, k) -> (out_rows, k).  nnz is padded to a ``block_e`` multiple with
    zero-valued entries at coordinate (0, 0) — an exact no-op."""
    nnz = vals.shape[0]
    be = min(block_e, max(8, nnz))
    pad_e = (-nnz) % be
    rows_p = jnp.pad(rows.astype(jnp.int32), (0, pad_e))
    cols_p = jnp.pad(cols.astype(jnp.int32), (0, pad_e))
    vals_p = jnp.pad(vals, (0, pad_e))
    grid = ((nnz + pad_e) // be,)
    return pl.pallas_call(
        functools.partial(_kernel, block_e=be),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(mat.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((out_rows, mat.shape[1]), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((out_rows, mat.shape[1]), mat.dtype),
        interpret=interpret,
    )(rows_p, cols_p, vals_p, mat)


def _kernel_batched(rows_ref, cols_ref, vals_ref, mat_ref, out_ref, *,
                    block_e: int):
    b = pl.program_id(0)
    blk = pl.program_id(1)

    @pl.when(blk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    base = blk * block_e

    def body(e, carry):
        r = rows_ref[b, base + e]
        c = cols_ref[b, base + e]
        val = vals_ref[b, base + e]
        out_ref[0, pl.ds(r, 1), :] += val * mat_ref[0, pl.ds(c, 1), :]
        return carry

    jax.lax.fori_loop(0, block_e, body, 0)


@functools.partial(jax.jit, static_argnames=("out_rows", "block_e", "interpret"))
def sparse_project_pallas_batched(
    rows: jax.Array,
    cols: jax.Array,
    vals: jax.Array,
    mat: jax.Array,
    out_rows: int,
    *,
    block_e: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Batched kernel: (B, nnz) coordinates, (B, src, k) factors -> (B,
    out_rows, k).  Grid (B, nnz/BE) — batch outermost, exactly the
    ``cauchy_matmul_pallas_batched`` fold."""
    bsz, nnz = vals.shape
    be = min(block_e, max(8, nnz))
    pad_e = (-nnz) % be
    rows_p = jnp.pad(rows.astype(jnp.int32), ((0, 0), (0, pad_e)))
    cols_p = jnp.pad(cols.astype(jnp.int32), ((0, 0), (0, pad_e)))
    vals_p = jnp.pad(vals, ((0, 0), (0, pad_e)))
    grid = (bsz, (nnz + pad_e) // be)
    src, k = mat.shape[-2:]
    return pl.pallas_call(
        functools.partial(_kernel_batched, block_e=be),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, src, k), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, out_rows, k), lambda b, i: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, out_rows, k), mat.dtype),
        interpret=interpret,
    )(rows_p, cols_p, vals_p, mat)


# ---------------------------------------------------------------------------
# Dispatch: Pallas (custom_vmap batch-in-grid) on TPU, XLA elsewhere
# ---------------------------------------------------------------------------


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.lru_cache(maxsize=None)
def _pallas_project_vmapped(out_rows: int):
    @custom_batching.custom_vmap
    def f(rows, cols, vals, mat):
        return sparse_project_pallas(rows, cols, vals, mat, out_rows,
                                     interpret=_interpret_default())

    @f.def_vmap
    def _f_vmap(axis_size, in_batched, rows, cols, vals, mat):
        def bcast(x, batched):
            return x if batched else jnp.broadcast_to(x, (axis_size,) + x.shape)

        args = [bcast(x, b) for x, b in zip((rows, cols, vals, mat), in_batched)]
        if args[2].ndim > 2:  # nested vmap: collapse leading axes into one batch
            lead = args[2].shape[:-1]
            args = [x.reshape((-1,) + x.shape[len(lead):]) for x in args]
            out = sparse_project_pallas_batched(*args, out_rows,
                                                interpret=_interpret_default())
            return out.reshape(lead + out.shape[1:]), True
        out = sparse_project_pallas_batched(*args, out_rows,
                                            interpret=_interpret_default())
        return out, True

    return f


def sparse_project(rows, cols, vals, mat, out_rows: int, *,
                   interpret: bool | None = None):
    """Dispatching entry: ``out = S @ mat`` for the static-nnz COO ``S``.

    ``interpret`` forces interpret-mode Pallas (tests); otherwise Pallas on
    TPU (vmap folds the batch into the grid), the XLA segment-sum fallback
    elsewhere.  Leading batch axes on all four operands run batched.
    """
    vals = jnp.asarray(vals)
    batched = vals.ndim > 1 or jnp.asarray(mat).ndim > 2
    if interpret is not None:
        if batched:
            r, c, v, m_ = _broadcast_batch(rows, cols, vals, mat)
            lead = v.shape[:-1]
            out = sparse_project_pallas_batched(
                r.reshape((-1,) + r.shape[-1:]),
                c.reshape((-1,) + c.shape[-1:]),
                v.reshape((-1,) + v.shape[-1:]),
                m_.reshape((-1,) + m_.shape[-2:]),
                out_rows, interpret=interpret)
            return out.reshape(lead + out.shape[1:])
        return sparse_project_pallas(
            jnp.asarray(rows, jnp.int32), jnp.asarray(cols, jnp.int32), vals,
            jnp.asarray(mat), out_rows, interpret=interpret)
    if jax.default_backend() == "tpu":
        f = _pallas_project_vmapped(out_rows)
        if batched:
            return jax.vmap(f)(*_broadcast_batch(rows, cols, vals, mat))
        return f(jnp.asarray(rows), jnp.asarray(cols), vals, jnp.asarray(mat))
    return sparse_project_xla(rows, cols, vals, mat, out_rows)
