"""Differential op-fuzz harness (ISSUE 9 satellite): seed-pinned randomized
``Compose`` chains over the WHOLE op vocabulary — RankK, AppendRows,
AppendCols, RemoveRows, RemoveCols, DenseDelta, Sparse, Decay, Window —
checked against ``apply_dense`` on the single, truncated, and batched
routes at several geometries.

The generator is a numpy-Philox walk (``np.random.Generator(Philox(seed))``)
so the core suite is fully deterministic and runs on the no-hypothesis
tier-1 CI job; a hypothesis layer on top widens the seed space when the
library is installed (the conftest shim skips it otherwise).

Exactness discipline: every sampled chain keeps the TRUE rank of every
intermediate matrix within the state's rank budget (rank-increasing ops are
budget-counted; append blocks are sampled inside the current row/column
space), so the planner's output must match the dense reference to
``ATOL`` — any drift is a real bug, not truncation noise.

Chain count: ``N_SEEDS x CHAINS_PER_SEED x len(GEOMETRIES)`` single-route
chains (>= 200 by construction, asserted below) plus the batched sweep.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro import api
from repro.api import SvdState
from repro.updates import (
    AppendCols,
    AppendRows,
    Compose,
    Decay,
    DenseDelta,
    RankK,
    RemoveCols,
    RemoveRows,
    Sparse,
    Window,
)

# float64 drift over a 3-op chain on O(10)-magnitude matrices reaches a few
# 1e-8; real lowering bugs show up at 1e-1 or worse, so 1e-6 separates the
# regimes with 5 orders of margin either side
ATOL = 1e-6
N_SEEDS = 12
CHAINS_PER_SEED = 9
GEOMETRIES = [(8, 7, 4), (7, 9, 4)]        # (m, n, state_rank)
DATA_RANK = 2
MAX_CHAIN = 3

# every sampled chain keeps dims inside these rails so the jit-compile set
# stays bounded (each distinct geometry compiles once per run)
MIN_DIM, MAX_DIM = 5, 12


def test_fuzz_covers_at_least_200_chains():
    assert N_SEEDS * CHAINS_PER_SEED * len(GEOMETRIES) >= 200


def _sample_op(rng, m, n, dense, rank_used, state_rank):
    """One random op valid at geometry (m, n) given the current dense
    reference; returns (op, new_dense, new_rank_used) or None to resample.

    ``rank_used`` counts the worst-case true rank so far; rank-increasing
    ops are only sampled while budget remains, keeping parity exact.
    """
    kinds = ["decay"]
    if rank_used < state_rank:
        kinds += ["rank_k", "dense_delta", "sparse"]
    if m + 1 <= MAX_DIM:
        kinds.append("append_rows")
    if n + 1 <= MAX_DIM:
        kinds.append("append_cols")
    if m - 1 >= max(MIN_DIM, state_rank) and m - 1 >= 1:
        kinds += ["remove_rows", "window"]
    if n - 1 >= max(MIN_DIM, state_rank):
        kinds.append("remove_cols")
    kind = kinds[rng.integers(len(kinds))]

    if kind == "decay":
        op = Decay(float(rng.uniform(0.5, 1.0)))
        return op, np.asarray(op.apply_dense(dense)), rank_used
    if kind == "rank_k":
        op = RankK(rng.normal(size=(m, 1)), rng.normal(size=(n, 1)))
        return op, np.asarray(op.apply_dense(dense)), rank_used + 1
    if kind == "dense_delta":
        delta = np.outer(rng.normal(size=m), rng.normal(size=n))
        op = DenseDelta(delta, rank=1)
        return op, np.asarray(op.apply_dense(dense)), rank_used + 1
    if kind == "sparse":
        nnz = 3
        row = int(rng.integers(m))              # one row: rank(S) = 1
        rows = np.full(nnz, row, dtype=np.int32)
        cols = rng.choice(n, size=nnz, replace=False).astype(np.int32)
        op = Sparse(rows, cols, rng.normal(size=nnz), rank=1)
        return op, np.asarray(op.apply_dense(dense)), rank_used + 1
    if kind == "append_rows":
        # rows inside the current row space: true rank unchanged
        rows = rng.normal(size=(1, m)) @ dense
        op = AppendRows(rows)
        return op, np.asarray(op.apply_dense(dense)), rank_used
    if kind == "append_cols":
        cols = dense @ rng.normal(size=(n, 1))
        op = AppendCols(cols)
        return op, np.asarray(op.apply_dense(dense)), rank_used
    if kind == "remove_rows":
        op = RemoveRows(int(rng.integers(m)))
        return op, np.asarray(op.apply_dense(dense)), rank_used
    if kind == "remove_cols":
        op = RemoveCols(int(rng.integers(n)))
        return op, np.asarray(op.apply_dense(dense)), rank_used
    # window: evict exactly one oldest row, with a decay
    op = Window(m - 1, lam=float(rng.uniform(0.5, 1.0)))
    return op, np.asarray(op.apply_dense(dense)), rank_used


def _sample_chain(rng, m, n, dense, state_rank):
    """A random 1..MAX_CHAIN op chain; returns (Compose-or-op, final dense)."""
    length = int(rng.integers(1, MAX_CHAIN + 1))
    ops, rank_used = [], DATA_RANK
    for _ in range(length):
        op, dense, rank_used = _sample_op(rng, dense.shape[0], dense.shape[1],
                                          dense, rank_used, state_rank)
        ops.append(op)
    chain = ops[0] if len(ops) == 1 else Compose(tuple(ops))
    return chain, dense


def _top_r(dense, r):
    u, s, vt = np.linalg.svd(np.asarray(dense), full_matrices=False)
    return (u[:, :r] * s[:r]) @ vt[:r]


def _run_chains(seed: int, n_chains: int = CHAINS_PER_SEED) -> int:
    """The differential core: n_chains per geometry under one Philox seed."""
    ran = 0
    for m, n, state_rank in GEOMETRIES:
        rng = np.random.Generator(np.random.Philox(seed * 1009 + m * 13 + n))
        base = rng.normal(size=(m, DATA_RANK)) @ rng.normal(size=(DATA_RANK, n))
        state = SvdState.from_dense(jnp.asarray(base), rank=state_rank)
        for c in range(n_chains):
            chain, ref_dense = _sample_chain(rng, m, n, base, state_rank)
            out = api.apply(state, chain)
            assert out.geometry[:2] == ref_dense.shape, (
                f"seed={seed} chain={c} spec={chain.spec()}"
            )
            got = np.asarray(out.materialize())
            want = _top_r(ref_dense, out.rank)
            err = float(np.abs(got - want).max())
            assert err < ATOL, (
                f"seed={seed} geom=({m},{n}) chain={c} err={err:.3e} "
                f"spec={chain.spec()}"
            )
            ran += 1
    return ran


@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_fuzz_single_route(seed):
    assert _run_chains(seed) == CHAINS_PER_SEED * len(GEOMETRIES)


def test_fuzz_batched_route():
    """Stacked-state sweep over the batch-generic ops (removes, window,
    decay, batched RankK): the stacked result must match per-member
    singles bitwise-closely on every sampled chain."""
    B, m, n, r = 3, 8, 7, 4
    rng = np.random.Generator(np.random.Philox(77))
    n_chains = 24
    for c in range(n_chains):
        bases = [rng.normal(size=(m, DATA_RANK)) @
                 rng.normal(size=(DATA_RANK, n)) for _ in range(B)]
        sts = [SvdState.from_dense(jnp.asarray(b), rank=r) for b in bases]
        stacked = SvdState(u=jnp.stack([s.u for s in sts]),
                           s=jnp.stack([s.s for s in sts]),
                           v=jnp.stack([s.v for s in sts]))
        pick = int(rng.integers(4))
        if pick == 0:
            op = RemoveRows(tuple(sorted(
                rng.choice(m, size=2, replace=False).tolist())))
        elif pick == 1:
            op = RemoveCols(int(rng.integers(n)))
        elif pick == 2:
            op = Window(m - 1, lam=float(rng.uniform(0.5, 1.0)))
        else:
            op = RankK(rng.normal(size=(B, m, 1)), rng.normal(size=(B, n, 1)))
        outb = api.apply(stacked, op)
        mat = np.asarray(outb.materialize())
        for j, st_j in enumerate(sts):
            op_j = op if pick != 3 else RankK(np.asarray(op.u)[j],
                                              np.asarray(op.v)[j])
            single = api.apply(st_j, op_j)
            np.testing.assert_allclose(
                mat[j], np.asarray(single.materialize()), atol=ATOL,
                err_msg=f"chain={c} member={j} spec={op.spec()}")


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=N_SEEDS, max_value=2**20))
def test_fuzz_hypothesis_layer(seed):
    """Wider seed space when hypothesis is installed (skipped otherwise by
    the conftest shim); 2 chains per geometry keeps each example cheap."""
    _run_chains(seed, n_chains=2)
