"""Compressed all-reduce primitives for the data-parallel axis.

The paper's system pitch: at DP scale a dense gradient all-reduce moves
``m*n`` floats per layer per step, while the rank-r compressed path moves
only the two factors — ``r*(m+n)`` floats (``factor_wire_bytes``).  This
module is the one place those collectives are issued, so every consumer
(``optim.compression``, ``dist.merge``, the serve layer) shares one wire
discipline and the dry-run HLO shows exactly these small collectives.

Everything is axis-name based (call under ``shard_map``); ``axis_name=None``
degrades to the single-worker no-op so the same code path runs unsharded.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.svd_update import TruncatedSvd

__all__ = [
    "pmean_factor",
    "psum_factor",
    "all_gather_tsvd",
    "factor_wire_bytes",
]


def pmean_factor(x: jax.Array, axis_name) -> jax.Array:
    """Mean-reduce one compression factor across the DP axis.

    The ONLY thing that crosses the wire in a compressed all-reduce round is
    this ``(m, r)`` / ``(n, r)`` factor — never the dense ``(m, n)`` gradient.
    """
    if axis_name is None:
        return x
    return jax.lax.pmean(x, axis_name)


def psum_factor(x: jax.Array, axis_name) -> jax.Array:
    """Sum-reduce one factor across the DP axis (no-op when unsharded)."""
    if axis_name is None:
        return x
    return jax.lax.psum(x, axis_name)


def all_gather_tsvd(tsvd: TruncatedSvd, axis_name) -> TruncatedSvd:
    """Gather per-worker truncated-SVD factors: leaves gain a leading
    ``(n_workers,)`` axis.  Wire cost is ``r*(m+n+1)`` floats per worker —
    the input to ``dist.merge.distributed_merge``'s local merge tree.

    ``axis_name=None`` returns the single-worker stack (leading axis 1).
    """
    if axis_name is None:
        return jax.tree.map(lambda x: x[None], tsvd)
    return jax.tree.map(lambda x: jax.lax.all_gather(x, axis_name), tsvd)


def factor_wire_bytes(m: int, n: int, rank: int, *, n_workers: int = 1,
                      itemsize: int = 4) -> dict:
    """Per-layer wire accounting: dense all-reduce vs the compressed factor
    exchange (two pmean rounds) vs a full factor all-gather."""
    dense = m * n * itemsize
    compressed = rank * (m + n) * itemsize
    gather = n_workers * rank * (m + n + 1) * itemsize
    return {
        "dense_allreduce": dense,
        "compressed_allreduce": compressed,
        "factor_allgather": gather,
        "ratio": dense / compressed,
    }
