"""Serving: LM engine (prefill/decode) + the streaming SVD-update service.

``serve.engine``      — batched token generation over ModelApi caches.
``serve.svd_service`` — checkpointable async micro-batching rank-1
                        SVD-update service: many streams enqueue (a, b)
                        pairs, each flush is one batched
                        ``core.engine.SvdEngine`` call (batch axis
                        shardable over the policy mesh), snapshots persist
                        through ``train.checkpoint`` (DESIGN.md §9).
"""

from repro.serve.svd_service import (  # noqa: F401
    SNAPSHOT_VERSION,
    ServiceSnapshot,
    SvdService,
    SvdServiceStats,
)
