import sys
import types

import jax
import pytest

# Core numerics (secular / Loewner / Cauchy) need f64 for the orthogonality
# guarantees under test. Model code pins its dtypes explicitly, so enabling
# x64 only changes defaults. NOTE: XLA_FLAGS device-count forcing is NOT set
# here on purpose — only launch/dryrun.py uses 512 placeholder devices;
# distributed tests spawn subprocesses with their own env.
jax.config.update("jax_enable_x64", True)


# ---------------------------------------------------------------------------
# hypothesis shim: property tests are optional (the `test` extra installs the
# real library). Without it, `from hypothesis import given, ...` resolves to
# this stub and @given tests are collected but skipped — the rest of the
# module (the deterministic tier-1 tests) still runs.
# ---------------------------------------------------------------------------

try:
    import hypothesis  # noqa: F401
except ImportError:
    _skip = pytest.mark.skip(reason="hypothesis not installed (pip install -e .[test])")

    def _given(*_args, **_kwargs):
        def deco(fn):
            return _skip(fn)

        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: (lambda *a, **k: None)

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
