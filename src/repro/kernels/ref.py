"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests).

The secular oracle shares its bisection/Newton loop body with the kernel
itself (``kernels.secular_body``) so the two cannot drift; the fused-update
oracle is the *unfused* chain of per-phase dispatches
(``core.svd_update._svd_update_impl(method="direct")``) — an independent
implementation of the same algorithm, which is what makes it a real
reference for the megakernel rather than a restatement of it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.secular_body import secular_iterate

__all__ = [
    "cauchy_matmul_ref",
    "secular_solve_ref",
    "nearfield_ref",
    "svd_update_fused_ref",
]


def cauchy_matmul_ref(w, src, anchor_vals, tau, tgt_mask):
    """Oracle for kernels.cauchy_matmul.cauchy_matmul_pallas."""
    denom = (src[:, None] - anchor_vals[None, :]) - tau[None, :]
    safe = jnp.where(denom == 0.0, 1.0, denom)
    c = jnp.where(denom != 0.0, 1.0 / safe, 0.0) * tgt_mask.astype(w.dtype)[None, :]
    return w @ c


def secular_solve_ref(dc, zc2, rho, anchor_vals, lo, hi, *, n_bisect=58, n_newton=4):
    """Oracle for kernels.secular_newton.secular_solve_pallas."""
    diff = dc[:, None] - anchor_vals[None, :]
    return secular_iterate(diff, zc2, rho, lo, hi,
                           n_bisect=n_bisect, n_newton=n_newton, poles_axis=0)


def svd_update_fused_ref(u, s, v, a, b, *, sign_fix=True, deflate_rtol=None):
    """Oracle for kernels.fused_update: the unfused per-phase dispatch chain.

    Returns the plain ``(u, s, v, d_left, d_right)`` tuple.  Differences vs
    the fused body are limited to floating-point op order and the deflation
    strategy for *near*-coincident poles (the fused body merges by pole gap,
    the chain by Givens off-diagonal size) — tests compare at f64 tolerances.
    """
    from repro.core.svd_update import _svd_update_impl

    res = _svd_update_impl(u, s, v, a, b, method="direct",
                           sign_fix=sign_fix, deflate_rtol=deflate_rtol)
    return (res.u, res.s, res.v, res.d_left, res.d_right)


def nearfield_ref(w_near, x_near, av_b, tau_b, tgt_mask):
    """Oracle for kernels.nearfield.nearfield_pallas."""
    denom = (av_b[:, None, :] - x_near[:, :, None]) + tau_b[:, None, :]
    safe = jnp.where(denom == 0.0, 1.0, denom)
    c = jnp.where(denom != 0.0, 1.0 / safe, 0.0) * tgt_mask.astype(w_near.dtype)[:, None, :]
    return jnp.einsum("rbc,bct->rbt", w_near, c)
