"""Pallas TPU kernel: in-VMEM secular-equation root solve.

The jnp solver (core.secular.secular_solve) re-reads the (N poles x M roots)
difference tensor from HBM on every bisection sweep: ~n_iter * N * M * 8B of
traffic. This kernel keeps the pole vector and a (BN=all, BM) tile of root
state resident in VMEM for all iterations — HBM traffic drops to O(N + M),
turning the O(n^2) eigenvalue phase (paper Table 1, row 2) from memory-bound
to VPU compute-bound.

Grid: (M/BM,). Each program owns BM roots and the full pole set. The entire
bisection + Newton iteration runs inside the kernel (jax.lax loops).
Brackets/anchors are precomputed by the caller exactly like the jnp path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.secular_body import secular_iterate

__all__ = ["secular_solve_pallas"]


def _kernel(dc_ref, zc2_ref, rho_ref, av_ref, lo_ref, hi_ref, tau_ref, *, n_bisect, n_newton):
    dc = dc_ref[...][0]     # (N,)
    zc2 = zc2_ref[...][0]   # (N,)  (invalid sources pre-zeroed)
    rho = rho_ref[...][0, 0]
    av = av_ref[...][0]     # (BM,)
    lo = lo_ref[...][0]
    hi = hi_ref[...][0]

    diff = dc[:, None] - av[None, :]  # (N, BM) — resident for all iterations
    # the loop body is shared with kernels.ref / kernels.fused_update
    # (kernels.secular_body) so the kernel and its oracle cannot drift
    tau = secular_iterate(diff, zc2, rho, lo, hi,
                          n_bisect=n_bisect, n_newton=n_newton, poles_axis=0)
    tau_ref[...] = tau[None, :]


@functools.partial(
    jax.jit, static_argnames=("block_m", "n_bisect", "n_newton", "interpret")
)
def secular_solve_pallas(
    dc: jax.Array,
    zc2: jax.Array,
    rho: jax.Array,
    anchor_vals: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    *,
    block_m: int = 128,
    n_bisect: int = 58,
    n_newton: int = 4,
    interpret: bool = False,
) -> jax.Array:
    """Solve w(av_i + tau_i) = 0 for tau_i within brackets [lo_i, hi_i]."""
    n = dc.shape[0]
    m = anchor_vals.shape[0]
    dt = dc.dtype

    bm = min(block_m, max(8, m))
    pad_m = (-m) % bm

    dc_p = dc[None, :]
    zc2_p = zc2[None, :]
    rho_p = jnp.reshape(rho.astype(dt), (1, 1))
    av_p = jnp.pad(anchor_vals, (0, pad_m))[None, :]
    # padded roots get a degenerate bracket [0, 0] -> tau 0
    lo_p = jnp.pad(lo, (0, pad_m))[None, :]
    hi_p = jnp.pad(hi, (0, pad_m))[None, :]
    mp = av_p.shape[1]

    kern = functools.partial(_kernel, n_bisect=n_bisect, n_newton=n_newton)
    out = pl.pallas_call(
        kern,
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((1, n), lambda j: (0, 0)),
            pl.BlockSpec((1, n), lambda j: (0, 0)),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
            pl.BlockSpec((1, bm), lambda j: (0, j)),
            pl.BlockSpec((1, bm), lambda j: (0, j)),
            pl.BlockSpec((1, bm), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, mp), dt),
        interpret=interpret,
    )(dc_p, zc2_p, rho_p, av_p, lo_p, hi_p)
    return out[0, :m]
