"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv/audio frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings (b, s_enc, d_model) directly. Positions are
sinusoidal (parameter-free, so 32k/500k decode shapes need no giant learned
tables). Decoder = causal self-attention + cross-attention + GELU MLP,
layernorm throughout (whisper convention).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn
from repro.models.attention import _sdpa  # shared scaled-dot-product core
from repro.models.transformer import remat_wrap, scan_or_unroll
from repro.models.layers import (
    cross_entropy,
    dot,
    embed_init,
    embed_lookup,
    mlp_apply,
    mlp_init,
    norm_apply,
    norm_init,
    uniform_init,
)

__all__ = [
    "encdec_init",
    "encdec_train_loss",
    "encdec_prefill",
    "encdec_decode_step",
    "encdec_cache_spec",
]


def _sinusoid(positions, d_model):
    half = d_model // 2
    freq = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _xattn_init(key, cfg, dtype):
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = (1.0 / d) ** 0.5
    return {
        "wq": uniform_init(ks[0], (d, h * dh), s, dtype),
        "wk": uniform_init(ks[1], (d, h * dh), s, dtype),
        "wv": uniform_init(ks[2], (d, h * dh), s, dtype),
        "wo": uniform_init(ks[3], (h * dh, d), (1.0 / (h * dh)) ** 0.5, dtype),
    }


def _enc_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": norm_init(cfg.d_model, cfg.norm_type, dtype),
        "attn": attn.attn_init(ks[0], cfg, dtype),
        "ln2": norm_init(cfg.d_model, cfg.norm_type, dtype),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype),
    }


def _dec_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": norm_init(cfg.d_model, cfg.norm_type, dtype),
        "self": attn.attn_init(ks[0], cfg, dtype),
        "ln_x": norm_init(cfg.d_model, cfg.norm_type, dtype),
        "cross": _xattn_init(ks[1], cfg, dtype),
        "ln2": norm_init(cfg.d_model, cfg.norm_type, dtype),
        "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype),
    }


def encdec_init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.n_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": embed_init(ks[2], cfg.padded_vocab, cfg.d_model, dtype),
        "enc_layers": jax.vmap(partial(_enc_layer_init, cfg=cfg, dtype=dtype))(enc_keys),
        "enc_norm": norm_init(cfg.d_model, cfg.norm_type, dtype),
        "dec_layers": jax.vmap(partial(_dec_layer_init, cfg=cfg, dtype=dtype))(dec_keys),
        "final_norm": norm_init(cfg.d_model, cfg.norm_type, dtype),
        "head": uniform_init(ks[3], (cfg.d_model, cfg.padded_vocab), cfg.d_model ** -0.5, dtype),
    }


def _encode(params, frames, cfg):
    b, s, _ = frames.shape
    pos = jnp.arange(s, dtype=jnp.int32)
    x = frames + _sinusoid(pos, cfg.d_model)[None].astype(frames.dtype)
    positions = jnp.broadcast_to(pos[None, :], (b, s))

    def body(carry, lp):
        h = carry + attn.attn_train(
            norm_apply(carry, lp["ln1"], cfg.norm_type), lp["attn"], cfg, positions, causal=False
        )
        h = h + mlp_apply(norm_apply(h, lp["ln2"], cfg.norm_type), lp["mlp"],
                          cfg.mlp_type, jnp.dtype(cfg.compute_dtype))
        return h, None

    body = remat_wrap(body, cfg)
    x, _ = scan_or_unroll(body, x, params["enc_layers"], cfg)
    return norm_apply(x, params["enc_norm"], cfg.norm_type)


def _cross_attn(x, memory_kv, lp, cfg):
    """x: (b, sq, d); memory_kv: precomputed {"k","v"}: (b, s_enc, h, dh)."""
    b, sq, _ = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    cd = jnp.dtype(cfg.compute_dtype)
    q = dot(x, lp["wq"], cd).reshape(b, sq, h, dh).astype(x.dtype)
    o = _sdpa(q, memory_kv["k"], memory_kv["v"], cfg, causal=False)
    return dot(o, lp["wo"], cd).astype(x.dtype)


def _memory_kv(memory, lp, cfg):
    b, s, _ = memory.shape
    h, dh = cfg.n_heads, cfg.head_dim
    cd = jnp.dtype(cfg.compute_dtype)
    k = dot(memory, lp["wk"], cd).reshape(b, s, h, dh).astype(memory.dtype)
    v = dot(memory, lp["wv"], cd).reshape(b, s, h, dh).astype(memory.dtype)
    return {"k": k, "v": v}


def _dec_layer_train(x, memory, lp, cfg, positions):
    h = x + attn.attn_train(norm_apply(x, lp["ln1"], cfg.norm_type), lp["self"], cfg, positions)
    mkv = _memory_kv(memory, lp["cross"], cfg)
    h = h + _cross_attn(norm_apply(h, lp["ln_x"], cfg.norm_type), mkv, lp["cross"], cfg)
    return h + mlp_apply(norm_apply(h, lp["ln2"], cfg.norm_type), lp["mlp"],
                         cfg.mlp_type, jnp.dtype(cfg.compute_dtype))


def _logits(x, params, cfg):
    cd = jnp.dtype(cfg.compute_dtype)
    logits = jnp.matmul(x.astype(cd), params["head"].astype(cd),
                        preferred_element_type=jnp.float32)
    vmask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    return jnp.where(vmask[None, None, :], logits, -1e30)


def encdec_forward(params, batch, cfg):
    memory = _encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    pos = jnp.arange(s, dtype=jnp.int32)
    x = embed_lookup(tokens, params["embed"])
    x = x + _sinusoid(pos, cfg.d_model)[None].astype(x.dtype)
    positions = jnp.broadcast_to(pos[None, :], (b, s))

    def body(carry, lp):
        return _dec_layer_train(carry, memory, lp, cfg, positions), None

    body = remat_wrap(body, cfg)
    x, _ = scan_or_unroll(body, x, params["dec_layers"], cfg)
    x = norm_apply(x, params["final_norm"], cfg.norm_type)
    return _logits(x, params, cfg)


def encdec_train_loss(params, batch, cfg):
    return cross_entropy(encdec_forward(params, batch, cfg), batch["labels"], cfg.vocab_size)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def encdec_cache_spec(cfg, batch, enc_len, max_dec_len, dtype):
    L, h, dh, kvh = cfg.n_layers, cfg.n_heads, cfg.head_dim, cfg.n_kv_heads
    return {
        "self": {
            "k": jax.ShapeDtypeStruct((L, batch, max_dec_len, kvh, dh), dtype),
            "v": jax.ShapeDtypeStruct((L, batch, max_dec_len, kvh, dh), dtype),
        },
        "cross": {
            "k": jax.ShapeDtypeStruct((L, batch, enc_len, h, dh), dtype),
            "v": jax.ShapeDtypeStruct((L, batch, enc_len, h, dh), dtype),
        },
    }


def encdec_prefill(params, batch, cfg, *, max_dec_len=None):
    """Encode frames + prefill decoder prompt; returns (logits, caches)."""
    memory = _encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    max_dec_len = max_dec_len or s
    pad = max_dec_len - s
    pos = jnp.arange(s, dtype=jnp.int32)
    x = embed_lookup(tokens, params["embed"])
    x = x + _sinusoid(pos, cfg.d_model)[None].astype(x.dtype)
    positions = jnp.broadcast_to(pos[None, :], (b, s))

    def body(carry, lp):
        x_in = carry
        h_norm = norm_apply(x_in, lp["ln1"], cfg.norm_type)
        a, self_kv = attn.attn_prefill(h_norm, lp["self"], cfg, positions)
        h = x_in + a
        mkv = _memory_kv(memory, lp["cross"], cfg)
        h = h + _cross_attn(norm_apply(h, lp["ln_x"], cfg.norm_type), mkv, lp["cross"], cfg)
        h = h + mlp_apply(norm_apply(h, lp["ln2"], cfg.norm_type), lp["mlp"],
                          cfg.mlp_type, jnp.dtype(cfg.compute_dtype))
        self_kv = jax.tree.map(
            lambda c: jnp.pad(c, ((0, 0), (0, pad)) + ((0, 0),) * (c.ndim - 2)), self_kv
        )
        return h, (self_kv, mkv)

    x, (self_kvs, cross_kvs) = scan_or_unroll(body, x, params["dec_layers"], cfg)
    x = norm_apply(x, params["final_norm"], cfg.norm_type)
    return _logits(x[:, -1:, :], params, cfg), {"self": self_kvs, "cross": cross_kvs}


def encdec_decode_step(params, cache, token, pos, cfg):
    b = token.shape[0]
    x = embed_lookup(token, params["embed"])
    x = x + _sinusoid(jnp.full((1,), pos, jnp.int32), cfg.d_model)[None].astype(x.dtype)

    def body(carry, xs):
        lp, self_kv, cross_kv = xs
        x_in = carry
        h_norm = norm_apply(x_in, lp["ln1"], cfg.norm_type)
        a, new_self = attn.attn_decode(h_norm, lp["self"], cfg, self_kv, pos)
        h = x_in + a
        h = h + _cross_attn(norm_apply(h, lp["ln_x"], cfg.norm_type), cross_kv, lp["cross"], cfg)
        h = h + mlp_apply(norm_apply(h, lp["ln2"], cfg.norm_type), lp["mlp"],
                          cfg.mlp_type, jnp.dtype(cfg.compute_dtype))
        return h, new_self

    x, new_self_kvs = scan_or_unroll(body, x, (params["dec_layers"], cache["self"], cache["cross"]), cfg)
    x = norm_apply(x, params["final_norm"], cfg.norm_type)
    return _logits(x, params, cfg), {"self": new_self_kvs, "cross": cache["cross"]}
