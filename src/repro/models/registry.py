"""Architecture registry: one API for all assigned architectures.

``build_model(cfg)`` returns a ``ModelApi`` whose entry points cover the
assigned shape kinds:

  train_loss(params, batch)              — train_* shapes
  prefill(params, batch)                 — prefill_* shapes
  decode_step(params, cache, token, pos) — decode_* / long_* shapes

``input_specs(shape)`` yields ShapeDtypeStruct stand-ins for every input of
the relevant entry point (dry-run contract: no device allocation).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, rwkv_model, transformer

__all__ = ["ModelApi", "build_model", "zeros_like_specs"]


class ModelApi(NamedTuple):
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    train_loss: Callable[..., jax.Array]
    prefill: Callable[..., tuple]
    decode_step: Callable[..., tuple]
    input_specs: Callable[[ShapeConfig], dict]


def zeros_like_specs(specs):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)


def _tok(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def _decoder_api(cfg: ModelConfig) -> ModelApi:
    act_dt = jnp.dtype(cfg.compute_dtype)

    def input_specs(shape: ShapeConfig):
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            batch = {"tokens": _tok(b, s), "labels": _tok(b, s)}
            if cfg.frontend == "vision":
                p = cfg.n_frontend_tokens
                batch = {
                    "tokens": _tok(b, s - p),
                    "labels": _tok(b, s - p),
                    "patches": jax.ShapeDtypeStruct((b, p, cfg.d_model), act_dt),
                }
            return {"batch": batch}
        if shape.kind == "prefill":
            batch = {"tokens": _tok(b, s)}
            if cfg.frontend == "vision":
                p = cfg.n_frontend_tokens
                batch = {
                    "tokens": _tok(b, s - p),
                    "patches": jax.ShapeDtypeStruct((b, p, cfg.d_model), act_dt),
                }
            return {"batch": batch}
        # decode: one new token against a cache of seq_len
        return {
            "cache": transformer.decode_cache_spec(cfg, b, s, act_dt),
            "token": _tok(b, 1),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }

    return ModelApi(
        cfg=cfg,
        init=lambda key: transformer.decoder_init(key, cfg),
        train_loss=lambda params, batch: transformer.decoder_train_loss(params, batch, cfg),
        prefill=lambda params, batch, **kw: transformer.decoder_prefill(params, batch, cfg, **kw),
        decode_step=lambda params, cache, token, pos: transformer.decoder_decode_step(
            params, cache, token, pos, cfg
        ),
        input_specs=input_specs,
    )


def _hybrid_api(cfg: ModelConfig) -> ModelApi:
    act_dt = jnp.dtype(cfg.compute_dtype)

    def input_specs(shape: ShapeConfig):
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {"batch": {"tokens": _tok(b, s), "labels": _tok(b, s)}}
        if shape.kind == "prefill":
            return {"batch": {"tokens": _tok(b, s)}}
        return {
            "cache": hybrid.hybrid_state_spec(cfg, b, s, act_dt),
            "token": _tok(b, 1),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }

    return ModelApi(
        cfg=cfg,
        init=lambda key: hybrid.hybrid_init(key, cfg),
        train_loss=lambda params, batch: hybrid.hybrid_train_loss(params, batch, cfg),
        prefill=lambda params, batch, **kw: hybrid.hybrid_prefill(params, batch, cfg, **kw),
        decode_step=lambda params, cache, token, pos: hybrid.hybrid_decode_step(
            params, cache, token, pos, cfg
        ),
        input_specs=input_specs,
    )


def _rwkv_api(cfg: ModelConfig) -> ModelApi:
    act_dt = jnp.dtype(cfg.compute_dtype)

    def input_specs(shape: ShapeConfig):
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {"batch": {"tokens": _tok(b, s), "labels": _tok(b, s)}}
        if shape.kind == "prefill":
            return {"batch": {"tokens": _tok(b, s)}}
        return {
            "cache": rwkv_model.rwkv_state_spec(cfg, b, act_dt),
            "token": _tok(b, 1),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }

    return ModelApi(
        cfg=cfg,
        init=lambda key: rwkv_model.rwkv_model_init(key, cfg),
        train_loss=lambda params, batch: rwkv_model.rwkv_train_loss(params, batch, cfg),
        prefill=lambda params, batch: rwkv_model.rwkv_prefill(params, batch, cfg),
        decode_step=lambda params, cache, token, pos: rwkv_model.rwkv_decode_step(
            params, cache, token, pos, cfg
        ),
        input_specs=input_specs,
    )


def _encdec_api(cfg: ModelConfig) -> ModelApi:
    act_dt = jnp.dtype(cfg.compute_dtype)

    def input_specs(shape: ShapeConfig):
        b, s = shape.global_batch, shape.seq_len
        s_dec = max(s // cfg.dec_ratio, 64)
        if shape.kind == "train":
            return {
                "batch": {
                    "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), act_dt),
                    "tokens": _tok(b, s_dec),
                    "labels": _tok(b, s_dec),
                }
            }
        if shape.kind == "prefill":
            return {
                "batch": {
                    "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), act_dt),
                    "tokens": _tok(b, s_dec),
                }
            }
        return {
            "cache": encdec.encdec_cache_spec(cfg, b, s, s, act_dt),
            "token": _tok(b, 1),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }

    return ModelApi(
        cfg=cfg,
        init=lambda key: encdec.encdec_init(key, cfg),
        train_loss=lambda params, batch: encdec.encdec_train_loss(params, batch, cfg),
        prefill=lambda params, batch, **kw: encdec.encdec_prefill(params, batch, cfg, **kw),
        decode_step=lambda params, cache, token, pos: encdec.encdec_decode_step(
            params, cache, token, pos, cfg
        ),
        input_specs=input_specs,
    )


def build_model(cfg: ModelConfig) -> ModelApi:
    if cfg.encdec:
        return _encdec_api(cfg)
    if cfg.rwkv is not None:
        return _rwkv_api(cfg)
    if cfg.ssm is not None and cfg.attn_every > 0:
        return _hybrid_api(cfg)
    return _decoder_api(cfg)
