"""Streaming SVD maintenance — the paper's motivating big-data scenario.

A rank-r sketch of a user x item interaction matrix is maintained under a
stream of rank-1 observations (each event adds w * e_u v_item^T). Every
event is one ``api.update`` on a truncated ``SvdState`` (Brand augmentation
+ the paper's diagonal-plus-rank-1 core — geometry picks the truncated
route; no method name threading). We compare against periodically
recomputing a fresh SVD — dominant singular values track to ~1e-8 relative
(truncation inherently discards rank-(r+1) mass, so exact equality is
impossible for any streaming method) while the per-event cost is
O((m+n) r + r^2 p) instead of O(m n min(m,n)).

Run:  PYTHONPATH=src python examples/streaming_svd.py
"""

import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro import api

M_USERS, N_ITEMS, RANK, EVENTS = 600, 400, 12, 200


def main():
    rng = np.random.default_rng(0)

    # ground truth low-rank preference structure + noise stream
    u_true = rng.normal(size=(M_USERS, 4))
    v_true = rng.normal(size=(N_ITEMS, 4))

    dense = np.zeros((M_USERS, N_ITEMS))
    t = api.SvdState.from_factors(
        np.linalg.qr(rng.normal(size=(M_USERS, RANK)))[0],
        np.zeros((RANK,)),
        np.linalg.qr(rng.normal(size=(N_ITEMS, RANK)))[0],
    )

    policy = api.UpdatePolicy()            # auto: the (r+1)-sized core runs direct
    t0 = time.perf_counter()
    for step in range(EVENTS):
        # one "interaction batch": a user factor bumps an item direction
        a = u_true @ rng.normal(size=4) + 0.1 * rng.normal(size=M_USERS)
        b = v_true @ rng.normal(size=4) + 0.1 * rng.normal(size=N_ITEMS)
        dense += np.outer(a, b)
        t = api.update(t, jnp.asarray(a), jnp.asarray(b), policy)
    dt = time.perf_counter() - t0

    sv_stream = np.asarray(t.s)
    sv_true = np.linalg.svd(dense, compute_uv=False)[:RANK]
    rel = np.abs(sv_stream - sv_true) / sv_true[0]
    print(f"{EVENTS} rank-1 events in {dt:.2f}s "
          f"({dt / EVENTS * 1e3:.2f} ms/event, plan-cached engine, CPU)")
    print("top-5 singular values (streamed) :", np.round(sv_stream[:5], 6))
    print("top-5 singular values (recompute):", np.round(sv_true[:5], 6))
    print(f"max relative deviation over rank-{RANK}: {rel.max():.2e}")
    assert rel[:3].max() < 1e-6  # dominant structure tracked
    print("OK")


if __name__ == "__main__":
    main()
