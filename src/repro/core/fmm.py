"""TPU-native 1-D Fast Multipole Method for Cauchy sums (paper §5, App. D).

Evaluates, for targets ``y`` and sources ``x`` with weights ``w``:

    f(y_i) = sum_j w_j / (y_i - x_j)

in O((N+M) p) per weight vector, p = Chebyshev order (paper: eps = 5^-p).

Adaptation from the paper's scalar tree-walk FMM to TPU (see DESIGN.md §2):

* All boxes of a level form one tensor; P2M/M2M/M2L/L2P are dense (batched)
  matmuls against *shared, scale-invariant* p x p operators. The kernel
  1/(y-x) is homogeneous, so one M2L operator per offset in {±2, ±3} serves
  every level (scaled by 1/r_level).
* The plan/apply split: ``build_plan`` computes geometry (value-space binning
  with static capacity, anterpolation/evaluation operators, near-field
  inverse blocks) once; ``fmm_apply`` then runs the whole FMM as einsums for
  a *batch* of weight vectors — this is what makes ``U2 = U1 @ C`` (n Trummer
  instances, paper §3.2.1) MXU-shaped.
* Static shapes: value binning uses a fixed per-box capacity; pathological
  clustering sets ``plan.overflow`` and callers fall back to the dense path.
* Near-pole accuracy: targets may be passed in anchored form
  (y_i = src[anchor_i] + tau_i) so near-field denominators are computed
  without cancellation (matters when updated eigenvalues hug old ones).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.cheb import cheb_nodes, lagrange_eval

__all__ = ["FmmPlan", "build_plan", "fmm_apply", "fmm_matvec", "fmm_error_bound"]

_M2L_OFFSETS = (-3, -2, 2, 3)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "src",
        "src_box_idx",
        "src_box_mask",
        "tgt_box_idx",
        "tgt_box_mask",
        "anterp",
        "tgt_eval",
        "m2m_l",
        "m2m_r",
        "t_hat",
        "near_inv",
        "near_src_idx",
        "out_idx",
        "out_inv",
        "src_out_idx",
        "src_out_inv",
        "span",
        "overflow",
    ],
    meta_fields=["p", "nlevs", "nb", "cap", "capt", "n", "m", "k_out"],
)
@dataclasses.dataclass(frozen=True)
class FmmPlan:
    # geometry + operators (arrays)
    src: jax.Array            # (N,) source coordinates
    src_box_idx: jax.Array    # (nb, cap) int32 indices into sources
    src_box_mask: jax.Array   # (nb, cap) bool
    tgt_box_idx: jax.Array    # (nb, capt) int32 indices into targets
    tgt_box_mask: jax.Array   # (nb, capt) bool
    anterp: jax.Array         # (nb, p, cap) P2M operator per leaf box
    tgt_eval: jax.Array       # (nb, capt, p) L2P operator per leaf box
    m2m_l: jax.Array          # (p, p) child->parent (left)
    m2m_r: jax.Array          # (p, p) child->parent (right)
    t_hat: jax.Array          # (4, p, p) scale-free M2L for offsets (-3,-2,2,3)
    near_inv: jax.Array       # (nb, 3*cap, capt) masked 1/(y - x) near-field blocks
    near_src_idx: jax.Array   # (nb, 3*cap) int32 indices into sources
    out_idx: jax.Array        # (k_out,) int32 out-of-grid target indices
    out_inv: jax.Array        # (k_out, N) masked 1/(y - x) for outlier targets
    src_out_idx: jax.Array    # (k_out,) int32 out-of-bulk source indices
    src_out_inv: jax.Array    # (k_out, M) masked 1/(y - x) for outlier sources
    span: jax.Array           # () domain scale (for level radii)
    overflow: jax.Array       # () bool — capacity exceeded somewhere
    # static structure
    p: int
    nlevs: int
    nb: int
    cap: int
    capt: int
    n: int
    m: int
    k_out: int


def fmm_error_bound(p: int) -> float:
    """Geometric convergence bound for offset-2 separation (~(3+2sqrt2)^-p)."""
    rho = 3.0 + 2.0 * (2.0 ** 0.5)
    return 4.0 * rho ** (1 - p)


def _bin_points(x, valid, lo, width, nb, cap):
    """Static-shape value binning. Invalid points go to a discarded overflow bin."""
    n = x.shape[0]
    ib = jnp.clip(jnp.floor((x - lo) / width).astype(jnp.int32), 0, nb - 1)
    ib = jnp.where(valid, ib, nb)  # invalid -> spill bin nb
    order = jnp.argsort(ib, stable=True)
    ib_sorted = ib[order]
    starts = jnp.searchsorted(ib_sorted, jnp.arange(nb + 1), side="left")
    rank = jnp.arange(n) - starts[ib_sorted]
    ok = (rank < cap) & (ib_sorted < nb)
    counts = jnp.bincount(jnp.where(ib < nb, ib, nb), length=nb + 1)[:nb]
    overflow = jnp.any(counts > cap)

    box_idx = jnp.zeros((nb + 1, cap), jnp.int32)
    box_mask = jnp.zeros((nb + 1, cap), bool)
    rows = jnp.where(ok, ib_sorted, nb)
    cols = jnp.clip(rank, 0, cap - 1)
    box_idx = box_idx.at[rows, cols].set(order.astype(jnp.int32), mode="drop")
    box_mask = box_mask.at[rows, cols].set(ok, mode="drop")
    return box_idx[:nb], box_mask[:nb], overflow


def build_plan(
    src: jax.Array,
    tgt: jax.Array,
    *,
    p: int = 20,
    leaf_size: int | None = None,
    cap_factor: int = 4,
    src_valid: jax.Array | None = None,
    tgt_valid: jax.Array | None = None,
    tgt_anchor: jax.Array | None = None,
    tgt_tau: jax.Array | None = None,
) -> FmmPlan:
    """Build the FMM geometry + operators for sources ``src`` / targets ``tgt``.

    If ``tgt_anchor``/``tgt_tau`` are given, targets are ``src[anchor] + tau``
    and near-field denominators use the cancellation-free form
    ``(src_j - src[anchor_i]) - tau_i``.
    """
    n = src.shape[0]
    m = tgt.shape[0]
    dt = src.dtype
    if src_valid is None:
        src_valid = jnp.ones((n,), bool)
    if tgt_valid is None:
        tgt_valid = jnp.ones((m,), bool)
    if leaf_size is None:
        leaf_size = max(2 * p, 8)

    nlevs = max(2, math.ceil(math.log2(max(n, 1) / leaf_size))) if n > leaf_size else 2
    nb = 2 ** nlevs
    cap = cap_factor * max(n // nb, 1) + 8
    capt = cap_factor * max(m // nb, 1) + 8
    k_out = 8  # static cap on out-of-grid targets handled densely

    # The grid covers the BULK of the source distribution. Extreme poles
    # (realistic spectra — e.g. squared singular values — have one huge
    # eigenvalue above a cluster) and the out-of-range secular roots they
    # induce would degenerate a uniform grid into one crowded box; instead
    # both are peeled off (up to k_out each) and handled as dense rows/cols.
    big = jnp.asarray(jnp.finfo(dt).max, dt)
    src_masked = jnp.where(src_valid, src, jnp.nan)
    lo_full = jnp.min(jnp.where(src_valid, src, big))
    hi_full = jnp.max(jnp.where(src_valid, src, -big))
    q_lo = jnp.nanquantile(src_masked, 0.02)
    q_hi = jnp.nanquantile(src_masked, 0.98)
    bulk_span = (q_hi - q_lo) + jnp.finfo(dt).tiny
    use_bulk = (hi_full - lo_full) > 4.0 * bulk_span
    lo = jnp.where(use_bulk, q_lo - 0.05 * bulk_span, lo_full)
    hi = jnp.where(use_bulk, q_hi + 0.05 * bulk_span, hi_full)
    span = (hi - lo) * (1 + 16 * jnp.finfo(dt).eps) + jnp.finfo(dt).tiny
    width = span / nb

    src_in = (src >= lo) & (src < lo + span)
    src_tree_valid = src_valid & src_in
    in_range = (tgt >= lo) & (tgt < lo + span)
    tgt_tree_valid = tgt_valid & in_range

    sb_idx, sb_mask, ovf_s = _bin_points(src, src_tree_valid, lo, width, nb, cap)
    tb_idx, tb_mask, ovf_t = _bin_points(tgt, tgt_tree_valid, lo, width, nb, capt)

    # outlier targets: dense rows against all sources
    is_out = tgt_valid & ~in_range
    score = jnp.where(is_out, jnp.maximum(lo - tgt, tgt - (lo + span)), -1.0)
    _, out_idx = jax.lax.top_k(score, k_out)
    out_idx = out_idx.astype(jnp.int32)
    out_mask = score[out_idx] > 0
    if tgt_anchor is not None:
        # anchored form — outliers a hair past the grid edge (tiny tau on the
        # top pole) keep full relative accuracy
        denom_out = (src[tgt_anchor[out_idx]][:, None] - src[None, :]) + tgt_tau[out_idx][:, None]
    else:
        denom_out = tgt[out_idx][:, None] - src[None, :]
    out_inv = jnp.where(
        out_mask[:, None] & src_valid[None, :] & (denom_out != 0.0),
        1.0 / jnp.where(denom_out == 0.0, 1.0, denom_out),
        0.0,
    )

    # outlier sources: dense columns against the non-outlier targets (outlier
    # targets already see ALL sources through out_inv — exclude them here to
    # avoid double counting)
    s_is_out = src_valid & ~src_in
    s_score = jnp.where(s_is_out, jnp.maximum(lo - src, src - (lo + span)), -1.0)
    _, src_out_idx = jax.lax.top_k(s_score, k_out)
    src_out_idx = src_out_idx.astype(jnp.int32)
    s_out_mask = s_score[src_out_idx] > 0
    if tgt_anchor is not None:
        denom_s = (src[tgt_anchor][None, :] - src[src_out_idx][:, None]) + tgt_tau[None, :]
    else:
        denom_s = tgt[None, :] - src[src_out_idx][:, None]
    tgt_not_out = tgt_valid & in_range
    src_out_inv = jnp.where(
        s_out_mask[:, None] & tgt_not_out[None, :] & (denom_s != 0.0),
        1.0 / jnp.where(denom_s == 0.0, 1.0, denom_s),
        0.0,
    )

    overflow = ovf_s | ovf_t | (jnp.sum(is_out) > k_out) | (jnp.sum(s_is_out) > k_out)

    t = cheb_nodes(p, dt)
    centers = lo + (jnp.arange(nb, dtype=dt) + 0.5) * width
    r_leaf = 0.5 * width

    # P2M anterpolation per leaf box: anterp[b, q, c] = u_q((x - c_b)/r)
    xs = src[sb_idx]
    xhat = (xs - centers[:, None]) / r_leaf
    anterp = jnp.moveaxis(lagrange_eval(t, xhat), 0, 1) * sb_mask[:, None, :]

    # L2P per leaf box: tgt_eval[b, c, q] = u_q((y - c_b)/r)
    ys = tgt[tb_idx]
    yhat = (ys - centers[:, None]) / r_leaf
    tgt_eval = jnp.moveaxis(lagrange_eval(t, yhat), 0, -1) * tb_mask[:, :, None]

    # shared translation operators
    m2m_l = lagrange_eval(t, (t - 1.0) / 2.0)  # (p=q, p=q') : u_q(left-child node q')
    m2m_r = lagrange_eval(t, (t + 1.0) / 2.0)
    t_hat = jnp.stack(
        [1.0 / (t[:, None] - t[None, :] - 2.0 * o) for o in _M2L_OFFSETS], axis=0
    )

    # near field: neighbor boxes b-1, b, b+1 — masked inverse blocks
    def shift_rows(a, mask, o):
        if o == 0:
            return a, mask
        pad_spec = ((1, 0),) + ((0, 0),) * (a.ndim - 1) if o > 0 else ((0, 1),) + ((0, 0),) * (a.ndim - 1)
        if o > 0:  # out[b] = a[b-1]
            return (
                jnp.pad(a, pad_spec)[:-1],
                jnp.pad(mask, pad_spec[: mask.ndim], constant_values=False)[:-1],
            )
        return (
            jnp.pad(a, pad_spec)[1:],
            jnp.pad(mask, pad_spec[: mask.ndim], constant_values=False)[1:],
        )

    near_idx_parts, near_mask_parts = [], []
    for o in (-1, 0, 1):
        ai, mi = shift_rows(sb_idx, sb_mask, -o)  # neighbor box b+o
        near_idx_parts.append(ai)
        near_mask_parts.append(mi)
    near_src_idx = jnp.concatenate(near_idx_parts, axis=1)  # (nb, 3cap)
    near_mask = jnp.concatenate(near_mask_parts, axis=1)

    x_near = src[near_src_idx]  # (nb, 3cap)
    if tgt_anchor is not None:
        anchor_vals = src[tgt_anchor]
        av_b = anchor_vals[tb_idx]  # (nb, capt)
        tau_b = tgt_tau[tb_idx]
        denom = (av_b[:, None, :] - x_near[:, :, None]) + tau_b[:, None, :]
    else:
        y_b = tgt[tb_idx]
        denom = y_b[:, None, :] - x_near[:, :, None]  # (nb, 3cap, capt)
    pair_mask = near_mask[:, :, None] & tb_mask[:, None, :] & (denom != 0.0)
    near_inv = jnp.where(pair_mask, 1.0 / jnp.where(denom == 0.0, 1.0, denom), 0.0)

    return FmmPlan(
        src=src,
        src_box_idx=sb_idx,
        src_box_mask=sb_mask,
        tgt_box_idx=tb_idx,
        tgt_box_mask=tb_mask,
        anterp=anterp,
        tgt_eval=tgt_eval,
        m2m_l=m2m_l,
        m2m_r=m2m_r,
        t_hat=t_hat,
        near_inv=near_inv,
        near_src_idx=near_src_idx,
        out_idx=out_idx,
        out_inv=out_inv,
        src_out_idx=src_out_idx,
        src_out_inv=src_out_inv,
        span=span,
        overflow=overflow,
        p=p,
        nlevs=nlevs,
        nb=nb,
        cap=cap,
        capt=capt,
        n=n,
        m=m,
        k_out=k_out,
    )


def _shift_boxes(w, o):
    """out[..., b, :] = w[..., b+o, :] with zero fill."""
    if o == 0:
        return w
    nbl = w.shape[-2]
    if o > 0:
        pad = [(0, 0)] * w.ndim
        pad[-2] = (0, o)
        return jnp.pad(w, pad)[..., o : o + nbl, :]
    pad = [(0, 0)] * w.ndim
    pad[-2] = (-o, 0)
    return jnp.pad(w, pad)[..., :nbl, :]


@jax.jit
def fmm_apply(plan: FmmPlan, w: jax.Array) -> jax.Array:
    """f[r, i] = sum_j w[r, j] / (tgt_i - src_j)   for w of shape (R, N)."""
    squeeze = w.ndim == 1
    if squeeze:
        w = w[None, :]
    r_dim = w.shape[0]
    dt = plan.src.dtype
    nlevs, nb, p = plan.nlevs, plan.nb, plan.p

    # ---- P2M at leaves
    w_boxed = w[:, plan.src_box_idx] * plan.src_box_mask[None, :, :]  # (R, nb, cap)
    mp = {nlevs: jnp.einsum("bqc,rbc->rbq", plan.anterp, w_boxed)}

    # ---- upward (M2M)
    for lvl in range(nlevs - 1, 1, -1):
        child = mp[lvl + 1].reshape(r_dim, 2 ** lvl, 2, p)
        mp[lvl] = child[:, :, 0, :] @ plan.m2m_l.T + child[:, :, 1, :] @ plan.m2m_r.T

    # ---- downward (M2L + L2L)
    loc = jnp.zeros((r_dim, 4, p), dt)
    for lvl in range(2, nlevs + 1):
        nbl = 2 ** lvl
        if lvl > 2:
            parent = loc  # (R, nbl/2, p)
            even = parent @ plan.m2m_l
            odd = parent @ plan.m2m_r
            loc = jnp.stack([even, odd], axis=2).reshape(r_dim, nbl, p)
        else:
            loc = jnp.zeros((r_dim, nbl, p), dt)
        r_lvl = plan.span / (2.0 ** (lvl + 1))
        box_ids = jnp.arange(nbl)
        even_mask = (box_ids % 2 == 0).astype(dt)
        odd_mask = 1.0 - even_mask
        # even boxes: offsets {-2, +2, +3}; odd boxes: offsets {-3, -2, +2}
        parity_mask = {
            -3: odd_mask,
            -2: even_mask + odd_mask,
            2: even_mask + odd_mask,
            3: even_mask,
        }
        contrib = jnp.zeros_like(loc)
        for oi, o in enumerate(_M2L_OFFSETS):
            w_shift = _shift_boxes(mp[lvl], o)  # (R, nbl, p) multipoles of box b+o
            term = w_shift @ plan.t_hat[oi].T  # l[q] = sum_q' that[q,q'] w[q']
            contrib = contrib + term * parity_mask[o][None, :, None]
        loc = loc + contrib / r_lvl

    # ---- leaf evaluation: far field + near field
    f_far = jnp.einsum("btq,rbq->rbt", plan.tgt_eval, loc)  # (R, nb, capt)
    w_near = w[:, plan.near_src_idx]  # (R, nb, 3cap) (mask folded into near_inv)
    f_near = jnp.einsum("rbc,bct->rbt", w_near, plan.near_inv)
    f_boxed = f_far + f_near

    # ---- scatter back to target order
    out = jnp.zeros((r_dim, plan.m), dt)
    flat_idx = plan.tgt_box_idx.reshape(-1)
    flat_val = f_boxed.reshape(r_dim, -1)
    flat_mask = plan.tgt_box_mask.reshape(-1)
    out = out.at[:, flat_idx].add(jnp.where(flat_mask[None, :], flat_val, 0.0))

    # ---- out-of-grid targets (dense rows; masks folded into out_inv)
    f_out = jnp.einsum("rn,kn->rk", w, plan.out_inv)
    out = out.at[:, plan.out_idx].add(f_out)

    # ---- out-of-bulk sources (dense columns over in-grid targets)
    w_sout = w[:, plan.src_out_idx]                     # (R, k_out)
    out = out + jnp.einsum("rk,km->rm", w_sout, plan.src_out_inv)
    if squeeze:
        out = out[0]
    return out


def fmm_matvec(
    weights: jax.Array, src: jax.Array, tgt: jax.Array, *, p: int = 20, **kw
) -> jax.Array:
    """One-shot convenience:  f(tgt_i) = sum_j weights_j / (tgt_i - src_j)."""
    plan = build_plan(src, tgt, p=p, **kw)
    return fmm_apply(plan, weights)
