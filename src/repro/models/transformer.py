"""Decoder-only transformer (dense / MoE / MLA variants).

Layers are *stacked* (leading n_layers axis) and iterated with ``lax.scan`` so
the HLO stays O(1) in depth — essential for 80-layer dry-run compiles — with
optional per-layer remat (activation checkpointing).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models.layers import (
    cross_entropy,
    embed_init,
    embed_lookup,
    mlp_apply,
    mlp_init,
    norm_apply,
    norm_init,
    uniform_init,
)


def scan_or_unroll(body, carry, stacked, cfg, *, length=None):
    """lax.scan over stacked leaves, or a python unroll when
    cfg.scan_layers is False (dry-run cost-extraction mode: XLA's
    cost_analysis counts while-loop bodies ONCE, so roofline measurements
    use unrolled programs — see launch/dryrun.py)."""
    if cfg.scan_layers:
        return lax.scan(body, carry, stacked, length=length)
    n = length if length is not None else jax.tree.leaves(stacked)[0].shape[0]
    ys = []
    for i in range(n):
        sl = jax.tree.map(lambda a: a[i], stacked) if stacked is not None else None
        carry, y = body(carry, sl)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *xs: jnp.stack(xs), *ys)
    else:
        ys = None
    return carry, ys



def remat_wrap(body, cfg):
    """Activation-checkpoint wrapper honoring cfg.remat_policy.

    "full": recompute everything in backward (min memory, +1 fwd of FLOPs);
    "dots": save matmul outputs, recompute elementwise only — trades a little
    memory for removing most recompute FLOPs (see EXPERIMENTS.md §Perf).
    """
    if not cfg.remat:
        return body
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(body)

__all__ = [
    "decoder_init",
    "decoder_train_loss",
    "decoder_prefill",
    "decoder_decode_step",
    "decode_cache_spec",
]


def _use_mla(cfg) -> bool:
    return cfg.mla is not None


def _use_moe(cfg) -> bool:
    return cfg.moe is not None


def _layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    p = {"ln1": norm_init(cfg.d_model, cfg.norm_type, dtype),
         "ln2": norm_init(cfg.d_model, cfg.norm_type, dtype)}
    if _use_mla(cfg):
        p["mla"] = mla_mod.mla_init(ks[0], cfg, dtype)
    else:
        p["attn"] = attn.attn_init(ks[0], cfg, dtype)
    if _use_moe(cfg):
        p["moe"] = moe_mod.moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
    return p


def decoder_init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(partial(_layer_init, cfg=cfg, dtype=dtype))(layer_keys)
    params = {
        "embed": embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": norm_init(cfg.d_model, cfg.norm_type, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = uniform_init(
            k_head, (cfg.d_model, cfg.padded_vocab), cfg.d_model ** -0.5, dtype
        )
    return params


def _mixer_train(x, lp, cfg, positions):
    if _use_mla(cfg):
        return mla_mod.mla_train(x, lp["mla"], cfg, positions)
    return attn.attn_train(x, lp["attn"], cfg, positions)


def _ffn(x, lp, cfg):
    if _use_moe(cfg):
        return moe_mod.moe_apply(x, lp["moe"], cfg)
    return mlp_apply(x, lp["mlp"], cfg.mlp_type, jnp.dtype(cfg.compute_dtype))


def _layer_train(x, lp, cfg, positions):
    h = x + _mixer_train(norm_apply(x, lp["ln1"], cfg.norm_type), lp, cfg, positions)
    return h + _ffn(norm_apply(h, lp["ln2"], cfg.norm_type), lp, cfg)


def _logits(x, params, cfg):
    cd = jnp.dtype(cfg.compute_dtype)
    if cfg.tie_embeddings:
        w = params["embed"]["table"].T
    else:
        w = params["head"]
    logits = jnp.matmul(x.astype(cd), w.astype(cd), preferred_element_type=jnp.float32)
    vmask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    return jnp.where(vmask[None, None, :], logits, -1e30)


def _embed_inputs(params, batch, cfg):
    """Tokens (+ optional VLM patch embeddings prepended)."""
    x = embed_lookup(batch["tokens"], params["embed"])
    if cfg.frontend == "vision" and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    return x


def decoder_forward(params, batch, cfg):
    x = _embed_inputs(params, batch, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))

    def body(carry, lp):
        return _layer_train(carry, lp, cfg, positions), None

    body = remat_wrap(body, cfg)
    x, _ = scan_or_unroll(body, x, params["layers"], cfg)
    x = norm_apply(x, params["final_norm"], cfg.norm_type)
    return _logits(x, params, cfg)


def decoder_train_loss(params, batch, cfg):
    logits = decoder_forward(params, batch, cfg)
    labels = batch["labels"]
    if cfg.frontend == "vision" and "patches" in batch:
        logits = logits[:, -labels.shape[1]:, :]  # loss on the token stream only
    return cross_entropy(logits, labels, cfg.vocab_size)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def decode_cache_spec(cfg, batch, max_len, dtype):
    """ShapeDtypeStructs of the stacked decode cache."""
    if _use_mla(cfg):
        one = {
            "c_kv": jax.ShapeDtypeStruct((batch, max_len, cfg.mla.kv_lora_rank), dtype),
            "k_rope": jax.ShapeDtypeStruct((batch, max_len, cfg.mla.qk_rope_head_dim), dtype),
        }
    elif cfg.kv_cache_dtype == "int8":
        import jax.numpy as _jnp
        one = {
            "k": jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads, cfg.head_dim), _jnp.int8),
            "v": jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads, cfg.head_dim), _jnp.int8),
            "k_scale": jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads), _jnp.float32),
            "v_scale": jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads), _jnp.float32),
        }
    else:
        one = {
            "k": jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        }
    return jax.tree.map(
        lambda sd: jax.ShapeDtypeStruct((cfg.n_layers,) + sd.shape, sd.dtype), one
    )


def decoder_prefill(params, batch, cfg, *, max_len=None):
    """Returns (last-position logits, stacked kv cache padded to max_len)."""
    x = _embed_inputs(params, batch, cfg)
    b, s, _ = x.shape
    max_len = max_len or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))

    def body(carry, lp):
        x_in = carry
        h_norm = norm_apply(x_in, lp["ln1"], cfg.norm_type)
        if _use_mla(cfg):
            h, cache = mla_mod.mla_prefill(h_norm, lp["mla"], cfg, positions)
        else:
            h, cache = attn.attn_prefill(h_norm, lp["attn"], cfg, positions)
        h = x_in + h
        out = h + _ffn(norm_apply(h, lp["ln2"], cfg.norm_type), lp, cfg)
        pad = max_len - s
        cache = jax.tree.map(
            lambda c: jnp.pad(c, ((0, 0), (0, pad)) + ((0, 0),) * (c.ndim - 2)), cache
        )
        return out, cache

    body = remat_wrap(body, cfg)
    x, caches = scan_or_unroll(body, x, params["layers"], cfg)
    x = norm_apply(x, params["final_norm"], cfg.norm_type)
    return _logits(x[:, -1:, :], params, cfg), caches


def decoder_decode_step(params, cache, token, pos, cfg):
    """One decode step. token: (b, 1) int32; cache: stacked over layers."""
    x = embed_lookup(token, params["embed"])

    def body(carry, xs):
        lp, cache_l = xs
        x_in = carry
        h_norm = norm_apply(x_in, lp["ln1"], cfg.norm_type)
        if _use_mla(cfg):
            h, new_cache = mla_mod.mla_decode(h_norm, lp["mla"], cfg, cache_l, pos)
        else:
            h, new_cache = attn.attn_decode(h_norm, lp["attn"], cfg, cache_l, pos)
        h = x_in + h
        out = h + _ffn(norm_apply(h, lp["ln2"], cfg.norm_type), lp, cfg)
        return out, new_cache

    x, new_caches = scan_or_unroll(body, x, (params["layers"], cache), cfg)
    x = norm_apply(x, params["final_norm"], cfg.norm_type)
    return _logits(x, params, cfg), new_caches
