"""Process-global metrics registry (DESIGN.md §15).

One ``MetricsRegistry`` per process holds every counter/gauge/histogram the
system emits — serve flush accounting, fleet per-shard counters, engine and
planner cache hit/miss, numerical-health gauges.  Three rules keep it
production-shaped:

* **Zero overhead when disabled.**  The registry exists either way, but every
  instrumentation site in the library guards on ``repro.obs.enabled()`` —
  a single module-flag read — so the default (disabled) configuration adds
  no locks, no allocations and no dict lookups to hot paths.  Nothing is
  ever recorded from inside a traced/jitted function, so jaxprs are
  bitwise-independent of the obs state.

* **Allocation-free hot path when enabled.**  Metric handles are created
  once (``registry().counter(name)``) and cached by the call site; ``inc``
  / ``set`` / ``observe`` mutate preallocated slots (histograms are
  fixed-bucket int lists — no per-observation allocation).

* **Labels are first-class.**  ``counter(name, shard="3")`` returns an
  independent child series; exporters render the label sets and
  ``aggregate(name)`` sums across them (the fleet rolls per-shard series
  into fleet totals this way).

Exporters: ``to_json()`` (machine-readable snapshot, also the
snapshot/restore wire format) and ``to_prometheus()`` (text exposition
format v0.0.4 — ``# TYPE`` lines, ``_total``/``_bucket`` conventions).
"""

from __future__ import annotations

import json
import threading
from typing import Iterable

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "registry",
    "set_registry",
    "DEFAULT_BUCKETS_US",
]

# Latency-flavored default buckets (microseconds): 10us .. 10s, log-ish.
DEFAULT_BUCKETS_US = (
    10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
    1e3, 2.5e3, 5e3, 1e4, 2.5e4, 5e4, 1e5, 2.5e5, 5e5, 1e6, 1e7,
)


def _sanitize(name: str) -> str:
    """Prometheus metric-name charset: [a-zA-Z_:][a-zA-Z0-9_:]*."""
    out = "".join(c if (c.isalnum() or c in "_:") else "_" for c in name)
    if out and out[0].isdigit():
        out = "_" + out
    return out


def _labels_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _labels_text(key: tuple) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class Counter:
    """Monotonic counter.  ``inc`` is the only mutator."""

    __slots__ = ("name", "labels", "_value", "_lock")
    kind = "counter"

    def __init__(self, name: str, labels: tuple = ()):
        self.name = name
        self.labels = labels
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value

    def _state(self):
        return self._value

    def _restore(self, state) -> None:
        self._value = int(state)


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("name", "labels", "_value", "_lock")
    kind = "gauge"

    def __init__(self, name: str, labels: tuple = ()):
        self.name = name
        self.labels = labels
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, x: float) -> None:
        with self._lock:
            self._value = float(x)

    def max(self, x: float) -> None:
        """Keep the running maximum (peak gauges)."""
        with self._lock:
            if x > self._value:
                self._value = float(x)

    @property
    def value(self) -> float:
        return self._value

    def _state(self):
        return self._value

    def _restore(self, state) -> None:
        self._value = float(state)


class Histogram:
    """Fixed-bucket histogram — cumulative-bucket semantics on export.

    Bucket bounds are frozen at construction; ``observe`` does a linear
    scan over a small tuple and bumps one preallocated int slot (no
    allocation, no resize).  Tracks count/sum for mean and a running max.
    """

    __slots__ = ("name", "labels", "bounds", "_counts", "_count", "_sum",
                 "_max", "_lock")
    kind = "histogram"

    def __init__(self, name: str, labels: tuple = (),
                 bounds: Iterable[float] = DEFAULT_BUCKETS_US):
        self.name = name
        self.labels = labels
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(self.bounds):
            raise ValueError(f"histogram bounds must be sorted: {self.bounds}")
        self._counts = [0] * (len(self.bounds) + 1)   # +1: +Inf bucket
        self._count = 0
        self._sum = 0.0
        self._max = 0.0
        self._lock = threading.Lock()

    def observe(self, x: float) -> None:
        x = float(x)
        i = 0
        for b in self.bounds:          # small fixed tuple — no bisect alloc
            if x <= b:
                break
            i += 1
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += x
            if x > self._max:
                self._max = x

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def value(self) -> dict:
        return {"count": self._count, "sum": self._sum, "max": self._max,
                "counts": list(self._counts)}

    def _state(self):
        return {"bounds": list(self.bounds), "counts": list(self._counts),
                "count": self._count, "sum": self._sum, "max": self._max}

    def _restore(self, state) -> None:
        if list(state["bounds"]) != list(self.bounds):
            # bound mismatch across versions: keep count/sum, drop buckets
            self._counts = [0] * (len(self.bounds) + 1)
        else:
            self._counts = [int(c) for c in state["counts"]]
        self._count = int(state["count"])
        self._sum = float(state["sum"])
        self._max = float(state.get("max", 0.0))


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Thread-safe home for every metric series in the process.

    Series are keyed by ``(name, sorted-label-tuple)``; the first
    ``counter``/``gauge``/``histogram`` call for a key creates the series,
    later calls return the same object (cache the handle at the call site
    for hot paths).  Asking for an existing name with a different kind is
    an error — one name, one type, as in Prometheus.
    """

    def __init__(self):
        self._series: dict[tuple, object] = {}
        self._lock = threading.Lock()
        # bumped on reset() so call sites holding cached handles can tell
        # their series were dropped and must re-fetch
        self.generation = 0

    # -- handle creation ----------------------------------------------------

    def _get(self, kind: str, name: str, labels: dict, **kw):
        key = (name, _labels_key(labels))
        with self._lock:
            m = self._series.get(key)
            if m is None:
                m = _KINDS[kind](name, key[1], **kw)
                self._series[key] = m
            elif m.kind != kind:
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {kind}"
                )
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, *, bounds=DEFAULT_BUCKETS_US, **labels) -> Histogram:
        return self._get("histogram", name, labels, bounds=bounds)

    # -- read side ----------------------------------------------------------

    def series(self) -> list:
        with self._lock:
            return sorted(self._series.values(),
                          key=lambda m: (m.name, m.labels))

    def get(self, name: str, **labels):
        """The series for (name, labels), or None if never recorded."""
        return self._series.get((name, _labels_key(labels)))

    def aggregate(self, name: str) -> float:
        """Sum of a metric across all its label sets (counters/gauges) —
        the fleet-total view of per-shard series."""
        total = 0.0
        for m in self.series():
            if m.name == name and m.kind in ("counter", "gauge"):
                total += m.value
        return total

    def reset(self) -> None:
        with self._lock:
            self._series.clear()
            self.generation += 1

    # -- snapshot / restore (rides ServiceSnapshot / FleetSnapshot) ---------

    def snapshot(self, prefix: str | None = None) -> tuple:
        """Deterministic state of every series (optionally only those whose
        name starts with ``prefix``) — the payload that rides service/fleet
        snapshots.  Rows are fully hashable ``(name, labels, kind,
        json-state)`` tuples, so they can live in pytree metadata."""
        rows = []
        for m in self.series():
            if prefix is not None and not m.name.startswith(prefix):
                continue
            rows.append((m.name, m.labels, m.kind, json.dumps(m._state())))
        return tuple(rows)

    def restore(self, rows) -> None:
        """Merge a ``snapshot()`` payload back in (overwrites same-key
        series, leaves unrelated series alone).  Accepts list-shaped rows
        too (the aux-spec JSON round trip turns tuples into lists)."""
        for name, labels, kind, state in rows:
            state = json.loads(state) if isinstance(state, str) else state
            kw = {}
            if kind == "histogram" and isinstance(state, dict) and "bounds" in state:
                # recreate with the SAVED bounds (a fresh-process restore has
                # no call site to have fixed them yet)
                kw["bounds"] = tuple(state["bounds"])
            m = self._get(kind, name,
                          dict((str(k), str(v)) for k, v in labels), **kw)
            m._restore(state)

    # -- exporters ----------------------------------------------------------

    def to_json(self, indent: int | None = None) -> str:
        rows = [
            {"name": m.name, "labels": dict(m.labels), "kind": m.kind,
             "value": m.value}
            for m in self.series()
        ]
        return json.dumps(rows, indent=indent)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format v0.0.4."""
        by_name: dict[str, list] = {}
        for m in self.series():
            by_name.setdefault(m.name, []).append(m)
        lines = []
        for name in sorted(by_name):
            group = by_name[name]
            kind = group[0].kind
            base = _sanitize(name)
            if kind == "counter" and not base.endswith("_total"):
                base += "_total"
            lines.append(f"# TYPE {base} {kind}")
            for m in group:
                lt = _labels_text(m.labels)
                if kind in ("counter", "gauge"):
                    lines.append(f"{base}{lt} {_fmt(m.value)}")
                else:
                    cum = 0
                    for bound, c in zip(m.bounds, m._counts):
                        cum += c
                        blt = _bucket_labels(m.labels, _fmt(bound))
                        lines.append(f"{base}_bucket{blt} {cum}")
                    cum += m._counts[-1]
                    blt = _bucket_labels(m.labels, "+Inf")
                    lines.append(f"{base}_bucket{blt} {cum}")
                    lines.append(f"{base}_sum{lt} {_fmt(m.sum)}")
                    lines.append(f"{base}_count{lt} {m.count}")
        return "\n".join(lines) + ("\n" if lines else "")


def _fmt(x) -> str:
    if isinstance(x, bool):
        return "1" if x else "0"
    if isinstance(x, int):
        return str(x)
    f = float(x)
    return str(int(f)) if f.is_integer() and abs(f) < 1e15 else repr(f)


def _bucket_labels(labels: tuple, le: str) -> str:
    return _labels_text(labels + (("le", le),))


# ---------------------------------------------------------------------------
# Process-global registry
# ---------------------------------------------------------------------------

_registry = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-global registry every library site records into."""
    return _registry


def set_registry(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-global registry (tests); returns the previous one."""
    global _registry
    prev = _registry
    _registry = reg
    return prev
