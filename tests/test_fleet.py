"""``repro.fleet`` acceptance: the mesh-sharded service tier (DESIGN.md §13).

Pins the contracts the subsystem ships on:

* a fleet ``query`` over enqueued traffic is **bitwise** equal to the
  single-service reference at any shard count — placement cannot change
  what a query returns (the settle path applies each stream's queue
  through the same per-stream sequence a standalone service would);
* continuous-batching ordering: a stream's result does not depend on how
  admission windows cut its event sequence (like-for-like replays are
  bitwise; different pump patterns agree to ulp — the XLA
  batch-composition caveat, see fleet.fleet module doc);
* ``FleetSnapshot`` v4 kill-and-resume is bitwise ACROSS processes, and
  elastic restore under a different shard count regroups per-stream
  leaves bitwise;
* a restore with a warm persistent compilation cache compiles nothing in
  a fresh process (the zero-recompile failover contract).
"""

import dataclasses
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import SvdState, UpdatePolicy
from repro.fleet import (
    FLEET_SNAPSHOT_VERSION,
    FleetSnapshot,
    PlacementSpec,
    SvdFleet,
    shard_of,
)
from repro.serve import SvdService
from repro.train import checkpoint as ckpt

REPO = Path(__file__).resolve().parent.parent
SUB_ENV = {
    "PYTHONPATH": str(REPO / "src"),
    "PATH": "/usr/bin:/bin",
    "JAX_PLATFORMS": "cpu",
    "HOME": "/tmp",
}

M, N, R = 8, 10, 3
STREAMS = 5
IDS = [f"s{i}" for i in range(STREAMS)]
POLICY = UpdatePolicy(method="direct")


def _states(seed=7):
    rng = np.random.default_rng(seed)
    return [
        SvdState.from_factors(
            np.linalg.qr(rng.normal(size=(M, R)))[0],
            np.sort(np.abs(rng.normal(size=R)))[::-1].copy(),
            np.linalg.qr(rng.normal(size=(N, R)))[0],
        )
        for _ in range(STREAMS)
    ]


def _traffic(count, seed=8):
    rng = np.random.default_rng(seed)
    return [
        (f"s{i % STREAMS}",
         jnp.asarray(rng.normal(size=M)), jnp.asarray(rng.normal(size=N)))
        for i in range(count)
    ]


def _single(**kw) -> SvdService:
    kw.setdefault("max_batch", 1 << 30)       # no autoflush: pure settle path
    svc = SvdService(policy=POLICY, **kw)
    for sid, st in zip(IDS, _states()):
        svc.register(sid, st)
    return svc


def _fleet(shards, **kw) -> SvdFleet:
    kw.setdefault("continuous", False)
    kw.setdefault("max_batch", 1 << 30)
    fl = SvdFleet(shards, policy=POLICY, **kw)
    for sid, st in zip(IDS, _states()):
        fl.register(sid, st)
    return fl


def _feed(tgt, events):
    return [tgt.enqueue(sid, a, b) for sid, a, b in events]


def _assert_states(a, b, *, exact=True, tol=1e-8):
    for f in ("u", "s", "v"):
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        if exact:
            np.testing.assert_allclose(x, y, rtol=0, atol=0)
        else:
            np.testing.assert_allclose(x, y, rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# routing + surface
# ---------------------------------------------------------------------------


def test_fleet_routes_streams_and_keeps_service_surface():
    fl = _fleet(3)
    assert fl.num_shards == 3
    for sid, st in zip(IDS, _states()):
        assert fl.shard_of(sid) == shard_of(fl.placement, sid)
        _assert_states(fl.state(sid), st)      # registered bitwise, routed
    toks = _feed(fl, _traffic(11))
    assert fl.pending() == 11
    for (sh, _), (sid, _, _) in zip(toks, _traffic(11)):
        assert sh == fl.shard_of(sid)          # token carries the owner shard
    got = fl.evict("s0")
    with pytest.raises(KeyError):
        fl.state("s0")
    assert isinstance(got, type(fl.state("s1")))


def test_fleet_constructor_rejects_mismatched_placement():
    with pytest.raises(ValueError):
        SvdFleet(2, policy=POLICY, placement=PlacementSpec(4))


# ---------------------------------------------------------------------------
# the acceptance contract: query == single-service reference, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shards", [1, 2, 4])
def test_query_bitwise_vs_single_service(shards):
    """Same streams, same enqueued traffic: the fleet's cross-shard query
    is bitwise-equal (rtol=0/atol=0, f64) to ``merge_streams`` on one
    service — at every shard count, so placement is unobservable."""
    events = _traffic(17)
    svc = _single()
    _feed(svc, events)
    fl = _fleet(shards)
    _feed(fl, events)
    _assert_states(fl.query(IDS, rank=R), svc.merge_streams(IDS, rank=R))


def test_query_respects_stream_order_not_shard_order():
    """The merge runs in ``stream_ids`` order, not in shard-grouped order
    — a permuted query matches the permuted single-service reference."""
    events = _traffic(13)
    perm = [IDS[i] for i in (3, 0, 4, 2, 1)]
    svc = _single()
    _feed(svc, events)
    fl = _fleet(3)
    _feed(fl, events)
    _assert_states(fl.query(perm, rank=R), svc.merge_streams(perm, rank=R))


def test_merge_streams_registers_target_on_its_hashed_shard():
    fl = _fleet(2)
    _feed(fl, _traffic(6))
    merged = fl.merge_streams(IDS[:3], target="merged", rank=R)
    home = fl.shards[fl.shard_of("merged")]
    _assert_states(fl.state("merged"), merged)
    assert "merged" in home.service._streams


# ---------------------------------------------------------------------------
# continuous batching: visibility, depth rounds, ordering
# ---------------------------------------------------------------------------


def test_all_tokens_become_visible_after_drain():
    fl = _fleet(2, continuous=True, max_batch=64, max_depth=4)
    toks = _feed(fl, _traffic(20))
    fl.drain()
    seen = set(fl.poll())
    assert seen == set(toks)
    assert fl.poll() == []                     # poll drains; second call empty
    assert fl.pending() == 0


def test_continuous_drain_seals_deep_scan_rounds():
    """A backlogged stream drains as rank-k scan columns, not one-event
    rounds: 8 events on one stream -> a single depth-8 round."""
    fl = _fleet(1, continuous=True, max_batch=64, max_depth=8)
    _feed(fl, [( "s0", a, b) for _, a, b in _traffic(8)])
    fl.drain()
    st = fl.stats()
    assert st.scan_rounds >= 1
    assert st.max_depth == 8
    assert st.applied == 8


def test_continuous_ordering_replay_bitwise():
    """Like-for-like: the same traffic through the same pump pattern twice
    is bitwise — the continuous path is deterministic."""
    def run():
        fl = _fleet(2, continuous=True, max_batch=64, max_depth=4)
        for i, (sid, a, b) in enumerate(_traffic(18)):
            fl.enqueue(sid, a, b)
            if i % 5 == 4:
                fl.pump()
        fl.drain()
        return [fl.state(sid) for sid in IDS]
    for a, b in zip(run(), run()):
        _assert_states(a, b)


def test_continuous_ordering_pump_pattern_invariant():
    """A stream's result does not depend on where admission windows cut
    its sequence: every pump pattern applies the same per-stream FIFO
    order, so all patterns agree with the sequential settle reference.
    Tolerance is ulp-level, not zero: different window cuts compile
    different batch compositions, and XLA may round reductions in a
    different order (see fleet.fleet module doc)."""
    events = _traffic(18)
    ref = _single()
    _feed(ref, events)
    ref_states = ref.settle(IDS)

    for period in (1, 3, 7, None):             # None = drain-only
        fl = _fleet(2, continuous=True, max_batch=64, max_depth=4)
        for i, (sid, a, b) in enumerate(events):
            fl.enqueue(sid, a, b)
            if period and i % period == period - 1:
                fl.pump()
        fl.drain()
        for sid, want in zip(IDS, ref_states):
            _assert_states(fl.state(sid), want, exact=False, tol=1e-9)


def test_fixed_mode_is_the_plain_service():
    """continuous=False on one shard degrades to the service's fixed
    boundaries exactly — identical autoflush compositions, bitwise."""
    events = _traffic(16)
    svc = _single(max_batch=4)
    _feed(svc, events)
    svc.drain()
    fl = _fleet(1, continuous=False, max_batch=4)
    _feed(fl, events)
    fl.drain()
    for sid in IDS:
        _assert_states(fl.state(sid), svc.state(sid))


def test_backpressure_bounds_pending():
    fl = _fleet(1, continuous=True, max_batch=64, max_depth=2,
                max_backlog=4, max_in_flight=1)
    peak = 0
    for sid, a, b in _traffic(16):
        fl.enqueue(sid, a, b)
        peak = max(peak, fl.pending())
    assert peak <= 4
    fl.drain()
    assert fl.pending() == 0
    assert fl.stats().backpressure_waits >= 1


# ---------------------------------------------------------------------------
# FleetSnapshot v4
# ---------------------------------------------------------------------------


def test_snapshot_roundtrip_in_process(tmp_path):
    fl = _fleet(3)
    _feed(fl, _traffic(14))
    snap = fl.snapshot()
    assert snap.version == FLEET_SNAPSHOT_VERSION == 8
    assert snap.placement == fl.placement
    assert dict(snap.config)["continuous"] is False
    fl.save(tmp_path, step=14)

    step, loaded = FleetSnapshot.load(tmp_path)
    assert step == 14
    re = SvdFleet.from_snapshot(loaded, policy=POLICY)
    assert re.num_shards == 3
    assert re.pending() == 14                  # pending FIFOs survive
    svc = _single()
    _feed(svc, _traffic(14))
    _assert_states(re.query(IDS, rank=R), svc.merge_streams(IDS, rank=R))


def test_snapshot_refuses_newer_version_and_foreign_checkpoints(tmp_path):
    fl = _fleet(2)
    newer = dataclasses.replace(fl.snapshot(), version=FLEET_SNAPSHOT_VERSION + 1)
    newer.save(tmp_path / "newer", step=1)
    with pytest.raises(ValueError, match="newer"):
        FleetSnapshot.load(tmp_path / "newer")
    # a non-fleet checkpoint is rejected by format, not by crashing later
    ckpt.save(tmp_path / "plain", 1, {"x": np.zeros(2)}, aux={"format": "other"})
    with pytest.raises(ValueError, match="not a FleetSnapshot"):
        FleetSnapshot.load(tmp_path / "plain")


def test_elastic_regroup_is_bitwise(tmp_path):
    """restore(num_shards=k) re-places every stream's leaves wholesale:
    the regrouped fleet answers queries bitwise-identically."""
    fl = _fleet(2)
    _feed(fl, _traffic(14))
    fl.save(tmp_path, step=14)

    svc = _single()
    _feed(svc, _traffic(14))
    want = svc.merge_streams(IDS, rank=R)

    for k in (1, 3, 4):
        step, re = SvdFleet.restore(tmp_path, num_shards=k, policy=POLICY)
        assert (step, re.num_shards) == (14, k)
        assert re.placement.num_shards == k
        assert re.pending() == 14
        for sid in IDS:                        # every stream found its shard
            assert sid in re.shards[re.shard_of(sid)].service._streams
        _assert_states(re.query(IDS, rank=R), want)


def test_regrouped_same_count_is_identity_and_auto_plans_devices(tmp_path):
    fl = _fleet(2)
    snap = fl.snapshot()
    assert snap.regrouped(2) is snap
    _feed(fl, _traffic(9))
    fl.save(tmp_path, step=9)
    # "auto" sizes the fleet to live devices (1 CPU in the test process)
    import jax

    step, re = SvdFleet.restore(tmp_path, num_shards="auto", policy=POLICY)
    assert re.num_shards == jax.device_count()
    assert re.pending() == 9


# ---------------------------------------------------------------------------
# kill-and-resume across processes (the §13 acceptance test)
# ---------------------------------------------------------------------------

_KILL_RESUME_SCRIPT = textwrap.dedent(
    """
    import hashlib, json, sys
    import numpy as np
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from repro.api import SvdState, UpdatePolicy
    from repro.fleet import SvdFleet

    mode, ckpt_dir = sys.argv[1], sys.argv[2]
    M, N, R, STREAMS, EVENTS, SPLIT, SHARDS = 8, 10, 3, 5, 24, 15, 3

    def build():
        fl = SvdFleet(SHARDS, policy=UpdatePolicy(method="direct"),
                      continuous=False, max_batch=1 << 30)
        rng = np.random.default_rng(7)
        for i in range(STREAMS):
            fl.register(f"s{i}", SvdState.from_factors(
                np.linalg.qr(rng.normal(size=(M, R)))[0],
                np.sort(np.abs(rng.normal(size=R)))[::-1].copy(),
                np.linalg.qr(rng.normal(size=(N, R)))[0]))
        return fl

    rng = np.random.default_rng(8)
    events = [(f"s{i % STREAMS}", jnp.asarray(rng.normal(size=M)),
               jnp.asarray(rng.normal(size=N))) for i in range(EVENTS)]

    def digest(fl):
        h = hashlib.sha256()
        q = fl.query([f"s{i}" for i in range(STREAMS)], rank=R)
        for f in ("u", "s", "v"):
            arr = np.asarray(getattr(q, f))
            assert arr.dtype == np.float64, arr.dtype
            h.update(arr.tobytes())
        return h.hexdigest()

    if mode == "ref":
        fl = build()
        for sid, a, b in events:
            fl.enqueue(sid, a, b)
        print(json.dumps({"digest": digest(fl)}))
    elif mode == "save":
        fl = build()
        for sid, a, b in events[:SPLIT]:
            fl.enqueue(sid, a, b)
        fl.save(ckpt_dir, step=SPLIT)
        print(json.dumps({"pending": fl.pending()}))
    elif mode == "resume":
        step, fl = SvdFleet.restore(ckpt_dir)
        pending = fl.pending()
        for sid, a, b in events[SPLIT:]:
            fl.enqueue(sid, a, b)
        print(json.dumps({"digest": digest(fl), "step": step,
                          "shards": fl.num_shards,
                          "restored_pending": pending}))
    """
)


def _run_sub(script, *argv, timeout=420):
    proc = subprocess.run(
        [sys.executable, "-c", script, *argv],
        capture_output=True, text=True, timeout=timeout, env=SUB_ENV,
    )
    assert proc.returncode == 0, proc.stderr
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_kill_and_resume_bitwise_across_processes(tmp_path):
    """Save mid-stream in one process, resume in another, finish the
    traffic: the resumed fleet's query digest equals an uninterrupted
    third process's — bitwise, including every pending-FIFO leaf."""
    ref = _run_sub(_KILL_RESUME_SCRIPT, "ref", str(tmp_path))
    saved = _run_sub(_KILL_RESUME_SCRIPT, "save", str(tmp_path))
    assert saved["pending"] == 15
    got = _run_sub(_KILL_RESUME_SCRIPT, "resume", str(tmp_path))
    assert got["restored_pending"] == 15
    assert (got["step"], got["shards"]) == (15, 3)
    assert got["digest"] == ref["digest"]


# ---------------------------------------------------------------------------
# persistent compilation cache: zero-recompile failover
# ---------------------------------------------------------------------------

_CACHE_SCRIPT = textwrap.dedent(
    """
    import json, sys
    import numpy as np
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from repro.api import (SvdState, UpdatePolicy, compilation_cache_entries,
                           enable_compilation_cache)
    from repro.fleet import SvdFleet

    mode, root = sys.argv[1], sys.argv[2]
    cache, ckpt = root + "/cache", root + "/ckpt"
    M, N, R, STREAMS, EVENTS = 8, 10, 3, 4, 12

    def feed(fl):
        rng = np.random.default_rng(9)
        for i in range(EVENTS):
            fl.enqueue(f"s{i % STREAMS}", jnp.asarray(rng.normal(size=M)),
                       jnp.asarray(rng.normal(size=N)))

    if mode == "seed":
        enable_compilation_cache(cache)
        fl = SvdFleet(2, policy=UpdatePolicy(method="direct"),
                      continuous=True, max_batch=64, max_depth=4)
        rng = np.random.default_rng(7)
        for i in range(STREAMS):
            fl.register(f"s{i}", SvdState.from_factors(
                np.linalg.qr(rng.normal(size=(M, R)))[0],
                np.sort(np.abs(rng.normal(size=R)))[::-1].copy(),
                np.linalg.qr(rng.normal(size=(N, R)))[0]))
        feed(fl)
        fl.drain()
        fl.save(ckpt, step=1)
        print(json.dumps({"entries": compilation_cache_entries(cache)}))
    elif mode == "resume":
        step, fl = SvdFleet.restore(ckpt, cache_dir=cache)
        after_restore = compilation_cache_entries(cache)
        feed(fl)
        fl.drain()
        print(json.dumps({"after_restore": after_restore,
                          "after_traffic": compilation_cache_entries(cache)}))
    """
)


def test_restore_with_warm_cache_compiles_nothing_in_fresh_process(tmp_path):
    """The failover contract: process A seeds the persistent cache (its
    flush rounds record the warmed geometry set); process B restores with
    ``cache_dir`` and replays identical traffic — the cache gains ZERO new
    entries, i.e. the fresh process compiled nothing."""
    seeded = _run_sub(_CACHE_SCRIPT, "seed", str(tmp_path))
    assert seeded["entries"] > 0
    got = _run_sub(_CACHE_SCRIPT, "resume", str(tmp_path))
    assert got["after_restore"] == seeded["entries"]
    assert got["after_traffic"] == seeded["entries"]


def test_elastic_regroup_with_pending_deletions(tmp_path):
    """ISSUE 9: queued RemoveRows/Window downdates survive an elastic
    regroup.  The snapshot carries the deletion events whole (Remove ops
    are pure metadata, Window a single ``lam`` leaf); restoring at a
    different shard count then draining matches the single-service
    reference bitwise, post-shrink traffic included."""
    from repro.updates import RemoveRows, Window

    rng = np.random.default_rng(21)
    post = [(sid, jnp.asarray(rng.normal(size=5)), jnp.asarray(rng.normal(size=N)))
            for sid in IDS]

    def feed(tgt):
        _feed(tgt, _traffic(10))
        for sid in IDS:
            tgt.enqueue_op(sid, RemoveRows((1, 6)))
            tgt.enqueue_op(sid, Window(5, lam=0.9))
        _feed(tgt, post)

    fl = _fleet(2)
    feed(fl)
    n_events = fl.pending()
    assert n_events == 10 + 3 * STREAMS
    fl.save(tmp_path, step=1)

    svc = _single()
    feed(svc)
    want = svc.settle(IDS)

    for k in (1, 3):
        _, re = SvdFleet.restore(tmp_path, num_shards=k, policy=POLICY)
        assert re.pending() == n_events        # deletions still queued
        # settle, not drain: the per-stream settle sequence is the bitwise
        # contract; drain's cross-stream batching composes per shard count
        got = re.settle(IDS)
        for st, ref in zip(got, want):
            assert st.shape == (5, N)
            _assert_states(st, ref)
