import jax

# Core numerics (secular / Loewner / Cauchy) need f64 for the orthogonality
# guarantees under test. Model code pins its dtypes explicitly, so enabling
# x64 only changes defaults. NOTE: XLA_FLAGS device-count forcing is NOT set
# here on purpose — only launch/dryrun.py uses 512 placeholder devices;
# distributed tests spawn subprocesses with their own env.
jax.config.update("jax_enable_x64", True)
