"""Quickstart: the paper's rank-1 SVD update through the ``repro.api`` surface.

One state (``SvdState``), one policy (``UpdatePolicy``), one entry point
(``api.update``) — the same three objects scale from this script to the
batched/sharded production paths.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro import api

rng = np.random.default_rng(0)
m, n = 200, 300

# A known SVD ...
a_mat = rng.uniform(1, 9, size=(m, n))           # paper's experimental setup
state = api.SvdState.from_dense(a_mat)           # full paper state: u (m,m), v (n,n)

# ... perturbed by a rank-1 update (a streaming observation, a gradient, ...)
a = rng.normal(size=m)
b = rng.normal(size=n)

# Algorithm 6.1: secular roots + Loewner weights + FMM Cauchy products —
# O(n^2 log 1/eps) instead of O(n^3) for a fresh SVD. The policy names the
# numerics once; geometry picks the dispatch route.
policy = api.UpdatePolicy(method="fmm")
state = api.update(state, a, b, policy)

a_hat = a_mat + np.outer(a, b)
recon = np.asarray(state.materialize())
smax = np.linalg.svd(a_hat, compute_uv=False)[0]
err = np.max(np.abs(a_hat - recon)) / smax

print(f"updated sigma_max   : {float(state.s[0]):.6f}")
print(f"fresh-SVD sigma_max : {smax:.6f}")
print(f"Eq.32 error         : {err:.3e}   (paper Table 2 reports ~5e-2 at n=50)")
u_np = np.asarray(state.u)
print(f"orthogonality |U^TU - I|: {np.max(np.abs(u_np.T @ u_np - np.eye(m))):.3e}")
assert err < 1e-9
print("OK")
