"""whisper-base [audio] — 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865 —
enc-dec, conv frontend STUB per assignment (input_specs feeds frame
embeddings). [arXiv:2212.04356; unverified]

vocab 51865 padded to 51968 for TP divisibility; 8 heads < 16 shards relies
on GSPMD padding (tiny model; waste documented in DESIGN.md §6).
long_500k skipped (enc-dec audio, out of family scope)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="audio",
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
        d_ff=2048, vocab_size=51865,
        mlp_type="gelu", norm_type="layernorm", use_rope=False,
        encdec=True, dec_ratio=4, frontend="audio",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="whisper-base-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512, vocab_pad_to=64,
        compute_dtype="float32", remat=False,
    )
