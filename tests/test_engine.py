"""Batch-first engine (core.engine) + micro-batching service (serve.svd_service).

Acceptance-criteria coverage: batched results match a loop of single
``api.update`` calls across methods, plan-cache hit behavior, and the
svd_service micro-batching round trip.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.api import SvdState, UpdatePolicy
from repro.core.engine import SvdEngine, default_engine
from repro.core.svd_update import TruncatedSvd
from repro.serve.svd_service import SvdService


def svd_update(u, s, v, a, b, *, method="direct"):
    """Single full update via the api surface (per-item reference)."""
    return api.update(SvdState.from_factors(u, s, v), a, b,
                      UpdatePolicy(method=method))


def svd_update_truncated(tsvd, a, b):
    """Single truncated update via the api surface (per-item reference)."""
    return api.update(tsvd, a, b, UpdatePolicy(method="direct"))

RNG = np.random.default_rng(11)


def _stacked_problem(b, m, n):
    us, ss, vs, as_, bs = [], [], [], [], []
    for _ in range(b):
        a_mat = RNG.uniform(1, 9, (m, n))
        u, s, vt = np.linalg.svd(a_mat)
        us.append(u)
        ss.append(s)
        vs.append(vt.T)
        as_.append(RNG.normal(size=m))
        bs.append(RNG.normal(size=n))
    return tuple(jnp.asarray(np.stack(x)) for x in (us, ss, vs, as_, bs))


def _rel_err(x, ref):
    return float(jnp.max(jnp.abs(x - ref)) / (jnp.max(jnp.abs(ref)) + 1e-300))


@pytest.mark.parametrize("method", ["direct", "fmm", "kernel"])
def test_batch_matches_loop_of_singles(method):
    """B=32 stacked updates == 32 individual svd_update calls (acceptance)."""
    b, m, n = 32, 12, 16
    u, s, v, a, bb = _stacked_problem(b, m, n)
    eng = SvdEngine(method=method)
    res = eng.update_batch(u, s, v, a, bb)
    for i in range(b):
        ref = svd_update(u[i], s[i], v[i], a[i], bb[i], method=method)
        assert _rel_err(res.s[i], ref.s) < 1e-5
        assert _rel_err(res.u[i], ref.u) < 1e-5
        assert _rel_err(res.v[i], ref.v) < 1e-5


@pytest.mark.parametrize("method", ["direct", "fmm"])
def test_batch_fmm_geometry_matches_loop(method):
    """Above the FMM size floor the batched tree plans must agree too."""
    b, m, n = 3, 100, 128
    u, s, v, a, bb = _stacked_problem(b, m, n)
    res = default_engine(method).update_batch(u, s, v, a, bb)
    for i in range(b):
        ref = svd_update(u[i], s[i], v[i], a[i], bb[i], method=method)
        assert _rel_err(res.s[i], ref.s) < 1e-5
        assert _rel_err(res.v[i], ref.v) < 1e-5


def test_batch_reconstructs_perturbed_matrix():
    b, m, n = 8, 10, 14
    u, s, v, a, bb = _stacked_problem(b, m, n)
    res = SvdEngine().update_batch(u, s, v, a, bb)
    for i in range(b):
        a_hat = (
            np.asarray(u[i]) @ np.diag(np.asarray(s[i])) @ np.asarray(v[i])[:, :m].T
            + np.outer(a[i], bb[i])
        )
        recon = (
            np.asarray(res.u[i])
            @ np.diag(np.asarray(res.s[i]))
            @ np.asarray(res.v[i])[:, :m].T
        )
        assert np.max(np.abs(a_hat - recon)) < 1e-9


def test_truncated_batch_matches_loop():
    b, m, n, r = 16, 20, 24, 5
    t = TruncatedSvd(
        jnp.asarray(np.stack([np.linalg.qr(RNG.normal(size=(m, r)))[0] for _ in range(b)])),
        jnp.asarray(np.sort(np.abs(RNG.normal(size=(b, r))), axis=1)[:, ::-1].copy()),
        jnp.asarray(np.stack([np.linalg.qr(RNG.normal(size=(n, r)))[0] for _ in range(b)])),
    )
    a = jnp.asarray(RNG.normal(size=(b, m)))
    bb = jnp.asarray(RNG.normal(size=(b, n)))
    out = SvdEngine().update_truncated_batch(t, a, bb)
    for i in range(b):
        ref = svd_update_truncated(TruncatedSvd(t.u[i], t.s[i], t.v[i]), a[i], bb[i])
        assert _rel_err(out.s[i], ref.s) < 1e-8
        assert _rel_err(out.u[i], ref.u) < 1e-8


@pytest.mark.parametrize("method,build_fmm", [("direct", False), ("fmm", True), ("kernel", False)])
def test_eigh_plan_apply_batch_matches_singles(method, build_fmm):
    """Batched eigen-level plan/apply (make_plan_batch/apply_update_batch)
    == loop of single make_plan/apply_update."""
    from repro.core.eigh_update import apply_update, apply_update_batch, eigenvalues, make_plan, make_plan_batch

    b, n = 4, 96 if build_fmm else 24  # above _FMM_MIN_N when fmm
    d = jnp.asarray(np.sort(RNG.uniform(1, 9, (b, n)), axis=1))
    z = jnp.asarray(RNG.normal(size=(b, n)))
    rho = jnp.asarray(np.abs(RNG.normal(size=b)) + 0.1)
    w = jnp.asarray(np.stack([np.linalg.qr(RNG.normal(size=(n, n)))[0] for _ in range(b)]))

    plan_b = make_plan_batch(d, z, rho, rho_positive=True, build_fmm=build_fmm)
    out_b = apply_update_batch(plan_b, w, method=method)
    mu_b = jax.vmap(eigenvalues)(plan_b)
    for i in range(b):
        plan = make_plan(d[i], z[i], rho[i], rho_positive=True, build_fmm=build_fmm)
        ref = apply_update(plan, w[i], method=method)
        assert _rel_err(out_b[i], ref) < 1e-10
        assert _rel_err(mu_b[i], eigenvalues(plan)) < 1e-12


def test_plan_cache_hits():
    eng = SvdEngine()
    b, m, n = 4, 8, 10
    u, s, v, a, bb = _stacked_problem(b, m, n)
    assert eng.cache_info() == (0, 0, 0)
    eng.update_batch(u, s, v, a, bb)
    assert eng.cache_info().misses == 1
    assert eng.cache_info().hits == 0
    eng.update_batch(u, s, v, a, bb)
    eng.update_batch(u, s, v, a, bb)
    assert eng.cache_info().hits == 2
    assert eng.cache_info().entries == 1
    # a new geometry is a new entry, old entries still hit
    u2, s2, v2, a2, bb2 = _stacked_problem(b + 1, m, n)
    eng.update_batch(u2, s2, v2, a2, bb2)
    assert eng.cache_info().misses == 2
    assert eng.cache_info().entries == 2
    eng.cache_clear()
    assert eng.cache_info() == (0, 0, 0)


def test_plan_cache_warmup_precompiles():
    eng = SvdEngine()
    info = eng.warmup(batch=4, m=8, n=10, dtype=jnp.float64)
    assert info.entries == 1
    info = eng.warmup(batch=4, m=8, n=10, rank=3, dtype=jnp.float64)
    assert info.entries == 2
    # warmup geometry == call geometry -> hit
    u, s, v, a, bb = _stacked_problem(4, 8, 10)
    eng.update_batch(u, s, v, a, bb)
    assert eng.cache_info().hits == 1


def test_batch_sharding_spreads_engine_batch():
    """Engine with dist.batch_sharding: results unchanged, inputs
    constrained to the mesh (single-device CPU mesh — semantics, not perf)."""
    from repro.dist.sharding import batch_pad, batch_sharding
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(data=1, model=1)
    sh = batch_sharding(mesh, "data")
    eng = SvdEngine(sharding=sh)
    b, m, n = 4, 8, 10
    assert batch_pad(b, mesh, "data") == 0
    u, s, v, a, bb = _stacked_problem(b, m, n)
    res = eng.update_batch(u, s, v, a, bb)
    ref = SvdEngine().update_batch(u, s, v, a, bb)
    assert _rel_err(res.s, ref.s) == 0.0
    assert _rel_err(res.v, ref.v) == 0.0


def test_default_engine_shared():
    e1 = default_engine("direct")
    e2 = default_engine("direct")
    assert e1 is e2
    assert default_engine("kernel") is not e1


def test_single_update_via_engine_matches_functional():
    m, n = 12, 16
    u, s, v, a, bb = _stacked_problem(1, m, n)
    eng = SvdEngine()
    res = eng.update(u[0], s[0], v[0], a[0], bb[0])
    ref = svd_update(u[0], s[0], v[0], a[0], bb[0])
    assert _rel_err(res.s, ref.s) == 0.0
    assert _rel_err(res.v, ref.v) == 0.0


# ---------------------------------------------------------------------------
# serve.svd_service micro-batching
# ---------------------------------------------------------------------------


def _fresh_stream(m, n, r):
    return TruncatedSvd(
        jnp.asarray(np.linalg.qr(RNG.normal(size=(m, r)))[0]),
        jnp.asarray(np.sort(np.abs(RNG.normal(size=r)))[::-1].copy()),
        jnp.asarray(np.linalg.qr(RNG.normal(size=(n, r)))[0]),
    )


def test_service_microbatch_roundtrip():
    """Enqueue across many streams, flush as batched calls, states match a
    sequential reference per stream (acceptance)."""
    m, n, r = 14, 18, 4
    eng = SvdEngine()
    svc = SvdService(engine=eng, max_batch=8)

    refs = {}
    pairs = {}
    for i in range(10):
        sid = f"stream-{i}"
        t = _fresh_stream(m, n, r)
        svc.register(sid, t)
        refs[sid] = t
        k = 2 if i % 4 == 0 else 1  # some streams queue several pairs (FIFO)
        pairs[sid] = [
            (jnp.asarray(RNG.normal(size=m)), jnp.asarray(RNG.normal(size=n)))
            for _ in range(k)
        ]

    for sid, ps in pairs.items():
        for a, b in ps:
            svc.enqueue(sid, a, b)
    svc.flush()
    assert svc.pending() == 0

    for sid, ps in pairs.items():
        ref = refs[sid]
        for a, b in ps:
            ref = svd_update_truncated(ref, a, b)
        got = svc.state(sid)
        assert _rel_err(got.s, ref.s) < 1e-8
        assert _rel_err(got.u, ref.u) < 1e-8
        assert _rel_err(got.v, ref.v) < 1e-8

    assert svc.stats.applied == sum(len(p) for p in pairs.values())
    assert svc.stats.max_batch >= 8  # micro-batching actually batched


def test_service_auto_flush_and_bucketing():
    m, n, r = 8, 9, 3
    svc = SvdService(max_batch=4)
    for i in range(4):
        svc.register(f"s{i}", _fresh_stream(m, n, r))
    for i in range(3):
        svc.enqueue(f"s{i}", jnp.zeros(m), jnp.zeros(n))
    assert svc.pending() == 3  # below max_batch: nothing flushed yet
    svc.enqueue("s3", jnp.zeros(m), jnp.zeros(n))
    assert svc.pending() == 0  # auto-flush at max_batch
    assert svc.stats.flushes == 1
    # mixed geometries group separately in one round
    svc.register("wide", _fresh_stream(m, 2 * n, r))
    svc.enqueue("s0", jnp.zeros(m), jnp.zeros(n))
    svc.enqueue("wide", jnp.zeros(m), jnp.zeros(2 * n))
    svc.flush()
    assert svc.pending() == 0


def test_service_reregister_drops_stale_queue():
    m, n, r = 8, 9, 3
    svc = SvdService(max_batch=16)
    svc.register("x", _fresh_stream(m, n, r))
    svc.enqueue("x", jnp.asarray(RNG.normal(size=m)), jnp.asarray(RNG.normal(size=n)))
    t_new = _fresh_stream(2 * m, n, r)  # different geometry
    svc.register("x", t_new)            # must drop the stale pending pair
    assert svc.pending("x") == 0
    svc.flush()
    assert _rel_err(svc.state("x").s, t_new.s) == 0.0


def test_service_evict_returns_flushed_state():
    m, n, r = 8, 9, 3
    svc = SvdService(max_batch=16)
    t = _fresh_stream(m, n, r)
    svc.register("x", t)
    svc.register("bystander", _fresh_stream(m, n, r))
    a = jnp.asarray(RNG.normal(size=m))
    b = jnp.asarray(RNG.normal(size=n))
    svc.enqueue("x", a, b)
    svc.enqueue("bystander", a, b)
    out = svc.evict("x")
    ref = svd_update_truncated(t, a, b)
    assert _rel_err(out.s, ref.s) < 1e-8
    # evicting one stream must not advance anyone else's state
    assert svc.pending("bystander") == 1
    with pytest.raises(KeyError):
        svc.enqueue("x", a, b)


def test_service_flush_failure_keeps_pairs_queued():
    """A failed engine dispatch must not lose queued updates (peek-then-pop)."""
    m, n, r = 8, 9, 3
    eng = SvdEngine()
    svc = SvdService(engine=eng, max_batch=16)
    svc.register("x", _fresh_stream(m, n, r))
    a = jnp.asarray(RNG.normal(size=m))
    b = jnp.asarray(RNG.normal(size=n))
    svc.enqueue("x", a, b)
    before = svc.state("x")

    real = eng.update_truncated_batch
    calls = {"n": 0}

    def flaky(*args, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("simulated backend failure")
        return real(*args, **kw)

    eng.update_truncated_batch = flaky
    try:
        with pytest.raises(RuntimeError):
            svc.flush()
        assert svc.pending("x") == 1          # pair survived the failure
        assert _rel_err(svc.state("x").s, before.s) == 0.0  # state untouched
        assert svc.flush() == 1               # retry applies it
    finally:
        eng.update_truncated_batch = real
    ref = svd_update_truncated(before, a, b)
    assert _rel_err(svc.state("x").s, ref.s) < 1e-8


def test_service_group_larger_than_max_batch_does_not_wedge():
    """Retry accumulation can make a round group exceed max_batch — the
    service must dispatch it (unbucketed) instead of computing negative pad."""
    m, n, r = 8, 9, 3
    eng = SvdEngine()
    svc = SvdService(engine=eng, max_batch=4)
    real = eng.update_truncated_batch
    fail = {"on": True}

    def flaky(*args, **kw):
        if fail["on"]:
            raise RuntimeError("transient")
        return real(*args, **kw)

    eng.update_truncated_batch = flaky
    try:
        for i in range(4):
            svc.register(f"s{i}", _fresh_stream(m, n, r))
        with pytest.raises(RuntimeError):  # auto-flush at max_batch fails
            for i in range(4):
                svc.enqueue(f"s{i}", jnp.zeros(m), jnp.zeros(n))
        svc.register("s4", _fresh_stream(m, n, r))
        with pytest.raises(RuntimeError):  # 5th stream: group now > max_batch
            svc.enqueue("s4", jnp.zeros(m), jnp.zeros(n))
        fail["on"] = False
    finally:
        eng.update_truncated_batch = real
    assert svc.flush() == 5                # recovers, applies all 5
    assert svc.pending() == 0


def test_service_rejects_mismatched_pair_at_enqueue():
    m, n, r = 8, 9, 3
    svc = SvdService(max_batch=16)
    svc.register("x", _fresh_stream(m, n, r))
    with pytest.raises(ValueError, match="geometry"):
        svc.enqueue("x", jnp.zeros(m + 1), jnp.zeros(n))
    # a bad pair must not poison later valid traffic
    svc.enqueue("x", jnp.zeros(m), jnp.zeros(n))
    assert svc.flush() == 1


def test_warmup_engine_usable_under_trace():
    """AOT warmup must not break traced consumers (jit / lax.cond)."""
    eng = SvdEngine()
    b, m, n, r = 2, 8, 10, 3
    eng.warmup(batch=b, m=m, n=n, rank=r, dtype=jnp.float64)
    t = TruncatedSvd(
        jnp.asarray(np.stack([np.linalg.qr(RNG.normal(size=(m, r)))[0] for _ in range(b)])),
        jnp.asarray(np.abs(RNG.normal(size=(b, r)))),
        jnp.asarray(np.stack([np.linalg.qr(RNG.normal(size=(n, r)))[0] for _ in range(b)])),
    )
    a = jnp.asarray(RNG.normal(size=(b, m)))
    bb = jnp.asarray(RNG.normal(size=(b, n)))

    out_jit = jax.jit(lambda t_, a_, b_: eng.update_truncated_batch(t_, a_, b_))(t, a, bb)
    out_eager = eng.update_truncated_batch(t, a, bb)  # AOT path
    assert _rel_err(out_jit.s, out_eager.s) < 1e-12
