"""Elastic scaling: re-mesh on restart.

Checkpoints store full (host-gathered) arrays, so they are mesh-independent.
On restart, ``plan_mesh`` inspects the devices that are actually alive and
chooses the largest (data, model) factorization consistent with the model's
TP divisibility constraints; ``reshard`` places a restored pytree onto the
new mesh. At 1000+-node scale this is the recover-with-fewer-pods path: a
dead pod shrinks the data axis, training continues at reduced global batch.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding

from repro.dist import sharding as sh

__all__ = ["plan_mesh", "reshard", "largest_factorization"]


def largest_factorization(n: int, max_model: int = 16) -> tuple[int, int]:
    """(data, model) with model as large as possible, model | n, model <= max."""
    for m in range(min(max_model, n), 0, -1):
        if n % m == 0:
            return n // m, m
    return n, 1


def plan_mesh(max_model: int = 16):
    n = jax.device_count()
    data, model = largest_factorization(n, max_model)
    return jax.make_mesh((data, model), ("data", "model"))


def reshard(tree, mesh):
    """Place a host pytree onto ``mesh`` per the standard param rules."""
    specs = sh.param_pspecs(tree)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs
    )
