"""zamba2-7b [hybrid] — 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000,
ssm_state=64 — Mamba2 backbone + weight-shared attention every 6 layers.
[arXiv:2411.15242; unverified]

Simplifications (DESIGN.md §6): shared block applied on the residual stream
(no embedding concat, no per-invocation LoRA). Runs long_500k (hybrid: O(1)
SSM state + O(seq) shared-attn KV reads per decode step)."""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab_size=32000,
        mlp_type="swiglu", norm_type="rmsnorm",
        ssm=SSMConfig(d_state=64, expand=2, head_dim=64, conv_width=4, chunk=128),
        attn_every=6,
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="zamba2-7b-smoke", n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512, vocab_pad_to=64,
        ssm=SSMConfig(d_state=16, expand=2, head_dim=16, conv_width=4, chunk=16),
        attn_every=2,
        compute_dtype="float32", remat=False,
    )
