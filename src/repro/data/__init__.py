"""Deterministic, shardable data pipeline."""
