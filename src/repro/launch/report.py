"""Roofline report generator: benchmarks/dryrun/*.json -> markdown tables."""

from __future__ import annotations

import json
from pathlib import Path


def load(d: str | Path):
    rows = []
    for f in sorted(Path(d).glob("*.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def fmt_row(r):
    rt = r["roofline"]
    tc, tm, tl = rt["t_compute_s"], rt["t_memory_s"], rt["t_collective_s"]
    dom = max(("compute", tc), ("memory", tm), ("collective", tl), key=lambda kv: kv[1])
    ratio = r.get("useful_flops_ratio")
    peak = r["memory"].get("peak_bytes") or 0
    return {
        "arch": r["arch"],
        "shape": r["shape"],
        "mesh": r["mesh"],
        "method": r.get("method", "baseline"),
        "t_compute_ms": tc * 1e3,
        "t_memory_ms": tm * 1e3,
        "t_collective_ms": tl * 1e3,
        "bottleneck": dom[0],
        "useful_ratio": ratio,
        "peak_gb": peak / 1e9,
        "flops": rt["flops_per_device"],
        "bytes": rt["bytes_per_device"],
        "coll_bytes": rt["collective_bytes_per_device"],
    }


def markdown_table(rows, *, mesh=None, method="baseline"):
    out = [
        "| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | bottleneck | useful FLOPs | peak GB/dev |",
        "|---|---|---:|---:|---:|---|---:|---:|",
    ]
    for r in rows:
        fr = fmt_row(r)
        if mesh and fr["mesh"] != mesh:
            continue
        if method and fr["method"] != method:
            continue
        ur = f"{fr['useful_ratio']:.2f}" if fr["useful_ratio"] else "-"
        out.append(
            f"| {fr['arch']} | {fr['shape']} | {fr['t_compute_ms']:.2f} | "
            f"{fr['t_memory_ms']:.1f} | {fr['t_collective_ms']:.1f} | "
            f"{fr['bottleneck']} | {ur} | {fr['peak_gb']:.1f} |"
        )
    return "\n".join(out)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--method", default="baseline")
    args = ap.parse_args()
    rows = load(args.dir)
    print(markdown_table(rows, mesh=args.mesh, method=args.method))


if __name__ == "__main__":
    main()
