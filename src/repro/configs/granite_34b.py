"""granite-34b [dense] — 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 — llama-arch, code model. [arXiv:2405.04324; hf]

kv=1 < 16 model shards: the single KV head is replicated over the model axis
(standard MQA TP semantics)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-34b", family="dense",
        n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
        d_ff=24576, vocab_size=49152,
        mlp_type="swiglu", norm_type="rmsnorm",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="granite-34b-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab_size=512, vocab_pad_to=64,
        compute_dtype="float32", remat=False,
    )
