"""Fused rank-1 SVD update: the whole of Algorithm 6.1 in one kernel body.

The engine's other routes run the update as a chain of separate XLA
dispatches — project, deflate, secular solve, Cauchy rotation, sign fix —
with every intermediate bouncing through HBM, which is why *full* batched
updates historically ran at ~1.4x over the per-update loop while truncated
ones reached 12.5x (BENCH_engine.json).  This module is the designated
hot-path fix (ROADMAP): ONE body that keeps the whole per-update state
resident, expressed so the SAME code traces

* as a plain-jnp XLA fusion (``fused_update_xla``) — the CPU path and the
  natural ``jax.vmap`` target, and
* inside a Pallas kernel (``fused_update_pallas`` /
  ``fused_update_pallas_batched``) — grid ``(B,)``, one program per update,
  everything in VMEM; ``interpret=True`` executes the body on CPU in tests.

To make the body kernel-clean it eliminates every construct that is slow
under vmap or unsupported in Mosaic:

* **no argsort / gather** — the eigenvalue orders of all four phases are
  static reversals (``d = s^2`` is descending, negation flips), and the one
  data-dependent reorder (deflated passthrough values interleaving secular
  roots) is done with a stable comparison-matrix rank + one-hot permutation
  matmul (MXU-friendly);
* **no lax.cond / per-rotation scan** — the direct path's sequential Givens
  deflation chain (a both-branches scan under vmap that copies the full
  (B, m, n) operand per step — the actual 1.4x bottleneck) is replaced by a
  closed-form grouped Householder merge of (near-)coincident poles, built
  as one dense (k, k) matrix from masks;
* **shared secular loop** — the bisection/Newton iteration is
  ``kernels.secular_body.secular_iterate``, the same body the standalone
  secular kernel and its oracle use.  The Newton phase is a *safeguarded
  pole-free* iteration on ``f(tau) = tau * w(tau)`` (smooth across the
  anchor pole, bracket maintained every step — see ``secular_body``), so
  each Newton step is at worst one more bisection halving and typically
  quadratic.  That lets the fused defaults run 16 bisection + 6 Newton
  steps (vs the standalone kernel's 58+4): the bisections localize into
  the Newton basin (observed requirement is ~12 even for clustered
  spectra; 16 doubles the margin) and the pole-free Newton then converges
  to machine precision — even pole-hugging streaming roots measure
  ~1e-13 one-step error.  The secular loop is the fused hot path, so
  dropping the dead rounds is a ~35% end-to-end win at (32, 48).  Parity
  vs the 58+4 direct route stays at working-precision level even for
  clustered spectra just above the deflation gap (tests/test_fused.py).

Mixed precision: the body takes a ``compute_dtype`` — bf16/f16 *storage*
factors are upcast on entry (inside the kernel, after the bf16 HBM->VMEM
load — that is the bandwidth win on TPU), the secular solve and all
rotations run in f32/f64, and outputs are cast back to the storage dtype.
The documented error budget for bf16 storage is ``BF16_ERROR_BUDGET``
(enforced in tests/test_fused.py, table in DESIGN.md §11).

Deflation semantics vs the direct path: coincident-pole handling merges by
pole *gap* (``gap <= rtol * scale``) instead of by Givens off-diagonal
size.  Exact duplicates (the n-m structural zeros of the right-hand
problem, repeated deflated eigenvalues feeding later phases) merge
identically; *near*-coincident poles may deflate slightly differently —
both choices perturb the problem by O(rtol * scale), so the routes agree
to the tolerances the parity tests pin.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.secular_body import secular_iterate

__all__ = [
    "BF16_ERROR_BUDGET",
    "FUSED_VMEM_BUDGET",
    "fused_supported",
    "fused_update_xla",
    "fused_update_truncated_xla",
    "fused_update_pallas",
    "fused_update_pallas_batched",
    "fused_update_truncated_pallas",
    "fused_update_truncated_pallas_batched",
]


# Per-core VMEM the fused body may claim (half of a TPU core's ~16 MiB,
# leaving headroom for double buffering and control).  See DESIGN.md §11.
FUSED_VMEM_BUDGET = 8 * 1024 * 1024

# bf16-storage error budget vs the f64 dense reference (DESIGN.md §11).
# Pinned by tests/test_fused.py; measured on the bench geometry (32, 48)
# with ~4x headroom over observed worst cases.  bf16 eps ~= 7.8e-3: one
# update costs a few eps in sigma, reconstruction is dominated by the bf16
# quantization of the stored factors themselves, and sequential-update
# drift grows roughly linearly (Peña–Sauer-style accumulation).
BF16_ERROR_BUDGET = {
    "sigma_rel": 5e-2,        # max_i |s_i - s_ref_i| / s_ref_0, single update
    "recon_rel": 8e-2,        # ||U S V^T - ref||_F / ||ref||_F, single update
    "drift_sigma_rel": 2e-1,  # sigma_rel after 8 sequential updates
}


def _compute_dtype_for(storage_dtype) -> jnp.dtype:
    dt = jnp.dtype(storage_dtype)
    return jnp.dtype(jnp.float32) if dt.itemsize <= 2 else dt


def fused_supported(m: int, n: int, rank: int | None = None,
                    dtype=jnp.float32) -> bool:
    """Whether the fused body's working set fits ``FUSED_VMEM_BUDGET``.

    ``rank=None`` is the full update (working set dominated by the dense
    (n, n) phase operators); otherwise the truncated route, whose secular
    core is (rank+1)-sized with (m, rank+1)/(n, rank+1) factor blocks.
    """
    isz = _compute_dtype_for(dtype).itemsize
    if rank is None:
        if m > n:
            return False
        est = (10 * n * n + 10 * m * m + 8 * (m + n)) * isz
    else:
        k = rank + 1
        est = (10 * k * k + 4 * k * (m + n) + 8 * (m + n)) * isz
    return est <= FUSED_VMEM_BUDGET


# ---------------------------------------------------------------------------
# kernel-clean primitives
# ---------------------------------------------------------------------------


def _iota1(k: int):
    # 1D iota is unsupported on TPU; broadcast a 2D one and slice.
    return lax.broadcasted_iota(jnp.int32, (k, 1), 0)[:, 0]


def _mm(a, b):
    return jnp.dot(a, b, preferred_element_type=a.dtype)


def _flip2(x):
    return jnp.flip(jnp.flip(x, 0), 1)


def _stable_sort_perm(mu, iota_c):
    """One-hot permutation P with P[i, r] = 1 iff stable-rank(mu_i) == r.

    ``x_sorted = x @ P`` (vectors), ``Q_sorted = Q @ P`` (columns) — the
    argsort-free reorder used for the phase output ordering.
    """
    k = mu.shape[0]
    dt = mu.dtype
    idx = _iota1(k)
    lt = (mu[None, :] < mu[:, None]).astype(jnp.int32)       # mu_j <  mu_i
    eq = (mu[None, :] == mu[:, None]) & (idx[None, :] < idx[:, None])
    rank = jnp.sum(lt, axis=1) + jnp.sum(eq.astype(jnp.int32), axis=1)
    return (rank[:, None] == iota_c).astype(dt)


# ---------------------------------------------------------------------------
# one diagonal-plus-rank-1 eigen phase:  eig(diag(d) + rho z z^T),  rho > 0
# ---------------------------------------------------------------------------


def _phase(d, z, rho, *, rtol, n_bisect, n_newton):
    """Eigen-update of ``diag(d) + rho z z^T`` (d ascending, rho > 0).

    Returns ``(mu_sorted, Phi)``: eigenvalues ascending and the dense (k, k)
    rotation with eigenvector columns in that order (``W_new = W @ Phi``).
    Structured as Householder-merge -> tiny-z deflation -> bracketed secular
    solve (anchored) -> Loewner zhat -> scaled-Cauchy columns -> stable
    one-hot output permutation; every step is masks + matmuls + the two
    fixed-count secular loops.
    """
    k = d.shape[0]
    dt = d.dtype
    eps = jnp.finfo(dt).eps
    tiny = jnp.finfo(dt).tiny
    rtol_v = 64.0 * float(eps) if rtol is None else rtol

    idx = _iota1(k)
    iota_r = lax.broadcasted_iota(jnp.int32, (k, k), 0)
    iota_c = lax.broadcasted_iota(jnp.int32, (k, k), 1)
    eye = (iota_r == iota_c).astype(dt)

    z2_raw = z * z
    scale = jnp.maximum(jnp.max(jnp.abs(d)), rho * jnp.sum(z2_raw)) + tiny
    tol = rtol_v * scale

    # -- group (near-)coincident poles: leader = first pole within gap tol.
    # d is ascending so {j <= i : d_i - d_j <= tol} is a suffix; the min is
    # the group leader.  log2(k) rounds of leader <- leader[leader] close
    # chains (a gather, expressed as a one-hot matvec for the MXU).
    ok = (iota_c <= iota_r) & ((d[:, None] - d[None, :]) <= tol)
    leader = jnp.min(jnp.where(ok, iota_c, k), axis=1)
    for _ in range(max(1, math.ceil(math.log2(max(k, 2))))):
        hop = (leader[:, None] == iota_c).astype(dt)
        leader = _mm(hop, leader.astype(dt)).astype(jnp.int32)

    # -- grouped Householder merge: per group H z|_g = r e_rep (disjoint
    # supports, so all groups share one dense symmetric-orthogonal H).
    same = (leader[:, None] == leader[None, :])
    sf = same.astype(dt)
    is_rep = (leader == idx).astype(dt)
    gz2 = _mm(sf, z2_raw)                       # group ||z||^2, broadcast
    z_rep = _mm(sf, z * is_rep)                 # group rep's z, broadcast
    sgn = jnp.where(z_rep < 0.0, 1.0, -1.0).astype(dt)
    r_vec = sgn * jnp.sqrt(gz2)                 # r = -sign(z_rep) ||z_g||
    wv = z - r_vec * is_rep                     # Householder vector (no
    gn2 = _mm(sf, wv * wv)                      # cancellation by sign choice)
    denom = jnp.where(gn2 > 0.0, gn2, 1.0)
    hh = eye - jnp.where(same & (gn2[:, None] > 0.0),
                         2.0 * wv[:, None] * wv[None, :] / denom[:, None], 0.0)
    z_m = r_vec * is_rep                        # merged z: exact zeros off-rep

    # -- tiny-z deflation on the merged weights
    z2 = z_m * z_m
    keep = rho * z2 > tol
    z2k = jnp.where(keep, z2, 0.0)
    zn2 = jnp.sum(z2k)

    # -- brackets: (d_i, next kept pole) per kept i; last kept gets the
    # Weyl cap d_i + rho ||z||^2.  Merging guarantees kept gaps > tol.
    big = jnp.asarray(jnp.finfo(dt).max, dt) * 0.25
    cand = jnp.where((iota_c > iota_r) & keep[None, :],
                     jnp.broadcast_to(d[None, :], (k, k)), big)
    nxt = jnp.min(cand, axis=1)
    is_last = keep & (nxt >= 0.5 * big)
    right = jnp.where(is_last, d + rho * zn2, nxt)
    left = d
    width = jnp.where(keep, right - left, 0.0)

    # -- anchor by midpoint sign (w increasing on the bracket); the last
    # interval's right end is not a pole, so it always anchors left.
    delta_mid = (d[None, :] - left[:, None]) - (0.5 * width)[:, None]
    safe_mid = jnp.where(delta_mid == 0.0, 1.0, delta_mid)
    inv_mid = jnp.where(delta_mid != 0.0, 1.0 / safe_mid, 0.0)
    w_mid = 1.0 + rho * jnp.sum(z2k[None, :] * inv_mid, axis=1)
    use_left = (w_mid > 0.0) | is_last
    anchor = jnp.where(use_left, left, right)
    lo = jnp.where(use_left, 0.0, -0.5 * width)
    hi = jnp.where(is_last, width, jnp.where(use_left, 0.5 * width, 0.0))

    diff = d[None, :] - anchor[:, None]         # (roots, poles), anchored
    tau = secular_iterate(diff, z2k, rho, lo, hi,
                          n_bisect=n_bisect, n_newton=n_newton, poles_axis=1)
    tau = jnp.where(keep, tau, 0.0)
    mu = jnp.where(keep, anchor + tau, d)

    # -- Loewner zhat (Gu–Eisenstat), log-magnitude space, anchored deltas
    delta_md = (anchor[:, None] - d[None, :]) + tau[:, None]   # mu_i - d_j
    num = jnp.where(keep[:, None], delta_md, 1.0)
    log_num = jnp.sum(jnp.log(jnp.abs(num) + tiny), axis=0)
    dd = d[:, None] - d[None, :]
    den = jnp.where((iota_r != iota_c) & keep[:, None], dd, 1.0)
    log_den = jnp.sum(jnp.log(jnp.abs(den) + tiny), axis=0)
    log_zhat2 = log_num - log_den - jnp.log(rho)
    zhat = jnp.sign(z_m) * jnp.exp(0.5 * log_zhat2)
    zhat = jnp.where(keep, zhat, 0.0)

    # -- scaled-Cauchy eigenvector columns; deflated columns pass through
    cden = (diff - tau[:, None]).T              # [j, i] = d_j - mu_i, anchored
    safe = jnp.where(cden == 0.0, 1.0, cden)
    invc = jnp.where(cden != 0.0, 1.0 / safe, 0.0)
    nrm2 = jnp.sum((zhat * zhat)[:, None] * invc * invc, axis=0)
    colnorm = jnp.where(keep, jnp.sqrt(nrm2), 1.0)
    qt = jnp.where(keep[None, :], zhat[:, None] * invc / colnorm[None, :], eye)

    perm = _stable_sort_perm(mu, iota_c)
    phi = _mm(_mm(hh, qt), perm)
    return _mm(mu[None, :], perm)[0], phi


def _chain(d0_asc, z1, z2w, rho_pos, rho_neg, *, rtol, n_bisect, n_newton):
    """Two chained phases (paper STEPS 4-5 or 6-7) in ascending coords.

    ``z1``/``z2w`` are the two update vectors already rotated into the
    ascending basis of ``d0_asc``; ``rho_pos > 0 > rho_neg`` (static signs
    from the 2x2 Schur split).  The rho<0 phase solves the negated problem
    (eig(D + rho zz^T) = -eig(-D + |rho| zz^T), reversed order), which in
    ascending coordinates is a pure double flip.  Returns final eigenvalues
    (ascending) and the composed operator G with Q_final = Q0_asc @ G.
    """
    kw = dict(rtol=rtol, n_bisect=n_bisect, n_newton=n_newton)
    mu1, phi1 = _phase(d0_asc, z1, rho_pos, **kw)
    z2 = _mm(phi1.T, z2w[:, None])[:, 0]
    mu_b, phi_b = _phase(jnp.flip(-mu1, 0), jnp.flip(z2, 0), -rho_neg, **kw)
    mu2 = jnp.flip(-mu_b, 0)
    phi2 = _flip2(phi_b)
    return mu2, _mm(phi1, phi2)


# ---------------------------------------------------------------------------
# the fused Algorithm 6.1 body (full update) + Brand truncated body
# ---------------------------------------------------------------------------


def _fused_body(u, s, v, a, b, *, sign_fix=True, deflate_rtol=None,
                n_bisect=16, n_newton=6, compute_dtype=None):
    """One full rank-1 SVD update, resident end to end.

    Same contract as ``core.svd_update._svd_update_impl`` (m <= n enforced
    by callers; shapes static): returns ``(u, s, v, d_left, d_right)`` with
    descending singular values and the structured sign fix applied.
    """
    m = u.shape[0]
    n = v.shape[0]
    store_dt = u.dtype
    cdt = jnp.dtype(compute_dtype) if compute_dtype is not None \
        else _compute_dtype_for(store_dt)
    u = u.astype(cdt)
    s = s.astype(cdt)
    v = v.astype(cdt)
    a = a.astype(cdt)
    b = b.astype(cdt)
    kw = dict(rtol=deflate_rtol, n_bisect=n_bisect, n_newton=n_newton)

    # STEP 1 — structured products (A never materialized)
    vtb = _mm(v.T, b[:, None])[:, 0]
    b_t = _mm(u, (s * vtb[:m])[:, None])[:, 0]
    uta = _mm(u.T, a[:, None])[:, 0]
    sv = jnp.concatenate([s * uta, jnp.zeros((n - m,), cdt)])
    a_t = _mm(v, sv[:, None])[:, 0]
    beta = jnp.sum(b * b)
    alpha = jnp.sum(a * a)

    # STEP 2/3 — analytic 2x2 Schur of [[beta, 1], [1, 0]]: eigenvalues
    # h ± sqrt(h^2+1) (one positive, one negative), unit vectors
    # [rho_i, 1] / sqrt(1 + rho_i^2).
    def split(c):
        h = 0.5 * c
        r = jnp.sqrt(h * h + 1.0)
        rho_p, rho_n = h + r, h - r
        np_ = jnp.sqrt(1.0 + rho_p * rho_p)
        nn_ = jnp.sqrt(1.0 + rho_n * rho_n)
        return rho_p, rho_n, (rho_p / np_, 1.0 / np_), (rho_n / nn_, 1.0 / nn_)

    rho1, rho2, qp, qn = split(beta)
    a1 = qp[0] * a + qp[1] * b_t
    b1 = qn[0] * a + qn[1] * b_t
    rho3, rho4, qpv, qnv = split(alpha)
    a2 = qpv[0] * b + qpv[1] * a_t
    b2 = qnv[0] * b + qnv[1] * a_t

    # STEPS 4-7 — chained eigen-updates; s^2 is descending, so ascending
    # order is a static flip on both sides (right side: n-m zeros lead).
    d0u = jnp.flip(s * s, 0)
    z1u = jnp.flip(_mm(u.T, a1[:, None])[:, 0], 0)
    z2u = jnp.flip(_mm(u.T, b1[:, None])[:, 0], 0)
    d_left_asc, g_u_asc = _chain(d0u, z1u, z2u, rho1, rho2, **kw)

    va2 = _mm(v.T, a2[:, None])[:, 0]
    vb2 = _mm(v.T, b2[:, None])[:, 0]

    # STEP 8 (left) — descending outputs; ascending -> descending is a
    # double flip back into the original (descending) coordinates of u.
    g_u = _flip2(g_u_asc)
    d_left = jnp.flip(d_left_asc, 0)
    s_n = jnp.sqrt(jnp.clip(d_left, 0.0, None))
    u_n = _mm(u, g_u)

    if n - m > 2:
        # Structural-zero compression.  A full m<n state gives the right
        # problem n-m poles that are *structurally* zero (the null-space
        # directions of A), and the rank-1 update only excites the 2-dim
        # slice of that null space spanned by the null components of a2/b2.
        # Instead of dragging n-m dead coordinates through both phases, build
        # an orthonormal M (two Householders) whose first two columns span
        # that slice, solve the chain on m+2 coordinates, and pass the other
        # n-m-2 null directions through untouched (eigenvalue exactly 0).
        # Shrinks every right-side tensor from (n+1)^2-ish to (m+2)^2 —
        # at (32, 48) that is 2.1x fewer secular elements on the right.
        k0 = n - m
        c1 = va2[m:]
        c2 = vb2[m:]
        eps = jnp.finfo(cdt).eps
        tiny = jnp.finfo(cdt).tiny
        idx0 = _iota1(k0)
        e1 = (idx0 == 0).astype(cdt)
        e2 = (idx0 == 1).astype(cdt)

        # q1, q2: Gram-Schmidt on (c1, c2) with branchless fallbacks so the
        # basis stays orthonormal even when a2/b2 have no null component.
        na2 = jnp.sqrt(jnp.sum(va2 * va2))
        r11 = jnp.sqrt(jnp.sum(c1 * c1))
        q1 = jnp.where(r11 > eps * na2, c1, e1)
        q1 = q1 / jnp.sqrt(jnp.sum(q1 * q1))
        c2p = c2 - jnp.sum(q1 * c2) * q1
        r22 = jnp.sqrt(jnp.sum(c2p * c2p))
        nb2 = jnp.sqrt(jnp.sum(vb2 * vb2))
        f1 = e1 - q1 * q1[0]          # fallbacks orthogonal to q1; at least
        f2 = e2 - q1 * q1[1]          # one has norm^2 >= 1/2
        fb = jnp.where(jnp.sum(f1 * f1) >= jnp.sum(f2 * f2), f1, f2)
        q2 = jnp.where(r22 > eps * (na2 + nb2), c2p, fb)
        q2 = q2 - jnp.sum(q1 * q2) * q1
        q2 = q2 / jnp.sqrt(jnp.sum(q2 * q2))

        # M = H1 @ H2: exactly orthogonal, M[:, 0] = ±q1, M[:, 1] ≈ ±q2.
        iota_r0 = lax.broadcasted_iota(jnp.int32, (k0, k0), 0)
        iota_c0 = lax.broadcasted_iota(jnp.int32, (k0, k0), 1)
        eye0 = (iota_r0 == iota_c0).astype(cdt)
        sgn1 = jnp.where(q1[0] >= 0.0, 1.0, -1.0).astype(cdt)
        w1 = q1 + sgn1 * e1           # ||w1||^2 = 2 + 2|q1[0]| >= 2
        h1 = eye0 - (2.0 / jnp.sum(w1 * w1)) * (w1[:, None] * w1[None, :])
        q2h = _mm(h1, q2[:, None])[:, 0] * (1.0 - e1)   # coord 0 exactly 0
        q2h = q2h / jnp.sqrt(jnp.maximum(jnp.sum(q2h * q2h), tiny))
        sgn2 = jnp.where(q2h[1] >= 0.0, 1.0, -1.0).astype(cdt)
        w2 = q2h + sgn2 * e2
        h2 = eye0 - (2.0 / jnp.sum(w2 * w2)) * (w2[:, None] * w2[None, :])
        mq = _mm(h1, h2)
        m2 = mq[:, :2]

        # chained eigen-updates on the m+2 active coordinates (ascending:
        # the two compressed zero poles lead, then s^2 ascending).
        d0v = jnp.concatenate([jnp.zeros((2,), cdt), jnp.flip(s * s, 0)])
        z1v = jnp.concatenate([_mm(m2.T, c1[:, None])[:, 0],
                               jnp.flip(va2[:m], 0)])
        z2v = jnp.concatenate([_mm(m2.T, c2[:, None])[:, 0],
                               jnp.flip(vb2[:m], 0)])
        d_act_asc, g_act = _chain(d0v, z1v, z2v, rho3, rho4, **kw)

        v_null = v[:, m:]
        v_act = jnp.concatenate([_mm(v_null, m2), jnp.flip(v[:, :m], 1)], 1)
        v_rot = _mm(v_act, g_act)
        v_inert = _mm(v_null, mq[:, 2:])
        v_n = jnp.concatenate([jnp.flip(v_rot, 1), v_inert], 1)
        d_right = jnp.concatenate([jnp.flip(d_act_asc, 0),
                                   jnp.zeros((k0 - 2,), cdt)])
        # old-v coordinates of the first m new right vectors (descending),
        # for the sign fix: rows 2.. of g_act are the v[:, :m] coords in
        # ascending order on both axes.
        gv_mm = _flip2(g_act[2:, :])[:, :m]
        btva = jnp.concatenate([_mm(vtb[m:][None, :], m2)[0],
                                jnp.flip(vtb[:m], 0)])
        bv = jnp.flip(_mm(btva[None, :], g_act)[0], 0)[:m]
    else:
        d0v = jnp.flip(jnp.concatenate([s * s, jnp.zeros((n - m,), cdt)]), 0)
        z1v = jnp.flip(va2, 0)
        z2v = jnp.flip(vb2, 0)
        d_right_asc, g_v_asc = _chain(d0v, z1v, z2v, rho3, rho4, **kw)
        g_v = _flip2(g_v_asc)
        d_right = jnp.flip(d_right_asc, 0)
        v_n = _mm(v, g_v)
        gv_mm = g_v[:m, :m]
        bv = _mm(vtb[None, :], g_v[:, :m])[0]

    if sign_fix:
        # diag_i = u_i^T (A + a b^T) v_i from the structured factors
        core = jnp.sum((s[:, None] * g_u) * gv_mm, axis=0)
        au = _mm(uta[None, :], g_u)[0]
        diag = core + au * bv
        flip = jnp.where(diag < 0.0, -1.0, 1.0).astype(cdt)
        flip_full = jnp.concatenate([flip, jnp.ones((n - m,), cdt)])
        v_n = v_n * flip_full[None, :]

    return (u_n.astype(store_dt), s_n.astype(store_dt), v_n.astype(store_dt),
            d_left.astype(store_dt), d_right.astype(store_dt))


def _fused_truncated_body(u, s, v, a, b, *, deflate_rtol=None, n_bisect=28,
                          n_newton=4, compute_dtype=None):
    """Brand augmentation + the fused core, resident end to end.

    Same contract as ``core.svd_update._svd_update_truncated_impl``:
    ``u``: (m, r), ``s``: (r,), ``v``: (n, r) -> same shapes.
    """
    m, r = u.shape
    n = v.shape[0]
    store_dt = u.dtype
    cdt = jnp.dtype(compute_dtype) if compute_dtype is not None \
        else _compute_dtype_for(store_dt)
    uc = u.astype(cdt)
    sc = s.astype(cdt)
    vc = v.astype(cdt)
    ac = a.astype(cdt)
    bc = b.astype(cdt)

    p_vec = _mm(uc.T, ac[:, None])[:, 0]
    a_perp = ac - _mm(uc, p_vec[:, None])[:, 0]
    ra = jnp.sqrt(jnp.sum(a_perp * a_perp))
    ok_a = ra > 1e-12
    p_unit = jnp.where(ok_a, a_perp / jnp.where(ok_a, ra, 1.0), 0.0)
    ra = jnp.where(ok_a, ra, 0.0)

    q_vec = _mm(vc.T, bc[:, None])[:, 0]
    b_perp = bc - _mm(vc, q_vec[:, None])[:, 0]
    rb = jnp.sqrt(jnp.sum(b_perp * b_perp))
    ok_b = rb > 1e-12
    q_unit = jnp.where(ok_b, b_perp / jnp.where(ok_b, rb, 1.0), 0.0)
    rb = jnp.where(ok_b, rb, 0.0)

    s_aug = jnp.concatenate([sc, jnp.zeros((1,), cdt)])
    ak = jnp.concatenate([p_vec, ra[None]])
    bk = jnp.concatenate([q_vec, rb[None]])
    eye = jnp.eye(r + 1, dtype=cdt)
    uu, ss, vv, _, _ = _fused_body(
        eye, s_aug, eye, ak, bk, sign_fix=True, deflate_rtol=deflate_rtol,
        n_bisect=n_bisect, n_newton=n_newton, compute_dtype=cdt,
    )

    u_aug = jnp.concatenate([uc, p_unit[:, None]], axis=1)
    v_aug = jnp.concatenate([vc, q_unit[:, None]], axis=1)
    u_new = _mm(u_aug, uu[:, :r])
    v_new = _mm(v_aug, vv[:, :r])
    return (u_new.astype(store_dt), ss[:r].astype(store_dt),
            v_new.astype(store_dt))


# ---------------------------------------------------------------------------
# XLA entry points (jit / vmap targets)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=(
    "sign_fix", "n_bisect", "n_newton", "compute_dtype"))
def fused_update_xla(u, s, v, a, b, *, sign_fix=True, deflate_rtol=None,
                     n_bisect=16, n_newton=6, compute_dtype=None):
    """The fused body as one XLA fusion (CPU path; vmaps cleanly)."""
    return _fused_body(u, s, v, a, b, sign_fix=sign_fix,
                       deflate_rtol=deflate_rtol, n_bisect=n_bisect,
                       n_newton=n_newton, compute_dtype=compute_dtype)


@functools.partial(jax.jit, static_argnames=(
    "n_bisect", "n_newton", "compute_dtype"))
def fused_update_truncated_xla(u, s, v, a, b, *, deflate_rtol=None,
                               n_bisect=16, n_newton=6, compute_dtype=None):
    return _fused_truncated_body(u, s, v, a, b, deflate_rtol=deflate_rtol,
                                 n_bisect=n_bisect, n_newton=n_newton,
                                 compute_dtype=compute_dtype)


# ---------------------------------------------------------------------------
# Pallas entry points — grid (B,), one program per update, all phases in VMEM
# ---------------------------------------------------------------------------


def _full_kernel(u_ref, s_ref, v_ref, a_ref, b_ref,
                 uo_ref, so_ref, vo_ref, dl_ref, dr_ref, *, statics):
    out = _fused_body(u_ref[0], s_ref[0], v_ref[0], a_ref[0], b_ref[0],
                      **statics)
    uo_ref[0] = out[0]
    so_ref[0] = out[1]
    vo_ref[0] = out[2]
    dl_ref[0] = out[3]
    dr_ref[0] = out[4]


def _trunc_kernel(u_ref, s_ref, v_ref, a_ref, b_ref,
                  uo_ref, so_ref, vo_ref, *, statics):
    out = _fused_truncated_body(u_ref[0], s_ref[0], v_ref[0], a_ref[0],
                                b_ref[0], **statics)
    uo_ref[0] = out[0]
    so_ref[0] = out[1]
    vo_ref[0] = out[2]


def _batched_specs(batch, shapes):
    return [pl.BlockSpec((1,) + sh, lambda i, _nz=len(sh): (i,) + (0,) * _nz)
            for sh in shapes]


@functools.partial(jax.jit, static_argnames=(
    "sign_fix", "n_bisect", "n_newton", "compute_dtype", "interpret"))
def fused_update_pallas_batched(u, s, v, a, b, *, sign_fix=True,
                                deflate_rtol=None, n_bisect=16, n_newton=6,
                                compute_dtype=None, interpret=False):
    """B stacked fused updates, batch folded into the Pallas grid.

    ``u``: (B, m, m), ``s``: (B, m), ``v``: (B, n, n), ``a``: (B, m),
    ``b``: (B, n) -> the 5-tuple of stacked ``SvdUpdateResult`` leaves.
    """
    bsz, m, _ = u.shape
    n = v.shape[-1]
    dt = u.dtype
    statics = dict(sign_fix=sign_fix, deflate_rtol=deflate_rtol,
                   n_bisect=n_bisect, n_newton=n_newton,
                   compute_dtype=compute_dtype)
    kern = functools.partial(_full_kernel, statics=statics)
    out_shapes = [(m, m), (m,), (n, n), (m,), (n,)]
    return pl.pallas_call(
        kern,
        grid=(bsz,),
        in_specs=_batched_specs(bsz, [(m, m), (m,), (n, n), (m,), (n,)]),
        out_specs=_batched_specs(bsz, out_shapes),
        out_shape=[jax.ShapeDtypeStruct((bsz,) + sh, dt) for sh in out_shapes],
        interpret=interpret,
    )(u, s.astype(dt), v, a.astype(dt), b.astype(dt))


def fused_update_pallas(u, s, v, a, b, **kw):
    """Single fused update via the (B,)-grid kernel with B = 1."""
    out = fused_update_pallas_batched(u[None], s[None], v[None],
                                      a[None], b[None], **kw)
    return tuple(x[0] for x in out)


@functools.partial(jax.jit, static_argnames=(
    "n_bisect", "n_newton", "compute_dtype", "interpret"))
def fused_update_truncated_pallas_batched(u, s, v, a, b, *, deflate_rtol=None,
                                          n_bisect=16, n_newton=6,
                                          compute_dtype=None, interpret=False):
    """B stacked fused truncated updates (Brand + fused core per program)."""
    bsz, m, r = u.shape
    n = v.shape[-2]
    dt = u.dtype
    statics = dict(deflate_rtol=deflate_rtol, n_bisect=n_bisect,
                   n_newton=n_newton, compute_dtype=compute_dtype)
    kern = functools.partial(_trunc_kernel, statics=statics)
    out_shapes = [(m, r), (r,), (n, r)]
    return pl.pallas_call(
        kern,
        grid=(bsz,),
        in_specs=_batched_specs(bsz, [(m, r), (r,), (n, r), (m,), (n,)]),
        out_specs=_batched_specs(bsz, out_shapes),
        out_shape=[jax.ShapeDtypeStruct((bsz,) + sh, dt) for sh in out_shapes],
        interpret=interpret,
    )(u, s.astype(dt), v, a.astype(dt), b.astype(dt))


def fused_update_truncated_pallas(u, s, v, a, b, **kw):
    out = fused_update_truncated_pallas_batched(u[None], s[None], v[None],
                                                a[None], b[None], **kw)
    return tuple(x[0] for x in out)
