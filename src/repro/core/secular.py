"""Secular-equation machinery for the symmetric diagonal-plus-rank-1 eigenproblem.

Solves  eig(D + rho * z z^T)  where D = diag(d), d ascending, rho > 0, via the
secular equation (paper Eq. 11 / Golub 1973):

    w(mu) = 1 + rho * sum_k z_k^2 / (d_k - mu) = 0.

Numerical structure (paper §3.1 + the Gu–Eisenstat corrections it cites):

* Bunch–Nielsen–Sorensen deflation: tiny ``|z_i|`` and (near-)repeated ``d_i``
  are deflated before the solve. Repeated entries are merged with Givens
  rotations whose (c, s) pairs are recorded for the eigenvector back
  transformation. Everything is static-shape (masks + permutations), so the
  whole pipeline jits.
* Roots are represented as (anchor index, tau) with ``mu_i = d[anchor_i] +
  tau_i`` and the anchor chosen as the *nearest* pole. All downstream
  difference computations use ``d_j - mu_i = (d_j - d_anchor) - tau`` which is
  accurate even when the root is within eps of a pole. This is what makes the
  scaled-Cauchy eigenvectors orthogonal to working precision.
* Hybrid solver: fixed-count bisection (guaranteed bracket) + Newton polish,
  vectorized over all roots (no data-dependent control flow).
* Loewner reweighting (Gu–Eisenstat / LAPACK dlaed3): ``zhat`` is recomputed
  from the solved roots so that the Cauchy-column eigenvectors are numerically
  orthogonal:  zhat_j^2 = prod_i (mu_i - d_j) / (rho * prod_{i!=j} (d_i - d_j)).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "DeflationResult",
    "SecularRoots",
    "deflate",
    "apply_givens_columns",
    "secular_solve",
    "loewner_zhat",
    "mu_minus_d",
]


# ---------------------------------------------------------------------------
# Deflation
# ---------------------------------------------------------------------------


class DeflationResult(NamedTuple):
    """Static-shape description of a deflated D + rho z z^T problem.

    All arrays have length n (the original size); ``keep`` marks retained
    entries, ``n_keep`` counts them. ``compact`` is a permutation putting
    retained entries first (stable, so retained d stays ascending).
    """

    d: jax.Array          # (n,) diagonal, ascending (unchanged values)
    z: jax.Array          # (n,) z after Givens merging (zeros at deflated slots)
    keep: jax.Array       # (n,) bool
    n_keep: jax.Array     # () int32
    givens_a: jax.Array   # (n,) int32 first coordinate of rotation i (or i)
    givens_b: jax.Array   # (n,) int32 second coordinate of rotation i (or i)
    givens_c: jax.Array   # (n,) rotation cosines (1.0 where identity)
    givens_s: jax.Array   # (n,) rotation sines   (0.0 where identity)
    any_rot: jax.Array    # () bool — fast-path skip flag
    compact: jax.Array    # (n,) int32 permutation, retained-first


def _rep_anchored_literal(val, like: jax.Array, dtype) -> jax.Array:
    """A literal constant whose shard_map replication tracking follows ``like``.

    Under ``shard_map(check_rep=True)`` literal constants carry rep ``None``
    ("replicated over all axes") while values derived from operands carry
    concrete axis sets; ``lax.scan`` requires the carry rep to be *equal* on
    input and output, so a literal initial carry spuriously trips the check
    (jax 0.4.x scan-replication error). Selecting the same literal on a
    ``like``-derived predicate is a no-op numerically but inherits ``like``'s
    rep, making the scan carry rep invariant.
    """
    c = jnp.asarray(val, dtype)
    return lax.select(like.reshape(-1)[0] == like.reshape(-1)[0], c, c)


def deflate(d: jax.Array, z: jax.Array, rho: jax.Array, *, rtol: float | None = None) -> DeflationResult:
    """BNS deflation for ``D + rho z z^T`` (rho > 0, d ascending).

    LAPACK-style duplicate merging: each entry is compared against the *last
    retained* entry (not just its neighbor), so duplicate chains interrupted
    by tiny-z entries still merge correctly.
    """
    n = d.shape[0]
    dt = d.dtype
    eps = jnp.finfo(dt).eps
    if rtol is None:
        rtol = 64.0 * float(eps)

    znorm2 = jnp.sum(z * z)
    scale = jnp.maximum(jnp.max(jnp.abs(d)), jnp.abs(rho) * znorm2) + jnp.finfo(dt).tiny
    tol = rtol * scale

    def step(carry, i):
        z_arr, last = carry
        zi = z_arr[i]
        tiny_i = jnp.abs(rho) * zi * zi <= tol
        have_last = last >= 0
        lastc = jnp.maximum(last, 0)
        zl = z_arr[lastc]
        gap = d[i] - d[lastc]
        r = jnp.sqrt(zl * zl + zi * zi)
        safe_r = jnp.where(r > 0, r, 1.0)
        c = jnp.where(r > 0, zi / safe_r, 1.0)
        s = jnp.where(r > 0, -zl / safe_r, 0.0)
        offdiag = jnp.abs(c * s * gap)
        do_rot = have_last & (~tiny_i) & (offdiag <= tol) & (jnp.abs(zl) > 0)
        c = jnp.where(do_rot, c, 1.0)
        s = jnp.where(do_rot, s, 0.0)
        z_new = jnp.where(do_rot, z_arr.at[lastc].set(0.0).at[i].set(r), z_arr)
        new_last = jnp.where(tiny_i, last, i)
        a_idx = jnp.where(do_rot, lastc, i).astype(jnp.int32)
        b_idx = jnp.asarray(i, jnp.int32)
        return (z_new, new_last), (a_idx, b_idx, c, s)

    last0 = _rep_anchored_literal(-1, z, jnp.arange(1).dtype)  # default int dtype (x64-aware)
    (z_merged, _), (gas, gbs, cs, ss) = lax.scan(step, (z, last0), jnp.arange(n))

    # deflate tiny z entries
    keep = jnp.abs(rho) * z_merged * z_merged > tol
    z_final = jnp.where(keep, z_merged, 0.0)
    n_keep = jnp.sum(keep).astype(jnp.int32)

    # retained-first stable permutation (retained d remains ascending)
    compact = jnp.argsort(jnp.where(keep, 0, 1), stable=True).astype(jnp.int32)
    any_rot = jnp.any(ss != 0.0)

    return DeflationResult(d, z_final, keep, n_keep, gas, gbs, cs, ss, any_rot, compact)


def apply_givens_columns(
    w: jax.Array,
    a_idx: jax.Array,
    b_idx: jax.Array,
    c: jax.Array,
    s: jax.Array,
    any_rot: jax.Array,
) -> jax.Array:
    """Apply the recorded deflation rotations to *columns* of ``w``.

    Deflation produced B' = R_k ... R_1 B R_1^T ... R_k^T, so eigenvectors of
    B are Q = R_1^T ... R_k^T Q'. Right-multiplying a row space:
    ``w @ (R_1^T R_2^T ...)`` — apply the recorded rotations in forward order,
    each mixing columns (a_i, b_i):
        col_a' = c col_a + s col_b,   col_b' = -s col_a + c col_b.
    """
    n = w.shape[1]
    if n < 2:
        return w

    def do_apply(w0):
        def step(wc, i):
            ai = a_idx[i]
            bi = b_idx[i]
            ci = c[i]
            si = s[i]
            col_a = wc[:, ai]
            col_b = wc[:, bi]
            new_a = ci * col_a + si * col_b
            new_b = -si * col_a + ci * col_b
            wc = wc.at[:, ai].set(new_a).at[:, bi].set(new_b)
            return wc, None

        out, _ = lax.scan(step, w0, jnp.arange(n))
        return out

    return lax.cond(any_rot, do_apply, lambda w0: w0, w)


# ---------------------------------------------------------------------------
# Secular solve
# ---------------------------------------------------------------------------


class SecularRoots(NamedTuple):
    """Roots of the secular equation on the *compacted* retained problem.

    Entry ``i`` (for ``i < n_keep``) is the root in the i-th retained
    interval:  mu_i = dc[anchor[i]] + tau[i].  Entries ``i >= n_keep`` are
    padding (mu = dc[i], tau = 0).
    """

    mu: jax.Array       # (n,) root values (padding: dc)
    anchor: jax.Array   # (n,) int32 anchor pole index into dc
    tau: jax.Array      # (n,) offset from anchor pole
    valid: jax.Array    # (n,) bool — i < n_keep


def _eval_w_and_deriv(dc, zc2, rho, anchor_vals, tau, valid_src):
    """Evaluate w(mu) = 1 + rho * sum_j zc2_j / (dc_j - mu) and w'(mu).

    mu is represented as anchor_vals + tau (per root).  Shapes: roots along
    axis 0, sources along axis 1.  ``valid_src`` masks padded sources.
    """
    # delta[i, j] = dc_j - mu_i computed stably
    delta = (dc[None, :] - anchor_vals[:, None]) - tau[:, None]
    safe = jnp.where(delta == 0.0, 1.0, delta)
    inv = jnp.where(valid_src[None, :], 1.0 / safe, 0.0)
    w = 1.0 + rho * jnp.sum(zc2[None, :] * inv, axis=1)
    wp = rho * jnp.sum(zc2[None, :] * inv * inv, axis=1)  # w'(mu) = rho sum z^2/delta^2
    return w, wp


@partial(jax.jit, static_argnames=("n_bisect", "n_newton"))
def secular_solve(
    dc: jax.Array,
    zc: jax.Array,
    rho: jax.Array,
    n_keep: jax.Array,
    *,
    n_bisect: int = 58,
    n_newton: int = 4,
) -> SecularRoots:
    """Solve the secular equation for the compacted problem (rho > 0).

    ``dc``: (n,) retained poles first (ascending over the first ``n_keep``),
    ``zc``: matching z values (nonzero over retained), padding arbitrary.
    Returns all n roots with validity mask.
    """
    n = dc.shape[0]
    dt = dc.dtype
    idx = jnp.arange(n)
    valid = idx < n_keep
    valid_src = valid

    zc2 = jnp.where(valid, zc * zc, 0.0)
    znorm2 = jnp.sum(zc2)

    # interval (dc_i, dc_{i+1}) for i < n_keep-1; last: (dc_{k-1}, dc_{k-1}+rho*|z|^2)
    is_last = idx == (n_keep - 1)
    d_right = jnp.roll(dc, -1)  # dc_{i+1}; junk at last retained, fixed below
    right = jnp.where(is_last, dc + rho * znorm2, d_right)
    left = dc
    width = right - left

    # --- anchor selection: evaluate w at the midpoint; w is increasing on the
    # interval, so w(mid) > 0 => root in left half (anchor = left pole i),
    # else right half (anchor = right pole i+1, tau negative).
    mid_anchor_vals = left
    mid_tau = 0.5 * width
    w_mid, _ = _eval_w_and_deriv(dc, zc2, rho, mid_anchor_vals, mid_tau, valid_src)
    # For the last interval the "right end" dc_{k-1}+rho|z|^2 is not a pole, so
    # there is no cancellation risk on the right — always anchor it left.
    use_left = (w_mid > 0.0) | is_last

    anchor_idx = jnp.where(use_left, idx, jnp.minimum(idx + 1, n - 1)).astype(jnp.int32)
    anchor_vals = jnp.where(use_left, left, right)
    # tau brackets relative to anchor. The last root is always left-anchored,
    # so its bracket must span the whole interval, not the left half.
    lo = jnp.where(use_left, 0.0, -0.5 * width)
    hi = jnp.where(is_last, width, jnp.where(use_left, 0.5 * width, 0.0))

    # --- bisection (vectorized, fixed count)
    def bis_step(_, carry):
        lo_c, hi_c = carry
        tmid = 0.5 * (lo_c + hi_c)
        w, _ = _eval_w_and_deriv(dc, zc2, rho, anchor_vals, tmid, valid_src)
        go_right = w < 0.0  # w increasing: root above tmid
        lo_n = jnp.where(go_right, tmid, lo_c)
        hi_n = jnp.where(go_right, hi_c, tmid)
        return lo_n, hi_n

    lo, hi = lax.fori_loop(0, n_bisect, bis_step, (lo, hi))
    tau = 0.5 * (lo + hi)

    # --- Newton polish (projected into the bracket)
    def newton_step(_, tau_c):
        w, wp = _eval_w_and_deriv(dc, zc2, rho, anchor_vals, tau_c, valid_src)
        step = w / jnp.maximum(wp, jnp.finfo(dt).tiny)
        tau_n = tau_c - step
        tau_n = jnp.clip(tau_n, lo, hi)
        return tau_n

    tau = lax.fori_loop(0, n_newton, newton_step, tau)

    mu = anchor_vals + tau
    mu = jnp.where(valid, mu, dc)
    tau = jnp.where(valid, tau, 0.0)
    anchor_idx = jnp.where(valid, anchor_idx, idx.astype(jnp.int32))
    return SecularRoots(mu, anchor_idx, tau, valid)


def mu_minus_d(roots: SecularRoots, dc: jax.Array) -> jax.Array:
    """Accurate difference matrix  delta[i, j] = mu_i - dc_j  (n, n)."""
    anchor_vals = dc[roots.anchor]
    return (anchor_vals[:, None] - dc[None, :]) + roots.tau[:, None]


# ---------------------------------------------------------------------------
# Loewner reweighting
# ---------------------------------------------------------------------------


def loewner_zhat(
    dc: jax.Array,
    zc: jax.Array,
    rho: jax.Array,
    roots: SecularRoots,
) -> jax.Array:
    """Gu–Eisenstat zhat from the solved roots (compacted problem).

    zhat_j^2 = prod_{i<k} (mu_i - dc_j) / (rho * prod_{i<k, i!=j} (dc_i - dc_j))

    computed with accurate differences (anchored representation) in
    log-magnitude space. The ratio is mathematically positive; signs are
    inherited from the original z. Padded entries return 0.
    """
    n = dc.shape[0]
    dt = dc.dtype
    idx = jnp.arange(n)
    valid = roots.valid  # (n,) roots mask == sources mask (same count)

    # numerator: prod_i (mu_i - dc_j) over valid roots i
    delta = mu_minus_d(roots, dc)  # (roots i, poles j)
    num = jnp.where(valid[:, None], delta, 1.0)
    log_num = jnp.sum(jnp.log(jnp.abs(num) + jnp.finfo(dt).tiny), axis=0)  # (j,)

    # denominator: prod_{i != j} (dc_i - dc_j) over valid i, valid j
    dd = dc[:, None] - dc[None, :]
    offdiag = (idx[:, None] != idx[None, :]) & valid[:, None]
    den = jnp.where(offdiag, dd, 1.0)
    log_den = jnp.sum(jnp.log(jnp.abs(den) + jnp.finfo(dt).tiny), axis=0)  # (j,)

    log_zhat2 = log_num - log_den - jnp.log(jnp.abs(rho))
    zhat = jnp.sign(zc) * jnp.exp(0.5 * log_zhat2)
    zhat = jnp.where(valid, zhat, 0.0)
    return zhat
