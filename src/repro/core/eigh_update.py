"""Symmetric diagonal-plus-rank-1 eigen-update (paper Algorithm 6.2).

Computes the eigendecomposition of ``diag(d) + rho z z^T`` and exposes the
eigenvector rotation Q as a *structured operator* (permutation ∘ deflation
rotations ∘ scaled-Cauchy matrix), so the singular-vector update
``U_new = U @ Q`` (paper Eq. 10/20) can be evaluated:

* ``method="direct"`` — dense stable Cauchy product, O(m n^2);
* ``method="fmm"``    — batched Chebyshev FMM, O(m n p) (paper §5);
* ``method="kernel"`` — Pallas on-the-fly Cauchy kernel (TPU hot path).

The plan/apply split mirrors how the framework uses it: one plan, several
applies (U update, Q materialization for the sign fix, diagnostics).

Both halves are pure static-shape functions of their array inputs, so an
``EighUpdatePlan`` batches cleanly under ``jax.vmap`` — a batched plan
stacks every data field along a leading batch axis while meta fields stay
shared. That property is what lets ``core.engine`` vmap whole SVD updates
(which call make_plan/apply_update internally); ``make_plan_batch`` /
``apply_update_batch`` expose the same batched plan/apply split directly
for eigen-level consumers. Under vmap the ``method="kernel"`` Cauchy product
dispatches to the batched Pallas kernel (batch folded into the grid, see
``kernels.cauchy_matmul``) via a ``custom_vmap`` rule in ``kernels.ops``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import cauchy as _cauchy
from repro.core import fmm as _fmm
from repro.core.secular import (
    SecularRoots,
    apply_givens_columns,
    deflate,
    loewner_zhat,
    secular_solve,
)

__all__ = [
    "EighUpdatePlan",
    "make_plan",
    "make_plan_batch",
    "eigenvalues",
    "apply_update",
    "apply_update_batch",
    "materialize_q",
    "eigh_update",
]

_FMM_MIN_N = 96  # below this the FMM tree is pointless; fall back to direct


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "sort_idx",
        "givens_a",
        "givens_b",
        "givens_c",
        "givens_s",
        "any_rot",
        "compact",
        "dc",
        "zc",
        "rho",
        "zhat",
        "mu",
        "anchor",
        "tau",
        "valid",
        "colnorm",
        "mu_full",
        "out_sort",
        "fmm",
    ],
    meta_fields=["n", "negated", "has_fmm"],
)
@dataclasses.dataclass(frozen=True)
class EighUpdatePlan:
    sort_idx: jax.Array   # (n,) ascending-d permutation of the (possibly negated) problem
    givens_a: jax.Array
    givens_b: jax.Array
    givens_c: jax.Array
    givens_s: jax.Array
    any_rot: jax.Array
    compact: jax.Array    # retained-first permutation (on sorted problem)
    dc: jax.Array         # (n,) sorted+compacted poles
    zc: jax.Array         # (n,) merged z, compacted
    rho: jax.Array        # () positive rho of the solved problem
    zhat: jax.Array       # (n,) Loewner weights (0 on padding)
    mu: jax.Array         # (n,) secular roots (compacted positions)
    anchor: jax.Array     # (n,) int32
    tau: jax.Array        # (n,)
    valid: jax.Array      # (n,) bool
    colnorm: jax.Array    # (n,) scaled-Cauchy column norms (1 on padding)
    mu_full: jax.Array    # (n,) eigenvalues in compacted positions
    out_sort: jax.Array   # (n,) final ascending order
    fmm: Any              # FmmPlan or None
    n: int
    negated: bool         # problem was negated to make rho positive
    has_fmm: bool


def make_plan(
    d: jax.Array,
    z: jax.Array,
    rho: jax.Array,
    *,
    rho_positive: bool,
    fmm_p: int = 20,
    build_fmm: bool = False,
    deflate_rtol: float | None = None,
) -> EighUpdatePlan:
    """Build the structured eigen-update operator for ``diag(d) + rho z z^T``.

    ``rho_positive`` must reflect the *static* sign of rho (in the SVD update
    the two 2x2 Schur eigenvalues have fixed signs). For rho < 0 the problem
    is negated: eig(D + rho zz^T) = -eig(-D + |rho| zz^T), same eigenvectors.
    """
    n = d.shape[0]
    negated = not rho_positive
    d_w = -d if negated else d
    rho_w = -rho if negated else rho

    sort_idx = jnp.argsort(d_w).astype(jnp.int32)
    ds = d_w[sort_idx]
    zs = z[sort_idx]

    defl = deflate(ds, zs, rho_w, rtol=deflate_rtol)
    dc = ds[defl.compact]
    zc = defl.z[defl.compact]

    roots = secular_solve(dc, zc, rho_w, defl.n_keep)
    zhat = loewner_zhat(dc, zc, rho_w, roots)
    colnorm = _cauchy.cauchy_colnorms_stable(
        zhat, dc, roots.anchor, roots.tau, src_valid=roots.valid, tgt_valid=roots.valid
    )
    mu_full = jnp.where(roots.valid, roots.mu, dc)
    out_sort = jnp.argsort(mu_full, stable=True).astype(jnp.int32)

    fmm_plan = None
    use_fmm = build_fmm and n >= _FMM_MIN_N
    if use_fmm:
        fmm_plan = _fmm.build_plan(
            dc,
            mu_full,
            p=fmm_p,
            src_valid=roots.valid,
            tgt_valid=roots.valid,
            tgt_anchor=roots.anchor,
            tgt_tau=roots.tau,
        )

    return EighUpdatePlan(
        sort_idx=sort_idx,
        givens_a=defl.givens_a,
        givens_b=defl.givens_b,
        givens_c=defl.givens_c,
        givens_s=defl.givens_s,
        any_rot=defl.any_rot,
        compact=defl.compact,
        dc=dc,
        zc=zc,
        rho=rho_w,
        zhat=zhat,
        mu=roots.mu,
        anchor=roots.anchor,
        tau=roots.tau,
        valid=roots.valid,
        colnorm=colnorm,
        mu_full=mu_full,
        out_sort=out_sort,
        fmm=fmm_plan,
        n=n,
        negated=negated,
        has_fmm=use_fmm,
    )


def eigenvalues(plan: EighUpdatePlan) -> jax.Array:
    """Eigenvalues of diag(d) + rho zz^T, ascending."""
    mu = plan.mu_full[plan.out_sort]
    if plan.negated:
        mu = -mu[::-1]
    return mu


def _cauchy_block(plan: EighUpdatePlan, wc: jax.Array, method: str) -> jax.Array:
    """out[:, i] = sum_j wc[:, j] * zhat_j / (dc_j - mu_i), columns /colnorm."""
    wz = wc * plan.zhat[None, :]
    if method == "fmm" and plan.has_fmm:
        # fmm computes sum wz/(mu_i - dc_j); Cauchy convention flips the sign.
        # Pathological spectra that overflow the static box capacity fall back
        # to the dense stable product (correctness safety net, see DESIGN.md).
        def _via_fmm(w_in):
            return -_fmm.fmm_apply(plan.fmm, w_in)

        def _via_dense(w_in):
            return _cauchy.cauchy_matmul_stable(
                w_in, plan.dc, plan.anchor, plan.tau,
                src_valid=plan.valid, tgt_valid=plan.valid,
            )

        out = jax.lax.cond(plan.fmm.overflow, _via_dense, _via_fmm, wz)
    elif method == "kernel":
        from repro.kernels import ops as _kops

        out = _kops.cauchy_matmul_stable(
            wz, plan.dc, plan.anchor, plan.tau,
            src_valid=plan.valid, tgt_valid=plan.valid,
        )
    else:
        out = _cauchy.cauchy_matmul_stable(
            wz, plan.dc, plan.anchor, plan.tau,
            src_valid=plan.valid, tgt_valid=plan.valid,
        )
    return out / plan.colnorm[None, :]


@partial(jax.jit, static_argnames=("method",))
def apply_update(plan: EighUpdatePlan, w: jax.Array, *, method: str = "direct") -> jax.Array:
    """Compute ``w @ Q`` where Q's columns are the eigenvectors (ascending mu).

    w: (m, n). The structured pipeline: column permutation (sort) → deflation
    rotations → compaction → scaled-Cauchy product on the retained block with
    deflated columns passing through → final eigenvalue ordering.
    """
    ws = w[:, plan.sort_idx]
    ws = apply_givens_columns(ws, plan.givens_a, plan.givens_b, plan.givens_c, plan.givens_s, plan.any_rot)
    wc = ws[:, plan.compact]

    cau = _cauchy_block(plan, wc, method)
    out_c = jnp.where(plan.valid[None, :], cau, wc)
    out = out_c[:, plan.out_sort]
    if plan.negated:
        out = out[:, ::-1]
    return out


def make_plan_batch(
    d: jax.Array,
    z: jax.Array,
    rho: jax.Array,
    *,
    rho_positive: bool,
    fmm_p: int = 20,
    build_fmm: bool = False,
    deflate_rtol: float | None = None,
) -> EighUpdatePlan:
    """Batched ``make_plan``: ``d``/``z`` are (B, n), ``rho`` is (B,).

    Returns one ``EighUpdatePlan`` whose data fields carry a leading batch
    axis; the static meta fields (n, negated, has_fmm) are shared across the
    batch — the point of grouping equal geometries before batching.
    """
    fn = partial(
        make_plan,
        rho_positive=rho_positive,
        fmm_p=fmm_p,
        build_fmm=build_fmm,
        deflate_rtol=deflate_rtol,
    )
    return jax.vmap(fn)(d, z, rho)


@partial(jax.jit, static_argnames=("method",))
def apply_update_batch(plan: EighUpdatePlan, w: jax.Array, *, method: str = "direct") -> jax.Array:
    """Batched ``apply_update``: batched plan (from ``make_plan_batch``) and
    ``w`` of shape (B, m, n) -> (B, m, n)."""
    return jax.vmap(partial(apply_update, method=method))(plan, w)


def materialize_q(plan: EighUpdatePlan, *, method: str = "direct", dtype=None) -> jax.Array:
    """Materialize the n x n eigenvector rotation Q (ascending-mu columns)."""
    dt = dtype or plan.dc.dtype
    return apply_update(plan, jnp.eye(plan.n, dtype=dt), method=method)


def eigh_update(
    u: jax.Array,
    d: jax.Array,
    z: jax.Array,
    rho: jax.Array,
    *,
    rho_positive: bool,
    method: str = "direct",
    fmm_p: int = 20,
):
    """(mu, U_new) for  U diag(d) U^T + rho (Uz)(Uz)^T = U_new diag(mu) U_new^T.

    Matches paper Algorithm 6.2 (with z already projected: z = U^T a_1).
    """
    plan = make_plan(d, z, rho, rho_positive=rho_positive, build_fmm=(method == "fmm"), fmm_p=fmm_p)
    return eigenvalues(plan), apply_update(plan, u, method=method)
