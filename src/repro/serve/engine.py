"""Serving engine: batched prefill + decode with greedy/temperature sampling.

Small but real: requests are batched, prefilled once, then decoded step by
step with the per-architecture cache machinery (KV / compressed-MLA / SSM /
WKV states all behind the same ModelApi).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.registry import ModelApi

__all__ = ["ServeConfig", "generate"]


@dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0   # 0 = greedy
    seed: int = 0


def _sample(logits, temperature, key):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


def generate(api: ModelApi, params, prompts: jax.Array, serve_cfg: ServeConfig,
             *, max_len: int | None = None):
    """prompts: (b, prompt_len) int32. Returns (b, max_new_tokens) int32."""
    b, prompt_len = prompts.shape
    total = prompt_len + serve_cfg.max_new_tokens
    max_len = max_len or total

    logits, cache = api.prefill(params, {"tokens": prompts}, max_len=max_len)
    key = jax.random.PRNGKey(serve_cfg.seed)

    decode = jax.jit(api.decode_step, donate_argnums=(1,))

    out = []
    token = _sample(logits[:, -1, :], serve_cfg.temperature, key)[:, None]
    out.append(token)
    pos = prompt_len
    for i in range(serve_cfg.max_new_tokens - 1):
        key, sub = jax.random.split(key)
        logits, cache = decode(params, cache, token, jnp.asarray(pos, jnp.int32))
        token = _sample(logits[:, -1, :], serve_cfg.temperature, sub)[:, None]
        out.append(token)
        pos += 1
    return jnp.concatenate(out, axis=1)
