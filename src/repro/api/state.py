"""``SvdState`` — the one SVD container every update path speaks (DESIGN.md §8).

The paper's operation is "given an SVD, absorb a rank-1 perturbation". The
codebase previously carried that state in two shapes — ``SvdUpdateResult``
(full: square bases + eigen diagnostics) and ``TruncatedSvd`` (rank-r
factors) — and every consumer picked a call path by container type.
``SvdState`` unifies them:

* ``u: (..., m, k)``, ``s: (..., k)``, ``v: (..., n, k)`` — ``k == m`` (with
  square ``v``) is the *full* paper state whose reconstruction uses
  ``v[:, :m]``; ``k < min(m, n)`` is the truncated streaming state.  A
  leading batch axis (``u.ndim == 3``) marks a *stacked* state of B
  independent problems — the geometry the batch-first engine dispatches on.
* ``d_left`` / ``d_right`` — the optional eigen-update diagnostics a full
  Algorithm-6.1 update produces (``None`` on truncated / constructed states;
  ``None`` leaves vanish from the pytree, so a diagnostics-free ``SvdState``
  has exactly the three array leaves ``TruncatedSvd`` had).
* ``mesh`` — optional static placement hint (``jax.sharding.Mesh``): where a
  batched update of this state should spread its batch axis when the policy
  itself does not name a mesh.  Metadata, not a leaf.

It is a frozen, registered-pytree dataclass: it jits, vmaps, shard_maps and
stacks (``jax.tree.map``) like the NamedTuples it replaces.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["SvdState", "as_state"]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["u", "s", "v", "d_left", "d_right"],
    meta_fields=["mesh"],
)
@dataclasses.dataclass(frozen=True)
class SvdState:
    """Immutable SVD state: ``A ≈ u @ diag(s) @ v[..., :k].T`` (see module doc)."""

    u: jax.Array                    # (..., m, k) left singular vectors
    s: jax.Array                    # (..., k)    singular values, descending
    v: jax.Array                    # (..., n, k) right singular vectors
    d_left: jax.Array | None = None   # (..., m) eigenvalues of (A)(A)^T (full updates)
    d_right: jax.Array | None = None  # (..., n) eigenvalues of (A)^T(A) (full updates)
    mesh: Any = None                  # optional jax.sharding.Mesh placement hint

    # -- geometry -----------------------------------------------------------

    @property
    def m(self) -> int:
        return self.u.shape[-2]

    @property
    def n(self) -> int:
        return self.v.shape[-2]

    @property
    def rank(self) -> int:
        return self.s.shape[-1]

    @property
    def shape(self) -> tuple[int, int]:
        return (self.m, self.n)

    @property
    def dtype(self):
        return self.u.dtype

    @property
    def is_full(self) -> bool:
        """Paper-shaped full state: square bases, ``s`` of length ``m``."""
        return (
            self.u.shape[-1] == self.u.shape[-2]
            and self.v.shape[-1] == self.v.shape[-2]
            and self.s.shape[-1] == self.u.shape[-2]
        )

    @property
    def is_batched(self) -> bool:
        """True when the leaves carry a leading batch axis of B problems."""
        return self.u.ndim == 3

    @property
    def batch(self) -> int | None:
        return self.u.shape[0] if self.is_batched else None

    @property
    def geometry(self) -> tuple:
        """Batching-group key: states sharing it stack into one engine call."""
        return (self.m, self.n, self.rank, jnp.result_type(self.u), self.is_full)

    # -- constructors -------------------------------------------------------

    @classmethod
    def from_dense(cls, x, rank: int | None = None, *, mesh: Any = None) -> "SvdState":
        """SVD of a dense matrix.

        ``rank=None`` builds the full paper state (``u (m, m)``, ``s (m,)``,
        ``v (n, n)``; requires ``m <= n``); an integer builds the rank-r
        truncated streaming state.

        >>> import numpy as np
        >>> from repro.api import SvdState
        >>> x = np.arange(12.0).reshape(3, 4)      # rank-2 matrix
        >>> full = SvdState.from_dense(x)          # full paper state
        >>> full.shape, full.rank, full.is_full
        ((3, 4), 3, True)
        >>> tr = SvdState.from_dense(x, rank=2)    # truncated streaming state
        >>> tr.rank, tr.is_full
        (2, False)
        >>> bool(np.allclose(tr.materialize(), x, atol=1e-8))
        True
        """
        x = jnp.asarray(x)
        if x.ndim != 2:
            raise ValueError(f"from_dense expects a 2-D matrix; got {x.shape}")
        m, n = x.shape
        if rank is None:
            if m > n:
                raise ValueError(
                    "full SvdState requires m <= n; transpose the problem "
                    "(the paper's convention) or pass rank= for a truncated state"
                )
            u, s, vt = jnp.linalg.svd(x, full_matrices=True)
            return cls(u=u, s=s, v=vt.T, mesh=mesh)
        if rank > min(m, n):
            raise ValueError(f"rank {rank} exceeds min(m, n) = {min(m, n)}")
        u, s, vt = jnp.linalg.svd(x, full_matrices=False)
        return cls(u=u[:, :rank], s=s[:rank], v=vt[:rank].T, mesh=mesh)

    @classmethod
    def from_factors(cls, u, s, v, *, mesh: Any = None) -> "SvdState":
        """Wrap existing factors (full or truncated, stacked or single).

        ``v`` is the matrix of right singular vectors as COLUMNS — pass
        ``vt.T`` if the factors come from ``np.linalg.svd``:

        >>> import numpy as np
        >>> from repro.api import SvdState
        >>> u, s, vt = np.linalg.svd(np.eye(3, 5))
        >>> st = SvdState.from_factors(u, s, vt.T)
        >>> st.shape, st.is_full
        ((3, 5), True)
        >>> stacked = SvdState.from_factors(u[None], s[None], vt.T[None])
        >>> stacked.is_batched, stacked.batch    # leading axis = B problems
        (True, 1)
        """
        u, s, v = jnp.asarray(u), jnp.asarray(s), jnp.asarray(v)
        if u.ndim != v.ndim or u.ndim != s.ndim + 1 or u.ndim not in (2, 3):
            raise ValueError(
                f"inconsistent factor ranks: u {u.shape}, s {s.shape}, v {v.shape}"
            )
        # u always carries one column per singular value (full states have
        # len(s) == m == u columns); only v gets the square exemption (full
        # states: v (n, n) against s (m,))
        if u.shape[-1] != s.shape[-1]:
            raise ValueError(
                f"u has {u.shape[-1]} columns but s carries {s.shape[-1]} values"
            )
        if v.shape[-1] != s.shape[-1] and v.shape[-1] != v.shape[-2]:
            raise ValueError(
                f"v has {v.shape[-1]} columns but s carries {s.shape[-1]} values "
                f"(did you pass vt from np.linalg.svd instead of v = vt.T?)"
            )
        return cls(u=u, s=s, v=v, mesh=mesh)

    # -- transforms ---------------------------------------------------------

    def replace(self, **kw) -> "SvdState":
        return dataclasses.replace(self, **kw)

    def truncate(self, rank: int) -> "SvdState":
        """Keep the top-``rank`` triplets (drops eigen diagnostics).

        >>> import numpy as np
        >>> from repro.api import SvdState
        >>> st = SvdState.from_dense(np.eye(4, 6), rank=3)
        >>> st.truncate(2).rank
        2
        """
        if rank > self.rank:
            raise ValueError(f"cannot truncate rank {self.rank} state to {rank}")
        return SvdState(
            u=self.u[..., :, :rank],
            s=self.s[..., :rank],
            v=self.v[..., :, :rank],
            mesh=self.mesh,
        )

    def materialize(self) -> jax.Array:
        """Dense ``A = u @ diag(s) @ v_k^T`` (full states use ``v[:, :m]``).

        >>> import numpy as np
        >>> from repro.api import SvdState
        >>> x = np.array([[2.0, 0.0, 0.0], [0.0, 1.0, 0.0]])
        >>> bool(np.allclose(SvdState.from_dense(x).materialize(), x))
        True
        """
        v = self.v[..., :, : self.rank]
        return jnp.einsum("...mk,...k,...nk->...mn", self.u, self.s, v)


def like_container(tmpl, u, s, v):
    """Rebuild ``(u, s, v)`` factors in the container type of ``tmpl``
    (``SvdState`` or legacy ``TruncatedSvd``) — pytree structure (shard_map
    spec trees, checkpoints) is caller-owned, so layers that transform a
    caller-supplied container must hand the same type back."""
    return type(tmpl)(u, s, v)


def as_state(obj) -> SvdState:
    """Coerce any SVD container (``SvdState``, ``TruncatedSvd``,
    ``SvdUpdateResult``, or a plain ``(u, s, v)`` triple) to ``SvdState``.

    >>> import numpy as np
    >>> from repro.api import as_state
    >>> st = as_state((np.eye(3), np.ones(3), np.eye(4)[:, :3]))
    >>> (st.m, st.n, st.rank)
    (3, 4, 3)
    """
    if isinstance(obj, SvdState):
        return obj
    u = getattr(obj, "u", None)
    if u is not None:
        return SvdState(
            u=obj.u,
            s=obj.s,
            v=obj.v,
            d_left=getattr(obj, "d_left", None),
            d_right=getattr(obj, "d_right", None),
        )
    u, s, v = obj
    return SvdState.from_factors(u, s, v)
