"""Rank-1 SVD update (paper Algorithm 6.1) and the streaming truncated variant.

Given A = U diag(s) V^T (m <= n, U: m x m, V: n x n, s: (m,)) and vectors
a (m,), b (n,), computes the SVD of  A + a b^T  in O(n^2 log(1/eps)):

  STEP 1   b~ = A b, a~ = A^T a, beta = b^T b, alpha = a^T a
  STEP 2/3 2x2 Schur of [[beta,1],[1,0]] / [[alpha,1],[1,0]] — analytic;
           the eigenvalues are rho_12 = beta/2 ± sqrt(beta^2/4 + 1), so one is
           always positive and one always negative (static signs).
  STEP 4-7 four diagonal-plus-rank-1 eigen-updates (core.eigh_update): two for
           the left subspace (A A^T + ...), two for the right (A^T A + ...).
  STEP 8   singular values = sqrt of updated eigenvalues.

Additions over the paper (see DESIGN.md §1): Loewner reweighting + deflation
live in eigh_update; a structured O(n^2 p) sign fix restores
U_n diag(s_n) V_n[:, :m]^T ≈ A + a b^T (the paper computes left/right updates
independently and never reconciles signs).

This module is implementation: the unjitted, vmap-clean bodies
(``_svd_update_impl`` / ``_svd_update_truncated_impl``) that
``core.engine.SvdEngine`` jits/vmaps, plus the two result containers.  The
public entry point for every update path is ``repro.api.update`` (DESIGN.md
§8); the pre-api module-level call shapes were removed after the migration.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.eigh_update import apply_update, eigenvalues, make_plan, materialize_q

__all__ = ["SvdUpdateResult", "TruncatedSvd"]


class SvdUpdateResult(NamedTuple):
    u: jax.Array       # (m, m) updated left singular vectors
    s: jax.Array       # (m,)  updated singular values, descending
    v: jax.Array       # (n, n) updated right singular vectors
    # diagnostics
    d_left: jax.Array  # (m,) eigenvalues of (A+ab^T)(A+ab^T)^T, descending
    d_right: jax.Array # (n,) eigenvalues of (A+ab^T)^T(A+ab^T), descending


def _rank2_symmetric_split(beta):
    """Analytic Schur of [[beta, 1], [1, 0]] (paper STEP 2/3).

    Returns (rho_pos, rho_neg, q_pos, q_neg): eigenvalues (one positive, one
    negative — det = -1) and unit eigenvectors [rho_i, 1]/sqrt(1+rho_i^2).
    """
    h = 0.5 * beta
    r = jnp.sqrt(h * h + 1.0)
    rho_pos = h + r
    rho_neg = h - r
    n_pos = jnp.sqrt(1.0 + rho_pos * rho_pos)
    n_neg = jnp.sqrt(1.0 + rho_neg * rho_neg)
    q_pos = jnp.stack([rho_pos, 1.0]) / n_pos
    q_neg = jnp.stack([rho_neg, 1.0]) / n_neg
    return rho_pos, rho_neg, q_pos, q_neg


def _double_update(q0, d0, w1, w2, rho_pos, rho_neg, *, method, fmm_p, want_g,
                   deflate_rtol=None):
    """Two chained symmetric rank-1 eigen-updates of Q0 diag(d0) Q0^T.

    Returns (d_final ascending, Q_final, G) with Q_final = Q0 @ G and G
    materialized only when ``want_g`` (used by the sign fix).
    """
    build_fmm = method == "fmm"
    z1 = q0.T @ w1
    plan1 = make_plan(d0, z1, rho_pos, rho_positive=True, build_fmm=build_fmm, fmm_p=fmm_p,
                      deflate_rtol=deflate_rtol)
    q1 = apply_update(plan1, q0, method=method)
    d1 = eigenvalues(plan1)

    z2 = q1.T @ w2
    plan2 = make_plan(d1, z2, rho_neg, rho_positive=False, build_fmm=build_fmm, fmm_p=fmm_p,
                      deflate_rtol=deflate_rtol)
    q2 = apply_update(plan2, q1, method=method)
    d2 = eigenvalues(plan2)

    g = None
    if want_g:
        g1 = materialize_q(plan1, method=method)
        g = apply_update(plan2, g1, method=method)
    return d2, q2, g


def _svd_update_impl(
    u: jax.Array,
    s: jax.Array,
    v: jax.Array,
    a: jax.Array,
    b: jax.Array,
    *,
    method: str = "direct",
    fmm_p: int = 20,
    sign_fix: bool = True,
    deflate_rtol: float | None = None,
    compute_dtype=None,
) -> SvdUpdateResult:
    """Unjitted Algorithm 6.1 body — pure, static-shape, and vmap-clean.

    ``core.engine`` maps this over a leading batch axis; ``svd_update`` is the
    jitted single-instance wrapper.  ``compute_dtype`` (mixed precision):
    inputs may be stored narrower (bf16) — the fused route upcasts inside the
    kernel, the phase-chain routes upcast here and cast results back.
    """
    m = u.shape[0]
    n = v.shape[0]
    if m > n:
        raise ValueError("svd_update expects m <= n; transpose the problem (swap u/v, a/b).")

    if method == "fused":
        # one-kernel route: whole update resident (kernels.fused_update);
        # the storage->compute cast happens inside the body/kernel.
        from repro.kernels import ops as _kops

        out = _kops.fused_update(u, s, v, a, b, sign_fix=sign_fix,
                                 deflate_rtol=deflate_rtol,
                                 compute_dtype=compute_dtype)
        return SvdUpdateResult(u=out[0], s=out[1], v=out[2],
                               d_left=out[3], d_right=out[4])

    store_dt = u.dtype
    if compute_dtype is not None and jnp.dtype(compute_dtype) != store_dt:
        cdt = jnp.dtype(compute_dtype)
        res = _svd_update_impl(
            u.astype(cdt), s.astype(cdt), v.astype(cdt),
            a.astype(cdt), b.astype(cdt),
            method=method, fmm_p=fmm_p, sign_fix=sign_fix,
            deflate_rtol=deflate_rtol,
        )
        return SvdUpdateResult(*(x.astype(store_dt) for x in res))

    dt = u.dtype
    s = s.astype(dt)

    # STEP 1 — structured products (A never materialized)
    vtb = v.T @ b                                     # (n,)
    b_t = u @ (s * vtb[:m])                           # b~ = A b        (m,)
    uta = u.T @ a                                     # (m,)
    a_t = v @ jnp.concatenate([s * uta, jnp.zeros((n - m,), dt)])  # a~ = A^T a (n,)
    beta = jnp.dot(b, b)
    alpha = jnp.dot(a, a)

    d_u = s * s                                       # (m,)
    d_v = jnp.concatenate([s * s, jnp.zeros((n - m,), dt)])  # (n,)

    # STEP 2 — left split:  b~ a^T + a b~^T + beta a a^T
    rho1, rho2, qp, qn = _rank2_symmetric_split(beta)
    a1 = qp[0] * a + qp[1] * b_t
    b1 = qn[0] * a + qn[1] * b_t

    # STEP 3 — right split:  a~ b^T + b a~^T + alpha b b^T
    rho3, rho4, qp_v, qn_v = _rank2_symmetric_split(alpha)
    a2 = qp_v[0] * b + qp_v[1] * a_t
    b2 = qn_v[0] * b + qn_v[1] * a_t

    # STEPS 4-7 — chained eigen-updates
    d_left, u_n, g_u = _double_update(
        u, d_u, a1, b1, rho1, rho2, method=method, fmm_p=fmm_p, want_g=sign_fix,
        deflate_rtol=deflate_rtol,
    )
    d_right, v_n, g_v = _double_update(
        v, d_v, a2, b2, rho3, rho4, method=method, fmm_p=fmm_p, want_g=sign_fix,
        deflate_rtol=deflate_rtol,
    )

    # STEP 8 — singular values, descending order
    ord_l = jnp.argsort(-d_left)
    ord_r = jnp.argsort(-d_right)
    d_left_s = d_left[ord_l]
    d_right_s = d_right[ord_r]
    u_n = u_n[:, ord_l]
    v_n = v_n[:, ord_r]
    s_n = jnp.sqrt(jnp.clip(d_left_s, 0.0, None))

    if sign_fix:
        # diag_i = u_i^T (A + a b^T) v_i computed from the structured factors:
        #   = sum_k s_k G_u[k, i] G_v[k, i] + (a^T u_i)(b^T v_i)
        g_u = g_u[:, ord_l]
        g_v = g_v[:, ord_r]
        core = jnp.einsum("k,ki,ki->i", s, g_u, g_v[:m, :m])
        au = uta @ g_u                                 # a^T U G_u  (m,)
        bv = vtb @ g_v[:, :m]                          # b^T V G_v  (m,)
        diag = core + au * bv
        flip = jnp.where(diag < 0, -1.0, 1.0).astype(dt)
        v_n = v_n.at[:, :m].multiply(flip[None, :])

    return SvdUpdateResult(u=u_n, s=s_n, v=v_n, d_left=d_left_s, d_right=d_right_s)


# ---------------------------------------------------------------------------
# Streaming truncated rank-1 SVD update (Brand augmentation + Algorithm 6.1)
# ---------------------------------------------------------------------------


class TruncatedSvd(NamedTuple):
    u: jax.Array  # (m, r)
    s: jax.Array  # (r,) descending
    v: jax.Array  # (n, r)


def _svd_update_truncated_impl(
    tsvd: TruncatedSvd,
    a: jax.Array,
    b: jax.Array,
    *,
    method: str = "direct",
    fmm_p: int = 20,
    deflate_rtol: float | None = None,
    compute_dtype=None,
) -> TruncatedSvd:
    """Unjitted truncated-update body (vmap-clean, see ``core.engine``).

    Accepts any (u, s, v)-carrying container (``TruncatedSvd`` or an
    ``repro.api.SvdState``); returns ``TruncatedSvd``."""
    u, s, v = tsvd.u, tsvd.s, tsvd.v

    if method == "fused":
        from repro.kernels import ops as _kops

        out = _kops.fused_update_truncated(u, s, v, a, b,
                                           deflate_rtol=deflate_rtol,
                                           compute_dtype=compute_dtype)
        return TruncatedSvd(u=out[0], s=out[1], v=out[2])

    if compute_dtype is not None and jnp.dtype(compute_dtype) != u.dtype:
        cdt = jnp.dtype(compute_dtype)
        store_dt = u.dtype
        res = _svd_update_truncated_impl(
            TruncatedSvd(u.astype(cdt), s.astype(cdt), v.astype(cdt)),
            a.astype(cdt), b.astype(cdt),
            method=method, fmm_p=fmm_p, deflate_rtol=deflate_rtol,
        )
        return TruncatedSvd(*(x.astype(store_dt) for x in res))

    m, r = u.shape
    n = v.shape[0]
    dt = u.dtype

    p_vec = u.T @ a
    a_perp = a - u @ p_vec
    ra = jnp.linalg.norm(a_perp)
    safe_ra = jnp.where(ra > 1e-12, ra, 1.0)
    p_unit = jnp.where(ra > 1e-12, a_perp / safe_ra, 0.0)
    ra = jnp.where(ra > 1e-12, ra, 0.0)

    q_vec = v.T @ b
    b_perp = b - v @ q_vec
    rb = jnp.linalg.norm(b_perp)
    safe_rb = jnp.where(rb > 1e-12, rb, 1.0)
    q_unit = jnp.where(rb > 1e-12, b_perp / safe_rb, 0.0)
    rb = jnp.where(rb > 1e-12, rb, 0.0)

    # K = diag([s, 0]) + [p; ra] [q; rb]^T   of size (r+1, r+1)
    s_aug = jnp.concatenate([s, jnp.zeros((1,), dt)])
    ak = jnp.concatenate([p_vec, ra[None]])
    bk = jnp.concatenate([q_vec, rb[None]])
    eye = jnp.eye(r + 1, dtype=dt)
    res = _svd_update_impl(eye, s_aug, eye, ak, bk, method=method, fmm_p=fmm_p,
                           sign_fix=True, deflate_rtol=deflate_rtol)

    u_aug = jnp.concatenate([u, p_unit[:, None]], axis=1)   # (m, r+1)
    v_aug = jnp.concatenate([v, q_unit[:, None]], axis=1)   # (n, r+1)
    u_new = u_aug @ res.u[:, :r]
    v_new = v_aug @ res.v[:, :r]
    return TruncatedSvd(u=u_new, s=res.s[:r], v=v_new)
