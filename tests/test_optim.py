"""Optimizer substrate: AdamW, spectral projection, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.compression import (
    compression_init,
    compress_decompress,
    wire_bytes,
)
from repro.optim.schedule import warmup_cosine
from repro.optim.spectral import project, spectral_init, spectral_update_basis, unproject

RNG = np.random.default_rng(0)


def test_adamw_optimizes_quadratic():
    target = jnp.asarray(RNG.normal(size=(4, 4)))
    params = {"w": jnp.zeros((4, 4))}

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2)

    state = adamw_init(params)
    for _ in range(300):
        grads = jax.grad(loss_fn)(params)
        params, state, _ = adamw_update(grads, state, params, lr=5e-2, weight_decay=0.0)
    assert float(loss_fn(params)) < 1e-2


def test_adamw_grad_clip():
    params = {"w": jnp.zeros((3,))}
    state = adamw_init(params)
    grads = {"w": jnp.full((3,), 1e6)}
    _, _, gnorm = adamw_update(grads, state, params, lr=1e-3, grad_clip=1.0)
    assert float(gnorm) > 1e5  # reported norm is pre-clip


def test_schedule_warmup_and_decay():
    lrs = [float(warmup_cosine(jnp.asarray(s), base_lr=1.0, warmup_steps=10,
                               total_steps=100)) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < 1.0
    assert lrs[4] >= 0.1 - 1e-6  # min_ratio floor


def test_spectral_tracker_finds_dominant_subspace():
    """Feed gradients living in a fixed rank-2 subspace; after a few updates
    the streaming-SVD basis must capture it (projection preserves energy)."""
    m, n, r = 32, 24, 4
    basis_u = np.linalg.qr(RNG.normal(size=(m, 2)))[0]
    basis_v = np.linalg.qr(RNG.normal(size=(n, 2)))[0]
    state = spectral_init(jax.random.PRNGKey(0), m, n, r)
    for i in range(25):
        coeffs = RNG.normal(size=(2, 2))
        g = jnp.asarray(basis_u @ coeffs @ basis_v.T)
        state = spectral_update_basis(state, g)
    g = jnp.asarray(basis_u @ RNG.normal(size=(2, 2)) @ basis_v.T)
    gp = project(state, g)
    g_back = unproject(state, gp)
    rel = float(jnp.linalg.norm(g_back - g) / jnp.linalg.norm(g))
    assert rel < 0.05, f"projection loses {rel:.1%} of in-subspace gradient"


def test_spectral_moment_memory_shrinks():
    m, n, r = 1024, 512, 16
    dense = 2 * m * n
    projected = 2 * r * n + (m + n + 1) * r  # moments + tracker
    assert projected < dense / 10


def test_compression_error_feedback_converges():
    """With error feedback, repeated compression of a CONSTANT gradient must
    transmit it fully over time (sum of g_hat -> k*g)."""
    m, n, r = 24, 16, 2
    g = jnp.asarray(RNG.normal(size=(m, n)))
    state = compression_init(jax.random.PRNGKey(0), m, n, r)
    acc = jnp.zeros_like(g)
    k = 60
    for _ in range(k):
        g_hat, state = compress_decompress(state, g)
        acc = acc + g_hat
    rel = float(jnp.linalg.norm(acc / k - g) / jnp.linalg.norm(g))
    assert rel < 0.1, f"error feedback leaves {rel:.1%} untransmitted"


def test_compression_exact_for_low_rank_grad():
    """rank(g) < r: the PowerSGD projection P P^T g reconstructs g exactly
    on the very first call (span(gV) = col(g) w.p. 1 for random V)."""
    m, n, r = 30, 20, 4
    u = RNG.normal(size=(m, r - 1))
    v = RNG.normal(size=(n, r - 1))
    g = jnp.asarray(u @ v.T)
    state = compression_init(jax.random.PRNGKey(1), m, n, r)
    g_hat, state = compress_decompress(state, g)
    rel = float(jnp.linalg.norm(g_hat - g) / jnp.linalg.norm(g))
    assert rel < 1e-5
    # and the error-feedback buffer is correspondingly empty
    assert float(jnp.linalg.norm(state.error)) < 1e-5 * float(jnp.linalg.norm(g))


def test_wire_bytes_ratio():
    wb = wire_bytes(8192, 8192, 64)
    assert wb["ratio"] > 60  # >60x smaller DP all-reduce payload
