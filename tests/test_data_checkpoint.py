"""Data determinism + checkpoint atomicity/resume (fault-tolerance substrate)."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.synthetic import batch_for_step, host_slice_for_step
from repro.train import checkpoint as ckpt


def test_data_restart_exact():
    a = batch_for_step(0, 17, batch=8, seq=32, vocab=100)
    b = batch_for_step(0, 17, batch=8, seq=32, vocab=100)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_data_steps_differ():
    a = batch_for_step(0, 1, batch=8, seq=32, vocab=100)
    b = batch_for_step(0, 2, batch=8, seq=32, vocab=100)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_data_host_sharding_consistent():
    """Union of rank slices == global batch (shardable pipeline contract)."""
    full = batch_for_step(3, 5, batch=8, seq=16, vocab=50)
    parts = [host_slice_for_step(3, 5, batch=8, seq=16, vocab=50, rank=r, world=4)
             for r in range(4)]
    merged = np.concatenate([np.asarray(p["tokens"]) for p in parts], axis=0)
    np.testing.assert_array_equal(merged, np.asarray(full["tokens"]))


def test_data_labels_are_shifted_tokens():
    d = batch_for_step(0, 0, batch=4, seq=16, vocab=64)
    assert d["tokens"].shape == (4, 16)
    assert d["labels"].shape == (4, 16)
    assert int(jnp.max(d["tokens"])) < 64


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "w": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(5, dtype=jnp.float32), "step": jnp.asarray(3)},
    }


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(tmp_path, 10, tree)
    step, restored = ckpt.restore(tmp_path, tree)
    assert step == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_and_gc(tmp_path):
    tree = _tree()
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(tmp_path, s, tree, keep=3)
    assert ckpt.latest_step(tmp_path) == 5
    assert sorted(ckpt.available_steps(tmp_path)) == [3, 4, 5]


def test_checkpoint_torn_write_ignored(tmp_path):
    """A crash mid-write must leave the previous checkpoint authoritative."""
    tree = _tree()
    ckpt.save(tmp_path, 7, tree)
    # simulate a torn write: step dir without a complete manifest
    torn = tmp_path / "step_000000008"
    torn.mkdir()
    (torn / "arrays.npz").write_bytes(b"garbage")
    assert ckpt.latest_step(tmp_path) == 7
    step, _ = ckpt.restore(tmp_path, tree)
    assert step == 7


def test_checkpoint_checksum_validation(tmp_path):
    tree = _tree()
    d = ckpt.save(tmp_path, 3, tree)
    # corrupt the arrays post-manifest
    data = (d / "arrays.npz").read_bytes()
    (d / "arrays.npz").write_bytes(data[:-10] + b"corruption")
    with pytest.raises(IOError):
        ckpt.restore(tmp_path, tree, 3)


def test_checkpoint_incompatible_structure_rejected(tmp_path):
    ckpt.save(tmp_path, 1, _tree())
    wrong = {"only_one_leaf": jnp.zeros(3)}
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, wrong, 1)


@settings(max_examples=10, deadline=None)
@given(
    shapes=st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5)), min_size=1, max_size=4),
    step=st.integers(0, 10_000),
)
def test_property_checkpoint_roundtrip_any_tree(tmp_path_factory, shapes, step):
    tmp_path = tmp_path_factory.mktemp("ckpt")
    tree = {f"leaf{i}": jnp.full(s, float(i)) for i, s in enumerate(shapes)}
    ckpt.save(tmp_path, step, tree)
    got_step, restored = ckpt.restore(tmp_path, tree)
    assert got_step == step
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
