"""Streaming rank-1 SVD-update service: async micro-batched engine flushes,
checkpointable to disk (DESIGN.md §9).

The serving story for the paper's machinery: many concurrent streams (one
per user/session/adapter) each own a truncated ``repro.api.SvdState`` that
evolves by rank-1 updates — personalization vectors folding into low-rank
adapters, per-tenant gradient sketches, online covariance trackers. Issuing
those updates one at a time wastes the hardware; this service queues them
and flushes *one batched engine call* per round:

    svc = SvdService(max_batch=64, policy=UpdatePolicy(method="auto"))
    svc.register("user-1", api.SvdState.from_dense(m1, rank=8))
    svc.enqueue("user-1", a, b)        # cheap: just queues
    svc.enqueue("user-2", a2, b2)
    svc.enqueue_op("user-1", RankK(u_blk, v_blk))   # structured: rank-k bucket
    svc.enqueue_op("user-2", AppendRows(new_rows))  # growing matrix event
    svc.flush()                        # one batched truncated update
    svc.save("/ckpts/svd", step=1)     # versioned snapshot; survives restart

* Structured events (``repro.updates`` ops): ``enqueue_op`` lowers
  geometry-preserving ops (``RankK``, ``DenseDelta``, ``Compose`` of them)
  into the pair FIFO — a rank-k op becomes a k-deep flush bucket whose
  steps batch with other streams' heads (``DenseDelta`` sketches through
  the planner's shared ``op_low_rank_factors`` range-finder — serve and
  planner can never drift) — while geometry-changing appends and ``Decay``
  folds stay whole and apply through the planner at flush.  ``Sparse``
  events stay whole too (snapshots carry their COO leaves bitwise) but
  expand into their rank-1 pairs at the head of a flush round — the
  deterministic sketch makes pre/post-snapshot expansion bitwise identical
  — so sparse events batch into rounds like every other pair.  Downdates
  (``RemoveRows``/``RemoveCols``/``Window``) stay whole like appends —
  geometry-shrinking, validated against the stream's effective shape at
  enqueue, planned onto the rank-1 engine at flush (GDPR-style "forget
  these rows now" across per-user streams).
  Snapshots (v3+) carry ops bitwise (``pending_ops``/``pending_order``).
* Cold-start control: every flush records its ``(kind, geometry)`` in the
  warmed set; snapshots persist it and ``restore`` eagerly ``api.warmup``s
  each entry, so the first post-failover flush never compiles under
  traffic.

* Per-stream ordering: a stream's queued pairs are applied in FIFO order;
  each flush round takes at most one pending pair per stream (they are
  sequential updates to the same state, so they cannot share a batch).
* Micro-batching: ``enqueue`` auto-flushes once ``max_batch`` streams have
  a pending pair. Batches are padded up to bucket sizes (powers of two) so
  the engine's plan cache sees a handful of geometries, not every B.
* Async double-buffered flushing: a flush round *dispatches* its batched
  engine call and returns — stream states become JAX async futures and the
  host keeps enqueueing while the device computes. Dispatched rounds are
  tracked in an in-flight buffer; once ``max_in_flight`` rounds are
  outstanding, the next round first blocks on the oldest (backpressure),
  so the host can never run unboundedly ahead of the device.
  ``jax.block_until_ready`` is otherwise only issued at the explicit
  barriers: ``drain()`` and ``snapshot()``.
* Checkpointing: ``snapshot()`` captures the whole service — every stream's
  ``SvdState``, every pending FIFO, the policy and the batching config — as
  a versioned ``ServiceSnapshot`` pytree; ``save``/``restore`` persist it
  through ``train.checkpoint`` (atomic, checksummed, self-describing via
  the aux spec). Restore is **exact**: a restored service produces bitwise
  the same factors as one that never stopped (DESIGN.md §9 contract,
  ``tests/test_serve_checkpoint.py``).
* Policy: an ``UpdatePolicy`` names the numerics (method/fmm_p/...) and the
  placement — ``policy.mesh`` spreads every flush's batch axis over the
  mesh via the engine's shard_map dispatch.  A legacy ``engine=`` override
  wins over the policy-derived engine.  The mesh is *runtime placement*,
  not state: snapshots record that a mesh was set but never serialize it —
  pass ``mesh=`` (or a full ``policy=``) to ``restore`` on the new topology.
* Multi-worker: per-worker shard streams combine into one global truncated
  SVD via ``merge_streams`` (the ``repro.dist.merge`` log-depth tree).

The LM engine (``serve.engine``) serves tokens; this serves spectra.
"""

from __future__ import annotations

import dataclasses
import threading
import warnings
from collections import OrderedDict, deque
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as _obs
from repro.api import SvdState, UpdatePolicy, as_state
from repro.api.update import engine_from_key, warmup as _api_warmup
from repro.core.engine import (
    SvdEngine,
    group_indices,
    stack_trees,
    truncated_geometry,
    unstack_tree,
)
from repro.core.svd_update import TruncatedSvd
from repro.dist.merge import merge_tree
from repro.train import checkpoint as _checkpoint
from repro.updates import ops as _ops
from repro.updates import planner as _planner
from repro.updates import sketch as _sketch

__all__ = [
    "SNAPSHOT_VERSION",
    "ServiceSnapshot",
    "SvdService",
    "SvdServiceStats",
]

# v4 and v6 are NOT service formats: the fleet tier's FleetSnapshot (which
# embeds per-shard ServiceSnapshots) took them on the shared version line —
# see ``repro.fleet.fleet.FLEET_SNAPSHOT_VERSION`` and DESIGN.md §14's table.
# v7 (current) added the ``obs_metrics`` registry capture (DESIGN.md §15).
SNAPSHOT_VERSION = 7
_SNAPSHOT_FORMAT = "repro.serve.ServiceSnapshot"

# UpdatePolicy fields a snapshot records verbatim. ``mesh`` is deliberately
# absent: it names live devices of THIS process; the restoring process
# supplies its own (see module doc).
_POLICY_SPEC_FIELDS = (
    "method",
    "fmm_p",
    "sign_fix",
    "deflate_rtol",
    "precision",
    "storage_dtype",
    "sketch_oversample",
    "sketch_power_iters",
    "batch_axis",
    "truncate_to",
    "health_every",
)

# policy fields added after SNAPSHOT_VERSION was minted: old snapshots lack
# them, so restore falls back to each field's UpdatePolicy default
_POLICY_SPEC_DEFAULTS = {
    "storage_dtype": None,
    "sketch_oversample": 8,
    "sketch_power_iters": 1,
    "health_every": None,
}


def _obs_rows(rows) -> tuple:
    """Re-hash registry snapshot rows after a JSON round trip (the aux spec
    turns tuples into lists; pytree metadata must be hashable)."""
    return tuple(
        (name, tuple((str(k), str(v)) for k, v in labels), kind, state)
        for name, labels, kind, state in rows
    )


def _policy_spec(policy: UpdatePolicy) -> dict:
    spec = {f: getattr(policy, f) for f in _POLICY_SPEC_FIELDS}
    if spec["storage_dtype"] is not None:
        spec["storage_dtype"] = np.dtype(spec["storage_dtype"]).name
    spec["had_mesh"] = policy.mesh is not None
    return spec


def _policy_from_spec(spec: dict, mesh=None) -> UpdatePolicy:
    kw = {
        f: spec.get(f, _POLICY_SPEC_DEFAULTS.get(f))
        for f in _POLICY_SPEC_FIELDS
    }
    return UpdatePolicy(mesh=mesh, **kw)


@dataclass
class SvdServiceStats:
    enqueued: int = 0
    applied: int = 0
    flushes: int = 0
    rounds: int = 0          # batched engine calls (one per geometry group)
    max_batch: int = 0       # largest batch (incl. bucket padding) dispatched
    backpressure_waits: int = 0   # rounds that had to wait for an older one
    in_flight_peak: int = 0       # most rounds ever outstanding at once
    ops_applied: int = 0          # structured (non-pair) events applied
    scan_rounds: int = 0          # depth-batched (rank-k scan) engine calls
    max_depth: int = 0            # deepest scan column ever dispatched


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["states", "pending_a", "pending_b", "pending_ops"],
    meta_fields=[
        "version",
        "stream_ids",
        "policy_spec",
        "max_batch",
        "pad_to_bucket",
        "max_in_flight",
        "stats",
        "pending_order",
        "warmed",
        "obs_metrics",
    ],
)
@dataclasses.dataclass(frozen=True)
class ServiceSnapshot:
    """Versioned, self-describing capture of a whole ``SvdService``.

    A registered pytree: the array leaves are every stream's (u, s, v)
    factors plus its pending FIFO — rank-1 pairs stacked as two ``(k_i, m)``
    / ``(k_i, n)`` arrays, structured events (``repro.updates`` ops: decay,
    appends) as op pytrees in ``pending_ops``, with ``pending_order`` (one
    ``"p"``/``"o"`` marker string per stream) recording how pairs and ops
    interleave in FIFO order.  Everything non-array — stream ids, the policy
    spec, bucket/backpressure config, stats counters, the warmed
    ``(kind, geometry)`` set — is pytree metadata, mirrored into the JSON
    ``aux`` spec so a fresh process can rebuild the tree structure before it
    has loaded a single array (``skeleton``; op structure rebuilds through
    ``repro.updates.ops.skeleton_from_spec``).

    Versioning: ``version`` is written into both the pytree and the aux
    spec; ``load`` refuses snapshots newer than this build understands and
    upgrades older ones in place.  v1 -> v2 added ``pending_ops`` /
    ``pending_order`` / ``warmed``; v1 snapshots (all-pair FIFOs, nothing
    warmed) load as v2 with the empty defaults — their leaf list is
    unchanged, so restore stays bitwise.  v2 -> v3 added ``Sparse`` op
    events (their COO leaves ride ``pending_ops`` bitwise), the sketch
    policy knobs in ``policy_spec``, and ``sketch_*`` warmed kinds — no
    structural change, so v1/v2 snapshots load as v3 unchanged (the sketch
    knobs fall back to their ``UpdatePolicy`` defaults); the bump exists so
    pre-sparse builds refuse v3 snapshots cleanly instead of failing inside
    ``skeleton_from_spec``.  v3 -> v5 added downdate op events
    (``RemoveRows``/``RemoveCols``/``Window``) riding ``pending_ops`` —
    Remove ops are pure metadata (zero leaves; indices live in the aux
    spec), ``Window`` carries its ``lam`` leaf — again no structural change,
    so v1–v3 snapshots load unchanged; pre-downdate builds refuse v5
    cleanly.  v4 was never a service format (the fleet tier's
    ``FleetSnapshot`` took it on the shared version line), so the service
    skips from 3 to 5.  v5 -> v7 added ``obs_metrics`` — a
    ``repro.obs.MetricsRegistry.snapshot()`` capture (hashable metadata,
    zero array leaves, empty when obs is disabled) so telemetry counters
    survive failover exactly like the stats counters do; v1–v5 snapshots
    load with the empty default, and v6 was the fleet tier's again.
    """

    states: tuple          # tuple[SvdState, ...] — diagnostics-free, per stream
    pending_a: tuple       # tuple[(k_i, m_i) array, ...] queued a-vectors, FIFO
    pending_b: tuple       # tuple[(k_i, n_i) array, ...] queued b-vectors, FIFO
    pending_ops: tuple = ()   # tuple[tuple[UpdateOp, ...], ...] per stream, FIFO
    version: int = SNAPSHOT_VERSION
    stream_ids: tuple = ()
    policy_spec: tuple = ()   # tuple of (field, value) pairs (hashable meta)
    max_batch: int = 64
    pad_to_bucket: bool = True
    max_in_flight: int = 2
    stats: tuple = ()         # SvdServiceStats counters as (name, value) pairs
    pending_order: tuple = () # per stream: "p"/"o" markers in FIFO order
    warmed: tuple = ()        # (kind, batch, m, n, rank, dtype_str) tuples
    obs_metrics: tuple = ()   # MetricsRegistry.snapshot() rows (v7+; hashable)

    def aux(self) -> dict:
        """The JSON spec persisted next to the arrays (checkpoint ``aux=``)."""
        return {
            "format": _SNAPSHOT_FORMAT,
            "version": self.version,
            "stream_ids": list(self.stream_ids),
            "policy": dict(self.policy_spec),
            "max_batch": self.max_batch,
            "pad_to_bucket": self.pad_to_bucket,
            "max_in_flight": self.max_in_flight,
            "stats": dict(self.stats),
            "pending_order": list(self.pending_order),
            "pending_ops": [
                [_ops.spec_to_json(op.spec()) for op in stream_ops]
                for stream_ops in self.pending_ops
            ],
            "warmed": [list(w) for w in self.warmed],
            "obs_metrics": [list(r) for r in self.obs_metrics],
        }

    @classmethod
    def skeleton(cls, aux: dict) -> "ServiceSnapshot":
        """A structure-only snapshot (placeholder leaves) built from an aux
        spec — its treedef is what ``load`` unflattens restored leaves into.

        v1 aux specs (no ``pending_ops``/``pending_order``/``warmed``) get
        the empty defaults: the tree gains no leaves, so v1 leaf lists
        unflatten unchanged (the in-place upgrade path).
        """
        n = len(aux["stream_ids"])
        op_specs = aux.get("pending_ops", [[] for _ in range(n)])
        return cls(
            states=tuple(SvdState(u=0.0, s=0.0, v=0.0) for _ in range(n)),
            pending_a=tuple(0.0 for _ in range(n)),
            pending_b=tuple(0.0 for _ in range(n)),
            pending_ops=tuple(
                tuple(_ops.skeleton_from_spec(_ops.spec_from_json(sp)) for sp in sps)
                for sps in op_specs
            ),
            version=SNAPSHOT_VERSION,
            stream_ids=tuple(aux["stream_ids"]),
            policy_spec=tuple((k, v) for k, v in aux["policy"].items()),
            max_batch=aux["max_batch"],
            pad_to_bucket=aux["pad_to_bucket"],
            max_in_flight=aux["max_in_flight"],
            stats=tuple((k, v) for k, v in aux["stats"].items()),
            pending_order=tuple(aux.get("pending_order", ())),
            warmed=tuple(tuple(w) for w in aux.get("warmed", ())),
            obs_metrics=_obs_rows(aux.get("obs_metrics", ())),
        )

    def save(self, ckpt_dir, step: int, *, keep: int = 3):
        """Persist through ``train.checkpoint`` (atomic + checksummed)."""
        return _checkpoint.save(ckpt_dir, step, self, aux=self.aux())

    @classmethod
    def load(cls, ckpt_dir, step: int | None = None) -> tuple[int, "ServiceSnapshot"]:
        """Load ``(step, snapshot)`` from a checkpoint directory.

        Leaves come back exactly as saved (numpy, bitwise-identical — no
        dtype cast, no device transfer); they join device computation on
        the first flush after restore.
        """
        step, aux = _checkpoint.load_aux(ckpt_dir, step)
        if aux is None or aux.get("format") != _SNAPSHOT_FORMAT:
            raise ValueError(
                f"checkpoint at step {step} is not a ServiceSnapshot "
                f"(aux format: {None if aux is None else aux.get('format')!r})"
            )
        if aux["version"] > SNAPSHOT_VERSION:
            raise ValueError(
                f"snapshot version {aux['version']} is newer than this build "
                f"understands (<= {SNAPSHOT_VERSION})"
            )
        _, leaves = _checkpoint.restore(ckpt_dir, None, step)
        treedef = jax.tree.structure(cls.skeleton(aux))
        return step, jax.tree.unflatten(treedef, leaves)


def _bucket(b: int, cap: int) -> int:
    """Smallest power of two >= b (clamped to cap) — bounds plan-cache size."""
    p = 1
    while p < b:
        p <<= 1
    return min(p, max(cap, 1))


def _depth_bucket(run: int, cap: int) -> int:
    """Largest power of two <= min(run, cap) — the scan depth a stream's
    consecutive-pair backlog dispatches as.  Flooring (not ceiling) keeps
    depth groups exact: a stream never pads its OWN column with no-op pairs
    (scan outputs are kept, so k-padding would have to be bitwise-identity;
    B-padding outputs are discarded, so zero pairs are safe there)."""
    run = min(run, max(cap, 1))
    p = 1
    while p * 2 <= run:
        p <<= 1
    return p


def _is_ready(x) -> bool:
    fn = getattr(x, "is_ready", None)
    return True if fn is None else fn()


class SvdService:
    """Async micro-batching front end over the batched truncated-update
    engine, checkpointable via ``snapshot``/``save``/``restore``."""

    def __init__(
        self,
        *,
        engine: SvdEngine | None = None,
        method: str = "direct",
        max_batch: int = 64,
        pad_to_bucket: bool = True,
        max_in_flight: int = 2,
        policy: UpdatePolicy | None = None,
    ):
        if max_in_flight < 0:
            raise ValueError(f"max_in_flight must be >= 0; got {max_in_flight}")
        self.policy = policy if policy is not None else UpdatePolicy(method=method)
        self.engine = engine            # explicit override; None -> policy-derived
        self.max_batch = max_batch
        self.pad_to_bucket = pad_to_bucket
        # 0 = synchronous (every round blocks before returning — the bench
        # baseline); 1 = single buffer; 2 = double buffering (default): the
        # device computes round k while the host assembles round k+1.
        self.max_in_flight = max_in_flight
        self.stats = SvdServiceStats()
        self._streams: OrderedDict[str, SvdState] = OrderedDict()
        # FIFO of events per stream, each carrying a visibility token:
        # ("pair", a, b, token) | ("op", UpdateOp, token)
        self._pending: dict[str, deque] = {}
        self._eff_shape: dict[str, tuple] = {}   # post-queue (m, n) per stream
        # per dispatched round: (device outputs, tokens the round carried)
        self._in_flight: deque[tuple[list, list]] = deque()
        self._warmed: set[tuple] = set()         # (kind, batch, m, n, r, dtype)
        self._next_token = 0                     # visibility tokens (runtime-only)
        self._visible: list[int] = []            # retired tokens, FIFO, undrained
        self._lock = threading.RLock()
        # observability (repro.obs, DESIGN.md §15): the fleet tier grafts
        # per-shard labels on; the health monitor follows policy.health_every
        self._obs_labels: dict = {}
        self._health: "_obs.HealthMonitor | None" = None
        self._stat_gauges: tuple | None = None   # cached (field, gauge) handles

    # -- visibility tokens ---------------------------------------------------
    #
    # Every enqueued event gets a monotonically increasing token; a token
    # becomes *visible* when the flush round that applied it has retired
    # (its device outputs are concrete).  Enqueue-to-visible is the latency
    # the fleet benchmark reports; the continuous-batching frontend polls
    # ``take_visible`` after every pump.  Tokens are runtime state — they are
    # NOT snapshotted (a restored service issues fresh ones).

    def _issue_token(self) -> int:
        t = self._next_token
        self._next_token += 1
        return t

    def take_visible(self) -> list[int]:
        """Drain and return tokens whose updates are now visible (their
        round retired — or was applied synchronously).  Reaps ready rounds
        first, so polling callers see completions without blocking."""
        with self._lock:
            self._reap_ready()
            out, self._visible = self._visible, []
            return out

    def _engine_for(self, rank: int) -> SvdEngine:
        if self.engine is not None:
            return self.engine
        return engine_from_key(self.policy, rank + 1)

    def _record_warm(self, kind: str, batch, m: int, n: int, r: int, dt) -> None:
        """Track the (kind, geometry) set flushes have compiled — snapshotted
        so ``restore`` can ``api.warmup`` them eagerly before traffic."""
        self._warmed.add((kind, batch, m, n, r, jnp.dtype(dt).name))

    # -- stream lifecycle ---------------------------------------------------

    def register(self, stream_id: str, state) -> None:
        """Create (or replace) a stream with its current truncated SVD
        (any container — coerced to a diagnostics-free ``SvdState``, so
        every stream snapshots to exactly three array leaves).

        Replacing drops any pending pairs — they were queued against the old
        state (and may not even match the new geometry).
        """
        with self._lock:
            st = as_state(state)
            self._streams[stream_id] = SvdState(u=st.u, s=st.s, v=st.v)
            self._pending[stream_id] = deque()
            self._eff_shape[stream_id] = (st.m, st.n)

    def evict(self, stream_id: str) -> SvdState:
        """Drop a stream and return its state with its OWN queue applied.

        Other streams' pending events are left queued — eviction of one user
        must not advance anyone else's state.
        """
        with self._lock:
            state = self._streams[stream_id]
            queue = self._pending.get(stream_id, deque())
            while queue:
                state = self._apply_event(state, queue[0])
                self._token_visible(queue.popleft())
            del self._streams[stream_id]
            self._pending.pop(stream_id, None)
            self._eff_shape.pop(stream_id, None)
            return state

    def _token_visible(self, ev: tuple) -> None:
        """Mark a consumed event's token visible (``None`` = an expanded
        Sparse pair whose op token rides the LAST expanded pair)."""
        if ev[-1] is not None:
            self._visible.append(ev[-1])

    def _apply_one(self, state: SvdState, a, b) -> SvdState:
        eng = self._engine_for(state.rank)
        self._record_warm("trunc", None, state.m, state.n, state.rank, state.dtype)
        t = eng.update_truncated(TruncatedSvd(state.u, state.s, state.v), a, b)
        return SvdState(u=t.u, s=t.s, v=t.v)

    def _apply_event(self, state: SvdState, ev: tuple) -> SvdState:
        """Apply one FIFO event to a single stream's state.

        Counts ``stats.applied``/``stats.ops_applied`` on success; callers
        pop the event from its queue AFTER this returns (failure-atomic:
        a raising engine call leaves the event queued for retry).
        """
        if ev[0] == "pair":
            out = self._apply_one(state, ev[1], ev[2])
            self.stats.applied += 1
            return out
        op = ev[1]
        self._record_op_warm(state, op)
        out = _planner.apply(state, op, self.policy)
        self.stats.applied += 1
        self.stats.ops_applied += 1
        return SvdState(u=out.u, s=out.s, v=out.v)

    def _record_op_warm(self, state: SvdState, op) -> None:
        """Record every single-update geometry an op's schedule dispatches
        (appends shift it mid-schedule) plus every sketch site the lowering
        runs through, so restore warms those too."""
        m, n = state.m, state.n
        for sm, sn, sk, snnz in _planner._sketch_sites(op.spec(), m, n)[0]:
            kind = "sketch_dense" if snnz is None else "sketch_sparse"
            self._record_warm(kind, snnz, sm, sn, sk, state.dtype)
        for step in _planner.lower(op, state, self.policy):
            if step[0] == "pad_rows":
                m += step[1]
            elif step[0] == "pad_cols":
                n += step[1]
            elif step[0] == "drop_rows":
                m -= len(step[1])
            elif step[0] == "drop_cols":
                n -= len(step[1])
            elif step[0] in ("rank1", "rank1_scan"):
                # scan steps dispatch the same truncated geometry (the k-loop
                # is inside the executable), so one warm record covers both
                self._record_warm("trunc", None, m, n, state.rank, state.dtype)

    def _effective_shape(self, stream_id: str) -> tuple[int, int]:
        """Stream geometry AFTER every queued event (appends grow it) — the
        geometry new enqueues must match.  Maintained incrementally: state
        changes and queue drains cancel out, so only ``register`` and
        ``enqueue_op`` ever move it (enqueue stays O(1) at any queue depth).
        """
        return self._eff_shape[stream_id]

    def state(self, stream_id: str) -> SvdState:
        """Current state — pending (unflushed) pairs are NOT yet applied.

        The returned factors may still be in-flight async futures; reading
        their values blocks transparently (JAX async dispatch)."""
        with self._lock:
            return self._streams[stream_id]

    def settle(self, stream_ids) -> list[SvdState]:
        """Apply each named stream's OWN queued events and return the settled
        states, in ``stream_ids`` order (other streams' queues untouched).

        This is the query-time primitive: ``merge_streams`` settles before
        merging, and the fleet tier (``repro.fleet``) settles each shard's
        members before the cross-shard merge — both see states as of *every*
        enqueued event, wherever the stream lives.  Runs under the service
        lock; the per-event applies dispatch async and the returned states
        may be futures (read = transparent block, like ``state()``).
        """
        with self._lock:
            states = []
            for sid in stream_ids:
                state = self._streams[sid]
                queue = self._pending[sid]
                while queue:
                    state = self._apply_event(state, queue[0])
                    self._token_visible(queue.popleft())
                self._streams[sid] = state
                states.append(state)
            return states

    def merge_streams(
        self,
        stream_ids,
        *,
        target: str | None = None,
        rank: int | None = None,
    ) -> SvdState:
        """Hierarchically merge several streams into one truncated SVD.

        The multi-worker story: each worker feeds its own stream (a shard
        tracker over its row block of a logically-shared matrix — per-tenant
        gradient sketches, federated covariance shards) and the service
        periodically combines them with the log-depth rank-1-update merge
        (``repro.dist.merge.merge_tree``) — row blocks concatenate in
        ``stream_ids`` order.  Each stream's OWN pending pairs are applied
        first (the merge must see current states; other streams' queues are
        untouched).  With ``target`` the result is registered as a new
        stream; the source streams keep evolving independently.

        The snapshot (queue drain) happens under the service lock; the
        log-depth merge itself — including its first-call jit compile —
        runs OUTSIDE it, so concurrent ``enqueue``/``flush`` traffic on
        other streams is never stalled.  The merge reflects the states as
        of the snapshot.
        """
        states = self.settle(stream_ids)
        merged = merge_tree(states, rank=rank, engine=self.engine,
                            policy=self.policy)
        if target is not None:
            with self._lock:
                self.register(target, merged)
        return merged

    def pending(self, stream_id: str | None = None) -> int:
        with self._lock:
            if stream_id is not None:
                return len(self._pending[stream_id])
            return sum(len(q) for q in self._pending.values())

    def in_flight(self) -> int:
        """Dispatched-but-unretired flush rounds (after reaping ready ones)."""
        with self._lock:
            self._reap_ready()
            return len(self._in_flight)

    # -- the hot path -------------------------------------------------------

    def enqueue(self, stream_id: str, a: jax.Array, b: jax.Array) -> int:
        """Queue one rank-1 perturbation ``a b^T`` for a stream; returns the
        event's visibility token (see ``take_visible``).

        Auto-flushes when ``max_batch`` streams have a pending head event.
        The flush only *dispatches* device work (async); enqueue never waits
        for it unless the in-flight buffer is full (backpressure).
        """
        with self._lock:
            if stream_id not in self._streams:
                raise KeyError(f"unknown stream {stream_id!r}; register() first")
            # match the geometry the stream will have once queued appends
            # flush — reject HERE: at flush time a bad pair would poison a
            # whole geometry group (events are popped before the engine call)
            m, n = self._effective_shape(stream_id)
            if a.shape != (m,) or b.shape != (n,):
                raise ValueError(
                    f"pair shapes {a.shape}/{b.shape} do not match stream "
                    f"{stream_id!r} geometry ({m},)/({n},)"
                )
            token = self._issue_token()
            self._pending[stream_id].append(("pair", a, b, token))
            self.stats.enqueued += 1
            self._maybe_autoflush()
            return token

    def enqueue_op(self, stream_id: str, op: "_ops.UpdateOp") -> None:
        """Queue one structured perturbation (a ``repro.updates`` op).

        Geometry-preserving ops lower into the pair FIFO at enqueue time —
        ``RankK`` becomes k pairs (a "rank-k flush bucket": k flush rounds,
        each batched with the other streams' heads), ``DenseDelta`` sketches
        into ``rank`` pairs, ``Compose`` decomposes child-by-child.
        Geometry-changing ops (appends and the ``RemoveRows`` /
        ``RemoveCols`` / ``Window`` downdates) and ``Decay`` stay whole as
        op events: they re-plan the stream's geometry at flush; decay folds
        into the singular values without an engine dispatch.  ``Sparse``
        deltas also stay whole — snapshots then carry their O(nnz) COO
        leaves bitwise instead of sketched pairs — and expand into their
        ``rank`` pairs only when they reach the head of a flush round.
        FIFO order with previously queued pairs is preserved either way.
        Returns the token of the op's LAST lowered event — visible once the
        whole op has applied.
        """
        with self._lock:
            if stream_id not in self._streams:
                raise KeyError(f"unknown stream {stream_id!r}; register() first")
            if not isinstance(op, _ops.UpdateOp):
                raise TypeError(f"enqueue_op takes a repro.updates op; got {type(op)}")
            m, n = self._effective_shape(stream_id)
            events, out_shape = self._lower_op_events(op, m, n, stream_id)
            events = [ev + (self._issue_token(),) for ev in events]
            self._pending[stream_id].extend(events)
            self._eff_shape[stream_id] = out_shape
            self.stats.enqueued += len(events)
            self._maybe_autoflush()
            return events[-1][-1]

    def _lower_op_events(self, op, m: int, n: int, sid: str) -> tuple[list, tuple]:
        """Lower an op into FIFO events at the (m, n) geometry; returns
        ``(events, geometry after the op)``."""
        if isinstance(op, _ops.Compose):
            events: list = []
            for child in op.ops:
                sub, (m, n) = self._lower_op_events(child, m, n, sid)
                events.extend(sub)
            return events, (m, n)
        if isinstance(op, _ops.RankK):
            u, v = jnp.asarray(op.u), jnp.asarray(op.v)
            if u.shape != (m, op.k) or v.shape != (n, op.k):
                raise ValueError(
                    f"RankK factors {u.shape}/{v.shape} do not match stream "
                    f"{sid!r} geometry ({m},{op.k})/({n},{op.k})"
                )
            return [("pair", u[:, i], v[:, i]) for i in range(op.k)], (m, n)
        if isinstance(op, _ops.DenseDelta):
            delta = jnp.asarray(op.delta)
            if delta.shape != (m, n):
                raise ValueError(
                    f"DenseDelta shape {delta.shape} does not match stream "
                    f"{sid!r} geometry ({m}, {n})"
                )
            # the planner's shared range-finder (updates.sketch) — the ONE
            # low-rank extraction path; serve can never drift from plan
            self._record_warm("sketch_dense", None, m, n, op.rank, delta.dtype)
            du, ds, dv = _planner.op_low_rank_factors(op, m, n, self.policy)
            return (
                [("pair", du[:, i] * ds[i], dv[:, i]) for i in range(op.rank)],
                (m, n),
            )
        if isinstance(op, _ops.Sparse):
            rows, cols = jnp.asarray(op.rows), jnp.asarray(op.cols)
            vals = jnp.asarray(op.vals)
            if not (rows.shape == cols.shape == vals.shape and vals.ndim == 1):
                raise ValueError(
                    f"Sparse coordinates must be matching 1-D (nnz,) arrays; "
                    f"got {rows.shape}/{cols.shape}/{vals.shape} for stream "
                    f"{sid!r}"
                )
            # queued WHOLE so snapshots carry the COO leaves bitwise (v3);
            # _flush_round expands the head into its rank pairs — the
            # deterministic sketch makes pre/post-restore expansion identical
            return [("op", op)], (m, n)
        if isinstance(op, (_ops.AppendRows, _ops.AppendCols)):
            width_ok = (
                (op.rows.shape[1] == n if op.rows is not None else op.v.shape[0] == n)
                if isinstance(op, _ops.AppendRows)
                else (op.cols.shape[0] == m if op.cols is not None else op.u.shape[0] == m)
            )
            if not width_ok:
                raise ValueError(
                    f"{type(op).__name__} block does not match stream {sid!r} "
                    f"geometry ({m}, {n})"
                )
            return [("op", op)], op.out_shape(m, n)
        if isinstance(op, (_ops.RemoveRows, _ops.RemoveCols, _ops.Window)):
            # downdates stay whole like appends (geometry-changing; zero or
            # one data leaf, so snapshots carry them bitwise for free) —
            # reject bad indices HERE, not at flush, where a poisoned event
            # would stay queued forever under the failure-atomicity contract
            if isinstance(op, _ops.RemoveRows) and op.idx[-1] >= m:
                raise ValueError(
                    f"RemoveRows{op.idx} out of range for stream {sid!r} "
                    f"geometry ({m}, {n})"
                )
            if isinstance(op, _ops.RemoveCols) and op.idx[-1] >= n:
                raise ValueError(
                    f"RemoveCols{op.idx} out of range for stream {sid!r} "
                    f"geometry ({m}, {n})"
                )
            out = op.out_shape(m, n)
            rank = self._streams[sid].rank
            if rank > min(out):
                raise ValueError(
                    f"{type(op).__name__} shrinks stream {sid!r} to {out}, "
                    f"below its rank {rank} — truncate first"
                )
            return [("op", op)], out
        return [("op", op)], op.out_shape(m, n)   # Decay and future scalars

    def _expand_sparse_head(self, sid: str) -> None:
        """Lower the ``Sparse`` op at the head of ``sid``'s queue into its
        ``rank`` pairs, in place — O((m+n)·rank + nnz) through the planner's
        shared range-finder, never densifying.  Factors are computed BEFORE
        the pop so a raising sketch leaves the event queued (the flush
        failure-atomicity contract)."""
        op = self._pending[sid][0][1]
        tok = self._pending[sid][0][-1]
        st = self._streams[sid]
        self._record_warm(
            "sketch_sparse", op.nnz, st.m, st.n, op.rank,
            jnp.asarray(op.vals).dtype,
        )
        u, s, v = _planner.op_low_rank_factors(op, st.m, st.n, self.policy)
        self._pending[sid].popleft()
        # the op's token rides the LAST expanded pair (visible = whole op done)
        self._pending[sid].extendleft(
            ("pair", u[:, i] * s[i], v[:, i],
             tok if i == op.rank - 1 else None)
            for i in range(op.rank - 1, -1, -1)
        )
        # one structured event became ``rank`` pair events; keep the
        # enqueued-vs-applied ledger balanced
        self.stats.enqueued += op.rank - 1
        self.stats.ops_applied += 1

    def _maybe_autoflush(self) -> None:
        ready = sum(1 for q in self._pending.values() if q)
        if ready >= self.max_batch:
            self._flush_round()

    def flush(self) -> int:
        """Dispatch ALL pending pairs (possibly several rounds); returns the
        number of updates applied.  Rounds are dispatched asynchronously —
        use ``drain()`` for a completion barrier."""
        with self._lock:
            applied = 0
            while any(self._pending.values()):
                applied += self._flush_round()
            return applied

    def drain(self) -> int:
        """Flush everything, then block until all dispatched work is done
        (the shutdown / handoff barrier). Returns the number applied."""
        with self._lock:
            applied = self.flush()
            self._barrier()
            return applied

    # -- in-flight buffer management ----------------------------------------

    def _reap_ready(self) -> None:
        """Retire finished rounds without blocking (oldest-first); their
        tokens become visible."""
        while self._in_flight and all(_is_ready(x) for x in self._in_flight[0][0]):
            self._visible.extend(self._in_flight.popleft()[1])

    def _retire_oldest(self) -> None:
        outputs, tokens = self._in_flight.popleft()
        with _obs.span("reap", outputs=len(outputs)):
            jax.block_until_ready(outputs)
        self._visible.extend(tokens)

    # -- observability (repro.obs) ------------------------------------------

    def _publish_stats(self) -> None:
        """Mirror the stats counter bag into the metrics registry (gauges —
        idempotent re-publication after every flush; the fleet tier labels
        each shard's series and ``registry().aggregate`` rolls them up)."""
        reg = _obs.registry()
        cache_key = (reg, reg.generation)
        if self._stat_gauges is None or self._stat_gauges[0] != cache_key:
            self._stat_gauges = (cache_key, [
                (f.name, reg.gauge(f"serve_{f.name}", **self._obs_labels))
                for f in dataclasses.fields(SvdServiceStats)])
        for name, gauge in self._stat_gauges[1]:
            gauge.set(getattr(self.stats, name))

    def _health_monitor(self) -> "_obs.HealthMonitor":
        if self._health is None:
            self._health = _obs.HealthMonitor(
                every=self.policy.health_every or 1, **self._obs_labels)
        return self._health

    def _barrier(self) -> None:
        """Block until every dispatched round AND every stream state is
        concrete — the only place (besides backpressure) the service waits
        on the device."""
        while self._in_flight:
            self._retire_oldest()
        jax.block_until_ready(list(self._streams.values()))

    def flush_round(self, *, max_depth: int = 1) -> int:
        """Dispatch ONE flush round (public form — the continuous-batching
        frontend's seal primitive; ``repro.fleet.frontend``).

        ``max_depth > 1`` enables depth batching: a stream whose queue head
        is a run of consecutive rank-1 pairs contributes up to ``max_depth``
        of them as one scan column (power-of-two floored), and the round
        groups by ``(geometry, depth)`` — depth-k groups dispatch through
        the engine's ``update_truncated_rank_k_batch`` ``lax.scan`` route,
        ONE engine call applying ``B x k`` events.  The scan applies a
        stream's pairs in FIFO order (per-stream ordering by data
        dependence), and the scan executable is bitwise-identical to the k
        sequential single updates it replaces (pinned in tests/test_fleet.py).
        """
        with self._lock:
            return self._flush_round(max_depth=max_depth)

    def has_capacity(self) -> bool:
        """True when a ``flush_round`` would dispatch WITHOUT blocking on an
        older round (the frontend's pump guard).  Reaps finished rounds."""
        with self._lock:
            if self.max_in_flight == 0:
                return True
            self._reap_ready()
            return len(self._in_flight) < self.max_in_flight

    def _flush_round(self, *, max_depth: int = 1) -> int:
        """One round: pair-headed streams group by (geometry, depth) into
        batched engine calls (at most one event per stream at depth 1, up to
        ``max_depth`` consecutive pairs as a scan column otherwise);
        op-headed streams (appends, decay folds) apply through the planner —
        all dispatched async.  Each round is one ``flush_round`` trace span;
        with obs enabled the stats bag mirrors into the registry afterwards
        and the health monitor samples on its ``policy.health_every`` cadence.
        """
        live_ids = [sid for sid, q in self._pending.items() if q]
        if not live_ids:
            return 0
        with _obs.span("flush_round", streams=len(live_ids),
                       max_depth=max_depth):
            applied = self._flush_round_impl(live_ids, max_depth)
        if _obs.enabled():
            self._publish_stats()
        return applied

    def _flush_round_impl(self, live_ids: list, max_depth: int) -> int:
        # Backpressure: bound how far the host can run ahead of the device.
        self._reap_ready()
        while self.max_in_flight > 0 and len(self._in_flight) >= self.max_in_flight:
            self._retire_oldest()
            self.stats.backpressure_waits += 1

        applied = 0        # pair updates dispatched through batched calls
        ops_applied = 0    # structured heads (already counted by _apply_event)
        round_outputs: list = []
        round_tokens: list = []

        # structured heads: per-stream planner application (geometry may
        # change mid-event, so they cannot share a batch)
        round_ids = []
        for sid in live_ids:
            head = self._pending[sid][0]
            if head[0] == "op" and isinstance(head[1], _ops.Sparse):
                # expand a Sparse head into its rank pairs IN PLACE so sparse
                # events batch into pair rounds like everything else; the
                # deterministic sketch makes this bitwise-identical whether
                # it runs before or after a snapshot/restore cycle
                self._expand_sparse_head(sid)
                head = self._pending[sid][0]
            if head[0] == "op":
                # apply BEFORE popping: a raising engine call leaves the
                # event queued, mirroring the pair path's peek-don't-pop
                # failure atomicity below
                self._streams[sid] = self._apply_event(self._streams[sid], head)
                ev = self._pending[sid].popleft()
                if ev[-1] is not None:
                    round_tokens.append(ev[-1])
                round_outputs.extend(jax.tree.leaves(self._streams[sid]))
                ops_applied += 1
            else:
                round_ids.append(sid)

        # depth per stream: how many consecutive pair heads ride this round
        # as one scan column (1 = the classic one-event-per-stream round)
        depths = {}
        for sid in round_ids:
            if max_depth > 1:
                run = 0
                for ev in self._pending[sid]:
                    if ev[0] != "pair":
                        break
                    run += 1
                    if run >= max_depth:
                        break
                depths[sid] = _depth_bucket(run, max_depth)
            else:
                depths[sid] = 1

        # health sampling: decide once per round; the first depth-1 group's
        # (pre-state, pair, post-state) triple feeds one probe after dispatch
        sample_due = (
            _obs.enabled() and self.policy.health_every is not None
            and self._health_monitor().due()
        )
        probe_args = None

        keys = [truncated_geometry(self._streams[sid]) + (depths[sid],)
                for sid in round_ids]

        for (m, n, r, dt, k), idxs in group_indices(keys).items():
            sids = [round_ids[i] for i in idxs]
            # peek, don't pop: if the engine call raises (first-compile OOM,
            # backend error), the pairs stay queued and a retry re-applies
            # them — flush stays failure-atomic per group
            pairs = [
                [(q[j][1], q[j][2]) for j in range(k)]
                for q in (self._pending[sid] for sid in sids)
            ]
            states = [self._streams[sid] for sid in sids]
            bsz = len(sids)
            pad = 0
            if self.pad_to_bucket:
                # a group can exceed max_batch (retry after a failed flush
                # accumulates streams) — never pad negative, just dispatch big
                pad = max(0, _bucket(bsz, self.max_batch) - bsz)

            t_stack = stack_trees(
                [TruncatedSvd(s.u, s.s, s.v) for s in states]
            )
            if k == 1:
                a_stack = jnp.stack([jnp.asarray(col[0][0], dt) for col in pairs])
                b_stack = jnp.stack([jnp.asarray(col[0][1], dt) for col in pairs])
                pad_a, pad_b = (pad, m), (pad, n)
            else:
                a_stack = jnp.stack([
                    jnp.stack([jnp.asarray(a, dt) for a, _ in col]) for col in pairs
                ])
                b_stack = jnp.stack([
                    jnp.stack([jnp.asarray(b, dt) for _, b in col]) for col in pairs
                ])
                pad_a, pad_b = (pad, k, m), (pad, k, n)
            if pad:
                # no-op rank-1 pairs (a = b = 0) along the BATCH axis only;
                # padded outputs are discarded (scan columns are never padded
                # — their outputs are kept, see _depth_bucket)
                t_stack = jax.tree.map(
                    lambda x: jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)]),
                    t_stack,
                )
                a_stack = jnp.concatenate([a_stack, jnp.zeros(pad_a, dt)])
                b_stack = jnp.concatenate([b_stack, jnp.zeros(pad_b, dt)])

            eng = self._engine_for(r)
            if self.policy.mesh is None:
                kind = "trunc_batch" if k == 1 else f"trunc_scan{k}"
                self._record_warm(kind, bsz + pad, m, n, r, dt)
            with _obs.span("dispatch", m=m, n=n, rank=r, batch=bsz + pad,
                           depth=k):
                if k == 1:
                    out = eng.update_truncated_batch(
                        t_stack, a_stack, b_stack,
                        mesh=self.policy.mesh, batch_axis=self.policy.batch_axis,
                    )
                else:
                    out = eng.update_truncated_rank_k_batch(
                        t_stack, a_stack, b_stack,
                        mesh=self.policy.mesh, batch_axis=self.policy.batch_axis,
                    )
                    self.stats.scan_rounds += 1
                    self.stats.max_depth = max(self.stats.max_depth, k)
            if sample_due and probe_args is None and k == 1:
                st1 = unstack_tree(out, 0)
                probe_args = (states[0].u, states[0].s, states[0].v,
                              a_stack[0], b_stack[0], st1.u, st1.s, st1.v)
            for j, sid in enumerate(sids):
                t = unstack_tree(out, j)
                self._streams[sid] = SvdState(u=t.u, s=t.s, v=t.v)
                for _ in range(k):
                    ev = self._pending[sid].popleft()
                    if ev[-1] is not None:
                        round_tokens.append(ev[-1])
            round_outputs.extend(jax.tree.leaves(out))
            applied += bsz * k
            self.stats.rounds += 1
            self.stats.max_batch = max(self.stats.max_batch, bsz + pad)

        if self.max_in_flight == 0:
            jax.block_until_ready(round_outputs)       # synchronous mode
            self._visible.extend(round_tokens)
        else:
            self._in_flight.append((round_outputs, round_tokens))
            self.stats.in_flight_peak = max(
                self.stats.in_flight_peak, len(self._in_flight)
            )
        self.stats.flushes += 1
        self.stats.applied += applied
        if probe_args is not None:
            # separate jitted probe over the just-flushed factors — outside
            # the update's traced path; forces the sampled state concrete
            self._health_monitor().sample_update(
                *probe_args, deflate_rtol=self.policy.deflate_rtol)
        return applied + ops_applied

    # -- checkpointing ------------------------------------------------------

    def snapshot(self) -> ServiceSnapshot:
        """Capture the whole service as a versioned pytree.

        This is a barrier: in-flight rounds are retired and every stream
        state is forced concrete first, so the snapshot is a consistent
        point on every stream's timeline — states as of all *flushed*
        updates, pending FIFOs holding exactly the unflushed ones.
        """
        with self._lock:
            self._barrier()
            states, pend_a, pend_b, pend_ops, orders = [], [], [], [], []
            for sid, st in self._streams.items():
                states.append(st)
                a_vecs, b_vecs, stream_ops, order = [], [], [], []
                geom_m, geom_n = st.m, st.n
                geom_changed = False
                for ev in self._pending[sid]:
                    if ev[0] == "pair" and not geom_changed:
                        a_vecs.append(jnp.asarray(ev[1]))
                        b_vecs.append(jnp.asarray(ev[2]))
                        order.append("p")
                    elif ev[0] == "pair":
                        # a queued append changed the geometry: later pairs
                        # no longer fit the rectangular (k_i, m)/(k_i, n)
                        # stacks — carry them as rank-1 RankK op leaves
                        # (bitwise: restore unwraps k=1 RankK back to pairs)
                        stream_ops.append(
                            _ops.RankK(jnp.asarray(ev[1])[:, None],
                                       jnp.asarray(ev[2])[:, None])
                        )
                        order.append("o")
                    else:
                        stream_ops.append(ev[1])
                        order.append("o")
                        if ev[1].out_shape(geom_m, geom_n) != (geom_m, geom_n):
                            geom_changed = True
                if a_vecs:
                    pend_a.append(jnp.stack(a_vecs))
                    pend_b.append(jnp.stack(b_vecs))
                else:
                    pend_a.append(np.zeros((0, geom_m), st.u.dtype))
                    pend_b.append(np.zeros((0, geom_n), st.v.dtype))
                pend_ops.append(tuple(stream_ops))
                orders.append("".join(order))
            return ServiceSnapshot(
                states=tuple(states),
                pending_a=tuple(pend_a),
                pending_b=tuple(pend_b),
                pending_ops=tuple(pend_ops),
                version=SNAPSHOT_VERSION,
                stream_ids=tuple(self._streams),
                policy_spec=tuple(_policy_spec(self.policy).items()),
                max_batch=self.max_batch,
                pad_to_bucket=self.pad_to_bucket,
                max_in_flight=self.max_in_flight,
                stats=tuple(dataclasses.asdict(self.stats).items()),
                pending_order=tuple(orders),
                warmed=tuple(sorted(self._warmed)),
                # telemetry rides the snapshot like the stats bag does —
                # captured only when obs is on (empty tuple otherwise)
                obs_metrics=(_obs.registry().snapshot()
                             if _obs.enabled() else ()),
            )

    def save(self, ckpt_dir, step: int, *, keep: int = 3):
        """``snapshot()`` + atomic write through ``train.checkpoint``."""
        return self.snapshot().save(ckpt_dir, step, keep=keep)

    @classmethod
    def from_snapshot(
        cls,
        snap: ServiceSnapshot,
        *,
        mesh=None,
        engine: SvdEngine | None = None,
        policy: UpdatePolicy | None = None,
    ) -> "SvdService":
        """Rebuild a service from a snapshot.

        ``policy`` (full override) or ``mesh`` (grafted onto the recorded
        policy spec) re-establish placement on the restoring topology;
        with neither, the recorded numerics run unsharded.
        """
        spec = dict(snap.policy_spec)
        if policy is None:
            if spec.get("had_mesh") and mesh is None:
                warnings.warn(
                    "snapshot was taken under a mesh-sharded policy but "
                    "restore got no mesh= (and no policy=): flushes will run "
                    "unsharded on this process",
                    stacklevel=2,
                )
            policy = _policy_from_spec(spec, mesh=mesh)
        svc = cls(
            engine=engine,
            max_batch=snap.max_batch,
            pad_to_bucket=snap.pad_to_bucket,
            max_in_flight=snap.max_in_flight,
            policy=policy,
        )
        n_streams = len(snap.stream_ids)
        pend_ops = snap.pending_ops or ((),) * n_streams
        orders = snap.pending_order or (None,) * n_streams
        for sid, st, pa, pb, sops, order in zip(
            snap.stream_ids, snap.states, snap.pending_a, snap.pending_b,
            pend_ops, orders,
        ):
            svc._streams[sid] = SvdState(u=st.u, s=st.s, v=st.v)
            n_pairs = np.asarray(pa).shape[0]
            if order is None:
                order = "p" * n_pairs          # v1 snapshots: all-pair FIFOs
            queue: deque = deque()
            pi = oi = 0
            # visibility tokens are runtime-only: restored events get fresh
            # ones (nobody is waiting on the old process's tokens)
            for marker in order:
                if marker == "p":
                    queue.append(("pair", pa[pi], pb[pi], svc._issue_token()))
                    pi += 1
                    continue
                op = sops[oi]
                oi += 1
                if isinstance(op, _ops.RankK):
                    # k=1 RankK leaves are pairs the snapshot wrapped to keep
                    # the pair stacks rectangular past a geometry change
                    for i in range(op.k):
                        queue.append(("pair", jnp.asarray(op.u)[:, i],
                                      jnp.asarray(op.v)[:, i],
                                      svc._issue_token()))
                else:
                    queue.append(("op", op, svc._issue_token()))
            svc._pending[sid] = queue
            m_eff, n_eff = svc._streams[sid].m, svc._streams[sid].n
            for ev in queue:
                if ev[0] == "op":
                    m_eff, n_eff = ev[1].out_shape(m_eff, n_eff)
            svc._eff_shape[sid] = (m_eff, n_eff)
        svc.stats = SvdServiceStats(**dict(snap.stats))
        if snap.obs_metrics:
            _obs.registry().restore(snap.obs_metrics)
        svc._warmed = {tuple(w) for w in snap.warmed}
        # cold-start control (ROADMAP item): eagerly AOT-warm every
        # (kind, geometry) the snapshotted service had compiled, so the first
        # post-restore flush hits the plan cache instead of compiling under
        # traffic.  Skipped when an explicit engine override is active (its
        # plans are caller-managed) or the policy re-shards over a mesh (the
        # shard_map route keys on the live mesh, which warmup cannot AOT).
        if engine is None and policy.mesh is None:
            for kind, batch, m, n, r, dtype_name in svc._warmed:
                if kind in ("sketch_dense", "sketch_sparse"):
                    # sketch executables warm by running on zeros (the jit
                    # call cache, not the engine plan cache); ``batch`` slot
                    # carries nnz for the sparse kind
                    _sketch.warmup_sketch(
                        m=m, n=n, k=r,
                        oversample=policy.sketch_oversample,
                        power_iters=policy.sketch_power_iters,
                        nnz=batch if kind == "sketch_sparse" else None,
                        dtype=jnp.dtype(dtype_name),
                    )
                    continue
                # depth-batched rounds record "trunc_scan<k>" — the scan
                # depth rides the kind string (the warm tuple is fixed-width)
                scan_k = (int(kind[len("trunc_scan"):])
                          if kind.startswith("trunc_scan") else None)
                _api_warmup(
                    svc.policy, m=m, n=n,
                    batch=batch if kind != "trunc" else None,
                    rank=r, k=scan_k, dtype=jnp.dtype(dtype_name),
                )
        return svc

    @classmethod
    def restore(
        cls,
        ckpt_dir,
        *,
        step: int | None = None,
        mesh=None,
        engine: SvdEngine | None = None,
        policy: UpdatePolicy | None = None,
        cache_dir=None,
    ) -> tuple[int, "SvdService"]:
        """Load the latest (or ``step``-th) snapshot and rebuild the service.

        Returns ``(step, service)``.  Restore-exactness contract: the
        restored service, fed the same post-snapshot traffic, produces
        bitwise-identical factors to the service that never stopped
        (DESIGN.md §9; kill-and-resume test in test_serve_checkpoint.py).

        ``cache_dir`` (opt-in) enables the persistent XLA compilation cache
        BEFORE the warmed-geometry set re-warms (``api.
        enable_compilation_cache``): a restore on a machine that has flushed
        these geometries before recompiles NOTHING — warmup replays cached
        binaries (the fresh-process proof is in tests/test_fleet.py).
        """
        if cache_dir is not None:
            from repro.api import enable_compilation_cache

            enable_compilation_cache(cache_dir)
        step, snap = ServiceSnapshot.load(ckpt_dir, step)
        return step, cls.from_snapshot(snap, mesh=mesh, engine=engine, policy=policy)
