"""FMM vs direct Cauchy sums: exactness, error-vs-p, outliers, overflow."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cauchy import cauchy_matmul_stable
from repro.core.fmm import build_plan, fmm_apply, fmm_error_bound

RNG = np.random.default_rng(7)


def _direct(w, src, tgt):
    return np.einsum("rj,ji->ri", w, 1.0 / (tgt[None, :] - src[:, None]))


@pytest.mark.parametrize("n", [64, 200, 513, 2048])
@pytest.mark.parametrize("p", [8, 16, 24])
def test_fmm_matches_direct(n, p):
    src = np.sort(RNG.uniform(0, 1, n))
    tgt = np.sort(RNG.uniform(0, 1, n)) + 1e-7
    w = RNG.normal(size=(4, n))
    plan = build_plan(jnp.asarray(src), jnp.asarray(tgt), p=p)
    assert not bool(plan.overflow)
    out = np.asarray(fmm_apply(plan, jnp.asarray(w)))
    ref = _direct(w, src, tgt)
    rel = np.max(np.abs(out - ref)) / np.max(np.abs(ref))
    assert rel < max(10 * fmm_error_bound(p), 1e-13)


def test_error_decreases_with_p():
    """Reproduces the shape of paper Fig. 3: error ~ 5^-p until fp64 floor."""
    n = 400
    src = np.sort(RNG.uniform(0, 1, n))
    tgt = np.sort(RNG.uniform(0, 1, n)) + 1e-7
    w = RNG.normal(size=(1, n))
    ref = _direct(w, src, tgt)
    errs = []
    for p in [4, 8, 12, 16]:
        plan = build_plan(jnp.asarray(src), jnp.asarray(tgt), p=p)
        out = np.asarray(fmm_apply(plan, jnp.asarray(w)))
        errs.append(np.max(np.abs(out - ref)) / np.max(np.abs(ref)))
    assert errs[0] > errs[1] > errs[2]
    assert errs[-1] < 1e-10


def test_outlier_targets_handled_densely():
    """Targets far outside the source range (the top secular root case)."""
    n = 256
    src = np.sort(RNG.uniform(0, 1, n))
    tgt = np.concatenate([np.sort(RNG.uniform(0, 1, n - 3)) + 1e-7,
                          [5.0, 17.0, 123.0]])
    w = RNG.normal(size=(3, n))
    plan = build_plan(jnp.asarray(src), jnp.asarray(tgt), p=16)
    assert not bool(plan.overflow)
    out = np.asarray(fmm_apply(plan, jnp.asarray(w)))
    ref = _direct(w, src, tgt)
    np.testing.assert_allclose(out, ref, rtol=0, atol=1e-10 * np.max(np.abs(ref)))


def test_overflow_flag_on_pathological_clustering():
    """A mass point inside a well-spread bulk overflows one box's static
    capacity and must be flagged (the dense fallback then engages).

    NOTE: a *separated* cluster (all mass at one value, few spread points) is
    now handled without overflow by bulk-quantile gridding + source peeling —
    that improved case is covered by test_source_outlier_peeling below."""
    n = 1024
    src = np.sort(np.concatenate([
        np.full(n // 2, 0.5) + np.linspace(0, 1e-9, n // 2),  # mass point IN bulk
        np.linspace(0.0, 1.0, n - n // 2),                     # spread bulk
    ]))
    tgt = src + 1e-12
    plan = build_plan(jnp.asarray(src), jnp.asarray(tgt), p=8)
    assert bool(plan.overflow)


def test_source_outlier_peeling():
    """Skewed spectra (e.g. squared singular values: huge top eigenvalue over
    a clustered bulk) are handled exactly via dense peeled rows/cols."""
    n = 300
    src = np.sort(np.concatenate([RNG.uniform(0, 10, n - 2), [16_000.0, 16_500.0]]))
    tgt = np.sort(np.concatenate([RNG.uniform(0, 10, n - 2) + 1e-7,
                                  [15_000.0, 16_600.0]]))
    w = RNG.normal(size=(3, n))
    plan = build_plan(jnp.asarray(src), jnp.asarray(tgt), p=16)
    assert not bool(plan.overflow)
    out = np.asarray(fmm_apply(plan, jnp.asarray(w)))
    ref = _direct(w, src, tgt)
    assert np.max(np.abs(out - ref)) / np.max(np.abs(ref)) < 1e-12


def test_masked_invalid_sources_and_targets():
    n = 128
    src = np.sort(RNG.uniform(0, 1, n))
    tgt = np.sort(RNG.uniform(0, 1, n)) + 1e-7
    sv = RNG.uniform(size=n) > 0.2
    tv = RNG.uniform(size=n) > 0.2
    w = RNG.normal(size=(2, n))
    plan = build_plan(
        jnp.asarray(src), jnp.asarray(tgt), p=16,
        src_valid=jnp.asarray(sv), tgt_valid=jnp.asarray(tv),
    )
    out = np.asarray(fmm_apply(plan, jnp.asarray(w * sv[None, :])))
    ref = _direct(w * sv[None, :], src, tgt) * tv[None, :]
    np.testing.assert_allclose(out * tv[None, :], ref, atol=1e-9 * np.max(np.abs(ref)))
    assert np.allclose(out[:, ~tv], 0.0)


def test_anchored_targets_near_poles():
    """Near-pole targets via (anchor, tau) keep full relative accuracy."""
    n = 200
    src = np.sort(RNG.uniform(0, 1, n))
    anchor = np.arange(n, dtype=np.int32)
    tau = np.full(n, 1e-13)
    tgt = src + tau
    w = RNG.normal(size=(2, n))
    plan = build_plan(
        jnp.asarray(src), jnp.asarray(tgt), p=20,
        tgt_anchor=jnp.asarray(anchor), tgt_tau=jnp.asarray(tau),
    )
    out = np.asarray(fmm_apply(plan, jnp.asarray(w)))
    ref = np.asarray(cauchy_matmul_stable(
        jnp.asarray(w), jnp.asarray(src), jnp.asarray(anchor), jnp.asarray(tau)
    ))
    # cauchy convention: sum w/(src - mu) = -fmm
    np.testing.assert_allclose(-out, ref, rtol=1e-9)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(64, 600), p=st.integers(10, 24), seed=st.integers(0, 2**31 - 1))
def test_property_fmm_error_within_bound(n, p, seed):
    rng = np.random.default_rng(seed)
    src = np.sort(rng.uniform(-2, 3, n))
    tgt = np.sort(rng.uniform(-2, 3, n)) * (1 - 1e-9) + 1e-7
    w = rng.normal(size=(2, n))
    plan = build_plan(jnp.asarray(src), jnp.asarray(tgt), p=p)
    if bool(plan.overflow):
        return  # documented fallback path, exercised elsewhere
    out = np.asarray(fmm_apply(plan, jnp.asarray(w)))
    ref = _direct(w, src, tgt)
    scale = np.max(np.abs(ref)) + 1e-30
    assert np.max(np.abs(out - ref)) / scale < max(100 * fmm_error_bound(p), 1e-12)
