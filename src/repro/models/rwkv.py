"""RWKV-6 ("Finch") blocks: data-dependent decay linear attention.

Training uses the chunked matmul formulation (strictly-causal (Q x Q) score
matmuls with per-channel decay folded into q/k scalings); decode is the O(1)
recurrence. A step-by-step recurrent reference (`wkv_recurrent`) backs the
tests.

State per layer: time-mix token shift (b, d), wkv state (b, h, dk, dv),
channel-mix token shift (b, d).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dot, rmsnorm, uniform_init

__all__ = [
    "rwkv_init",
    "rwkv_time_mix_train",
    "rwkv_channel_mix_train",
    "rwkv_decode_step",
    "init_rwkv_state",
    "wkv_recurrent",
]

_LOGW_CLIP = 30.0  # bounds per-chunk decay products in the matmul split


def rwkv_init(key, cfg, dtype):
    d = cfg.d_model
    r = cfg.rwkv
    h = d // r.head_dim
    ks = jax.random.split(key, 10)
    s = (1.0 / d) ** 0.5
    return {
        "mu_r": jnp.full((d,), 0.5, dtype),
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype),
        "mu_g": jnp.full((d,), 0.5, dtype),
        "mu_w": jnp.full((d,), 0.5, dtype),
        "wr": uniform_init(ks[0], (d, d), s, dtype),
        "wk": uniform_init(ks[1], (d, d), s, dtype),
        "wv": uniform_init(ks[2], (d, d), s, dtype),
        "wg": uniform_init(ks[3], (d, d), s, dtype),
        "w0": jnp.full((d,), -2.0, dtype),  # base log-decay rate
        "w_lora_a": uniform_init(ks[4], (d, r.decay_lora), s, dtype),
        "w_lora_b": uniform_init(ks[5], (r.decay_lora, d), (1.0 / r.decay_lora) ** 0.5, dtype),
        "u_bonus": uniform_init(ks[6], (h, r.head_dim), 0.5, dtype),
        "ln_x": jnp.ones((d,), dtype),
        "wo": uniform_init(ks[7], (d, d), s, dtype),
        # channel mix
        "cm_mu_k": jnp.full((d,), 0.5, dtype),
        "cm_mu_r": jnp.full((d,), 0.5, dtype),
        "cm_wk": uniform_init(ks[8], (d, cfg.d_ff), s, dtype),
        "cm_wv": uniform_init(ks[9], (cfg.d_ff, d), (1.0 / cfg.d_ff) ** 0.5, dtype),
        "cm_wr": uniform_init(jax.random.fold_in(key, 77), (d, d), s, dtype),
    }


def _shift(x, x_prev_last):
    """Token shift: x_{t-1} with x_prev_last (b, d) as position -1."""
    return jnp.concatenate([x_prev_last[:, None, :], x[:, :-1, :]], axis=1)


def _lerp(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)[None, None, :]


def _projections(x, xs, p, cfg):
    cd = jnp.dtype(cfg.compute_dtype)
    r = dot(_lerp(x, xs, p["mu_r"]), p["wr"], cd)
    k = dot(_lerp(x, xs, p["mu_k"]), p["wk"], cd)
    v = dot(_lerp(x, xs, p["mu_v"]), p["wv"], cd)
    g = dot(_lerp(x, xs, p["mu_g"]), p["wg"], cd)
    # data-dependent decay (the RWKV-6 signature)
    wx = _lerp(x, xs, p["mu_w"])
    lora = dot(jnp.tanh(dot(wx, p["w_lora_a"], cd)).astype(x.dtype), p["w_lora_b"], cd)
    logw = -jnp.exp(jnp.clip(p["w0"].astype(jnp.float32)[None, None, :]
                             + lora.astype(jnp.float32), -8.0, 4.0))  # log w_t <= 0
    return r.astype(x.dtype), k.astype(x.dtype), v.astype(x.dtype), g.astype(x.dtype), logw


def wkv_recurrent(r, k, v, logw, u, state):
    """Reference recurrence. r/k/v: (b, l, h, dk|dv); logw: (b, l, h, dk).

    y_t = (S_{t-1} + u k_t v_t^T)^T r_t ;  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    def step(s, inp):
        rt, kt, vt, lwt = inp  # (b,h,dk), ..., (b,h,dk)
        bonus = jnp.einsum("bhi,hi,bhi,bhj->bhj", rt, u, kt, vt)
        y = jnp.einsum("bhi,bhij->bhj", rt, s) + bonus
        s = s * jnp.exp(lwt)[..., None] + jnp.einsum("bhi,bhj->bhij", kt, vt)
        return s, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, logw))
    state, ys = lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state


def _wkv_chunked(r, k, v, logw, u, state, chunk, unroll=False):
    """Chunked matmul WKV. Shapes as in wkv_recurrent; l % chunk == 0."""
    b, l, h, dk = r.shape
    dv = v.shape[-1]
    q = chunk
    nc = l // q
    f32 = jnp.promote_types(r.dtype, jnp.float32)  # >= f32; f64 under x64 tests

    rc = r.reshape(b, nc, q, h, dk).astype(f32)
    kc = k.reshape(b, nc, q, h, dk).astype(f32)
    vc = v.reshape(b, nc, q, h, dv).astype(f32)
    lw = logw.reshape(b, nc, q, h, dk).astype(f32)

    lpw = jnp.cumsum(lw, axis=2) - lw            # exclusive cumsum: prod_{s<t} w_s
    lpw_tot = lpw[:, :, -1, :, :] + lw[:, :, -1, :, :]  # full-chunk decay

    # matmul split (clipped to avoid overflow in exp(-lpw))
    q_dec = rc * jnp.exp(jnp.maximum(lpw, -_LOGW_CLIP))
    k_dec = kc * jnp.exp(jnp.minimum(-(lpw + lw), _LOGW_CLIP))

    scores = jnp.einsum("bcqhi,bcshi->bchqs", q_dec, k_dec)   # strict-causal
    mask = jnp.tril(jnp.ones((q, q), bool), k=-1)
    scores = jnp.where(mask[None, None, None, :, :], scores, 0.0)
    y_intra = jnp.einsum("bchqs,bcshj->bcqhj", scores, vc)

    # u bonus (diagonal term)
    bonus = jnp.einsum("bcqhi,hi,bcqhi->bcqh", rc, u.astype(f32), kc)
    y_intra = y_intra + bonus[..., None] * vc

    # chunk state summaries: sum_s (k_s * prod_{u>s} w_u) v_s^T
    k_tail = kc * jnp.exp(jnp.maximum(lpw_tot[:, :, None, :, :] - (lpw + lw), -_LOGW_CLIP))
    s_local = jnp.einsum("bcshi,bcshj->bchij", k_tail, vc)

    def step(s, inp):
        s_loc, lw_tot, r_dec_c, v_c = inp
        y_inter = jnp.einsum("bqhi,bhij->bqhj", r_dec_c, s)
        s = s * jnp.exp(lw_tot)[..., None] + s_loc
        return s, y_inter

    xs = (
        jnp.moveaxis(s_local, 1, 0),
        jnp.moveaxis(lpw_tot, 1, 0),
        jnp.moveaxis(q_dec, 1, 0),
        jnp.moveaxis(vc, 1, 0),
    )
    if unroll:
        st = state.astype(f32)
        ys = []
        for i in range(nc):
            st, y = step(st, jax.tree.map(lambda a: a[i], xs))
            ys.append(y)
        state = st
        y_inter = jnp.stack(ys, axis=1)
    else:
        state, y_inter = lax.scan(step, state.astype(f32), xs)
        y_inter = jnp.moveaxis(y_inter, 0, 1)

    y = (y_intra + y_inter).reshape(b, l, h, dv)
    return y, state


def rwkv_time_mix_train(x, p, cfg, x_last, state):
    """x: (b, l, d). Returns (out, (new_x_last, new_state))."""
    r_cfg = cfg.rwkv
    d = cfg.d_model
    h = d // r_cfg.head_dim
    b, l, _ = x.shape
    xs = _shift(x, x_last)
    r, k, v, g, logw = _projections(x, xs, p, cfg)

    hr = r.reshape(b, l, h, r_cfg.head_dim)
    hk = k.reshape(b, l, h, r_cfg.head_dim)
    hv = v.reshape(b, l, h, r_cfg.head_dim)
    hw = logw.reshape(b, l, h, r_cfg.head_dim)

    y, new_state = _wkv_chunked(hr, hk, hv, hw, p["u_bonus"], state, r_cfg.chunk,
                                unroll=not cfg.scan_layers)
    y = y.reshape(b, l, d).astype(x.dtype)
    y = rmsnorm(y, p["ln_x"]) * jax.nn.silu(g)
    out = dot(y, p["wo"], jnp.dtype(cfg.compute_dtype)).astype(x.dtype)
    return out, (x[:, -1, :], new_state)


def rwkv_channel_mix_train(x, p, cfg, x_last):
    cd = jnp.dtype(cfg.compute_dtype)
    xs = _shift(x, x_last)
    xk = _lerp(x, xs, p["cm_mu_k"])
    xr = _lerp(x, xs, p["cm_mu_r"])
    k = jnp.square(jax.nn.relu(dot(xk, p["cm_wk"], cd))).astype(x.dtype)
    kv = dot(k, p["cm_wv"], cd).astype(x.dtype)
    return jax.nn.sigmoid(dot(xr, p["cm_wr"], cd)).astype(x.dtype) * kv, x[:, -1, :]


def init_rwkv_state(batch, cfg, dtype):
    d = cfg.d_model
    r = cfg.rwkv
    h = d // r.head_dim
    return {
        "tm_x": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, h, r.head_dim, r.head_dim), jnp.float32),
        "cm_x": jnp.zeros((batch, d), dtype),
    }


def rwkv_decode_step(x, p, cfg, state):
    """One token through time mix + channel mix. x: (b, 1, d)."""
    r_cfg = cfg.rwkv
    d = cfg.d_model
    h = d // r_cfg.head_dim
    b = x.shape[0]
    xs = state["tm_x"][:, None, :].astype(x.dtype)
    r, k, v, g, logw = _projections(x, xs, p, cfg)
    hr = r.reshape(b, 1, h, r_cfg.head_dim)
    hk = k.reshape(b, 1, h, r_cfg.head_dim)
    hv = v.reshape(b, 1, h, r_cfg.head_dim)
    hw = logw.reshape(b, 1, h, r_cfg.head_dim)
    y, wkv = wkv_recurrent(hr, hk, hv, hw, p["u_bonus"], state["wkv"])
    y = y.reshape(b, 1, d).astype(x.dtype)
    y = rmsnorm(y, p["ln_x"]) * jax.nn.silu(g)
    tm_out = dot(y, p["wo"], jnp.dtype(cfg.compute_dtype)).astype(x.dtype)

    return tm_out, {"tm_x": x[:, 0, :], "wkv": wkv, "cm_x": state["cm_x"]}


def rwkv_channel_mix_decode(x, p, cfg, state):
    # _shift handles the single-token case: x_{t-1} comes from the carried state
    out, cm_x = rwkv_channel_mix_train(x, p, cfg, state["cm_x"])
    return out, cm_x
