import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract memory / cost / collective roofline terms.

The two lines above MUST stay first: jax locks the device count on first
init, and only the dry-run wants 512 placeholder devices (smoke tests and
benches see 1 device).

Cost extraction caveat (measured, see EXPERIMENTS.md §Dry-run): XLA's
``cost_analysis`` counts while-loop bodies ONCE, so a scanned-L-layer program
under-reports FLOPs/bytes/collectives by ~L. The dry-run therefore compiles
each cell twice more with depth-1 and depth-2 *unrolled* stacks
(``scan_layers=False``) and affine-extrapolates:

    total(L) = f(1) + (L - 1) * (f(2) - f(1))

Memory analysis (does-it-fit) always comes from the real scanned program.
SSD/WKV chunk scans are unrolled too; where that would explode the HLO
(32k-sequence cells) the measurement chunk is enlarged and the intra-chunk
over-count documented (<5% of total FLOPs).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes [--out DIR]
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro import configs
from repro.configs.base import SHAPES
from repro.dist import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import HW, collective_bytes, model_flops, roofline_terms
from repro.models.registry import build_model
from repro.optim.adamw import AdamWState, adamw_init, adamw_update


def _train_step_fn(api, lr=3e-4):
    cfg = api.cfg

    def loss_fn(params, batch):
        if cfg.fsdp_gather_params:
            compute = sh.gather_for_compute(params, cfg.compute_dtype)
            return api.train_loss(compute, batch)
        return api.train_loss(params, batch)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_state, gnorm = adamw_update(grads, opt_state, params, lr=lr)
        return new_params, new_state, loss, gnorm

    return train_step


def lower_cell(cfg, shape, mesh, *, multi_pod: bool, shape_name: str,
               cache_seq_fallback: bool = True):
    """Lower + compile one (config, shape) cell on ``mesh``. Returns compiled."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    api = build_model(cfg)
    specs = api.input_specs(shape)
    param_shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    p_specs = sh.param_pspecs(param_shapes)

    def ns(spec_tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    with mesh:
        if shape.kind == "train":
            batch_specs = sh.batch_pspecs(specs["batch"], multi_pod=multi_pod)
            opt_shapes = jax.eval_shape(adamw_init, param_shapes)
            o_specs = AdamWState(step=P(), m=p_specs, v=p_specs)
            lowered = jax.jit(
                _train_step_fn(api),
                in_shardings=(ns(p_specs), ns(o_specs), ns(batch_specs)),
                out_shardings=(ns(p_specs), ns(o_specs), NamedSharding(mesh, P()),
                               NamedSharding(mesh, P())),
                donate_argnums=(0, 1),
            ).lower(param_shapes, opt_shapes, specs["batch"])
        elif shape.kind == "prefill":
            batch_specs = sh.batch_pspecs(specs["batch"], multi_pod=multi_pod)

            def prefill_fn(params, batch):
                if cfg.fsdp_gather_params:
                    params = sh.gather_for_compute(params, cfg.compute_dtype)
                return api.prefill(params, batch)

            lowered = jax.jit(
                prefill_fn, in_shardings=(ns(p_specs), ns(batch_specs))
            ).lower(param_shapes, specs["batch"])
        else:
            long_ctx = shape_name.startswith("long")
            cache_specs = sh.cache_pspecs(
                specs["cache"], multi_pod=multi_pod, long_context=long_ctx,
                seq_shard_fallback=cache_seq_fallback,
            )
            if long_ctx:
                tok_specs = P(None, None)
            else:
                tok_specs = sh.batch_pspecs(
                    {"token": specs["token"]}, multi_pod=multi_pod
                )["token"]

            def decode_fn(params, cache, token, pos):
                return api.decode_step(params, cache, token, pos)

            lowered = jax.jit(
                decode_fn,
                in_shardings=(ns(p_specs), ns(cache_specs),
                              NamedSharding(mesh, tok_specs), NamedSharding(mesh, P())),
                donate_argnums=(1,),
            ).lower(param_shapes, specs["cache"], specs["token"], specs["pos"])

        return lowered.compile()


def _extract(compiled, n_dev):
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text(), n_dev)
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            coll)


def _measurement_cfg(cfg, shape, n_units: int):
    """Reduced-depth, fully-unrolled config for cost extraction."""
    unit = cfg.attn_every if (cfg.ssm is not None and cfg.attn_every) else 1
    kw = {"n_layers": n_units * unit, "scan_layers": False}
    if cfg.ssm is not None:
        max_bodies = 32  # heavy SSD bodies
        chunk = max(cfg.ssm.chunk, shape.seq_len // max_bodies)
        kw["ssm"] = dataclasses.replace(cfg.ssm, chunk=chunk)
    if cfg.rwkv is not None:
        max_bodies = 256  # cheap WKV bodies
        chunk = max(cfg.rwkv.chunk, shape.seq_len // max_bodies)
        kw["rwkv"] = dataclasses.replace(cfg.rwkv, chunk=chunk)
    return cfg.replace(**kw)


def _affine(f1, f2, n_units):
    """Depth-affine extrapolation, clamped: a real L-layer program costs at
    least its 2-layer measurement (guards noisy f2 < f1 on depth-independent
    decode cells, which would extrapolate negative)."""
    return max(f1 + (n_units - 1.0) * (f2 - f1), max(f2, 0.0))


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir: Path,
             method_tag: str = "baseline", extrapolate: bool = True,
             cfg_override=None, cache_seq_fallback: bool = True) -> dict:
    cfg = cfg_override if cfg_override is not None else configs.get(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()

    # (1) the REAL program: scanned, remat'd — memory analysis + compilability
    compiled = lower_cell(cfg, shape, mesh, multi_pod=multi_pod, shape_name=shape_name,
                          cache_seq_fallback=cache_seq_fallback)
    mem = compiled.memory_analysis()
    flops_raw, bytes_raw, coll_raw = _extract(compiled, n_dev)

    # (2+3) depth-affine cost extraction on unrolled reduced stacks
    extra = {}
    if extrapolate:
        unit = cfg.attn_every if (cfg.ssm is not None and cfg.attn_every) else 1
        n_units = cfg.n_layers / unit
        c1 = lower_cell(_measurement_cfg(cfg, shape, 1), shape, mesh,
                        multi_pod=multi_pod, shape_name=shape_name,
                        cache_seq_fallback=cache_seq_fallback)
        c2 = lower_cell(_measurement_cfg(cfg, shape, 2), shape, mesh,
                        multi_pod=multi_pod, shape_name=shape_name,
                        cache_seq_fallback=cache_seq_fallback)
        f1, b1, k1 = _extract(c1, n_dev)
        f2, b2, k2 = _extract(c2, n_dev)
        flops = _affine(f1, f2, n_units)
        byts = _affine(b1, b2, n_units)
        coll = {k: _affine(k1[k], k2[k], n_units) for k in k1}
        extra = {"depth_units": n_units, "f1": f1, "f2": f2}
    else:
        flops, byts, coll = flops_raw, bytes_raw, coll_raw

    t_compile = time.time() - t0
    hw = HW(chips=n_dev)
    terms = roofline_terms({"flops": flops, "bytes accessed": byts}, coll, hw)
    mf = model_flops(cfg, shape)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "method": method_tag,
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {"flops": flops, "bytes accessed": byts,
                 "flops_scanned_raw": flops_raw, "bytes_scanned_raw": bytes_raw},
        "collectives": coll,
        "roofline": terms,
        "model_flops_global": mf,
        "model_flops_per_device": mf / n_dev,
        "useful_flops_ratio": (mf / n_dev) / terms["flops_per_device"]
        if terms["flops_per_device"] else None,
        "extrapolation": extra,
    }

    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}__{shape_name}__{result['mesh']}"
    if method_tag != "baseline":
        tag += f"__{method_tag}"
    (out_dir / f"{tag}.json").write_text(json.dumps(result, indent=2))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="benchmarks/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-extrapolate", action="store_true")
    args = ap.parse_args()

    out_dir = Path(args.out)
    cells = configs.cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch, shape_name in cells:
        for mp in meshes:
            mesh_tag = "2x16x16" if mp else "16x16"
            tag = f"{arch}__{shape_name}__{mesh_tag}"
            if args.skip_existing and (out_dir / f"{tag}.json").exists():
                print(f"SKIP {tag}", flush=True)
                continue
            try:
                r = run_cell(arch, shape_name, multi_pod=mp, out_dir=out_dir,
                             extrapolate=not args.no_extrapolate)
                rt = r["roofline"]
                print(
                    f"OK   {tag}: compile={r['compile_s']}s "
                    f"flops/dev={rt['flops_per_device']:.3e} "
                    f"t_comp={rt['t_compute_s']*1e3:.2f}ms "
                    f"t_mem={rt['t_memory_s']*1e3:.2f}ms "
                    f"t_coll={rt['t_collective_s']*1e3:.2f}ms "
                    f"useful={r['useful_flops_ratio'] and round(r['useful_flops_ratio'], 3)}",
                    flush=True,
                )
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"FAIL {tag}: {e}", flush=True)
                traceback.print_exc(limit=4)

    if failures:
        print(f"\n{len(failures)} failures:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nall dry-run cells green")


if __name__ == "__main__":
    main()
