"""Downdates as first-class ops (ISSUE 9): ``RemoveRows`` / ``RemoveCols``
/ ``Window`` — op algebra, planner lowering, exact-reference parity on the
single / batched / truncated / mesh-sharded routes, ill-conditioned
deletions (in-span residual ``r_b -> 0``, repeated singular values),
remove-then-reappend round-trips, the geometry-shrinking ``apply_many``
grouping, serve wiring, and ``dist.merge`` compatibility.

Parity contract (same as every other op): the downdated state's
``materialize()`` must match the top-rank reconstruction of
``op.apply_dense(A)`` — deletion is exact rank-1 algebra, not an
approximation, whenever the data's rank fits the state's budget.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.api import SvdState, UpdatePolicy
from repro.dist.merge import merge_tree
from repro.updates import (
    AppendCols,
    AppendRows,
    Compose,
    Decay,
    RankK,
    RemoveCols,
    RemoveRows,
    Window,
    apply_many,
    lower,
    skeleton_from_spec,
    spec_from_json,
    spec_to_json,
    warmup_plan,
)
from repro.updates.planner import _SCAN_MIN

RNG = np.random.default_rng(909)
REPO = Path(__file__).resolve().parent.parent


def _lowrank(m, n, r, rng=RNG):
    return rng.normal(size=(m, r)) @ rng.normal(size=(r, n))


def _top_r(dense, r):
    u, s, vt = np.linalg.svd(np.asarray(dense), full_matrices=False)
    return (u[:, :r] * s[:r]) @ vt[:r]


def _roomy_state(m, n, data_rank, state_rank, rng=RNG):
    return SvdState.from_dense(jnp.asarray(_lowrank(m, n, data_rank, rng)),
                               rank=state_rank)


def _assert_parity(state, op, *, atol=1e-10):
    out = api.apply(state, op)
    dense = np.asarray(op.apply_dense(np.asarray(state.materialize())))
    rec = _top_r(dense, out.rank)
    np.testing.assert_allclose(np.asarray(out.materialize()), rec, atol=atol)
    return out


# ---------------------------------------------------------------------------
# op algebra: dense semantics, geometry, specs, validation
# ---------------------------------------------------------------------------


def test_remove_dense_semantics_and_geometry():
    a_mat = RNG.normal(size=(5, 4))
    np.testing.assert_allclose(
        np.asarray(RemoveRows((1, 3)).apply_dense(a_mat)),
        np.delete(a_mat, (1, 3), axis=0),
    )
    np.testing.assert_allclose(
        np.asarray(RemoveCols(2).apply_dense(a_mat)),
        np.delete(a_mat, 2, axis=1),
    )
    np.testing.assert_allclose(
        np.asarray(Window(3, lam=0.5).apply_dense(a_mat)),
        0.5 * a_mat[-3:],
    )
    assert RemoveRows((1, 3)).out_shape(5, 4) == (3, 4)
    assert RemoveCols(2).out_shape(5, 4) == (5, 3)
    assert Window(3).out_shape(5, 4) == (3, 4)
    assert Window(9).out_shape(5, 4) == (5, 4)   # already fits: no shrink


def test_remove_batched_dense_semantics():
    a_mat = RNG.normal(size=(3, 5, 4))
    np.testing.assert_allclose(
        np.asarray(RemoveRows((0, 4)).apply_dense(a_mat)),
        np.delete(a_mat, (0, 4), axis=1),
    )
    np.testing.assert_allclose(
        np.asarray(Window(2).apply_dense(a_mat)), a_mat[:, -2:],
    )


def test_remove_idx_normalization_and_validation():
    assert RemoveRows((3, 0, 1)).idx == (0, 1, 3)   # sorted
    assert RemoveCols(np.int64(2)).idx == (2,)      # int-likes accepted
    with pytest.raises(ValueError, match="unique"):
        RemoveRows((1, 1))
    with pytest.raises(ValueError, match="non-negative"):
        RemoveCols((-1,))
    with pytest.raises(ValueError, match="at least one"):
        RemoveRows(())
    with pytest.raises(ValueError, match="size"):
        Window(0)
    with pytest.raises(ValueError, match="out of range"):
        RemoveRows(9).apply_dense(np.zeros((3, 2)))


def test_remove_specs_hashable_json_and_skeletons():
    for op in (RemoveRows((0, 2)), RemoveCols(1), Window(4, lam=0.7)):
        spec = op.spec()
        hash(spec)   # hashable: planner schedule-cache key
        assert spec_from_json(json.loads(json.dumps(spec_to_json(spec)))) == spec
        skel = skeleton_from_spec(spec)
        assert jax.tree.structure(skel) == jax.tree.structure(op)
    # Remove ops are pure metadata: zero array leaves ride the snapshot
    assert jax.tree.leaves(RemoveRows((0, 2))) == []
    assert len(jax.tree.leaves(Window(4, lam=0.7))) == 1


# ---------------------------------------------------------------------------
# planner lowering: schedule shapes, validation
# ---------------------------------------------------------------------------


def test_remove_lowering_steps():
    st = _roomy_state(8, 6, 2, 3)
    plan = lower(RemoveRows((1, 5)), st)
    assert plan == (("rank1", (), "remove_rows", 0),
                    ("rank1", (), "remove_rows", 1),
                    ("drop_rows", (1, 5)))
    plan = lower(Window(6, lam=0.9), st)
    assert plan == (("decay", ()),
                    ("rank1", (), "window_rows", 0),
                    ("rank1", (), "window_rows", 1),
                    ("drop_rows", (0, 1)))
    # fits already: decay fold only, zero engine dispatches
    assert lower(Window(8), st) == (("decay", ()),)


def test_remove_long_runs_lower_to_one_scan():
    st = _roomy_state(_SCAN_MIN + 8, 6, 2, 3)
    idx = tuple(range(_SCAN_MIN))
    plan = lower(RemoveRows(idx), st)
    assert plan == (("rank1_scan", (), "remove_rows", _SCAN_MIN),
                    ("drop_rows", idx))


def test_remove_requires_truncated_state():
    full = SvdState.from_dense(jnp.asarray(_lowrank(4, 5, 2)))
    for op in (RemoveRows(0), RemoveCols(0), Window(3)):
        with pytest.raises(ValueError, match="truncated"):
            api.apply(full, op)


def test_remove_validates_bounds_and_rank():
    st = _roomy_state(6, 5, 2, 4)
    with pytest.raises(ValueError, match="out of range"):
        api.apply(st, RemoveRows(6))
    with pytest.raises(ValueError, match="below the state's rank"):
        api.apply(st, RemoveCols((0, 1)))       # (6, 3) < rank 4
    with pytest.raises(ValueError, match="below the state's rank"):
        api.apply(st, Window(3))                # (3, 5) < rank 4


# ---------------------------------------------------------------------------
# parity: single / truncated routes (the acceptance identity)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("make_op", [
    lambda m, n: RemoveRows(0),
    lambda m, n: RemoveRows((1, m - 1)),
    lambda m, n: RemoveCols((0, n - 2)),
    lambda m, n: Window(m - 2),
    lambda m, n: Window(m - 1, lam=0.9),
    lambda m, n: Compose((Decay(0.8), RemoveRows(2), RemoveCols(1))),
    lambda m, n: Compose((RemoveCols(0), RemoveCols(0))),  # shifting indices
], ids=["rows0", "rows2", "cols2", "window", "window-lam", "mixed",
        "cols-twice"])
@pytest.mark.parametrize("geom", [(8, 6), (6, 8), (9, 9)])
def test_remove_parity_truncated(geom, make_op):
    m, n = geom
    st = _roomy_state(m, n, 2, 4)
    _assert_parity(st, make_op(m, n))


def test_window_equals_decay_plus_remove_rows():
    st = _roomy_state(9, 6, 2, 4)
    win = api.apply(st, Window(6, lam=0.85))
    explicit = api.apply(st, Compose((Decay(0.85), RemoveRows((0, 1, 2)))))
    np.testing.assert_allclose(np.asarray(win.materialize()),
                               np.asarray(explicit.materialize()), atol=1e-10)


def test_remove_scan_parity_matches_unrolled():
    """A >= _SCAN_MIN deletion list (one lax.scan dispatch) matches both the
    dense reference and the unrolled per-index schedule."""
    m, n = _SCAN_MIN + 10, 7
    st = _roomy_state(m, n, 2, 4)
    idx = tuple(range(1, _SCAN_MIN + 1))
    out = _assert_parity(st, RemoveRows(idx), atol=1e-9)
    unrolled = st
    for k, j in enumerate(idx):
        unrolled = api.apply(unrolled, RemoveRows(j - k))  # indices shift
    np.testing.assert_allclose(np.asarray(out.materialize()),
                               np.asarray(unrolled.materialize()), atol=1e-9)


def test_remove_then_reappend_round_trip():
    """Delete rows, then append fresh ones: the workhorse sliding-stream
    cycle.  Parity against the dense reference end-to-end."""
    rng = np.random.default_rng(3)
    m, n = 8, 6
    dense = _lowrank(m, n, 2, rng)
    st = SvdState.from_dense(jnp.asarray(dense), rank=4)
    new_rows = rng.normal(size=(2, m)) @ dense      # stays in the row space
    op = Compose((RemoveRows((0, 1)), AppendRows(new_rows)))
    out = _assert_parity(st, op)
    assert out.geometry[:2] == (m, n)


def test_remove_parity_against_dense_svd_of_deleted_matrix():
    """The literal acceptance sentence: api.apply(state, RemoveCols(idx))
    .materialize() == dense SVD of the column-deleted matrix."""
    dense = _lowrank(7, 9, 3)
    st = SvdState.from_dense(jnp.asarray(dense), rank=5)
    out = api.apply(st, RemoveCols((2, 6)))
    u, s, vt = np.linalg.svd(np.delete(dense, (2, 6), axis=1),
                             full_matrices=False)
    rec = (u[:, :5] * s[:5]) @ vt[:5]
    np.testing.assert_allclose(np.asarray(out.materialize()), rec, atol=1e-10)


# ---------------------------------------------------------------------------
# ill-conditioning: in-span deletions, repeated singular values
# ---------------------------------------------------------------------------


def test_remove_column_exactly_in_span():
    """Removing a column whose indicator e_j lies EXACTLY in span(V) drives
    the augmentation residual r_b to 0 — the engine's guarded normalization
    (residual > 1e-12 gate) must keep the downdate finite and exact."""
    rng = np.random.default_rng(5)
    m, n = 7, 6
    # A = u1 e_2^T + u2 w^T with w ⊥ e_2: V-span contains e_2 exactly
    e2 = np.zeros(n); e2[2] = 1.0
    w = rng.normal(size=n); w[2] = 0.0
    dense = np.outer(rng.normal(size=m), e2) + np.outer(rng.normal(size=m), w)
    st = SvdState.from_dense(jnp.asarray(dense), rank=4)
    out = api.apply(st, RemoveCols(2))
    got = np.asarray(out.materialize())
    assert np.isfinite(got).all()
    np.testing.assert_allclose(
        got, _top_r(np.delete(dense, 2, axis=1), 4), atol=1e-9)


def test_remove_nearly_in_span_column():
    """r_b -> 0 continuously: perturb the in-span construction by eps and
    pin the error budget explicitly."""
    rng = np.random.default_rng(6)
    m, n = 7, 6
    e2 = np.zeros(n); e2[2] = 1.0
    w = rng.normal(size=n); w[2] = 0.0
    for eps in (1e-6, 1e-10, 1e-13):
        dense = (np.outer(rng.normal(size=m), e2)
                 + np.outer(rng.normal(size=m), w)
                 + eps * np.outer(rng.normal(size=m), rng.normal(size=n)))
        st = SvdState.from_dense(jnp.asarray(dense), rank=4)
        got = np.asarray(api.apply(st, RemoveCols(2)).materialize())
        assert np.isfinite(got).all()
        # the deleted matrix has rank <= 3 + an eps-sized tail the rank-4
        # state absorbs; near-defective spectra amplify cancellation noise
        # to ~1e-7, so the budget here is looser than the exact-span case
        np.testing.assert_allclose(
            got, _top_r(np.delete(dense, 2, axis=1), 4), atol=1e-6)


def test_remove_row_with_repeated_singular_values():
    """Downdating a state with degenerate spectrum (repeated s_i) exercises
    the secular solver's clustered-root path."""
    rng = np.random.default_rng(7)
    m, n, r = 8, 6, 4
    qu, _ = np.linalg.qr(rng.normal(size=(m, r)))
    qv, _ = np.linalg.qr(rng.normal(size=(n, r)))
    s = np.array([3.0, 3.0, 3.0, 1.0])      # triple singular value
    dense = (qu * s) @ qv.T
    st = SvdState.from_dense(jnp.asarray(dense), rank=r + 1)
    got = np.asarray(api.apply(st, RemoveRows((0, 3))).materialize())
    assert np.isfinite(got).all()
    np.testing.assert_allclose(
        got, _top_r(np.delete(dense, (0, 3), axis=0), r + 1), atol=1e-9)


def test_remove_zero_column_is_a_no_op_downdate():
    """Deleting an all-zero column: the rank-1 step is a strict no-op
    (a = 0) and only the geometry shrinks."""
    rng = np.random.default_rng(8)
    dense = _lowrank(6, 5, 2, rng)
    dense[:, 3] = 0.0
    st = SvdState.from_dense(jnp.asarray(dense), rank=3)
    _assert_parity(st, RemoveCols(3))


# ---------------------------------------------------------------------------
# batched routes: stacked states, apply_many geometry-shrinking groups
# ---------------------------------------------------------------------------


def _stack(states):
    return SvdState(u=jnp.stack([s.u for s in states]),
                    s=jnp.stack([s.s for s in states]),
                    v=jnp.stack([s.v for s in states]))


@pytest.mark.parametrize("op", [
    RemoveRows((0, 4)), RemoveCols(1), Window(5, lam=0.9),
], ids=["rows", "cols", "window"])
def test_remove_parity_batched_stacked(op):
    rng = np.random.default_rng(12)
    sts = [_roomy_state(7, 6, 2, 4, rng) for _ in range(3)]
    out = api.apply(_stack(sts), op)
    for j, st in enumerate(sts):
        ref = _top_r(op.apply_dense(np.asarray(st.materialize())), 4)
        np.testing.assert_allclose(np.asarray(out.materialize())[j], ref,
                                   atol=1e-10)


def test_apply_many_groups_shrinking_schedules():
    """The ISSUE small-fix audit, pinned: same-(geometry, plan) downdates
    take the batched group path — whose rank-1 pairs bind from the STATE,
    not per-member op data — and match per-state singles exactly."""
    rng = np.random.default_rng(13)
    sts = [_roomy_state(7, 6, 2, 4, rng) for _ in range(4)]
    ops = [RemoveRows((1, 5))] * 4
    outs = apply_many(sts, ops)
    singles = [api.apply(st, op) for st, op in zip(sts, ops)]
    for got, want in zip(outs, singles):
        assert got.geometry[:2] == (5, 6)
        np.testing.assert_allclose(np.asarray(got.materialize()),
                                   np.asarray(want.materialize()), atol=1e-10)


def test_apply_many_mixed_shrinking_and_preserving_groups():
    """Different plans (and different post-op geometries) never share a
    group; every member still matches its own single-path result."""
    rng = np.random.default_rng(14)
    sts = [_roomy_state(7, 6, 2, 3, rng) for _ in range(5)]
    ops = [RemoveRows(0), RemoveRows(0), RemoveCols((1, 2)),
           Window(5, lam=0.5),
           RankK(rng.normal(size=(7, 2)), rng.normal(size=(6, 2)))]
    outs = apply_many(sts, ops)
    for st, op, got in zip(sts, ops, outs):
        want = api.apply(st, op)
        assert got.geometry == want.geometry
        np.testing.assert_allclose(np.asarray(got.materialize()),
                                   np.asarray(want.materialize()), atol=1e-10)


def test_apply_many_batched_scan_group():
    """Long deletion lists group-batch through ONE scanned dispatch."""
    rng = np.random.default_rng(15)
    m = _SCAN_MIN + 6
    sts = [_roomy_state(m, 6, 2, 3, rng) for _ in range(3)]
    ops = [RemoveRows(tuple(range(_SCAN_MIN)))] * 3
    outs = apply_many(sts, ops)
    for st, op, got in zip(sts, ops, outs):
        ref = _top_r(op.apply_dense(np.asarray(st.materialize())), 3)
        np.testing.assert_allclose(np.asarray(got.materialize()), ref,
                                   atol=1e-9)


# ---------------------------------------------------------------------------
# warmup / planner bookkeeping through shrinking geometries
# ---------------------------------------------------------------------------


def test_warmup_plan_tracks_shrinking_geometries():
    pol = UpdatePolicy()
    op = Compose((RemoveRows((0, 1)), RemoveCols(0)))
    geoms = warmup_plan(pol, op, m=8, n=6, rank=3)
    # remove steps dispatch at the PRE-drop geometry of each stage
    assert geoms == [(8, 6), (6, 6)]


# ---------------------------------------------------------------------------
# serve wiring: enqueue_op validation + flush parity
# ---------------------------------------------------------------------------


def test_serve_enqueue_remove_and_window():
    from repro.serve.svd_service import SvdService

    rng = np.random.default_rng(21)
    svc = SvdService(max_batch=64)
    dense = {}
    for sid in ("a", "b"):
        d = _lowrank(8, 6, 2, rng)
        dense[sid] = d
        svc.register(sid, SvdState.from_dense(jnp.asarray(d), rank=3))
    svc.enqueue_op("a", RemoveRows((0, 5)))
    svc.enqueue_op("a", Window(5, lam=0.9))
    svc.enqueue_op("b", RemoveCols(2))
    assert svc._effective_shape("a") == (5, 6)
    assert svc._effective_shape("b") == (8, 5)
    while svc.flush():
        pass
    ref_a = Window(5, lam=0.9).apply_dense(
        RemoveRows((0, 5)).apply_dense(dense["a"]))
    ref_b = RemoveCols(2).apply_dense(dense["b"])
    for sid, ref in (("a", ref_a), ("b", ref_b)):
        np.testing.assert_allclose(
            np.asarray(svc.state(sid).materialize()),
            _top_r(np.asarray(ref), 3), atol=1e-9)


def test_serve_enqueue_remove_validation():
    from repro.serve.svd_service import SvdService

    svc = SvdService()
    svc.register("s", _roomy_state(6, 5, 2, 3))
    with pytest.raises(ValueError, match="out of range"):
        svc.enqueue_op("s", RemoveRows(6))
    with pytest.raises(ValueError, match="below its rank"):
        svc.enqueue_op("s", RemoveCols((0, 1, 2)))
    # validation runs against the EFFECTIVE (post-queue) geometry
    svc.enqueue_op("s", RemoveRows((0, 1)))
    with pytest.raises(ValueError, match="out of range"):
        svc.enqueue_op("s", RemoveRows(4))      # only 4 rows will remain
    # pairs enqueued after a queued downdate must match the shrunk geometry
    with pytest.raises(ValueError, match="geometry"):
        svc.enqueue("s", jnp.zeros(6), jnp.zeros(5))
    svc.enqueue("s", jnp.zeros(4), jnp.zeros(5))


# ---------------------------------------------------------------------------
# dist.merge compatibility: downdated shards merge like any truncated state
# ---------------------------------------------------------------------------


def test_merge_tree_after_downdates():
    """Shards that shrank by different amounts still merge: row blocks
    concatenate in order, and the merged SVD matches the dense stack."""
    rng = np.random.default_rng(31)
    base = _lowrank(12, 6, 2, rng)
    st0 = SvdState.from_dense(jnp.asarray(base[:6]), rank=4)
    st1 = SvdState.from_dense(jnp.asarray(base[6:]), rank=4)
    down0 = api.apply(st0, RemoveRows(1))
    down1 = api.apply(st1, Window(4, lam=1.0))
    merged = merge_tree([down0, down1], rank=4)
    ref = np.concatenate([np.delete(base[:6], 1, axis=0), base[6:][-4:]])
    np.testing.assert_allclose(np.asarray(merged.materialize()),
                               _top_r(ref, 4), atol=1e-9)


# ---------------------------------------------------------------------------
# mesh-sharded route (8 fake devices, subprocess)
# ---------------------------------------------------------------------------


def test_mesh_sharded_downdate_parity_on_8_devices():
    script = textwrap.dedent("""
        import json
        import jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp, numpy as np
        from repro import api
        from repro.updates import RemoveCols, RemoveRows, Window

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        B, m, n, r = 8, 7, 6, 3

        def lowrank(m, n, q):
            return rng.normal(size=(m, q)) @ rng.normal(size=(q, n))

        dense = np.stack([lowrank(m, n, 2) for _ in range(B)])
        sts = [api.SvdState.from_dense(jnp.asarray(d), rank=r) for d in dense]
        stacked = api.SvdState(
            u=jnp.stack([s.u for s in sts]),
            s=jnp.stack([s.s for s in sts]),
            v=jnp.stack([s.v for s in sts]),
        )
        pol = api.UpdatePolicy(method="direct", mesh=mesh, batch_axis="data")

        def top_r(d, k):
            u, s, vt = np.linalg.svd(d, full_matrices=False)
            return (u[:, :k] * s[:k]) @ vt[:k]

        errs = {}
        for name, op in [("rows", RemoveRows((0, 4))),
                         ("cols", RemoveCols(1)),
                         ("window", Window(5, lam=0.9))]:
            out = api.apply(stacked, op, pol)
            e = 0.0
            for i in range(B):
                ref = top_r(np.asarray(op.apply_dense(dense[i])), r)
                e = max(e, float(np.abs(
                    np.asarray(out.materialize()[i]) - ref).max()))
            errs[name] = e
        errs["devices"] = jax.device_count()
        print(json.dumps(errs))
    """)
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=420,
        env={
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
            "PYTHONPATH": str(REPO / "src"),
            "PATH": "/usr/bin:/bin",
            "JAX_PLATFORMS": "cpu",
            "HOME": "/tmp",
        },
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-4000:]}"
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    for name in ("rows", "cols", "window"):
        assert out[name] < 1e-8, (name, out[name])
