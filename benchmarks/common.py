"""Shared benchmark helpers: timing, CSV emission, the environment stamp
every BENCH_*.json carries, and the open-loop latency harness (Poisson
arrivals + enqueue-to-visible percentiles) used by bench_serve and
bench_fleet."""

from __future__ import annotations

import datetime
import time

import numpy as np

import jax

from repro import obs as _obs


def bench_metadata() -> dict:
    """The environment block stamped into every BENCH_*.json (DESIGN.md
    §15): enough to tell two artifacts apart without rerunning them."""
    dev = jax.devices()[0]
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": dev.device_kind,
        "device_count": jax.device_count(),
        "timestamp_utc": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
    }


def time_fn(fn, *args, warmup: int = 2, iters: int = 7) -> float:
    """Min wall time (us) of fn(*args) with block_until_ready.

    Min, not median: scheduler noise on a shared box is strictly additive,
    so the fastest repetition is the best estimate of the true cost.
    """
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6


def time_host_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> None:
    """One CSV result row; with ``repro.obs`` enabled the row is also
    recorded as a ``bench_us{bench=name}`` gauge so benchmark results and
    runtime telemetry share one export surface."""
    print(f"{name},{us:.1f},{derived}", flush=True)
    if _obs.enabled():
        _obs.registry().gauge("bench_us", bench=name).set(us)


# ---------------------------------------------------------------------------
# open-loop latency harness (DESIGN.md §13)
# ---------------------------------------------------------------------------


def poisson_arrivals(rate_hz: float, count: int, *, seed: int = 0) -> list[float]:
    """``count`` cumulative arrival times (s) of a Poisson process at
    ``rate_hz`` — the open-loop load model: arrivals do NOT wait for the
    system (a closed loop hides queueing delay by self-throttling)."""
    rng = np.random.default_rng(seed)
    return list(np.cumsum(rng.exponential(1.0 / rate_hz, size=count)))


def percentile(xs, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) — no interpolation, so the
    reported p99 is a latency that actually happened."""
    if not len(xs):
        raise ValueError("no samples")
    ordered = sorted(xs)
    k = max(0, min(len(ordered) - 1, int(np.ceil(q / 100.0 * len(ordered))) - 1))
    return ordered[k]


def latency_summary(lat_s) -> dict:
    """p50/p99/mean/max (us) of enqueue-to-visible samples, JSON-ready."""
    lat_us = [t * 1e6 for t in lat_s]
    return {
        "samples": len(lat_us),
        "p50_us": percentile(lat_us, 50),
        "p99_us": percentile(lat_us, 99),
        "mean_us": float(np.mean(lat_us)),
        "max_us": max(lat_us),
    }


def open_loop(enqueue, tick, drain, events, arrivals) -> dict:
    """Drive ``events`` at ``arrivals`` (open loop) and measure
    enqueue-to-visible latency per event.

    ``enqueue(event) -> token``: admit one event, return its visibility
    token.  ``tick() -> iterable[token]``: one event-loop turn (pump/poll) —
    called continuously while waiting for the next arrival, so visibility is
    stamped with sub-millisecond lag.  ``drain()``: stop-admission barrier;
    after it, remaining tokens must surface through ``tick``.

    Returns ``latency_summary`` plus the offered/sustained rates.  Late
    arrivals are NOT dropped: if the system falls behind, the queueing
    delay lands in the tail percentiles — that is the point of open loop.
    """
    sent: dict = {}
    lat: list[float] = []

    def reap():
        now = time.perf_counter()
        for tok in tick():
            lat.append(now - sent.pop(tok))

    t0 = time.perf_counter()
    for ev, due in zip(events, arrivals):
        while True:
            reap()
            wait = t0 + due - time.perf_counter()
            if wait <= 0:
                break
            time.sleep(min(wait, 5e-4))
        sent[enqueue(ev)] = time.perf_counter()
    drain()
    deadline = time.perf_counter() + 30.0
    while sent and time.perf_counter() < deadline:
        reap()
    wall = time.perf_counter() - t0
    if sent:
        raise RuntimeError(f"{len(sent)} tokens never became visible")
    out = latency_summary(lat)
    out["offered_rate_hz"] = len(events) / arrivals[-1]
    out["sustained_rate_hz"] = len(events) / wall
    return out
