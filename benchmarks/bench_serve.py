"""Async vs. synchronous flush throughput of ``serve.SvdService`` (DESIGN.md §9).

The service's double-buffered dispatch lets the host assemble and dispatch
round k+1 while the device still computes round k; the synchronous baseline
(``max_in_flight=0``) blocks on every round's outputs before returning.
This bench feeds identical traffic (STREAMS streams x ROUNDS events each,
auto-flushing batched rounds) through both modes and reports two numbers:

* end-to-end updates/s (feed + drain): the async mode overlaps round k's
  device compute with round k+1's host-side batch assembly. On this CPU
  container the two run within scheduler noise of each other (parity to
  ~1.2x run-to-run; modes are interleaved and best-of-REPEAT to damp
  drift) — the overlap window that makes the double buffer pay is an
  accelerator property, where device rounds are long and the host is free;
* worst-case enqueue stall, recorded for observability. On CPU it is
  dominated by the host-side ``jnp.stack`` batch assembly that both modes
  pay, so expect parity here; the sync-mode device wait it would expose
  only dominates on accelerator backends.

A third experiment reports the latency SLO view: Poisson open-loop arrivals
at LOAD x the async sustained rate through ``common.open_loop`` (the same
harness bench_fleet uses), with per-event enqueue-to-visible p50/p99.

A fourth arm (ISSUE 10) re-runs the async pass fully instrumented —
``repro.obs`` metrics + span tracing + health sampling — and reports the
observability overhead (acceptance: <= 2% throughput regression) together
with export validity checks (Chrome trace parses and contains flush-round
spans; Prometheus text carries cache counters and >= 3 health gauges).

CSV rows (benchmarks/run.py style):
  bench_serve/<mode>/B=<streams>,us,updates_per_s=... max_enqueue_us=...
  bench_serve/latency/<mode>,p99_us,p50_us=... rate_hz=...
  bench_serve/obs/B=<streams>,us,overhead_vs_async=...

and a machine-readable summary at benchmarks/BENCH_serve.json (stamped
with ``common.bench_metadata``).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

import jax.numpy as jnp

from benchmarks.common import bench_metadata, emit, open_loop, poisson_arrivals
from repro import obs
from repro.api import SvdState, UpdatePolicy
from repro.serve import SvdService

# Geometry where a flush round carries real device work (tall factors):
# below ~(256, 384) the CPU round is host-assembly-bound and async == sync.
M, N, RANK = 512, 768, 16
STREAMS = 16
ROUNDS = 8             # events per stream
REPEAT = 5

OPEN_EVENTS = 128      # open-loop latency experiment length
LOAD = 0.5             # offered rate as a fraction of async sustained rate

OUT = Path(__file__).parent / "BENCH_serve.json"


def _service(max_in_flight: int, *, health_every: int | None = None) -> SvdService:
    rng = np.random.default_rng(0)
    svc = SvdService(
        max_batch=STREAMS,
        max_in_flight=max_in_flight,
        policy=UpdatePolicy(method="direct", health_every=health_every),
    )
    for i in range(STREAMS):
        svc.register(
            f"s{i}",
            SvdState.from_factors(
                np.linalg.qr(rng.normal(size=(M, RANK)))[0],
                np.sort(np.abs(rng.normal(size=RANK)))[::-1].copy(),
                np.linalg.qr(rng.normal(size=(N, RANK)))[0],
            ),
        )
    return svc


def _traffic():
    rng = np.random.default_rng(1)
    return [
        (f"s{i % STREAMS}",
         jnp.asarray(rng.normal(size=M)), jnp.asarray(rng.normal(size=N)))
        for i in range(STREAMS * ROUNDS)
    ]


def _one_pass(max_in_flight: int, traffic,
              health_every: int | None = None) -> tuple[float, float, SvdService]:
    """(wall seconds, worst single-enqueue seconds, service) for one feed+drain.

    A fresh service per pass (same initial streams), but the policy-derived
    default engine is process-shared — the plan cache stays warm across
    passes, so steady-state dispatch is what gets timed.
    """
    svc = _service(max_in_flight, health_every=health_every)
    stall = 0.0
    t0 = time.perf_counter()
    for sid, a, b in traffic:
        e0 = time.perf_counter()
        svc.enqueue(sid, a, b)
        stall = max(stall, time.perf_counter() - e0)
    svc.drain()
    return time.perf_counter() - t0, stall, svc


def _latency(max_in_flight: int, rate_hz: float, *, seed: int) -> dict:
    """Enqueue-to-visible p50/p99 under Poisson open-loop load at rate_hz."""
    svc = _service(max_in_flight)
    traffic = _traffic()[:OPEN_EVENTS]
    arrivals = poisson_arrivals(rate_hz, OPEN_EVENTS, seed=seed)
    return open_loop(
        lambda ev: svc.enqueue(*ev), svc.take_visible, svc.drain,
        traffic, arrivals,
    )


def _obs_arm(traffic) -> dict:
    """The fully-instrumented pass: obs metrics + span tracing + health
    sampling ON, same traffic as the async arm.  Validates the exports
    (Chrome trace JSON, Prometheus text) and reports throughput relative to
    the uninstrumented async arm — the ISSUE 10 acceptance is <= 2%
    regression while emitting flush-round spans, cache counters and >= 3
    health gauges.

    The comparison is drift-proof the same way the sync/async arms are:
    plain and instrumented passes INTERLEAVE inside one window and each
    side keeps its best, so a slow-machine minute hits both equally.  One
    untimed instrumented pass first absorbs the health-probe jit compile
    (a one-time cost, not steady-state overhead)."""
    obs.registry().reset()
    obs.clear_trace()

    def _instrumented():
        obs.enable()
        obs.start_tracing()
        try:
            return _one_pass(2, traffic, health_every=ROUNDS)
        finally:
            obs.stop_tracing()
            obs.disable()

    _instrumented()                     # absorb probe compile, warm spans
    best = None
    plain_s = float("inf")
    for _ in range(REPEAT):
        plain_s = min(plain_s, _one_pass(2, traffic)[0])
        t, stall, svc = _instrumented()
        if best is None or t < best[0]:
            best = (t, stall, svc)
    t, stall, svc = best

    trace = json.loads(obs.chrome_trace())
    span_names = {e["name"] for e in trace["traceEvents"]}
    prom = obs.registry().to_prometheus()
    health = sorted({
        m.name for m in obs.registry().series()
        if m.name.startswith("health_") and m.kind == "gauge"
    })
    checks = {
        "trace_has_flush_round": "flush_round" in span_names,
        "prom_has_cache_counters":
            "engine_plan_cache_hits_total" in prom,
        "health_gauges_ge_3": len(health) >= 3,
    }
    ups = len(traffic) / t
    overhead = t / plain_s - 1.0
    emit(f"bench_serve/obs/B={STREAMS}", t * 1e6,
         f"updates_per_s={ups:.0f} overhead_vs_async={overhead * 100:.1f}% "
         f"spans={len(trace['traceEvents'])}")
    return {
        "seconds": t,
        "plain_async_seconds": plain_s,
        "updates_per_s": ups,
        "overhead_vs_async": overhead,
        "trace_events": len(trace["traceEvents"]),
        "span_names": sorted(span_names),
        "prometheus_lines": len(prom.splitlines()),
        "health_gauges": health,
        "checks": checks,
    }


def run() -> dict:
    traffic = _traffic()
    obs.disable()              # the sync/async arms time the UNinstrumented path
    _one_pass(0, traffic)      # warm the shared plan cache (compile round)

    # Interleave the modes so slow machine drift hits both equally; keep the
    # best pass per mode, with stats from that SAME pass so the JSON
    # artifact is internally consistent.
    best = {"sync": None, "async": None}
    for _ in range(REPEAT):
        for mode, mif in (("sync", 0), ("async", 2)):
            t, stall, svc = _one_pass(mif, traffic)
            if best[mode] is None or t < best[mode][0]:
                best[mode] = (t, stall, svc)

    results = {}
    runs = {"sync": best["sync"], "async": best["async"]}
    for mode, (t, stall, svc) in runs.items():
        ups = len(traffic) / t
        results[mode] = {
            "max_in_flight": svc.max_in_flight,
            "seconds": t,
            "updates_per_s": ups,
            "max_enqueue_stall_us": stall * 1e6,
            "flush_rounds": svc.stats.rounds,
            "backpressure_waits": svc.stats.backpressure_waits,
            "in_flight_peak": svc.stats.in_flight_peak,
        }
        emit(
            f"bench_serve/{mode}/B={STREAMS}",
            t * 1e6,
            f"updates_per_s={ups:.0f} max_enqueue_us={stall * 1e6:.0f}",
        )

    # open-loop latency columns (shared harness with bench_fleet)
    rate = LOAD * results["async"]["updates_per_s"]
    for mode, mif in (("sync", 0), ("async", 2)):
        _latency(mif, rate, seed=2)                 # warm the shapes
        lat = _latency(mif, rate, seed=3)           # measured
        results[mode]["latency"] = lat
        emit(f"bench_serve/latency/{mode}", lat["p99_us"],
             f"p50_us={lat['p50_us']:.0f} rate_hz={rate:.0f} "
             f"sustained_hz={lat['sustained_rate_hz']:.0f}")

    throughput_speedup = results["sync"]["seconds"] / results["async"]["seconds"]
    stall_ratio = (results["sync"]["max_enqueue_stall_us"]
                   / results["async"]["max_enqueue_stall_us"])
    emit(f"bench_serve/speedup/B={STREAMS}", results["async"]["seconds"] * 1e6,
         f"async_vs_sync={throughput_speedup:.2f}x "
         f"enqueue_stall_reduction={stall_ratio:.1f}x")
    obs_arm = _obs_arm(traffic)
    summary = {
        "meta": bench_metadata(),
        "m": M,
        "n": N,
        "rank": RANK,
        "streams": STREAMS,
        "events": len(traffic),
        "sync": results["sync"],
        "async": results["async"],
        "obs": obs_arm,
        "async_vs_sync_throughput": throughput_speedup,
        "enqueue_stall_reduction": stall_ratio,
        "accept": {
            "obs_overhead_le_2pct": obs_arm["overhead_vs_async"] <= 0.02,
            **obs_arm["checks"],
        },
    }
    OUT.write_text(json.dumps(summary, indent=2) + "\n")
    print(f"wrote {OUT}")
    return summary


if __name__ == "__main__":
    run()
