"""Streaming rank-1 SVD-update service: async micro-batched engine flushes,
checkpointable to disk (DESIGN.md §9).

The serving story for the paper's machinery: many concurrent streams (one
per user/session/adapter) each own a truncated ``repro.api.SvdState`` that
evolves by rank-1 updates — personalization vectors folding into low-rank
adapters, per-tenant gradient sketches, online covariance trackers. Issuing
those updates one at a time wastes the hardware; this service queues them
and flushes *one batched engine call* per round:

    svc = SvdService(max_batch=64, policy=UpdatePolicy(method="auto"))
    svc.register("user-1", api.SvdState.from_dense(m1, rank=8))
    svc.enqueue("user-1", a, b)        # cheap: just queues
    svc.enqueue("user-2", a2, b2)
    svc.flush()                        # one batched truncated update
    svc.save("/ckpts/svd", step=1)     # versioned snapshot; survives restart

* Per-stream ordering: a stream's queued pairs are applied in FIFO order;
  each flush round takes at most one pending pair per stream (they are
  sequential updates to the same state, so they cannot share a batch).
* Micro-batching: ``enqueue`` auto-flushes once ``max_batch`` streams have
  a pending pair. Batches are padded up to bucket sizes (powers of two) so
  the engine's plan cache sees a handful of geometries, not every B.
* Async double-buffered flushing: a flush round *dispatches* its batched
  engine call and returns — stream states become JAX async futures and the
  host keeps enqueueing while the device computes. Dispatched rounds are
  tracked in an in-flight buffer; once ``max_in_flight`` rounds are
  outstanding, the next round first blocks on the oldest (backpressure),
  so the host can never run unboundedly ahead of the device.
  ``jax.block_until_ready`` is otherwise only issued at the explicit
  barriers: ``drain()`` and ``snapshot()``.
* Checkpointing: ``snapshot()`` captures the whole service — every stream's
  ``SvdState``, every pending FIFO, the policy and the batching config — as
  a versioned ``ServiceSnapshot`` pytree; ``save``/``restore`` persist it
  through ``train.checkpoint`` (atomic, checksummed, self-describing via
  the aux spec). Restore is **exact**: a restored service produces bitwise
  the same factors as one that never stopped (DESIGN.md §9 contract,
  ``tests/test_serve_checkpoint.py``).
* Policy: an ``UpdatePolicy`` names the numerics (method/fmm_p/...) and the
  placement — ``policy.mesh`` spreads every flush's batch axis over the
  mesh via the engine's shard_map dispatch.  A legacy ``engine=`` override
  wins over the policy-derived engine.  The mesh is *runtime placement*,
  not state: snapshots record that a mesh was set but never serialize it —
  pass ``mesh=`` (or a full ``policy=``) to ``restore`` on the new topology.
* Multi-worker: per-worker shard streams combine into one global truncated
  SVD via ``merge_streams`` (the ``repro.dist.merge`` log-depth tree).

The LM engine (``serve.engine``) serves tokens; this serves spectra.
"""

from __future__ import annotations

import dataclasses
import threading
import warnings
from collections import OrderedDict, deque
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import SvdState, UpdatePolicy, as_state
from repro.api.update import engine_from_key
from repro.core.engine import (
    SvdEngine,
    group_indices,
    stack_trees,
    truncated_geometry,
    unstack_tree,
)
from repro.core.svd_update import TruncatedSvd
from repro.dist.merge import merge_tree
from repro.train import checkpoint as _checkpoint

__all__ = [
    "SNAPSHOT_VERSION",
    "ServiceSnapshot",
    "SvdService",
    "SvdServiceStats",
]

SNAPSHOT_VERSION = 1
_SNAPSHOT_FORMAT = "repro.serve.ServiceSnapshot"

# UpdatePolicy fields a snapshot records verbatim. ``mesh`` is deliberately
# absent: it names live devices of THIS process; the restoring process
# supplies its own (see module doc).
_POLICY_SPEC_FIELDS = (
    "method",
    "fmm_p",
    "sign_fix",
    "deflate_rtol",
    "precision",
    "batch_axis",
    "truncate_to",
)


def _policy_spec(policy: UpdatePolicy) -> dict:
    spec = {f: getattr(policy, f) for f in _POLICY_SPEC_FIELDS}
    spec["had_mesh"] = policy.mesh is not None
    return spec


def _policy_from_spec(spec: dict, mesh=None) -> UpdatePolicy:
    return UpdatePolicy(mesh=mesh, **{f: spec[f] for f in _POLICY_SPEC_FIELDS})


@dataclass
class SvdServiceStats:
    enqueued: int = 0
    applied: int = 0
    flushes: int = 0
    rounds: int = 0          # batched engine calls (one per geometry group)
    max_batch: int = 0       # largest batch (incl. bucket padding) dispatched
    backpressure_waits: int = 0   # rounds that had to wait for an older one
    in_flight_peak: int = 0       # most rounds ever outstanding at once


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["states", "pending_a", "pending_b"],
    meta_fields=[
        "version",
        "stream_ids",
        "policy_spec",
        "max_batch",
        "pad_to_bucket",
        "max_in_flight",
        "stats",
    ],
)
@dataclasses.dataclass(frozen=True)
class ServiceSnapshot:
    """Versioned, self-describing capture of a whole ``SvdService``.

    A registered pytree: the array leaves are every stream's (u, s, v)
    factors plus its pending FIFO stacked as two ``(k_i, m)`` / ``(k_i, n)``
    arrays (FIFO order preserved along the leading axis; ``k_i = 0`` streams
    carry empty arrays).  Everything non-array — stream ids, the policy
    spec, bucket/backpressure config, stats counters — is pytree metadata,
    mirrored into the JSON ``aux`` spec so a fresh process can rebuild the
    tree structure before it has loaded a single array (``skeleton``).

    Versioning: ``version`` is written into both the pytree and the aux
    spec; ``load`` refuses snapshots newer than this build understands and
    upgrades older ones in place (none exist yet — v1 is the first format).
    """

    states: tuple          # tuple[SvdState, ...] — diagnostics-free, per stream
    pending_a: tuple       # tuple[(k_i, m_i) array, ...] queued a-vectors, FIFO
    pending_b: tuple       # tuple[(k_i, n_i) array, ...] queued b-vectors, FIFO
    version: int = SNAPSHOT_VERSION
    stream_ids: tuple = ()
    policy_spec: tuple = ()   # tuple of (field, value) pairs (hashable meta)
    max_batch: int = 64
    pad_to_bucket: bool = True
    max_in_flight: int = 2
    stats: tuple = ()         # SvdServiceStats counters as (name, value) pairs

    def aux(self) -> dict:
        """The JSON spec persisted next to the arrays (checkpoint ``aux=``)."""
        return {
            "format": _SNAPSHOT_FORMAT,
            "version": self.version,
            "stream_ids": list(self.stream_ids),
            "policy": dict(self.policy_spec),
            "max_batch": self.max_batch,
            "pad_to_bucket": self.pad_to_bucket,
            "max_in_flight": self.max_in_flight,
            "stats": dict(self.stats),
        }

    @classmethod
    def skeleton(cls, aux: dict) -> "ServiceSnapshot":
        """A structure-only snapshot (placeholder leaves) built from an aux
        spec — its treedef is what ``load`` unflattens restored leaves into."""
        n = len(aux["stream_ids"])
        return cls(
            states=tuple(SvdState(u=0.0, s=0.0, v=0.0) for _ in range(n)),
            pending_a=tuple(0.0 for _ in range(n)),
            pending_b=tuple(0.0 for _ in range(n)),
            version=aux["version"],
            stream_ids=tuple(aux["stream_ids"]),
            policy_spec=tuple((k, v) for k, v in aux["policy"].items()),
            max_batch=aux["max_batch"],
            pad_to_bucket=aux["pad_to_bucket"],
            max_in_flight=aux["max_in_flight"],
            stats=tuple((k, v) for k, v in aux["stats"].items()),
        )

    def save(self, ckpt_dir, step: int, *, keep: int = 3):
        """Persist through ``train.checkpoint`` (atomic + checksummed)."""
        return _checkpoint.save(ckpt_dir, step, self, aux=self.aux())

    @classmethod
    def load(cls, ckpt_dir, step: int | None = None) -> tuple[int, "ServiceSnapshot"]:
        """Load ``(step, snapshot)`` from a checkpoint directory.

        Leaves come back exactly as saved (numpy, bitwise-identical — no
        dtype cast, no device transfer); they join device computation on
        the first flush after restore.
        """
        step, aux = _checkpoint.load_aux(ckpt_dir, step)
        if aux is None or aux.get("format") != _SNAPSHOT_FORMAT:
            raise ValueError(
                f"checkpoint at step {step} is not a ServiceSnapshot "
                f"(aux format: {None if aux is None else aux.get('format')!r})"
            )
        if aux["version"] > SNAPSHOT_VERSION:
            raise ValueError(
                f"snapshot version {aux['version']} is newer than this build "
                f"understands (<= {SNAPSHOT_VERSION})"
            )
        _, leaves = _checkpoint.restore(ckpt_dir, None, step)
        treedef = jax.tree.structure(cls.skeleton(aux))
        return step, jax.tree.unflatten(treedef, leaves)


def _bucket(b: int, cap: int) -> int:
    """Smallest power of two >= b (clamped to cap) — bounds plan-cache size."""
    p = 1
    while p < b:
        p <<= 1
    return min(p, max(cap, 1))


def _is_ready(x) -> bool:
    fn = getattr(x, "is_ready", None)
    return True if fn is None else fn()


class SvdService:
    """Async micro-batching front end over the batched truncated-update
    engine, checkpointable via ``snapshot``/``save``/``restore``."""

    def __init__(
        self,
        *,
        engine: SvdEngine | None = None,
        method: str = "direct",
        max_batch: int = 64,
        pad_to_bucket: bool = True,
        max_in_flight: int = 2,
        policy: UpdatePolicy | None = None,
    ):
        if max_in_flight < 0:
            raise ValueError(f"max_in_flight must be >= 0; got {max_in_flight}")
        self.policy = policy if policy is not None else UpdatePolicy(method=method)
        self.engine = engine            # explicit override; None -> policy-derived
        self.max_batch = max_batch
        self.pad_to_bucket = pad_to_bucket
        # 0 = synchronous (every round blocks before returning — the bench
        # baseline); 1 = single buffer; 2 = double buffering (default): the
        # device computes round k while the host assembles round k+1.
        self.max_in_flight = max_in_flight
        self.stats = SvdServiceStats()
        self._streams: OrderedDict[str, SvdState] = OrderedDict()
        self._pending: dict[str, deque] = {}
        self._in_flight: deque[list] = deque()   # per round: dispatched outputs
        self._lock = threading.RLock()

    def _engine_for(self, rank: int) -> SvdEngine:
        if self.engine is not None:
            return self.engine
        return engine_from_key(self.policy, rank + 1)

    # -- stream lifecycle ---------------------------------------------------

    def register(self, stream_id: str, state) -> None:
        """Create (or replace) a stream with its current truncated SVD
        (any container — coerced to a diagnostics-free ``SvdState``, so
        every stream snapshots to exactly three array leaves).

        Replacing drops any pending pairs — they were queued against the old
        state (and may not even match the new geometry).
        """
        with self._lock:
            st = as_state(state)
            self._streams[stream_id] = SvdState(u=st.u, s=st.s, v=st.v)
            self._pending[stream_id] = deque()

    def evict(self, stream_id: str) -> SvdState:
        """Drop a stream and return its state with its OWN queue applied.

        Other streams' pending pairs are left queued — eviction of one user
        must not advance anyone else's state.
        """
        with self._lock:
            state = self._streams.pop(stream_id)
            queue = self._pending.pop(stream_id, deque())
            for a, b in queue:
                state = self._apply_one(state, a, b)
                self.stats.applied += 1
            return state

    def _apply_one(self, state: SvdState, a, b) -> SvdState:
        eng = self._engine_for(state.rank)
        t = eng.update_truncated(TruncatedSvd(state.u, state.s, state.v), a, b)
        return SvdState(u=t.u, s=t.s, v=t.v)

    def state(self, stream_id: str) -> SvdState:
        """Current state — pending (unflushed) pairs are NOT yet applied.

        The returned factors may still be in-flight async futures; reading
        their values blocks transparently (JAX async dispatch)."""
        with self._lock:
            return self._streams[stream_id]

    def merge_streams(
        self,
        stream_ids,
        *,
        target: str | None = None,
        rank: int | None = None,
    ) -> SvdState:
        """Hierarchically merge several streams into one truncated SVD.

        The multi-worker story: each worker feeds its own stream (a shard
        tracker over its row block of a logically-shared matrix — per-tenant
        gradient sketches, federated covariance shards) and the service
        periodically combines them with the log-depth rank-1-update merge
        (``repro.dist.merge.merge_tree``) — row blocks concatenate in
        ``stream_ids`` order.  Each stream's OWN pending pairs are applied
        first (the merge must see current states; other streams' queues are
        untouched).  With ``target`` the result is registered as a new
        stream; the source streams keep evolving independently.

        The snapshot (queue drain) happens under the service lock; the
        log-depth merge itself — including its first-call jit compile —
        runs OUTSIDE it, so concurrent ``enqueue``/``flush`` traffic on
        other streams is never stalled.  The merge reflects the states as
        of the snapshot.
        """
        with self._lock:
            states = []
            for sid in stream_ids:
                state = self._streams[sid]
                queue = self._pending[sid]
                while queue:
                    a, b = queue.popleft()
                    state = self._apply_one(state, a, b)
                    self.stats.applied += 1
                self._streams[sid] = state
                states.append(state)
        merged = merge_tree(states, rank=rank, engine=self.engine,
                            policy=self.policy)
        if target is not None:
            with self._lock:
                self.register(target, merged)
        return merged

    def pending(self, stream_id: str | None = None) -> int:
        with self._lock:
            if stream_id is not None:
                return len(self._pending[stream_id])
            return sum(len(q) for q in self._pending.values())

    def in_flight(self) -> int:
        """Dispatched-but-unretired flush rounds (after reaping ready ones)."""
        with self._lock:
            self._reap_ready()
            return len(self._in_flight)

    # -- the hot path -------------------------------------------------------

    def enqueue(self, stream_id: str, a: jax.Array, b: jax.Array) -> None:
        """Queue one rank-1 perturbation ``a b^T`` for a stream.

        Auto-flushes when ``max_batch`` streams have a pending head pair.
        The flush only *dispatches* device work (async); enqueue never waits
        for it unless the in-flight buffer is full (backpressure).
        """
        with self._lock:
            if stream_id not in self._streams:
                raise KeyError(f"unknown stream {stream_id!r}; register() first")
            t = self._streams[stream_id]
            m, n = t.m, t.n
            if a.shape != (m,) or b.shape != (n,):
                # reject HERE: at flush time a bad pair would poison a whole
                # geometry group (pairs are popped before the engine call)
                raise ValueError(
                    f"pair shapes {a.shape}/{b.shape} do not match stream "
                    f"{stream_id!r} geometry ({m},)/({n},)"
                )
            self._pending[stream_id].append((a, b))
            self.stats.enqueued += 1
            ready = sum(1 for q in self._pending.values() if q)
            if ready >= self.max_batch:
                self._flush_round()

    def flush(self) -> int:
        """Dispatch ALL pending pairs (possibly several rounds); returns the
        number of updates applied.  Rounds are dispatched asynchronously —
        use ``drain()`` for a completion barrier."""
        with self._lock:
            applied = 0
            while any(self._pending.values()):
                applied += self._flush_round()
            return applied

    def drain(self) -> int:
        """Flush everything, then block until all dispatched work is done
        (the shutdown / handoff barrier). Returns the number applied."""
        with self._lock:
            applied = self.flush()
            self._barrier()
            return applied

    # -- in-flight buffer management ----------------------------------------

    def _reap_ready(self) -> None:
        """Retire finished rounds without blocking (oldest-first)."""
        while self._in_flight and all(_is_ready(x) for x in self._in_flight[0]):
            self._in_flight.popleft()

    def _retire_oldest(self) -> None:
        jax.block_until_ready(self._in_flight.popleft())

    def _barrier(self) -> None:
        """Block until every dispatched round AND every stream state is
        concrete — the only place (besides backpressure) the service waits
        on the device."""
        while self._in_flight:
            self._retire_oldest()
        jax.block_until_ready(list(self._streams.values()))

    def _flush_round(self) -> int:
        """One round: at most one pending pair per stream, grouped by
        geometry, one batched engine call per group — dispatched async."""
        round_ids = [sid for sid, q in self._pending.items() if q]
        if not round_ids:
            return 0

        # Backpressure: bound how far the host can run ahead of the device.
        self._reap_ready()
        while self.max_in_flight > 0 and len(self._in_flight) >= self.max_in_flight:
            self._retire_oldest()
            self.stats.backpressure_waits += 1

        keys = [truncated_geometry(self._streams[sid]) for sid in round_ids]

        applied = 0
        round_outputs: list = []
        for (m, n, r, dt), idxs in group_indices(keys).items():
            sids = [round_ids[i] for i in idxs]
            # peek, don't pop: if the engine call raises (first-compile OOM,
            # backend error), the pairs stay queued and a retry re-applies
            # them — flush stays failure-atomic per group
            pairs = [self._pending[sid][0] for sid in sids]
            states = [self._streams[sid] for sid in sids]
            bsz = len(sids)
            pad = 0
            if self.pad_to_bucket:
                # a group can exceed max_batch (retry after a failed flush
                # accumulates streams) — never pad negative, just dispatch big
                pad = max(0, _bucket(bsz, self.max_batch) - bsz)

            t_stack = stack_trees(
                [TruncatedSvd(s.u, s.s, s.v) for s in states]
            )
            a_stack = jnp.stack([jnp.asarray(a, dt) for a, _ in pairs])
            b_stack = jnp.stack([jnp.asarray(b, dt) for _, b in pairs])
            if pad:
                # no-op rank-1 pairs (a = b = 0); padded outputs are discarded
                t_stack = jax.tree.map(
                    lambda x: jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)]),
                    t_stack,
                )
                a_stack = jnp.concatenate([a_stack, jnp.zeros((pad, m), dt)])
                b_stack = jnp.concatenate([b_stack, jnp.zeros((pad, n), dt)])

            eng = self._engine_for(r)
            out = eng.update_truncated_batch(
                t_stack, a_stack, b_stack,
                mesh=self.policy.mesh, batch_axis=self.policy.batch_axis,
            )
            for j, sid in enumerate(sids):
                t = unstack_tree(out, j)
                self._streams[sid] = SvdState(u=t.u, s=t.s, v=t.v)
                self._pending[sid].popleft()
            round_outputs.extend(jax.tree.leaves(out))
            applied += bsz
            self.stats.rounds += 1
            self.stats.max_batch = max(self.stats.max_batch, bsz + pad)

        if self.max_in_flight == 0:
            jax.block_until_ready(round_outputs)       # synchronous mode
        else:
            self._in_flight.append(round_outputs)
            self.stats.in_flight_peak = max(
                self.stats.in_flight_peak, len(self._in_flight)
            )
        self.stats.flushes += 1
        self.stats.applied += applied
        return applied

    # -- checkpointing ------------------------------------------------------

    def snapshot(self) -> ServiceSnapshot:
        """Capture the whole service as a versioned pytree.

        This is a barrier: in-flight rounds are retired and every stream
        state is forced concrete first, so the snapshot is a consistent
        point on every stream's timeline — states as of all *flushed*
        updates, pending FIFOs holding exactly the unflushed ones.
        """
        with self._lock:
            self._barrier()
            states, pend_a, pend_b = [], [], []
            for sid, st in self._streams.items():
                states.append(st)
                queue = self._pending[sid]
                if queue:
                    pend_a.append(jnp.stack([jnp.asarray(a) for a, _ in queue]))
                    pend_b.append(jnp.stack([jnp.asarray(b) for _, b in queue]))
                else:
                    pend_a.append(np.zeros((0, st.m), st.u.dtype))
                    pend_b.append(np.zeros((0, st.n), st.v.dtype))
            return ServiceSnapshot(
                states=tuple(states),
                pending_a=tuple(pend_a),
                pending_b=tuple(pend_b),
                version=SNAPSHOT_VERSION,
                stream_ids=tuple(self._streams),
                policy_spec=tuple(_policy_spec(self.policy).items()),
                max_batch=self.max_batch,
                pad_to_bucket=self.pad_to_bucket,
                max_in_flight=self.max_in_flight,
                stats=tuple(dataclasses.asdict(self.stats).items()),
            )

    def save(self, ckpt_dir, step: int, *, keep: int = 3):
        """``snapshot()`` + atomic write through ``train.checkpoint``."""
        return self.snapshot().save(ckpt_dir, step, keep=keep)

    @classmethod
    def from_snapshot(
        cls,
        snap: ServiceSnapshot,
        *,
        mesh=None,
        engine: SvdEngine | None = None,
        policy: UpdatePolicy | None = None,
    ) -> "SvdService":
        """Rebuild a service from a snapshot.

        ``policy`` (full override) or ``mesh`` (grafted onto the recorded
        policy spec) re-establish placement on the restoring topology;
        with neither, the recorded numerics run unsharded.
        """
        spec = dict(snap.policy_spec)
        if policy is None:
            if spec.get("had_mesh") and mesh is None:
                warnings.warn(
                    "snapshot was taken under a mesh-sharded policy but "
                    "restore got no mesh= (and no policy=): flushes will run "
                    "unsharded on this process",
                    stacklevel=2,
                )
            policy = _policy_from_spec(spec, mesh=mesh)
        svc = cls(
            engine=engine,
            max_batch=snap.max_batch,
            pad_to_bucket=snap.pad_to_bucket,
            max_in_flight=snap.max_in_flight,
            policy=policy,
        )
        for sid, st, pa, pb in zip(
            snap.stream_ids, snap.states, snap.pending_a, snap.pending_b
        ):
            svc._streams[sid] = SvdState(u=st.u, s=st.s, v=st.v)
            svc._pending[sid] = deque(
                (pa[i], pb[i]) for i in range(np.asarray(pa).shape[0])
            )
        svc.stats = SvdServiceStats(**dict(snap.stats))
        return svc

    @classmethod
    def restore(
        cls,
        ckpt_dir,
        *,
        step: int | None = None,
        mesh=None,
        engine: SvdEngine | None = None,
        policy: UpdatePolicy | None = None,
    ) -> tuple[int, "SvdService"]:
        """Load the latest (or ``step``-th) snapshot and rebuild the service.

        Returns ``(step, service)``.  Restore-exactness contract: the
        restored service, fed the same post-snapshot traffic, produces
        bitwise-identical factors to the service that never stopped
        (DESIGN.md §9; kill-and-resume test in test_serve_checkpoint.py).
        """
        step, snap = ServiceSnapshot.load(ckpt_dir, step)
        return step, cls.from_snapshot(snap, mesh=mesh, engine=engine, policy=policy)
