"""repro.obs — metrics registry, span tracing and numerical health
(DESIGN.md §15).

Pinned here:
* registry semantics: typed series, labels, kind conflicts, aggregation;
* exporter goldens: exact Prometheus text and JSON for a small registry;
* zero overhead when disabled: obs on/off changes neither results (bitwise)
  nor jaxprs (equation-count equal) — instrumentation lives strictly
  outside traced code;
* snapshot/restore: registry rows ride ServiceSnapshot (v7) and
  FleetSnapshot (v8) through the aux JSON round trip;
* engine/planner cache counters mirror the public cache_info() numbers;
* the health watchdog warns (HealthWarning) on a drifted state and counts
  the trip.
"""

from __future__ import annotations

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api, obs
from repro.api import SvdState, UpdatePolicy
from repro.core.engine import SvdEngine
from repro.obs import metrics as obs_metrics
from repro.serve.svd_service import SNAPSHOT_VERSION, ServiceSnapshot, SvdService
from repro.updates import RankK
from repro.updates.planner import lower, schedule_cache_info

RNG = np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _isolated_obs():
    """Fresh registry + disabled obs around every test (obs state is
    process-global by design; tests must not leak into each other)."""
    prev = obs_metrics.set_registry(obs_metrics.MetricsRegistry())
    obs.disable()
    obs.stop_tracing()
    obs.clear_trace()
    yield
    obs.stop_tracing()
    obs.clear_trace()
    obs.disable()
    obs_metrics.set_registry(prev)


def _state(m=12, n=9, rank=None, rng=RNG):
    dense = jnp.asarray(rng.standard_normal((m, n)))
    return SvdState.from_dense(dense, rank=rank if rank is not None else min(m, n))


def _event(m=12, n=9, rng=RNG):
    return (jnp.asarray(rng.standard_normal(m)),
            jnp.asarray(rng.standard_normal(n)))


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_basics():
    reg = obs.registry()
    c = reg.counter("events")
    c.inc()
    c.inc(4)
    assert c.value == 5
    assert reg.counter("events") is c          # same handle per key

    g = reg.gauge("depth")
    g.set(3)
    g.max(7)
    g.max(2)                                    # running max keeps 7
    assert g.value == 7.0

    h = reg.histogram("lat", bounds=(1.0, 10.0))
    for x in (0.5, 5.0, 50.0):
        h.observe(x)
    assert h.count == 3
    assert h.sum == pytest.approx(55.5)
    assert h.value["counts"] == [1, 1, 1]       # one per bucket incl. +Inf


def test_kind_conflict_raises():
    reg = obs.registry()
    reg.counter("x")
    with pytest.raises(TypeError, match="already registered as counter"):
        reg.gauge("x")


def test_labels_make_independent_series_and_aggregate_sums():
    reg = obs.registry()
    reg.counter("applied", shard="0").inc(3)
    reg.counter("applied", shard="1").inc(4)
    assert reg.get("applied", shard="0").value == 3
    assert reg.get("applied") is None           # unlabeled series never made
    assert reg.aggregate("applied") == 7.0


# ---------------------------------------------------------------------------
# exporter goldens
# ---------------------------------------------------------------------------


def test_prometheus_export_golden():
    reg = obs.registry()
    reg.counter("flushes", shard="0").inc(2)
    reg.gauge("depth").set(3)
    h = reg.histogram("lat_us", bounds=(1.0, 10.0))
    h.observe(0.5)
    h.observe(5.0)
    golden = "\n".join([
        '# TYPE depth gauge',
        'depth 3',
        '# TYPE flushes_total counter',
        'flushes_total{shard="0"} 2',
        '# TYPE lat_us histogram',
        'lat_us_bucket{le="1"} 1',
        'lat_us_bucket{le="10"} 2',
        'lat_us_bucket{le="+Inf"} 2',
        'lat_us_sum 5.5',
        'lat_us_count 2',
    ]) + "\n"
    assert reg.to_prometheus() == golden


def test_json_export_golden():
    reg = obs.registry()
    reg.counter("flushes", shard="0").inc(2)
    reg.gauge("depth").set(3)
    rows = json.loads(reg.to_json())
    assert rows == [
        {"name": "depth", "labels": {}, "kind": "gauge", "value": 3.0},
        {"name": "flushes", "labels": {"shard": "0"}, "kind": "counter",
         "value": 2},
    ]


def test_registry_snapshot_restore_round_trip():
    reg = obs.registry()
    reg.counter("c", shard="2").inc(9)
    reg.gauge("g").set(1.5)
    h = reg.histogram("h", bounds=(1.0,))
    h.observe(0.5)
    h.observe(2.0)
    rows = reg.snapshot()
    # rows must be hashable: they ride pytree METADATA in ServiceSnapshot
    hash(rows)
    # the aux JSON round trip turns tuples into lists — restore accepts both
    rows_json = json.loads(json.dumps(rows))
    fresh = obs_metrics.MetricsRegistry()
    obs_metrics.set_registry(fresh)
    try:
        fresh.restore(rows_json)
        assert fresh.get("c", shard="2").value == 9
        assert fresh.get("g").value == 1.5
        assert fresh.get("h").value["counts"] == [1, 1]
    finally:
        obs_metrics.set_registry(reg)


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------


def test_span_disabled_is_shared_noop():
    s1 = obs.span("a", x=1)
    s2 = obs.span("b")
    assert s1 is s2                             # the singleton: no allocation
    with s1 as sp:
        sp.set(y=2)
    assert obs.trace_events() == []


def test_chrome_trace_shape():
    obs.start_tracing()
    with obs.span("outer", depth=2):
        with obs.span("inner") as sp:
            sp.set(batch=4)
    obs.stop_tracing()
    doc = json.loads(obs.chrome_trace())
    assert doc["displayTimeUnit"] == "ms"
    by_name = {e["name"]: e for e in doc["traceEvents"]}
    assert set(by_name) == {"outer", "inner"}
    for e in by_name.values():
        assert e["ph"] == "X"
        assert e["dur"] >= 0.0
    assert by_name["inner"]["args"] == {"batch": 4}
    # inner nests inside outer on the monotonic clock
    assert by_name["outer"]["ts"] <= by_name["inner"]["ts"]
    assert (by_name["inner"]["ts"] + by_name["inner"]["dur"]
            <= by_name["outer"]["ts"] + by_name["outer"]["dur"] + 1e-3)


def test_span_feeds_duration_histogram_when_enabled():
    obs.enable()
    obs.start_tracing()
    with obs.span("flush_round"):
        pass
    obs.stop_tracing()
    h = obs.registry().get("span_duration_us", span="flush_round")
    assert h is not None and h.count == 1


# ---------------------------------------------------------------------------
# zero overhead when disabled
# ---------------------------------------------------------------------------


def test_disabled_obs_is_bitwise_and_jaxpr_invisible():
    pol = UpdatePolicy(method="direct")
    st = _state()
    a, b = _event()

    off = api.update(st, a, b, pol)
    n_off = len(jax.make_jaxpr(
        lambda u, s, v, aa, bb: api.update(SvdState(u, s, v), aa, bb, pol)
    )(st.u, st.s, st.v, a, b).eqns)

    obs.enable()
    obs.start_tracing()
    on = api.update(st, a, b, pol)
    n_on = len(jax.make_jaxpr(
        lambda u, s, v, aa, bb: api.update(SvdState(u, s, v), aa, bb, pol)
    )(st.u, st.s, st.v, a, b).eqns)
    obs.stop_tracing()

    # identical executable, identical result — obs never touches traced code
    assert n_on == n_off
    for name in ("u", "s", "v"):
        np.testing.assert_array_equal(np.asarray(getattr(on, name)),
                                      np.asarray(getattr(off, name)))


def test_disabled_sites_record_nothing():
    # a full service flush with obs disabled must leave the registry empty
    svc = SvdService(max_batch=2, policy=UpdatePolicy(method="direct"))
    svc.register("s0", _state())
    svc.enqueue("s0", *_event())
    svc.drain()
    assert obs.registry().series() == []
    assert obs.trace_events() == []


# ---------------------------------------------------------------------------
# engine / planner counters mirror cache_info
# ---------------------------------------------------------------------------


def test_engine_counters_match_cache_info():
    obs.enable()
    eng = SvdEngine()
    rng = np.random.default_rng(5)
    m, n = 6, 8                                # update_batch wants square u, v
    u = jnp.asarray(np.linalg.qr(rng.standard_normal((m, m)))[0])
    v = jnp.asarray(np.linalg.qr(rng.standard_normal((n, n)))[0])
    s = jnp.asarray(np.sort(np.abs(rng.standard_normal(m)))[::-1].copy())
    a, b = _event(m, n, rng)
    stack = (jnp.stack([u]), jnp.stack([s]), jnp.stack([v]),
             jnp.stack([a]), jnp.stack([b]))
    eng.update_batch(*stack)
    eng.update_batch(*stack)
    info = eng.cache_info()
    reg = obs.registry()
    assert reg.get("engine_plan_cache_misses").value == info.misses == 1
    assert reg.get("engine_plan_cache_hits").value == info.hits == 1


def test_planner_counters_match_schedule_cache_info():
    obs.enable()
    rng = np.random.default_rng(3)
    st = _state(10, 8, 4, rng)
    op = RankK(jnp.asarray(rng.standard_normal((10, 2))),
               jnp.asarray(rng.standard_normal((8, 2))))
    before = schedule_cache_info()
    lower(op, st)
    lower(op, st)
    after = schedule_cache_info()
    reg = obs.registry()
    hits = getattr(reg.get("planner_schedule_cache_hits"), "value", 0)
    misses = getattr(reg.get("planner_schedule_cache_misses"), "value", 0)
    assert hits == after.hits - before.hits >= 1
    assert misses == after.misses - before.misses


# ---------------------------------------------------------------------------
# snapshot plumbing: registry rows ride service / fleet snapshots
# ---------------------------------------------------------------------------


def test_service_snapshot_round_trips_obs_rows():
    obs.enable()
    pol = UpdatePolicy(method="direct", health_every=1)
    svc = SvdService(max_batch=2, policy=pol)
    svc.register("s0", _state())
    svc.enqueue("s0", *_event())
    svc.drain()
    snap = svc.snapshot()
    assert snap.version == SNAPSHOT_VERSION == 7
    assert snap.obs_metrics                    # rows captured while enabled

    # aux JSON round trip (what checkpoint save/load does to metadata)
    snap2 = ServiceSnapshot.skeleton(snap.aux())
    assert snap2.obs_metrics == snap.obs_metrics
    hash(snap2.obs_metrics)                    # still pytree-metadata safe

    applied = obs.registry().get("serve_applied").value
    obs_metrics.set_registry(obs_metrics.MetricsRegistry())
    svc2 = SvdService.from_snapshot(snap)
    assert obs.registry().get("serve_applied").value == applied
    assert svc2.stats.applied == svc.stats.applied


def test_fleet_snapshot_round_trips_obs_rows():
    from repro.fleet.fleet import FLEET_SNAPSHOT_VERSION, SvdFleet

    obs.enable()
    fleet = SvdFleet(num_shards=2, policy=UpdatePolicy(method="direct"),
                     max_batch=2)
    rng = np.random.default_rng(7)
    for i in range(4):
        fleet.register(f"f{i}", _state(10, 7, 3, rng))
    for i in range(4):
        fleet.enqueue(f"f{i}", *_event(10, 7, rng))
    fleet.drain()
    fleet.stats()                              # publishes fleet_* gauges
    snap = fleet.snapshot()
    assert snap.version == FLEET_SNAPSHOT_VERSION == 8

    per_shard = obs.registry().get("serve_applied", shard="0")
    assert per_shard is not None
    total = obs.registry().aggregate("serve_applied")

    obs_metrics.set_registry(obs_metrics.MetricsRegistry())
    SvdFleet.from_snapshot(snap)
    assert obs.registry().aggregate("serve_applied") == total


def test_old_snapshot_without_obs_rows_still_loads():
    svc = SvdService(max_batch=2, policy=UpdatePolicy(method="direct"))
    svc.register("s0", _state())
    svc.drain()
    aux = svc.snapshot().aux()
    del aux["obs_metrics"]                     # what a v5-era aux looks like
    snap = ServiceSnapshot.skeleton(aux)
    assert snap.obs_metrics == ()


# ---------------------------------------------------------------------------
# serve wiring: spans + stats gauges + health sampling
# ---------------------------------------------------------------------------


def test_serve_flush_emits_spans_and_stats_gauges():
    obs.enable()
    obs.start_tracing()
    svc = SvdService(max_batch=2, policy=UpdatePolicy(method="direct",
                                                      health_every=1))
    svc.register("s0", _state())
    svc.register("s1", _state())
    for _ in range(2):
        svc.enqueue("s0", *_event())
        svc.enqueue("s1", *_event())
    svc.drain()
    obs.stop_tracing()

    names = {e["name"] for e in obs.trace_events()}
    assert {"flush_round", "dispatch"} <= names
    reg = obs.registry()
    assert reg.get("serve_applied").value == svc.stats.applied == 4
    for probe in ("health_ortho_drift", "health_secular_residual",
                  "health_deflation_fraction", "health_bf16_headroom"):
        assert reg.get(probe) is not None, probe


def test_health_watchdog_warns_and_counts_on_drifted_state():
    obs.enable()
    rng = np.random.default_rng(11)
    st = _state(10, 8, 4, rng)
    drifted_u = st.u * 1.05                    # ||UᵀU - I|| ≈ 0.1 >> 1e-3
    mon = obs.HealthMonitor(every=1)
    with pytest.warns(obs.HealthWarning, match="ortho_drift"):
        mon.sample_state(drifted_u, st.s, st.v)
    warned = obs.registry().get("health_warnings_total", probe="ortho_drift")
    assert warned is not None and warned.value == 1


def test_healthy_state_does_not_warn():
    import warnings

    obs.enable()
    st = _state(10, 8, 4)
    mon = obs.HealthMonitor(every=1)
    with warnings.catch_warnings():
        warnings.simplefilter("error", obs.HealthWarning)
        mon.sample_state(st.u, st.s, st.v)
    assert obs.registry().get("health_ortho_drift").value < 1e-6


def test_probe_update_on_exact_update_is_clean():
    pol = UpdatePolicy(method="direct")
    rng = np.random.default_rng(13)
    st = _state(12, 9, rng=rng)                # full-rank: update is exact
    a, b = _event(12, 9, rng)
    out = api.update(st, a, b, pol)
    rep = obs.probe_update(st.u, st.s, st.v, a, b, out.u, out.s, out.v)
    assert rep.ortho_drift < 1e-8
    assert rep.secular_residual < 1e-6
    assert 0.0 <= rep.deflation_fraction <= 1.0
    assert rep.bf16_headroom > 0.0


def test_health_every_cadence():
    obs.enable()
    mon = obs.HealthMonitor(every=3)
    # samples on every 3rd flush tick
    assert [mon.due() for _ in range(7)] == [
        False, False, True, False, False, True, False]
