"""Shared model building blocks (pure functions over explicit param pytrees)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "dot",
    "rmsnorm",
    "layernorm",
    "norm_apply",
    "norm_init",
    "mlp_init",
    "mlp_apply",
    "rope_freqs",
    "rope_apply",
    "embed_init",
    "embed_lookup",
    "unembed",
    "cross_entropy",
    "uniform_init",
]


def uniform_init(key, shape, scale, dtype):
    """Scaled truncated-normal-ish init (uniform for cheap determinism)."""
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def dot(x: jax.Array, w: jax.Array, compute_dtype) -> jax.Array:
    """Matmul in the compute dtype with f32 accumulation (MXU convention)."""
    return jnp.matmul(
        x.astype(compute_dtype), w.astype(compute_dtype),
        preferred_element_type=jnp.float32,
    )


def rmsnorm(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm(x, w, b, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32) + b.astype(jnp.float32)
    return out.astype(x.dtype)


def norm_init(d, norm_type, dtype):
    if norm_type == "rmsnorm":
        return {"w": jnp.ones((d,), dtype)}
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def norm_apply(x, p, norm_type):
    if norm_type == "rmsnorm":
        return rmsnorm(x, p["w"])
    return layernorm(x, p["w"], p["b"])


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------


def mlp_init(key, d_model, d_ff, mlp_type, dtype):
    ks = jax.random.split(key, 3)
    scale_in = (1.0 / d_model) ** 0.5
    scale_out = (1.0 / d_ff) ** 0.5
    if mlp_type == "swiglu":
        return {
            "wg": uniform_init(ks[0], (d_model, d_ff), scale_in, dtype),
            "wu": uniform_init(ks[1], (d_model, d_ff), scale_in, dtype),
            "wd": uniform_init(ks[2], (d_ff, d_model), scale_out, dtype),
        }
    return {
        "wi": uniform_init(ks[0], (d_model, d_ff), scale_in, dtype),
        "wd": uniform_init(ks[2], (d_ff, d_model), scale_out, dtype),
    }


def mlp_apply(x, p, mlp_type, compute_dtype):
    if mlp_type == "swiglu":
        g = dot(x, p["wg"], compute_dtype)
        u = dot(x, p["wu"], compute_dtype)
        h = jax.nn.silu(g) * u
        return dot(h.astype(x.dtype), p["wd"], compute_dtype).astype(x.dtype)
    h = dot(x, p["wi"], compute_dtype)
    if mlp_type == "relu2":  # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(h))
    else:  # gelu
        h = jax.nn.gelu(h)
    return dot(h.astype(x.dtype), p["wd"], compute_dtype).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim, theta):
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    return inv  # (half,)


def rope_apply(x, positions, theta):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    half = x.shape[-1] // 2
    inv = rope_freqs(x.shape[-1], theta)
    ang = positions[..., :, None].astype(jnp.float32) * inv[None, :]  # (..., seq, half)
    sin = jnp.sin(ang)[..., :, None, :]
    cos = jnp.cos(ang)[..., :, None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_init(key, padded_vocab, d_model, dtype):
    return {"table": uniform_init(key, (padded_vocab, d_model), d_model ** -0.5, dtype)}


def embed_lookup(tokens, p):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(x, p, compute_dtype):
    """Logits = x @ table^T (tied); returns f32 logits."""
    return jnp.matmul(
        x.astype(compute_dtype), p["table"].T.astype(compute_dtype),
        preferred_element_type=jnp.float32,
    )


def cross_entropy(logits, labels, vocab_size):
    """Mean token NLL; ignores padded vocab tail via label validity."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    return jnp.mean(nll)
