"""Hierarchical distributed truncated-SVD merge (Iwen & Ong, arXiv:1601.07010),
built from the paper's rank-1 update machinery.

Problem: ``W`` workers each hold a truncated SVD ``(U_i, S_i, V_i)`` of their
row block ``M_i``; we want the rank-r SVD of the concatenation
``M = [M_1; ...; M_W]`` without ever materializing ``M``.

For one pair ``[A; B]`` with ``A ~ U_a S_a V_a^T`` (rank r_a) and
``B ~ U_b S_b V_b^T`` (rank r_b):

    [A; B] = [[U_a, 0], [0, U_b]] @ K,    K = [[S_a V_a^T], [S_b V_b^T]]

so the whole merge reduces to the SVD of the small ``(r_a + r_b, n)`` core
``K`` — which we build by *rank-1 updates*: start from ``[S_a V_a^T; 0]``
(exactly representable at rank r_a with orthonormal bases
``u = [I_{r_a}; 0]``, ``v = V_a``) and absorb B's components one at a time,

    K <- K + (s_i e_{r_a + i}) v_i^T        (i = 1..r_b),

each step an ``SvdEngine.update_truncated`` call (Brand augmentation +
Algorithm 6.1; fast truncated updating in the spirit of Deng et al.,
arXiv:2401.09703).  Every intermediate state ``K_j`` keeps rank r: since
``K_j``'s rows are a subset of ``K``'s, ``rank(K_j) <= rank(K)``, so for a
globally rank-<=r matrix the truncation after each step discards an exact
zero and the log-depth tree merge reproduces the rank-r SVD of ``M`` exactly;
for general matrices it is the streaming near-optimal approximation with the
usual hierarchical-merge error (Iwen & Ong Thm 3).

``merge_tree`` reduces a shard list pairwise in log depth, batching all the
pairs of a level through ONE ``update_truncated_batch`` engine call per
rank-1 step.  ``distributed_merge`` is the shard_map form: ``all_gather`` of
the small factors (``r*(m+n+1)`` floats per worker — the only wire traffic),
then the same tree merge runs replicated on every worker.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import SvdEngine, default_engine, stack_trees, unstack_tree
from repro.core.svd_update import TruncatedSvd
from repro.dist.collectives import all_gather_tsvd

__all__ = ["merge_pair", "merge_tree", "distributed_merge"]


def _merge_cores_batched(
    a_stack: TruncatedSvd, b_stack: TruncatedSvd, engine: SvdEngine
) -> TruncatedSvd:
    """SVDs of the stacked cores ``K_p = [S_a V_a^T; S_b V_b^T]`` for P pairs.

    Leaves of ``a_stack``/``b_stack`` carry a leading pair axis P; all pairs
    share one geometry, so each of the ``r_b`` rank-1 absorptions is a single
    batched engine call (P plans for the price of one).
    """
    p_pairs, _, r_a = a_stack.u.shape
    r_b = b_stack.s.shape[1]
    dt = a_stack.u.dtype
    rows = r_a + r_b

    # [S_a V_a^T; 0] at rank r_a with orthonormal bases.  (Never pad the
    # state with zero *columns*: non-orthonormal bases poison the Brand
    # augmentation once zero singular values tie in the eigen-update.)
    u0 = jnp.broadcast_to(jnp.eye(rows, r_a, dtype=dt), (p_pairs, rows, r_a))
    core = TruncatedSvd(u=u0, s=a_stack.s, v=a_stack.v)

    for i in range(r_b):
        # s_i e_{r_a+i} v_i^T — the e-vector lands on B's (so-far untouched)
        # row block, orthogonal to the initial column span of u0.
        e_i = jnp.zeros((p_pairs, rows), dt).at[:, r_a + i].set(b_stack.s[:, i])
        core = engine.update_truncated_batch(core, e_i, b_stack.v[:, :, i])
    return core


def _combine_bases(a: TruncatedSvd, b: TruncatedSvd, core: TruncatedSvd,
                   rank: int) -> TruncatedSvd:
    """Lift the core SVD back through the block-diagonal left bases."""
    r_a = a.s.shape[0]
    uk = core.u[:, :rank]
    u = jnp.concatenate([a.u @ uk[:r_a], b.u @ uk[r_a:]], axis=0)
    return TruncatedSvd(u=u, s=core.s[:rank], v=core.v[:, :rank])


def merge_pair(
    a: TruncatedSvd,
    b: TruncatedSvd,
    *,
    rank: int | None = None,
    engine: SvdEngine | None = None,
    method: str = "direct",
) -> TruncatedSvd:
    """Rank-``rank`` truncated SVD of the row concatenation ``[A; B]``.

    ``rank`` defaults to (and may not exceed) ``r_a``, the rank carried by
    the core state.  Columns beyond the true rank of ``[A; B]`` come back
    with zero singular values (their vectors are padding, as in any
    truncated SVD of a rank-deficient matrix).
    """
    if a.v.shape[0] != b.v.shape[0]:
        raise ValueError(
            f"row-concatenated shards must share the column space: "
            f"n={a.v.shape[0]} vs {b.v.shape[0]}"
        )
    if engine is None:
        engine = default_engine(method)
    r_a = a.s.shape[0]
    r = rank if rank is not None else r_a
    if r > r_a:
        raise ValueError(
            f"merge rank {r} exceeds the left shard's rank {r_a}; the core "
            f"state carries rank r_a — order the higher-rank shard first"
        )
    a_stack = jax.tree.map(lambda x: x[None], a)
    b_stack = jax.tree.map(lambda x: x[None], b)
    core = unstack_tree(_merge_cores_batched(a_stack, b_stack, engine), 0)
    return _combine_bases(a, b, core, r)


def merge_tree(
    shards,
    *,
    rank: int | None = None,
    engine: SvdEngine | None = None,
    method: str = "direct",
) -> TruncatedSvd:
    """Log-depth pairwise merge of row-partitioned truncated SVDs.

    ``shards`` are ordered row blocks.  Each level pairs neighbors
    (preserving row order) and merges all equal-geometry pairs through one
    batched engine call per rank-1 step; an odd tail shard rides up a level
    unchanged.  Depth is ``ceil(log2 W)`` — the reduction shape that keeps a
    1000-worker merge at ~10 sequential rounds.
    """
    shards = list(shards)
    if not shards:
        raise ValueError("merge_tree needs at least one shard")
    if engine is None:
        engine = default_engine(method)
    r_min = min(int(t.s.shape[0]) for t in shards)
    if rank is None:
        rank = r_min
    elif rank > r_min:
        raise ValueError(
            f"merge rank {rank} exceeds the smallest shard rank {r_min}; "
            f"the pairwise core state cannot carry more than the shard rank"
        )

    while len(shards) > 1:
        pairs = [(shards[i], shards[i + 1]) for i in range(0, len(shards) - 1, 2)]
        tail = [shards[-1]] if len(shards) % 2 else []
        geoms = {(p[0].u.shape, p[1].u.shape) for p in pairs}
        merged: list = []
        if len(geoms) == 1:
            a_stack = stack_trees([p[0] for p in pairs])
            b_stack = stack_trees([p[1] for p in pairs])
            cores = _merge_cores_batched(a_stack, b_stack, engine)
            merged = [
                _combine_bases(p[0], p[1], unstack_tree(cores, j), rank)
                for j, p in enumerate(pairs)
            ]
        else:  # unequal shard heights (odd tails): merge pairwise
            merged = [merge_pair(x, y, rank=rank, engine=engine) for x, y in pairs]
        shards = merged + tail
    return shards[0]


def distributed_merge(
    local: TruncatedSvd,
    axis_name,
    *,
    rank: int | None = None,
    engine: SvdEngine | None = None,
    method: str = "direct",
) -> TruncatedSvd:
    """Merge per-worker truncated SVDs across a mesh axis (call under
    ``shard_map``).

    ``all_gather`` moves only the ``(m, r) + (r,) + (n, r)`` factors; the
    log-depth tree merge then runs identically on every worker, so the result
    is replicated — each worker ends with the rank-r SVD of the row-stacked
    global matrix ``[M_1; ...; M_W]`` (rows ordered by worker index, worker
    ``i`` owning rows ``[i*m, (i+1)*m)``).  Outside shard_map
    (``axis_name=None``) this is just a local no-op merge.
    """
    gathered = all_gather_tsvd(local, axis_name)
    n_workers = gathered.u.shape[0]
    shards = [unstack_tree(gathered, i) for i in range(n_workers)]
    return merge_tree(shards, rank=rank, engine=engine, method=method)
