"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required by the dry-run contract.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds a leading pod=2 axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    n = jax.device_count()
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


# ``batch_sharding`` / ``batch_pad`` live in ``repro.dist.sharding`` (the
# one sharding home, DESIGN.md §7); the transitional re-exports that used
# to sit here were removed with the rest of the pre-api surface.
