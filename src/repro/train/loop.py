"""Training loop: sharded step, auto-resume, straggler hooks, metrics.

Composes the substrate: model (registry) + optimizer (adamw [+ spectral
projection]) + DP gradient sync (dense via SPMD psum, or the paper's
compressed all-reduce) + deterministic data + atomic checkpoints.

Fault-tolerance posture (DESIGN.md §5):
* every ``checkpoint_every`` steps an atomic checkpoint is written; on start
  the loop resumes from the latest COMPLETE one (crash-in-the-middle leaves
  the previous checkpoint authoritative);
* the data stream is a pure function of step — resume is bit-exact;
* a per-step watchdog (``straggler_timeout_s``) records slow steps and calls
  a user hook (at pod scale: re-dispatch / hot-spare swap; here: logged).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.data.synthetic import batch_for_step
from repro.models.registry import build_model
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine
from repro.train import checkpoint as ckpt

__all__ = ["TrainResult", "train"]


@dataclass
class TrainResult:
    final_step: int
    losses: list = field(default_factory=list)
    grad_norms: list = field(default_factory=list)
    resumed_from: int | None = None
    straggler_events: list = field(default_factory=list)


def train(
    run: RunConfig,
    *,
    batch_size: int,
    seq_len: int,
    mesh=None,
    straggler_timeout_s: float = 300.0,
    on_straggler: Callable[[int, float], Any] | None = None,
    spectral_params: dict | None = None,
) -> TrainResult:
    cfg = run.model
    opt = run.optimizer
    api = build_model(cfg)

    key = jax.random.PRNGKey(run.seed)
    params = api.init(key)
    opt_state = adamw_init(params)
    start_step = 0
    resumed_from = None

    # ---- auto-resume
    latest = ckpt.latest_step(run.checkpoint_dir)
    if latest is not None:
        start_step, (params, opt_state) = ckpt.restore(
            run.checkpoint_dir, (params, opt_state), latest
        )
        resumed_from = start_step

    # optional paper-technique policy: streaming-SVD low-rank moment
    # projection (optim/spectral_adam.py) instead of dense AdamW moments
    use_spectral = opt.spectral_rank > 0
    if use_spectral:
        from repro.optim.spectral_adam import spectral_adam_init, spectral_adam_update

        opt_state = spectral_adam_init(jax.random.PRNGKey(run.seed + 1), params,
                                       rank=opt.spectral_rank)

    def step_fn(params, opt_state, batch, step):
        loss, grads = jax.value_and_grad(api.train_loss)(params, batch)
        lr = warmup_cosine(
            step, base_lr=opt.lr, warmup_steps=opt.warmup_steps, total_steps=opt.total_steps
        )
        if use_spectral:
            # basis_refresh_every: periodic tracker consensus/re-factorization
            # via optim.compression.agree_tracker (axis_name=None here — the
            # step is SPMD-jitted, not shard_map'd, so gradients are already
            # globally synced and the refresh is the local re-factorization)
            new_params, new_state = spectral_adam_update(
                grads, opt_state, params,
                lr=lr, betas=opt.betas, eps=opt.eps, weight_decay=opt.weight_decay,
                basis_refresh_every=opt.basis_refresh_every,
            )
            from repro.optim.adamw import global_norm
            gnorm = global_norm(grads)
        else:
            new_params, new_state, gnorm = adamw_update(
                grads, opt_state, params,
                lr=lr, betas=opt.betas, eps=opt.eps,
                weight_decay=opt.weight_decay, grad_clip=opt.grad_clip,
            )
        return new_params, new_state, loss, gnorm

    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.dist import sharding as sh

        p_specs = sh.param_pspecs(params)
        b_specs = sh.batch_pspecs({
            "tokens": jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32),
        })

        def ns(t):
            return jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))

        from repro.optim.adamw import AdamWState

        o_specs = AdamWState(step=P(), m=p_specs, v=p_specs)
        step_jit = jax.jit(
            step_fn,
            in_shardings=(ns(p_specs), ns(o_specs), ns(b_specs), NamedSharding(mesh, P())),
            donate_argnums=(0, 1),
        )
        ctx = mesh
    else:
        step_jit = jax.jit(step_fn, donate_argnums=(0, 1))
        import contextlib

        ctx = contextlib.nullcontext()

    result = TrainResult(final_step=start_step, resumed_from=resumed_from)

    with ctx:
        for step in range(start_step, run.steps):
            t0 = time.time()
            batch = batch_for_step(
                run.seed, step, batch=batch_size, seq=seq_len, vocab=cfg.vocab_size
            )
            params, opt_state, loss, gnorm = step_jit(
                params, opt_state, batch, jnp.asarray(step, jnp.int32)
            )
            if step % run.log_every == 0 or step == run.steps - 1:
                lv = float(loss)
                gv = float(gnorm)
                result.losses.append((step, lv))
                result.grad_norms.append((step, gv))
                print(f"step {step:6d} loss {lv:.4f} gnorm {gv:.3f} "
                      f"dt {time.time()-t0:.2f}s", flush=True)
            dt = time.time() - t0
            if dt > straggler_timeout_s:
                result.straggler_events.append((step, dt))
                if on_straggler is not None:
                    on_straggler(step, dt)
            if run.checkpoint_every and (step + 1) % run.checkpoint_every == 0:
                ckpt.save(run.checkpoint_dir, step + 1, (params, opt_state),
                          keep=run.keep_checkpoints)
            result.final_step = step + 1

    if run.checkpoint_every:
        ckpt.save(run.checkpoint_dir, result.final_step, (params, opt_state),
                  keep=run.keep_checkpoints)
    return result
