import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

"""Distributed-layer benchmarks on 8 fake CPU devices (DESIGN.md §7).

The two lines above MUST stay first: jax locks the device count on first
init (same contract as launch/dryrun.py).

1. **Sharded vs single-device batched updates** — B stacked truncated rank-1
   updates through ``SvdEngine.update_truncated_batch`` with and without the
   ``mesh=`` shard_map dispatch.  (Fake CPU devices share one physical core,
   so this measures dispatch overhead + correctness of the path, not real
   parallel speedup; on a real mesh each device runs B/8 updates.)

2. **Bytes on the wire: compressed vs dense all-reduce** — the dense DP
   gradient pmean against ``optim.compression.compress_decompress`` under
   shard_map, both analytically (``dist.collectives.factor_wire_bytes``) and
   measured from the compiled HLO (``launch.roofline.collective_bytes``):
   the compressed path must move only O((m+n)·r) per layer.

CSV rows (benchmarks/run.py style) + benchmarks/BENCH_dist.json.
"""

import json
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from benchmarks.common import emit, time_fn
from repro.api import SvdState
from repro.core.engine import SvdEngine
from repro.core.svd_update import TruncatedSvd
from repro.dist.collectives import factor_wire_bytes
from repro.launch.roofline import collective_bytes
from repro.optim.compression import (
    CompressionState,
    compress_decompress,
    compression_init,
)

BATCHES = [8, 32, 64]
M, N, RANK = 32, 48, 8
GRAD_M, GRAD_N, GRAD_RANK = 256, 512, 8   # compressed-allreduce layer geometry

OUT = Path(__file__).parent / "BENCH_dist.json"


def _trunc_problem(rng, b):
    us = np.stack([np.linalg.qr(rng.normal(size=(M, RANK)))[0] for _ in range(b)])
    vs = np.stack([np.linalg.qr(rng.normal(size=(N, RANK)))[0] for _ in range(b)])
    ss = np.sort(np.abs(rng.normal(size=(b, RANK))), axis=1)[:, ::-1].copy()
    t = TruncatedSvd(jnp.asarray(us), jnp.asarray(ss), jnp.asarray(vs))
    return t, jnp.asarray(rng.normal(size=(b, M))), jnp.asarray(rng.normal(size=(b, N)))


def bench_sharded_updates(mesh) -> list[dict]:
    rng = np.random.default_rng(0)
    engine = SvdEngine(method="direct")
    rows = []
    for b in BATCHES:
        t, a, bb = _trunc_problem(rng, b)

        us_single = time_fn(lambda t, a, bb: engine.update_truncated_batch(t, a, bb).s,
                            t, a, bb)
        us_shard = time_fn(
            lambda t, a, bb: engine.update_truncated_batch(
                t, a, bb, mesh=mesh, batch_axis="data").s,
            t, a, bb,
        )
        row = {
            "kind": "trunc_batch", "B": b, "m": M, "n": N, "rank": RANK,
            "single_us": us_single, "sharded_us": us_shard,
            "sharded_over_single": us_shard / us_single,
            "devices": jax.device_count(),
        }
        rows.append(row)
        emit(f"bench_dist/trunc/B={b}/single", us_single,
             f"updates_per_s={b / us_single * 1e6:.0f}")
        emit(f"bench_dist/trunc/B={b}/sharded8", us_shard,
             f"updates_per_s={b / us_shard * 1e6:.0f} ratio={row['sharded_over_single']:.2f}")
    return rows


def _hlo_collective_bytes(jitted, *args) -> dict:
    return collective_bytes(jax.jit(jitted).lower(*args).compile().as_text(),
                            jax.device_count())


def bench_wire(mesh) -> dict:
    m, n, r = GRAD_M, GRAD_N, GRAD_RANK
    n_dev = jax.device_count()
    rng = np.random.default_rng(1)
    g_all = jnp.asarray(rng.normal(size=(n_dev, m, n)), jnp.float32)
    state = compression_init(jax.random.PRNGKey(0), m, n, r)

    def dense(g):
        return jax.lax.pmean(g, "data")

    def compressed(g_local, st):
        g_hat, st2 = compress_decompress(st, g_local[0], axis_name="data")
        return g_hat[None], st2._replace(error=st2.error[None])

    dense_fn = shard_map(dense, mesh=mesh, in_specs=(P("data"),), out_specs=P("data"))
    comp_fn = shard_map(
        compressed, mesh=mesh,
        in_specs=(P("data"), P()),
        out_specs=(P("data"), CompressionState(
            v_basis=P(), error=P("data"), tracker=SvdState(P(), P(), P()))),
    )

    hlo_dense = _hlo_collective_bytes(dense_fn, g_all)
    hlo_comp = _hlo_collective_bytes(comp_fn, g_all, state)
    analytic = factor_wire_bytes(m, n, r, n_workers=n_dev)

    dense_bytes = sum(v for k, v in hlo_dense.items() if k != "count")
    comp_bytes = sum(v for k, v in hlo_comp.items() if k != "count")
    result = {
        "layer": {"m": m, "n": n, "rank": r},
        "analytic": analytic,
        "hlo_dense_bytes_per_device": dense_bytes,
        "hlo_compressed_bytes_per_device": comp_bytes,
        "hlo_ratio": dense_bytes / comp_bytes if comp_bytes else None,
        "hlo_detail": {"dense": hlo_dense, "compressed": hlo_comp},
    }
    emit("bench_dist/wire/dense", 0.0, f"bytes={dense_bytes:.0f}")
    emit("bench_dist/wire/compressed", 0.0,
         f"bytes={comp_bytes:.0f} ratio={result['hlo_ratio']:.1f} "
         f"analytic_ratio={analytic['ratio']:.1f}")
    return result


def run() -> dict:
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    summary = {
        "devices": jax.device_count(),
        "sharded_updates": bench_sharded_updates(mesh),
        "wire": bench_wire(mesh),
    }
    OUT.write_text(json.dumps(summary, indent=2))
    print(f"wrote {OUT}", flush=True)
    return summary


if __name__ == "__main__":
    jax.config.update("jax_enable_x64", True)
    run()
