"""qwen1.5-32b [dense] — 64L d_model=5120 40H (GQA kv=40) d_ff=27392
vocab=152064, QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]

40 heads % 16 mesh shards != 0: attention activations rely on GSPMD implicit
padding on the head axis (documented waste, DESIGN.md §6).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
        d_ff=27392, vocab_size=152064, qkv_bias=True,
        mlp_type="swiglu", norm_type="rmsnorm",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="qwen1.5-32b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512, vocab_pad_to=64,
        compute_dtype="float32", remat=False,
    )
