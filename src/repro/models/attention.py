"""GQA/MQA/MHA attention with KV cache (train, prefill, decode paths)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dot, rope_apply, uniform_init

__all__ = ["attn_init", "attn_train", "attn_prefill", "attn_decode", "init_kv_cache"]


def attn_init(key, cfg, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    kvh = cfg.n_kv_heads
    dh = cfg.head_dim
    ks = jax.random.split(key, 4)
    s = (1.0 / d) ** 0.5
    p = {
        "wq": uniform_init(ks[0], (d, h * dh), s, dtype),
        "wk": uniform_init(ks[1], (d, kvh * dh), s, dtype),
        "wv": uniform_init(ks[2], (d, kvh * dh), s, dtype),
        "wo": uniform_init(ks[3], (h * dh, d), (1.0 / (h * dh)) ** 0.5, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((kvh * dh,), dtype)
        p["bv"] = jnp.zeros((kvh * dh,), dtype)
    return p


def _qkv(x, p, cfg):
    b, s, _ = x.shape
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cd = jnp.dtype(cfg.compute_dtype)
    q = dot(x, p["wq"], cd)
    k = dot(x, p["wk"], cd)
    v = dot(x, p["wv"], cd)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(b, s, h, dh).astype(x.dtype)
    k = k.reshape(b, s, kvh, dh).astype(x.dtype)
    v = v.reshape(b, s, kvh, dh).astype(x.dtype)
    return q, k, v


def _sdpa_full(q, k, v, cfg, causal, q_offset=0):
    """Vanilla attention: materializes the (sq, sk) score tensor."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    rep = h // kvh
    cd = jnp.dtype(cfg.compute_dtype)
    qg = q.reshape(b, sq, kvh, rep, dh)
    scores = jnp.einsum(
        "bqkrd,bskd->bkrqs", qg.astype(cd), k.astype(cd),
        preferred_element_type=jnp.float32,
    ) / (dh ** 0.5)
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(sk)
        mask = qpos[:, None] >= kpos[None, :]
        scores = jnp.where(mask[None, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkrqs,bskd->bqkrd", w.astype(cd), v.astype(cd),
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, sq, h * dh).astype(q.dtype)


def _sdpa_blockwise(q, k, v, cfg, causal, q_offset=0):
    """Flash-style attention: online softmax over KV blocks via lax.scan.

    Activation footprint drops from O(sq*sk) to O(sq*block): the memory-term
    fix for 32k prefill / 4k train (EXPERIMENTS.md §Perf). Causal masking is
    applied per block; fully-masked blocks still execute (~2x score-matmul
    flop overhead for causal, which the memory win dwarfs on the dominant
    term). Exact — matches _sdpa_full to fp tolerance (tested).
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    rep = h // kvh
    cd = jnp.dtype(cfg.compute_dtype)
    bk = min(cfg.attn_block_k, sk)
    if sk % bk:
        return _sdpa_full(q, k, v, cfg, causal, q_offset)
    nb = sk // bk

    qg = (q.reshape(b, sq, kvh, rep, dh).astype(cd) / (dh ** 0.5))
    kb = jnp.moveaxis(k.reshape(b, nb, bk, kvh, dh), 1, 0)  # (nb, b, bk, kvh, dh)
    vb = jnp.moveaxis(v.reshape(b, nb, bk, kvh, dh), 1, 0)
    qpos = jnp.arange(sq) + q_offset

    def body(carry, xs):
        m, l, acc = carry
        k_j, v_j, j = xs
        s = jnp.einsum("bqkrd,bskd->bkrqs", qg, k_j.astype(cd),
                       preferred_element_type=jnp.float32)
        if causal:
            kpos = j * bk + jnp.arange(bk)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None, None, :, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkrqs,bskd->bkrqd", p.astype(cd), v_j.astype(cd),
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((b, kvh, rep, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kvh, rep, sq), jnp.float32)
    acc0 = jnp.zeros((b, kvh, rep, sq, dh), jnp.float32)
    if cfg.scan_layers:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0),
                                      (kb, vb, jnp.arange(nb)))
    else:  # unrolled for dry-run cost extraction
        carry = (m0, l0, acc0)
        for j in range(nb):
            carry, _ = body(carry, (kb[j], vb[j], jnp.asarray(j)))
        m, l, acc = carry
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h * dh)
    return out.astype(q.dtype)


def _sdpa(q, k, v, cfg, causal, q_offset=0):
    """q: (b, sq, h, dh); k/v: (b, sk, kvh, dh). GQA via head grouping.
    Dispatches to blockwise (flash) attention when cfg.attn_block_k is set
    and the KV length warrants it."""
    sq, sk = q.shape[1], k.shape[1]
    if cfg.attn_block_k and sk > cfg.attn_block_k and sq > 1:
        return _sdpa_blockwise(q, k, v, cfg, causal, q_offset)
    return _sdpa_full(q, k, v, cfg, causal, q_offset)


def _maybe_rope(q, k, cfg, positions):
    if cfg.use_rope:
        q = rope_apply(q, positions, cfg.rope_theta)
        k = rope_apply(k, positions, cfg.rope_theta)
    return q, k


def attn_train(x, p, cfg, positions, causal=True):
    q, k, v = _qkv(x, p, cfg)
    q, k = _maybe_rope(q, k, cfg, positions)
    o = _sdpa(q, k, v, cfg, causal=causal)
    return dot(o, p["wo"], jnp.dtype(cfg.compute_dtype)).astype(x.dtype)


def init_kv_cache(batch, max_len, cfg, dtype):
    kvh, dh = cfg.n_kv_heads, cfg.head_dim
    cache = {
        "k": jnp.zeros((batch, max_len, kvh, dh), dtype),
        "v": jnp.zeros((batch, max_len, kvh, dh), dtype),
    }
    if cfg.kv_cache_dtype == "int8":
        cache = {
            "k": jnp.zeros((batch, max_len, kvh, dh), jnp.int8),
            "v": jnp.zeros((batch, max_len, kvh, dh), jnp.int8),
            "k_scale": jnp.zeros((batch, max_len, kvh), jnp.float32),
            "v_scale": jnp.zeros((batch, max_len, kvh), jnp.float32),
        }
    return cache


def _quantize_kv(x):
    """Per-(token, head) symmetric int8. x: (b, s, kvh, dh)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def attn_prefill(x, p, cfg, positions):
    """Full-sequence prefill; returns (out, cache with seq_len entries)."""
    q, k, v = _qkv(x, p, cfg)
    q, k = _maybe_rope(q, k, cfg, positions)
    o = _sdpa(q, k, v, cfg, causal=True)
    out = dot(o, p["wo"], jnp.dtype(cfg.compute_dtype)).astype(x.dtype)
    return out, {"k": k, "v": v}


def attn_decode(x, p, cfg, cache, pos):
    """One-token decode: x (b, 1, d); cache holds ``pos`` valid entries.

    With cfg.kv_cache_dtype == "int8" the cache stores per-(token, head)
    symmetric-quantized KV (+ f32 scales): 2x less HBM than bf16 — the
    §Perf 'kv-int8' iteration that makes qwen1.5 decode_32k fit 16 GB chips.
    """
    b = x.shape[0]
    q, k, v = _qkv(x, p, cfg)
    posv = jnp.full((b, 1), pos, jnp.int32)
    q, k = _maybe_rope(q, k, cfg, posv)
    quantized = cfg.kv_cache_dtype == "int8"
    if quantized:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, pos, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, pos, axis=1),
            "k_scale": jax.lax.dynamic_update_slice_in_dim(cache["k_scale"], ks, pos, axis=1),
            "v_scale": jax.lax.dynamic_update_slice_in_dim(cache["v_scale"], vs, pos, axis=1),
        }
        ck = _dequantize_kv(new_cache["k"], new_cache["k_scale"], x.dtype)
        cv = _dequantize_kv(new_cache["v"], new_cache["v_scale"], x.dtype)
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
    # attend over the full (static) cache; mask positions beyond pos
    sk = ck.shape[1]
    kvh, dh = cfg.n_kv_heads, cfg.head_dim
    h = cfg.n_heads
    rep = h // kvh
    cd = jnp.dtype(cfg.compute_dtype)
    qg = q.reshape(b, 1, kvh, rep, dh)
    scores = jnp.einsum(
        "bqkrd,bskd->bkrqs", qg.astype(cd), ck.astype(cd),
        preferred_element_type=jnp.float32,
    ) / (dh ** 0.5)
    valid = (jnp.arange(sk) <= pos)[None, None, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum(
        "bkrqs,bskd->bqkrd", w.astype(cd), cv.astype(cd),
        preferred_element_type=jnp.float32,
    ).reshape(b, 1, h * dh).astype(x.dtype)
    out = dot(o, p["wo"], cd).astype(x.dtype)
    if quantized:
        return out, new_cache
    return out, {"k": ck, "v": cv}
