"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> record.

Three LM cells (selected from the baseline roofline table — worst fraction /
most collective-bound / most technique-representative plumbing; see
EXPERIMENTS.md §Perf for the napkin math per hypothesis):

  A. qwen2-72b      x train_4k    (biggest dense; memory+collective bound)
  B. deepseek-v2-lite x prefill_32k (most collective-bound; MoE+MLA)
  C. qwen1.5-32b    x decode_32k  (worst fit: MHA cache replicates on model)

Each variant re-runs the dry-run cell with a method tag; JSONs land next to
the baselines for before/after diffing.

``--svd`` measures the OTHER hot path this repo serves — batched truncated
rank-1 SVD updates — through ``repro.api``'s policy-resolved engine
(``aot_compiled`` on the shared plan cache; pre-api call shapes are gone
from this driver): HLO cost extraction + roofline terms + the analytic
useful-FLOPs ratio (``roofline.svd_update_flops``) per service geometry,
JSONs in the same ``benchmarks/dryrun`` table.  The ``FLEET_CELLS`` rows
roofline the fleet tier's per-shard rounds (``repro.fleet``): the rank-k
scan executable a backlogged shard seals, where useful FLOPs scale with the
depth k while the host-side state (re)stacking is paid once per round.
"""

# must precede the first jax-importing module: jax locks the device count on
# first init, and only the dry-run wants 512 placeholder devices
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import traceback
from pathlib import Path

from repro import configs
from repro.launch.dryrun import run_cell
from repro.launch.roofline import HW, roofline_terms, svd_update_flops

VARIANTS = {
    # ---- cell A: qwen2-72b train_4k
    ("qwen2-72b", "train_4k"): [
        # H1: remat recompute inflates HLO flops ~1.33x; saving matmul
        # outputs removes most recompute at modest memory cost.
        ("remat-dots", lambda c: c.replace(remat_policy="dots"), {}),
        # H2: the (s x s) score tensor dominates "bytes accessed" at seq 4k;
        # blockwise attention removes its HBM residency.
        ("flash1k", lambda c: c.replace(attn_block_k=1024), {}),
        # H3: both.
        ("flash1k+dots", lambda c: c.replace(attn_block_k=1024, remat_policy="dots"), {}),
        # H8: peak is only 3.4 GB of 16 — remat over-saves; dropping it
        # removes the recompute forward entirely (flops -~25%).
        ("no-remat", lambda c: c.replace(remat=False), {}),
        # H9: 9.6 TB/step of all-reduce = XLA reducing partial matmul
        # products over the FSDP-sharded contraction dim. Gather bf16 weights
        # at use instead (ZeRO-3): ~17 GB of all-gather replaces it.
        ("zero3-gather", lambda c: c.replace(fsdp_gather_params=True), {}),
        ("zero3+no-remat", lambda c: c.replace(fsdp_gather_params=True, remat=False), {}),
    ],
    # ---- cell B: deepseek-v2-lite prefill_32k
    ("deepseek-v2-lite-16b", "prefill_32k"): [
        # H4: GSPMD reshards the MoE dispatch tensors through all-gathers;
        # explicit EP constraints keep group on data / experts on model.
        ("moe-ep", lambda c: c.replace(moe_shard_constraints=True), {}),
        # H5: the absorbed-MLA (h, sq, sk) scores at 32k dominate memory;
        # query chunking shrinks them 16x.
        ("mla-qchunk", lambda c: c.replace(mla_q_chunk=2048), {}),
        ("moe-ep+qchunk", lambda c: c.replace(moe_shard_constraints=True,
                                              mla_q_chunk=2048), {}),
        # H9b: same contraction-dim AR pathology as cell A.
        ("zero3-gather", lambda c: c.replace(fsdp_gather_params=True), {}),
        ("zero3+qchunk", lambda c: c.replace(fsdp_gather_params=True,
                                             mla_q_chunk=2048), {}),
    ],
    # ---- cell C: qwen1.5-32b decode_32k
    ("qwen1.5-32b", "decode_32k"): [
        # H6: kv heads (40) don't divide model=16 -> cache replicated 16x;
        # shard the sequence dim over model instead.
        ("kv-seq-shard", lambda c: c, {"cache_seq_fallback": True}),
        # H7: int8 KV halves cache bytes again -> fits 16 GB.
        ("kv-seq-shard+int8", lambda c: c.replace(kv_cache_dtype="int8"),
         {"cache_seq_fallback": True}),
    ],
}


# SVD serving cells: (m, n, rank, batch) — tracker flushes (optimizer
# geometry), per-user adapters (serving geometry), and a wide-matrix stream.
SVD_CELLS = [
    (256, 512, 8, 64),
    (512, 768, 16, 16),
    (1024, 4096, 32, 8),
]

# Fleet per-shard cells: (m, n, rank, batch, depth) — the round a backlogged
# fleet shard seals (repro.fleet, DESIGN.md §13): bench_fleet's geometry
# partitioned over 8 shards (64 streams -> B=8 per shard), with the depth-k
# scan column amortizing state re-stacking over k sequential pairs.
FLEET_CELLS = [
    (64, 96, 8, 8, 8),
    (64, 96, 8, 8, 32),
    (512, 768, 16, 2, 8),
]


def run_svd_cell(m: int, n: int, r: int, batch: int, *, out_dir: Path,
                 k: int | None = None, dtype="float32") -> dict:
    """Roofline one batched truncated-update flush through the api-resolved
    engine (the shared plan cache — no side lowering).  ``k`` rooflines the
    rank-k scan executable a fleet shard's deep rounds dispatch."""
    import jax.numpy as jnp

    from repro import api
    from repro.api.update import engine_from_key

    policy = api.UpdatePolicy(method="direct")
    eng = engine_from_key(policy, r + 1)
    compiled = eng.aot_compiled(batch=batch, m=m, n=n, rank=r, k=k,
                                dtype=jnp.dtype(dtype))
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    if k and cost:
        # XLA cost analysis counts a lax.scan body ONCE, not per trip —
        # scale to the k trips a deep round actually executes
        cost = {key: v * k if isinstance(v, (int, float)) else v
                for key, v in cost.items()}
    mem = compiled.memory_analysis()
    hw = HW(chips=1)
    rt = roofline_terms(cost or {}, {"count": 0}, hw)
    # k sequential pairs per stream per call: the useful work scales with k
    model = svd_update_flops(m, n, r, batch) * (k or 1)
    shape = f"B{batch}_m{m}_n{n}_r{r}" + (f"_k{k}" if k else "")
    record = {
        "arch": "svd-flush" if k is None else "svd-fleet-shard",
        "shape": shape,
        "mesh": "single",
        "method": "engine-trunc-batch" if k is None else "engine-rank-k-scan",
        "roofline": rt,
        "memory": {
            "peak_bytes": getattr(mem, "temp_size_in_bytes", None),
        },
        "useful_flops_ratio": (
            model / rt["flops_per_device"] if rt["flops_per_device"] else None
        ),
        "model_flops": model,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"svd_{shape}.json"
    path.write_text(json.dumps(record, indent=1))
    return record


def run_svd_cells(out_dir: Path) -> None:
    cells = [(m, n, r, b, None) for m, n, r, b in SVD_CELLS]
    cells += list(FLEET_CELLS)
    for m, n, r, b, k in cells:
        rec = run_svd_cell(m, n, r, b, k=k, out_dir=out_dir)
        rt = rec["roofline"]
        ur = rec["useful_flops_ratio"]
        print(f"OK {rec['arch']}/{rec['shape']}: "
              f"t_comp={rt['t_compute_s']*1e3:.3f}ms "
              f"t_mem={rt['t_memory_s']*1e3:.3f}ms "
              f"useful={ur if ur is None else round(ur, 3)}",
              flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/dryrun")
    ap.add_argument("--cell", default=None, help="arch:shape filter")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--svd", action="store_true",
                    help="roofline the SVD flush cells instead of LM variants")
    args = ap.parse_args()
    out_dir = Path(args.out)

    if args.svd:
        run_svd_cells(out_dir)
        return

    for (arch, shape), variants in VARIANTS.items():
        if args.cell and args.cell != f"{arch}:{shape}":
            continue
        for tag, mutate, kw in variants:
            try:
                cfg = mutate(configs.get(arch))
                # baseline comparability: cell C's baseline ran without the
                # seq-shard fallback; variants opt in explicitly
                kwargs = {"cache_seq_fallback": False}
                kwargs.update(kw)
                r = run_cell(arch, shape, multi_pod=args.multi_pod,
                             out_dir=out_dir, method_tag=tag,
                             cfg_override=cfg, **kwargs)
                rt = r["roofline"]
                print(f"OK {arch}/{shape}/{tag}: "
                      f"t_comp={rt['t_compute_s']*1e3:.1f}ms "
                      f"t_mem={rt['t_memory_s']*1e3:.1f}ms "
                      f"t_coll={rt['t_collective_s']*1e3:.1f}ms "
                      f"peak={r['memory']['peak_bytes'] and r['memory']['peak_bytes']/1e9:.1f}GB",
                      flush=True)
            except Exception as e:
                print(f"FAIL {arch}/{shape}/{tag}: {e}", flush=True)
                traceback.print_exc(limit=3)


if __name__ == "__main__":
    main()
