"""``repro.updates`` — op algebra, planner lowering, and exact-reference
parity on every dispatch route (ISSUE 5 acceptance).

Parity contract: ``api.apply(state, op).materialize()`` must match the
rank-r reconstruction of ``jnp.linalg.svd(op.apply_dense(A))`` for every op
type and ``Compose`` ordering, on the single, batched, truncated, and
mesh-sharded routes (the golden-harness style of ``test_api_compat.py``).
Truncated routes use rank-budgeted problems (low-rank data inside a roomy
state) where the Brand truncation discards exact zeros, so the comparison
is tight.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.api import SvdState, UpdatePolicy
from repro.core.engine import default_engine
from repro.updates import (
    AppendCols,
    AppendRows,
    Compose,
    Decay,
    DenseDelta,
    RankK,
    Sparse,
    apply_many,
    lower,
    schedule_cache_info,
    skeleton_from_spec,
    sketch_svd,
    sparse_sketch_svd,
    spec_from_json,
    spec_to_json,
    warmup_plan,
)

RNG = np.random.default_rng(11)
REPO = Path(__file__).resolve().parent.parent


def _lowrank(m, n, r, rng=RNG):
    """A dense (m, n) matrix of exact rank r."""
    return rng.normal(size=(m, r)) @ rng.normal(size=(r, n))


def _top_r_reconstruction(dense, r):
    u, s, vt = np.linalg.svd(np.asarray(dense), full_matrices=False)
    return (u[:, :r] * s[:r]) @ vt[:r]


def _assert_parity(state, op, *, atol=1e-10):
    """api.apply(state, op).materialize() == top-rank reconstruction of the
    dense reference — the ISSUE acceptance identity."""
    out = api.apply(state, op)
    dense = np.asarray(op.apply_dense(np.asarray(state.materialize())))
    rec = _top_r_reconstruction(dense, out.rank)
    np.testing.assert_allclose(np.asarray(out.materialize()), rec, atol=atol)
    return out


# ---------------------------------------------------------------------------
# op algebra: dense semantics, geometry, specs, pytree behaviour
# ---------------------------------------------------------------------------


def test_op_dense_semantics_and_geometry():
    a_mat = RNG.normal(size=(4, 6))
    uk, vk = RNG.normal(size=(4, 2)), RNG.normal(size=(6, 2))
    np.testing.assert_allclose(
        np.asarray(RankK(uk, vk).apply_dense(a_mat)), a_mat + uk @ vk.T
    )
    rows = RNG.normal(size=(3, 6))
    op = AppendRows(rows)
    assert op.out_shape(4, 6) == (7, 6)
    np.testing.assert_allclose(
        np.asarray(op.apply_dense(a_mat)), np.concatenate([a_mat, rows])
    )
    cols = RNG.normal(size=(4, 2))
    assert AppendCols(cols).out_shape(4, 6) == (4, 8)
    np.testing.assert_allclose(
        np.asarray(Decay(0.25).apply_dense(a_mat)), 0.25 * a_mat
    )
    comp = Compose((Decay(2.0), AppendRows(rows), RankK(np.zeros((7, 1)),
                                                        np.zeros((6, 1)))))
    assert comp.out_shape(4, 6) == (7, 6)
    np.testing.assert_allclose(
        np.asarray(comp.apply_dense(a_mat)),
        np.concatenate([2.0 * a_mat, rows]),
    )


def test_op_validation():
    with pytest.raises(ValueError, match="either rows= or from_svd"):
        AppendRows()
    with pytest.raises(ValueError, match="either rows= or from_svd"):
        AppendRows(rows=np.zeros((1, 2)), u=np.zeros((1, 1)),
                   s=np.zeros(1), v=np.zeros((2, 1)))
    with pytest.raises(ValueError, match="sketch rank"):
        DenseDelta(np.zeros((2, 2)), rank=0)
    with pytest.raises(TypeError, match="UpdateOps"):
        Compose((Decay(0.5), "not-an-op"))


def test_specs_roundtrip_and_skeletons():
    ops = [
        RankK(np.zeros((3, 2)), np.zeros((4, 2))),
        AppendRows(np.zeros((2, 4))),
        AppendRows.from_svd(np.zeros((2, 1)), np.zeros(1), np.zeros((4, 1))),
        AppendCols.from_svd(np.zeros((3, 1)), np.zeros(1), np.zeros((2, 1))),
        DenseDelta(np.zeros((3, 4)), rank=2),
        Decay(0.5),
        Compose((Decay(0.9), RankK(np.zeros((3, 1)), np.zeros((4, 1))))),
    ]
    for op in ops:
        spec = op.spec()
        assert hash(spec) is not None                       # cache-key-able
        assert spec_from_json(json.loads(json.dumps(spec_to_json(spec)))) == spec
        skel = skeleton_from_spec(spec)
        assert jax.tree.structure(skel) == jax.tree.structure(op)


def test_ops_are_pytrees():
    op = Compose((Decay(0.5), RankK(jnp.ones((2, 1)), jnp.ones((3, 1)))))
    doubled = jax.tree.map(lambda x: 2 * x, op)
    assert isinstance(doubled, Compose)
    assert float(np.asarray(doubled.ops[0].lam)) == 1.0
    assert len(jax.tree.leaves(op)) == 3                    # lam + u + v


# ---------------------------------------------------------------------------
# parity: full (single + batched) routes
# ---------------------------------------------------------------------------


def _full_state(m, n, rng=RNG):
    return SvdState.from_dense(jnp.asarray(rng.uniform(1, 9, (m, n))))


@pytest.mark.parametrize("make_op", [
    lambda m, n, rng: RankK(rng.normal(size=(m, 3)), rng.normal(size=(n, 3))),
    lambda m, n, rng: DenseDelta(_lowrank(m, n, 2, rng), rank=2),
    lambda m, n, rng: Decay(0.7),
    lambda m, n, rng: Compose((
        Decay(0.9),
        RankK(rng.normal(size=(m, 2)), rng.normal(size=(n, 2))),
        DenseDelta(_lowrank(m, n, 1, rng), rank=1),
    )),
], ids=["rank_k", "dense_delta", "decay", "compose"])
def test_full_single_parity(make_op):
    rng = np.random.default_rng(0)
    st = _full_state(6, 9, rng)
    _assert_parity(st, make_op(6, 9, rng), atol=1e-9)


def test_full_batched_parity_matches_loop_of_singles():
    rng = np.random.default_rng(1)
    b_sz, m, n = 4, 5, 7
    singles = [_full_state(m, n, rng) for _ in range(b_sz)]
    stacked = SvdState(
        u=jnp.stack([s.u for s in singles]),
        s=jnp.stack([s.s for s in singles]),
        v=jnp.stack([s.v for s in singles]),
    )
    uk = rng.normal(size=(b_sz, m, 2))
    vk = rng.normal(size=(b_sz, n, 2))
    out = api.apply(stacked, RankK(uk, vk))
    assert out.is_batched and out.batch == b_sz
    for i in range(b_sz):
        ref = api.apply(singles[i], RankK(uk[i], vk[i]))
        np.testing.assert_allclose(np.asarray(out.materialize()[i]),
                                   np.asarray(ref.materialize()), atol=1e-9)


# ---------------------------------------------------------------------------
# parity: truncated routes (appends live here; rank-budgeted exactness)
# ---------------------------------------------------------------------------


def _roomy_state(m, n, data_rank, state_rank, rng=RNG):
    """Truncated state over exact-rank-``data_rank`` data with headroom."""
    return SvdState.from_dense(jnp.asarray(_lowrank(m, n, data_rank, rng)),
                               rank=state_rank)


@pytest.mark.parametrize("make_op", [
    lambda m, n, rng: RankK(rng.normal(size=(m, 1)), rng.normal(size=(n, 1))),
    lambda m, n, rng: AppendRows(_lowrank(3, n, 1, rng)),
    lambda m, n, rng: AppendRows.from_svd(
        np.linalg.qr(rng.normal(size=(3, 2)))[0],
        np.abs(rng.normal(size=2)) + 1,
        np.linalg.qr(rng.normal(size=(n, 2)))[0]),
    lambda m, n, rng: AppendCols(_lowrank(m, 2, 1, rng)),
    lambda m, n, rng: Compose((
        Decay(0.8),
        AppendRows(_lowrank(2, n, 1, rng)),
        RankK(rng.normal(size=(m + 2, 1)), rng.normal(size=(n, 1))),
    )),
], ids=["rank1", "append_rows", "append_rows_factored", "append_cols",
        "compose_decay_append_rank1"])
def test_truncated_single_parity(make_op):
    rng = np.random.default_rng(2)
    m, n = 7, 10
    st = _roomy_state(m, n, data_rank=2, state_rank=6, rng=rng)
    _assert_parity(st, make_op(m, n, rng), atol=1e-8)


def test_compose_orderings_differ_and_each_matches():
    """Decay-then-RankK != RankK-then-Decay; both lower exactly."""
    rng = np.random.default_rng(3)
    m, n = 6, 8
    st = _roomy_state(m, n, data_rank=2, state_rank=5, rng=rng)
    uk, vk = rng.normal(size=(m, 1)), rng.normal(size=(n, 1))
    ab = Compose((Decay(0.5), RankK(uk, vk)))
    ba = Compose((RankK(uk, vk), Decay(0.5)))
    out_ab = _assert_parity(st, ab, atol=1e-8)
    out_ba = _assert_parity(st, ba, atol=1e-8)
    gap = np.abs(np.asarray(out_ab.materialize())
                 - np.asarray(out_ba.materialize())).max()
    assert gap > 1e-3          # genuinely different operators


def test_truncated_batched_parity():
    rng = np.random.default_rng(4)
    b_sz, m, n, r = 5, 6, 8, 4
    singles = [_roomy_state(m, n, 2, r, rng) for _ in range(b_sz)]
    stacked = SvdState(
        u=jnp.stack([s.u for s in singles]),
        s=jnp.stack([s.s for s in singles]),
        v=jnp.stack([s.v for s in singles]),
    )
    uk = rng.normal(size=(b_sz, m, 2))
    vk = rng.normal(size=(b_sz, n, 2))
    out = api.apply(stacked, RankK(uk, vk))
    for i in range(b_sz):
        dense = np.asarray(singles[i].materialize()) + uk[i] @ vk[i].T
        np.testing.assert_allclose(np.asarray(out.materialize()[i]),
                                   _top_r_reconstruction(dense, r), atol=1e-8)


def test_append_requires_truncated_state():
    st = _full_state(4, 6)
    with pytest.raises(ValueError, match="truncated state"):
        api.apply(st, AppendRows(np.zeros((2, 6))))


# ---------------------------------------------------------------------------
# mesh-sharded route (8 fake devices, subprocess)
# ---------------------------------------------------------------------------


def test_mesh_sharded_apply_parity_on_8_devices():
    script = textwrap.dedent("""
        import json
        import jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp, numpy as np
        from repro import api
        from repro.updates import RankK

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        B, m, n, r, k = 8, 6, 8, 4, 3

        def lowrank(m, n, q):
            return rng.normal(size=(m, q)) @ rng.normal(size=(q, n))

        dense = np.stack([lowrank(m, n, 1) for _ in range(B)])
        sts = [api.SvdState.from_dense(jnp.asarray(d), rank=r) for d in dense]
        stacked = api.SvdState(
            u=jnp.stack([s.u for s in sts]),
            s=jnp.stack([s.s for s in sts]),
            v=jnp.stack([s.v for s in sts]),
        )
        uk = rng.normal(size=(B, m, k)); vk = rng.normal(size=(B, n, k))
        pol = api.UpdatePolicy(method="direct", mesh=mesh, batch_axis="data")
        out = api.apply(stacked, RankK(uk, vk), pol)
        err = 0.0
        for i in range(B):
            d = dense[i] + uk[i] @ vk[i].T
            u, s, vt = np.linalg.svd(d, full_matrices=False)
            rec = (u[:, :r] * s[:r]) @ vt[:r]
            err = max(err, float(np.abs(np.asarray(out.materialize()[i]) - rec).max()))

        # Sparse rides the same sharded route: shared COO, batched values
        from repro.updates import Sparse
        nnz = 6
        rows = rng.integers(0, 2, nnz).astype(np.int32)   # rank(S) <= 2
        cols = rng.integers(0, n, nnz).astype(np.int32)
        bvals = rng.normal(size=(B, nnz))
        sout = api.apply(stacked, Sparse(rows, cols, bvals, rank=2), pol)
        serr = 0.0
        for i in range(B):
            d = dense[i].copy()
            np.add.at(d, (rows, cols), bvals[i])
            u, s, vt = np.linalg.svd(d, full_matrices=False)
            rec = (u[:, :r] * s[:r]) @ vt[:r]
            serr = max(serr, float(np.abs(np.asarray(sout.materialize()[i]) - rec).max()))
        print(json.dumps({"err": err, "sparse_err": serr,
                          "devices": jax.device_count()}))
    """)
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=420,
        env={
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
            "PYTHONPATH": str(REPO / "src"),
            "PATH": "/usr/bin:/bin",
            "JAX_PLATFORMS": "cpu",
            "HOME": "/tmp",
        },
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-4000:]}"
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    assert out["err"] < 1e-8
    assert out["sparse_err"] < 1e-8


# ---------------------------------------------------------------------------
# planner: schedule cache, free decay, cross-op batching
# ---------------------------------------------------------------------------


def test_schedule_cache_hits_on_same_shape():
    rng = np.random.default_rng(5)
    st = _roomy_state(6, 8, 2, 4, rng)
    op1 = RankK(rng.normal(size=(6, 2)), rng.normal(size=(8, 2)))
    op2 = RankK(rng.normal(size=(6, 2)), rng.normal(size=(8, 2)))
    lower(op1, st)
    before = schedule_cache_info()
    plan = lower(op2, st)                # same spec + geometry -> cache hit
    after = schedule_cache_info()
    assert after.hits == before.hits + 1
    assert after.misses == before.misses
    assert [s[0] for s in plan] == ["rank1", "rank1"]


def test_decay_is_free_of_engine_dispatches():
    rng = np.random.default_rng(6)
    st = _roomy_state(6, 8, 2, 4, rng)
    # a private engine configuration: any dispatch would show up here
    pol = UpdatePolicy(method="direct", deflate_rtol=3.25e-13)
    eng = default_engine("direct", deflate_rtol=3.25e-13)
    before = eng.cache_info()
    out = api.apply(st, Decay(0.5), pol)
    after = eng.cache_info()
    assert (after.hits, after.misses) == (before.hits, before.misses)
    np.testing.assert_allclose(np.asarray(out.s), 0.5 * np.asarray(st.s),
                               rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(out.u), np.asarray(st.u), rtol=0, atol=0)


def test_apply_many_batches_rank_k_across_streams():
    """B streams x rank-k: the planner runs k BATCHED dispatches (one
    geometry entry, k calls), not B*k singles — and matches the sequential
    reference exactly."""
    rng = np.random.default_rng(7)
    b_sz, m, n, r, k = 6, 6, 8, 4, 3
    sts = [_roomy_state(m, n, 1, r, rng) for _ in range(b_sz)]
    ops = [RankK(rng.normal(size=(m, k)), rng.normal(size=(n, k)))
           for _ in range(b_sz)]

    # private engine config so dispatch accounting is isolated
    pol = UpdatePolicy(method="direct", deflate_rtol=7.25e-13)
    eng = default_engine("direct", deflate_rtol=7.25e-13)
    assert eng.cache_info().entries == 0
    outs = apply_many(sts, ops, pol)
    info = eng.cache_info()
    assert info.entries == 1               # ONE batched geometry, reused
    assert info.misses == 1 and info.hits == k - 1

    for st, op, out in zip(sts, ops, outs):
        seq = st
        for i in range(k):
            seq = api.update(seq, op.u[:, i], op.v[:, i], pol)
        np.testing.assert_allclose(np.asarray(out.materialize()),
                                   np.asarray(seq.materialize()), atol=1e-9)


def test_apply_many_mixed_ops_and_geometries():
    rng = np.random.default_rng(8)
    sts = [
        _roomy_state(6, 8, 1, 4, rng),
        _roomy_state(6, 8, 1, 4, rng),
        _roomy_state(5, 9, 1, 3, rng),
    ]
    ops = [
        RankK(rng.normal(size=(6, 2)), rng.normal(size=(8, 2))),
        Compose((Decay(0.5), RankK(rng.normal(size=(6, 2)),
                                   rng.normal(size=(8, 2))))),
        Decay(0.25),
    ]
    outs = apply_many(sts, ops, UpdatePolicy(method="direct"))
    for st, op, out in zip(sts, ops, outs):
        dense = np.asarray(op.apply_dense(np.asarray(st.materialize())))
        np.testing.assert_allclose(np.asarray(out.materialize()),
                                   _top_r_reconstruction(dense, out.rank),
                                   atol=1e-8)


def test_warmup_plan_covers_append_geometries():
    pol = UpdatePolicy(method="direct")
    op = Compose((AppendRows(np.zeros((2, 8))),
                  RankK(np.zeros((8, 1)), np.zeros((8, 1)))))
    geoms = warmup_plan(pol, op, m=6, n=8, rank=4, dtype=jnp.float64)
    assert geoms == [(8, 8)]               # post-append geometry warmed


# ---------------------------------------------------------------------------
# api surface
# ---------------------------------------------------------------------------


def test_api_exposes_apply():
    from repro.updates import planner

    assert api.apply is planner.apply
    assert api.apply_many is planner.apply_many
    assert "apply" in api.__all__ and "apply_many" in api.__all__


def test_apply_many_rejects_stacked_states():
    st = SvdState(u=jnp.zeros((2, 4, 3)), s=jnp.ones((2, 3)),
                  v=jnp.zeros((2, 5, 3)))
    with pytest.raises(ValueError, match="unbatched"):
        apply_many([st], [Decay(0.5)])


# ---------------------------------------------------------------------------
# dist.merge: mixed-height shards ride the AppendRows lowering
# ---------------------------------------------------------------------------


def test_merge_append_matches_dense_svd():
    from repro.dist.merge import merge_append, merge_tree

    rng = np.random.default_rng(9)
    n, r = 10, 3
    blocks = [jnp.asarray(_lowrank(m_i, n, 1, rng)) for m_i in (6, 4, 3)]
    shards = [SvdState.from_dense(b, rank=r) for b in blocks]

    merged = merge_append(shards[0], shards[1], rank=r)
    dense = np.concatenate([np.asarray(b) for b in blocks[:2]])
    got = np.asarray(merged.u) * np.asarray(merged.s) @ np.asarray(merged.v).T
    np.testing.assert_allclose(got, _top_r_reconstruction(dense, r), atol=1e-8)

    # the tree merge routes mixed heights through the same lowering
    out = merge_tree(shards, rank=r)
    dense = np.concatenate([np.asarray(b) for b in blocks])
    got = np.asarray(out.u) * np.asarray(out.s) @ np.asarray(out.v).T
    np.testing.assert_allclose(got, _top_r_reconstruction(dense, r), atol=1e-8)


# ---------------------------------------------------------------------------
# optim: rank-k tracker absorb through the planner
# ---------------------------------------------------------------------------


def test_compression_tracker_rank_k():
    from repro.optim import compression as C

    key = jax.random.PRNGKey(0)
    m, n, r = 12, 10, 4
    st = C.compression_init(key, m, n, r, jnp.float64)
    g = jnp.asarray(np.random.default_rng(10).normal(size=(m, n)))
    gh1, s1 = C.compress_decompress(st, g, tracker_rank=1)
    gh3, s3 = C.compress_decompress(st, g, tracker_rank=3)
    # the compressed gradient is tracker-independent
    np.testing.assert_allclose(np.asarray(gh1), np.asarray(gh3), rtol=0, atol=0)
    # a rank-k absorb captures strictly more spectral mass than rank-1
    assert float(s3.tracker.s.sum()) > float(s1.tracker.s.sum())
    assert int((np.asarray(s3.tracker.s) > 1e-8).sum()) >= 3

# ---------------------------------------------------------------------------
# ISSUE 7: sketch extraction + Sparse lowering parity (DESIGN.md §12)
# ---------------------------------------------------------------------------


def _sparse_coo(m, n, nnz, rng, *, rows_used=None):
    """Random COO with duplicates; ``rows_used`` caps rank(S) by confining
    all entries to that many distinct rows."""
    hi = rows_used if rows_used is not None else m
    rows = rng.integers(0, hi, nnz).astype(np.int32)
    cols = rng.integers(0, n, nnz).astype(np.int32)
    if nnz >= 2:
        rows[1], cols[1] = rows[0], cols[0]      # collision must accumulate
    vals = rng.normal(size=nnz)
    return rows, cols, vals


def test_sketch_svd_matches_dense_topk():
    """Dense range-finder == numpy top-k on a low-rank delta (exact regime),
    close on a full-rank one; batched call == loop of singles."""
    rng = np.random.default_rng(21)
    m, n, k = 30, 24, 4
    delta = jnp.asarray(_lowrank(m, n, k, rng))
    u, s, v = sketch_svd(delta, k)
    np.testing.assert_allclose(
        np.asarray(u) * np.asarray(s) @ np.asarray(v).T, np.asarray(delta),
        atol=1e-9)
    sv = np.linalg.svd(np.asarray(delta), compute_uv=False)
    np.testing.assert_allclose(np.asarray(s), sv[:k], atol=1e-9)

    batch = jnp.asarray(np.stack([_lowrank(m, n, k, rng) for _ in range(3)]))
    ub, sb, vb = sketch_svd(batch, k)
    for i in range(3):
        # same trace-time test matrix -> same subspace; batched LAPACK may
        # differ from the single path only at rounding level
        ui, si, vi = sketch_svd(batch[i], k)
        np.testing.assert_allclose(np.asarray(sb[i]), np.asarray(si),
                                   rtol=1e-12)
        np.testing.assert_allclose(
            np.asarray(ub[i]) * np.asarray(sb[i]) @ np.asarray(vb[i]).T,
            np.asarray(ui) * np.asarray(si) @ np.asarray(vi).T, atol=1e-9)


def test_sparse_sketch_svd_exact_and_truncating():
    rng = np.random.default_rng(22)
    m, n, nnz = 40, 30, 18
    rows, cols, vals = _sparse_coo(m, n, nnz, rng)
    S = np.zeros((m, n))
    np.add.at(S, (rows, cols), vals)
    rank = np.linalg.matrix_rank(S)
    # exact regime: k + oversample covers rank(S)
    u, s, v = sparse_sketch_svd(rows, cols, jnp.asarray(vals), m=m, n=n,
                                k=int(rank), oversample=8)
    np.testing.assert_allclose(np.asarray(u) * np.asarray(s) @ np.asarray(v).T,
                               S, atol=1e-10)
    # truncating regime still nails the top singular values (l >= rank here)
    kt = 3
    _, st, _ = sparse_sketch_svd(rows, cols, jnp.asarray(vals), m=m, n=n,
                                 k=kt, oversample=int(rank))
    sv = np.linalg.svd(S, compute_uv=False)
    np.testing.assert_allclose(np.asarray(st), sv[:kt], atol=1e-10)


def test_sparse_full_single_parity():
    rng = np.random.default_rng(24)
    m, n, nnz = 6, 9, 10
    st = _full_state(m, n, rng)
    rows, cols, vals = _sparse_coo(m, n, nnz, rng, rows_used=3)
    _assert_parity(st, Sparse(rows, cols, vals, rank=3), atol=1e-8)


def test_sparse_truncated_single_parity():
    rng = np.random.default_rng(25)
    m, n = 7, 10
    st = _roomy_state(m, n, data_rank=2, state_rank=6, rng=rng)
    rows, cols, vals = _sparse_coo(m, n, 8, rng, rows_used=2)
    _assert_parity(st, Sparse(rows, cols, vals, rank=2), atol=1e-8)


def test_sparse_batched_parity_matches_loop_of_singles():
    """Batched vals over shared coordinates == loop of single applies."""
    rng = np.random.default_rng(26)
    b_sz, m, n, nnz = 3, 5, 7, 6
    singles = [_full_state(m, n, rng) for _ in range(b_sz)]
    stacked = SvdState(
        u=jnp.stack([s.u for s in singles]),
        s=jnp.stack([s.s for s in singles]),
        v=jnp.stack([s.v for s in singles]),
    )
    rows, cols, _ = _sparse_coo(m, n, nnz, rng, rows_used=2)
    bvals = rng.normal(size=(b_sz, nnz))
    out = api.apply(stacked, Sparse(rows, cols, bvals, rank=2))
    assert out.is_batched and out.batch == b_sz
    for i in range(b_sz):
        ref = api.apply(singles[i], Sparse(rows, cols, bvals[i], rank=2))
        np.testing.assert_allclose(np.asarray(out.materialize()[i]),
                                   np.asarray(ref.materialize()), atol=1e-8)


def test_sparse_nnz_padding_is_exact_noop():
    """Zero-valued entries at (0, 0) — the static-nnz bucket convention —
    leave the applied state numerically unchanged."""
    rng = np.random.default_rng(27)
    m, n, nnz = 6, 9, 7
    st = _full_state(m, n, rng)
    rows, cols, vals = _sparse_coo(m, n, nnz, rng, rows_used=2)
    base = api.apply(st, Sparse(rows, cols, vals, rank=2))
    pad = 5
    padded_op = Sparse(np.concatenate([rows, np.zeros(pad, np.int32)]),
                       np.concatenate([cols, np.zeros(pad, np.int32)]),
                       np.concatenate([vals, np.zeros(pad)]), rank=2)
    assert padded_op.nnz == nnz + pad and padded_op.spec() != Sparse(
        rows, cols, vals, rank=2).spec()       # distinct schedule-cache keys
    out = api.apply(st, padded_op)
    np.testing.assert_allclose(np.asarray(out.materialize()),
                               np.asarray(base.materialize()), atol=1e-10)


def test_sketch_policy_knobs_fold_into_caches():
    """sketch_oversample/power_iters key the schedule cache and engine_key —
    policy-distinct sketches can never share a stale plan."""
    rng = np.random.default_rng(28)
    st = _full_state(5, 8, rng)
    op = DenseDelta(_lowrank(5, 8, 1, rng), rank=1)
    p1 = UpdatePolicy(method="direct")
    p2 = UpdatePolicy(method="direct", sketch_oversample=4,
                      sketch_power_iters=2)
    assert p1.engine_key(5) != p2.engine_key(5)
    api.apply(st, op, p1)
    before = schedule_cache_info().entries
    api.apply(st, op, p2)
    assert schedule_cache_info().entries == before + 1


def test_no_dense_svd_call_on_lowering_paths():
    """ISSUE 7 acceptance: zero ``jnp.linalg.svd`` call sites on the
    DenseDelta/Sparse/serve lowering path (compression's agree_tracker and
    ``SvdState.from_dense`` are exempt by charter)."""
    for rel in ("src/repro/updates/ops.py",
                "src/repro/updates/planner.py",
                "src/repro/updates/sketch.py",
                "src/repro/serve/svd_service.py",
                "src/repro/kernels/sparse_proj.py"):
        src = (REPO / rel).read_text()
        assert "jnp.linalg.svd(" not in src, f"dense SVD call in {rel}"
