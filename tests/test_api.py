"""``repro.api`` surface stability: __all__ snapshot, SvdState/UpdatePolicy
semantics, and policy-keyed plan-cache folding (zero recompiles across
policy-equal calls)."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.api import SvdState, UpdatePolicy

RNG = np.random.default_rng(7)

# The public surface the next PRs build on — additions require updating this
# snapshot deliberately; removals/renames are API breaks.
API_SURFACE = [
    "METHODS",
    "SvdState",
    "UpdatePolicy",
    "apply",          # structured perturbations (repro.updates, DESIGN §10)
    "apply_many",
    "as_state",
    "compilation_cache_entries",  # persistent-warmup observability (DESIGN §13)
    "enable_compilation_cache",   # cross-process AOT warmup (DESIGN §13)
    "engine_for",
    "update",
    "update_many",
    "update_rank_k",  # scan-lowered rank-k schedules (DESIGN §11)
    "warmup",
]


def _full_state(m, n):
    a_mat = RNG.uniform(1, 9, (m, n))
    u, s, vt = np.linalg.svd(a_mat)
    return SvdState.from_factors(u, s, vt.T)


def _trunc_state(m, n, r):
    return SvdState.from_factors(
        np.linalg.qr(RNG.normal(size=(m, r)))[0],
        np.sort(np.abs(RNG.normal(size=r)))[::-1].copy(),
        np.linalg.qr(RNG.normal(size=(n, r)))[0],
    )


# ---------------------------------------------------------------------------
# surface snapshot
# ---------------------------------------------------------------------------


def test_api_all_snapshot():
    assert sorted(api.__all__) == API_SURFACE
    for name in api.__all__:
        assert getattr(api, name) is not None


# ---------------------------------------------------------------------------
# SvdState
# ---------------------------------------------------------------------------


def test_state_full_vs_truncated_geometry():
    full = _full_state(8, 10)
    assert full.is_full and not full.is_batched
    assert (full.m, full.n, full.rank) == (8, 10, 8)
    tr = _trunc_state(8, 10, 3)
    assert not tr.is_full
    assert tr.geometry != full.geometry

    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), tr, _trunc_state(8, 10, 3))
    assert stacked.is_batched and stacked.batch == 2


def test_state_from_dense_and_materialize():
    a_mat = RNG.uniform(1, 9, (6, 9))
    full = SvdState.from_dense(a_mat)
    np.testing.assert_allclose(np.asarray(full.materialize()), a_mat, atol=1e-9)
    tr = SvdState.from_dense(a_mat, rank=2)
    assert tr.rank == 2 and not tr.is_full
    # best rank-2 approximation
    u, s, vt = np.linalg.svd(a_mat)
    opt = (u[:, :2] * s[:2]) @ vt[:2]
    np.testing.assert_allclose(np.asarray(tr.materialize()), opt, atol=1e-9)
    with pytest.raises(ValueError, match="m <= n"):
        SvdState.from_dense(a_mat.T)
    with pytest.raises(ValueError, match="rank"):
        SvdState.from_dense(a_mat, rank=7)


def test_state_truncate_and_immutability():
    full = _full_state(8, 10)
    tr = full.truncate(3)
    assert tr.rank == 3 and tr.u.shape == (8, 3) and tr.v.shape == (10, 3)
    with pytest.raises(ValueError, match="truncate"):
        tr.truncate(5)
    with pytest.raises(dataclasses.FrozenInstanceError):
        full.s = jnp.zeros(8)


def test_as_state_coercions():
    from repro.core.svd_update import TruncatedSvd

    tr = _trunc_state(8, 10, 3)
    legacy = TruncatedSvd(tr.u, tr.s, tr.v)
    st = api.as_state(legacy)
    assert isinstance(st, SvdState)
    assert st.u is legacy.u
    assert api.as_state(st) is st
    st2 = api.as_state((tr.u, tr.s, tr.v))
    assert st2.rank == 3


def test_state_is_pytree_with_three_leaves():
    """Diagnostics-free SvdState must keep TruncatedSvd's leaf count, so
    existing stacked/sharded tree code keeps working."""
    tr = _trunc_state(8, 10, 3)
    assert len(jax.tree.leaves(tr)) == 3
    mapped = jax.tree.map(lambda x: x * 2, tr)
    assert isinstance(mapped, SvdState)


# ---------------------------------------------------------------------------
# UpdatePolicy
# ---------------------------------------------------------------------------


def test_policy_frozen_hashable_equal():
    p1 = UpdatePolicy(method="fmm", fmm_p=24)
    p2 = UpdatePolicy(method="fmm", fmm_p=24)
    assert p1 == p2 and hash(p1) == hash(p2)
    assert len({p1: 1, p2: 2}) == 1
    assert p1 != UpdatePolicy(method="fmm", fmm_p=25)
    with pytest.raises(dataclasses.FrozenInstanceError):
        p1.method = "direct"
    assert p1.replace(method="direct").method == "direct"


def test_policy_validation_and_resolution():
    with pytest.raises(ValueError, match="unknown method"):
        UpdatePolicy(method="magic")
    with pytest.raises(ValueError, match="truncate_to"):
        UpdatePolicy(truncate_to=0)
    assert UpdatePolicy(method="pallas").resolve_method(64) == "kernel"
    assert UpdatePolicy(method="auto").resolve_method(8) == "direct"
    assert UpdatePolicy(method="auto").resolve_method(128) == "fmm"
    with pytest.raises(NotImplementedError, match="benchmark"):
        UpdatePolicy(method="fast").resolve_method(8)


def test_policy_truncation_rule():
    full = _full_state(8, 10)
    a = jnp.asarray(RNG.normal(size=8))
    b = jnp.asarray(RNG.normal(size=10))
    out = api.update(full, a, b, UpdatePolicy(method="direct", truncate_to=3))
    assert out.rank == 3 and not out.is_full
    ref = api.update(full, a, b, UpdatePolicy(method="direct"))
    np.testing.assert_allclose(np.asarray(out.s), np.asarray(ref.s[:3]), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# policy-keyed plan cache: equal policies -> one engine, zero recompiles
# ---------------------------------------------------------------------------


def test_policy_equal_calls_share_engine_and_plan_cache():
    # fmm_p=21 gives this test a private default-engine key: counts are ours
    p1 = UpdatePolicy(method="direct", fmm_p=21)
    p2 = UpdatePolicy(method="direct", fmm_p=21)
    st = _trunc_state(9, 11, 3)
    eng = api.engine_for(p1, st)
    assert api.engine_for(p2, st) is eng

    b, m, n, r = 4, 9, 11, 3
    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[_trunc_state(m, n, r) for _ in range(b)]
    )
    a1 = jnp.asarray(RNG.normal(size=(b, m)))
    b1 = jnp.asarray(RNG.normal(size=(b, n)))
    api.update(stacked, a1, b1, p1)
    base = eng.cache_info()

    # only batch CONTENTS change -> zero recompiles (no new cache entries,
    # pure hits), even across distinct-but-equal policy objects
    for pol in (p1, p2, UpdatePolicy(method="direct", fmm_p=21)):
        a2 = jnp.asarray(RNG.normal(size=(b, m)))
        b2 = jnp.asarray(RNG.normal(size=(b, n)))
        api.update(stacked, a2, b2, pol)
    info = eng.cache_info()
    assert info.misses == base.misses, "policy-equal call recompiled"
    assert info.entries == base.entries
    assert info.hits == base.hits + 3


def test_policy_difference_is_a_different_engine():
    st = _trunc_state(9, 11, 3)
    e1 = api.engine_for(UpdatePolicy(method="direct", fmm_p=21), st)
    e2 = api.engine_for(UpdatePolicy(method="direct", fmm_p=22), st)
    e3 = api.engine_for(UpdatePolicy(method="direct", fmm_p=21, deflate_rtol=1e-10), st)
    assert e1 is not e2 and e1 is not e3


# ---------------------------------------------------------------------------
# update_many grouping
# ---------------------------------------------------------------------------


def test_update_many_groups_mixed_geometries():
    pol = UpdatePolicy(method="direct")
    states = [
        _trunc_state(8, 10, 3),
        _full_state(6, 7),
        _trunc_state(8, 10, 3),
        _trunc_state(12, 10, 3),
    ]
    A = [jnp.asarray(RNG.normal(size=s.m)) for s in states]
    B = [jnp.asarray(RNG.normal(size=s.n)) for s in states]
    outs = api.update_many(states, A, B, pol)
    assert len(outs) == 4
    for st, a, b, out in zip(states, A, B, outs):
        ref = api.update(st, a, b, pol)
        np.testing.assert_allclose(np.asarray(out.s), np.asarray(ref.s),
                                   rtol=0, atol=1e-12)
        assert out.is_full == st.is_full

    with pytest.raises(ValueError, match="pair up"):
        api.update_many(states, A[:2], B, pol)


def test_update_many_rejects_batched_states():
    tr = _trunc_state(8, 10, 3)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), tr, tr)
    with pytest.raises(ValueError, match="unbatched"):
        api.update_many([stacked], [jnp.zeros((2, 8))], [jnp.zeros((2, 10))])


def test_warmup_precompiles_policy_geometry():
    pol = UpdatePolicy(method="direct", fmm_p=23)  # private engine key
    info = api.warmup(pol, m=8, n=10, batch=4, rank=3, dtype=jnp.float64)
    assert info.entries == 1
    st = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[_trunc_state(8, 10, 3) for _ in range(4)]
    )
    eng = api.engine_for(pol, st)
    api.update(st, jnp.zeros((4, 8)), jnp.zeros((4, 10)), pol)
    assert eng.cache_info().hits >= 1
