"""Quickstart: the paper's rank-1 SVD update in five lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import svd_update

rng = np.random.default_rng(0)
m, n = 200, 300

# A known SVD ...
a_mat = rng.uniform(1, 9, size=(m, n))           # paper's experimental setup
u, s, vt = np.linalg.svd(a_mat)

# ... perturbed by a rank-1 update (a streaming observation, a gradient, ...)
a = rng.normal(size=m)
b = rng.normal(size=n)

# Algorithm 6.1: secular roots + Loewner weights + FMM Cauchy products —
# O(n^2 log 1/eps) instead of O(n^3) for a fresh SVD.
res = svd_update(
    jnp.asarray(u), jnp.asarray(s), jnp.asarray(vt.T),
    jnp.asarray(a), jnp.asarray(b),
    method="fmm",
)

a_hat = a_mat + np.outer(a, b)
recon = np.asarray(res.u) @ np.diag(np.asarray(res.s)) @ np.asarray(res.v)[:, :m].T
smax = np.linalg.svd(a_hat, compute_uv=False)[0]
err = np.max(np.abs(a_hat - recon)) / smax

print(f"updated sigma_max   : {float(res.s[0]):.6f}")
print(f"fresh-SVD sigma_max : {smax:.6f}")
print(f"Eq.32 error         : {err:.3e}   (paper Table 2 reports ~5e-2 at n=50)")
print(f"orthogonality |U^TU - I|: {np.max(np.abs(np.asarray(res.u).T @ np.asarray(res.u) - np.eye(m))):.3e}")
assert err < 1e-9
print("OK")
