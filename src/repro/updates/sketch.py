"""Randomized range-finder sketching — THE low-rank extraction primitive
(DESIGN.md §12; Halko-Martinsson-Tropp, grounded for SVD updating by
Peña & Sauer, arXiv:1809.03285).

Every place the update stack turns a dense (or sparse) perturbation into
rank-1 components used to call a full ``jnp.linalg.svd`` — O(min(m,n)·m·n)
and a LAPACK/cuSOLVER sync point, duplicated between the planner and serve.
This module replaces both call sites with one O(m·n·k) primitive:

    Y = Δ @ Ω            Ω: (n, l) fixed Gaussian test matrix, l = k + p
    Q = qr(Y)            (power iterations re-orthonormalize Δᵀ-passes)
    B = Qᵀ @ Δ           the (l, n) sketch;  Δ ≈ Q @ B exactly when
                         l >= rank(Δ)  (Q spans range(Δ))

followed by a small factorization of ``B`` that needs NO dense SVD at all:
``Bᵀ = Q₂R₂`` (tall QR), then the (2l, 2l) Jordan-Wielandt eigendecomposition
of ``R₂ᵀ`` — ``eigh([[0, C], [Cᵀ, 0]])`` has eigenpairs ``±σᵢ`` with
vectors ``[uᵢ; ±vᵢ]/√2`` — so singular values come out UNsquared (no Gram
condition-number loss).

Accuracy knobs (policy-visible as ``UpdatePolicy.sketch_oversample`` /
``sketch_power_iters``, folded into the planner's schedule cache key):

* ``oversample`` — extra sample columns p beyond the target rank k.  The
  sketch is *exact* (machine precision) whenever ``k + p >= rank(Δ)``; the
  structured ops feed exactly-rank-k deltas, so the default p=8 is pure
  safety margin.
* ``power_iters`` — subspace (power) iterations ``Q <- qr(Δ qr(Δᵀ Q))``;
  sharpens the captured spectrum for DENSE deltas with slow singular decay
  (truncating sketches, ``optim.compression`` absorbs).  A dense pass is a
  GEMM — extra passes are nearly free accuracy.

The sparse variant deliberately does NOT power-iterate.  A sparse pass is a
serialized O(nnz) gather/scatter — passes dominate the whole lowering, the
exact opposite cost profile of the dense GEMM pass — so ``Sparse`` deltas
run the Tropp-style TWO-SIDED SINGLE-PASS sketch instead (Tropp, Yurtsever,
Udell & Cevher, arXiv:1609.00048): sketch both sides independently
(``Y = SΩ``, ``W = SᵀΨ`` — the two S-applications that are the
information-theoretic minimum to build both factor sides), then solve the
small core from the sketches alone, ``C = (ΨᵀQ)⁺ (ΨᵀY) (PᵀΩ)⁺``.  Same
exactness regime (machine precision whenever ``l >= rank(S)``); its
accuracy knob is ``oversample`` alone.

Everything is jit/vmap-clean: test matrices are fixed-seed numpy-Philox
constants baked in at trace time (deterministic and platform-stable —
bitwise snapshot/restore stays exact, zero runtime RNG cost), leading batch
axes run batched, and the sparse variant reaches the matrix only through
``kernels.sparse_proj.sparse_project`` — O((m+n)·l² + nnz·l), never a
densified m·n.

>>> import numpy as np
>>> rng = np.random.default_rng(0)
>>> delta = rng.normal(size=(9, 3)) @ rng.normal(size=(3, 7))   # rank 3
>>> u, s, v = sketch_svd(delta, k=3)
>>> u.shape, s.shape, v.shape
((9, 3), (3,), (7, 3))
>>> bool(np.allclose((u * s) @ np.swapaxes(v, -1, -2), delta, atol=1e-9))
True
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.sparse_proj import sparse_project

__all__ = [
    "factored_svd",
    "range_finder",
    "sample_count",
    "sketch_svd",
    "sparse_sketch_svd",
    "warmup_sketch",
]

# Fixed seeds: test matrices are deterministic constants, so sketched
# lowerings are reproducible run-to-run and bitwise across snapshot/restore.
# _SEED draws the range sketch Ω; _SEED_CORANGE the co-range sketch Ψ of the
# sparse single-pass path (independent by construction).
_SEED = 0
_SEED_CORANGE = 1


def sample_count(k: int, oversample: int, m: int, n: int) -> int:
    """Sample columns l = min(k + oversample, m, n) the range-finder draws.

    >>> sample_count(8, 8, 1024, 1024), sample_count(8, 8, 4, 6)
    (16, 4)
    """
    return max(1, min(k + oversample, m, n))


@functools.lru_cache(maxsize=None)
def _test_matrix_np(n: int, l: int, seed: int):
    # numpy Philox at TRACE time: the matrix enters the jaxpr as a constant
    # (zero runtime RNG cost) and is bitwise identical on every platform
    return np.random.Generator(np.random.Philox(seed)).standard_normal((n, l))


def _test_matrix(n: int, l: int, dtype, seed: int = _SEED) -> jax.Array:
    return jnp.asarray(_test_matrix_np(n, l, seed), dtype=dtype)


def _small_svd(c):
    """SVD of a small square core ``c`` (..., l, l) WITHOUT jnp.linalg.svd:
    the Jordan-Wielandt embedding [[0, C], [Cᵀ, 0]] is symmetric with
    eigenpairs (±σᵢ, [uᵢ; ±vᵢ]/√2) — one (2l, 2l) eigh, values unsquared."""
    l = c.shape[-1]
    zero = jnp.zeros_like(c)
    mtx = jnp.concatenate(
        [
            jnp.concatenate([zero, c], axis=-1),
            jnp.concatenate([jnp.swapaxes(c, -1, -2), zero], axis=-1),
        ],
        axis=-2,
    )
    w, vecs = jnp.linalg.eigh(mtx)                  # ascending: -σ₁ ... +σ₁
    s = jnp.maximum(w[..., ::-1][..., :l], 0.0)     # top l = +σ, descending
    vecs = vecs[..., :, ::-1][..., :, :l]

    def _unit(x):
        # each half has norm 1/√2 for σ > 0; σ = 0 halves are arbitrary but
        # their components vanish (a = u·σ = 0), so the guard is harmless
        nrm = jnp.linalg.norm(x, axis=-2, keepdims=True)
        return x / jnp.where(nrm > 0, nrm, 1.0)

    return _unit(vecs[..., :l, :]), s, _unit(vecs[..., l:, :])


def _qb_svd(q, b):
    """(u, s, v) of ``Q @ B`` from the range-finder pair: tall QR of Bᵀ,
    then the (2l, 2l) Jordan-Wielandt core — no LAPACK SVD anywhere."""
    q2, r2 = jnp.linalg.qr(jnp.swapaxes(b, -1, -2))            # Bᵀ = Q₂R₂
    uc, s, vc = _small_svd(jnp.swapaxes(r2, -1, -2))           # R₂ᵀ (l, l)
    u = jnp.einsum("...ml,...lp->...mp", q, uc)
    v = jnp.einsum("...nl,...lp->...np", q2, vc)
    return u, s, v


def _topk(u, s, v, k: int):
    """Top-k triplets; zero-padded up to k when fewer samples exist (a zero
    component binds to a zero rank-1 pair — an exact no-op update)."""
    l = s.shape[-1]
    if l >= k:
        return u[..., :, :k], s[..., :k], v[..., :, :k]
    pad = [(0, 0)] * (s.ndim - 1)
    u = jnp.pad(u, pad + [(0, 0), (0, k - l)])
    v = jnp.pad(v, pad + [(0, 0), (0, k - l)])
    return u, jnp.pad(s, pad + [(0, k - l)]), v


@functools.partial(jax.jit, static_argnames=("k",))
def factored_svd(q, b, k: int):
    """Top-k triplets of the already-factored product ``q @ b`` — for
    callers that hold a low-rank factorization (``optim.compression``'s
    ``p_hat @ qᵀ`` absorb) and want its exact dominant components without
    ever forming the dense product or calling a LAPACK SVD.  ``q``:
    (..., m, l) with orthonormal columns, ``b``: (..., l, n).

    >>> import numpy as np
    >>> rng = np.random.default_rng(3)
    >>> qm, _ = np.linalg.qr(rng.normal(size=(7, 2)))
    >>> b = rng.normal(size=(2, 5))
    >>> u, s, v = factored_svd(qm, b, k=2)
    >>> bool(np.allclose((u * s) @ np.swapaxes(v, -1, -2), qm @ b, atol=1e-12))
    True
    """
    return _topk(*_qb_svd(jnp.asarray(q), jnp.asarray(b)), k)


@functools.partial(jax.jit, static_argnames=("k", "oversample", "power_iters"))
def range_finder(delta, k: int, *, oversample: int = 8, power_iters: int = 1):
    """The QB decomposition ``delta ≈ q @ b`` (Halko stage A + sketch).

    ``delta``: (..., m, n); returns ``q`` (..., m, l), ``b`` (..., l, n)
    with ``l = sample_count(k, oversample, m, n)``.  Exact (``q @ b ==
    delta`` to machine precision) whenever ``l >= rank(delta)``.

    >>> import numpy as np
    >>> rng = np.random.default_rng(1)
    >>> delta = np.outer(rng.normal(size=5), rng.normal(size=6))  # rank 1
    >>> q, b = range_finder(delta, k=1, oversample=2)
    >>> q.shape, b.shape
    ((5, 3), (3, 6))
    >>> bool(np.allclose(q @ b, delta, atol=1e-12))
    True
    """
    delta = jnp.asarray(delta)
    m, n = delta.shape[-2:]
    l = sample_count(k, oversample, m, n)
    omega = _test_matrix(n, l, delta.dtype)
    q, _ = jnp.linalg.qr(jnp.einsum("...mn,nl->...ml", delta, omega))
    for _ in range(power_iters):
        z, _ = jnp.linalg.qr(jnp.einsum("...mn,...ml->...nl", delta, q))
        q, _ = jnp.linalg.qr(jnp.einsum("...mn,...nl->...ml", delta, z))
    b = jnp.einsum("...ml,...mn->...ln", q, delta)
    return q, b


@functools.partial(jax.jit, static_argnames=("k", "oversample", "power_iters"))
def sketch_svd(delta, k: int, *, oversample: int = 8, power_iters: int = 1):
    """Top-k SVD triplets ``(u, s, v)`` of ``delta`` via the range-finder —
    the replacement for every dense ``jnp.linalg.svd`` sketch call site
    (``updates.planner`` + ``serve.svd_service``).  O(m·n·l) instead of
    O(min(m,n)·m·n); leading batch axes run batched.

    >>> import numpy as np
    >>> rng = np.random.default_rng(2)
    >>> deltas = np.einsum("bm,bn->bmn", rng.normal(size=(4, 5)),
    ...                    rng.normal(size=(4, 6)))               # 4 x rank-1
    >>> u, s, v = sketch_svd(deltas, k=1)
    >>> u.shape, s.shape, v.shape
    ((4, 5, 1), (4, 1), (4, 6, 1))
    >>> recon = np.einsum("bmk,bk,bnk->bmn", u, s, v)
    >>> bool(np.allclose(recon, deltas, atol=1e-10))
    True
    """
    q, b = range_finder(delta, k, oversample=oversample,
                        power_iters=power_iters)
    return _topk(*_qb_svd(q, b), k)


@functools.partial(jax.jit, static_argnames=("m", "n", "k", "oversample"))
def sparse_sketch_svd(rows, cols, vals, *, m: int, n: int, k: int,
                      oversample: int = 8):
    """Top-k triplets of the static-nnz COO delta ``S[rows[e], cols[e]] +=
    vals[e]`` on geometry (m, n) — the ``Sparse`` op's lowering core.

    Two-sided single-pass sketch (see module doc): every pass over a sparse
    matrix is a serialized O(nnz) scatter, so this path makes exactly the
    TWO S-applications needed to build the two factor sides —

        Y = S Ω,  W = Sᵀ Ψ          (independent fixed test matrices)
        Q = qr(Y),  P = qr(W)
        C = (ΨᵀQ)⁻¹ (ΨᵀY) (PᵀΩ)⁻¹  (small l x l solves; ΨᵀY is a GEMM)
        S ≈ Q C Pᵀ                   (exact whenever l >= rank(S))

    — then factors ``C`` through the same LAPACK-SVD-free Jordan-Wielandt
    core as the dense path.  The matrix is touched ONLY through
    ``kernels.sparse_proj.sparse_project``: cost O((m + n)·l² + nnz·l),
    never a densified m·n.  Zero-valued padding entries at coordinate
    (0, 0) are exact no-ops.  There is deliberately no ``power_iters``
    (dense-path knob); ``oversample`` is the accuracy lever here.

    >>> import numpy as np
    >>> rows, cols = np.array([0, 2, 1]), np.array([1, 0, 1])
    >>> vals = np.array([3.0, -2.0, 4.0])
    >>> u, s, v = sparse_sketch_svd(rows, cols, vals, m=3, n=2, k=2)
    >>> dense = np.zeros((3, 2)); dense[rows, cols] = vals
    >>> bool(np.allclose((u * s) @ np.swapaxes(v, -1, -2), dense, atol=1e-12))
    True
    """
    vals = jnp.asarray(vals)
    rows = jnp.asarray(rows, jnp.int32)
    cols = jnp.asarray(cols, jnp.int32)
    l = sample_count(k, oversample, m, n)
    omega = _test_matrix(n, l, vals.dtype)                     # Ω: (n, l)
    psi = _test_matrix(m, l, vals.dtype, seed=_SEED_CORANGE)   # Ψ: (m, l)
    if vals.ndim > 1:
        omega = jnp.broadcast_to(omega, vals.shape[:-1] + omega.shape)
        psi = jnp.broadcast_to(psi, vals.shape[:-1] + psi.shape)
    y = sparse_project(rows, cols, vals, omega, m)             # S Ω: (.., m, l)
    w = sparse_project(cols, rows, vals, psi, n)               # SᵀΨ: (.., n, l)
    q, _ = jnp.linalg.qr(y)
    p, _ = jnp.linalg.qr(w)
    mid = jnp.einsum("...ml,...mp->...lp", psi, y)             # ΨᵀY  (l, l)
    a = jnp.einsum("...ml,...mp->...lp", psi, q)               # ΨᵀQ  (l, l)
    b = jnp.einsum("...nl,...np->...lp", p, omega)             # PᵀΩ  (l, l)
    # A and B are (rotated) l x l Gaussians — generically invertible and
    # well-conditioned; in the exact regime the solves recover C = QᵀSP
    c = jnp.linalg.solve(a, mid)                               # A⁻¹ (ΨᵀY)
    c = jnp.swapaxes(jnp.linalg.solve(
        jnp.swapaxes(b, -1, -2), jnp.swapaxes(c, -1, -2)), -1, -2)
    uc, s, vc = _qb_svd(q, c)                                  # Q C = u s vcᵀ
    v = jnp.einsum("...nl,...lp->...np", p, vc)                # back to n-space
    return _topk(uc, s, v, k)


def warmup_sketch(*, m: int, n: int, k: int, oversample: int = 8,
                  power_iters: int = 1, nnz: int | None = None,
                  batch: int | None = None, dtype=jnp.float64):
    """Warm the jitted sketch executable for one geometry before traffic
    (``planner.warmup_plan`` / serve-restore call this so no sketch compiles
    on the hot path).  ``nnz=None`` warms the dense variant, else the sparse
    one; ``batch`` warms the stacked form.  Runs on zeros and blocks."""
    lead = () if batch is None else (batch,)
    if nnz is None:
        out = sketch_svd(jnp.zeros(lead + (m, n), dtype), k,
                         oversample=oversample, power_iters=power_iters)
    else:
        # the sparse single-pass path has no power_iters knob (module doc)
        idx = jnp.zeros(lead + (nnz,), jnp.int32)
        out = sparse_sketch_svd(idx, idx, jnp.zeros(lead + (nnz,), dtype),
                                m=m, n=n, k=k, oversample=oversample)
    return jax.block_until_ready(out)
