"""deepseek-v2-lite-16b [moe+mla] — 27L d_model=2048 16H d_ff=1408
vocab=102400, MLA kv_lora=512, MoE 2 shared + 64 routed top-6.
[arXiv:2405.04434; hf]

The assignment line says "64e top-6"; the arXiv model card lists 160 routed
experts. We implement the inline numbers (64) — the field is a knob.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=102400,
        mlp_type="swiglu", norm_type="rmsnorm",
        moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_ff_expert=1408),
        mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                      qk_rope_head_dim=64, v_head_dim=128),
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="deepseek-v2-lite-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, vocab_size=512, vocab_pad_to=64,
        moe=MoEConfig(n_routed=8, n_shared=1, top_k=2, d_ff_expert=96, capacity_factor=2.0),
        mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
        compute_dtype="float32", remat=False,
    )
