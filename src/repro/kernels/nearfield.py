"""Pallas TPU kernel: FMM near-field block products with on-the-fly kernels.

The FMM near field is a block-tridiagonal Cauchy product: each leaf box's
targets interact directly with sources of boxes (b-1, b, b+1). The jnp path
precomputes ``near_inv`` (nb, 3*cap, capt) in HBM; this kernel instead
generates each (3*cap, capt) inverse-distance block in VMEM from the gathered
coordinates and contracts on the MXU, removing the near_inv HBM residency
(the dominant memory term of an FMM apply at large N — see EXPERIMENTS.md).

Grid: (nb, R/BR). Stable denominators via anchored targets, matching
core.fmm.build_plan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["nearfield_pallas"]


def _kernel(w_ref, x_ref, av_ref, tau_ref, tmask_ref, out_ref):
    w = w_ref[...][:, 0, :]       # (BR, 3cap) — weights already source-masked
    x = x_ref[...][0]             # (3cap,)
    av = av_ref[...][0]           # (capt,)
    tau = tau_ref[...][0]         # (capt,)
    tm = tmask_ref[...][0]        # (capt,)

    denom = (av[None, :] - x[:, None]) + tau[None, :]   # (3cap, capt) = y - x
    safe = jnp.where(denom == 0.0, 1.0, denom)
    c = jnp.where(denom != 0.0, 1.0 / safe, 0.0) * tm[None, :]
    out_ref[...] = jnp.dot(w, c, preferred_element_type=out_ref.dtype)[:, None, :]


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def nearfield_pallas(
    w_near: jax.Array,    # (R, nb, 3cap) gathered weights, invalid slots zeroed
    x_near: jax.Array,    # (nb, 3cap) gathered source coords
    av_b: jax.Array,      # (nb, capt) target anchor values per box
    tau_b: jax.Array,     # (nb, capt) target taus per box
    tgt_mask: jax.Array,  # (nb, capt) bool
    *,
    block_r: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """out[r, b, t] = sum_c w_near[r, b, c] / (y_{b,t} - x_{b,c})."""
    r, nb, c3 = w_near.shape
    capt = av_b.shape[1]
    dt = w_near.dtype

    br = min(block_r, max(8, r))
    pad_r = (-r) % br
    w_p = jnp.pad(w_near, ((0, pad_r), (0, 0), (0, 0)))
    rp = w_p.shape[0]
    # pad x so masked slots cannot alias target values (w is zero there anyway)
    tm = tgt_mask.astype(dt)

    out = pl.pallas_call(
        _kernel,
        grid=(nb, rp // br),
        in_specs=[
            pl.BlockSpec((br, 1, c3), lambda b, i: (i, b, 0)),
            pl.BlockSpec((1, c3), lambda b, i: (b, 0)),
            pl.BlockSpec((1, capt), lambda b, i: (b, 0)),
            pl.BlockSpec((1, capt), lambda b, i: (b, 0)),
            pl.BlockSpec((1, capt), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((br, 1, capt), lambda b, i: (i, b, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, nb, capt), dt),
        interpret=interpret,
    )(w_p, x_near, av_b, tau_b, tm)
    return out[:r]
