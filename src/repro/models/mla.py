"""Multi-head Latent Attention (DeepSeek-V2) with compressed KV cache.

Faithful to the V2-Lite variant: no q-LoRA; KV compressed to
``kv_lora_rank`` + a shared RoPE key of ``qk_rope_head_dim``. The decode path
uses the absorbed-matrix trick (scores against the compressed c_kv directly),
so the cache per token is (kv_lora_rank + rope_dim) floats instead of
2 * n_heads * head_dim — the memory win that makes 32k/500k decode shapes
viable, visible in the dry-run bytes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dot, rmsnorm, rope_apply, uniform_init

__all__ = ["mla_init", "mla_train", "mla_prefill", "mla_decode", "init_mla_cache"]


def mla_init(key, cfg, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    m = cfg.mla
    dn, dr, dv, r = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim, m.kv_lora_rank
    ks = jax.random.split(key, 5)
    s = (1.0 / d) ** 0.5
    return {
        "wq": uniform_init(ks[0], (d, h * (dn + dr)), s, dtype),
        "w_dkv": uniform_init(ks[1], (d, r + dr), s, dtype),
        "kv_norm": jnp.ones((r,), dtype),
        "w_uk": uniform_init(ks[2], (r, h * dn), (1.0 / r) ** 0.5, dtype),
        "w_uv": uniform_init(ks[3], (r, h * dv), (1.0 / r) ** 0.5, dtype),
        "wo": uniform_init(ks[4], (h * dv, d), (1.0 / (h * dv)) ** 0.5, dtype),
    }


def _project(x, p, cfg, positions):
    """Returns per-head q_nope, q_rope and compressed c_kv, k_rope."""
    b, s, _ = x.shape
    h = cfg.n_heads
    m = cfg.mla
    dn, dr, r = m.qk_nope_head_dim, m.qk_rope_head_dim, m.kv_lora_rank
    cd = jnp.dtype(cfg.compute_dtype)

    q = dot(x, p["wq"], cd).reshape(b, s, h, dn + dr).astype(x.dtype)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope_apply(q_rope, positions, cfg.rope_theta)

    ckv_full = dot(x, p["w_dkv"], cd).astype(x.dtype)
    c_kv = rmsnorm(ckv_full[..., :r], p["kv_norm"])
    k_rope = ckv_full[..., r:][:, :, None, :]  # single shared rope head
    k_rope = rope_apply(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def _absorbed_attention(q_nope, q_rope, c_kv, k_rope, p, cfg, causal, q_offset=0):
    """Scores computed in compressed space: q_nope absorbed through w_uk."""
    b, sq, h, dn = q_nope.shape
    m = cfg.mla
    r, dv = m.kv_lora_rank, m.v_head_dim
    cd = jnp.dtype(cfg.compute_dtype)

    w_uk = p["w_uk"].reshape(r, h, dn)
    q_abs = jnp.einsum(
        "bqhd,rhd->bqhr", q_nope.astype(cd), w_uk.astype(cd),
        preferred_element_type=jnp.float32,
    ).astype(q_nope.dtype)

    scale = 1.0 / ((dn + m.qk_rope_head_dim) ** 0.5)
    scores = (
        jnp.einsum("bqhr,bsr->bhqs", q_abs.astype(cd), c_kv.astype(cd),
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(cd), k_rope.astype(cd),
                     preferred_element_type=jnp.float32)
    ) * scale
    if causal:
        sk = c_kv.shape[1]
        qpos = jnp.arange(sq) + q_offset
        mask = qpos[:, None] >= jnp.arange(sk)[None, :]
        scores = jnp.where(mask[None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)

    # values also stay compressed until after the weighted sum
    ctx = jnp.einsum("bhqs,bsr->bqhr", w.astype(cd), c_kv.astype(cd),
                     preferred_element_type=jnp.float32).astype(q_nope.dtype)
    w_uv = p["w_uv"].reshape(r, h, dv)
    o = jnp.einsum("bqhr,rhd->bqhd", ctx.astype(cd), w_uv.astype(cd),
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, sq, h * dv).astype(q_nope.dtype)
    return dot(o, p["wo"], cd).astype(q_nope.dtype)


def _attend(q_nope, q_rope, c_kv, k_rope, p, cfg, out_shape):
    """Absorbed attention, query-chunked when cfg.mla_q_chunk is set: the
    (h, sq, sk) score tensor shrinks to (h, qc, sk) per chunk — §Perf
    'mla-qchunk' iteration."""
    qc = cfg.mla_q_chunk
    sq = q_nope.shape[1]
    if qc and sq > qc and sq % qc == 0:
        nq = sq // qc

        def one(i):
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, i * qc, qc, axis=1)
            return _absorbed_attention(sl(q_nope), sl(q_rope), c_kv, k_rope,
                                       p, cfg, causal=True, q_offset=i * qc)

        if cfg.scan_layers:
            outs = jax.lax.map(one, jnp.arange(nq))
        else:
            outs = jnp.stack([one(jnp.asarray(i)) for i in range(nq)])
        return jnp.moveaxis(outs, 0, 1).reshape(out_shape)
    return _absorbed_attention(q_nope, q_rope, c_kv, k_rope, p, cfg, causal=True)


def mla_train(x, p, cfg, positions):
    q_nope, q_rope, c_kv, k_rope = _project(x, p, cfg, positions)
    return _attend(q_nope, q_rope, c_kv, k_rope, p, cfg, x.shape)


def init_mla_cache(batch, max_len, cfg, dtype):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
    }


def mla_prefill(x, p, cfg, positions):
    q_nope, q_rope, c_kv, k_rope = _project(x, p, cfg, positions)
    out = _attend(q_nope, q_rope, c_kv, k_rope, p, cfg, x.shape)
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode(x, p, cfg, cache, pos):
    b = x.shape[0]
    posv = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = _project(x, p, cfg, posv)
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), pos, axis=1
    )
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), pos, axis=1
    )
    sk = c_kv.shape[1]
    # mask beyond pos by zeroing scores via a big negative — reuse the
    # absorbed attention with explicit mask
    m = cfg.mla
    h = cfg.n_heads
    dn, dv, r = m.qk_nope_head_dim, m.v_head_dim, m.kv_lora_rank
    cd = jnp.dtype(cfg.compute_dtype)
    w_uk = p["w_uk"].reshape(r, h, dn)
    q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(cd), w_uk.astype(cd),
                       preferred_element_type=jnp.float32).astype(x.dtype)
    scale = 1.0 / ((dn + m.qk_rope_head_dim) ** 0.5)
    scores = (
        jnp.einsum("bqhr,bsr->bhqs", q_abs.astype(cd), c_kv.astype(cd),
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(cd), k_rope.astype(cd),
                     preferred_element_type=jnp.float32)
    ) * scale
    valid = (jnp.arange(sk) <= pos)[None, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    wgt = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bqhr", wgt.astype(cd), c_kv.astype(cd),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    w_uv = p["w_uv"].reshape(r, h, dv)
    o = jnp.einsum("bqhr,rhd->bqhd", ctx.astype(cd), w_uv.astype(cd),
                   preferred_element_type=jnp.float32).reshape(b, 1, h * dv).astype(x.dtype)
    out = dot(o, p["wo"], cd).astype(x.dtype)
    return out, {"c_kv": c_kv, "k_rope": k_rope}
