"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch, shape, mesh), in seconds (TPU v5e constants):

  compute    = HLO_FLOPs / (chips * 197e12)         [bf16 MXU peak]
  memory     = HLO_bytes / (chips * 819e9)          [HBM bandwidth]
  collective = collective_bytes / (chips * 50e9)    [per-link ICI]

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-partition SPMD
module). collective_bytes is parsed from the compiled HLO text: for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute we
take the per-device wire bytes under ring semantics:

  all-gather:      out_bytes * (g-1)/g
  reduce-scatter:  in_bytes  * (g-1)/g
  all-reduce:      2 * bytes * (g-1)/g
  all-to-all:      bytes * (g-1)/g
  collective-permute: bytes

with g = replica-group size parsed from the op attributes.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

__all__ = ["HW", "collective_bytes", "roofline_terms", "model_flops",
           "svd_update_flops", "sketch_flops", "sparse_lowering_flops"]

# TPU v5e, per chip
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclass
class HW:
    chips: int
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over all array shapes in an HLO type string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0]
        ids = [t for t in first.replace("{", "").split(",") if t.strip() != ""]
        if ids:
            return len(ids)
    return default


def collective_bytes(hlo_text: str, total_devices: int) -> dict:
    """Per-device wire bytes by collective kind (ring model)."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0, "count": 0}
    seen_start = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        # avoid double counting start/done pairs: count only -start or plain
        if "-done(" in line:
            continue
        opname = line.split("=")[0].strip()
        if opname in seen_start:
            continue
        seen_start.add(opname)
        b = _shape_bytes(type_str)
        if b == 0:
            continue
        g = _group_size(line, total_devices)
        if g <= 1:
            continue
        frac = (g - 1) / g
        if kind == "all-gather":
            out[kind] += b * frac
        elif kind == "reduce-scatter":
            # HLO result type is the scattered (per-shard) output; wire bytes
            # per device under ring = input*(g-1)/g = out_bytes*(g-1)
            out[kind] += b * (g - 1)
        elif kind == "all-reduce":
            out[kind] += 2.0 * b * frac
        elif kind == "all-to-all":
            out[kind] += b * frac
        elif kind == "collective-permute":
            out[kind] += b
        out["count"] += 1
    # clean up reduce-scatter estimate: output bytes ~ input/g; wire = in*(g-1)/g
    return out


def roofline_terms(cost: dict, coll: dict, hw: HW) -> dict:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cbytes = sum(v for k, v in coll.items() if k != "count")
    return {
        "flops_per_device": flops,
        "bytes_per_device": byts,
        "collective_bytes_per_device": cbytes,
        "t_compute_s": flops / hw.peak_flops,
        "t_memory_s": byts / hw.hbm_bw,
        "t_collective_s": cbytes / hw.ici_bw,
    }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE), D = tokens processed.

    For decode shapes D = global_batch (one token each); train counts fwd+bwd
    (factor 6); prefill/decode count forward only (factor 2).
    """
    n_params_active = _active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        factor = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        factor = 2.0
    else:
        tokens = shape.global_batch * 1
        factor = 2.0
    return factor * n_params_active * tokens


def svd_update_flops(m: int, n: int, r: int, batch: int = 1) -> float:
    """Analytic MODEL_FLOPS of one batched truncated rank-1 SVD update.

    The serving hot path (``engine.update_truncated_batch``): Brand
    projections/deflections ``~4r(m+n)``, the (r+1)-sized Algorithm-6.1 core
    (four chained eigen-updates plus the sign-fix G materialization,
    ``~24(r+1)^3`` under the direct method), and the two basis rotations
    ``~2r(r+1)(m+n)``.  Feeds the useful-FLOPs ratio of the SVD roofline
    cells (``launch.perf_iter --svd``) exactly as ``model_flops`` does for
    the LM cells.
    """
    per = 4.0 * r * (m + n) + 2.0 * r * (r + 1) * (m + n) + 24.0 * (r + 1) ** 3
    return batch * per


def sketch_flops(m: int, n: int, k: int, *, oversample: int = 8,
                 power_iters: int = 1, batch: int = 1) -> float:
    """Analytic MODEL_FLOPS of one randomized range-finder sketch
    (``updates.sketch.sketch_svd``) at l = min(k + oversample, m, n) samples:
    the (1 + 2·power_iters + 1) dense l-wide passes over the delta, the tall
    QRs ``~2(m + n)l²`` per orthonormalization, and the (2l)³-scale
    Jordan-Wielandt core.  The dense-SVD sketch this replaces costs
    ``~4·min(m,n)·m·n`` — the gap is the ≥3x bench gate in
    ``benchmarks/bench_updates.py``."""
    l = max(1, min(k + oversample, m, n))
    passes = 2.0 * (2.0 + 2.0 * power_iters) * m * n * l
    qr = 2.0 * (1.0 + 2.0 * power_iters) * (m + n) * l * l
    core = 24.0 * (2 * l) ** 3
    return batch * (passes + qr + core)


def sparse_lowering_flops(m: int, n: int, k: int, nnz: int, *,
                          oversample: int = 8, batch: int = 1) -> float:
    """Analytic MODEL_FLOPS of lowering one ``Sparse`` COO delta to its k
    pairs (``updates.sketch.sparse_sketch_svd``, the two-sided SINGLE-pass
    sketch): exactly two ``kernels.sparse_proj`` applications (``Y = SΩ``,
    ``W = SᵀΨ``, ``2·nnz·l`` each — the sparse scatter is the serialized
    hot loop, which is why there is no power-iteration knob here), two tall
    QRs, the ``ΨᵀQ``/``ΨᵀY``/``PᵀΩ`` core GEMMs with their two l×l solves,
    and the Jordan-Wielandt core.  O((m + n)·l² + nnz·l), never the
    densified ``m·n`` the densify-then-``DenseDelta`` route pays."""
    l = max(1, min(k + oversample, m, n))
    passes = 2.0 * 2.0 * nnz * l
    qr = 2.0 * 2.0 * (m + n) * l * l
    core_gemms = 2.0 * (2.0 * m + n) * l * l
    solves = 2.0 * (2.0 / 3.0) * l ** 3
    core = 24.0 * (2 * l) ** 3
    return batch * (passes + qr + core_gemms + solves + core)


def _active_param_count(cfg) -> float:
    """Analytic per-token-active parameter count (excl. embeddings)."""
    d = cfg.d_model
    L = cfg.n_layers
    if cfg.rwkv is not None:
        per_layer = 5 * d * d + 2 * d * cfg.d_ff + 2 * d * cfg.rwkv.decay_lora
        return L * per_layer
    if cfg.ssm is not None and cfg.attn_every:
        d_inner = cfg.ssm.expand * d
        per_mamba = d * (2 * d_inner + 2 * cfg.ssm.d_state + d_inner // cfg.ssm.head_dim) + d_inner * d
        n_attn = L // cfg.attn_every
        attn = 2 * d * cfg.n_heads * cfg.head_dim + 2 * d * cfg.n_kv_heads * cfg.head_dim
        mlp = 3 * d * cfg.d_ff
        # shared weights are stored once but *applied* n_attn times — active
        # (compute) params count per application
        return L * per_mamba + n_attn * (attn + mlp)
    # attention params
    if cfg.mla is not None:
        m = cfg.mla
        attn = (d * cfg.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                + cfg.n_heads * m.v_head_dim * d)
    else:
        attn = (d * cfg.n_heads * cfg.head_dim * 2
                + d * cfg.n_kv_heads * cfg.head_dim * 2)
    # ffn params (active)
    if cfg.moe is not None:
        mo = cfg.moe
        ffn = 3 * d * mo.d_ff_expert * (mo.top_k + mo.n_shared)
    else:
        mult = 3 if cfg.mlp_type == "swiglu" else 2
        ffn = mult * d * cfg.d_ff
    total = cfg.n_layers * (attn + ffn)
    if cfg.encdec:
        total *= 2  # encoder + decoder stacks
    return float(total)
