"""Deterministic stream->shard placement for the fleet tier (DESIGN.md §13).

The contract a million-stream service needs from placement:

* **deterministic across processes** — a restored fleet (possibly on another
  machine) must route every stream to the shard that holds its state.
  Python's builtin ``hash`` is salted per process (PYTHONHASHSEED), so
  placement hashes with ``blake2b`` — same id, same shard, every process,
  forever (pinned by a fresh-process test in tests/test_fleet_placement.py).
* **balanced without coordination** — shards never exchange load info; the
  hash's uniformity is the balancer.  At 10k streams over 8 shards the
  max/mean shard load stays within a stated bound (test-pinned ~20%;
  the binomial std dev is ``sqrt(S/num_shards)``).
* **re-placeable** — the spec is pure data ``(num_shards, salt)``; elastic
  restore onto a different shard count is just ``spec.replaced(k)`` plus a
  regroup of the per-stream snapshot leaves (``fleet.FleetSnapshot``), not
  a state migration protocol.

Placement is consistent-hash-free on purpose: shards are not physical hosts
here but service partitions inside one process group, so a shard-count
change may remap any stream (the snapshot regroup moves state wholesale and
bitwise); what matters is determinism and balance, not minimal movement.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import Counter

import jax
import jax.numpy as jnp

from repro.dist.sharding import batch_pspecs

__all__ = [
    "PlacementSpec",
    "shard_of",
    "assign",
    "shard_loads",
    "plan_devices",
]


@dataclasses.dataclass(frozen=True)
class PlacementSpec:
    """The complete placement function, as data: ``shard_of`` is a pure
    function of (spec, stream_id).  Frozen + hashable; JSON round-trips
    through ``to_json``/``from_json`` so ``FleetSnapshot`` carries it in the
    aux spec and a fresh process rebuilds the exact routing table."""

    num_shards: int
    salt: str = "repro.fleet"

    def __post_init__(self):
        if self.num_shards < 1:
            raise ValueError(f"num_shards must be >= 1; got {self.num_shards}")

    def replaced(self, num_shards: int) -> "PlacementSpec":
        """The same placement family at a new shard count — the elastic
        restore primitive (same salt: ids that hash together stay stable
        relative to each other)."""
        return dataclasses.replace(self, num_shards=num_shards)

    def to_json(self) -> dict:
        return {"num_shards": self.num_shards, "salt": self.salt}

    @classmethod
    def from_json(cls, d: dict) -> "PlacementSpec":
        return cls(num_shards=int(d["num_shards"]), salt=d["salt"])


def shard_of(spec: PlacementSpec, stream_id: str) -> int:
    """The shard owning ``stream_id`` — deterministic across processes,
    machines and Python versions (keyed blake2b, not the salted builtin
    ``hash``)."""
    digest = hashlib.blake2b(
        stream_id.encode("utf-8"),
        digest_size=8,
        key=spec.salt.encode("utf-8")[:64],
    ).digest()
    return int.from_bytes(digest, "big") % spec.num_shards


def assign(spec: PlacementSpec, stream_ids) -> dict[str, int]:
    """Vectorized ``shard_of`` over many ids: ``{stream_id: shard}``."""
    return {sid: shard_of(spec, sid) for sid in stream_ids}


def shard_loads(spec: PlacementSpec, stream_ids) -> list[int]:
    """Streams per shard under ``spec`` — the balance observable
    (tests pin max/mean at 10k synthetic ids)."""
    counts = Counter(shard_of(spec, sid) for sid in stream_ids)
    return [counts.get(i, 0) for i in range(spec.num_shards)]


def plan_devices(num_shards: int, *, devices=None, mesh=None) -> tuple:
    """Per-shard device pinning plan: shard ``i`` dispatches its flush
    rounds under ``plan[i]`` (round-robin when shards outnumber devices).

    ``devices=None, mesh=None`` reads ``jax.devices()``.  With a ``mesh``
    the plan walks the devices of the mesh axes a flush's batch would be
    sharded over (``dist.batch_pspecs`` names them — the one definition of
    the batch axes), so shard placement and in-shard batch sharding agree
    about which devices carry flush work.
    """
    if devices is None:
        if mesh is not None:
            # the batch axes of a flush, per the dist contract (P(ax, None))
            axes = batch_pspecs(jnp.zeros((1, 1)))[0]
            axes = axes if isinstance(axes, tuple) else (axes,)
            names = [ax for ax in axes if ax in mesh.shape]
            devices = list(
                mesh.devices.transpose(
                    [list(mesh.axis_names).index(ax) for ax in names]
                    + [i for i, ax in enumerate(mesh.axis_names) if ax not in names]
                ).flat
            )
        else:
            devices = jax.devices()
    if not devices:
        raise ValueError("no devices to place shards on")
    return tuple(devices[i % len(devices)] for i in range(num_shards))
