"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064 — phi3-mini backbone + CLIP STUB (input_specs feeds patch
embeddings merged into the token stream).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

long_500k skipped (full attention)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", family="vlm",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32064,
        mlp_type="swiglu", norm_type="rmsnorm",
        frontend="vision", n_frontend_tokens=576,
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="phi-3-vision-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512, vocab_pad_to=64, n_frontend_tokens=8,
        compute_dtype="float32", remat=False,
    )
