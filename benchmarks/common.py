"""Shared benchmark helpers: timing + CSV emission."""

from __future__ import annotations

import time

import jax


def time_fn(fn, *args, warmup: int = 2, iters: int = 7) -> float:
    """Min wall time (us) of fn(*args) with block_until_ready.

    Min, not median: scheduler noise on a shared box is strictly additive,
    so the fastest repetition is the best estimate of the true cost.
    """
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6


def time_host_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)
