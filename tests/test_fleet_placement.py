"""Placement contract of the fleet tier (DESIGN.md §13): deterministic
across processes (keyed blake2b, not the salted builtin ``hash``),
balanced at population scale, and re-placeable as pure data.

The pinned digests below are the actual blake2b values — if they ever
change, every existing ``FleetSnapshot`` on disk would route streams to
shards that do not hold their state, so a failure here is a data-loss
bug, not a test to update.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.fleet import PlacementSpec, assign, plan_devices, shard_loads, shard_of

REPO = Path(__file__).resolve().parent.parent
SUB_ENV = {
    "PYTHONPATH": str(REPO / "src"),
    "PATH": "/usr/bin:/bin",
    "JAX_PLATFORMS": "cpu",
    "HOME": "/tmp",
}


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------


def test_shard_of_pinned_values():
    """Exact digests, pinned forever (see module doc)."""
    expected = {
        "user-0": (1, 1, 1),
        "user-1": (0, 0, 0),
        "user-2": (0, 2, 2),
        "stream/alpha": (0, 0, 4),
        "": (1, 1, 5),
    }
    for sid, shards in expected.items():
        got = tuple(shard_of(PlacementSpec(n), sid) for n in (2, 4, 8))
        assert got == shards, sid
    # the salt is part of the placement function, not decoration
    assert shard_of(PlacementSpec(8, salt="other-salt"), "user-0") == 3


def test_assign_matches_shard_of_in_fresh_process():
    """A different process (different PYTHONHASHSEED) routes every id to
    the same shard — the property a restored fleet's correctness rests on."""
    ids = [f"stream-{i}" for i in range(50)] + ["user-0", "a/b/c", ""]
    here = assign(PlacementSpec(8), ids)
    script = textwrap.dedent(
        """
        import json, sys
        from repro.fleet import PlacementSpec, assign
        ids = json.loads(sys.argv[1])
        print(json.dumps(assign(PlacementSpec(8), ids)))
        """
    )
    proc = subprocess.run(
        [sys.executable, "-c", script, json.dumps(ids)],
        capture_output=True, text=True, timeout=420, env=SUB_ENV,
    )
    assert proc.returncode == 0, proc.stderr
    there = json.loads(proc.stdout.strip().splitlines()[-1])
    assert there == here


def test_assign_consistent_with_shard_loads():
    ids = [f"s{i}" for i in range(200)]
    spec = PlacementSpec(4)
    a = assign(spec, ids)
    loads = shard_loads(spec, ids)
    assert len(loads) == 4 and sum(loads) == len(ids)
    for sh in range(4):
        assert loads[sh] == sum(1 for v in a.values() if v == sh)


# ---------------------------------------------------------------------------
# balance
# ---------------------------------------------------------------------------


def test_balance_10k_ids_over_8_shards():
    """Hash uniformity is the only balancer: at 10k ids the worst shard
    stays within 20% of the mean (binomial std dev ~sqrt(10000/8) ~ 35,
    so 20% = ~7 sigma — a failure means the hash broke, not bad luck)."""
    ids = [f"user-{i}" for i in range(10_000)]
    loads = shard_loads(PlacementSpec(8), ids)
    mean = sum(loads) / len(loads)
    assert min(loads) > 0
    assert max(loads) / mean <= 1.2


# ---------------------------------------------------------------------------
# the spec as data
# ---------------------------------------------------------------------------


def test_spec_replaced_and_json_roundtrip():
    spec = PlacementSpec(2, salt="custom")
    grown = spec.replaced(8)
    assert (grown.num_shards, grown.salt) == (8, "custom")
    assert spec.num_shards == 2        # frozen: replaced returns a new spec
    back = PlacementSpec.from_json(json.loads(json.dumps(grown.to_json())))
    assert back == grown
    for sid in ("user-0", "user-1", "x"):
        assert shard_of(back, sid) == shard_of(grown, sid)


def test_spec_rejects_nonpositive_shards():
    with pytest.raises(ValueError):
        PlacementSpec(0)
    with pytest.raises(ValueError):
        PlacementSpec(1).replaced(-2)


# ---------------------------------------------------------------------------
# device planning
# ---------------------------------------------------------------------------


def test_plan_devices_round_robin():
    devs = ["d0", "d1", "d2"]
    assert plan_devices(5, devices=devs) == ("d0", "d1", "d2", "d0", "d1")
    assert plan_devices(2, devices=devs) == ("d0", "d1")
    with pytest.raises(ValueError):
        plan_devices(2, devices=[])


def test_plan_devices_defaults_to_live_devices():
    import jax

    plan = plan_devices(4)
    assert len(plan) == 4
    assert set(plan) <= set(jax.devices())


# ---------------------------------------------------------------------------
# properties (skipped when hypothesis is absent — conftest shim)
# ---------------------------------------------------------------------------


@settings(max_examples=200, deadline=None)
@given(st.text(max_size=64), st.integers(min_value=1, max_value=64))
def test_shard_of_in_range_and_stable(sid, n):
    spec = PlacementSpec(n)
    sh = shard_of(spec, sid)
    assert 0 <= sh < n
    assert shard_of(spec, sid) == sh


@settings(max_examples=100, deadline=None)
@given(st.text(max_size=64))
def test_single_shard_absorbs_everything(sid):
    assert shard_of(PlacementSpec(1), sid) == 0
