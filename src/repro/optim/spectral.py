"""Spectral gradient projection — the paper's technique as an optimizer feature.

GaLore-style low-rank optimizer-state compression with one crucial change:
instead of re-running a full SVD every T steps (O(m n r)), each 2-D
parameter keeps a *streaming* truncated SVD of its gradient history — an
``repro.api.SvdState`` tracker — that is updated every step with the paper's
rank-1 machinery through the single api entry point (``api.update`` /
``api.update_many``; Brand augmentation + secular/Loewner/Cauchy).

Per step and per (m, n) parameter:
  1. one power-iteration step (warm-started) extracts the dominant rank-1
     component of the fresh gradient: g ≈ sigma * u v^T           O(m n)
  2. the tracker SVD is updated with that rank-1 term               O((m+n) r + r^2 p)
  3. the gradient is projected onto the rank-r left basis: G_p = U_r^T G
     and Adam moments live in the (r, n) projected space            O(m n r) -> O(m r n)

Memory: moments shrink from 2 m n to 2 r n floats (plus the (m+r+1) r
tracker) — the big win for billion-parameter training.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.api import SvdState, UpdatePolicy, as_state, update as api_update
from repro.api.policy import policy_from_legacy
from repro.core.engine import group_indices, stack_trees, unstack_tree

__all__ = [
    "SpectralState",
    "spectral_init",
    "spectral_update_basis",
    "spectral_update_basis_grouped",
    "project",
    "unproject",
]


class SpectralState(NamedTuple):
    tracker: SvdState         # streaming SVD of the gradient history
    power_v: jax.Array        # (n,) warm-started power-iteration vector
    step: jax.Array


def spectral_init(key, m: int, n: int, rank: int, dtype=jnp.float32) -> SpectralState:
    ku, kv, kp = jax.random.split(key, 3)
    u0, _ = jnp.linalg.qr(jax.random.normal(ku, (m, rank), dtype))
    v0, _ = jnp.linalg.qr(jax.random.normal(kv, (n, rank), dtype))
    return SpectralState(
        tracker=SvdState(u=u0, s=jnp.zeros((rank,), dtype), v=v0),
        power_v=jax.random.normal(kp, (n,), dtype) / (n ** 0.5),
        step=jnp.zeros((), jnp.int32),
    )


def _rank1_of_grad(state: SpectralState, grad: jax.Array, decay: float):
    """Power-iteration front half: decayed tracker + (a, b) rank-1 vectors.

    Pure and vmap-clean — the batched path maps this over stacked states and
    hands the stacked (a, b) pairs to one engine call.
    """
    g = grad.astype(state.tracker.u.dtype)

    # one warm-started power iteration: v <- G^T G v / |.|, u = G v / |G v|
    v = state.power_v
    gv = g @ v
    u = gv / (jnp.linalg.norm(gv) + 1e-30)
    gtu = g.T @ u
    sigma = jnp.linalg.norm(gtu)
    v_new = gtu / (sigma + 1e-30)

    # decay the tracker (recency weighting) before the rank-1 absorption
    tr = state.tracker.replace(s=state.tracker.s * decay)
    return tr, u * jnp.sqrt(sigma), v_new * jnp.sqrt(sigma), v_new


@partial(jax.jit, static_argnames=("method", "policy"))
def spectral_update_basis(state: SpectralState, grad: jax.Array, *, decay: float = 0.99,
                          method: str = "direct",
                          policy: UpdatePolicy | None = None) -> SpectralState:
    """Fold the fresh gradient's dominant rank-1 component into the tracker."""
    pol = policy_from_legacy(policy, method)
    tr, a_vec, b_vec, v_new = _rank1_of_grad(state, grad, decay)
    tr = api_update(tr, a_vec, b_vec, pol)
    return SpectralState(tracker=tr, power_v=v_new, step=state.step + 1)


def spectral_update_basis_grouped(
    states: Sequence[SpectralState],
    grads: Sequence[jax.Array],
    *,
    decay: float = 0.99,
    method: str = "direct",
    policy: UpdatePolicy | None = None,
    mesh=None,
    batch_axis: str = "data",
) -> tuple[SpectralState, ...]:
    """Batched basis update: group equal-geometry parameters, one batched
    ``api.update`` call per group.

    ``states[i]`` / ``grads[i]`` pair up; parameters sharing (m, n, rank,
    dtype) are stacked along a batch axis and their trackers updated by a
    single batched dispatch — B rank-1 updates for one plan instead of B
    Python-loop iterations.  ``policy.mesh`` (or the legacy ``mesh=``)
    spreads each group's batch over the mesh's batch axis via shard_map.
    """
    if len(states) != len(grads):
        raise ValueError("states and grads must pair up")
    pol = policy_from_legacy(policy, method, mesh=mesh, batch_axis=batch_axis)

    keys = []
    for i, (st, g) in enumerate(zip(states, grads)):
        tr = as_state(st.tracker)
        geo = (tr.m, tr.n, tr.rank, jnp.result_type(tr.u))
        if g.shape != (tr.m, tr.n):
            raise ValueError(
                f"grad {i} shape {g.shape} != tracker geometry {(tr.m, tr.n)}"
            )
        keys.append(geo)

    out: list[SpectralState | None] = [None] * len(states)
    for idxs in group_indices(keys).values():
        stacked = stack_trees([states[i] for i in idxs])
        g_stack = jnp.stack([grads[i] for i in idxs])
        tr, a_vec, b_vec, v_new = jax.vmap(partial(_rank1_of_grad, decay=decay))(
            stacked, g_stack
        )
        tr = api_update(tr, a_vec, b_vec, pol)
        batched = SpectralState(tracker=tr, power_v=v_new, step=stacked.step + 1)
        for j, i in enumerate(idxs):
            out[i] = unstack_tree(batched, j)
    return tuple(out)


def project(state: SpectralState, grad: jax.Array) -> jax.Array:
    """G_p = U_r^T G  — (r, n) projected gradient."""
    return state.tracker.u.T @ grad.astype(state.tracker.u.dtype)


def unproject(state: SpectralState, update_p: jax.Array) -> jax.Array:
    """Back to parameter space: U_r @ update_p."""
    return state.tracker.u @ update_p
