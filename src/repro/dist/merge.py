"""Hierarchical distributed truncated-SVD merge (Iwen & Ong, arXiv:1601.07010),
built from the paper's rank-1 update machinery.

Problem: ``W`` workers each hold a truncated SVD ``(U_i, S_i, V_i)`` of their
row block ``M_i``; we want the rank-r SVD of the concatenation
``M = [M_1; ...; M_W]`` without ever materializing ``M``.

For one pair ``[A; B]`` with ``A ~ U_a S_a V_a^T`` (rank r_a) and
``B ~ U_b S_b V_b^T`` (rank r_b):

    [A; B] = [[U_a, 0], [0, U_b]] @ K,    K = [[S_a V_a^T], [S_b V_b^T]]

so the whole merge reduces to the SVD of the small ``(r_a + r_b, n)`` core
``K`` — which we build by *rank-1 updates*: start from ``[S_a V_a^T; 0]``
(exactly representable at rank r_a with orthonormal bases
``u = [I_{r_a}; 0]``, ``v = V_a``) and absorb B's components one at a time,

    K <- K + (s_i e_{r_a + i}) v_i^T        (i = 1..r_b),

each step a truncated-update engine call (Brand augmentation +
Algorithm 6.1; fast truncated updating in the spirit of Deng et al.,
arXiv:2401.09703).  Every intermediate state ``K_j`` keeps rank r: since
``K_j``'s rows are a subset of ``K``'s, ``rank(K_j) <= rank(K)``, so for a
globally rank-<=r matrix the truncation after each step discards an exact
zero and the log-depth tree merge reproduces the rank-r SVD of ``M`` exactly;
for general matrices it is the streaming near-optimal approximation with the
usual hierarchical-merge error (Iwen & Ong Thm 3).

``merge_tree`` reduces a shard list pairwise in log depth, batching all the
pairs of a level through ONE batched engine call per rank-1 step.  When the
shards share one geometry, a non-power-of-two shard count is padded with
zero shards (``s = 0``; the zero rows fall at the bottom and are sliced off
the final left factor), so every level pairs equal geometries and runs the
batched path — no sequential ``merge_pair`` fallback.  ``distributed_merge``
is the shard_map form: ``all_gather`` of the small factors
(``r*(m+n+1)`` floats per worker — the only wire traffic), then the same
tree merge runs replicated on every worker.

Shards may be ``repro.api.SvdState`` or legacy ``TruncatedSvd`` containers;
the result comes back in the container type of the first shard.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import obs as _obs
from repro.api import UpdatePolicy
from repro.api.policy import policy_from_legacy
from repro.api.state import SvdState, like_container as _like
from repro.api.update import engine_from_key
from repro.core.engine import SvdEngine, stack_trees, unstack_tree
from repro.core.svd_update import TruncatedSvd
from repro.dist.collectives import all_gather_tsvd, factor_wire_bytes
from repro.updates.ops import AppendRows
from repro.updates.planner import apply as _planned_apply

__all__ = ["merge_append", "merge_pair", "merge_tree", "distributed_merge"]


def _engine_from(
    engine: SvdEngine | None,
    policy: UpdatePolicy | None,
    method: str,
    rank: int,
) -> SvdEngine:
    """Engine for the merge's truncated core updates: explicit ``engine`` >
    ``policy`` > legacy ``method`` string — all landing on the shared
    policy-keyed ``default_engine`` caches."""
    if engine is not None:
        return engine
    return engine_from_key(policy_from_legacy(policy, method), rank + 1)


def _merge_cores_batched(
    a_stack: TruncatedSvd, b_stack: TruncatedSvd, engine: SvdEngine
) -> TruncatedSvd:
    """SVDs of the stacked cores ``K_p = [S_a V_a^T; S_b V_b^T]`` for P pairs.

    Leaves of ``a_stack``/``b_stack`` carry a leading pair axis P; all pairs
    share one geometry, so each of the ``r_b`` rank-1 absorptions is a single
    batched engine call (P plans for the price of one).
    """
    p_pairs, _, r_a = a_stack.u.shape
    r_b = b_stack.s.shape[1]
    dt = a_stack.u.dtype
    rows = r_a + r_b

    # [S_a V_a^T; 0] at rank r_a with orthonormal bases.  (Never pad the
    # state with zero *columns*: non-orthonormal bases poison the Brand
    # augmentation once zero singular values tie in the eigen-update.)
    u0 = jnp.broadcast_to(jnp.eye(rows, r_a, dtype=dt), (p_pairs, rows, r_a))
    core = TruncatedSvd(u=u0, s=a_stack.s, v=a_stack.v)

    for i in range(r_b):
        # s_i e_{r_a+i} v_i^T — the e-vector lands on B's (so-far untouched)
        # row block, orthogonal to the initial column span of u0.
        e_i = jnp.zeros((p_pairs, rows), dt).at[:, r_a + i].set(b_stack.s[:, i])
        core = engine.update_truncated_batch(core, e_i, b_stack.v[:, :, i])
    return core


def _combine_bases(a, b, core: TruncatedSvd, rank: int):
    """Lift the core SVD back through the block-diagonal left bases."""
    r_a = a.s.shape[0]
    uk = core.u[:, :rank]
    u = jnp.concatenate([a.u @ uk[:r_a], b.u @ uk[r_a:]], axis=0)
    return _like(a, u, core.s[:rank], core.v[:, :rank])


def merge_pair(
    a,
    b,
    *,
    rank: int | None = None,
    engine: SvdEngine | None = None,
    method: str = "direct",
    policy: UpdatePolicy | None = None,
):
    """Rank-``rank`` truncated SVD of the row concatenation ``[A; B]``.

    ``rank`` defaults to (and may not exceed) ``r_a``, the rank carried by
    the core state.  Columns beyond the true rank of ``[A; B]`` come back
    with zero singular values (their vectors are padding, as in any
    truncated SVD of a rank-deficient matrix).
    """
    if a.v.shape[0] != b.v.shape[0]:
        raise ValueError(
            f"row-concatenated shards must share the column space: "
            f"n={a.v.shape[0]} vs {b.v.shape[0]}"
        )
    r_a = a.s.shape[0]
    r = rank if rank is not None else r_a
    if r > r_a:
        raise ValueError(
            f"merge rank {r} exceeds the left shard's rank {r_a}; the core "
            f"state carries rank r_a — order the higher-rank shard first"
        )
    engine = _engine_from(engine, policy, method, r_a)
    a_stack = jax.tree.map(lambda x: x[None], TruncatedSvd(a.u, a.s, a.v))
    b_stack = jax.tree.map(lambda x: x[None], TruncatedSvd(b.u, b.s, b.v))
    core = unstack_tree(_merge_cores_batched(a_stack, b_stack, engine), 0)
    return _combine_bases(a, b, core, r)


def merge_append(
    a,
    b,
    *,
    rank: int | None = None,
    policy: UpdatePolicy | None = None,
):
    """Rank-``rank`` truncated SVD of ``[A; B]`` via the structured-update
    planner: ``B`` is an ``AppendRows.from_svd`` op on ``A``'s state.

    The lowering zero-pads ``A``'s left basis by ``B``'s rows and absorbs
    ``B``'s components as planned rank-1 steps — the same math as
    ``merge_pair``'s small-core trick lifted to the full-height state, and
    the path ``merge_tree`` uses for genuinely mixed shard heights (where
    the equal-geometry batched core cannot).  Exact under the same global
    rank-``r_a`` condition.
    """
    if a.v.shape[0] != b.v.shape[0]:
        raise ValueError(
            f"row-concatenated shards must share the column space: "
            f"n={a.v.shape[0]} vs {b.v.shape[0]}"
        )
    r_a = a.s.shape[0]
    r = rank if rank is not None else r_a
    if r > r_a:
        raise ValueError(
            f"merge rank {r} exceeds the left shard's rank {r_a}; the core "
            f"state carries rank r_a — order the higher-rank shard first"
        )
    out = _planned_apply(
        SvdState(u=a.u, s=a.s, v=a.v),
        AppendRows.from_svd(b.u, b.s, b.v),
        policy_from_legacy(policy),
    )
    return _like(a, out.u[:, :r], out.s[:r], out.v[:, :r])


def _pad_to_pow2(shards: list) -> tuple[list, int]:
    """Append zero shards (``s = 0``, zero left rows, the last shard's
    orthonormal ``v``) until the count is a power of two.

    Only possible when all shards share one geometry; a zero shard is the
    exact SVD of an all-zero row block, so ``[M_1; ...; M_W; 0; ...; 0]``
    has the same singular values/right basis as ``M`` and the padded rows —
    appended at the END, so they stay at the bottom through every ordered
    pairwise level — are sliced off the final left factor by the caller.
    Returns (padded shard list, number of real rows).
    """
    w = len(shards)
    real_rows = sum(int(t.u.shape[0]) for t in shards)
    target = 1
    while target < w:
        target <<= 1
    if target == w:
        return shards, real_rows
    tmpl = shards[-1]
    zero = _like(
        tmpl,
        jnp.zeros_like(tmpl.u),
        jnp.zeros_like(tmpl.s),
        tmpl.v,  # any orthonormal basis keeps the Brand invariant
    )
    return shards + [zero] * (target - w), real_rows


def merge_tree(
    shards,
    *,
    rank: int | None = None,
    engine: SvdEngine | None = None,
    method: str = "direct",
    policy: UpdatePolicy | None = None,
):
    """Log-depth pairwise merge of row-partitioned truncated SVDs.

    ``shards`` are ordered row blocks.  Each level pairs neighbors
    (preserving row order) and merges all equal-geometry pairs through one
    batched engine call per rank-1 step; equal-geometry shard lists of
    non-power-of-two length are padded with zero shards so EVERY level runs
    the batched path (the padding's zero rows are sliced off the result).
    Genuinely mixed geometries merge pairwise through the structured-update
    planner's ``AppendRows`` lowering (``merge_append``; pairwise
    ``merge_pair`` when the caller pinned an explicit engine), with an odd
    tail riding up a level.  Depth is ``ceil(log2 W)`` — the reduction shape
    that keeps a 1000-worker merge at ~10 sequential rounds.
    """
    shards = list(shards)
    if not shards:
        raise ValueError("merge_tree needs at least one shard")
    r_min = min(int(t.s.shape[0]) for t in shards)
    if rank is None:
        rank = r_min
    elif rank > r_min:
        raise ValueError(
            f"merge rank {rank} exceeds the smallest shard rank {r_min}; "
            f"the pairwise core state cannot carry more than the shard rank"
        )
    explicit_engine = engine
    pol = policy_from_legacy(policy, method)
    engine = _engine_from(engine, policy, method, r_min)

    real_rows = None
    if len(shards) > 1:
        geoms = {(t.u.shape, t.s.shape, t.v.shape) for t in shards}
        if len(geoms) == 1:
            shards, real_rows = _pad_to_pow2(shards)

    level = 0
    while len(shards) > 1:
        pairs = [(shards[i], shards[i + 1]) for i in range(0, len(shards) - 1, 2)]
        tail = [shards[-1]] if len(shards) % 2 else []
        geoms = {(p[0].u.shape, p[1].u.shape) for p in pairs}
        merged: list = []
        # wire accounting for the trace: what this level's factor exchange
        # would cost over the wire (first pair's geometry as representative)
        wires = factor_wire_bytes(
            int(pairs[0][0].u.shape[0]) + int(pairs[0][1].u.shape[0]),
            int(pairs[0][0].v.shape[0]),
            rank,
            n_workers=len(pairs) * 2,
            itemsize=pairs[0][0].u.dtype.itemsize,
        )
        with _obs.span("merge_level", level=level, pairs=len(pairs),
                       batched=len(geoms) == 1, **wires):
            if len(geoms) == 1:
                a_stack = stack_trees([TruncatedSvd(p[0].u, p[0].s, p[0].v) for p in pairs])
                b_stack = stack_trees([TruncatedSvd(p[1].u, p[1].s, p[1].v) for p in pairs])
                cores = _merge_cores_batched(a_stack, b_stack, engine)
                merged = [
                    _combine_bases(p[0], p[1], unstack_tree(cores, j), rank)
                    for j, p in enumerate(pairs)
                ]
            elif explicit_engine is not None:
                # caller-managed engine: the planner resolves engines from the
                # policy only, so keep the small-core pairwise path
                merged = [merge_pair(x, y, rank=rank, engine=engine) for x, y in pairs]
            else:
                # genuinely unequal shard heights: each pair is an AppendRows
                # lowering through the structured-update planner
                merged = [merge_append(x, y, rank=rank, policy=pol) for x, y in pairs]
        if _obs.enabled():
            reg = _obs.registry()
            reg.counter("merge_levels").inc()
            reg.counter("merge_pairs").inc(len(pairs))
            reg.counter("merge_wire_bytes",
                        kind="factor_allgather").inc(int(wires["factor_allgather"]))
            reg.counter("merge_wire_bytes",
                        kind="dense_allreduce").inc(int(wires["dense_allreduce"]))
        shards = merged + tail
        level += 1

    out = shards[0]
    if real_rows is not None and out.u.shape[0] != real_rows:
        out = _like(out, out.u[:real_rows], out.s, out.v)
    return out


def distributed_merge(
    local,
    axis_name,
    *,
    rank: int | None = None,
    engine: SvdEngine | None = None,
    method: str = "direct",
    policy: UpdatePolicy | None = None,
):
    """Merge per-worker truncated SVDs across a mesh axis (call under
    ``shard_map``).

    ``all_gather`` moves only the ``(m, r) + (r,) + (n, r)`` factors; the
    log-depth tree merge then runs identically on every worker, so the result
    is replicated — each worker ends with the rank-r SVD of the row-stacked
    global matrix ``[M_1; ...; M_W]`` (rows ordered by worker index, worker
    ``i`` owning rows ``[i*m, (i+1)*m)``).  Outside shard_map
    (``axis_name=None``) this is just a local no-op merge.
    """
    gathered = all_gather_tsvd(local, axis_name)
    n_workers = gathered.u.shape[0]
    shards = [unstack_tree(gathered, i) for i in range(n_workers)]
    return merge_tree(shards, rank=rank, engine=engine, method=method, policy=policy)
