import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

"""Fleet tier vs single service: sustained throughput + latency SLO
(DESIGN.md §13).

The two lines above MUST stay first: jax locks the device count on first
init (same contract as bench_dist.py) — the fleet arms run on 8 fake CPU
devices.  Fake devices share ONE physical core, so the fleet's win here is
NOT device parallelism: it is continuous batching's round shape.  A
standalone service dispatches one-event-per-stream rounds (depth 1,
re-stacking every stream's state each wave); a backlogged fleet shard seals
rank-k scan columns (depth up to MAX_DEPTH), so the same event count ships
in ~ROUNDS/MAX_DEPTH fewer engine rounds with ~MAX_DEPTH-fold less host-side
state re-stacking.  On a real accelerator mesh the per-shard device pinning
adds device parallelism on top.

Two experiments, shared geometry (small factors: host-overhead-bound, the
regime the fleet tier targets — million-stream populations of modest rank):

1. **Sustained enqueue throughput** (closed loop): feed STREAMS x ROUNDS
   events as fast as the admission layer accepts them, drain, report
   events/s.  Arms: single service; fleet at 2/4/8 shards.  Acceptance:
   fleet@8 >= 1.5x single.

2. **Enqueue-to-visible latency** (open loop): Poisson arrivals at
   LOAD x the single service's sustained rate, driven through
   ``common.open_loop``; every event's token is stamped when its flush
   round retires.  Arms: single service with fixed flush boundaries
   (autoflush at FIXED_BATCH); fleet@8 with the same fixed boundaries
   (continuous=False); fleet@8 with continuous batching.  Acceptance:
   continuous p99 < fixed-boundary p99 at the same offered load.

CSV rows (benchmarks/run.py style):
  bench_fleet/throughput/<arm>,us_total,events_per_s=...
  bench_fleet/latency/<arm>,p99_us,p50_us=... rate_hz=...

and a machine-readable summary at benchmarks/BENCH_fleet.json.
"""

import json
import time
from pathlib import Path

import numpy as np

import jax.numpy as jnp

from benchmarks.common import bench_metadata, emit, open_loop
from repro import obs
from repro.api import SvdState, UpdatePolicy
from repro.fleet import SvdFleet
from repro.serve import SvdService

M, N, RANK = 64, 96, 8
STREAMS = 64
ROUNDS = 32            # events per stream, closed-loop experiment
MAX_DEPTH = 32
SHARD_COUNTS = (2, 4, 8)
REPEAT = 3

OPEN_EVENTS = 768      # open-loop experiment length
LOAD = 0.5             # offered rate as a fraction of single sustained rate
FIXED_BATCH = 16       # fixed-boundary arms autoflush at this fill count

OUT = Path(__file__).parent / "BENCH_fleet.json"
POLICY = UpdatePolicy(method="direct")


def _states():
    rng = np.random.default_rng(0)
    return [
        SvdState.from_factors(
            np.linalg.qr(rng.normal(size=(M, RANK)))[0],
            np.sort(np.abs(rng.normal(size=RANK)))[::-1].copy(),
            np.linalg.qr(rng.normal(size=(N, RANK)))[0],
        )
        for _ in range(STREAMS)
    ]


def _traffic(count: int):
    rng = np.random.default_rng(1)
    return [
        (f"s{i % STREAMS}",
         jnp.asarray(rng.normal(size=M)), jnp.asarray(rng.normal(size=N)))
        for i in range(count)
    ]


def _single(max_batch: int = STREAMS) -> SvdService:
    svc = SvdService(max_batch=max_batch, max_in_flight=2, policy=POLICY)
    for i, st in enumerate(_states()):
        svc.register(f"s{i}", st)
    return svc


def _fleet(shards: int, *, continuous: bool = True,
           max_batch: int = STREAMS) -> SvdFleet:
    # devices deliberately unpinned: fake CPU devices share one core, and
    # XLA compiles per (executable, device) — pinning shard i to device i
    # would multiply every (batch-bucket x depth-bucket) compile by 8 for
    # zero parallelism.  On a real mesh pass devices="auto".
    fl = SvdFleet(
        shards,
        policy=POLICY,
        max_batch=max_batch,
        max_depth=MAX_DEPTH,
        max_in_flight=2,
        continuous=continuous,
    )
    for i, st in enumerate(_states()):
        fl.register(f"s{i}", st)
    return fl


# ---------------------------------------------------------------------------
# 1. sustained enqueue throughput (closed loop)
# ---------------------------------------------------------------------------


def _feed_drain(make) -> tuple[float, object]:
    tgt = make()
    traffic = _traffic(STREAMS * ROUNDS)
    t0 = time.perf_counter()
    for sid, a, b in traffic:
        tgt.enqueue(sid, a, b)
    tgt.drain()
    return time.perf_counter() - t0, tgt


def _prewarm() -> None:
    """AOT-compile the full (batch-bucket x depth-bucket) executable grid.

    Round shapes depend on retire timing (which streams a window catches),
    so no single warm pass covers every shape later passes may seal.  But
    bucket padding (powers of two) makes the whole space enumerable: ~40
    executables, compiled once here, shared by every arm — the same
    warmed-set contract the service replays on restore (DESIGN.md §12/§13).
    """
    from repro.api import warmup

    for b in (1, 2, 4, 8, 16, 32, 64):
        warmup(POLICY, m=M, n=N, batch=b, rank=RANK)
        for k in (2, 4, 8, 16, 32):
            if k <= MAX_DEPTH:
                warmup(POLICY, m=M, n=N, batch=b, rank=RANK, k=k)


def bench_throughput() -> dict:
    arms: dict = {"single": _single}
    for k in SHARD_COUNTS:
        arms[f"fleet{k}"] = lambda k=k: _fleet(k)

    _prewarm()
    # one host-path warm pass per arm (executables are already compiled)
    for make in arms.values():
        _feed_drain(make)

    events = STREAMS * ROUNDS
    best: dict = {name: (float("inf"), None) for name in arms}
    for _ in range(REPEAT):       # interleaved: drift hits all arms equally
        for name, make in arms.items():
            t, tgt = _feed_drain(make)
            if t < best[name][0]:
                best[name] = (t, tgt)

    out = {}
    for name, (t, tgt) in best.items():
        stats = tgt.stats() if hasattr(tgt, "stats") and callable(tgt.stats) \
            else tgt.stats
        out[name] = {
            "seconds": t,
            "events_per_s": events / t,
            "rounds": stats.rounds,
            "scan_rounds": stats.scan_rounds,
            "max_depth": stats.max_depth,
            "max_batch": stats.max_batch,
        }
        emit(f"bench_fleet/throughput/{name}", t * 1e6,
             f"events_per_s={events / t:.0f} rounds={stats.rounds} "
             f"scan_rounds={stats.scan_rounds}")
    ratio = out["fleet8"]["events_per_s"] / out["single"]["events_per_s"]
    out["fleet8_vs_single"] = ratio
    emit("bench_fleet/throughput/fleet8_vs_single",
         best["fleet8"][0] * 1e6, f"speedup={ratio:.2f}x")
    return out


# ---------------------------------------------------------------------------
# 2. enqueue-to-visible latency under Poisson open-loop load
# ---------------------------------------------------------------------------


def _run_open_loop(make, rate_hz: float, *, seed: int) -> dict:
    tgt = make()
    traffic = _traffic(OPEN_EVENTS)
    arrivals = [0.0]
    from benchmarks.common import poisson_arrivals

    arrivals = poisson_arrivals(rate_hz, OPEN_EVENTS, seed=seed)

    is_fleet = isinstance(tgt, SvdFleet)

    def enqueue(ev):
        sid, a, b = ev
        return tgt.enqueue(sid, a, b)

    def tick():
        if is_fleet:
            tgt.pump()
        return tgt.poll() if is_fleet else tgt.take_visible()

    return open_loop(enqueue, tick, tgt.drain, traffic, arrivals)


def bench_latency(single_rate_hz: float) -> dict:
    rate = LOAD * single_rate_hz
    arms = {
        "single_fixed": lambda: _single(max_batch=FIXED_BATCH),
        "fleet8_fixed": lambda: _fleet(8, continuous=False,
                                       max_batch=FIXED_BATCH),
        "fleet8_continuous": lambda: _fleet(8),
    }
    out: dict = {"offered_rate_hz": rate}
    for name, make in arms.items():
        _run_open_loop(make, rate, seed=2)          # warm shapes
        res = _run_open_loop(make, rate, seed=3)    # measured
        out[name] = res
        emit(f"bench_fleet/latency/{name}", res["p99_us"],
             f"p50_us={res['p50_us']:.0f} rate_hz={rate:.0f} "
             f"sustained_hz={res['sustained_rate_hz']:.0f}")
    out["continuous_vs_fixed_p99"] = (
        out["fleet8_fixed"]["p99_us"] / out["fleet8_continuous"]["p99_us"])
    emit("bench_fleet/latency/continuous_vs_fixed",
         out["fleet8_continuous"]["p99_us"],
         f"p99_reduction={out['continuous_vs_fixed_p99']:.2f}x")
    return out


def run() -> dict:
    # metrics on for every arm (uniform cost, so arm ratios are untouched):
    # per-shard serve_* gauges, fleet_* rollups and the emit() bench_us rows
    # all land in one registry the summary can count.
    obs.enable()
    throughput = bench_throughput()
    latency = bench_latency(throughput["single"]["events_per_s"])
    reg = obs.registry()
    shard_series = sorted({
        dict(m.labels)["shard"] for m in reg.series()
        if "shard" in dict(m.labels)
    })
    obs_block = {
        "series": len(reg.series()),
        "shards_reporting": shard_series,
        "fleet_applied": reg.aggregate("fleet_applied"),
    }
    obs.disable()
    summary = {
        "meta": bench_metadata(),
        "obs": obs_block,
        "m": M, "n": N, "rank": RANK,
        "streams": STREAMS, "rounds": ROUNDS, "max_depth": MAX_DEPTH,
        "open_events": OPEN_EVENTS, "load_fraction": LOAD,
        "fixed_batch": FIXED_BATCH,
        "throughput": throughput,
        "latency": latency,
        "accept": {
            "fleet8_ge_1p5x_single":
                throughput["fleet8_vs_single"] >= 1.5,
            "continuous_p99_below_fixed":
                latency["continuous_vs_fixed_p99"] > 1.0,
        },
    }
    OUT.write_text(json.dumps(summary, indent=2) + "\n")
    print(f"wrote {OUT}")
    return summary


if __name__ == "__main__":
    run()
