"""Algorithm 6.1 end-to-end + streaming truncated variant (paper Table 2).

Exercised through the public ``repro.api`` surface (the pre-api call shapes
are gone); geometry picks the full vs truncated route.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import api
from repro.api import SvdState, UpdatePolicy
from repro.core.eigh_update import eigh_update
from repro.core.svd_update import TruncatedSvd


def svd_update(u, s, v, a, b, *, method="direct", fmm_p=20):
    """Full Algorithm-6.1 update via ``api.update`` (module-local helper)."""
    return api.update(SvdState.from_factors(u, s, v), a, b,
                      UpdatePolicy(method=method, fmm_p=fmm_p))


def svd_update_truncated(tsvd, a, b, *, method="direct"):
    """Truncated streaming update via ``api.update``."""
    return api.update(tsvd, a, b, UpdatePolicy(method=method))

RNG = np.random.default_rng(3)

# the paper's own accuracy (Table 2, Eq. 32 error) — our implementation must
# beat it by orders of magnitude thanks to Loewner reweighting
PAPER_TABLE2 = {10: 0.141, 20: 0.0838, 30: 0.0560, 40: 0.0624, 50: 0.0465}


def _setup(m, n, lo=1.0, hi=9.0):
    a_mat = RNG.uniform(lo, hi, size=(m, n))  # paper's experimental setup
    a = RNG.normal(size=m)
    b = RNG.normal(size=n)
    u, s, vt = np.linalg.svd(a_mat)
    return a_mat, u, s, vt.T, a, b


def _eq32_error(a_hat, res, m):
    recon = np.asarray(res.u) @ np.diag(np.asarray(res.s)) @ np.asarray(res.v)[:, :m].T
    smax = np.linalg.svd(a_hat, compute_uv=False)[0]
    return np.max(np.abs(a_hat - recon)) / smax


@pytest.mark.parametrize("n", sorted(PAPER_TABLE2))
@pytest.mark.parametrize("method", ["direct", "fmm"])
def test_table2_accuracy_beats_paper(n, method):
    a_mat, u, s, v, a, b = _setup(n, n)
    res = svd_update(jnp.asarray(u), jnp.asarray(s), jnp.asarray(v),
                     jnp.asarray(a), jnp.asarray(b), method=method)
    err = _eq32_error(a_mat + np.outer(a, b), res, n)
    assert err < 1e-10
    assert err < PAPER_TABLE2[n] * 1e-6  # beats the paper by >= 6 orders


@pytest.mark.parametrize("m,n", [(30, 50), (64, 64), (128, 200)])
@pytest.mark.parametrize("method", ["direct", "fmm"])
def test_rectangular_and_larger(m, n, method):
    a_mat, u, s, v, a, b = _setup(m, n)
    res = svd_update(jnp.asarray(u), jnp.asarray(s), jnp.asarray(v),
                     jnp.asarray(a), jnp.asarray(b), method=method)
    a_hat = a_mat + np.outer(a, b)
    assert _eq32_error(a_hat, res, m) < 1e-9
    # singular values match a fresh SVD
    sv_ref = np.linalg.svd(a_hat, compute_uv=False)
    np.testing.assert_allclose(np.asarray(res.s), sv_ref, rtol=1e-9)
    # orthogonality
    un = np.asarray(res.u)
    vn = np.asarray(res.v)
    assert np.max(np.abs(un.T @ un - np.eye(m))) < 1e-10
    assert np.max(np.abs(vn.T @ vn - np.eye(n))) < 1e-10


def test_kernel_method_matches_direct():
    m = n = 96
    a_mat, u, s, v, a, b = _setup(m, n)
    r_dir = svd_update(jnp.asarray(u), jnp.asarray(s), jnp.asarray(v),
                       jnp.asarray(a), jnp.asarray(b), method="direct")
    r_ker = svd_update(jnp.asarray(u), jnp.asarray(s), jnp.asarray(v),
                       jnp.asarray(a), jnp.asarray(b), method="kernel")
    np.testing.assert_allclose(np.asarray(r_dir.s), np.asarray(r_ker.s), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(r_dir.u), np.asarray(r_ker.u), atol=1e-11)


def test_repeated_updates_stay_orthogonal():
    """Streaming regime: 20 successive rank-1 updates, no re-factorization."""
    n = 40
    a_mat, u, s, v, _, _ = _setup(n, n)
    uj, sj, vj = jnp.asarray(u), jnp.asarray(s), jnp.asarray(v)
    acc = a_mat.copy()
    for i in range(20):
        a = RNG.normal(size=n)
        b = RNG.normal(size=n)
        res = svd_update(uj, sj, vj, jnp.asarray(a), jnp.asarray(b))
        uj, sj, vj = res.u, res.s, res.v
        acc = acc + np.outer(a, b)
    assert np.max(np.abs(np.asarray(uj).T @ np.asarray(uj) - np.eye(n))) < 1e-8
    sv_ref = np.linalg.svd(acc, compute_uv=False)
    np.testing.assert_allclose(np.asarray(sj), sv_ref, rtol=1e-7)


def test_truncated_streaming_matches_best_rank_r():
    m, n, r = 48, 32, 6
    g = RNG.normal(size=(m, n))
    u, s, vt = np.linalg.svd(g, full_matrices=False)
    t = TruncatedSvd(jnp.asarray(u[:, :r]), jnp.asarray(s[:r]), jnp.asarray(vt.T[:, :r]))
    low = u[:, :r] @ np.diag(s[:r]) @ vt[:r]
    a = RNG.normal(size=m)
    b = RNG.normal(size=n)
    t2 = svd_update_truncated(t, jnp.asarray(a), jnp.asarray(b))
    ref = low + np.outer(a, b)
    sv = np.linalg.svd(ref, compute_uv=False)
    np.testing.assert_allclose(np.asarray(t2.s), sv[:r], rtol=1e-10)
    u2 = np.asarray(t2.u)
    assert np.max(np.abs(u2.T @ u2 - np.eye(r))) < 1e-10


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(5, 40),
    extra=st.integers(0, 30),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_svd_update_reconstructs(m, extra, seed):
    n = m + extra
    rng = np.random.default_rng(seed)
    a_mat = rng.normal(size=(m, n))
    a = rng.normal(size=m)
    b = rng.normal(size=n)
    u, s, vt = np.linalg.svd(a_mat)
    res = svd_update(jnp.asarray(u), jnp.asarray(s), jnp.asarray(vt.T),
                     jnp.asarray(a), jnp.asarray(b))
    assert _eq32_error(a_mat + np.outer(a, b), res, m) < 1e-8


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), rho_pos=st.booleans())
def test_property_eigh_update_invariants(seed, rho_pos):
    """Orthogonality + trace preservation (trace(B) = sum mu)."""
    rng = np.random.default_rng(seed)
    n = rng.integers(8, 60)
    d = np.sort(rng.normal(size=n))
    z = rng.normal(size=n)
    rho = (1.0 if rho_pos else -1.0) * (abs(rng.normal()) + 0.05)
    u = np.linalg.qr(rng.normal(size=(n, n)))[0]
    mu, un = eigh_update(jnp.asarray(u), jnp.asarray(d), jnp.asarray(z),
                         jnp.asarray(rho), rho_positive=rho_pos)
    un = np.asarray(un)
    assert np.max(np.abs(un.T @ un - np.eye(n))) < 1e-10
    trace_ref = np.sum(d) + rho * np.dot(z, z)
    np.testing.assert_allclose(float(jnp.sum(mu)), trace_ref, rtol=1e-10)
