"""Architecture configs: ``get(arch_id)`` / ``get_smoke(arch_id)``.

Arch ids match the assignment table; shapes come from ``base.SHAPES``.
"""

from __future__ import annotations

import importlib

from repro.configs.base import (
    SHAPES,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    OptimizerConfig,
    RunConfig,
    RWKVConfig,
    ShapeConfig,
    SSMConfig,
)

_MODULES = {
    "qwen1.5-32b": "qwen1_5_32b",
    "nemotron-4-15b": "nemotron_4_15b",
    "granite-34b": "granite_34b",
    "qwen2-72b": "qwen2_72b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "whisper-base": "whisper_base",
    "zamba2-7b": "zamba2_7b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "rwkv6-1.6b": "rwkv6_1_6b",
}

ARCH_IDS = tuple(_MODULES)

# long_500k needs sub-quadratic attention: run only for SSM/hybrid archs
# (skips documented in DESIGN.md §6).
LONG_CONTEXT_ARCHS = ("zamba2-7b", "rwkv6-1.6b")


def _mod(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get(arch_id: str) -> ModelConfig:
    return _mod(arch_id).config()


def get_smoke(arch_id: str) -> ModelConfig:
    return _mod(arch_id).smoke()


def cells():
    """All assigned (arch, shape) dry-run cells, with documented skips."""
    out = []
    for arch in ARCH_IDS:
        for shape_name, shape in SHAPES.items():
            if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                continue
            out.append((arch, shape_name))
    return out


__all__ = [
    "ARCH_IDS",
    "LONG_CONTEXT_ARCHS",
    "SHAPES",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "OptimizerConfig",
    "RunConfig",
    "RWKVConfig",
    "ShapeConfig",
    "SSMConfig",
    "cells",
    "get",
    "get_smoke",
]
