"""Continuous-batching admission over one ``SvdService`` (DESIGN.md §13).

The plain service flushes at FIXED boundaries: a round dispatches when
``max_batch`` streams have a pending head (or on an explicit ``flush()``),
and it always takes exactly one event per stream.  Under an open-loop load
that is the latency shape of a bus schedule — an event that just missed a
round waits for the next boundary, and at moderate rates the boundary only
arrives when enough OTHER streams have queued (p99 = the batch-fill time).

This frontend replaces the boundary with an **admission window**:

    admit(...)  ->  [open window: per-stream FIFOs accumulate]
                        |  event-loop tick (pump) finds device capacity
                        |  (in-flight < max_in_flight)
                        v
                    seal: flush_round(max_depth) dispatches EVERYTHING
                    pending — wide (all ready streams) and deep (backlogged
                    streams contribute up to max_depth consecutive pairs as
                    one rank-k scan column)

* A round is sealed at the next ``pump`` tick with device capacity — never
  at a fill count, and never per admit (per-admit sealing freezes rounds
  at one event each and pays a full dispatch per event).  While the device
  is busy, arriving events join the open window, so the NEXT round's batch
  grows with load: light traffic gets small prompt rounds (minimum
  latency), heavy traffic gets wide+deep rounds (maximum throughput).
  That adaptivity IS continuous batching.
* Ordering correctness needs no locks beyond the service's: a stream's
  events sit in ONE per-stream FIFO, a round takes only a FIFO *prefix*,
  and a depth-k column applies its pairs in FIFO order inside the scan —
  so every stream's updates form a single data-dependence chain no matter
  how windows cut it (the proof obligation pinned by
  ``test_continuous_ordering_*`` in tests/test_fleet.py).
* Backpressure is per shard: past ``max_backlog`` pending events the next
  ``admit`` blocks on the oldest in-flight round before queueing — the
  host can neither run unboundedly ahead of the device (service
  ``max_in_flight``) nor buffer unboundedly many events (this bound).

Visibility: ``admit`` returns the service's enqueue token; ``poll()``
drains tokens whose round has retired.  Enqueue-to-visible is the fleet
SLO — ``benchmarks/bench_fleet.py`` reports its p50/p99.
"""

from __future__ import annotations

from repro import obs as _obs
from repro.serve.svd_service import SvdService

__all__ = ["ContinuousBatcher"]


class ContinuousBatcher:
    """Capacity-triggered admission over one shard's ``SvdService``.

    ``max_depth``: deepest rank-k scan column a sealed round may take from
    one stream's backlog (1 = classic one-event-per-stream rounds).
    ``max_backlog``: pending-event bound that blocks ``admit`` (None = the
    service's ``max_in_flight`` bounds host run-ahead on its own).
    ``device``: pin this shard's dispatches to one device
    (``placement.plan_devices``); None = the process default.
    """

    def __init__(
        self,
        service: SvdService,
        *,
        max_depth: int = 8,
        max_backlog: int | None = None,
        device=None,
        continuous: bool = True,
    ):
        if max_depth < 1:
            raise ValueError(f"max_depth must be >= 1; got {max_depth}")
        self.service = service
        self.max_depth = max_depth
        self.max_backlog = max_backlog
        self.device = device
        # continuous=False degrades to the service's own fixed boundaries
        # (autoflush at max_batch) — the benchmark's control arm
        self.continuous = continuous

    # -- admission ----------------------------------------------------------

    def admit(self, stream_id: str, a, b) -> int:
        """Admit one rank-1 event into the open window; returns its
        visibility token.  Admission NEVER seals: cutting a round per admit
        would freeze the round size at whatever the admission interval
        allows (one-event rounds on a host that outpaces its device, and
        every such round burns a full dispatch).  Rounds are sealed by the
        caller's event-loop tick (``pump``), by backpressure, or by
        ``drain`` — each sees the whole window and cuts maximally wide +
        deep rounds, which is what makes the batching *continuous*: the
        window between two ticks automatically spans however many events
        the load delivered."""
        self._backpressure()
        return self._enqueue(lambda: self.service.enqueue(stream_id, a, b))

    def admit_op(self, stream_id: str, op) -> int:
        """Admit one structured (``repro.updates``) event; returns the token
        of its last lowered sub-event (visible = whole op applied)."""
        self._backpressure()
        return self._enqueue(lambda: self.service.enqueue_op(stream_id, op))

    def _enqueue(self, do):
        if self.continuous:
            # suppress the service's count-triggered autoflush: the window
            # seals on CAPACITY, not on fill (restored below so explicit
            # service.flush()/drain() calls keep their semantics)
            saved, self.service.max_batch = self.service.max_batch, 1 << 30
            try:
                return do()
            finally:
                self.service.max_batch = saved
        return do()

    def _backpressure(self) -> None:
        if self.max_backlog is None or not self.continuous:
            return
        if self.service.pending() < self.max_backlog:
            return
        with _obs.span("backpressure", **self.service._obs_labels):
            while self.service.pending() >= self.max_backlog:
                # blocked: the window is as deep as allowed — wait for the
                # oldest round, then seal, freeing FIFO space
                with self.service._lock:
                    if self.service._in_flight:
                        self.service._retire_oldest()
                        self.service.stats.backpressure_waits += 1
                if not self.pump():
                    break   # nothing dispatchable: bound is all queued ops

    # -- sealing ------------------------------------------------------------

    def pump(self, *, once: bool = False) -> int:
        """Seal rounds while the device has capacity and events are pending;
        returns the number of events dispatched.  Never blocks: when the
        in-flight buffer is full the window simply stays open (that is the
        continuous-batching admission the module doc describes).  This is
        the event-loop tick — callers with their own loop (the fleet, the
        benchmark driver) call it between arrivals."""
        if not self.continuous or not self.service.pending():
            return 0
        dispatched = 0
        with _obs.span("pump", **self.service._obs_labels) as sp:
            while self.service.pending() and self.service.has_capacity():
                if self.device is not None:
                    import jax

                    with jax.default_device(self.device):
                        n = self.service.flush_round(max_depth=self.max_depth)
                else:
                    n = self.service.flush_round(max_depth=self.max_depth)
                if n == 0:
                    break
                dispatched += n
                if once:
                    break
            sp.set(dispatched=dispatched)
        return dispatched

    def poll(self) -> list[int]:
        """Newly visible tokens (their rounds retired); non-blocking."""
        return self.service.take_visible()

    def drain(self) -> int:
        """Seal everything (deep rounds, retiring in-flight work as needed)
        and block until visible — the shutdown/snapshot barrier."""
        n = 0
        if self.continuous:
            while self.service.pending():
                d = self.pump()
                n += d
                if not d:
                    # in-flight buffer full: wait for the oldest round, then
                    # keep sealing (service.drain alone would seal depth-1)
                    with self.service._lock:
                        if not self.service._in_flight:
                            break
                        self.service._retire_oldest()
        return n + self.service.drain()
