"""Batch-first SVD-update engine (DESIGN.md §4).

The paper's O(n^2 log(1/eps)) rank-1 update only pays off at system scale
when many updates run per step. ``SvdEngine`` is the subsystem that makes
that the default shape of the computation:

* **Plan cache.** Every distinct update geometry — (kind, batch, m, n, rank,
  dtype) x (method, fmm_p, sign_fix) — gets one cached, jitted executable.
  Trace + secular/FMM plan construction ("the plan") is paid once per
  geometry; every later call with that geometry is a cache hit that goes
  straight to the compiled batched update. ``warmup`` AOT-compiles a
  geometry ahead of traffic (serving cold-start control).

* **Batched entry points.** ``update_batch`` / ``update_truncated_batch``
  vmap Algorithm 6.1 over a leading batch axis of stacked (u, s, v) states
  and (a, b) perturbations. Under ``method="kernel"`` the hot Cauchy product
  lowers to ONE Pallas launch with the batch folded into the grid
  (``kernels.cauchy_matmul.cauchy_matmul_pallas_batched`` via the
  ``custom_vmap`` rule in ``kernels.ops``); under ``method="fmm"`` the
  Chebyshev-FMM plans batch as stacked tensors.

* **Sharding.** An optional ``jax.sharding.Sharding`` for the batch axis
  (build one with ``repro.dist.batch_sharding``) is applied to the stacked
  inputs, so a flush of B updates spreads over the mesh's data axis.

* **Mesh-aware dispatch.** ``update_batch`` / ``update_truncated_batch``
  accept ``mesh=`` + ``batch_axis=`` and then dispatch through
  ``shard_map``: the batch axis is split over the mesh axis and each shard
  runs the vmapped update — under ``method="kernel"`` one per-shard Pallas
  Cauchy launch with the local batch folded into its grid.  The update is
  embarrassingly parallel over the batch, so NOTHING crosses the wire
  inside the engine; only consumers' small factor collectives do
  (``repro.dist.collectives``).  Batches are auto-padded to the mesh axis
  size (no-op tail entries, results sliced off).

Consumers: ``optim.spectral`` / ``optim.compression`` group equal-geometry
parameters and make one engine call per group; ``serve.svd_service``
micro-batches streaming (a, b) pairs into engine flushes.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from repro import obs as _obs
from repro.core.svd_update import (
    SvdUpdateResult,
    TruncatedSvd,
    _svd_update_impl,
    _svd_update_truncated_impl,
)

__all__ = [
    "EngineCacheInfo",
    "SvdEngine",
    "default_engine",
    "group_indices",
    "stack_trees",
    "truncated_geometry",
    "unstack_tree",
]


# ---------------------------------------------------------------------------
# Group/stack/unstack helpers shared by every batching consumer
# (optim.spectral, optim.compression, serve.svd_service).
# ---------------------------------------------------------------------------


def truncated_geometry(tsvd: "TruncatedSvd") -> tuple:
    """Batching-group key for a truncated SVD state: ``(m, n, rank, dtype)``.

    States sharing this key can be stacked into one
    ``update_truncated_batch`` call — the single definition every batching
    consumer groups by."""
    m, r = tsvd.u.shape
    return (m, tsvd.v.shape[0], r, tsvd.u.dtype)


def group_indices(keys) -> dict:
    """``{key: [indices with that key]}`` preserving first-seen order."""
    groups: dict = {}
    for i, k in enumerate(keys):
        groups.setdefault(k, []).append(i)
    return groups


def stack_trees(trees):
    """Stack a sequence of identically-structured pytrees along a new
    leading batch axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def unstack_tree(tree, i: int):
    """Slice batch element ``i`` out of a stacked pytree."""
    return jax.tree.map(lambda x: x[i], tree)


class EngineCacheInfo(NamedTuple):
    hits: int
    misses: int
    entries: int


@dataclass
class _CacheEntry:
    fn: Callable[..., Any]          # jitted batched/single update
    compiled: Any = None            # AOT executable after warmup()
    calls: int = 0


def _geometry(kind: str, *arrays: jax.Array) -> tuple:
    return (kind,) + tuple((a.shape, jnp.result_type(a)) for a in arrays)


class SvdEngine:
    """Plan-cached, vmap-able rank-1 SVD update engine.

    One engine per (method, fmm_p, sign_fix) configuration; geometries are
    cached inside. Thread-safe: the serve layer flushes from request
    threads.
    """

    def __init__(
        self,
        *,
        method: str = "direct",
        fmm_p: int = 20,
        sign_fix: bool = True,
        deflate_rtol: float | None = None,
        precision: str | None = None,
        storage_dtype=None,
        sharding: jax.sharding.Sharding | None = None,
    ):
        if method not in ("direct", "fmm", "kernel", "fused"):
            raise ValueError(f"unknown method {method!r}")
        self.method = method
        self.fmm_p = fmm_p
        self.sign_fix = sign_fix
        self.deflate_rtol = deflate_rtol
        self.precision = precision
        # Mixed precision: with a 16-bit storage dtype the factors arrive
        # narrow; every impl then computes in f32 (in-kernel upcast on the
        # fused route, explicit cast on the phase-chain routes).
        self.storage_dtype = None if storage_dtype is None else jnp.dtype(storage_dtype)
        self.compute_dtype = (
            jnp.dtype(jnp.float32)
            if self.storage_dtype is not None and self.storage_dtype.itemsize <= 2
            else None
        )
        self.sharding = sharding
        self._cache: dict[tuple, _CacheEntry] = {}
        self._hits = 0
        self._misses = 0
        self._lock = threading.Lock()

    # -- plan cache ---------------------------------------------------------

    def cache_info(self) -> EngineCacheInfo:
        return EngineCacheInfo(self._hits, self._misses, len(self._cache))

    def cache_clear(self) -> None:
        with self._lock:
            self._cache.clear()
            self._hits = 0
            self._misses = 0

    def _entry(self, key: tuple, build: Callable[[], Callable]) -> _CacheEntry:
        with self._lock:
            ent = self._cache.get(key)
            hit = ent is not None
            if hit:
                self._hits += 1
            else:
                self._misses += 1
                ent = _CacheEntry(fn=build())
                self._cache[key] = ent
            ent.calls += 1
        if _obs.enabled():
            _obs.registry().counter(
                "engine_plan_cache_hits" if hit else "engine_plan_cache_misses"
            ).inc()
        return ent

    def _constrain(self, *arrays: jax.Array) -> tuple:
        if self.sharding is None:
            return arrays
        return tuple(jax.device_put(a, self.sharding) for a in arrays)

    # -- builders -----------------------------------------------------------

    def _with_precision(self, fn: Callable) -> Callable:
        """Wrap an impl so tracing runs under the configured matmul precision."""
        if self.precision is None:
            return fn
        prec = self.precision

        def wrapped(*args):
            with jax.default_matmul_precision(prec):
                return fn(*args)

        return wrapped

    def _full_impl(self) -> Callable:
        impl = partial(
            _svd_update_impl,
            method=self.method,
            fmm_p=self.fmm_p,
            sign_fix=self.sign_fix,
            deflate_rtol=self.deflate_rtol,
            compute_dtype=self.compute_dtype,
        )
        return self._with_precision(lambda u, s, v, a, b: impl(u, s, v, a, b))

    def _trunc_impl(self) -> Callable:
        impl = partial(
            _svd_update_truncated_impl,
            method=self.method,
            fmm_p=self.fmm_p,
            deflate_rtol=self.deflate_rtol,
            compute_dtype=self.compute_dtype,
        )
        return self._with_precision(lambda t, a, b: impl(t, a, b))

    # -- rank-k scan impls ---------------------------------------------------
    # A sequence of k rank-1 pairs applied through ONE lax.scan, so a long
    # repro.updates schedule traces k-independently (updates.planner lowers
    # k >= _SCAN_MIN schedules here). Diagnostics are the LAST step's.

    def _rank_k_fn(self) -> Callable:
        """Unjitted scan-of-updates body (exposed for trace-cost tests)."""
        impl = self._full_impl()

        def fn(u, s, v, va, vb):
            def step(carry, ab):
                res = impl(*carry, ab[0], ab[1])
                return (res.u, res.s, res.v), (res.d_left, res.d_right)

            (u2, s2, v2), (dls, drs) = jax.lax.scan(step, (u, s, v), (va, vb))
            return SvdUpdateResult(u=u2, s=s2, v=v2,
                                   d_left=dls[-1], d_right=drs[-1])

        return fn

    def _trunc_rank_k_fn(self) -> Callable:
        impl = self._trunc_impl()

        def fn(t, va, vb):
            def step(carry, ab):
                res = impl(TruncatedSvd(*carry), ab[0], ab[1])
                return (res.u, res.s, res.v), None

            carry, _ = jax.lax.scan(step, (t.u, t.s, t.v), (va, vb))
            return TruncatedSvd(*carry)

        return fn

    def _build_single(self) -> Callable:
        return jax.jit(self._full_impl())

    def _batch_jit_kwargs(self) -> dict:
        # Batched builders bake the batch sharding into the jit, so AOT
        # executables from warmup() accept the _constrain()-ed inputs.
        return {} if self.sharding is None else {"in_shardings": self.sharding}

    def _build_batch(self) -> Callable:
        return jax.jit(jax.vmap(self._full_impl()), **self._batch_jit_kwargs())

    def _build_truncated(self) -> Callable:
        return jax.jit(self._trunc_impl())

    def _build_truncated_batch(self) -> Callable:
        return jax.jit(jax.vmap(self._trunc_impl()), **self._batch_jit_kwargs())

    def _build_rank_k(self) -> Callable:
        return jax.jit(self._rank_k_fn())

    def _build_rank_k_batch(self) -> Callable:
        return jax.jit(jax.vmap(self._rank_k_fn()), **self._batch_jit_kwargs())

    def _build_trunc_rank_k(self) -> Callable:
        return jax.jit(self._trunc_rank_k_fn())

    def _build_trunc_rank_k_batch(self) -> Callable:
        return jax.jit(jax.vmap(self._trunc_rank_k_fn()), **self._batch_jit_kwargs())

    # -- mesh-aware (shard_map) builders ------------------------------------
    # Per-shard: the same vmapped impl, batch split over one mesh axis. The
    # update is independent per batch element, so there are no collectives
    # inside — check_rep is off because shard_map's replication checker has
    # nothing to verify here and trips on Pallas/custom_vmap internals on
    # the kernel path.

    def _build_batch_shard_map(self, mesh, axis: str) -> Callable:
        vf = jax.vmap(self._full_impl())
        spec = PartitionSpec(axis)
        return jax.jit(
            shard_map(vf, mesh=mesh, in_specs=(spec,) * 5, out_specs=spec,
                      check_rep=False)
        )

    def _build_truncated_batch_shard_map(self, mesh, axis: str) -> Callable:
        vf = jax.vmap(self._trunc_impl())
        spec = PartitionSpec(axis)
        return jax.jit(
            shard_map(vf, mesh=mesh, in_specs=(spec,) * 3, out_specs=spec,
                      check_rep=False)
        )

    @staticmethod
    def _mesh_axis_size(mesh, axis: str) -> int:
        try:
            return dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
        except KeyError:
            raise ValueError(
                f"mesh has no axis {axis!r}; axes: {mesh.axis_names}"
            ) from None

    @staticmethod
    def _pad_batch(arrays: tuple, size: int) -> tuple[tuple, int]:
        """Pad the leading batch dim to a multiple of ``size`` by repeating
        the last entry (a real but discarded update). Returns (padded, B)."""
        b = arrays[0].shape[0]
        pad = (-b) % size
        if pad == 0:
            return arrays, b
        padded = tuple(
            jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)]) for x in arrays
        )
        return padded, b

    # -- entry points -------------------------------------------------------

    @staticmethod
    def _call(ent: _CacheEntry, *args):
        # Prefer the AOT executable from warmup(): jit's dispatch cache is
        # NOT populated by lower().compile(), so calling ent.fn would retrace.
        # AOT executables only take concrete arrays — under an outer trace
        # (jit / lax.cond / shard_map consumers) fall back to the jitted fn.
        tracer_cls = getattr(jax.core, "Tracer", None)
        traced = tracer_cls is not None and any(
            isinstance(x, tracer_cls) for x in jax.tree.leaves(args)
        )
        if ent.compiled is not None and not traced:
            try:
                return ent.compiled(*args)
            except (TypeError, ValueError):
                pass  # tracer/sharding mismatch leaked past the check — retrace
        return ent.fn(*args)

    def update(self, u, s, v, a, b) -> SvdUpdateResult:
        """Single Algorithm-6.1 update (plan-cached jit)."""
        key = _geometry("single", u, s, v, a, b)
        ent = self._entry(key, self._build_single)
        return self._call(ent, u, s, v, a, b)

    def update_batch(self, u, s, v, a, b, *, mesh=None, batch_axis: str = "data") -> SvdUpdateResult:
        """B stacked updates in one call.

        ``u``: (B, m, m), ``s``: (B, m), ``v``: (B, n, n), ``a``: (B, m),
        ``b``: (B, n). Returns an ``SvdUpdateResult`` whose leaves carry the
        leading batch axis. Equivalent to B independent ``svd_update`` calls.

        With ``mesh`` the batch is split over ``batch_axis`` and dispatched
        through ``shard_map`` — each device runs its local slice of the
        batch; B is auto-padded up to the axis size and the padding sliced
        off the result.
        """
        if u.ndim != 3:
            raise ValueError(f"update_batch expects stacked (B, m, m) u; got {u.shape}")
        if mesh is None:
            key = _geometry("batch", u, s, v, a, b)
            ent = self._entry(key, self._build_batch)
            return self._call(ent, *self._constrain(u, s, v, a, b))
        size = self._mesh_axis_size(mesh, batch_axis)
        (u, s, v, a, b), b_orig = self._pad_batch((u, s, v, a, b), size)
        key = ("shard", mesh, batch_axis) + _geometry("batch", u, s, v, a, b)
        ent = self._entry(key, partial(self._build_batch_shard_map, mesh, batch_axis))
        out = self._call(ent, u, s, v, a, b)
        return jax.tree.map(lambda x: x[:b_orig], out)

    def update_truncated(self, tsvd: TruncatedSvd, a, b) -> TruncatedSvd:
        """Single streaming truncated update (plan-cached jit)."""
        key = _geometry("trunc", tsvd.u, tsvd.s, tsvd.v, a, b)
        ent = self._entry(key, self._build_truncated)
        return self._call(ent, tsvd, a, b)

    def update_truncated_batch(
        self, tsvd: TruncatedSvd, a, b, *, mesh=None, batch_axis: str = "data"
    ) -> TruncatedSvd:
        """B stacked rank-r streaming updates in one call.

        ``tsvd`` leaves: u (B, m, r), s (B, r), v (B, n, r); ``a``: (B, m),
        ``b``: (B, n). Returns a stacked ``TruncatedSvd``.  With ``mesh``
        the batch is split over ``batch_axis`` via ``shard_map`` (auto-padded
        to the axis size, padding sliced off).
        """
        if tsvd.u.ndim != 3:
            raise ValueError(
                f"update_truncated_batch expects stacked (B, m, r) u; got {tsvd.u.shape}"
            )
        if mesh is None:
            key = _geometry("trunc_batch", tsvd.u, tsvd.s, tsvd.v, a, b)
            ent = self._entry(key, self._build_truncated_batch)
            u_, s_, v_, a_, b_ = self._constrain(tsvd.u, tsvd.s, tsvd.v, a, b)
            return self._call(ent, TruncatedSvd(u_, s_, v_), a_, b_)
        size = self._mesh_axis_size(mesh, batch_axis)
        (u_, s_, v_, a_, b_), b_orig = self._pad_batch(
            (tsvd.u, tsvd.s, tsvd.v, a, b), size
        )
        key = ("shard", mesh, batch_axis) + _geometry("trunc_batch", u_, s_, v_, a_, b_)
        ent = self._entry(
            key, partial(self._build_truncated_batch_shard_map, mesh, batch_axis)
        )
        out = self._call(ent, TruncatedSvd(u_, s_, v_), a_, b_)
        return jax.tree.map(lambda x: x[:b_orig], out)

    # -- rank-k (scan) entry points -----------------------------------------

    def update_rank_k(self, u, s, v, va, vb) -> SvdUpdateResult:
        """k sequential rank-1 updates through one lax.scan.

        ``va``: (k, m), ``vb``: (k, n) — rank-1 pairs applied in row order.
        Trace/compile cost is k-independent (one step body); diagnostics
        (``d_left``/``d_right``) are the final step's.
        """
        key = _geometry("rank_k", u, s, v, va, vb)
        ent = self._entry(key, self._build_rank_k)
        return self._call(ent, u, s, v, va, vb)

    def update_rank_k_batch(self, u, s, v, va, vb, *, mesh=None,
                            batch_axis: str = "data") -> SvdUpdateResult:
        """B stacked k-step scans: ``u`` (B, m, m), ``va`` (B, k, m), ...."""
        if u.ndim != 3:
            raise ValueError(f"update_rank_k_batch expects stacked (B, m, m) u; got {u.shape}")
        if mesh is None:
            key = _geometry("rank_k_batch", u, s, v, va, vb)
            ent = self._entry(key, self._build_rank_k_batch)
            return self._call(ent, *self._constrain(u, s, v, va, vb))
        size = self._mesh_axis_size(mesh, batch_axis)
        (u, s, v, va, vb), b_orig = self._pad_batch((u, s, v, va, vb), size)
        key = ("shard", mesh, batch_axis) + _geometry("rank_k_batch", u, s, v, va, vb)
        ent = self._entry(
            key,
            lambda: jax.jit(shard_map(
                jax.vmap(self._rank_k_fn()), mesh=mesh,
                in_specs=(PartitionSpec(batch_axis),) * 5,
                out_specs=PartitionSpec(batch_axis), check_rep=False,
            )),
        )
        out = self._call(ent, u, s, v, va, vb)
        return jax.tree.map(lambda x: x[:b_orig], out)

    def update_truncated_rank_k(self, tsvd: TruncatedSvd, va, vb) -> TruncatedSvd:
        """k sequential truncated updates through one lax.scan."""
        key = _geometry("trunc_rank_k", tsvd.u, tsvd.s, tsvd.v, va, vb)
        ent = self._entry(key, self._build_trunc_rank_k)
        return self._call(ent, TruncatedSvd(tsvd.u, tsvd.s, tsvd.v), va, vb)

    def update_truncated_rank_k_batch(self, tsvd: TruncatedSvd, va, vb, *,
                                      mesh=None, batch_axis: str = "data") -> TruncatedSvd:
        """B stacked k-step truncated scans (mesh-shardable like the rest)."""
        if tsvd.u.ndim != 3:
            raise ValueError(
                f"update_truncated_rank_k_batch expects stacked (B, m, r) u; got {tsvd.u.shape}"
            )
        if mesh is None:
            key = _geometry("trunc_rank_k_batch", tsvd.u, tsvd.s, tsvd.v, va, vb)
            ent = self._entry(key, self._build_trunc_rank_k_batch)
            u_, s_, v_, va_, vb_ = self._constrain(tsvd.u, tsvd.s, tsvd.v, va, vb)
            return self._call(ent, TruncatedSvd(u_, s_, v_), va_, vb_)
        size = self._mesh_axis_size(mesh, batch_axis)
        (u_, s_, v_, va_, vb_), b_orig = self._pad_batch(
            (tsvd.u, tsvd.s, tsvd.v, va, vb), size
        )
        key = ("shard", mesh, batch_axis) + _geometry(
            "trunc_rank_k_batch", u_, s_, v_, va_, vb_
        )
        ent = self._entry(
            key,
            lambda: jax.jit(shard_map(
                jax.vmap(self._trunc_rank_k_fn()), mesh=mesh,
                in_specs=(PartitionSpec(batch_axis),) * 3,
                out_specs=PartitionSpec(batch_axis), check_rep=False,
            )),
        )
        out = self._call(ent, TruncatedSvd(u_, s_, v_), va_, vb_)
        return jax.tree.map(lambda x: x[:b_orig], out)

    # -- warmup -------------------------------------------------------------

    def warmup(
        self,
        *,
        batch: int | None,
        m: int,
        n: int,
        rank: int | None = None,
        k: int | None = None,
        dtype=jnp.float32,
    ) -> EngineCacheInfo:
        """AOT-compile the executable for one geometry before traffic.

        ``rank=None`` warms the full-update path, otherwise the truncated
        path; ``batch=None`` warms the single-instance variant; ``k`` warms
        the rank-k scan variant (k sequential pairs per call). The cache key
        includes ``dtype`` — warm with the dtype real traffic uses (default
        float32 matches ``compression_init``/``spectral_init`` trackers;
        pass ``jnp.float64`` for x64 workloads).
        """
        self._warm_entry(batch=batch, m=m, n=n, rank=rank, k=k, dtype=dtype)
        return self.cache_info()

    def aot_compiled(
        self,
        *,
        batch: int | None,
        m: int,
        n: int,
        rank: int | None = None,
        k: int | None = None,
        dtype=jnp.float32,
    ):
        """The AOT-compiled executable for one geometry (warming it first).

        Exposes the compiled object itself — ``cost_analysis()`` /
        ``memory_analysis()`` feed the launch-layer roofline cells
        (``repro.launch.perf_iter``) without re-lowering outside the shared
        plan cache.
        """
        return self._warm_entry(batch=batch, m=m, n=n, rank=rank, k=k,
                                dtype=dtype).compiled

    def _warm_entry(
        self,
        *,
        batch: int | None,
        m: int,
        n: int,
        rank: int | None = None,
        k: int | None = None,
        dtype=jnp.float32,
    ) -> _CacheEntry:
        dt = jnp.dtype(dtype)

        def sds(*shape):
            return jax.ShapeDtypeStruct(shape, dt)

        def vshape(*shape):
            # perturbation-pair shapes: (m,)/(n,) or (k, m)/(k, n) under scan
            return shape if k is None else (k,) + shape

        if rank is None:
            pair = (sds(*vshape(m)), sds(*vshape(n)))
            if batch is None:
                args = (sds(m, m), sds(m), sds(n, n), *pair)
                kind = "single" if k is None else "rank_k"
                build = self._build_single if k is None else self._build_rank_k
            else:
                pair = tuple(jax.ShapeDtypeStruct((batch,) + p.shape, dt) for p in pair)
                args = (sds(batch, m, m), sds(batch, m), sds(batch, n, n), *pair)
                kind = "batch" if k is None else "rank_k_batch"
                build = self._build_batch if k is None else self._build_rank_k_batch
            key = _geometry(kind, *args)
            ent = self._entry(key, build)
            if ent.compiled is None:
                with _obs.span("aot_warmup", kind=kind, batch=batch or 0,
                               m=m, n=n, k=k or 0):
                    ent.compiled = ent.fn.lower(*args).compile()
        else:
            pair = (sds(*vshape(m)), sds(*vshape(n)))
            if batch is None:
                leaves = (sds(m, rank), sds(rank), sds(n, rank))
                kind = "trunc" if k is None else "trunc_rank_k"
                build = self._build_truncated if k is None else self._build_trunc_rank_k
            else:
                pair = tuple(jax.ShapeDtypeStruct((batch,) + p.shape, dt) for p in pair)
                leaves = (sds(batch, m, rank), sds(batch, rank), sds(batch, n, rank))
                kind = "trunc_batch" if k is None else "trunc_rank_k_batch"
                build = (self._build_truncated_batch if k is None
                         else self._build_trunc_rank_k_batch)
            key = _geometry(kind, *leaves, *pair)
            ent = self._entry(key, build)
            if ent.compiled is None:
                with _obs.span("aot_warmup", kind=kind, batch=batch or 0,
                               m=m, n=n, rank=rank, k=k or 0):
                    ent.compiled = ent.fn.lower(TruncatedSvd(*leaves), *pair).compile()
        return ent


# ---------------------------------------------------------------------------
# Module-level default engines — one per configuration, shared plan caches.
# ---------------------------------------------------------------------------

_default_engines: dict[tuple, SvdEngine] = {}
_default_lock = threading.Lock()


def default_engine(
    method: str = "direct",
    *,
    fmm_p: int = 20,
    sign_fix: bool = True,
    deflate_rtol: float | None = None,
    precision: str | None = None,
    storage_dtype=None,
) -> SvdEngine:
    """Process-wide shared engine for a configuration (shared plan cache).

    The key covers every numerics knob an ``repro.api.UpdatePolicy`` carries,
    so policy-equal callers (old facades, the api layer, consumers) land on
    the SAME engine instance and plan cache — policy folds into the cache key.
    """
    sd = None if storage_dtype is None else jnp.dtype(storage_dtype)
    key = (method, fmm_p, sign_fix, deflate_rtol, precision, sd)
    with _default_lock:
        eng = _default_engines.get(key)
        if eng is None:
            eng = SvdEngine(method=method, fmm_p=fmm_p, sign_fix=sign_fix,
                            deflate_rtol=deflate_rtol, precision=precision,
                            storage_dtype=sd)
            _default_engines[key] = eng
        return eng
