"""FAST algorithm for Cauchy matrix-vector products (paper §4, Appendix C).

Gerasoulis (1988): evaluate  f(mu_i) = sum_j u_j / (lambda_j - mu_i)  as a
ratio of polynomials  f = h/g  with  g(x) = prod_j (lambda_j - x)  and
h = interpolation of  u_j * g'(lambda_j)  at the lambda nodes:

  1. coefficients of g via an FFT subproduct tree            O(n log^2 n)
  2. coefficients of g'                                      O(n)
  3. multipoint evaluation of g, g' at lambda and mu          O(n log^2 n)
  4. h_j = u_j g'(lambda_j)                                   O(n)
  5. interpolating polynomial h(x) through (lambda_j, h_j)    O(n log^2 n)
  6. f(mu_i) = h(mu_i) / g(mu_i)                              O(n)

This is the paper's *baseline* (Fig. 1 compares FAST vs FMM). It is known —
and the reason the paper itself moves to FMM — that power-basis coefficient
arithmetic is numerically catastrophic beyond n ≈ 60 (coefficients of
prod (lambda_j - x) span hundreds of orders of magnitude; the paper's own
experiments stop at n = 35). We implement it faithfully (numpy, FFT
subproduct tree) for the benchmark comparison and bound its valid range in
tests; steps 3/5 use the subproduct-tree remainder scheme so the asymptotic
complexity is honest.
"""

from __future__ import annotations

import numpy as np

__all__ = ["poly_from_roots", "multipoint_eval", "fast_cauchy_matvec", "fast_cauchy_matmul"]


def _polymul_fft(p: np.ndarray, q: np.ndarray) -> np.ndarray:
    """Product of two coefficient vectors (ascending powers) via FFT."""
    n_out = len(p) + len(q) - 1
    nfft = 1 << (n_out - 1).bit_length()
    fp = np.fft.rfft(p, nfft)
    fq = np.fft.rfft(q, nfft)
    out = np.fft.irfft(fp * fq, nfft)[:n_out]
    return out


def _subproduct_tree(roots: np.ndarray) -> list[list[np.ndarray]]:
    """Tree of polynomials; leaves are (x - r_j), root is prod_j (x - r_j)."""
    level = [np.array([-r, 1.0]) for r in roots]
    tree = [level]
    while len(level) > 1:
        nxt = []
        for i in range(0, len(level) - 1, 2):
            nxt.append(_polymul_fft(level[i], level[i + 1]))
        if len(level) % 2:
            nxt.append(level[-1])
        level = nxt
        tree.append(level)
    return tree


def poly_from_roots(roots: np.ndarray) -> np.ndarray:
    """Coefficients (ascending) of prod_j (x - r_j) via the FFT product tree."""
    return _subproduct_tree(np.asarray(roots, float))[-1][0]


def _poly_mod(p: np.ndarray, q: np.ndarray) -> np.ndarray:
    """p mod q (ascending coefficients), synthetic long division."""
    p = p.astype(float).copy()
    dq = len(q) - 1
    lead = q[-1]
    for k in range(len(p) - 1, dq - 1, -1):
        c = p[k] / lead
        if c != 0.0:
            p[k - dq : k + 1] -= c * q
        p[k] = 0.0
    return p[:dq] if dq > 0 else np.zeros(1)


def multipoint_eval(coeffs: np.ndarray, points: np.ndarray) -> np.ndarray:
    """Evaluate a polynomial at many points via remainder-tree descent.

    O(n log^2 n) like the paper's step 3. Falls back to Horner for tiny
    inputs.
    """
    points = np.asarray(points, float)
    if len(points) <= 8 or len(coeffs) <= 8:
        return np.polyval(coeffs[::-1], points)
    tree = _subproduct_tree(points)
    # descend: rem at node = parent rem mod node poly
    rems = {(len(tree) - 1, 0): _poly_mod(coeffs, tree[-1][0])}
    for lvl in range(len(tree) - 1, 0, -1):
        width = len(tree[lvl - 1])
        for i, node in enumerate(tree[lvl]):
            parent_rem = rems[(lvl, i)]
            li, ri = 2 * i, 2 * i + 1
            if li < width:
                rems[(lvl - 1, li)] = _poly_mod(parent_rem, tree[lvl - 1][li])
            if ri < width:
                rems[(lvl - 1, ri)] = _poly_mod(parent_rem, tree[lvl - 1][ri])
    out = np.empty(len(points))
    for j in range(len(points)):
        out[j] = rems[(0, j)][0]
    return out


def _newton_interp(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Coefficients (ascending) of the interpolating polynomial (Newton form)."""
    n = len(x)
    dd = y.astype(float).copy()
    for k in range(1, n):
        dd[k:] = (dd[k:] - dd[k - 1 : -1]) / (x[k:] - x[: n - k])
    # expand Newton form to power basis
    coeffs = np.zeros(n)
    coeffs[0] = dd[-1]
    for k in range(n - 2, -1, -1):
        # coeffs <- coeffs * (x - x_k) + dd[k]
        coeffs = np.concatenate([[0.0], coeffs[:-1]]) - x[k] * coeffs
        coeffs[0] += dd[k]
    return coeffs


def _normalize_domain(lam: np.ndarray, mu: np.ndarray):
    """Affine map of lam ∪ mu onto [-2, 2], the best-conditioned interval for
    power-basis arithmetic (monic Chebyshev polynomials there have sup-norm 2,
    so product-polynomial coefficients stay O(1) instead of exploding).
    f scales by 1/scale: sum u/(lam - mu) = (1/scale) sum u/(lam' - mu')."""
    lo = min(lam.min(), mu.min())
    hi = max(lam.max(), mu.max())
    scale = max((hi - lo) / 4.0, np.finfo(float).tiny)
    mid = 0.5 * (hi + lo)
    return (lam - mid) / scale, (mu - mid) / scale, scale


def fast_cauchy_matvec(u: np.ndarray, lam: np.ndarray, mu: np.ndarray) -> np.ndarray:
    """f(mu_i) = sum_j u_j / (lam_j - mu_i)  via the FAST algorithm."""
    lam = np.asarray(lam, float)
    mu = np.asarray(mu, float)
    u = np.asarray(u, float)
    n = len(lam)
    lam, mu, scale = _normalize_domain(lam, mu)

    # 1-2. g(x) = prod (lam_j - x) = (-1)^n prod (x - lam_j); g' coefficients
    g_monic = poly_from_roots(lam)             # prod (x - lam_j)
    sign = (-1.0) ** n
    g = sign * g_monic
    dg = g[1:] * np.arange(1, n + 1)

    # 3. evaluate g'(lam_j) and g(mu_i)
    dg_at_lam = multipoint_eval(dg, lam)
    g_at_mu = multipoint_eval(g, mu)

    # 4. h_j = -u_j g'(lam_j).  (The paper's step 4 states h_j = u_j g'(lam_j);
    # with g = prod (lam_j - x) we have g'(lam_j) = -prod_{k!=j}(lam_k - lam_j),
    # so the sign belongs in h. Verified against the direct sum in tests.)
    h_vals = -u * dg_at_lam

    # 5. interpolating polynomial through (lam_j, h_j); 6. ratio
    h_coeffs = _newton_interp(lam, h_vals)
    h_at_mu = multipoint_eval(h_coeffs, mu)
    return h_at_mu / g_at_mu / scale


def fast_cauchy_matmul(w: np.ndarray, lam: np.ndarray, mu: np.ndarray) -> np.ndarray:
    """Row-batched FAST: out[r, i] = sum_j w[r, j] / (lam_j - mu_i).

    The g-polynomial work is shared across rows (it depends only on the
    geometry); per-row work is the h interpolation + final ratio.
    """
    lam = np.asarray(lam, float)
    mu = np.asarray(mu, float)
    w = np.asarray(w, float)
    n = len(lam)
    lam, mu, scale = _normalize_domain(lam, mu)
    g_monic = poly_from_roots(lam)
    g = ((-1.0) ** n) * g_monic
    dg = g[1:] * np.arange(1, n + 1)
    dg_at_lam = multipoint_eval(dg, lam)
    g_at_mu = multipoint_eval(g, mu)
    out = np.empty((w.shape[0], len(mu)))
    for r in range(w.shape[0]):
        h_coeffs = _newton_interp(lam, -w[r] * dg_at_lam)
        out[r] = multipoint_eval(h_coeffs, mu) / g_at_mu / scale
    return out
