# Root conftest: loaded for BOTH the tier-1 run (tests/) and the doctest
# run (`pytest --doctest-modules src/repro/api`, which tests/conftest.py
# does not cover). The api doctests state numerical claims (allclose vs a
# fresh SVD) that hold at f64 working precision — enable x64 before any
# array is built, exactly as tests/conftest.py does for the test suite.
import jax

jax.config.update("jax_enable_x64", True)
