"""The ONE bisection+Newton secular loop body (kernel + reference + fused).

``kernels.secular_newton`` (the Pallas kernel), ``kernels.ref`` (its pure-jnp
oracle) and ``kernels.fused_update`` (the fused megakernel's secular phase)
all iterate the same fixed-count hybrid solve of

    w(mu) = 1 + rho * sum_j zc2_j / (dc_j - mu),   mu = anchor + tau,

on a precomputed difference tensor ``diff = dc - anchor``.  Before this
module the loop body was copy-pasted between the kernel and the reference —
they could drift silently.  Now there is exactly one definition; the only
degree of freedom is the layout (``poles_axis``): the secular kernel tiles
roots along the last axis (diff ``(N, BM)``), the fused kernel keeps roots
along the first (diff ``(K, K)``).

Everything here is plain jnp on values (no refs, no pallas imports), so the
same function body traces inside a Pallas kernel, inside jit, and in
interpret mode unchanged.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

__all__ = ["secular_iterate"]


def secular_iterate(
    diff,
    zc2,
    rho,
    lo,
    hi,
    *,
    n_bisect: int = 58,
    n_newton: int = 4,
    poles_axis: int = 0,
):
    """Fixed-count bisection + projected-Newton solve of the secular equation.

    ``diff[j, i] = dc_j - anchor_i`` when ``poles_axis == 0`` (roots on the
    last axis, ``zc2``: (N,), ``lo``/``hi``/result: (M,)), or
    ``diff[i, j] = dc_j - anchor_i`` when ``poles_axis == 1`` (roots on the
    first axis).  ``zc2`` must already be zeroed at invalid sources.  Returns
    the per-root offset ``tau`` with ``w(anchor + tau) ~= 0``, clipped to the
    bracket.
    """
    dt = diff.dtype

    # Bisection only ever looks at the SIGN of w, so it gets a w-only
    # evaluation; the derivative reduction (inv*inv) — ~40% of the work per
    # iteration — is computed only inside the Newton steps that use it.
    if poles_axis == 0:
        def _inv(tau):
            # Unguarded reciprocal + one select: 1/0 is a trap-free inf in
            # IEEE and the where picks 0 at exact-pole slots (deflated
            # entries, collapsed brackets).  No grads flow through here, so
            # the usual double-where safe-divide dance would only cost two
            # extra tensor passes per secular iteration.
            delta = diff - tau[None, :]
            return jnp.where(delta == 0.0, 0.0, 1.0 / delta)

        def w_only(tau):
            return 1.0 + rho * jnp.sum(zc2[:, None] * _inv(tau), axis=0)

        def w_of(tau):
            inv = _inv(tau)
            r = zc2[:, None] * inv
            w = 1.0 + rho * jnp.sum(r, axis=0)
            wp = rho * jnp.sum(r * inv, axis=0)
            return w, wp
    else:
        def _inv(tau):
            delta = diff - tau[:, None]
            return jnp.where(delta == 0.0, 0.0, 1.0 / delta)

        def w_only(tau):
            return 1.0 + rho * jnp.sum(zc2[None, :] * _inv(tau), axis=1)

        def w_of(tau):
            inv = _inv(tau)
            r = zc2[None, :] * inv
            w = 1.0 + rho * jnp.sum(r, axis=1)
            wp = rho * jnp.sum(r * inv, axis=1)
            return w, wp

    def bis_step(_, carry):
        lo_c, hi_c = carry
        mid = 0.5 * (lo_c + hi_c)
        w = w_only(mid)
        go_right = w < 0.0  # w increasing on the bracket: root above mid
        return jnp.where(go_right, mid, lo_c), jnp.where(go_right, hi_c, mid)

    lo_f, hi_f = lax.fori_loop(0, n_bisect, bis_step, (lo, hi))

    # Safeguarded pole-free Newton.  The anchor is always a pole of w, so
    # roots hugging it (tau -> 0) stall plain Newton: the linear model of a
    # near-hyperbola lands outside the bracket and every iteration degrades
    # to a bisection halving.  Iterating on f(tau) = tau * w(tau) instead
    # removes exactly that singularity — the anchor's term tau * rho*z_a^2 /
    # (0 - tau) is constant — and f is smooth on the whole bracket (all
    # other poles lie outside it), so Newton on f is quadratic even for
    # pole-hugging roots.  Each step first folds the sign at the current
    # iterate into the bracket, then takes the f-Newton step only if it
    # lands strictly inside; otherwise it bisects.  Worst case is therefore
    # n_bisect + n_newton halvings, typical is quadratic — which is what
    # lets the fused megakernel run 16+6 instead of 58+4.
    def newton_step(_, carry):
        lo_c, hi_c, tau_c = carry
        w, wp = w_of(tau_c)
        go_right = w < 0.0
        lo_n = jnp.where(go_right, tau_c, lo_c)
        hi_n = jnp.where(go_right, hi_c, tau_c)
        fp = w + tau_c * wp
        safe_fp = jnp.where(fp == 0.0, jnp.finfo(dt).tiny, fp)
        cand = tau_c - tau_c * w / safe_fp
        # CLOSED-interval acceptance.  After the fold, tau_c is itself one
        # of the bracket endpoints, and the step direction (sign of w, with
        # f' > 0) always points into the bracket — so cand can only land ON
        # an endpoint when the increment underflows, i.e. tau_c is already a
        # root at fp resolution.  A strict test would reject exactly that
        # converged iterate and a midpoint fallback would throw it away,
        # degrading the whole loop to plain bisection.
        inside = (cand >= lo_n) & (cand <= hi_n)
        tau_n = jnp.where(inside, cand, 0.5 * (lo_n + hi_n))
        return lo_n, hi_n, tau_n

    tau0 = 0.5 * (lo_f + hi_f)
    _, _, tau = lax.fori_loop(0, n_newton, newton_step, (lo_f, hi_f, tau0))
    return tau
