"""Sharding specs: one rulebook for params, batches, and decode caches.

The production mesh is ``(data=16, model=16)`` per pod, with an optional
leading ``pod=2`` axis (``launch.mesh.make_production_mesh``).  Specs emitted
here satisfy a single contract, checked by ``tests/test_dist.py``:

    every dim a spec shards is divisible by the product of the production
    sizes of the mesh axes assigned to it (``AXIS_SIZES``).

Spec rules (shape-driven, so the same code covers all 10 archs):

* **params** — 1-D leaves (norm gains, biases) replicate.  For >=2-D leaves
  the rightmost divisible dim takes ``model`` (tensor parallelism: the
  d_ff / head / vocab / expert-width dim in every family), and the rightmost
  *remaining* divisible dim takes ``data`` (ZeRO/FSDP-style weight sharding;
  gathered at use via ``gather_for_compute`` when ``cfg.fsdp_gather_params``).
  Leaves under a stacked-layer key (``layers``, ``groups``, ...) never shard
  their leading depth axis: ``lax.scan`` slices it every step and a sharded
  scan axis would turn each slice into a collective.
* **batches** — leading (global-batch) dim over ``data`` (and ``pod`` when
  multi-pod): pure data parallelism, everything else replicated.
* **caches** — stacked decode caches are ``(L, batch, seq, ...)``: batch dim
  over ``data``.  Long-context cells (batch=1) cannot data-shard the batch,
  so ``seq_shard_fallback`` shards the sequence axis instead (ring-attention
  style placement; the seed's 500k cells fit only this way).

Divisibility is checked against the *production* sizes even on smaller host
meshes: a dim divisible by 16 is divisible by every power of two below it,
and jit/GSPMD tolerates the (never exercised) uneven remainder cases.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "AXIS_SIZES",
    "param_pspecs",
    "batch_pspecs",
    "cache_pspecs",
    "gather_for_compute",
    "batch_sharding",
    "batch_pad",
]


#: Production mesh axis sizes — the divisibility contract for all specs.
AXIS_SIZES: dict[str, int] = {"pod": 2, "data": 16, "model": 16}

#: Pytree keys whose immediate children are layer stacks iterated by
#: ``lax.scan`` — their leading depth axis must never be sharded.
_STACKED_KEYS = frozenset(
    {"layers", "groups", "tail", "blocks", "enc_layers", "dec_layers"}
)


def _axis_divisor(ax) -> int:
    axes = ax if isinstance(ax, tuple) else (ax,)
    return math.prod(AXIS_SIZES[a] for a in axes)


def _dim_divides(dim: int, ax) -> bool:
    return dim % _axis_divisor(ax) == 0


def _leaf_param_spec(shape: tuple, *, stacked: bool) -> P:
    """Model/data assignment for one parameter leaf (see module docstring)."""
    nd = len(shape)
    if nd < 2:
        return P()
    axes: list = [None] * nd
    first = 1 if stacked else 0  # protect the scan depth axis

    # tensor-parallel axis: rightmost divisible dim
    for i in (nd - 1, nd - 2):
        if i >= first and _dim_divides(shape[i], "model"):
            axes[i] = "model"
            break
    # FSDP/data axis: rightmost remaining divisible dim
    for i in range(nd - 1, first - 1, -1):
        if axes[i] is None and _dim_divides(shape[i], "data"):
            axes[i] = "data"
            break
    while axes and axes[-1] is None:
        axes.pop()
    return P(*axes)


def param_pspecs(tree):
    """PartitionSpecs for a parameter pytree (arrays or ShapeDtypeStructs).

    Structure-preserving: ``jax.tree.map(NamedSharding(mesh, .), specs)``
    composes with ``jit(in_shardings=...)``; ``train.elastic.reshard`` uses
    the same specs for any mesh shape the elastic planner
    (``train.elastic.plan_mesh``) picks on restart.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = []
    for path, leaf in flat:
        keys = {getattr(p, "key", None) for p in path}
        specs.append(
            _leaf_param_spec(tuple(leaf.shape), stacked=bool(keys & _STACKED_KEYS))
        )
    return jax.tree_util.tree_unflatten(treedef, specs)


def _data_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else "data"


def batch_pspecs(batch, *, multi_pod: bool = False):
    """Data-parallel specs for an input batch: leading dim over ``data``
    (plus ``pod`` when multi-pod), everything else replicated."""
    ax = _data_axes(multi_pod)

    def spec(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        return P(ax, *([None] * (nd - 1)))

    return jax.tree.map(spec, batch)


def cache_pspecs(
    cache,
    *,
    multi_pod: bool = False,
    long_context: bool = False,
    seq_shard_fallback: bool = True,
):
    """Specs for stacked decode caches / recurrent states ``(L, batch, ...)``.

    Default: batch axis over ``data``.  ``long_context`` (batch=1) cells
    shard the largest trailing axis (the sequence) instead when
    ``seq_shard_fallback`` — otherwise the cache replicates.
    """
    ax = _data_axes(multi_pod)

    def spec(leaf):
        shape = tuple(leaf.shape)
        nd = len(shape)
        if nd < 2:
            return P()
        bdim = 1 if nd >= 3 else 0  # leading axis is the layer stack
        axes: list = [None] * nd
        if not long_context and _dim_divides(shape[bdim], ax):
            axes[bdim] = ax
        elif long_context and seq_shard_fallback and nd > bdim + 1:
            sdim = max(range(bdim + 1, nd), key=lambda i: shape[i])
            if _dim_divides(shape[sdim], ax):
                axes[sdim] = ax
        while axes and axes[-1] is None:
            axes.pop()
        return P(*axes)

    return jax.tree.map(spec, cache)


def gather_for_compute(params, compute_dtype):
    """ZeRO-3 gather-at-use: cast to the compute dtype and constrain every
    leaf to replicated, so XLA all-gathers FSDP-sharded weights right where
    they are consumed (and frees them after).  No-op outside a mesh context.
    """
    cd = jnp.dtype(compute_dtype)

    def g(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            x = x.astype(cd)
        try:
            return jax.lax.with_sharding_constraint(x, P())
        except (ValueError, RuntimeError):
            return x

    return jax.tree.map(g, params)


# ---------------------------------------------------------------------------
# Batch-axis helpers for the engine / serve layers
# (moved here from launch.mesh — repro.dist is the one sharding home).
# ---------------------------------------------------------------------------


def batch_sharding(mesh, axis: str = "data") -> NamedSharding:
    """Sharding that splits a leading batch axis over one mesh axis.

    This is what ``core.engine.SvdEngine`` / ``serve.svd_service`` take to
    spread a flush of B stacked rank-1 updates across the data axis: batch
    dim sharded, every per-update dim replicated.
    """
    return NamedSharding(mesh, P(axis))


def batch_pad(b: int, mesh, axis: str = "data") -> int:
    """Rows of padding needed to make a batch of ``b`` divisible by the mesh
    axis (batched updates pad with no-op rank-1 pairs, results discarded)."""
    k = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    return (-b) % k
