"""Numerical-health probes + watchdog (DESIGN.md §15).

The paper's O(n² log(1/ε)) update is only as trustworthy as its ε.  These
probes compute, from factors the serving path already holds (no dense
reconstruction, no reference SVD):

* ``ortho_drift``        max(‖UᵀU−I‖_max, ‖VᵀV−I‖_max) — the phase-chain's
                         orthogonality loss, the leading indicator of a
                         degrading sketch.
* ``deflation_fraction`` fraction of secular coordinates whose coupling
                         z_i = (Uᵀa)_i (Vᵀb)_i falls under the deflation
                         tolerance — how much of each update the solver
                         short-circuits (high values mean the stream is
                         nearly in-span; near-zero means every coordinate
                         pays the full secular solve).
* ``secular_residual``   max_i |(U₁ᵀ(U₀S₀V₀ᵀ + abᵀ)V₁)_ii − s₁_i| / s₁_max —
                         the updated triplet's own eigen-residual, computed
                         factored in O((m+n)r²).
* ``bf16_headroom``      BF16_ERROR_BUDGET["sigma_rel"] minus the measured
                         drift floor (storage-dtype quantization of the
                         current spectrum, or ortho drift if larger).
                         Positive = inside budget; ≤ 0 trips the watchdog.

Every probe is a separate jitted function over the SAME arrays the service
just flushed — probes never run inside the update's own traced path, so
enabling them cannot change update jaxprs or results.  ``HealthMonitor``
samples every N flushes (the ``UpdatePolicy.health_every`` knob), publishes
gauges into the metrics registry, and raises ``HealthWarning`` (plus a
``health_warnings_total`` counter) when a threshold trips.
"""

from __future__ import annotations

import threading
import warnings
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.obs import metrics as _metrics

__all__ = [
    "DEFAULT_THRESHOLDS",
    "HealthReport",
    "HealthMonitor",
    "HealthWarning",
    "ortho_drift",
    "probe_state",
    "probe_update",
]


class HealthWarning(RuntimeWarning):
    """A numerical-health gauge crossed its configured threshold."""


class HealthReport(NamedTuple):
    """One sample of the health gauges (host floats, registry-ready)."""

    ortho_drift: float
    deflation_fraction: float
    secular_residual: float
    bf16_headroom: float


# Gauges are "worse when larger" except bf16_headroom ("worse when smaller").
# Defaults are deliberately loose — they flag broken states (a drifted
# sketch, a budget blow-through), not working-precision noise.  f32 serving
# sits around 1e-6 drift; f64 around 1e-14.
DEFAULT_THRESHOLDS = {
    "ortho_drift": 1e-3,
    "secular_residual": 1e-3,
    "bf16_headroom": 0.0,        # lower bound: warn at/below zero headroom
}
_LOWER_IS_BAD = frozenset({"bf16_headroom"})

# sigma_rel budget for bf16 storage (kernels.fused_update pins the table;
# imported lazily so repro.obs does not pull the Pallas stack at import).
_BF16_SIGMA_BUDGET = 5e-2


@jax.jit
def _ortho_drift_impl(u, v):
    uf = u.astype(jnp.float32) if u.dtype.itemsize <= 2 else u
    vf = v.astype(jnp.float32) if v.dtype.itemsize <= 2 else v
    r_u = jnp.eye(uf.shape[1], dtype=uf.dtype) - uf.T @ uf
    r_v = jnp.eye(vf.shape[1], dtype=vf.dtype) - vf.T @ vf
    return jnp.maximum(jnp.max(jnp.abs(r_u)), jnp.max(jnp.abs(r_v)))


def ortho_drift(u, v) -> jax.Array:
    """max(‖UᵀU−I‖_max, ‖VᵀV−I‖_max) for one state's factors (jitted)."""
    return _ortho_drift_impl(u, v)


@jax.jit
def _probe_update_impl(u0, s0, v0, a, b, u1, s1, v1, rtol):
    cd = jnp.float32 if u0.dtype.itemsize <= 2 else u0.dtype
    u0f, v0f, s0f = u0.astype(cd), v0.astype(cd), s0.astype(cd)
    u1f, v1f, s1f = u1.astype(cd), v1.astype(cd), s1.astype(cd)
    af, bf = a.astype(cd), b.astype(cd)

    drift = _ortho_drift_impl(u1, v1)

    # deflation coupling on the pre-update basis: z_i = (U0^T a)_i (V0^T b)_i
    z = (u0f.T @ af) * (v0f.T @ bf)
    zmax = jnp.max(jnp.abs(z))
    tiny = jnp.asarray(jnp.finfo(cd).tiny, cd)
    hits = jnp.abs(z) <= rtol * (zmax + tiny)
    defl = jnp.mean(hits.astype(cd))

    # factored eigen-residual of the updated triplet:
    #   C = U1^T (U0 diag(s0) V0^T + a b^T) V1   (O((m+n) r^2), never dense)
    core = ((u1f.T @ u0f) * s0f[None, :]) @ (v0f.T @ v1f) \
        + jnp.outer(u1f.T @ af, bf @ v1f)
    smax = jnp.max(s1f) + tiny
    resid = jnp.max(jnp.abs(jnp.diagonal(core) - s1f)) / smax

    # bf16 headroom: budget minus the measured drift floor — storage-dtype
    # quantization of the current spectrum, or ortho drift if larger.
    quant = jnp.max(jnp.abs(s1f - s1.astype(u1.dtype).astype(cd))) / smax
    headroom = _BF16_SIGMA_BUDGET - jnp.maximum(quant, drift.astype(cd))

    return drift, defl, resid, headroom


def probe_update(u0, s0, v0, a, b, u1, s1, v1, *,
                 deflate_rtol: float | None = None) -> HealthReport:
    """Full health sample around one applied update.

    ``(u0, s0, v0)`` is the state the rank-1 pair ``(a, b)`` was applied to,
    ``(u1, s1, v1)`` the result.  One jitted call (cached per geometry);
    returns host floats.
    """
    if deflate_rtol is None:
        cd = jnp.float32 if jnp.dtype(u0.dtype).itemsize <= 2 else u0.dtype
        deflate_rtol = 64.0 * float(jnp.finfo(cd).eps)
    drift, defl, resid, headroom = _probe_update_impl(
        u0, s0, v0, a, b, u1, s1, v1, jnp.asarray(deflate_rtol))
    return HealthReport(float(drift), float(defl), float(resid),
                        float(headroom))


def probe_state(u, s, v) -> HealthReport:
    """Health sample from a bare state (no update pair in hand): ortho
    drift + quantization headroom; deflation/secular gauges report 0."""
    drift = float(_ortho_drift_impl(u, v))
    cd = jnp.float32 if jnp.dtype(u.dtype).itemsize <= 2 else jnp.dtype(u.dtype)
    sf = s.astype(cd)
    smax = float(jnp.max(sf)) or 1.0
    quant = float(jnp.max(jnp.abs(sf - s.astype(u.dtype).astype(cd)))) / smax
    return HealthReport(drift, 0.0, 0.0,
                        _BF16_SIGMA_BUDGET - max(quant, drift))


class HealthMonitor:
    """Samples health probes every N flushes and publishes gauges.

    ``every=N`` sets the cadence (``maybe_sample`` fires on every Nth
    tick); ``thresholds`` maps gauge name → limit (above = bad, except
    ``bf16_headroom`` where below = bad).  A trip raises ``HealthWarning``
    via ``warnings.warn`` and bumps ``health_warnings_total{probe=...}``.
    """

    def __init__(self, *, every: int = 1, thresholds: dict | None = None,
                 registry: "_metrics.MetricsRegistry | None" = None,
                 **labels):
        if every < 1:
            raise ValueError(f"health_every must be >= 1; got {every}")
        self.every = every
        self.thresholds = dict(DEFAULT_THRESHOLDS if thresholds is None
                               else thresholds)
        self.labels = labels
        self._registry = registry
        self._ticks = 0
        self._lock = threading.Lock()
        self.last: HealthReport | None = None

    @property
    def registry(self) -> "_metrics.MetricsRegistry":
        return self._registry if self._registry is not None else _metrics.registry()

    def due(self) -> bool:
        """Advance the flush tick; True when this tick should sample."""
        with self._lock:
            self._ticks += 1
            return self._ticks % self.every == 0

    def record(self, report: HealthReport) -> HealthReport:
        """Publish one report as gauges and run the watchdog."""
        reg = self.registry
        for name, value in report._asdict().items():
            reg.gauge(f"health_{name}", **self.labels).set(value)
            limit = self.thresholds.get(name)
            if limit is None:
                continue
            bad = value <= limit if name in _LOWER_IS_BAD else value >= limit
            if bad:
                reg.counter("health_warnings_total", probe=name,
                            **self.labels).inc()
                warnings.warn(
                    f"health watchdog: {name}={value:.3e} crossed "
                    f"threshold {limit:.3e}", HealthWarning, stacklevel=3)
        self.last = report
        return report

    def sample_update(self, u0, s0, v0, a, b, u1, s1, v1, *,
                      deflate_rtol: float | None = None) -> HealthReport:
        return self.record(probe_update(u0, s0, v0, a, b, u1, s1, v1,
                                        deflate_rtol=deflate_rtol))

    def sample_state(self, u, s, v) -> HealthReport:
        return self.record(probe_state(u, s, v))
