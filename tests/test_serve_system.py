"""System behaviour: serving engine + end-to-end training loop with resume."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import OptimizerConfig, RunConfig
from repro.models.registry import build_model
from repro.serve.engine import ServeConfig, generate
from repro.train import checkpoint as ckpt
from repro.train.loop import train

RNG = np.random.default_rng(0)


def test_generate_greedy_deterministic():
    cfg = configs.get_smoke("granite-34b")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    prompts = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    out1 = generate(api, params, prompts, ServeConfig(max_new_tokens=6))
    out2 = generate(api, params, prompts, ServeConfig(max_new_tokens=6))
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 6)
    assert int(jnp.max(out1)) < cfg.padded_vocab


def test_generate_matches_teacher_forcing():
    """Greedy generation must equal argmax of the full forward at each step."""
    from repro.models.transformer import decoder_forward

    cfg = configs.get_smoke("qwen2-72b")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(3))
    prompts = jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
    gen = np.asarray(generate(api, params, prompts, ServeConfig(max_new_tokens=4)))

    seq = np.asarray(prompts)
    for i in range(4):
        logits = decoder_forward(params, {"tokens": jnp.asarray(seq)}, cfg)
        nxt = int(jnp.argmax(logits[0, -1]))
        assert nxt == int(gen[0, i]), f"token {i}: engine {gen[0, i]} vs forward {nxt}"
        seq = np.concatenate([seq, [[nxt]]], axis=1)


def _tiny_run(tmp_path, steps, arch="granite-34b", ckpt_every=5):
    cfg = configs.get_smoke(arch)
    run = RunConfig(
        model=cfg,
        optimizer=OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=100),
        steps=steps,
        log_every=100,
        checkpoint_every=ckpt_every,
        checkpoint_dir=str(tmp_path),
        seed=7,
    )
    return train(run, batch_size=4, seq_len=32)


def test_train_loop_loss_decreases(tmp_path):
    res = _tiny_run(tmp_path / "a", steps=30)
    first = res.losses[0][1]
    last = res.losses[-1][1]
    assert last < first - 0.1, f"loss did not decrease: {first} -> {last}"


def test_train_resume_bit_exact(tmp_path):
    """Train 20 straight vs 10 + crash + resume 10 — identical final loss."""
    res_full = _tiny_run(tmp_path / "full", steps=20, ckpt_every=50)

    # interrupted run: 10 steps, checkpoint, then "restart" the loop
    res_a = _tiny_run(tmp_path / "resume", steps=10, ckpt_every=10)
    assert res_a.final_step == 10
    res_b = _tiny_run(tmp_path / "resume", steps=20, ckpt_every=10)
    assert res_b.resumed_from == 10

    np.testing.assert_allclose(res_full.losses[-1][1], res_b.losses[-1][1],
                               rtol=1e-5)


def test_elastic_remesh_roundtrip(tmp_path):
    """Checkpoint on one 'mesh', restore and reshard on another (1-device)."""
    from repro.train.elastic import plan_mesh, reshard

    cfg = configs.get_smoke("qwen1.5-32b")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    ckpt.save(tmp_path, 1, params)
    _, restored = ckpt.restore(tmp_path, params)
    mesh = plan_mesh(max_model=1)
    placed = reshard(restored, mesh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(placed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
