"""``repro.api.update`` / ``update_many`` — the single entry point for every
rank-1 SVD update path (DESIGN.md §8).

Dispatch is a pure function of *state geometry + policy*:

    state.is_full   state.is_batched   policy.mesh     route
    -------------   ----------------   -----------     ------------------------------
    yes             no                 (ignored)       engine.update            (single)
    yes             yes                None            engine.update_batch      (vmap)
    yes             yes                Mesh            shard_map'd batched update
    no              no                 (ignored)       engine.update_truncated  (Brand)
    no              yes                None            engine.update_truncated_batch
    no              yes                Mesh            shard_map'd truncated batch

All routes resolve to shared plan-cached ``core.engine.SvdEngine``
executables (``default_engine`` keyed by the policy's numerics fields), so
policy-equal calls never recompile and every route is bit-identical to the
engine executable it resolves to (golden-pinned in
``tests/test_api_compat.py``).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.api.policy import UpdatePolicy
from repro.api.state import SvdState, as_state
from repro.core.engine import (
    SvdEngine,
    default_engine,
    group_indices,
    stack_trees,
    unstack_tree,
)
from repro.core.svd_update import TruncatedSvd

__all__ = ["engine_for", "update", "update_many", "update_rank_k", "warmup"]

_DEFAULT_POLICY = UpdatePolicy()


def engine_from_key(policy: UpdatePolicy, problem_n: int, *,
                    m: int | None = None, n: int | None = None,
                    rank: int | None = None) -> SvdEngine:
    """The ONE place a policy's ``engine_key`` unpacks into ``default_engine``
    — every layer (api, dist.merge, serve) resolves through here, so the
    shared-plan-cache invariant ("equal policies never recompile") has a
    single definition.  The optional geometry lets ``method="auto"`` prefer
    the fused megakernel when the problem fits its VMEM budget.  The key's
    trailing sketch fields (oversample, power_iters) key the planner's
    schedule cache, not the engine — the rank-1 executables are
    sketch-independent, so they are dropped here."""
    (method, fmm_p, sign_fix, deflate_rtol, precision, storage_dtype,
     _sketch_os, _sketch_pi) = policy.engine_key(problem_n, m=m, n=n, rank=rank)
    return default_engine(
        method,
        fmm_p=fmm_p,
        sign_fix=sign_fix,
        deflate_rtol=deflate_rtol,
        precision=precision,
        storage_dtype=storage_dtype,
    )


def engine_for(policy: UpdatePolicy, state: SvdState) -> SvdEngine:
    """The shared plan-cached engine a (policy, state-geometry) pair runs on.

    Two equal policies — or any two callers with the same numerics knobs —
    return the SAME engine instance, hence one plan cache:

    >>> import numpy as np
    >>> from repro import api
    >>> st = api.SvdState.from_dense(np.eye(4, 6), rank=2)
    >>> pol = api.UpdatePolicy(method="direct")
    >>> api.engine_for(pol, st) is api.engine_for(pol.replace(truncate_to=2), st)
    True
    """
    if state.is_full:
        return engine_from_key(policy, state.n, m=state.m, n=state.n)
    return engine_from_key(policy, state.rank + 1, m=state.m, n=state.n,
                           rank=state.rank)


def _apply_storage_dtype(policy: UpdatePolicy, st: SvdState, a, b):
    """Cast state + perturbation to the policy's storage dtype (bf16 mode).

    The cast IS the policy: engine geometry keys then carry the narrow
    dtype, and the engine's compute_dtype upcasts inside the update."""
    if policy.storage_dtype is None:
        return st, a, b
    dt = jnp.dtype(policy.storage_dtype)
    if st.dtype == dt:
        return st, jnp.asarray(a, dt), jnp.asarray(b, dt)
    st = SvdState(
        u=st.u.astype(dt), s=st.s.astype(dt), v=st.v.astype(dt),
        d_left=None if st.d_left is None else st.d_left.astype(dt),
        d_right=None if st.d_right is None else st.d_right.astype(dt),
        mesh=st.mesh,
    )
    return st, jnp.asarray(a, dt), jnp.asarray(b, dt)


def _finish(state: SvdState, out: SvdState, policy: UpdatePolicy) -> SvdState:
    if policy.truncate_to is not None and policy.truncate_to < out.rank:
        out = out.truncate(policy.truncate_to)
    return out


def update(state, a, b, policy: UpdatePolicy | None = None) -> SvdState:
    """SVD of ``state + a b^T`` under ``policy`` — full, truncated, single or
    stacked, local or mesh-sharded, decided by geometry (module doc table).

    ``state``: any SVD container (``SvdState`` preferred; ``TruncatedSvd`` /
    ``SvdUpdateResult`` / ``(u, s, v)`` are coerced).  ``a``: (..., m),
    ``b``: (..., n), with the leading batch axis iff the state is stacked.
    Returns an ``SvdState`` (full states keep eigen diagnostics).

    >>> import numpy as np
    >>> from repro import api
    >>> rng = np.random.default_rng(0)
    >>> x = rng.normal(size=(4, 6))
    >>> st = api.SvdState.from_dense(x)               # full paper state
    >>> a, b = rng.normal(size=4), rng.normal(size=6)
    >>> out = api.update(st, a, b, api.UpdatePolicy(method="direct"))
    >>> out.shape, out.rank
    ((4, 6), 4)
    >>> ref = np.linalg.svd(x + np.outer(a, b), compute_uv=False)
    >>> bool(np.allclose(out.s, ref, atol=1e-10))     # matches a fresh SVD
    True

    The same entry point runs the truncated streaming route when the state
    is truncated — geometry picks the dispatch:

    >>> tr = api.SvdState.from_dense(x, rank=2)
    >>> api.update(tr, a, b).rank                     # default policy
    2
    """
    policy = policy if policy is not None else _DEFAULT_POLICY
    st = as_state(state)
    st, a, b = _apply_storage_dtype(policy, st, a, b)
    eng = engine_for(policy, st)
    mesh = policy.mesh if policy.mesh is not None else st.mesh
    if st.is_full:
        if st.is_batched:
            res = eng.update_batch(st.u, st.s, st.v, a, b, mesh=mesh,
                                   batch_axis=policy.batch_axis)
        else:
            res = eng.update(st.u, st.s, st.v, a, b)
        out = SvdState(u=res.u, s=res.s, v=res.v, d_left=res.d_left,
                       d_right=res.d_right, mesh=st.mesh)
    else:
        t = TruncatedSvd(u=st.u, s=st.s, v=st.v)
        if st.is_batched:
            t2 = eng.update_truncated_batch(t, a, b, mesh=mesh,
                                            batch_axis=policy.batch_axis)
        else:
            t2 = eng.update_truncated(t, a, b)
        out = SvdState(u=t2.u, s=t2.s, v=t2.v, mesh=st.mesh)
    return _finish(st, out, policy)


def update_many(
    states: Sequence,
    A,
    B,
    policy: UpdatePolicy | None = None,
) -> tuple[SvdState, ...]:
    """Many independent rank-1 updates in as few engine calls as possible.

    ``states[i]`` absorbs ``A[i] B[i]^T``.  States sharing a geometry
    ``(m, n, rank, dtype, fullness)`` are stacked along a batch axis and
    dispatched as ONE batched (possibly mesh-sharded) call through
    ``update``; results come back unstacked, in input order.  This is the
    generalized form of the grouped-update loops optim/serve carried by
    hand.

    >>> import numpy as np
    >>> from repro import api
    >>> rng = np.random.default_rng(1)
    >>> sts = [api.SvdState.from_dense(rng.normal(size=(4, 5)), rank=2)
    ...        for _ in range(3)]
    >>> A = [rng.normal(size=4) for _ in range(3)]
    >>> B = [rng.normal(size=5) for _ in range(3)]
    >>> outs = api.update_many(sts, A, B)             # one batched engine call
    >>> len(outs), outs[0].rank
    (3, 2)
    """
    policy = policy if policy is not None else _DEFAULT_POLICY
    sts = [as_state(s) for s in states]
    if len(sts) != len(A) or len(sts) != len(B):
        raise ValueError(
            f"states/A/B must pair up: {len(sts)} states, {len(A)} a-vectors, "
            f"{len(B)} b-vectors"
        )
    for i, st in enumerate(sts):
        if st.is_batched:
            raise ValueError(
                f"update_many takes unbatched states; state {i} is stacked "
                f"(u {st.u.shape}) — call update() on it directly"
            )

    out: list[SvdState | None] = [None] * len(sts)
    for idxs in group_indices([st.geometry for st in sts]).values():
        if len(idxs) == 1:
            i = idxs[0]
            out[i] = update(sts[i], A[i], B[i], policy)
            continue
        # drop diagnostics before stacking: members may differ in whether
        # they carry d_left/d_right, and batched dispatch recomputes them
        stacked = stack_trees(
            [SvdState(u=sts[i].u, s=sts[i].s, v=sts[i].v) for i in idxs]
        )
        a_stack = jnp.stack([jnp.asarray(A[i]) for i in idxs])
        b_stack = jnp.stack([jnp.asarray(B[i]) for i in idxs])
        batched = update(stacked, a_stack, b_stack, policy)
        for j, i in enumerate(idxs):
            out[i] = unstack_tree(batched, j).replace(mesh=sts[i].mesh)
    return tuple(out)


def update_rank_k(state, A, B, policy: UpdatePolicy | None = None) -> SvdState:
    """SVD of ``state + A^T B`` applied as k sequential rank-1 updates through
    ONE ``lax.scan`` — trace/compile cost is k-independent (the hot path for
    long ``repro.updates`` schedules; ``updates.planner`` lowers k >=
    ``_SCAN_MIN`` schedules here).

    ``A``: (k, m) rows of left vectors, ``B``: (k, n) rows of right vectors
    (leading batch axis before k iff the state is stacked).  ``truncate_to``
    falls back to the unrolled per-pair path (the rule must re-apply between
    pairs, which a scan carry of fixed rank cannot express).

    >>> import numpy as np
    >>> from repro import api
    >>> rng = np.random.default_rng(2)
    >>> x = rng.normal(size=(4, 6))
    >>> st = api.SvdState.from_dense(x)
    >>> A = rng.normal(size=(3, 4)); B = rng.normal(size=(3, 6))
    >>> out = api.update_rank_k(st, A, B, api.UpdatePolicy(method="direct"))
    >>> ref = np.linalg.svd(x + A.T @ B, compute_uv=False)
    >>> bool(np.allclose(out.s, ref, atol=1e-9))
    True
    """
    policy = policy if policy is not None else _DEFAULT_POLICY
    st = as_state(state)
    if policy.truncate_to is not None and policy.truncate_to < st.rank:
        out = st
        k = jnp.asarray(A).shape[-2]
        for i in range(k):
            out = update(out, jnp.asarray(A)[..., i, :], jnp.asarray(B)[..., i, :],
                         policy)
        return out
    st, A, B = _apply_storage_dtype(policy, st, A, B)
    eng = engine_for(policy, st)
    mesh = policy.mesh if policy.mesh is not None else st.mesh
    if st.is_full:
        if st.is_batched:
            res = eng.update_rank_k_batch(st.u, st.s, st.v, A, B, mesh=mesh,
                                          batch_axis=policy.batch_axis)
        else:
            res = eng.update_rank_k(st.u, st.s, st.v, A, B)
        out = SvdState(u=res.u, s=res.s, v=res.v, d_left=res.d_left,
                       d_right=res.d_right, mesh=st.mesh)
    else:
        t = TruncatedSvd(u=st.u, s=st.s, v=st.v)
        if st.is_batched:
            t2 = eng.update_truncated_rank_k_batch(t, A, B, mesh=mesh,
                                                   batch_axis=policy.batch_axis)
        else:
            t2 = eng.update_truncated_rank_k(t, A, B)
        out = SvdState(u=t2.u, s=t2.s, v=t2.v, mesh=st.mesh)
    return _finish(st, out, policy)


def warmup(
    policy: UpdatePolicy,
    *,
    m: int,
    n: int,
    batch: int | None = None,
    rank: int | None = None,
    k: int | None = None,
    dtype=jnp.float32,
    cache_dir=None,
):
    """AOT-compile the executable a (policy, geometry) pair will use, before
    traffic arrives (serving cold-start control).  ``rank=None`` warms the
    full route, else the truncated one; ``batch=None`` warms single-instance;
    ``k`` warms the rank-k scan route.  With ``policy.storage_dtype`` set the
    warmed geometry uses the storage dtype (what real casts will carry).

    ``cache_dir`` additionally persists the compiled binaries in the XLA
    compilation cache (``api.enable_compilation_cache``): a LATER process
    warming the same (policy, geometry) replays them from disk instead of
    recompiling — warmup survives restarts.

    >>> import jax.numpy as jnp
    >>> from repro import api
    >>> pol = api.UpdatePolicy(method="direct")
    >>> info = api.warmup(pol, m=4, n=5, rank=2, dtype=jnp.float64)
    >>> info.entries >= 1          # the (policy, geometry) plan is cached
    True
    """
    if cache_dir is not None:
        from repro.api.cache import enable_compilation_cache

        enable_compilation_cache(cache_dir)
    if policy.storage_dtype is not None:
        dtype = policy.storage_dtype
    eng = engine_from_key(policy, n if rank is None else rank + 1,
                          m=m, n=n, rank=rank)
    return eng.warmup(batch=batch, m=m, n=n, rank=rank, k=k, dtype=dtype)
