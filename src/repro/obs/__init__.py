"""``repro.obs`` — unified telemetry: metrics, span tracing, health probes.

The observability layer for the whole stack (DESIGN.md §15).  Three parts:

* :mod:`repro.obs.metrics` — process-global ``MetricsRegistry`` of typed
  counters/gauges/histograms with JSON + Prometheus-text exporters and
  per-shard label aggregation.
* :mod:`repro.obs.trace` — nestable, thread-safe span tracing on the
  monotonic clock, emitting Chrome ``trace_event`` JSON
  (``chrome://tracing`` / Perfetto).
* :mod:`repro.obs.health` — jit-compatible numerical-health probes
  (orthogonality drift, deflation fraction, secular residual, bf16
  headroom) with a sampling monitor + threshold watchdog.

Everything is OFF by default and the disabled path is free: library
instrumentation sites guard on ``obs.enabled()`` (one module-flag read),
``span()`` returns a shared no-op when tracing is off, and nothing ever
records from inside a traced function — update results and jaxprs are
bitwise-independent of the obs state.

Quickstart::

    from repro import obs

    obs.enable()                 # metrics on
    obs.start_tracing()          # spans on
    ... run traffic ...
    print(obs.registry().to_prometheus())
    obs.save_chrome_trace("trace.json")
"""

from __future__ import annotations

from repro.obs.health import (
    DEFAULT_THRESHOLDS,
    HealthMonitor,
    HealthReport,
    HealthWarning,
    ortho_drift,
    probe_state,
    probe_update,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    registry,
    set_registry,
)
from repro.obs.trace import (
    chrome_trace,
    clear_trace,
    save_chrome_trace,
    span,
    start_tracing,
    stop_tracing,
    trace_events,
    tracing,
)

__all__ = [
    "enabled",
    "enable",
    "disable",
    # metrics
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "registry",
    "set_registry",
    # trace
    "span",
    "start_tracing",
    "stop_tracing",
    "tracing",
    "trace_events",
    "clear_trace",
    "chrome_trace",
    "save_chrome_trace",
    # health
    "DEFAULT_THRESHOLDS",
    "HealthMonitor",
    "HealthReport",
    "HealthWarning",
    "ortho_drift",
    "probe_state",
    "probe_update",
]

_enabled = False


def enabled() -> bool:
    """Whether metric recording is on (the single hot-path gate)."""
    return _enabled


def enable() -> None:
    """Turn metric recording on (tracing is a separate switch)."""
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False
