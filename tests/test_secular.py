"""Secular solver + deflation + Loewner weights vs numpy oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.secular import deflate, loewner_zhat, secular_solve

RNG = np.random.default_rng(42)


def _solve_sorted(d, z, rho):
    dj, zj = jnp.asarray(d), jnp.asarray(z)
    defl = deflate(dj, zj, jnp.asarray(rho))
    dc = dj[defl.compact]
    zc = defl.z[defl.compact]
    roots = secular_solve(dc, zc, jnp.asarray(rho), defl.n_keep)
    mu = np.asarray(jnp.sort(jnp.where(roots.valid, roots.mu, dc)))
    return mu, defl, roots, np.asarray(dc)


@pytest.mark.parametrize("n", [4, 17, 64, 256])
def test_eigenvalues_match_numpy(n):
    d = np.sort(RNG.uniform(-3, 3, n))
    z = RNG.normal(size=n)
    rho = abs(RNG.normal()) + 0.1
    ref = np.linalg.eigvalsh(np.diag(d) + rho * np.outer(z, z))
    mu, *_ = _solve_sorted(d, z, rho)
    np.testing.assert_allclose(mu, ref, rtol=0, atol=1e-12 * max(1, np.abs(ref).max()))


def test_duplicate_poles_deflate():
    n = 60
    d = np.sort(RNG.uniform(0, 1, n))
    d[10:25] = d[10]  # multiplicity 15
    z = RNG.normal(size=n)
    rho = 0.5
    ref = np.linalg.eigvalsh(np.diag(d) + rho * np.outer(z, z))
    mu, defl, _, _ = _solve_sorted(d, z, rho)
    assert int(defl.n_keep) <= n - 14  # 15 duplicates merge into 1 retained
    np.testing.assert_allclose(mu, ref, atol=1e-12)


def test_zero_z_entries_deflate():
    n = 40
    d = np.sort(RNG.uniform(0, 1, n))
    z = RNG.normal(size=n)
    z[::4] = 0.0
    rho = 1.3
    ref = np.linalg.eigvalsh(np.diag(d) + rho * np.outer(z, z))
    mu, defl, _, _ = _solve_sorted(d, z, rho)
    assert int(defl.n_keep) == n - len(z[::4])
    np.testing.assert_allclose(mu, ref, atol=1e-12)


def test_interlacing_exact():
    """For rho > 0: d_i < mu_i < d_{i+1} (strict, on the retained set)."""
    n = 100
    d = np.sort(RNG.uniform(-1, 1, n))
    z = RNG.normal(size=n) + 0.1
    rho = 0.7
    dj, zj = jnp.asarray(d), jnp.asarray(z)
    defl = deflate(dj, zj, jnp.asarray(rho))
    dc = np.asarray(dj[defl.compact])
    zc = defl.z[defl.compact]
    roots = secular_solve(jnp.asarray(dc), zc, jnp.asarray(rho), defl.n_keep)
    k = int(defl.n_keep)
    mu = np.asarray(roots.mu)[:k]
    assert np.all(mu > dc[:k])
    upper = np.append(dc[1:k], dc[k - 1] + rho * float(jnp.sum(zc[:k] ** 2)) + 1e-12)
    assert np.all(mu <= upper)


def test_loewner_orthogonality_weights():
    """zhat from the computed roots reproduces the exact char-poly identity."""
    n = 50
    d = np.sort(RNG.uniform(0, 2, n))
    z = RNG.normal(size=n)
    rho = 0.9
    dj, zj = jnp.asarray(d), jnp.asarray(z)
    defl = deflate(dj, zj, jnp.asarray(rho))
    dc = dj[defl.compact]
    zc = defl.z[defl.compact]
    roots = secular_solve(dc, zc, jnp.asarray(rho), defl.n_keep)
    zhat = np.asarray(loewner_zhat(dc, zc, jnp.asarray(rho), roots))
    np.testing.assert_allclose(np.abs(zhat), np.abs(np.asarray(zc)), rtol=1e-8)
    assert np.all(np.sign(zhat) == np.sign(np.asarray(zc)))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(3, 40),
    seed=st.integers(0, 2**31 - 1),
    rho=st.floats(0.01, 10.0),
)
def test_property_eigenvalues_any_spectrum(n, seed, rho):
    """Hypothesis: random spectra (incl. duplicates) match numpy to 1e-10."""
    rng = np.random.default_rng(seed)
    d = np.sort(rng.uniform(-5, 5, n))
    if n > 4 and seed % 3 == 0:
        d[n // 4 : n // 2] = d[n // 4]  # inject duplicates
    z = rng.normal(size=n)
    if seed % 2 == 0 and n > 2:
        z[seed % n] = 0.0
    ref = np.linalg.eigvalsh(np.diag(d) + rho * np.outer(z, z))
    mu, *_ = _solve_sorted(d, z, rho)
    scale = max(1.0, np.abs(ref).max())
    np.testing.assert_allclose(mu, ref, atol=5e-11 * scale)
