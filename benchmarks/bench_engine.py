"""Batched engine vs. Python-loop-of-updates throughput (DESIGN.md §4).

For B in {1, 8, 32, 128}: B independent rank-1 SVD updates of (m, n)
states, run (a) as a Python loop of plan-cached single `SvdEngine.update`
calls and (b) as ONE `SvdEngine.update_batch` call, plus the same comparison for the
rank-r streaming truncated update (the optimizer/serving hot path).

On top of the unfused (direct) route, each batch size gets fused-megakernel
cells (`method="fused"`, kernels.fused_update — the whole update resident
per batch element) and a bf16-storage fused cell (the mixed-precision mode,
DESIGN.md §11).  All speedups are against the SAME per-update direct loop
baseline, so fused-vs-unfused reads straight off the rows.

CSV rows (benchmarks/run.py style):
  bench_engine/<kind>/<method>/B=<b>,us,updates_per_s=... speedup=...

and a machine-readable summary at benchmarks/BENCH_engine.json.
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.engine import SvdEngine
from repro.core.svd_update import TruncatedSvd

BATCHES = [1, 8, 32, 128]
M, N = 32, 48          # full-update geometry
RANK = 8               # truncated-update geometry (tracker rank)
METHODS = ["direct"]   # kernel/fmm cost extra compile time; direct is the CPU path

OUT = Path(__file__).parent / "BENCH_engine.json"


def _full_problem(rng, b):
    us, ss, vs, as_, bs = [], [], [], [], []
    for _ in range(b):
        a_mat = rng.uniform(1, 9, (M, N))
        u, s, vt = np.linalg.svd(a_mat)
        us.append(u)
        ss.append(s)
        vs.append(vt.T)
        as_.append(rng.normal(size=M))
        bs.append(rng.normal(size=N))
    return tuple(jnp.asarray(np.stack(x)) for x in (us, ss, vs, as_, bs))


def _trunc_problem(rng, b):
    us = np.stack([np.linalg.qr(rng.normal(size=(M, RANK)))[0] for _ in range(b)])
    vs = np.stack([np.linalg.qr(rng.normal(size=(N, RANK)))[0] for _ in range(b)])
    ss = np.sort(np.abs(rng.normal(size=(b, RANK))), axis=1)[:, ::-1].copy()
    t = TruncatedSvd(jnp.asarray(us), jnp.asarray(ss), jnp.asarray(vs))
    a = jnp.asarray(rng.normal(size=(b, M)))
    bb = jnp.asarray(rng.normal(size=(b, N)))
    return t, a, bb


def run() -> dict:
    rng = np.random.default_rng(0)
    results: list[dict] = []

    fused_engine = SvdEngine(method="fused")
    fused_bf16_engine = SvdEngine(method="fused", storage_dtype=jnp.bfloat16)

    for method in METHODS:
        engine = SvdEngine(method=method)

        for b in BATCHES:
            u, s, v, a, bb = _full_problem(rng, b)

            def loop_full(u, s, v, a, bb):
                outs = [
                    engine.update(u[i], s[i], v[i], a[i], bb[i])
                    for i in range(b)
                ]
                return outs[-1].s

            def batch_full(u, s, v, a, bb):
                return engine.update_batch(u, s, v, a, bb).s

            us_loop = time_fn(loop_full, u, s, v, a, bb)
            us_batch = time_fn(batch_full, u, s, v, a, bb)
            row = {
                "kind": "full",
                "method": method,
                "batch": b,
                "m": M,
                "n": N,
                "us_loop": us_loop,
                "us_batch": us_batch,
                "updates_per_s_loop": b / (us_loop * 1e-6),
                "updates_per_s_batch": b / (us_batch * 1e-6),
                "speedup": us_loop / us_batch,
            }
            results.append(row)
            emit(
                f"bench_engine/full/{method}/B={b}",
                us_batch,
                f"updates_per_s={row['updates_per_s_batch']:.0f} speedup={row['speedup']:.2f}x",
            )

            # fused megakernel and bf16-storage fused, against the SAME
            # direct per-update loop baseline (fused-vs-unfused cells)
            for fm, feng, cast in (
                ("fused", fused_engine, lambda x: x),
                ("fused_bf16", fused_bf16_engine,
                 lambda x: x.astype(jnp.bfloat16)),
            ):
                fu, fs, fv, fa, fbb = (cast(x) for x in (u, s, v, a, bb))

                def batch_fused(fu, fs, fv, fa, fbb):
                    return feng.update_batch(fu, fs, fv, fa, fbb).s

                us_f = time_fn(batch_fused, fu, fs, fv, fa, fbb)
                row = {
                    "kind": "full",
                    "method": fm,
                    "batch": b,
                    "m": M,
                    "n": N,
                    "us_loop": us_loop,
                    "us_batch": us_f,
                    "updates_per_s_loop": b / (us_loop * 1e-6),
                    "updates_per_s_batch": b / (us_f * 1e-6),
                    "speedup": us_loop / us_f,
                }
                results.append(row)
                emit(
                    f"bench_engine/full/{fm}/B={b}",
                    us_f,
                    f"updates_per_s={row['updates_per_s_batch']:.0f} speedup={row['speedup']:.2f}x",
                )

            t, ta, tb = _trunc_problem(rng, b)

            def loop_trunc(t, ta, tb):
                outs = [
                    engine.update_truncated(
                        TruncatedSvd(t.u[i], t.s[i], t.v[i]), ta[i], tb[i]
                    )
                    for i in range(b)
                ]
                return outs[-1].s

            def batch_trunc(t, ta, tb):
                return engine.update_truncated_batch(t, ta, tb).s

            us_loop = time_fn(loop_trunc, t, ta, tb)
            us_batch = time_fn(batch_trunc, t, ta, tb)
            row = {
                "kind": "truncated",
                "method": method,
                "batch": b,
                "m": M,
                "n": N,
                "rank": RANK,
                "us_loop": us_loop,
                "us_batch": us_batch,
                "updates_per_s_loop": b / (us_loop * 1e-6),
                "updates_per_s_batch": b / (us_batch * 1e-6),
                "speedup": us_loop / us_batch,
            }
            results.append(row)
            emit(
                f"bench_engine/truncated/{method}/B={b}",
                us_batch,
                f"updates_per_s={row['updates_per_s_batch']:.0f} speedup={row['speedup']:.2f}x",
            )

            def batch_trunc_fused(t, ta, tb):
                return fused_engine.update_truncated_batch(t, ta, tb).s

            us_tf = time_fn(batch_trunc_fused, t, ta, tb)
            row = {
                "kind": "truncated",
                "method": "fused",
                "batch": b,
                "m": M,
                "n": N,
                "rank": RANK,
                "us_loop": us_loop,
                "us_batch": us_tf,
                "updates_per_s_loop": b / (us_loop * 1e-6),
                "updates_per_s_batch": b / (us_tf * 1e-6),
                "speedup": us_loop / us_tf,
            }
            results.append(row)
            emit(
                f"bench_engine/truncated/fused/B={b}",
                us_tf,
                f"updates_per_s={row['updates_per_s_batch']:.0f} speedup={row['speedup']:.2f}x",
            )

    summary = {
        "geometry": {"m": M, "n": N, "rank": RANK},
        "batches": BATCHES,
        "results": results,
    }
    OUT.write_text(json.dumps(summary, indent=2))
    return summary


if __name__ == "__main__":
    jax.config.update("jax_enable_x64", True)
    run()
