"""Paper Fig. 3: update error (Eq. 32) vs Chebyshev order p, n = 25 fixed.

Paper setup: 25x25 matrices, values U[0,1], error = Eq. 32. Deviation: our
TPU-native FMM only engages above the dense crossover (n >= 96; below it the
dispatcher uses the exact dense path and the error is p-independent at the
fp64 floor), so the sweep runs at n = 256 where the multipole expansions are
real. The paper's curve flattens near p = 20 at ~5e-2; ours floors at
~1.5e-7 — NOT FMM truncation (which is below the floor for p >= 12; at
p <= 8 the box capacity overflows on this sqrt-clustered spectrum and the
exact dense fallback engages) but the intrinsic A A^T *squaring floor* of
this algorithm family: eigen-gaps between clustered small squared singular
values are ~1e-5 of ||D||, so eigenvectors keep ~eps*||D||/gap ~ 1e-7
accuracy. Still >= 5 orders better than the paper's reported error.
CSV: fig3/p=<p>,us,<error>
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.engine import default_engine


def svd_update(u, s, v, a, b, *, method, fmm_p=20):
    return default_engine(method, fmm_p=fmm_p).update(u, s, v, a, b)

N = 256


def run() -> None:
    rng = np.random.default_rng(0)
    a_mat = rng.uniform(0, 1, size=(N, N))  # paper: values in [0,1] for Fig. 3
    a = rng.normal(size=N)
    b = rng.normal(size=N)
    u, s, vt = np.linalg.svd(a_mat)
    a_hat = a_mat + np.outer(a, b)
    smax = np.linalg.svd(a_hat, compute_uv=False)[0]

    args = (jnp.asarray(u), jnp.asarray(s), jnp.asarray(vt.T),
            jnp.asarray(a), jnp.asarray(b))
    for p in [4, 8, 12, 16, 20, 24, 28]:
        res = svd_update(*args, method="fmm", fmm_p=p)
        recon = np.asarray(res.u) @ np.diag(np.asarray(res.s)) @ np.asarray(res.v)[:, :N].T
        err = np.max(np.abs(a_hat - recon)) / smax
        us = time_fn(lambda *xs, pp=p: svd_update(*xs, method="fmm", fmm_p=pp), *args)
        emit(f"fig3/p={p}", us, f"eq32_error={err:.3e}")


if __name__ == "__main__":
    run()
