"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(11)


@pytest.mark.parametrize("r,n,m", [(1, 16, 16), (7, 100, 90), (64, 300, 300),
                                   (3, 513, 700), (130, 64, 1030)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_cauchy_matmul_kernel(r, n, m, dtype):
    src = jnp.asarray(np.sort(RNG.uniform(0, 1, n)), dtype)
    anchor = jnp.asarray(RNG.integers(0, n, m), jnp.int32)
    tau = jnp.asarray(RNG.uniform(1e-6, 1e-3, m), dtype)
    w = jnp.asarray(RNG.normal(size=(r, n)), dtype)
    tgt_valid = jnp.asarray(RNG.uniform(size=m) > 0.1)
    out = ops.cauchy_matmul_stable(w, src, anchor, tau, tgt_valid=tgt_valid, interpret=True)
    want = ref.cauchy_matmul_ref(w, src, src[anchor], tau, tgt_valid)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol * float(jnp.max(jnp.abs(want))))


@pytest.mark.parametrize("n,m", [(50, 50), (200, 200), (333, 150)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_secular_kernel(n, m, dtype):
    dc = jnp.asarray(np.sort(RNG.uniform(0, 5, n)), dtype)
    zc2 = jnp.asarray(RNG.uniform(0.01, 1, n), dtype)
    rho = jnp.asarray(0.7, dtype)
    anchor_vals = jnp.asarray(np.sort(RNG.uniform(0, 5, m)), dtype)
    width = jnp.asarray(RNG.uniform(0.01, 0.5, m), dtype)
    lo = jnp.zeros(m, dtype)
    hi = width
    out = ops.secular_solve(dc, zc2, rho, anchor_vals, lo, hi, interpret=True)
    want = ref.secular_solve_ref(dc, zc2, rho, anchor_vals, lo, hi)
    tol = 1e-5 if dtype == jnp.float32 else 1e-14
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=tol)


@pytest.mark.parametrize("r,nb,c3,capt", [(2, 4, 12, 6), (5, 8, 24, 12), (9, 16, 48, 20)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_nearfield_kernel(r, nb, c3, capt, dtype):
    w = jnp.asarray(RNG.normal(size=(r, nb, c3)), dtype)
    x = jnp.asarray(RNG.uniform(0, 1, (nb, c3)), dtype)
    av = jnp.asarray(RNG.uniform(0, 1, (nb, capt)), dtype)
    tau = jnp.asarray(RNG.uniform(0, 1e-3, (nb, capt)), dtype)
    mask = jnp.asarray(RNG.uniform(size=(nb, capt)) > 0.2)
    out = ops.nearfield(w, x, av, tau, mask, interpret=True)
    want = ref.nearfield_ref(w, x, av, tau, mask)
    tol = 1e-4 if dtype == jnp.float32 else 1e-11
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol * float(jnp.max(jnp.abs(want)) + 1))


def test_kernel_vs_core_stable_cauchy():
    """ops.cauchy_matmul_stable == core.cauchy.cauchy_matmul_stable exactly."""
    from repro.core.cauchy import cauchy_matmul_stable as core_stable

    n, m, r = 180, 170, 5
    src = jnp.asarray(np.sort(RNG.uniform(0, 1, n)))
    anchor = jnp.asarray(RNG.integers(0, n, m), jnp.int32)
    tau = jnp.asarray(RNG.uniform(1e-9, 1e-3, m))
    w = jnp.asarray(RNG.normal(size=(r, n)))
    src_valid = jnp.asarray(RNG.uniform(size=n) > 0.1)
    tgt_valid = jnp.asarray(RNG.uniform(size=m) > 0.1)
    a = ops.cauchy_matmul_stable(w, src, anchor, tau, src_valid=src_valid,
                                 tgt_valid=tgt_valid, interpret=True)
    b = core_stable(w, src, anchor, tau, src_valid=src_valid, tgt_valid=tgt_valid)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-12)
