"""``repro.dist`` — the distributed layer (DESIGN.md §7).

One home for everything that knows about meshes and device placement:

* ``dist.sharding`` — PartitionSpec rules for params / batches / decode
  caches across all 10 archs, plus the batch-axis helpers
  (``batch_sharding`` / ``batch_pad``) the engine and serve layers use.
* ``dist.collectives`` — the compressed all-reduce primitives (factor
  pmeans, truncated-SVD factor all-gather, wire-byte accounting).
* ``dist.merge`` — hierarchical (log-depth) distributed truncated-SVD
  merge built from the paper's rank-1 update machinery.

Importing this package never touches jax device state (dry-run contract):
everything here is a function of shapes, specs, and axis names.
"""

from repro.dist import collectives, merge, sharding
from repro.dist.sharding import (
    AXIS_SIZES,
    batch_pad,
    batch_pspecs,
    batch_sharding,
    cache_pspecs,
    gather_for_compute,
    param_pspecs,
)

__all__ = [
    "AXIS_SIZES",
    "batch_pad",
    "batch_pspecs",
    "batch_sharding",
    "cache_pspecs",
    "collectives",
    "gather_for_compute",
    "merge",
    "param_pspecs",
    "sharding",
]
