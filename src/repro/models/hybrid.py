"""Zamba2-style hybrid: Mamba2 backbone with a weight-shared attention block.

81 Mamba2 layers; one *shared* transformer block (attention + MLP, single
weight copy) applied after every ``attn_every`` Mamba layers. Scan structure:
13 groups of 6 stacked Mamba layers (shared block closure-captured inside the
group scan — weight tying for free) + a stacked tail of 81 % 6 layers.

Deviation noted in DESIGN.md: real Zamba2 concatenates the block input with
the original embedding and adds per-invocation LoRAs on the shared block; we
apply the shared block on the residual stream directly.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn
from repro.models.transformer import remat_wrap, scan_or_unroll
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    cross_entropy,
    embed_init,
    embed_lookup,
    mlp_apply,
    mlp_init,
    norm_apply,
    norm_init,
    uniform_init,
)

__all__ = [
    "hybrid_init",
    "hybrid_train_loss",
    "hybrid_prefill",
    "hybrid_decode_step",
    "hybrid_state_spec",
    "hybrid_layout",
]


def hybrid_layout(cfg):
    k = cfg.attn_every
    n_groups = cfg.n_layers // k
    tail = cfg.n_layers - n_groups * k
    return n_groups, k, tail


def _mamba_layer_init(key, cfg, dtype):
    return {
        "ln": norm_init(cfg.d_model, cfg.norm_type, dtype),
        "ssm": ssm_mod.ssm_init(key, cfg, dtype),
    }


def hybrid_init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    n_groups, k, tail = hybrid_layout(cfg)
    ks = jax.random.split(key, 5)
    group_keys = jax.random.split(ks[0], n_groups * k).reshape(n_groups, k, 2)
    groups = jax.vmap(jax.vmap(partial(_mamba_layer_init, cfg=cfg, dtype=dtype)))(group_keys)
    params = {
        "embed": embed_init(ks[1], cfg.padded_vocab, cfg.d_model, dtype),
        "groups": groups,
        "shared": {
            "ln1": norm_init(cfg.d_model, cfg.norm_type, dtype),
            "attn": attn.attn_init(ks[2], cfg, dtype),
            "ln2": norm_init(cfg.d_model, cfg.norm_type, dtype),
            "mlp": mlp_init(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype),
        },
        "final_norm": norm_init(cfg.d_model, cfg.norm_type, dtype),
        "head": uniform_init(ks[4], (cfg.d_model, cfg.padded_vocab), cfg.d_model ** -0.5, dtype),
    }
    if tail:
        tail_keys = jax.random.split(jax.random.fold_in(key, 9), tail)
        params["tail"] = jax.vmap(partial(_mamba_layer_init, cfg=cfg, dtype=dtype))(tail_keys)
    return params


def _shared_block_train(x, sp, cfg, positions):
    h = x + attn.attn_train(norm_apply(x, sp["ln1"], cfg.norm_type), sp["attn"], cfg, positions)
    return h + mlp_apply(norm_apply(h, sp["ln2"], cfg.norm_type), sp["mlp"],
                         cfg.mlp_type, jnp.dtype(cfg.compute_dtype))


def _mamba_train(x, lp, cfg):
    return x + ssm_mod.ssm_train(norm_apply(x, lp["ln"], cfg.norm_type), lp["ssm"], cfg)


def hybrid_forward(params, batch, cfg):
    x = embed_lookup(batch["tokens"], params["embed"])
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
    shared = params["shared"]

    def group_body(carry, gp):
        def mamba_body(c, lp):
            return _mamba_train(c, lp, cfg), None

        h, _ = scan_or_unroll(mamba_body, carry, gp, cfg)
        h = _shared_block_train(h, shared, cfg, positions)
        return h, None

    group_body = remat_wrap(group_body, cfg)
    x, _ = scan_or_unroll(group_body, x, params["groups"], cfg)

    if "tail" in params:
        def tail_body(c, lp):
            return _mamba_train(c, lp, cfg), None
        x, _ = scan_or_unroll(tail_body, x, params["tail"], cfg)

    x = norm_apply(x, params["final_norm"], cfg.norm_type)
    cd = jnp.dtype(cfg.compute_dtype)
    logits = jnp.matmul(x.astype(cd), params["head"].astype(cd),
                        preferred_element_type=jnp.float32)
    vmask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    return jnp.where(vmask[None, None, :], logits, -1e30)


def hybrid_train_loss(params, batch, cfg):
    return cross_entropy(hybrid_forward(params, batch, cfg), batch["labels"], cfg.vocab_size)


# ---------------------------------------------------------------------------
# Serving: states = per-layer SSM states + per-application shared-attn KV
# ---------------------------------------------------------------------------


def hybrid_state_spec(cfg, batch, max_len, dtype):
    n_groups, k, tail = hybrid_layout(cfg)
    d_inner, n_heads, conv_dim = ssm_mod.ssm_dims(cfg)
    s = cfg.ssm
    one_ssm = {
        "conv": jax.ShapeDtypeStruct((batch, s.conv_width - 1, conv_dim), dtype),
        "ssm": jax.ShapeDtypeStruct((batch, n_heads, s.d_state, s.head_dim), jnp.float32),
    }
    spec = {
        "groups": jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct((n_groups, k) + sd.shape, sd.dtype), one_ssm
        ),
        "attn_kv": {
            "k": jax.ShapeDtypeStruct((n_groups, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jax.ShapeDtypeStruct((n_groups, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        },
    }
    if tail:
        spec["tail"] = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct((tail,) + sd.shape, sd.dtype), one_ssm
        )
    return spec


def _mamba_train_with_final_state(x, lp, cfg):
    """Training-mode ssm over the prompt + exact terminal decode state
    (read directly off the chunked recurrence — no per-token replay)."""
    xin = norm_apply(x, lp["ln"], cfg.norm_type)
    out, state = ssm_mod.ssm_train(xin, lp["ssm"], cfg, return_final_state=True)
    return x + out, state


def hybrid_prefill(params, batch, cfg, *, max_len=None):
    """Prompt prefill. NOTE: exact terminal SSM states are produced with a
    per-token replay (O(l) scan) per layer — fine for tests/small prompts; the
    32k/500k dry-run shapes use decode entry points with state specs instead.
    """
    x = embed_lookup(batch["tokens"], params["embed"])
    b, s, _ = x.shape
    max_len = max_len or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
    shared = params["shared"]
    pad = max_len - s

    def group_body(carry, gp):
        def mamba_body(c, lp):
            out, st = _mamba_train_with_final_state(c, lp, cfg)
            return out, st

        h, states = scan_or_unroll(mamba_body, carry, gp, cfg)
        h_norm = norm_apply(h, shared["ln1"], cfg.norm_type)
        a_out, kv = attn.attn_prefill(h_norm, shared["attn"], cfg, positions)
        kv = jax.tree.map(lambda c: jnp.pad(c, ((0, 0), (0, pad)) + ((0, 0),) * (c.ndim - 2)), kv)
        h = h + a_out
        h = h + mlp_apply(norm_apply(h, shared["ln2"], cfg.norm_type), shared["mlp"],
                          cfg.mlp_type, jnp.dtype(cfg.compute_dtype))
        return h, (states, kv)

    x, (g_states, kvs) = scan_or_unroll(group_body, x, params["groups"], cfg)

    state = {"groups": g_states, "attn_kv": kvs}
    if "tail" in params:
        def tail_body(c, lp):
            out, st = _mamba_train_with_final_state(c, lp, cfg)
            return out, st
        x, t_states = scan_or_unroll(tail_body, x, params["tail"], cfg)
        state["tail"] = t_states

    x = norm_apply(x, params["final_norm"], cfg.norm_type)
    cd = jnp.dtype(cfg.compute_dtype)
    logits = jnp.matmul(x[:, -1:, :].astype(cd), params["head"].astype(cd),
                        preferred_element_type=jnp.float32)
    vmask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    return jnp.where(vmask[None, None, :], logits, -1e30), state


def hybrid_decode_step(params, state, token, pos, cfg):
    x = embed_lookup(token, params["embed"])
    shared = params["shared"]

    def group_body(carry, xs):
        gp, g_state, kv = xs

        def mamba_body(c, xs2):
            lp, st = xs2
            h_norm = norm_apply(c, lp["ln"], cfg.norm_type)
            out, st2 = ssm_mod.ssm_decode(h_norm, lp["ssm"], cfg, st)
            return c + out, st2

        h, new_states = scan_or_unroll(mamba_body, carry, (gp, g_state), cfg)
        h_norm = norm_apply(h, shared["ln1"], cfg.norm_type)
        a_out, new_kv = attn.attn_decode(h_norm, shared["attn"], cfg, kv, pos)
        h = h + a_out
        h = h + mlp_apply(norm_apply(h, shared["ln2"], cfg.norm_type), shared["mlp"],
                          cfg.mlp_type, jnp.dtype(cfg.compute_dtype))
        return h, (new_states, new_kv)

    x, (new_g_states, new_kvs) = scan_or_unroll(
        group_body, x, (params["groups"], state["groups"], state["attn_kv"]), cfg
    )
    new_state = {"groups": new_g_states, "attn_kv": new_kvs}

    if "tail" in params:
        def tail_body(c, xs2):
            lp, st = xs2
            h_norm = norm_apply(c, lp["ln"], cfg.norm_type)
            out, st2 = ssm_mod.ssm_decode(h_norm, lp["ssm"], cfg, st)
            return c + out, st2

        x, new_t = scan_or_unroll(tail_body, x, (params["tail"], state["tail"]), cfg)
        new_state["tail"] = new_t

    x = norm_apply(x, params["final_norm"], cfg.norm_type)
    cd = jnp.dtype(cfg.compute_dtype)
    logits = jnp.matmul(x.astype(cd), params["head"].astype(cd),
                        preferred_element_type=jnp.float32)
    vmask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    return jnp.where(vmask[None, None, :], logits, -1e30), new_state
