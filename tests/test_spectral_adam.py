"""Spectral AdamW (paper-technique optimizer policy) behaviour."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.spectral_adam import (
    moment_memory_ratio,
    spectral_adam_init,
    spectral_adam_update,
)


def test_spectral_adam_optimizes_low_rank_quadratic():
    rng = np.random.default_rng(0)
    m, n, r = 128, 96, 8
    w_true = rng.normal(size=(m, 4)) @ rng.normal(size=(4, n))
    x = jnp.asarray(rng.normal(size=(64, m)))
    y = x @ jnp.asarray(w_true)
    params = {"w": jnp.zeros((m, n)), "b": jnp.zeros((n,))}

    def loss(p):
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)

    state = spectral_adam_init(jax.random.PRNGKey(0), params, rank=r)
    l0 = float(loss(params))
    grad = jax.jit(jax.grad(loss))
    step = jax.jit(lambda g, s, p: spectral_adam_update(g, s, p, lr=3e-1, weight_decay=0.0))
    for _ in range(60):
        params, state = step(grad(params), state, params)
    l1 = float(loss(params))
    assert l1 < 0.2 * l0, f"{l0} -> {l1}"


def test_moment_memory_shrinks():
    params = {"w": jnp.zeros((4096, 4096)), "ln": jnp.zeros((4096,))}
    assert moment_memory_ratio(params, rank=32) > 20


def test_small_params_fall_through_dense():
    params = {"tiny": jnp.zeros((8, 8))}
    state = spectral_adam_init(jax.random.PRNGKey(0), params, rank=8)
    leaf = jax.tree.leaves(state.leaves, is_leaf=lambda x: hasattr(x, "spectral"))[0]
    assert leaf.spectral is None
