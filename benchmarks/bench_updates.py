"""Planned structured updates vs naive sequential rank-1 (DESIGN.md §10).

The planner's claim: a rank-k update of B same-geometry streams lowers to k
BATCHED engine dispatches (``api.apply_many``) instead of B*k sequential
singles.  This bench measures that gap at the ISSUE 5 acceptance point
(k=8, B=16 on CPU; target >= 1.5x) plus neighboring shapes, and the cost of
a ``Decay`` fold (which must be engine-free, i.e. ~host-speed).

ISSUE 7 adds the extraction cells: the randomized range-finder sketch
(``updates.sketch.sketch_svd``) vs the dense ``jnp.linalg.svd`` it replaced
at m=n=1024, k=8 (target >= 3x), and the ``Sparse`` COO lowering
(``sparse_sketch_svd``, O((m+n)l^2 + nnz*l)) vs densify-then-``DenseDelta``
at 1% density (target >= 5x).

CSV rows (benchmarks/run.py style):
  bench_updates/rank_k/B=<b>/k=<k>,us,speedup=...
  bench_updates/decay/B=<b>,us,engine_calls=0
  bench_updates/sketch/m=<m>/k=<k>,us,speedup=...
  bench_updates/sparse/m=<m>/nnz=<nnz>,us,speedup=...

and a machine-readable summary at benchmarks/BENCH_updates.json.
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import bench_metadata, emit, time_fn
from repro import api, obs
from repro.api import SvdState, UpdatePolicy
from repro.updates import Decay, RankK, Window, sketch_svd, sparse_sketch_svd

M, N, RANK = 32, 48, 8    # the bench_engine.py truncated geometry
CELLS = [(16, 8), (16, 4), (8, 8)]     # (B streams, k) — first is acceptance
POLICY = UpdatePolicy(method="direct")

OUT = Path(__file__).parent / "BENCH_updates.json"


def _problem(rng, b, k):
    states, ops = [], []
    for _ in range(b):
        u = np.linalg.qr(rng.normal(size=(M, RANK)))[0]
        v = np.linalg.qr(rng.normal(size=(N, RANK)))[0]
        s = np.sort(np.abs(rng.normal(size=RANK)))[::-1].copy()
        states.append(SvdState(jnp.asarray(u), jnp.asarray(s), jnp.asarray(v)))
        ops.append(RankK(jnp.asarray(rng.normal(size=(M, k))),
                         jnp.asarray(rng.normal(size=(N, k)))))
    return states, ops


def _naive(states, ops, k):
    """B*k sequential single rank-1 api.update calls — the pre-planner shape."""
    outs = []
    for st, op in zip(states, ops):
        cur = st
        for i in range(k):
            cur = api.update(cur, op.u[:, i], op.v[:, i], POLICY)
        outs.append(cur)
    return outs


def run() -> dict:
    rng = np.random.default_rng(0)
    # metrics on for the whole run: emit() rows double as bench_us gauges
    # and the planner's schedule-cache counters land in the summary.
    obs.enable()
    results: dict = {"meta": bench_metadata(),
                     "m": M, "n": N, "rank": RANK, "cells": []}

    for b, k in CELLS:
        states, ops = _problem(rng, b, k)
        us_naive = time_fn(lambda: jax.block_until_ready(_naive(states, ops, k)))
        us_plan = time_fn(
            lambda: jax.block_until_ready(api.apply_many(states, ops, POLICY))
        )
        speedup = us_naive / us_plan
        emit(f"bench_updates/rank_k/B={b}/k={k}", us_plan,
             f"speedup={speedup:.2f} naive_us={us_naive:.0f}")
        results["cells"].append({
            "B": b, "k": k, "planned_us": us_plan, "naive_us": us_naive,
            "speedup": speedup,
        })

    # decay folds: engine-free by construction — host-speed regardless of B
    b = 16
    states, _ = _problem(rng, b, 1)
    decays = [Decay(0.99)] * b
    us_decay = time_fn(
        lambda: jax.block_until_ready(api.apply_many(states, decays, POLICY))
    )
    emit(f"bench_updates/decay/B={b}", us_decay, "engine_calls=0")
    results["decay"] = {"B": b, "us": us_decay}

    results["sketch"] = _bench_sketch(rng)
    results["sparse"] = _bench_sparse(rng)
    results["window"] = _bench_window(rng)

    accept = results["cells"][0]
    results["acceptance"] = {
        "target_speedup": 1.5,
        "measured_speedup": accept["speedup"],
        "pass": accept["speedup"] >= 1.5,
    }
    results["acceptance_sketch"] = {
        "target_speedup": 3.0,
        "measured_speedup": results["sketch"]["speedup"],
        "pass": results["sketch"]["speedup"] >= 3.0,
    }
    results["acceptance_sparse"] = {
        "target_speedup": 5.0,
        "measured_speedup": results["sparse"]["speedup"],
        "pass": results["sparse"]["speedup"] >= 5.0,
    }
    reg = obs.registry()
    results["obs"] = {
        "planner_schedule_cache_hits":
            getattr(reg.get("planner_schedule_cache_hits"), "value", 0),
        "planner_schedule_cache_misses":
            getattr(reg.get("planner_schedule_cache_misses"), "value", 0),
        "bench_rows": sum(1 for m in reg.series() if m.name == "bench_us"),
    }
    obs.disable()
    OUT.write_text(json.dumps(results, indent=1))
    return results


SKETCH_M = SKETCH_N = 1024
SKETCH_K = 8
SPARSE_DENSITY = 0.01


def _bench_sketch(rng) -> dict:
    """Randomized range-finder vs the dense LAPACK SVD it replaced, on the
    DenseDelta lowering shape (extract top-k of an m x n delta)."""
    m, n, k = SKETCH_M, SKETCH_N, SKETCH_K
    delta = jnp.asarray(rng.normal(size=(m, n)))

    @jax.jit
    def dense_topk(d):
        du, ds, dvt = jnp.linalg.svd(d, full_matrices=False)
        return du[:, :k] * ds[:k], dvt[:k]

    us_dense = time_fn(lambda: jax.block_until_ready(dense_topk(delta)))
    us_sketch = time_fn(lambda: jax.block_until_ready(sketch_svd(delta, k)))
    speedup = us_dense / us_sketch
    emit(f"bench_updates/sketch/m={m}/k={k}", us_sketch,
         f"speedup={speedup:.2f} dense_svd_us={us_dense:.0f}")
    return {"m": m, "n": n, "k": k, "sketch_us": us_sketch,
            "dense_svd_us": us_dense, "speedup": speedup}


def _bench_sparse(rng) -> dict:
    """O(nnz) Sparse lowering vs the densify-then-DenseDelta route (scatter
    the COO entries into an m x n buffer, then sketch the dense delta)."""
    m, n, k = SKETCH_M, SKETCH_N, SKETCH_K
    nnz = int(SPARSE_DENSITY * m * n)
    rows = jnp.asarray(rng.integers(0, m, nnz), jnp.int32)
    cols = jnp.asarray(rng.integers(0, n, nnz), jnp.int32)
    vals = jnp.asarray(rng.normal(size=nnz))

    @jax.jit
    def densify_then_sketch(r, c, v):
        dense = jnp.zeros((m, n), v.dtype).at[r, c].add(v)
        return sketch_svd(dense, k)

    us_densify = time_fn(
        lambda: jax.block_until_ready(densify_then_sketch(rows, cols, vals))
    )
    us_sparse = time_fn(
        lambda: jax.block_until_ready(
            sparse_sketch_svd(rows, cols, vals, m=m, n=n, k=k)
        )
    )
    speedup = us_densify / us_sparse
    emit(f"bench_updates/sparse/m={m}/nnz={nnz}", us_sparse,
         f"speedup={speedup:.2f} densify_us={us_densify:.0f}")
    return {"m": m, "n": n, "k": k, "nnz": nnz, "sparse_us": us_sparse,
            "densify_us": us_densify, "speedup": speedup}


WINDOW_M, WINDOW_N, WINDOW_RANK = 1024, 768, 8
WINDOW_CUT = 64


def _bench_window(rng) -> dict:
    """Sliding-stream eviction (ISSUE 9): ``Window`` keeps the newest
    ``m - cut`` rows of a rank-r sketch via ``cut`` state-bound rank-1
    downdates (one ``lax.scan`` when cut >= planner._SCAN_MIN) against the
    rebuild-from-dense alternative — materialize the decayed tail and run a
    fresh LAPACK SVD, the only option before downdates were ops."""
    m, n, r, cut = WINDOW_M, WINDOW_N, WINDOW_RANK, WINDOW_CUT
    keep = m - cut
    low = rng.normal(size=(m, r)) @ rng.normal(size=(r, n))
    state = SvdState.from_dense(jnp.asarray(low), rank=r)
    op = Window(keep, lam=0.97)

    @jax.jit
    def rebuild(u, s, v):
        tail = 0.97 * (u[-keep:] * s) @ v.T
        du, ds, dvt = jnp.linalg.svd(tail, full_matrices=False)
        return du[:, :r], ds[:r], dvt[:r].T

    us_rebuild = time_fn(
        lambda: jax.block_until_ready(rebuild(state.u, state.s, state.v))
    )
    us_plan = time_fn(
        lambda: jax.block_until_ready(api.apply(state, op, POLICY).s)
    )
    speedup = us_rebuild / us_plan
    emit(f"bench_updates/window/m={m}/cut={cut}", us_plan,
         f"speedup={speedup:.2f} rebuild_us={us_rebuild:.0f}")
    return {"m": m, "n": n, "rank": r, "cut": cut, "planned_us": us_plan,
            "rebuild_us": us_rebuild, "speedup": speedup}


if __name__ == "__main__":
    jax.config.update("jax_enable_x64", True)
    print("name,us_per_call,derived")
    r = run()
    print(f"# acceptance (k=8, B=16): {r['acceptance']}")
