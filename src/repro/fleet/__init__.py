"""repro.fleet — the mesh-sharded SvdService tier (DESIGN.md §13).

Layering (each file one layer, composed top-down):

    placement.py   deterministic hashed stream->shard assignment (pure data)
    frontend.py    continuous-batching admission over one service
    shard.py       one SvdService + frontend = one fleet shard
    fleet.py       SvdFleet: routing, query-time merge, FleetSnapshot v4

The fleet exposes the service surface (register / enqueue / enqueue_op /
state / flush / drain / merge_streams) over ``num_shards`` independent
services; shards compose only at query time through ``dist.merge``.
"""

from repro.fleet.fleet import FLEET_SNAPSHOT_VERSION, FleetSnapshot, SvdFleet
from repro.fleet.frontend import ContinuousBatcher
from repro.fleet.placement import (
    PlacementSpec,
    assign,
    plan_devices,
    shard_loads,
    shard_of,
)
from repro.fleet.shard import FleetShard

__all__ = [
    "FLEET_SNAPSHOT_VERSION",
    "ContinuousBatcher",
    "FleetShard",
    "FleetSnapshot",
    "PlacementSpec",
    "SvdFleet",
    "assign",
    "plan_devices",
    "shard_loads",
    "shard_of",
]
