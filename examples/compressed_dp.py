"""Compressed data-parallel training on 8 (emulated) devices.

Distributed-optimization demo of the paper-powered compressor: a small MLP
regression trained with shard_map data parallelism where 2-D gradients cross
the DP axis as rank-r factors (PowerSGD step + streaming-SVD long-horizon
basis from the paper's rank-1 update core), with per-worker error feedback. Compares loss
against dense-psum DP and prints the wire-byte savings.

NOTE: sets XLA_FLAGS *before* importing jax — run as a script, standalone.
Run:  python examples/compressed_dp.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.api import SvdState
from repro.optim.compression import (
    CompressionState,
    compression_init,
    compress_decompress,
    wire_bytes,
)

M_IN, M_HID, RANK, STEPS, LR = 64, 128, 8, 300, 2.0


def main():
    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    # low-rank target: the regime gradient compression exploits (real LM
    # gradients are spectrally concentrated — see the spectral optimizer)
    w_true = rng.normal(size=(M_IN, 4)) @ rng.normal(size=(4, M_HID))
    x_all = jnp.asarray(rng.normal(size=(8, 64, M_IN)))          # per-shard batches
    y_all = jnp.einsum("dbi,ih->dbh", x_all, jnp.asarray(w_true))

    def loss_fn(w, x, y):
        return jnp.mean((x @ w - y) ** 2)

    params0 = jnp.zeros((M_IN, M_HID))
    comp0 = compression_init(jax.random.PRNGKey(0), M_IN, M_HID, RANK)

    # ---- dense DP baseline
    def dense_step(w, x, y):
        g = jax.grad(loss_fn)(w, x[0], y[0])
        g = jax.lax.pmean(g, "data")
        return (w - LR * g)[None]

    dense_fn = jax.jit(shard_map(
        dense_step, mesh=mesh, in_specs=(P(), P("data"), P("data")),
        out_specs=P(None)))

    # ---- compressed DP
    def comp_step(w, comp, x, y):
        g = jax.grad(loss_fn)(w, x[0], y[0])
        comp = comp._replace(error=comp.error[0])  # unwrap per-shard leading axis
        g_hat, comp2 = compress_decompress(comp, g, axis_name="data")
        w2 = w - LR * g_hat
        return w2[None], comp2._replace(error=comp2.error[None])

    comp_specs = CompressionState(v_basis=P(), error=P("data"),
                                  tracker=SvdState(P(), P(), P()))
    comp_fn = jax.jit(shard_map(
        comp_step, mesh=mesh,
        in_specs=(P(), comp_specs._replace(error=P("data")), P("data"), P("data")),
        out_specs=(P(None), comp_specs)))

    w_d = params0
    w_c = params0
    comp = comp0._replace(error=jnp.zeros((8, M_IN, M_HID)))
    for step in range(STEPS):
        w_d = dense_fn(w_d, x_all, y_all)[0]
        w2, comp = comp_fn(w_c, comp, x_all, y_all)
        w_c = w2[0]

    ld = float(jnp.mean((x_all @ w_d - y_all) ** 2))
    lc = float(jnp.mean((x_all @ w_c - y_all) ** 2))
    wb = wire_bytes(M_IN, M_HID, RANK)
    print(f"devices               : {jax.device_count()}")
    print(f"dense-DP final loss   : {ld:.5f}")
    print(f"compressed final loss : {lc:.5f}")
    print(f"wire bytes/layer/step : {wb['dense']:,} -> {wb['compressed']:,} "
          f"({wb['ratio']:.1f}x smaller)")
    assert lc < 0.05 * float(jnp.mean(y_all ** 2)), "compressed DP failed to converge"
    assert lc < 2.0 * ld + 1e-6, "compressed DP much worse than dense DP"
    print("OK")


if __name__ == "__main__":
    main()
