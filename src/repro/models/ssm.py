"""Mamba2 (SSD) block: chunked matmul-form training scan + O(1) decode step.

The chunked state-space-dual formulation keeps everything MXU-shaped:
within-chunk interactions are (Q x Q) masked matmuls, inter-chunk state is a
short lax.scan over chunk summaries (b, h, d_state, head_dim). Decode keeps
(conv buffer, SSM state) per layer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dot, rmsnorm, uniform_init

__all__ = ["ssm_init", "ssm_train", "ssm_decode", "init_ssm_state", "ssm_dims"]


def ssm_dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.d_state
    return d_inner, n_heads, conv_dim


def ssm_init(key, cfg, dtype):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads, conv_dim = ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    sc = (1.0 / d) ** 0.5
    return {
        "in_proj": uniform_init(
            ks[0], (d, 2 * d_inner + 2 * s.d_state + n_heads), sc, dtype
        ),
        "conv_w": uniform_init(ks[1], (s.conv_width, conv_dim), 0.5, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads).astype(dtype)),
        "dt_bias": jnp.zeros((n_heads,), dtype),
        "d_skip": jnp.ones((n_heads,), dtype),
        "norm_w": jnp.ones((d_inner,), dtype),
        "out_proj": uniform_init(ks[2], (d_inner, d), (1.0 / d_inner) ** 0.5, dtype),
    }


def _split(zxbcdt, cfg):
    s = cfg.ssm
    d_inner, n_heads, _ = ssm_dims(cfg)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : d_inner + d_inner + 2 * s.d_state]
    dt = zxbcdt[..., -n_heads:]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv along time. xbc: (b, l, c); w: (k, c)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(k):
        out = out + pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :]
    return jax.nn.silu(out + b[None, None, :])


def ssm_train(x, p, cfg, *, return_final_state=False):
    """x: (b, l, d) -> (b, l, d); l must be a multiple of cfg.ssm.chunk."""
    s = cfg.ssm
    cd = jnp.dtype(cfg.compute_dtype)
    b, l, d = x.shape
    d_inner, n_heads, _ = ssm_dims(cfg)
    hd = s.head_dim
    q = min(s.chunk, l)
    if l % q:
        raise ValueError(f"sequence length {l} not divisible by SSD chunk {q}")
    nc = l // q

    zxbcdt = dot(x, p["in_proj"], cd).astype(x.dtype)
    z, xbc, dt_raw = _split(zxbcdt, cfg)
    xbc_preact = xbc  # raw conv inputs (terminal conv state for decode)
    xbc = _causal_conv(xbc, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    xs = xbc[..., :d_inner].reshape(b, l, n_heads, hd)
    bmat = xbc[..., d_inner : d_inner + s.d_state]          # (b, l, n)
    cmat = xbc[..., d_inner + s.d_state :]                  # (b, l, n)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))            # (h,) negative
    da = dt * a[None, None, :]                               # (b, l, h) <= 0

    # chunked views
    xs_c = xs.reshape(b, nc, q, n_heads, hd)
    b_c = bmat.reshape(b, nc, q, s.d_state)
    c_c = cmat.reshape(b, nc, q, s.d_state)
    dt_c = dt.reshape(b, nc, q, n_heads)
    da_c = da.reshape(b, nc, q, n_heads)

    seg = jnp.cumsum(da_c, axis=2)                           # inclusive (b,nc,q,h)
    seg_tot = seg[:, :, -1, :]                               # (b, nc, h)

    # within-chunk: Y_diag[t] = sum_{s<=t} exp(seg_t - seg_s) CB[t,s] dt_s x_s
    cb = jnp.einsum("bcqn,bcsn->bcqs", c_c.astype(cd), b_c.astype(cd),
                    preferred_element_type=jnp.float32)      # (b,nc,q,q)
    ldecay = seg[:, :, :, None, :] - seg[:, :, None, :, :]   # (b,nc,t,s,h)
    causal = jnp.tril(jnp.ones((q, q), bool))
    # mask INSIDE the exp: exp of masked (positive) exponents would be inf and
    # poison the backward pass through the where.
    decay = jnp.exp(jnp.where(causal[None, None, :, :, None], ldecay, -jnp.inf))
    w_ts = cb[..., None] * decay                             # (b,nc,t,s,h)
    xdt = xs_c.astype(jnp.float32) * dt_c[..., None]         # (b,nc,q,h,p)
    y_diag = jnp.einsum("bctsh,bcshp->bcthp", w_ts, xdt)

    # chunk summary states: S_c = sum_s exp(seg_tot - seg_s) dt_s B_s x_s^T
    dec_to_end = jnp.exp(seg_tot[:, :, None, :] - seg)       # (b,nc,q,h)
    bx = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", b_c.astype(jnp.float32),
                    dec_to_end * dt_c, xs_c.astype(jnp.float32))

    # inter-chunk recurrence over chunk states
    def step(state, inp):
        bx_c, seg_tot_c = inp                                # (b,h,n,p), (b,h)
        out_state = state                                    # state BEFORE chunk
        new_state = state * jnp.exp(seg_tot_c)[:, :, None, None] + bx_c
        return new_state, out_state

    init = jnp.zeros((b, n_heads, s.d_state, hd), jnp.float32)
    xs_scan = (jnp.moveaxis(bx, 1, 0), jnp.moveaxis(seg_tot, 1, 0))
    if cfg.scan_layers:
        final_state, states_prev = lax.scan(step, init, xs_scan)
        states_prev = jnp.moveaxis(states_prev, 0, 1)        # (b,nc,h,n,p)
    else:
        st = init
        outs = []
        for i in range(nc):
            st, o = step(st, jax.tree.map(lambda a: a[i], xs_scan))
            outs.append(o)
        final_state = st
        states_prev = jnp.stack(outs, axis=1)

    # inter-chunk contribution: Y_off[t] = exp(seg_t) C_t . S_prev
    y_off = jnp.einsum("bcqn,bchnp,bcqh->bcqhp",
                       c_c.astype(jnp.float32), states_prev, jnp.exp(seg))
    y = (y_diag + y_off).reshape(b, l, n_heads, hd)
    y = y + xs.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, l, d_inner).astype(x.dtype)

    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    out = dot(y, p["out_proj"], cd).astype(x.dtype)
    if return_final_state:
        # exact terminal decode state from the chunked recurrence: SSM state
        # after the last chunk + the conv buffer = last conv_width-1 inputs
        conv_state = xbc_preact[:, -(s.conv_width - 1):, :]
        return out, {"conv": conv_state, "ssm": final_state}
    return out


def init_ssm_state(batch, cfg, dtype):
    s = cfg.ssm
    d_inner, n_heads, conv_dim = ssm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, n_heads, s.d_state, s.head_dim), jnp.float32),
    }


def ssm_decode(x, p, cfg, state):
    """One-token step. x: (b, 1, d); returns (y, new_state)."""
    s = cfg.ssm
    cd = jnp.dtype(cfg.compute_dtype)
    b = x.shape[0]
    d_inner, n_heads, conv_dim = ssm_dims(cfg)
    hd = s.head_dim

    zxbcdt = dot(x, p["in_proj"], cd).astype(x.dtype)
    z, xbc, dt_raw = _split(zxbcdt, cfg)

    buf = jnp.concatenate([state["conv"], xbc], axis=1)      # (b, k, c)
    conv_out = jnp.einsum("bkc,kc->bc", buf.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xbc1 = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)
    new_conv = buf[:, 1:, :]

    xs = xbc1[..., :d_inner].reshape(b, n_heads, hd)
    bvec = xbc1[:, 0, d_inner : d_inner + s.d_state]
    cvec = xbc1[:, 0, d_inner + s.d_state :]

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a[None, :])                          # (b, h)

    ssm = state["ssm"] * decay[:, :, None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", bvec.astype(jnp.float32), dt, xs.astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhnp->bhp", cvec.astype(jnp.float32), ssm)
    y = y + xs.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    out = dot(y, p["out_proj"], cd).astype(x.dtype)
    return out, {"conv": new_conv, "ssm": ssm}
