"""Distributed-semantics tests on 8 fake CPU devices (subprocess: the device
count must be forced before jax initializes, and only for these tests)."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run(code: str) -> dict:
    script = textwrap.dedent(code)
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        timeout=420,
        env={
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
            "PYTHONPATH": str(REPO / "src"),
            "PATH": "/usr/bin:/bin",
            "JAX_PLATFORMS": "cpu",
            "HOME": "/tmp",
        },
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-4000:]}"
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_sharded_train_step_matches_single_device():
    """One sharded train step on a 4x2 mesh == the unsharded step."""
    out = _run("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.models.registry import build_model
        from repro.dist import sharding as sh
        from repro.optim.adamw import adamw_init, adamw_update, AdamWState
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = configs.get_smoke("nemotron-4-15b").replace(vocab_pad_to=16)
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
        }

        def step(params, opt, batch):
            loss, grads = jax.value_and_grad(api.train_loss)(params, batch)
            p2, o2, g = adamw_update(grads, opt, params, lr=1e-3)
            return p2, o2, loss

        p_ref, o_ref, loss_ref = jax.jit(step)(params, opt, batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        p_specs = sh.param_pspecs(params)
        b_specs = sh.batch_pspecs(batch, multi_pod=False)
        ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                    is_leaf=lambda x: isinstance(x, P))
        o_specs = AdamWState(step=P(), m=p_specs, v=p_specs)
        with mesh:
            p_sh, o_sh, loss_sh = jax.jit(
                step, in_shardings=(ns(p_specs), ns(o_specs), ns(b_specs))
            )(params, opt, batch)

        dl = abs(float(loss_ref) - float(loss_sh))
        dp = max(float(jnp.max(jnp.abs(a - b)))
                 for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)))
        print(json.dumps({"dloss": dl, "dparams": dp,
                          "devices": jax.device_count()}))
    """)
    assert out["devices"] == 8
    assert out["dloss"] < 1e-5
    assert out["dparams"] < 1e-4


def test_compressed_allreduce_under_shard_map():
    """Compressed DP all-reduce == dense pmean for rank<r gradients, and the
    HLO carries only the small factors across the wire."""
    out = _run("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim.compression import (CompressionState, compression_init,
                                             compress_decompress)
        from repro.core.svd_update import TruncatedSvd

        mesh = jax.make_mesh((8,), ("data",))
        m, n, r = 16, 12, 4
        rng = np.random.default_rng(0)
        # per-shard gradients share a rank-2 structure + shard-specific coeffs
        u = rng.normal(size=(m, 2)); v = rng.normal(size=(n, 2))
        coeffs = rng.normal(size=(8, 2, 2))
        g_all = jnp.asarray(np.stack([u @ c @ v.T for c in coeffs]))  # (8, m, n)
        state = compression_init(jax.random.PRNGKey(0), m, n, r)

        def body(g_local, state):
            g_hat, st2 = compress_decompress(state, g_local[0], axis_name="data")
            # the error-feedback buffer is PER-WORKER (local residual); the
            # basis and tracker are replicated (built from psum'd factors)
            return g_hat[None], st2._replace(error=st2.error[None])

        out_state_specs = CompressionState(
            v_basis=P(), error=P("data"),
            tracker=TruncatedSvd(P(), P(), P()),
        )
        fn = shard_map(body, mesh=mesh,
                       in_specs=(P("data"), P()),
                       out_specs=(P("data"), out_state_specs))
        g_hat, st = jax.jit(fn)(g_all, state)
        dense_mean = np.mean(np.asarray(g_all), axis=0)
        got = np.asarray(g_hat[0])  # pmean'd: every shard holds the mean
        rel = float(np.linalg.norm(got - dense_mean) / np.linalg.norm(dense_mean))
        print(json.dumps({"rel": rel, "err_shape": list(st.error.shape)}))
    """)
    assert out["rel"] < 1e-4


def test_param_specs_cover_all_archs():
    """Every arch's full-size param tree gets divisibility-consistent specs
    on the production mesh (the dry-run precondition)."""
    out = _run("""
        import json
        import jax
        from repro import configs
        from repro.models.registry import build_model
        from repro.dist import sharding as sh

        bad = []
        for arch in configs.ARCH_IDS:
            cfg = configs.get(arch)
            api = build_model(cfg)
            shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
            specs = sh.param_pspecs(shapes)
            flat_s, _ = jax.tree_util.tree_flatten_with_path(shapes)
            flat_p = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "_cls") or True)
            flat_p = jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, type(jax.sharding.PartitionSpec()))
            )[0]
            mesh_size = {"data": 16, "model": 16}
            for (path, shape), (_, spec) in zip(flat_s, flat_p):
                for dim, ax in zip(shape.shape, tuple(spec) + (None,) * 10):
                    if ax is None:
                        continue
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    total = 1
                    for a in axes:
                        total *= mesh_size[a]
                    if dim % total:
                        bad.append([arch, jax.tree_util.keystr(path), dim, str(ax)])
        print(json.dumps({"bad": bad}))
    """)
    assert out["bad"] == [], out["bad"]
