"""The structured-perturbation op algebra (DESIGN.md §10).

The paper gives one primitive — absorb ``a b^T`` into an SVD — but real
streaming workloads arrive as *structured* perturbations: mini-batch rank-k
gradient updates, row/column appends from new users, forgetting-factor decay
on stale streams (Peña & Sauer, arXiv:1809.03285; Deng et al.,
arXiv:2401.09703).  This module is the declarative layer: each op is a
frozen, registered-pytree dataclass with an *exact reference semantics*
``op.apply_dense(A)``; ``repro.updates.planner`` lowers any op onto a
minimal schedule of plan-cached rank-1 engine dispatches.

Ops:

* ``RankK(u, v)`` — ``A + u @ v^T`` with ``u (…, m, k)``, ``v (…, n, k)``.
* ``AppendRows(rows)`` / ``AppendCols(cols)`` — grow the matrix by new rows
  ``(p, n)`` / columns ``(m, p)``; ``from_svd`` carries a pre-factored block
  (the form ``dist.merge`` feeds) so lowering skips the dense SVD.
* ``DenseDelta(delta, rank)`` — ``A + delta`` lowered through a top-``rank``
  randomized sketch of ``delta`` (exact when ``rank >= rank(delta)``).
* ``Sparse(rows, cols, vals, rank)`` — ``A + S`` for a static-nnz COO delta;
  the lowering cost scales with nnz (``updates.sketch`` +
  ``kernels.sparse_proj``), never densifying m x n.
* ``Decay(lam)`` — ``lam * A``; folds into the singular values for free
  (zero engine dispatches).
* ``RemoveRows(idx)`` / ``RemoveCols(idx)`` — *downdates*: delete rows /
  columns by static index.  Each deletion is the dual rank-1 perturbation
  (Peña & Sauer, arXiv:1809.03285): zero the slice via ``A - (A e_j) e_j^T``
  on the existing rank-1 engine, then drop the zeroed row of the factor —
  a free geometry shrink, no LAPACK SVD anywhere.
* ``Window(size)`` — sliding-window convenience: keep the last ``size``
  rows (optionally decayed by ``lam``); lowers to
  ``Compose(Decay, RemoveRows(oldest...))``.
* ``Compose(ops)`` — apply a tuple of ops left-to-right.

Every op also carries:

* ``out_shape(m, n)`` — the geometry after the op (appends grow it);
* ``spec()`` — a hashable structural descriptor (type + static shape info,
  no array data).  It keys the planner's schedule cache and serializes into
  ``ServiceSnapshot`` aux JSON, from which ``skeleton_from_spec`` rebuilds a
  placeholder-leaf op with the identical pytree structure (checkpoint
  restore).

>>> import numpy as np
>>> from repro.updates import RankK, Decay, Compose
>>> a_mat = np.ones((2, 3))
>>> op = Compose((Decay(0.5), RankK(np.ones((2, 1)), np.ones((3, 1)))))
>>> np.asarray(op.apply_dense(a_mat))
array([[1.5, 1.5, 1.5],
       [1.5, 1.5, 1.5]])
>>> op.spec()
('compose', (('decay',), ('rank_k', 1)))
>>> op.out_shape(2, 3)
(2, 3)
"""

from __future__ import annotations

import dataclasses
import operator
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "AppendCols",
    "AppendRows",
    "Compose",
    "Decay",
    "DenseDelta",
    "RankK",
    "RemoveCols",
    "RemoveRows",
    "Sparse",
    "UpdateOp",
    "Window",
    "skeleton_from_spec",
    "spec_from_json",
    "spec_to_json",
]


def _normalize_idx(idx, what: str) -> tuple:
    """Sorted tuple of unique non-negative ints (static meta — keys the
    schedule cache and serializes into snapshot aux)."""
    try:
        idx = (operator.index(idx),)
    except TypeError:
        pass
    try:
        out = tuple(int(i) for i in idx)
    except TypeError:
        raise TypeError(f"{what} takes an int or a sequence of ints; "
                        f"got {idx!r}") from None
    if not out:
        raise ValueError(f"{what} needs at least one index")
    if any(i < 0 for i in out):
        raise ValueError(f"{what} indices must be non-negative; got {out}")
    if len(set(out)) != len(out):
        # duplicates would double-subtract under the rank-1 lowering
        # (zeroing an already-zeroed slice negates instead of removing)
        raise ValueError(f"{what} indices must be unique; got {out}")
    return tuple(sorted(out))


class UpdateOp:
    """Base class (isinstance anchor) for structured-perturbation ops."""

    def apply_dense(self, a_mat):
        """Exact reference semantics on a dense matrix."""
        raise NotImplementedError

    def out_shape(self, m: int, n: int) -> tuple[int, int]:
        """Geometry after the op (appends grow it; everything else keeps it)."""
        return (m, n)

    def spec(self) -> tuple:
        """Hashable structural descriptor: planner cache key + snapshot aux."""
        raise NotImplementedError

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


@partial(jax.tree_util.register_dataclass, data_fields=["u", "v"], meta_fields=[])
@dataclasses.dataclass(frozen=True)
class RankK(UpdateOp):
    """``A + u @ v^T``: a rank-k perturbation, e.g. a mini-batch of gradient
    sketches.  ``u``: (…, m, k), ``v``: (…, n, k); a leading batch axis means
    one rank-k update per stacked problem.

    >>> import numpy as np
    >>> op = RankK(np.eye(3, 2), np.eye(4, 2))
    >>> op.k, op.spec()
    (2, ('rank_k', 2))
    """

    u: jax.Array
    v: jax.Array

    @property
    def k(self) -> int:
        return self.u.shape[-1]

    def apply_dense(self, a_mat):
        return jnp.asarray(a_mat) + jnp.einsum(
            "...mk,...nk->...mn", jnp.asarray(self.u), jnp.asarray(self.v)
        )

    def spec(self) -> tuple:
        return ("rank_k", self.k)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["rows", "u", "s", "v"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class AppendRows(UpdateOp):
    """Grow the matrix by ``p`` new rows: ``[A; rows]``.

    Two storage modes: dense ``rows (p, n)``, or a pre-factored block
    ``from_svd(u, s, v)`` (``u (p, q)``, ``s (q,)``, ``v (n, q)``) — the form
    a ``dist.merge`` shard already carries, lowered without any dense SVD.

    >>> import numpy as np
    >>> AppendRows(np.zeros((2, 5))).out_shape(3, 5)
    (5, 5)
    """

    rows: jax.Array | None = None
    u: jax.Array | None = None
    s: jax.Array | None = None
    v: jax.Array | None = None

    def __post_init__(self):
        dense = self.rows is not None
        factored = self.u is not None and self.s is not None and self.v is not None
        if dense == factored:
            raise ValueError("AppendRows takes either rows= or from_svd factors")

    @classmethod
    def from_svd(cls, u, s, v) -> "AppendRows":
        return cls(rows=None, u=u, s=s, v=v)

    @property
    def p(self) -> int:
        """Number of appended rows."""
        return self.rows.shape[0] if self.rows is not None else self.u.shape[0]

    @property
    def block_rank(self) -> int:
        """Rank budget of the lowering (q components)."""
        if self.rows is not None:
            return min(self.rows.shape[0], self.rows.shape[1])
        return self.s.shape[0]

    def apply_dense(self, a_mat):
        block = self.rows
        if block is None:
            block = jnp.einsum("pq,q,nq->pn", self.u, self.s, self.v)
        return jnp.concatenate([jnp.asarray(a_mat), jnp.asarray(block)], axis=0)

    def out_shape(self, m: int, n: int) -> tuple[int, int]:
        return (m + self.p, n)

    def spec(self) -> tuple:
        mode = "dense" if self.rows is not None else "factored"
        return ("append_rows", self.p, self.block_rank, mode)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["cols", "u", "s", "v"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class AppendCols(UpdateOp):
    """Grow the matrix by ``p`` new columns: ``[A, cols]``.

    ``from_svd(u, s, v)`` carries a pre-factored block (``u (m, q)``,
    ``s (q,)``, ``v (p, q)``).

    >>> import numpy as np
    >>> AppendCols(np.zeros((3, 2))).out_shape(3, 5)
    (3, 7)
    """

    cols: jax.Array | None = None
    u: jax.Array | None = None
    s: jax.Array | None = None
    v: jax.Array | None = None

    def __post_init__(self):
        dense = self.cols is not None
        factored = self.u is not None and self.s is not None and self.v is not None
        if dense == factored:
            raise ValueError("AppendCols takes either cols= or from_svd factors")

    @classmethod
    def from_svd(cls, u, s, v) -> "AppendCols":
        return cls(cols=None, u=u, s=s, v=v)

    @property
    def p(self) -> int:
        return self.cols.shape[1] if self.cols is not None else self.v.shape[0]

    @property
    def block_rank(self) -> int:
        if self.cols is not None:
            return min(self.cols.shape[0], self.cols.shape[1])
        return self.s.shape[0]

    def apply_dense(self, a_mat):
        block = self.cols
        if block is None:
            block = jnp.einsum("mq,q,pq->mp", self.u, self.s, self.v)
        return jnp.concatenate([jnp.asarray(a_mat), jnp.asarray(block)], axis=1)

    def out_shape(self, m: int, n: int) -> tuple[int, int]:
        return (m, n + self.p)

    def spec(self) -> tuple:
        mode = "dense" if self.cols is not None else "factored"
        return ("append_cols", self.p, self.block_rank, mode)


@partial(
    jax.tree_util.register_dataclass, data_fields=["delta"], meta_fields=["rank"]
)
@dataclasses.dataclass(frozen=True)
class DenseDelta(UpdateOp):
    """``A + delta`` lowered through a top-``rank`` randomized sketch of
    ``delta`` (``updates.sketch.sketch_svd`` — O(m·n·rank), no LAPACK SVD).

    Exact when ``rank >= rank(delta)``; otherwise the lowering absorbs a
    near-best rank-``rank`` approximation of the delta (the reference
    semantics ``apply_dense`` stays the exact dense sum — parity tests
    should feed deltas within the sketch budget; the policy's
    ``sketch_oversample`` / ``sketch_power_iters`` knobs tune the tail).

    >>> import numpy as np
    >>> DenseDelta(np.ones((3, 4)), rank=1).spec()
    ('dense_delta', 1)
    """

    delta: jax.Array
    rank: int = 1

    def __post_init__(self):
        if self.rank < 1:
            raise ValueError(f"sketch rank must be >= 1; got {self.rank}")

    def apply_dense(self, a_mat):
        return jnp.asarray(a_mat) + jnp.asarray(self.delta)

    def spec(self) -> tuple:
        return ("dense_delta", self.rank)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["rows", "cols", "vals"],
    meta_fields=["rank"],
)
@dataclasses.dataclass(frozen=True)
class Sparse(UpdateOp):
    """``A + S`` for a static-nnz COO sparse delta ``S[rows[e], cols[e]] +=
    vals[e]`` — the representation-learning workload (each event touches a
    few rows of an embedding matrix; Deng et al., arXiv:2401.09703).

    ``rows``/``cols``/``vals``: (…, nnz) int/int/float with a leading batch
    axis iff one sparse delta per stacked problem.  ``nnz`` is static (it
    keys the schedule cache); streams with varying event counts pad to a
    bucket size with zero-valued entries at coordinate (0, 0) — exact
    no-ops.  Duplicate coordinates accumulate.  ``rank`` budgets the
    lowering (``rank >= rank(S)`` is exact; nnz entries touching ``r`` rows
    or ``c`` columns have ``rank(S) <= min(r, c) <= nnz``).

    The planner lowers through ``updates.sketch.sparse_sketch_svd`` +
    ``kernels.sparse_proj`` at O((m+n)·k² + nnz·k) — never densifying m·n.

    >>> import numpy as np
    >>> op = Sparse(np.array([0, 2]), np.array([1, 0]), np.array([5.0, -1.0]))
    >>> op.nnz, op.spec()
    (2, ('sparse', 2, 1))
    >>> np.asarray(op.apply_dense(np.zeros((3, 2))))
    array([[ 0.,  5.],
           [ 0.,  0.],
           [-1.,  0.]])
    """

    rows: jax.Array
    cols: jax.Array
    vals: jax.Array
    rank: int = 1

    def __post_init__(self):
        if self.rank < 1:
            raise ValueError(f"sketch rank must be >= 1; got {self.rank}")

    @property
    def nnz(self) -> int:
        """Static entry count (padding entries included)."""
        return self.vals.shape[-1]

    def apply_dense(self, a_mat):
        a_mat = jnp.asarray(a_mat)
        rows = jnp.asarray(self.rows)
        cols = jnp.asarray(self.cols)
        vals = jnp.asarray(self.vals)

        def one(base, r, c, v):
            return base.at[r, c].add(v)

        if vals.ndim == 1:
            if a_mat.ndim == 2:
                return one(a_mat, rows, cols, vals)
            return jax.vmap(lambda base: one(base, rows, cols, vals))(a_mat)
        if a_mat.ndim == 2:
            a_mat = jnp.broadcast_to(a_mat, vals.shape[:-1] + a_mat.shape)
        return jax.vmap(one)(a_mat, rows, cols, vals)

    def spec(self) -> tuple:
        return ("sparse", self.nnz, self.rank)


@partial(jax.tree_util.register_dataclass, data_fields=["lam"], meta_fields=[])
@dataclasses.dataclass(frozen=True)
class Decay(UpdateOp):
    """Forgetting-factor rescale ``lam * A`` — folds into the singular values
    for free (the planner emits zero engine dispatches for it).

    >>> import numpy as np
    >>> np.asarray(Decay(0.5).apply_dense(np.full((1, 2), 4.0)))
    array([[2., 2.]])
    """

    lam: jax.Array | float

    def apply_dense(self, a_mat):
        return jnp.asarray(self.lam) * jnp.asarray(a_mat)

    def spec(self) -> tuple:
        return ("decay",)


@partial(jax.tree_util.register_dataclass, data_fields=[], meta_fields=["idx"])
@dataclasses.dataclass(frozen=True)
class RemoveRows(UpdateOp):
    """Delete rows ``idx`` (static, unique, sorted): the downdate dual of
    ``AppendRows``.  Lowering zeroes each row on the rank-1 engine
    (``A - e_i (A^T e_i)^T`` — the pair is precomputable from the *original*
    factors because zeroing row ``i`` leaves every other row untouched),
    then drops the zeroed rows of ``u`` for free.  Carries no array data:
    the whole op is static metadata.

    >>> import numpy as np
    >>> op = RemoveRows((2, 0))
    >>> op.idx, op.spec(), op.out_shape(4, 3)
    ((0, 2), ('remove_rows', (0, 2)), (2, 3))
    >>> np.asarray(op.apply_dense(np.arange(12.0).reshape(4, 3)))
    array([[ 3.,  4.,  5.],
           [ 9., 10., 11.]])
    """

    idx: tuple

    def __post_init__(self):
        object.__setattr__(self, "idx", _normalize_idx(self.idx, "RemoveRows"))

    @property
    def p(self) -> int:
        """Number of removed rows."""
        return len(self.idx)

    def apply_dense(self, a_mat):
        a_mat = jnp.asarray(a_mat)
        if self.idx[-1] >= a_mat.shape[-2]:
            raise ValueError(
                f"RemoveRows{self.idx} out of range for {a_mat.shape[-2]} rows"
            )
        return jnp.delete(a_mat, jnp.array(self.idx), axis=-2)

    def out_shape(self, m: int, n: int) -> tuple[int, int]:
        return (m - self.p, n)

    def spec(self) -> tuple:
        return ("remove_rows", self.idx)


@partial(jax.tree_util.register_dataclass, data_fields=[], meta_fields=["idx"])
@dataclasses.dataclass(frozen=True)
class RemoveCols(UpdateOp):
    """Delete columns ``idx``: the downdate dual of ``AppendCols`` (the
    ``SVD.remove_column`` algebra, batched and LAPACK-free — each deletion is
    ``A - (A e_j) e_j^T`` on the rank-1 engine, then a free shrink of ``v``).

    >>> import numpy as np
    >>> op = RemoveCols(1)
    >>> op.idx, op.spec(), op.out_shape(2, 3)
    ((1,), ('remove_cols', (1,)), (2, 2))
    >>> np.asarray(op.apply_dense(np.arange(6.0).reshape(2, 3)))
    array([[0., 2.],
           [3., 5.]])
    """

    idx: tuple

    def __post_init__(self):
        object.__setattr__(self, "idx", _normalize_idx(self.idx, "RemoveCols"))

    @property
    def p(self) -> int:
        """Number of removed columns."""
        return len(self.idx)

    def apply_dense(self, a_mat):
        a_mat = jnp.asarray(a_mat)
        if self.idx[-1] >= a_mat.shape[-1]:
            raise ValueError(
                f"RemoveCols{self.idx} out of range for {a_mat.shape[-1]} cols"
            )
        return jnp.delete(a_mat, jnp.array(self.idx), axis=-1)

    def out_shape(self, m: int, n: int) -> tuple[int, int]:
        return (m, n - self.p)

    def spec(self) -> tuple:
        return ("remove_cols", self.idx)


@partial(jax.tree_util.register_dataclass, data_fields=["lam"],
         meta_fields=["size"])
@dataclasses.dataclass(frozen=True)
class Window(UpdateOp):
    """Sliding-window convenience: keep the LAST ``size`` rows (rows append
    at the bottom, so the oldest stream entries leave first), with an
    optional forgetting factor ``lam`` on the survivors.  Lowers to
    ``Compose(Decay(lam), RemoveRows(range(m - size)))`` — a decay fold plus
    one planned downdate per evicted row; a no-op shrink when the state
    already fits (``m <= size``).

    >>> import numpy as np
    >>> op = Window(2)
    >>> op.spec(), op.out_shape(5, 3), op.out_shape(1, 3)
    (('window', 2), (2, 3), (1, 3))
    >>> np.asarray(Window(2, lam=0.5).apply_dense(np.arange(8.0).reshape(4, 2)))
    array([[2. , 2.5],
           [3. , 3.5]])
    """

    size: int
    lam: jax.Array | float = 1.0

    def __post_init__(self):
        if not isinstance(self.size, int) or self.size < 1:
            raise ValueError(f"window size must be an int >= 1; got {self.size}")

    def apply_dense(self, a_mat):
        a_mat = jnp.asarray(a_mat)
        m = a_mat.shape[-2]
        kept = a_mat[..., max(0, m - self.size):, :]
        return jnp.asarray(self.lam) * kept

    def out_shape(self, m: int, n: int) -> tuple[int, int]:
        return (min(m, self.size), n)

    def spec(self) -> tuple:
        return ("window", self.size)


@partial(jax.tree_util.register_dataclass, data_fields=["ops"], meta_fields=[])
@dataclasses.dataclass(frozen=True)
class Compose(UpdateOp):
    """Apply a tuple of ops left-to-right: ``Compose((f, g))`` is "f, then g".

    >>> import numpy as np
    >>> op = Compose((Decay(2.0), Decay(3.0)))
    >>> float(op.apply_dense(np.ones((1, 1)))[0, 0])
    6.0
    """

    ops: tuple

    def __post_init__(self):
        object.__setattr__(self, "ops", tuple(self.ops))
        for child in self.ops:
            if not isinstance(child, UpdateOp):
                raise TypeError(f"Compose takes UpdateOps; got {type(child)}")

    def apply_dense(self, a_mat):
        out = jnp.asarray(a_mat)
        for child in self.ops:
            out = child.apply_dense(out)
        return out

    def out_shape(self, m: int, n: int) -> tuple[int, int]:
        for child in self.ops:
            m, n = child.out_shape(m, n)
        return (m, n)

    def spec(self) -> tuple:
        return ("compose", tuple(child.spec() for child in self.ops))


# ---------------------------------------------------------------------------
# Spec serialization: planner cache keys are the tuple form; ServiceSnapshot
# aux JSON carries the list form; skeletons rebuild placeholder-leaf ops with
# the exact pytree structure of the originals (checkpoint treedefs).
# ---------------------------------------------------------------------------


def spec_to_json(spec: tuple):
    """Tuple spec -> JSON-able nested lists."""
    return [spec_to_json(x) if isinstance(x, tuple) else x for x in spec]


def spec_from_json(spec) -> tuple:
    """JSON nested lists -> hashable tuple spec."""
    return tuple(spec_from_json(x) if isinstance(x, list) else x for x in spec)


def skeleton_from_spec(spec: tuple) -> UpdateOp:
    """Placeholder-leaf op with the pytree structure the spec describes —
    what ``ServiceSnapshot.skeleton`` unflattens restored leaves into.

    >>> import jax, numpy as np
    >>> op = RankK(np.zeros((3, 2)), np.zeros((4, 2)))
    >>> skel = skeleton_from_spec(op.spec())
    >>> jax.tree.structure(skel) == jax.tree.structure(op)
    True
    """
    kind = spec[0]
    if kind == "rank_k":
        return RankK(u=0.0, v=0.0)
    if kind in ("append_rows", "append_cols"):
        cls = AppendRows if kind == "append_rows" else AppendCols
        if spec[3] == "dense":
            return cls(0.0)
        return cls.from_svd(0.0, 0.0, 0.0)
    if kind == "dense_delta":
        return DenseDelta(delta=0.0, rank=spec[1])
    if kind == "sparse":
        return Sparse(rows=0.0, cols=0.0, vals=0.0, rank=spec[2])
    if kind == "decay":
        return Decay(lam=0.0)
    if kind == "remove_rows":
        return RemoveRows(spec[1])
    if kind == "remove_cols":
        return RemoveCols(spec[1])
    if kind == "window":
        return Window(size=spec[1], lam=0.0)
    if kind == "compose":
        return Compose(tuple(skeleton_from_spec(c) for c in spec[1]))
    raise ValueError(f"unknown op spec {spec!r}")
