"""Streaming rank-1 SVD-update service: micro-batched engine flushes.

The serving story for the paper's machinery: many concurrent streams (one
per user/session/adapter) each own a truncated ``repro.api.SvdState`` that
evolves by rank-1 updates — personalization vectors folding into low-rank
adapters, per-tenant gradient sketches, online covariance trackers. Issuing
those updates one at a time wastes the hardware; this service queues them
and flushes *one batched engine call* per round:

    svc = SvdService(max_batch=64, policy=UpdatePolicy(method="auto"))
    svc.register("user-1", api.SvdState.from_dense(m1, rank=8))
    svc.enqueue("user-1", a, b)        # cheap: just queues
    svc.enqueue("user-2", a2, b2)
    svc.flush()                        # one batched truncated update

* Per-stream ordering: a stream's queued pairs are applied in FIFO order;
  each flush round takes at most one pending pair per stream (they are
  sequential updates to the same state, so they cannot share a batch).
* Micro-batching: ``enqueue`` auto-flushes once ``max_batch`` streams have
  a pending pair. Batches are padded up to bucket sizes (powers of two) so
  the engine's plan cache sees a handful of geometries, not every B.
* Policy: an ``UpdatePolicy`` names the numerics (method/fmm_p/...) and the
  placement — ``policy.mesh`` spreads every flush's batch axis over the
  mesh via the engine's shard_map dispatch.  A legacy ``engine=`` override
  wins over the policy-derived engine.
* Multi-worker: per-worker shard streams combine into one global truncated
  SVD via ``merge_streams`` (the ``repro.dist.merge`` log-depth tree).

The LM engine (``serve.engine``) serves tokens; this serves spectra.
"""

from __future__ import annotations

import threading
from collections import OrderedDict, deque
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.api import SvdState, UpdatePolicy, as_state
from repro.api.update import engine_from_key
from repro.core.engine import (
    SvdEngine,
    group_indices,
    stack_trees,
    truncated_geometry,
    unstack_tree,
)
from repro.core.svd_update import TruncatedSvd
from repro.dist.merge import merge_tree

__all__ = ["SvdService", "SvdServiceStats"]


@dataclass
class SvdServiceStats:
    enqueued: int = 0
    applied: int = 0
    flushes: int = 0
    rounds: int = 0          # batched engine calls (one per geometry group)
    max_batch: int = 0       # largest batch (incl. bucket padding) dispatched


def _bucket(b: int, cap: int) -> int:
    """Smallest power of two >= b (clamped to cap) — bounds plan-cache size."""
    p = 1
    while p < b:
        p <<= 1
    return min(p, max(cap, 1))


class SvdService:
    """Micro-batching front end over the batched truncated-update engine."""

    def __init__(
        self,
        *,
        engine: SvdEngine | None = None,
        method: str = "direct",
        max_batch: int = 64,
        pad_to_bucket: bool = True,
        policy: UpdatePolicy | None = None,
    ):
        self.policy = policy if policy is not None else UpdatePolicy(method=method)
        self.engine = engine            # explicit override; None -> policy-derived
        self.max_batch = max_batch
        self.pad_to_bucket = pad_to_bucket
        self.stats = SvdServiceStats()
        self._streams: OrderedDict[str, SvdState] = OrderedDict()
        self._pending: dict[str, deque] = {}
        self._lock = threading.RLock()

    def _engine_for(self, rank: int) -> SvdEngine:
        if self.engine is not None:
            return self.engine
        return engine_from_key(self.policy, rank + 1)

    # -- stream lifecycle ---------------------------------------------------

    def register(self, stream_id: str, state) -> None:
        """Create (or replace) a stream with its current truncated SVD
        (any container — coerced to ``SvdState``).

        Replacing drops any pending pairs — they were queued against the old
        state (and may not even match the new geometry).
        """
        with self._lock:
            self._streams[stream_id] = as_state(state)
            self._pending[stream_id] = deque()

    def evict(self, stream_id: str) -> SvdState:
        """Drop a stream and return its state with its OWN queue applied.

        Other streams' pending pairs are left queued — eviction of one user
        must not advance anyone else's state.
        """
        with self._lock:
            state = self._streams.pop(stream_id)
            queue = self._pending.pop(stream_id, deque())
            for a, b in queue:
                state = self._apply_one(state, a, b)
                self.stats.applied += 1
            return state

    def _apply_one(self, state: SvdState, a, b) -> SvdState:
        eng = self._engine_for(state.rank)
        t = eng.update_truncated(TruncatedSvd(state.u, state.s, state.v), a, b)
        return SvdState(u=t.u, s=t.s, v=t.v)

    def state(self, stream_id: str) -> SvdState:
        """Current state — pending (unflushed) pairs are NOT yet applied."""
        with self._lock:
            return self._streams[stream_id]

    def merge_streams(
        self,
        stream_ids,
        *,
        target: str | None = None,
        rank: int | None = None,
    ) -> SvdState:
        """Hierarchically merge several streams into one truncated SVD.

        The multi-worker story: each worker feeds its own stream (a shard
        tracker over its row block of a logically-shared matrix — per-tenant
        gradient sketches, federated covariance shards) and the service
        periodically combines them with the log-depth rank-1-update merge
        (``repro.dist.merge.merge_tree``) — row blocks concatenate in
        ``stream_ids`` order.  Each stream's OWN pending pairs are applied
        first (the merge must see current states; other streams' queues are
        untouched).  With ``target`` the result is registered as a new
        stream; the source streams keep evolving independently.

        The snapshot (queue drain) happens under the service lock; the
        log-depth merge itself — including its first-call jit compile —
        runs OUTSIDE it, so concurrent ``enqueue``/``flush`` traffic on
        other streams is never stalled.  The merge reflects the states as
        of the snapshot.
        """
        with self._lock:
            states = []
            for sid in stream_ids:
                state = self._streams[sid]
                queue = self._pending[sid]
                while queue:
                    a, b = queue.popleft()
                    state = self._apply_one(state, a, b)
                    self.stats.applied += 1
                self._streams[sid] = state
                states.append(state)
        merged = merge_tree(states, rank=rank, engine=self.engine,
                            policy=self.policy)
        if target is not None:
            with self._lock:
                self.register(target, merged)
        return merged

    def pending(self, stream_id: str | None = None) -> int:
        with self._lock:
            if stream_id is not None:
                return len(self._pending[stream_id])
            return sum(len(q) for q in self._pending.values())

    # -- the hot path -------------------------------------------------------

    def enqueue(self, stream_id: str, a: jax.Array, b: jax.Array) -> None:
        """Queue one rank-1 perturbation ``a b^T`` for a stream.

        Auto-flushes when ``max_batch`` streams have a pending head pair.
        """
        with self._lock:
            if stream_id not in self._streams:
                raise KeyError(f"unknown stream {stream_id!r}; register() first")
            t = self._streams[stream_id]
            m, n = t.m, t.n
            if a.shape != (m,) or b.shape != (n,):
                # reject HERE: at flush time a bad pair would poison a whole
                # geometry group (pairs are popped before the engine call)
                raise ValueError(
                    f"pair shapes {a.shape}/{b.shape} do not match stream "
                    f"{stream_id!r} geometry ({m},)/({n},)"
                )
            self._pending[stream_id].append((a, b))
            self.stats.enqueued += 1
            ready = sum(1 for q in self._pending.values() if q)
            if ready >= self.max_batch:
                self._flush_round()

    def flush(self) -> int:
        """Apply ALL pending pairs (possibly several rounds); returns the
        number of updates applied."""
        with self._lock:
            applied = 0
            while any(self._pending.values()):
                applied += self._flush_round()
            return applied

    def _flush_round(self) -> int:
        """One round: at most one pending pair per stream, grouped by
        geometry, one batched engine call per group."""
        round_ids = [sid for sid, q in self._pending.items() if q]
        if not round_ids:
            return 0

        keys = [truncated_geometry(self._streams[sid]) for sid in round_ids]

        applied = 0
        for (m, n, r, dt), idxs in group_indices(keys).items():
            sids = [round_ids[i] for i in idxs]
            # peek, don't pop: if the engine call raises (first-compile OOM,
            # backend error), the pairs stay queued and a retry re-applies
            # them — flush stays failure-atomic per group
            pairs = [self._pending[sid][0] for sid in sids]
            states = [self._streams[sid] for sid in sids]
            bsz = len(sids)
            pad = 0
            if self.pad_to_bucket:
                # a group can exceed max_batch (retry after a failed flush
                # accumulates streams) — never pad negative, just dispatch big
                pad = max(0, _bucket(bsz, self.max_batch) - bsz)

            t_stack = stack_trees(
                [TruncatedSvd(s.u, s.s, s.v) for s in states]
            )
            a_stack = jnp.stack([jnp.asarray(a, dt) for a, _ in pairs])
            b_stack = jnp.stack([jnp.asarray(b, dt) for _, b in pairs])
            if pad:
                # no-op rank-1 pairs (a = b = 0); padded outputs are discarded
                t_stack = jax.tree.map(
                    lambda x: jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)]),
                    t_stack,
                )
                a_stack = jnp.concatenate([a_stack, jnp.zeros((pad, m), dt)])
                b_stack = jnp.concatenate([b_stack, jnp.zeros((pad, n), dt)])

            eng = self._engine_for(r)
            out = eng.update_truncated_batch(
                t_stack, a_stack, b_stack,
                mesh=self.policy.mesh, batch_axis=self.policy.batch_axis,
            )
            for j, sid in enumerate(sids):
                t = unstack_tree(out, j)
                self._streams[sid] = SvdState(u=t.u, s=t.s, v=t.v)
                self._pending[sid].popleft()
            applied += bsz
            self.stats.rounds += 1
            self.stats.max_batch = max(self.stats.max_batch, bsz + pad)

        self.stats.flushes += 1
        self.stats.applied += applied
        return applied
