"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required by the dry-run contract.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "batch_sharding", "batch_pad"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds a leading pod=2 axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    n = jax.device_count()
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def batch_sharding(mesh, axis: str = "data") -> jax.sharding.NamedSharding:
    """Sharding that splits a leading batch axis over one mesh axis.

    This is what ``core.engine.SvdEngine`` / ``serve.svd_service`` take to
    spread a flush of B stacked rank-1 updates across the data axis: batch
    dim sharded, every per-update dim replicated.
    """
    from jax.sharding import PartitionSpec

    return jax.sharding.NamedSharding(mesh, PartitionSpec(axis))


def batch_pad(b: int, mesh, axis: str = "data") -> int:
    """Rows of padding needed to make a batch of ``b`` divisible by the mesh
    axis (batched updates pad with no-op rank-1 pairs, results discarded)."""
    k = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    return (-b) % k
