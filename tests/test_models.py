"""Per-architecture smoke tests (assignment requirement) + decode-path
consistency: prefill+decode logits must match the full forward pass."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ShapeConfig
from repro.models.registry import build_model, zeros_like_specs

RNG = np.random.default_rng(0)
TRAIN_SHAPE = ShapeConfig("train_small", 32, 2, "train")
DECODE_SHAPE = ShapeConfig("decode_small", 32, 2, "decode")


def _concrete_batch(specs, vocab):
    return jax.tree.map(
        lambda s: (jnp.asarray(RNG.integers(0, vocab, s.shape), jnp.int32)
                   if s.dtype == jnp.int32
                   else jnp.asarray(RNG.normal(size=s.shape) * 0.02, s.dtype)),
        specs,
    )


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_train_step(arch):
    """Reduced same-family config: one fwd/bwd step, shapes + finiteness."""
    cfg = configs.get_smoke(arch)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = _concrete_batch(api.input_specs(TRAIN_SHAPE)["batch"], cfg.vocab_size)
    loss, grads = jax.value_and_grad(api.train_loss)(params, batch)
    assert np.isfinite(float(loss))
    assert 1.0 < float(loss) < 20.0  # ~ln(vocab) at init
    for g in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(g)))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = configs.get_smoke(arch)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    specs = api.input_specs(DECODE_SHAPE)
    cache = zeros_like_specs(specs["cache"])
    token = jnp.zeros(specs["token"].shape, jnp.int32)
    logits, cache2 = api.decode_step(params, cache, token, jnp.asarray(3, jnp.int32))
    assert logits.shape[0] == DECODE_SHAPE.global_batch
    assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab_size])))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["qwen1.5-32b", "granite-34b", "deepseek-v2-lite-16b",
                                  "rwkv6-1.6b", "zamba2-7b", "whisper-base"])
def test_prefill_decode_matches_forward(arch):
    """The decode path must reproduce the training-forward logits: prefill a
    prompt, decode the next tokens one by one, compare against the full
    causal forward on the whole sequence."""
    cfg = configs.get_smoke(arch)
    if cfg.moe is not None:
        # capacity dropping is batch-composition-dependent by design (GShard
        # semantics), which breaks bitwise prefill/forward equivalence; make
        # the router dropless so this test isolates the MLA/attention caches.
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_routed) / cfg.moe.top_k))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    b, total = 2, 16
    prompt_len = 8
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, total)), jnp.int32)

    if cfg.encdec:
        frames = jnp.asarray(RNG.normal(size=(b, 16, cfg.d_model)) * 0.02,
                             jnp.dtype(cfg.compute_dtype))
        from repro.models.encdec import encdec_forward
        full = encdec_forward(params, {"frames": frames, "tokens": toks}, cfg)
        logits_p, cache = api.prefill(params, {"frames": frames, "tokens": toks[:, :prompt_len]},
                                      max_dec_len=total)
        np.testing.assert_allclose(np.asarray(logits_p[:, -1]),
                                   np.asarray(full[:, prompt_len - 1]), rtol=3e-4, atol=3e-4)
        logits_d, cache = api.decode_step(params, cache, toks[:, prompt_len:prompt_len + 1],
                                          jnp.asarray(prompt_len, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                                   np.asarray(full[:, prompt_len]), rtol=3e-4, atol=3e-4)
        return

    if cfg.rwkv is not None:
        from repro.models.rwkv_model import rwkv_train_loss  # noqa: F401  (api covers it)
    # full forward logits
    if cfg.ssm is not None and cfg.attn_every:
        from repro.models.hybrid import hybrid_forward as fwd
    elif cfg.rwkv is not None:
        from repro.models.rwkv_model import _run_layers, _logits
        from repro.models.layers import embed_lookup, norm_apply

        def fwd(p, batch, c):
            x = embed_lookup(batch["tokens"], p["embed"])
            x, _ = _run_layers(x, p, c)
            x = norm_apply(x, p["final_norm"], c.norm_type)
            return _logits(x, p, c)
    else:
        from repro.models.transformer import decoder_forward as fwd

    full = fwd(params, {"tokens": toks}, cfg)

    logits_p, cache = api.prefill(params, {"tokens": toks[:, :prompt_len]}, max_len=total) \
        if cfg.rwkv is None else api.prefill(params, {"tokens": toks[:, :prompt_len]})
    np.testing.assert_allclose(np.asarray(logits_p[:, -1]),
                               np.asarray(full[:, prompt_len - 1]), rtol=3e-4, atol=3e-4)

    for i in range(prompt_len, min(prompt_len + 3, total)):
        logits_d, cache = api.decode_step(params, cache, toks[:, i:i + 1],
                                          jnp.asarray(i, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits_d[:, 0]), np.asarray(full[:, i]),
                                   rtol=3e-4, atol=3e-4,
                                   err_msg=f"decode step at pos {i} diverges from forward")


def test_rwkv_chunked_matches_recurrent():
    """The chunked WKV (training path) must equal the recurrence exactly."""
    from repro.models.rwkv import _wkv_chunked, wkv_recurrent

    b, l, h, dk = 2, 32, 3, 8
    r = jnp.asarray(RNG.normal(size=(b, l, h, dk)))
    k = jnp.asarray(RNG.normal(size=(b, l, h, dk)))
    v = jnp.asarray(RNG.normal(size=(b, l, h, dk)))
    logw = -jnp.asarray(RNG.uniform(0.01, 0.3, size=(b, l, h, dk)))
    u = jnp.asarray(RNG.normal(size=(h, dk)))
    s0 = jnp.asarray(RNG.normal(size=(b, h, dk, dk)))
    y_ref, s_ref = wkv_recurrent(r, k, v, logw, u, s0)
    y_chk, s_chk = _wkv_chunked(r, k, v, logw, u, s0, chunk=8)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref), atol=1e-10)
    np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_ref), atol=1e-10)


def test_ssm_decode_matches_train():
    """Mamba2: chunked training outputs == step-by-step decode outputs."""
    from repro.models import ssm as ssm_mod

    cfg = configs.get_smoke("zamba2-7b")
    p = ssm_mod.ssm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, l = 2, 32
    x = jnp.asarray(RNG.normal(size=(b, l, cfg.d_model)) * 0.1, jnp.float32)
    y_train = ssm_mod.ssm_train(x, p, cfg)
    st = ssm_mod.init_ssm_state(b, cfg, jnp.float32)
    outs = []
    for t in range(l):
        y, st = ssm_mod.ssm_decode(x[:, t:t + 1], p, cfg, st)
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train), atol=2e-4)


def test_moe_routing_respects_capacity():
    from repro.models import moe as moe_mod

    cfg = configs.get_smoke("deepseek-moe-16b")
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    y = moe_mod.moe_apply(x, p, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    # aux loss is ~1 for balanced routing at init
    aux = moe_mod.moe_aux_loss(x, p, cfg)
    assert 0.5 < float(aux) < float(cfg.moe.n_routed)


def test_flash_attention_matches_full():
    """Blockwise (flash) attention is exact vs vanilla attention."""
    from repro.models.attention import _sdpa_blockwise, _sdpa_full

    cfg = configs.get_smoke("qwen2-72b").replace(attn_block_k=16, compute_dtype="float64")
    b, sq, h, kvh, dh = 2, 64, 8, 2, 16
    cfg = cfg.replace(n_heads=h, n_kv_heads=kvh)
    q = jnp.asarray(RNG.normal(size=(b, sq, h, dh)))
    k = jnp.asarray(RNG.normal(size=(b, sq, kvh, dh)))
    v = jnp.asarray(RNG.normal(size=(b, sq, kvh, dh)))
    for causal in (True, False):
        full = _sdpa_full(q, k, v, cfg, causal)
        blk = _sdpa_blockwise(q, k, v, cfg, causal)
        np.testing.assert_allclose(np.asarray(blk), np.asarray(full), atol=5e-6)


def test_flash_attention_train_loss_matches():
    cfg = configs.get_smoke("granite-34b")
    api_full = build_model(cfg)
    api_flash = build_model(cfg.replace(attn_block_k=8))
    params = api_full.init(jax.random.PRNGKey(0))
    batch = _concrete_batch(api_full.input_specs(TRAIN_SHAPE)["batch"], cfg.vocab_size)
    l1 = float(api_full.train_loss(params, batch))
    l2 = float(api_flash.train_loss(params, batch))
    assert abs(l1 - l2) < 1e-4


def test_int8_kv_cache_decode_close_to_fp():
    """int8 KV decode logits track the fp cache closely (quantized serving)."""
    cfg = configs.get_smoke("qwen2-72b")
    api_fp = build_model(cfg)
    api_q = build_model(cfg.replace(kv_cache_dtype="int8"))
    params = api_fp.init(jax.random.PRNGKey(1))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 12)), jnp.int32)
    _, cache_fp = api_fp.prefill(params, {"tokens": toks[:, :8]}, max_len=12)
    # build the int8 cache by decoding the same prefix token by token
    from repro.models.registry import zeros_like_specs

    specs = api_q.input_specs(ShapeConfig("d", 12, 2, "decode"))
    cache_q = zeros_like_specs(specs["cache"])
    for i in range(8):
        logits_q, cache_q = api_q.decode_step(params, cache_q, toks[:, i:i + 1],
                                              jnp.asarray(i, jnp.int32))
    logits_fp, _ = api_fp.decode_step(params, cache_fp, toks[:, 8:9],
                                      jnp.asarray(8, jnp.int32))
    logits_q, _ = api_q.decode_step(params, cache_q, toks[:, 8:9],
                                    jnp.asarray(8, jnp.int32))
    a = np.asarray(logits_fp[..., :cfg.vocab_size])
    b = np.asarray(logits_q[..., :cfg.vocab_size])
    # int8 quantization noise is small relative to logit scale
    assert np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9) < 0.05
    # and the argmax (greedy token) agrees
    np.testing.assert_array_equal(np.argmax(a, -1), np.argmax(b, -1))


def test_remat_policy_dots_matches_loss():
    cfg = configs.get_smoke("nemotron-4-15b").replace(remat=True)
    api_full = build_model(cfg.replace(remat_policy="full"))
    api_dots = build_model(cfg.replace(remat_policy="dots"))
    params = api_full.init(jax.random.PRNGKey(0))
    batch = _concrete_batch(api_full.input_specs(TRAIN_SHAPE)["batch"], cfg.vocab_size)
    l1, g1 = jax.value_and_grad(api_full.train_loss)(params, batch)
    l2, g2 = jax.value_and_grad(api_dots.train_loss)(params, batch)
    assert abs(float(l1) - float(l2)) < 1e-5
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
