"""RWKV-6 decoder-only model wrapper (attention-free)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import rwkv as rw
from repro.models.transformer import remat_wrap, scan_or_unroll
from repro.models.layers import (
    cross_entropy,
    embed_init,
    embed_lookup,
    norm_apply,
    norm_init,
    uniform_init,
)

__all__ = [
    "rwkv_model_init",
    "rwkv_train_loss",
    "rwkv_prefill",
    "rwkv_decode_step",
    "rwkv_state_spec",
]


def _layer_init(key, cfg, dtype):
    return {
        "ln1": norm_init(cfg.d_model, cfg.norm_type, dtype),
        "ln2": norm_init(cfg.d_model, cfg.norm_type, dtype),
        "mix": rw.rwkv_init(key, cfg, dtype),
    }


def rwkv_model_init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    return {
        "embed": embed_init(ks[1], cfg.padded_vocab, cfg.d_model, dtype),
        "layers": jax.vmap(partial(_layer_init, cfg=cfg, dtype=dtype))(layer_keys),
        "final_norm": norm_init(cfg.d_model, cfg.norm_type, dtype),
        "head": uniform_init(ks[2], (cfg.d_model, cfg.padded_vocab), cfg.d_model ** -0.5, dtype),
    }


def _logits(x, params, cfg):
    cd = jnp.dtype(cfg.compute_dtype)
    logits = jnp.matmul(x.astype(cd), params["head"].astype(cd),
                        preferred_element_type=jnp.float32)
    vmask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    return jnp.where(vmask[None, None, :], logits, -1e30)


def _run_layers(x, params, cfg, states=None, *, collect_states=False):
    """states: per-layer stacked {tm_x, wkv, cm_x} or None (zeros)."""
    b = x.shape[0]
    if states is None:
        zero = rw.init_rwkv_state(b, cfg, x.dtype)
        states = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), zero
        )

    def body(carry, xs):
        lp, st = xs
        h = carry
        tm_in = norm_apply(h, lp["ln1"], cfg.norm_type)
        tm_out, (tm_x, wkv) = rw.rwkv_time_mix_train(tm_in, lp["mix"], cfg, st["tm_x"], st["wkv"])
        h = h + tm_out
        cm_in = norm_apply(h, lp["ln2"], cfg.norm_type)
        cm_out, cm_x = rw.rwkv_channel_mix_train(cm_in, lp["mix"], cfg, st["cm_x"])
        h = h + cm_out
        return h, {"tm_x": tm_x, "wkv": wkv, "cm_x": cm_x}

    body = remat_wrap(body, cfg)
    x, new_states = scan_or_unroll(body, x, (params["layers"], states), cfg)
    return x, new_states


def rwkv_train_loss(params, batch, cfg):
    x = embed_lookup(batch["tokens"], params["embed"])
    x, _ = _run_layers(x, params, cfg)
    x = norm_apply(x, params["final_norm"], cfg.norm_type)
    return cross_entropy(_logits(x, params, cfg), batch["labels"], cfg.vocab_size)


def rwkv_state_spec(cfg, batch, dtype):
    d = cfg.d_model
    h = d // cfg.rwkv.head_dim
    hd = cfg.rwkv.head_dim
    L = cfg.n_layers
    return {
        "tm_x": jax.ShapeDtypeStruct((L, batch, d), dtype),
        "wkv": jax.ShapeDtypeStruct((L, batch, h, hd, hd), jnp.float32),
        "cm_x": jax.ShapeDtypeStruct((L, batch, d), dtype),
    }


def rwkv_prefill(params, batch, cfg):
    """Prompt pass; returns (last logits, per-layer states) — O(1) state size,
    which is what makes the 500k-context decode shape viable (DESIGN.md)."""
    x = embed_lookup(batch["tokens"], params["embed"])
    x, states = _run_layers(x, params, cfg)
    x = norm_apply(x, params["final_norm"], cfg.norm_type)
    return _logits(x[:, -1:, :], params, cfg), states


def rwkv_decode_step(params, states, token, pos, cfg):
    del pos  # position-free architecture
    x = embed_lookup(token, params["embed"])

    def body(carry, xs):
        lp, st = xs
        h = carry
        tm_in = norm_apply(h, lp["ln1"], cfg.norm_type)
        tm_out, st2 = rw.rwkv_decode_step(tm_in, lp["mix"], cfg, st)
        h = h + tm_out
        cm_in = norm_apply(h, lp["ln2"], cfg.norm_type)
        cm_out, cm_x = rw.rwkv_channel_mix_decode(cm_in, lp["mix"], cfg, st)
        h = h + cm_out
        st2 = {"tm_x": st2["tm_x"], "wkv": st2["wkv"], "cm_x": cm_x}
        return h, st2

    x, new_states = scan_or_unroll(body, x, (params["layers"], states), cfg)
    x = norm_apply(x, params["final_norm"], cfg.norm_type)
    return _logits(x, params, cfg), new_states
