"""FAST baseline (Gerasoulis): correct in the paper's range, documented
instability beyond it (the reason the paper moves to FMM)."""

import numpy as np
import pytest

from repro.core.fast import fast_cauchy_matvec, multipoint_eval, poly_from_roots

RNG = np.random.default_rng(0)


def _direct(u, lam, mu):
    return np.sum(u[None, :] / (lam[None, :] - mu[:, None]), axis=1)


@pytest.mark.parametrize("n", [4, 8, 16])
def test_fast_small_n_accurate(n):
    """In the regime the paper actually benchmarked (n <= 35, Fig. 1)."""
    lam = np.sort(RNG.uniform(0, 1, n))
    mu = np.sort(RNG.uniform(0, 1, n)) + 1e-5
    u = RNG.normal(size=n)
    out = fast_cauchy_matvec(u, lam, mu)
    ref = _direct(u, lam, mu)
    rel = np.max(np.abs(out - ref)) / np.max(np.abs(ref))
    assert rel < 1e-6


def test_fast_instability_documented():
    """Power-basis arithmetic degrades catastrophically with n — faithful to
    the known behaviour of the FAST algorithm (why the paper adopts FMM)."""
    errs = {}
    for n in [8, 64]:
        lam = np.sort(RNG.uniform(0, 1, n))
        mu = np.sort(RNG.uniform(0, 1, n)) + 1e-5
        u = RNG.normal(size=n)
        out = fast_cauchy_matvec(u, lam, mu)
        ref = _direct(u, lam, mu)
        errs[n] = np.max(np.abs(out - ref)) / np.max(np.abs(ref))
    assert errs[8] < 1e-6
    assert errs[64] > 1e3  # blows up, as documented in EXPERIMENTS.md


def test_poly_from_roots():
    roots = np.array([1.0, -2.0, 3.0])
    c = poly_from_roots(roots)  # (x-1)(x+2)(x-3) = x^3 -2x^2 -5x + 6
    np.testing.assert_allclose(c, [6.0, -5.0, -2.0, 1.0], atol=1e-12)


def test_multipoint_eval_matches_horner():
    coeffs = RNG.normal(size=20)
    pts = RNG.uniform(-1, 1, 50)
    tree = multipoint_eval(coeffs, pts)
    horner = np.polyval(coeffs[::-1], pts)
    np.testing.assert_allclose(tree, horner, rtol=1e-8, atol=1e-8)
