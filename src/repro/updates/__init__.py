"""``repro.updates`` — structured perturbations lowered onto the rank-1
engine (DESIGN.md §10).

Declarative ops (``RankK``, ``AppendRows``/``AppendCols``, ``DenseDelta``,
``Decay``, ``Compose``) with exact dense reference semantics, and a planner
that compiles any of them into a minimal schedule of plan-cached
``repro.api`` rank-1 dispatches:

    from repro import api
    from repro.updates import RankK, Decay, Compose

    state = api.SvdState.from_dense(x, rank=8)
    op = Compose((Decay(0.99), RankK(u_block, v_block)))   # forget + absorb
    state = api.apply(state, op)                           # planned schedule

``api.apply`` / ``api.apply_many`` are the public entry points; the module
surface here is for building ops and inspecting the planner.
"""

from repro.updates.ops import (
    AppendCols,
    AppendRows,
    Compose,
    Decay,
    DenseDelta,
    RankK,
    UpdateOp,
    skeleton_from_spec,
    spec_from_json,
    spec_to_json,
)
from repro.updates.planner import (
    apply,
    apply_many,
    lower,
    schedule_cache_clear,
    schedule_cache_info,
    warmup_plan,
)

__all__ = [
    "AppendCols",
    "AppendRows",
    "Compose",
    "Decay",
    "DenseDelta",
    "RankK",
    "UpdateOp",
    "apply",
    "apply_many",
    "lower",
    "schedule_cache_clear",
    "schedule_cache_info",
    "skeleton_from_spec",
    "spec_from_json",
    "spec_to_json",
    "warmup_plan",
]
