"""Spectral AdamW: AdamW with streaming-SVD low-rank moment projection.

The paper-technique optimizer (DESIGN.md §3.1) as a drop-in train-loop
policy: every 2-D parameter with min(m, n) > 4*rank keeps

  * a SpectralState (streaming truncated SVD of its gradient history,
    maintained by the api's truncated rank-1 route — the paper's Algorithm
    6.1 on the Brand-augmented core), and
  * Adam moments in the (rank, n) projected space instead of (m, n):
    memory for moments shrinks by ~m/rank.

Per step and per projected parameter:
  1. fold the fresh gradient's dominant rank-1 into the tracker
     (``update_every`` controls cadence),
  2. G_p = U_r^T G;  Adam moment update in projected space;
  3. delta = U_r @ adam(G_p)  back in parameter space (+ weight decay).

Non-2-D (norms, biases) and small parameters fall through to dense AdamW.

Basis refresh (``OptimizerConfig.basis_refresh_every``): every N steps each
tracker is passed through ``optim.compression.agree_tracker`` — under
data-parallel shard_map (``axis_name=``) that merges per-worker trackers
into one consensus basis (the ``agree_basis`` machinery); on a single
worker it degrades to a local re-factorization that restores the
orthonormal-basis invariant long streams erode.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.engine import group_indices, stack_trees, unstack_tree
from repro.optim.compression import agree_tracker
from repro.optim.spectral import (
    SpectralState,
    project,
    spectral_init,
    spectral_update_basis_grouped,
    unproject,
)

__all__ = ["SpectralAdamState", "spectral_adam_init", "spectral_adam_update"]


class _LeafState(NamedTuple):
    spectral: SpectralState | None
    m: jax.Array
    v: jax.Array


class SpectralAdamState(NamedTuple):
    step: jax.Array
    leaves: object  # pytree of _LeafState


def _eligible(p, rank):
    return p.ndim == 2 and min(p.shape) > 4 * rank


def spectral_adam_init(key, params, *, rank: int = 32) -> SpectralAdamState:
    flat, treedef = jax.tree.flatten(params)
    keys = jax.random.split(key, max(len(flat), 1))
    leaves = []
    for k, p in zip(keys, flat):
        if _eligible(p, rank):
            m, n = p.shape
            leaves.append(_LeafState(
                spectral=spectral_init(k, m, n, rank),
                m=jnp.zeros((rank, n), jnp.float32),
                v=jnp.zeros((rank, n), jnp.float32),
            ))
        else:
            leaves.append(_LeafState(
                spectral=None,
                m=jnp.zeros_like(p, dtype=jnp.float32),
                v=jnp.zeros_like(p, dtype=jnp.float32),
            ))
    return SpectralAdamState(step=jnp.zeros((), jnp.int32),
                             leaves=jax.tree.unflatten(treedef, [(l,) for l in leaves]))


def spectral_adam_update(
    grads,
    state: SpectralAdamState,
    params,
    *,
    lr,
    betas=(0.9, 0.95),
    eps=1e-8,
    weight_decay=0.1,
    update_basis_every: int = 1,
    basis_refresh_every: int = 0,
    axis_name=None,
):
    b1, b2 = betas
    step = state.step + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_p = treedef.flatten_up_to(params)
    flat_s = [t[0] for t in jax.tree.leaves(
        state.leaves, is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], _LeafState))]
    # fallback flatten: leaves stored as 1-tuples of _LeafState
    if len(flat_s) != len(flat_g):
        flat_s = [t for t in jax.tree.leaves(
            state.leaves, is_leaf=lambda x: isinstance(x, _LeafState))]

    # Batched basis refresh: eligible leaves are grouped by geometry and
    # updated with one engine call per group (core.engine), instead of one
    # single truncated-update dispatch per parameter.
    elig = [i for i, s in enumerate(flat_s) if s.spectral is not None]
    new_specs: dict[int, SpectralState] = {}
    if elig:
        do_update = (step % update_basis_every) == 0
        spec_in = tuple(flat_s[i].spectral for i in elig)
        g_in = tuple(flat_g[i].astype(jnp.float32) for i in elig)
        updated = jax.lax.cond(
            do_update,
            lambda ops: spectral_update_basis_grouped(ops[0], ops[1]),
            lambda ops: ops[0],
            (spec_in, g_in),
        )
        # basis refresh cadence: consensus/re-factorization via the
        # compression layer's agree_tracker (OptimizerConfig.basis_refresh_every)
        if basis_refresh_every:
            def _refresh(specs):
                if axis_name is not None:
                    # collectives inside agree_tracker can't cross a vmap —
                    # refresh per leaf under shard_map
                    return tuple(
                        SpectralState(
                            tracker=agree_tracker(s.tracker, axis_name=axis_name)[0],
                            power_v=s.power_v,
                            step=s.step,
                        )
                        for s in specs
                    )
                # local refresh: one vmapped re-factorization per geometry
                # group instead of a per-leaf subgraph each
                out = list(specs)
                geos = [(s.tracker.u.shape, s.tracker.v.shape) for s in specs]
                for idxs in group_indices(geos).values():
                    stacked = stack_trees([specs[i].tracker for i in idxs])
                    refreshed = jax.vmap(
                        lambda t: agree_tracker(t, axis_name=None)[0]
                    )(stacked)
                    for j, i in enumerate(idxs):
                        out[i] = SpectralState(
                            tracker=unstack_tree(refreshed, j),
                            power_v=out[i].power_v,
                            step=out[i].step,
                        )
                return tuple(out)

            updated = jax.lax.cond(
                (step % basis_refresh_every) == 0,
                _refresh,
                lambda specs: specs,
                updated,
            )
        new_specs = dict(zip(elig, updated))

    new_p, new_s = [], []
    for i, (g, p, s) in enumerate(zip(flat_g, flat_p, flat_s)):
        gf = g.astype(jnp.float32)
        if s.spectral is not None:
            spec = new_specs[i]
            gp = project(spec, gf)                      # (r, n)
            m2 = b1 * s.m + (1 - b1) * gp
            v2 = b2 * s.v + (1 - b2) * gp * gp
            upd_p = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
            delta = unproject(spec, upd_p)              # (m, n)
            p2 = p.astype(jnp.float32) - lr * (delta + weight_decay * p.astype(jnp.float32))
            new_s.append(_LeafState(spectral=spec, m=m2, v=v2))
        else:
            m2 = b1 * s.m + (1 - b1) * gf
            v2 = b2 * s.v + (1 - b2) * gf * gf
            upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
            p2 = p.astype(jnp.float32) - lr * (upd + weight_decay * p.astype(jnp.float32))
            new_s.append(_LeafState(spectral=None, m=m2, v=v2))
        new_p.append(p2.astype(p.dtype))

    leaves = jax.tree.unflatten(treedef, [(l,) for l in new_s])
    return (jax.tree.unflatten(treedef, new_p),
            SpectralAdamState(step=step, leaves=leaves))


def moment_memory_ratio(params, rank: int) -> float:
    """Dense-Adam moment floats / spectral-Adam moment+tracker floats."""
    dense = proj = 0
    for p in jax.tree.leaves(params):
        n_el = 1
        for d in p.shape:
            n_el *= d
        dense += 2 * n_el
        if _eligible(p, rank):
            m, n = p.shape
            proj += 2 * rank * n + (m + n + 1) * rank + n
        else:
            proj += 2 * n_el
    return dense / max(proj, 1)
