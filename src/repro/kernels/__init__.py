"""Pallas TPU kernels for the paper's compute hot-spots.

cauchy_matmul   — on-the-fly U1 @ C(lambda, mu) (Trummer, MXU)
secular_newton  — in-VMEM secular-equation bisection+Newton (VPU)
nearfield       — FMM near-field block-tridiagonal product (MXU)
fused_update    — the whole rank-1 update (Alg. 6.1) in one (B,)-grid kernel
secular_body    — the ONE bisection/Newton loop body the above share
sparse_proj     — COO gather/scatter projection out = S @ mat (SMEM coords,
                  batch-in-grid custom_vmap) for the Sparse op's O(nnz)
                  lowering via updates.sketch (DESIGN.md §12)

Each has a pure-jnp oracle in ref.py (sparse_proj's is its XLA segment-sum
fallback); ops.py is the dispatching jit wrapper (interpret=True on CPU,
Mosaic on TPU). core.eigh_update routes here via method="kernel";
core.svd_update routes the megakernel via method="fused".
"""

from repro.kernels import ops, ref  # noqa: F401
