"""Paper Fig. 1/2: rank-1 update runtime — FAST vs FMM (vs direct, kernel).

The paper times the first rank-1 update (Eq. A.6 / 31) for n = 2..35 and
extrapolates. We time the same computation (one symmetric eigen-update of
U D U^T + rho a a^T, singular-vector rotation included) for FAST, FMM,
dense-direct and the Pallas kernel path, across a larger n range. CSV:
  fig1_2/<method>/n=<n>,us,<notes>
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn, time_host_fn
from repro.core.eigh_update import apply_update, make_plan
from repro.core.fast import fast_cauchy_matmul

SIZES = [8, 16, 32, 64, 128, 256, 512, 1024]
FAST_MAX = 64  # beyond this FAST output is numerically meaningless (see tests)


def run() -> None:
    rng = np.random.default_rng(0)
    for n in SIZES:
        d = np.sort(rng.uniform(1, 9, n))
        z = rng.normal(size=n)
        rho = 1.3
        u = np.linalg.qr(rng.normal(size=(n, n)))[0]
        dj, zj, uj = jnp.asarray(d), jnp.asarray(z), jnp.asarray(u)
        rhoj = jnp.asarray(rho)

        for method, build_fmm in [("direct", False), ("fmm", True), ("kernel", False)]:
            plan = make_plan(dj, zj, rhoj, rho_positive=True, build_fmm=build_fmm)
            fn = jax.jit(lambda w, p=plan, m=method: apply_update(p, w, method=m))
            us = time_fn(fn, uj)
            emit(f"fig1_2/{method}/n={n}", us, "apply-only")

            # full update including plan construction (secular solve etc.)
            def full(dd, zz, w, m=method, bf=build_fmm):
                p = make_plan(dd, zz, rhoj, rho_positive=True, build_fmm=bf)
                return apply_update(p, w, method=m)

            us_full = time_fn(jax.jit(full), dj, zj, uj)
            emit(f"fig1_2/{method}_full/n={n}", us_full, "plan+apply")

        if n <= FAST_MAX:
            mu = np.sort(d + rng.uniform(1e-4, 1e-2, n))  # stand-in targets
            us = time_host_fn(fast_cauchy_matmul, u, d, mu)
            emit(f"fig1_2/fast/n={n}", us, "numpy-host; unstable-beyond-24 (documented)")


if __name__ == "__main__":
    run()
