"""Training loop, state, checkpointing, elasticity."""
