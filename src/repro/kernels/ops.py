"""Dispatching jit wrappers for the Pallas kernels.

On CPU (this container) the kernels run in ``interpret=True`` mode — the
kernel bodies execute as written, which is how correctness is validated.
On TPU they compile to Mosaic. ``core.eigh_update`` calls these through
``method="kernel"``.

Batching: the Cauchy product carries a ``custom_vmap`` rule, so a
``jax.vmap`` over the kernel path (what ``core.engine`` does for batched
SVD updates) lowers to ONE ``cauchy_matmul_pallas_batched`` launch with the
batch axis folded into the Pallas grid — not B sequential kernel calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import custom_batching

from repro.kernels.cauchy_matmul import cauchy_matmul_pallas, cauchy_matmul_pallas_batched
from repro.kernels.fused_update import (
    fused_update_pallas,
    fused_update_pallas_batched,
    fused_update_truncated_pallas,
    fused_update_truncated_pallas_batched,
    fused_update_truncated_xla,
    fused_update_xla,
)
from repro.kernels.nearfield import nearfield_pallas
from repro.kernels.secular_newton import secular_solve_pallas

__all__ = [
    "interpret_default",
    "cauchy_matmul_stable",
    "secular_solve",
    "nearfield",
    "fused_update",
    "fused_update_truncated",
]


def interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@custom_batching.custom_vmap
def _cauchy_pallas(w, src, anchor_vals, tau, tgt_mask):
    return cauchy_matmul_pallas(
        w, src, anchor_vals, tau, tgt_mask, interpret=interpret_default()
    )


@_cauchy_pallas.def_vmap
def _cauchy_pallas_vmap(axis_size, in_batched, w, src, anchor_vals, tau, tgt_mask):
    def bcast(x, batched):
        return x if batched else jnp.broadcast_to(x, (axis_size,) + x.shape)

    args = [bcast(x, b) for x, b in zip((w, src, anchor_vals, tau, tgt_mask), in_batched)]
    w_b = args[0]
    if w_b.ndim > 3:  # nested vmap: collapse leading axes into one batch
        lead = w_b.shape[: w_b.ndim - 2]
        args = [x.reshape((-1,) + x.shape[len(lead):]) for x in args]
        out = cauchy_matmul_pallas_batched(*args, interpret=interpret_default())
        return out.reshape(lead + out.shape[1:]), True
    out = cauchy_matmul_pallas_batched(*args, interpret=interpret_default())
    return out, True


def cauchy_matmul_stable(
    w: jax.Array,
    src: jax.Array,
    anchor: jax.Array,
    tau: jax.Array,
    *,
    src_valid: jax.Array | None = None,
    tgt_valid: jax.Array | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Kernel-backed drop-in for core.cauchy.cauchy_matmul_stable.

    Note the sign convention: returns sum_j w_j/(src_j - mu_i) (Cauchy
    orientation), same as the core function. vmap-ing this folds the batch
    into the Pallas grid (see module docstring).
    """
    n = src.shape[0]
    m = anchor.shape[0]
    if src_valid is None:
        src_valid = jnp.ones((n,), bool)
    if tgt_valid is None:
        tgt_valid = jnp.ones((m,), bool)
    w_masked = jnp.where(src_valid[None, :], w, 0.0)
    anchor_vals = src[anchor]
    if interpret is not None:  # explicit override skips the custom_vmap path
        return cauchy_matmul_pallas(
            w_masked, src, anchor_vals, tau, tgt_valid, interpret=interpret
        )
    return _cauchy_pallas(w_masked, src, anchor_vals, tau, tgt_valid)


def secular_solve(
    dc, zc2, rho, anchor_vals, lo, hi, *, n_bisect=58, n_newton=4, interpret=None
):
    if interpret is None:
        interpret = interpret_default()
    return secular_solve_pallas(
        dc, zc2, rho, anchor_vals, lo, hi,
        n_bisect=n_bisect, n_newton=n_newton, interpret=interpret,
    )


def nearfield(w_near, x_near, av_b, tau_b, tgt_mask, *, interpret=None):
    if interpret is None:
        interpret = interpret_default()
    return nearfield_pallas(w_near, x_near, av_b, tau_b, tgt_mask, interpret=interpret)


# --- fused rank-1 update (kernels.fused_update) ---------------------------
#
# On TPU the single-update entry carries a custom_vmap rule (one factory per
# static config), so ``jax.vmap`` — what core.engine does for batched
# updates — lowers to ONE fused_update_pallas_batched launch with the batch
# folded into the Pallas grid.  Off-TPU the body runs as a plain XLA fusion
# (fused_update_xla), which vmaps natively; interpret-mode Pallas is for the
# kernel-body tests, not the production dispatch.


@functools.lru_cache(maxsize=None)
def _fused_pallas_vmapped(sign_fix, deflate_rtol, compute_dtype):
    kw = dict(sign_fix=sign_fix, deflate_rtol=deflate_rtol,
              compute_dtype=compute_dtype)

    @custom_batching.custom_vmap
    def f(u, s, v, a, b):
        return fused_update_pallas(u, s, v, a, b,
                                   interpret=interpret_default(), **kw)

    @f.def_vmap
    def _f_vmap(axis_size, in_batched, u, s, v, a, b):
        def bcast(x, batched):
            return x if batched else jnp.broadcast_to(x, (axis_size,) + x.shape)

        args = [bcast(x, bb) for x, bb in zip((u, s, v, a, b), in_batched)]
        out = fused_update_pallas_batched(*args, interpret=interpret_default(),
                                          **kw)
        return tuple(out), (True,) * 5

    return f


@functools.lru_cache(maxsize=None)
def _fused_trunc_pallas_vmapped(deflate_rtol, compute_dtype):
    kw = dict(deflate_rtol=deflate_rtol, compute_dtype=compute_dtype)

    @custom_batching.custom_vmap
    def f(u, s, v, a, b):
        return fused_update_truncated_pallas(u, s, v, a, b,
                                             interpret=interpret_default(), **kw)

    @f.def_vmap
    def _f_vmap(axis_size, in_batched, u, s, v, a, b):
        def bcast(x, batched):
            return x if batched else jnp.broadcast_to(x, (axis_size,) + x.shape)

        args = [bcast(x, bb) for x, bb in zip((u, s, v, a, b), in_batched)]
        out = fused_update_truncated_pallas_batched(
            *args, interpret=interpret_default(), **kw)
        return tuple(out), (True,) * 3

    return f


def fused_update(u, s, v, a, b, *, sign_fix=True, deflate_rtol=None,
                 compute_dtype=None, interpret=None):
    """Dispatching entry for the fused full update (core method="fused").

    Returns the plain ``(u, s, v, d_left, d_right)`` tuple.  ``interpret``
    forces interpret-mode Pallas (tests); otherwise Pallas on TPU, the XLA
    fusion elsewhere.
    """
    if interpret:
        return fused_update_pallas(u, s, v, a, b, sign_fix=sign_fix,
                                   deflate_rtol=deflate_rtol,
                                   compute_dtype=compute_dtype, interpret=True)
    if jax.default_backend() == "tpu":
        fn = _fused_pallas_vmapped(sign_fix, deflate_rtol, compute_dtype)
        return fn(u, s, v, a, b)
    return fused_update_xla(u, s, v, a, b, sign_fix=sign_fix,
                            deflate_rtol=deflate_rtol,
                            compute_dtype=compute_dtype)


def fused_update_truncated(u, s, v, a, b, *, deflate_rtol=None,
                           compute_dtype=None, interpret=None):
    """Dispatching entry for the fused truncated update: (u, s, v) tuple."""
    if interpret:
        return fused_update_truncated_pallas(u, s, v, a, b,
                                             deflate_rtol=deflate_rtol,
                                             compute_dtype=compute_dtype,
                                             interpret=True)
    if jax.default_backend() == "tpu":
        fn = _fused_trunc_pallas_vmapped(deflate_rtol, compute_dtype)
        return fn(u, s, v, a, b)
    return fused_update_truncated_xla(u, s, v, a, b,
                                      deflate_rtol=deflate_rtol,
                                      compute_dtype=compute_dtype)
