"""Optimizers + the paper-technique features (spectral, compression)."""
