"""LR schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine"]


def warmup_cosine(step, *, base_lr, warmup_steps, total_steps, min_ratio=0.1):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(step < warmup_steps, warm, cos)
