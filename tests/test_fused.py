"""The fused megakernel route (kernels.fused_update, DESIGN.md §11).

Four layers of pins:

* **numerics** — the fused body against the direct route / a dense f64 SVD,
  across single, batched, truncated, repeated-spectrum and zero-update
  geometries.  Degenerate trailing ``v`` columns (null-space basis for the
  n-m zero singular values) are an arbitrary orthonormal choice across
  differently-compiled paths, so full-update comparisons pin ``v[:, :m]``;
* **dispatch** — ``UpdatePolicy(method="fused")`` and geometry-aware
  ``auto`` resolve to the shared fused engine, including the mesh-sharded
  path on 8 fake devices (subprocess — device count precedes jax init);
* **mixed precision** — bf16 storage stays inside the documented
  ``BF16_ERROR_BUDGET`` against an f64 dense reference, single-shot and
  over an 8-update drift;
* **rank-k scan lowering** — long RankK schedules lower to ONE
  ``("rank1_scan", ...)`` step, trace cost is flat in k, and results match
  the dense reference.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.api import SvdState, UpdatePolicy
from repro.core.engine import SvdEngine, default_engine
from repro.core.svd_update import (
    TruncatedSvd,
    _svd_update_impl,
    _svd_update_truncated_impl,
)
from repro.kernels import fused_update as F
from repro.updates import RankK
from repro.updates import planner

RNG = np.random.default_rng(17)
REPO = Path(__file__).resolve().parent.parent


def _problem(m, n):
    a_mat = RNG.uniform(1, 9, (m, n))
    u, s, vt = np.linalg.svd(a_mat)
    return (jnp.asarray(u), jnp.asarray(s), jnp.asarray(vt.T),
            jnp.asarray(RNG.normal(size=m)), jnp.asarray(RNG.normal(size=n)))


def _dense(u, s, v):
    m, n = u.shape[0], v.shape[0]
    smat = np.zeros((m, n))
    np.fill_diagonal(smat, np.asarray(s)[: min(m, n)])
    return np.asarray(u) @ smat @ np.asarray(v).T


def _close(x, y, atol=1e-9):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol)


# ---------------------------------------------------------------------------
# numerics: fused body vs direct route / dense reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,n", [(4, 6), (8, 8), (12, 20), (32, 48)])
def test_fused_full_matches_direct(m, n):
    u, s, v, a, b = _problem(m, n)
    ref = _svd_update_impl(u, s, v, a, b, method="direct")
    out = F.fused_update_xla(u, s, v, a, b)
    _close(out[0], ref.u)
    _close(out[1], ref.s)
    _close(out[2][:, :m], ref.v[:, :m])
    _close(out[3], ref.d_left)
    _close(out[4], ref.d_right)


def test_fused_full_repeated_singular_values():
    m, n = 8, 10
    u = jnp.asarray(np.linalg.qr(RNG.normal(size=(m, m)))[0])
    v = jnp.asarray(np.linalg.qr(RNG.normal(size=(n, n)))[0])
    s = jnp.asarray(np.array([3.0, 3.0, 3.0, 2.0, 1.0, 1.0, 0.5, 0.25]))
    a = jnp.asarray(RNG.normal(size=m))
    b = jnp.asarray(RNG.normal(size=n))
    fu, fs, fv, _, _ = F.fused_update_xla(u, s, v, a, b)
    target = _dense(u, s, v) + np.outer(np.asarray(a), np.asarray(b))
    _close(np.sort(np.asarray(fs))[::-1],
           np.linalg.svd(target, compute_uv=False))
    rec = (np.asarray(fu)[:, :m] * np.asarray(fs)[None, :m]) @ np.asarray(fv)[:, :m].T
    _close(rec, target)


def test_fused_zero_update_is_identityish():
    m, n = 6, 9
    u, s, v, _, b = _problem(m, n)
    fu, fs, fv, _, _ = F.fused_update_xla(u, s, v, jnp.zeros(m), b)
    _close(np.sort(np.asarray(fs))[::-1][:m], np.asarray(s))
    rec = (np.asarray(fu)[:, :m] * np.asarray(fs)[None, :m]) @ np.asarray(fv)[:, :m].T
    _close(rec, _dense(u, s, v))


def test_fused_clustered_spectrum_stays_accurate():
    """Gaps just above the deflation tolerance — the hard bracket case for
    the shortened (16 bisect + 6 Newton) fused secular loop."""
    m, n = 8, 12
    u = jnp.asarray(np.linalg.qr(RNG.normal(size=(m, m)))[0])
    v = jnp.asarray(np.linalg.qr(RNG.normal(size=(n, n)))[0])
    s_np = np.linspace(5.0, 1.0, m)
    s_np[1] = s_np[0] * (1 - 1e-11)
    s_np[3] = s_np[2] * (1 - 1e-9)
    s = jnp.asarray(np.sort(s_np)[::-1].copy())
    a = jnp.asarray(1e-3 * RNG.normal(size=m))
    b = jnp.asarray(RNG.normal(size=n))
    fu, fs, fv, _, _ = F.fused_update_xla(u, s, v, a, b)
    target = _dense(u, s, v) + np.outer(np.asarray(a), np.asarray(b))
    _close(np.sort(np.asarray(fs))[::-1],
           np.linalg.svd(target, compute_uv=False), atol=1e-10)
    rec = (np.asarray(fu)[:, :m] * np.asarray(fs)[None, :m]) @ np.asarray(fv)[:, :m].T
    _close(rec, target, atol=1e-10)


def test_fused_truncated_matches_direct():
    m, n, r = 14, 18, 5
    u = jnp.asarray(np.linalg.qr(RNG.normal(size=(m, r)))[0])
    v = jnp.asarray(np.linalg.qr(RNG.normal(size=(n, r)))[0])
    s = jnp.asarray(np.sort(np.abs(RNG.normal(size=r)))[::-1].copy())
    a = jnp.asarray(RNG.normal(size=m))
    b = jnp.asarray(RNG.normal(size=n))
    ref = _svd_update_truncated_impl(TruncatedSvd(u, s, v), a, b)
    out = F.fused_update_truncated_xla(u, s, v, a, b)
    _close(out[0], ref.u, atol=1e-10)
    _close(out[1], ref.s, atol=1e-10)
    _close(out[2], ref.v, atol=1e-10)


# ---------------------------------------------------------------------------
# the Pallas kernel (interpret mode) agrees with its jnp body
# ---------------------------------------------------------------------------


def test_pallas_interpret_matches_body_full():
    m, n = 6, 9
    u, s, v, a, b = _problem(m, n)
    ref = F._fused_body(u, s, v, a, b)
    out = F.fused_update_pallas(u, s, v, a, b, interpret=True)
    for got, want, name in zip(out, ref, ("u", "s", "v", "dl", "dr")):
        got = got[:, :m] if name == "v" else got
        want = want[:, :m] if name == "v" else want
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-12, err_msg=name)


def test_pallas_interpret_matches_body_truncated():
    m, n, r = 10, 12, 4
    u = jnp.asarray(np.linalg.qr(RNG.normal(size=(m, r)))[0])
    v = jnp.asarray(np.linalg.qr(RNG.normal(size=(n, r)))[0])
    s = jnp.asarray(np.sort(np.abs(RNG.normal(size=r)))[::-1].copy())
    a = jnp.asarray(RNG.normal(size=m))
    b = jnp.asarray(RNG.normal(size=n))
    ref = F._fused_truncated_body(u, s, v, a, b)
    out = F.fused_update_truncated_pallas(u, s, v, a, b, interpret=True)
    for got, want in zip(out, ref):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-12)


def test_pallas_interpret_batched_matches_items():
    b_sz, m, n = 3, 5, 7
    cols = [[] for _ in range(5)]
    for _ in range(b_sz):
        for c, x in zip(cols, _problem(m, n)):
            c.append(x)
    u, s, v, a, bb = (jnp.stack(c) for c in cols)
    out = F.fused_update_pallas_batched(u, s, v, a, bb, interpret=True)
    for i in range(b_sz):
        ref = F._fused_body(u[i], s[i], v[i], a[i], bb[i])
        np.testing.assert_allclose(np.asarray(out[0][i]), np.asarray(ref[0]),
                                   atol=1e-12)
        np.testing.assert_allclose(np.asarray(out[1][i]), np.asarray(ref[1]),
                                   atol=1e-12)
        np.testing.assert_allclose(np.asarray(out[2][i][:, :m]),
                                   np.asarray(ref[2][:, :m]), atol=1e-12)


# ---------------------------------------------------------------------------
# dispatch: engine + api routes
# ---------------------------------------------------------------------------


def test_engine_fused_batch_matches_loop_of_singles():
    b_sz, m, n = 5, 10, 13
    cols = [[] for _ in range(5)]
    for _ in range(b_sz):
        for c, x in zip(cols, _problem(m, n)):
            c.append(x)
    u, s, v, a, bb = (jnp.stack(c) for c in cols)
    eng = SvdEngine(method="fused")
    out = eng.update_batch(u, s, v, a, bb)
    for i in range(b_sz):
        ref = eng.update(u[i], s[i], v[i], a[i], bb[i])
        _close(out.u[i], ref.u, atol=1e-10)
        _close(out.s[i], ref.s, atol=1e-10)
        _close(out.v[i][:, :m], ref.v[:, :m], atol=1e-10)


def test_auto_policy_resolves_to_fused_with_geometry():
    pol = UpdatePolicy()
    assert pol.resolve_method(48, m=32) == "fused"
    # no geometry: the pre-fused auto rule is unchanged
    assert pol.resolve_method(9) == "direct"
    assert pol.resolve_method(256) == "fmm"
    # geometry over the VMEM budget falls back too
    assert pol.resolve_method(4096, m=4096, n=4096) == "fmm"


def test_api_fused_route_is_engine_executable():
    u, s, v, a, b = _problem(12, 16)
    ref = default_engine("fused").update(u, s, v, a, b)
    out = api.update(SvdState.from_factors(u, s, v), a, b,
                     UpdatePolicy(method="fused"))
    for got, want in ((out.u, ref.u), (out.s, ref.s), (out.v, ref.v)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0, atol=0)
    # auto + full state geometry resolves to the same fused engine entry
    out2 = api.update(SvdState.from_factors(u, s, v), a, b, UpdatePolicy())
    np.testing.assert_allclose(np.asarray(out2.s), np.asarray(ref.s),
                               rtol=0, atol=0)


def test_fused_supported_boundaries():
    assert F.fused_supported(32, 48)
    assert not F.fused_supported(48, 32)          # full path needs m <= n
    assert F.fused_supported(256, 256, dtype=jnp.float32)
    assert not F.fused_supported(256, 256, dtype=jnp.float64)
    assert not F.fused_supported(2048, 2048)
    # truncated residency depends on k = rank+1, not m*n
    assert F.fused_supported(4096, 4096, rank=15, dtype=jnp.float32)
    assert not F.fused_supported(65536, 65536, rank=255, dtype=jnp.float32)


def test_fused_mesh_route_on_8_devices():
    """UpdatePolicy(method='fused', mesh=...) == the fused engine mesh path
    bitwise, and matches unsharded fused numerics (8 fake CPU devices)."""
    script = textwrap.dedent("""
        import json
        import jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp, numpy as np
        from repro import api
        from repro.core.engine import default_engine

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(5)
        B, m, n = 16, 8, 10
        us, ss, vs = [], [], []
        for _ in range(B):
            x = rng.uniform(1, 9, (m, n))
            u, s, vt = np.linalg.svd(x)
            us.append(u); ss.append(s); vs.append(vt.T)
        args = tuple(jnp.asarray(np.stack(x)) for x in (us, ss, vs))
        a = jnp.asarray(rng.normal(size=(B, m)))
        b = jnp.asarray(rng.normal(size=(B, n)))

        eng = default_engine("fused")
        ref = eng.update_batch(*args, a, b, mesh=mesh, batch_axis="data")
        pol = api.UpdatePolicy(method="fused", mesh=mesh, batch_axis="data")
        out = api.update(api.SvdState.from_factors(*args), a, b, pol)
        d_mesh = max(float(jnp.max(jnp.abs(x - y))) for x, y in
                     zip((out.u, out.s, out.v), (ref.u, ref.s, ref.v)))
        local = eng.update_batch(*args, a, b)
        d_num = max(
            float(jnp.max(jnp.abs(out.s - local.s))),
            float(jnp.max(jnp.abs(out.u - local.u))),
            float(jnp.max(jnp.abs(out.v[..., :m] - local.v[..., :m]))),
        )
        print(json.dumps({"devices": jax.device_count(),
                          "d_mesh": d_mesh, "d_num": d_num}))
    """)
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=420,
        env={
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
            "PYTHONPATH": str(REPO / "src"),
            "PATH": "/usr/bin:/bin",
            "JAX_PLATFORMS": "cpu",
            "HOME": "/tmp",
        },
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-4000:]}"
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    assert out["d_mesh"] == 0.0   # same engine cache entry -> bitwise
    assert out["d_num"] < 1e-10


# ---------------------------------------------------------------------------
# mixed precision: bf16 storage inside the documented budget
# ---------------------------------------------------------------------------


def test_bf16_single_update_within_budget():
    m, n = 32, 48
    u, s, v, a, b = _problem(m, n)
    target = _dense(u, s, v) + np.outer(np.asarray(a), np.asarray(b))
    s_ref = np.linalg.svd(target, compute_uv=False)

    pol = UpdatePolicy(method="fused", storage_dtype=jnp.bfloat16)
    out = api.update(SvdState.from_factors(u, s, v), a, b, pol)
    assert out.s.dtype == jnp.bfloat16
    assert out.u.dtype == jnp.bfloat16

    got = np.sort(np.asarray(out.s, dtype=np.float64))[::-1][:m]
    sigma_rel = float(np.max(np.abs(got - s_ref) / s_ref.max()))
    assert sigma_rel < F.BF16_ERROR_BUDGET["sigma_rel"], sigma_rel

    uo = np.asarray(out.u, dtype=np.float64)
    vo = np.asarray(out.v, dtype=np.float64)
    so = np.asarray(out.s, dtype=np.float64)
    rec = (uo[:, :m] * so[None, :m]) @ vo[:, :m].T
    recon_rel = float(np.max(np.abs(rec - target)) / np.abs(target).max())
    assert recon_rel < F.BF16_ERROR_BUDGET["recon_rel"], recon_rel


def test_bf16_drift_within_budget_over_8_updates():
    m, n, k = 32, 48, 8
    u, s, v, _, _ = _problem(m, n)
    target = _dense(u, s, v)
    st = SvdState.from_factors(u, s, v)
    pol = UpdatePolicy(method="fused", storage_dtype=jnp.bfloat16)
    for _ in range(k):
        a = RNG.normal(size=m)
        b = RNG.normal(size=n)
        target = target + np.outer(a, b)
        st = api.update(st, jnp.asarray(a), jnp.asarray(b), pol)
    s_ref = np.linalg.svd(target, compute_uv=False)
    got = np.sort(np.asarray(st.s, dtype=np.float64))[::-1][:m]
    drift = float(np.max(np.abs(got - s_ref) / s_ref.max()))
    assert drift < F.BF16_ERROR_BUDGET["drift_sigma_rel"], drift


# ---------------------------------------------------------------------------
# rank-k scan lowering (updates.planner <-> api.update_rank_k)
# ---------------------------------------------------------------------------


def test_long_rank_k_lowers_to_single_scan_step():
    st = SvdState.from_dense(np.asarray(RNG.normal(size=(6, 8))))
    k_long = planner._SCAN_MIN
    op = RankK(np.zeros((6, k_long)), np.zeros((8, k_long)))
    plan = planner.lower(op, st)
    assert plan == (("rank1_scan", (), "rank_k", k_long),)
    # short runs keep the unrolled per-pair lowering
    op8 = RankK(np.zeros((6, 8)), np.zeros((8, 8)))
    plan8 = planner.lower(op8, st)
    assert len(plan8) == 8 and all(s[0] == "rank1" for s in plan8)


def test_rank_k_scan_matches_dense_reference():
    m, n, k = 6, 8, 20
    x = RNG.normal(size=(m, n))
    uk = RNG.normal(size=(m, k))
    vk = RNG.normal(size=(n, k))
    out = api.apply(SvdState.from_dense(x), RankK(uk, vk),
                    UpdatePolicy(method="direct"))
    ref = np.linalg.svd(x + uk @ vk.T, compute_uv=False)
    _close(np.sort(np.asarray(out.s))[::-1][: min(m, n)], ref)


def test_update_rank_k_truncated_matches_sequential():
    m, n, r, k = 10, 12, 4, 20
    t = TruncatedSvd(
        jnp.asarray(np.linalg.qr(RNG.normal(size=(m, r)))[0]),
        jnp.asarray(np.sort(np.abs(RNG.normal(size=r)))[::-1].copy()),
        jnp.asarray(np.linalg.qr(RNG.normal(size=(n, r)))[0]),
    )
    va = jnp.asarray(RNG.normal(size=(k, m)))
    vb = jnp.asarray(RNG.normal(size=(k, n)))
    pol = UpdatePolicy(method="direct")
    out = api.update_rank_k(api.as_state(t), va, vb, pol)
    st = api.as_state(t)
    for i in range(k):
        st = api.update(st, va[i], vb[i], pol)
    _close(out.s, st.s, atol=1e-9)
    _close(out.u, st.u, atol=1e-8)


def test_rank_k_trace_cost_is_flat_in_k():
    """The scan lowering's point: tracing a k=64 schedule must cost the same
    number of jaxpr equations as k=8 (one scan, k only in the carry)."""
    eng = SvdEngine(method="direct")
    fn = eng._rank_k_fn()

    def n_eqns(k):
        m, n = 6, 8
        args = (jnp.zeros((m, m)), jnp.zeros(m), jnp.zeros((n, n)),
                jnp.zeros((k, m)), jnp.zeros((k, n)))
        return len(jax.make_jaxpr(fn)(*args).jaxpr.eqns)

    assert n_eqns(8) == n_eqns(64)


def test_apply_many_scan_path_matches_apply():
    m, n, k = 5, 7, 18
    xs = [RNG.normal(size=(m, n)) for _ in range(2)]
    ops = [RankK(RNG.normal(size=(m, k)), RNG.normal(size=(n, k)))
           for _ in range(2)]
    pol = UpdatePolicy(method="direct")
    outs = api.apply_many([SvdState.from_dense(x, rank=4) for x in xs], ops, pol)
    for x, op, out in zip(xs, ops, outs):
        ref = api.apply(SvdState.from_dense(x, rank=4), op, pol)
        _close(out.s, ref.s, atol=1e-9)
