"""``repro.updates`` — structured perturbations lowered onto the rank-1
engine (DESIGN.md §10).

Declarative ops (``RankK``, ``AppendRows``/``AppendCols``,
``RemoveRows``/``RemoveCols``, ``Window``, ``DenseDelta``, ``Sparse``,
``Decay``, ``Compose``) with exact dense reference semantics,
and a planner that compiles any of them into a minimal schedule of
plan-cached ``repro.api`` rank-1 dispatches.  All low-rank extraction runs
through the randomized range-finder in ``repro.updates.sketch`` (no dense
SVD on any lowering path); ``Sparse`` deltas scale with nnz via the
``kernels.sparse_proj`` projection kernel:

    from repro import api
    from repro.updates import RankK, Decay, Compose

    state = api.SvdState.from_dense(x, rank=8)
    op = Compose((Decay(0.99), RankK(u_block, v_block)))   # forget + absorb
    state = api.apply(state, op)                           # planned schedule

``api.apply`` / ``api.apply_many`` are the public entry points; the module
surface here is for building ops and inspecting the planner.
"""

from repro.updates.ops import (
    AppendCols,
    AppendRows,
    Compose,
    Decay,
    DenseDelta,
    RankK,
    RemoveCols,
    RemoveRows,
    Sparse,
    UpdateOp,
    Window,
    skeleton_from_spec,
    spec_from_json,
    spec_to_json,
)
from repro.updates.planner import (
    apply,
    apply_many,
    lower,
    op_low_rank_factors,
    schedule_cache_clear,
    schedule_cache_info,
    warmup_plan,
)
from repro.updates.sketch import (
    factored_svd,
    range_finder,
    sketch_svd,
    sparse_sketch_svd,
    warmup_sketch,
)

__all__ = [
    "AppendCols",
    "AppendRows",
    "Compose",
    "Decay",
    "DenseDelta",
    "RankK",
    "RemoveCols",
    "RemoveRows",
    "Sparse",
    "UpdateOp",
    "Window",
    "apply",
    "apply_many",
    "factored_svd",
    "lower",
    "op_low_rank_factors",
    "range_finder",
    "schedule_cache_clear",
    "schedule_cache_info",
    "skeleton_from_spec",
    "sketch_svd",
    "sparse_sketch_svd",
    "spec_from_json",
    "spec_to_json",
    "warmup_plan",
]
