"""``repro.api`` — the system's single public surface (DESIGN.md §8).

One state object, one policy object, two entry points:

    from repro import api

    state  = api.SvdState.from_dense(a_mat)            # or .from_factors(u, s, v)
    policy = api.UpdatePolicy(method="fmm", fmm_p=20)
    state  = api.update(state, a, b, policy)           # SVD of A + a b^T

    trackers = api.update_many(trackers, A_vecs, B_vecs, policy)   # grouped/batched

    state = api.apply(state, op, policy)               # structured perturbation
    states = api.apply_many(states, ops, policy)       # cross-op step batching

Structured perturbations (rank-k, appends, decay, compositions —
``repro.updates``) lower onto planned schedules of the same two rank-1
entry points (DESIGN.md §10).

Everything underneath — ``core.svd_update`` (Algorithm 6.1),
``core.engine`` (plan-cached batched executables), the Pallas kernels and
the ``repro.dist`` shard_map routes — is implementation.  The pre-api
module-level call shapes were deleted after the migration (DESIGN.md §8,
now historical, records the old→new map); this module is the only public
entry point.

Docstrings on this surface carry runnable ``>>>`` examples, enforced by
``pytest --doctest-modules src/repro/api`` in CI.
"""

from repro.api.cache import compilation_cache_entries, enable_compilation_cache
from repro.api.policy import METHODS, UpdatePolicy
from repro.api.state import SvdState, as_state
from repro.api.update import engine_for, update, update_many, update_rank_k, warmup

__all__ = [
    "METHODS",
    "SvdState",
    "UpdatePolicy",
    "apply",
    "apply_many",
    "as_state",
    "compilation_cache_entries",
    "enable_compilation_cache",
    "engine_for",
    "update",
    "update_many",
    "update_rank_k",
    "warmup",
]


def __getattr__(name: str):
    # ``apply`` / ``apply_many`` live in ``repro.updates.planner`` (the
    # structured-perturbation subsystem, DESIGN.md §10), which itself builds
    # on this package — resolve lazily to keep the import graph acyclic.
    if name in ("apply", "apply_many"):
        from repro.updates import planner

        return getattr(planner, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
