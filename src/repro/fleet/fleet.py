"""``SvdFleet`` — the mesh-sharded service tier (DESIGN.md §13).

One host-side ``SvdService`` owns every stream it serves; the mesh can
parallelize a flush's batch axis but never the stream population.  The
fleet partitions the population itself: ``num_shards`` independent services
(``fleet.shard.FleetShard``), streams assigned by deterministic hashed
placement (``fleet.placement``), each shard running its own FIFOs, bucket
rounds, in-flight buffer and continuous-batching admission window
(``fleet.frontend``).  The public surface is the service's —
``register`` / ``enqueue`` / ``enqueue_op`` / ``state`` / ``flush`` /
``drain`` / ``merge_streams`` — so a caller scales from one service to a
fleet by swapping the constructor.

Cross-shard composition happens ONLY at query time: ``query`` settles each
member stream on its own shard, then runs the hierarchical Iwen–Ong merge
(``dist.merge.merge_tree``) over the settled states in ``stream_ids``
order — exact for globally low-rank data, near-optimal otherwise.  The
settle path applies each stream's queue through the same per-stream
``_apply_event`` sequence a standalone service would, so a fleet query
over enqueued traffic is BITWISE-equal to the single-service reference
(the acceptance test in tests/test_fleet.py) — placement cannot change
what a query returns.  Flushed (batched-round) states carry the usual
XLA caveat: executables compiled for different batch compositions may
round reductions in different orders, so cross-topology comparisons of
flush-applied states are exact only to ulp-level tolerance — the
same-composition replay guarantees (snapshot restore) remain bitwise.

``FleetSnapshot`` (snapshot **v8**) captures the whole tier — one
``ServiceSnapshot`` (v7 payload) per shard plus the placement spec — and
restores bitwise, kill-and-resume, across processes.  Because placement is
pure data, restore accepts a DIFFERENT shard count: ``regrouped`` re-places
every stream's leaves (state + pending FIFO, moved wholesale and bitwise)
under the new spec before services are rebuilt — the elastic path
(``train.elastic.plan_shard_count`` picks the count from live devices).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax

from repro import obs as _obs
from repro.api import UpdatePolicy
from repro.api.state import SvdState
from repro.dist.merge import merge_tree
from repro.fleet.placement import PlacementSpec, plan_devices, shard_of
from repro.fleet.shard import FleetShard
from repro.serve.svd_service import ServiceSnapshot, SvdService, SvdServiceStats
from repro.train import checkpoint as _checkpoint

__all__ = ["FLEET_SNAPSHOT_VERSION", "FleetSnapshot", "SvdFleet"]

# The snapshot version line is shared with serve: v1-v3, v5 and v7 are
# single-service ``ServiceSnapshot`` formats (DESIGN.md §9/§12/§14/§15); v4
# was the first fleet-level format (v3 service payloads); v6 carried v5
# service payloads (downdate ops in the FIFOs); v8 carries v7 payloads
# (obs-metrics rows riding each shard's snapshot metadata, DESIGN.md §15).
# v4/v6 fleet snapshots still load — the payload loader accepts any service
# version <= 7, and missing obs rows restore as empty.
FLEET_SNAPSHOT_VERSION = 8
_SNAPSHOT_FORMAT = "repro.fleet.FleetSnapshot"

# fleet-level config a snapshot records (admission shape; devices are
# runtime placement and deliberately absent, like the service's mesh)
_CONFIG_FIELDS = ("continuous", "max_depth", "max_backlog")


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["shards"],
    meta_fields=["version", "placement", "config"],
)
@dataclasses.dataclass(frozen=True)
class FleetSnapshot:
    """Versioned capture of a whole fleet: per-shard ``ServiceSnapshot``
    payloads (the array leaves) + the placement spec and admission config
    (metadata, mirrored into the JSON aux so a fresh process rebuilds the
    exact routing table before loading a single array)."""

    shards: tuple            # tuple[ServiceSnapshot, ...], index = shard id
    version: int = FLEET_SNAPSHOT_VERSION
    placement: PlacementSpec = PlacementSpec(1)
    config: tuple = ()       # (field, value) pairs of _CONFIG_FIELDS

    def aux(self) -> dict:
        return {
            "format": _SNAPSHOT_FORMAT,
            "version": self.version,
            "placement": self.placement.to_json(),
            "config": dict(self.config),
            "shards": [s.aux() for s in self.shards],
        }

    @classmethod
    def skeleton(cls, aux: dict) -> "FleetSnapshot":
        return cls(
            shards=tuple(ServiceSnapshot.skeleton(sa) for sa in aux["shards"]),
            version=FLEET_SNAPSHOT_VERSION,
            placement=PlacementSpec.from_json(aux["placement"]),
            config=tuple(aux["config"].items()),
        )

    def save(self, ckpt_dir, step: int, *, keep: int = 3):
        return _checkpoint.save(ckpt_dir, step, self, aux=self.aux())

    @classmethod
    def load(cls, ckpt_dir, step: int | None = None) -> tuple[int, "FleetSnapshot"]:
        step, aux = _checkpoint.load_aux(ckpt_dir, step)
        if aux is None or aux.get("format") != _SNAPSHOT_FORMAT:
            raise ValueError(
                f"checkpoint at step {step} is not a FleetSnapshot "
                f"(aux format: {None if aux is None else aux.get('format')!r})"
            )
        if aux["version"] > FLEET_SNAPSHOT_VERSION:
            raise ValueError(
                f"snapshot version {aux['version']} is newer than this build "
                f"understands (<= {FLEET_SNAPSHOT_VERSION})"
            )
        _, leaves = _checkpoint.restore(ckpt_dir, None, step)
        treedef = jax.tree.structure(cls.skeleton(aux))
        return step, jax.tree.unflatten(treedef, leaves)

    # -- elastic re-placement ----------------------------------------------

    def regrouped(self, num_shards: int) -> "FleetSnapshot":
        """The same fleet under ``placement.replaced(num_shards)``: every
        stream's snapshot leaves (state + pending FIFO stacks + op pytrees +
        order string) move WHOLESALE to the shard the new spec hashes it to
        — pure pytree surgery, bitwise, no engine dispatch.  Warmed sets
        union into every new shard (a warm superset costs only warmup time);
        per-shard stats counters reset (they are per-process observability,
        not stream state).
        """
        if num_shards == self.placement.num_shards:
            return self
        new_spec = self.placement.replaced(num_shards)
        if not self.shards:
            return FleetSnapshot(shards=(), placement=new_spec,
                                 config=self.config)
        proto = self.shards[0]       # shards share the service config
        warmed = tuple(sorted({w for s in self.shards for w in s.warmed}))
        zero_stats = tuple(
            dataclasses.asdict(SvdServiceStats()).items()
        )
        buckets: list[list] = [[] for _ in range(num_shards)]
        for snap in self.shards:
            for i, sid in enumerate(snap.stream_ids):
                buckets[shard_of(new_spec, sid)].append((
                    sid, snap.states[i], snap.pending_a[i], snap.pending_b[i],
                    snap.pending_ops[i] if snap.pending_ops else (),
                    snap.pending_order[i] if snap.pending_order else "",
                ))
        shards = tuple(
            ServiceSnapshot(
                states=tuple(e[1] for e in bucket),
                pending_a=tuple(e[2] for e in bucket),
                pending_b=tuple(e[3] for e in bucket),
                pending_ops=tuple(e[4] for e in bucket),
                version=proto.version,
                stream_ids=tuple(e[0] for e in bucket),
                policy_spec=proto.policy_spec,
                max_batch=proto.max_batch,
                pad_to_bucket=proto.pad_to_bucket,
                max_in_flight=proto.max_in_flight,
                stats=zero_stats,
                pending_order=tuple(e[5] for e in bucket),
                warmed=warmed,
            )
            for bucket in buckets
        )
        return FleetSnapshot(shards=shards, placement=new_spec,
                             config=self.config)


class SvdFleet:
    """A population-sharded ``SvdService``: same surface, ``num_shards``
    independent engines' worth of admission capacity.

        fleet = SvdFleet(num_shards=8, policy=UpdatePolicy(method="auto"))
        fleet.register("user-1", api.SvdState.from_dense(m1, rank=8))
        fleet.enqueue("user-1", a, b)       # routed, admitted, maybe sealed
        merged = fleet.query(["user-1", "user-2"])   # cross-shard Iwen-Ong
        fleet.save("/ckpts/fleet", step=1)  # FleetSnapshot v8

    ``continuous=True`` (default) runs each shard behind its admission
    window (``fleet.frontend``); ``False`` degrades every shard to the
    plain fixed-boundary service (the benchmark control arm).
    ``devices="auto"`` pins shard ``i`` to device ``i mod n_devices``
    (``placement.plan_devices``); None leaves placement to the process
    default (single-device hosts).
    """

    def __init__(
        self,
        num_shards: int = 1,
        *,
        policy: UpdatePolicy | None = None,
        max_batch: int = 64,
        pad_to_bucket: bool = True,
        max_in_flight: int = 2,
        continuous: bool = True,
        max_depth: int = 8,
        max_backlog: int | None = None,
        placement: PlacementSpec | None = None,
        devices=None,
    ):
        self.placement = (placement if placement is not None
                          else PlacementSpec(num_shards))
        if self.placement.num_shards != num_shards:
            raise ValueError(
                f"placement spec is for {self.placement.num_shards} shards; "
                f"fleet has {num_shards}"
            )
        self.policy = policy if policy is not None else UpdatePolicy()
        self.continuous = continuous
        self.max_depth = max_depth
        self.max_backlog = max_backlog
        if devices == "auto":
            devices = plan_devices(num_shards, mesh=self.policy.mesh)
        elif devices is None:
            devices = (None,) * num_shards
        self.shards = tuple(
            FleetShard(
                i,
                policy=self.policy,
                max_batch=max_batch,
                pad_to_bucket=pad_to_bucket,
                max_in_flight=max_in_flight,
                continuous=continuous,
                max_depth=max_depth,
                max_backlog=max_backlog,
                device=devices[i % len(devices)],
            )
            for i in range(num_shards)
        )

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    def shard_of(self, stream_id: str) -> int:
        return shard_of(self.placement, stream_id)

    def _shard(self, stream_id: str) -> FleetShard:
        return self.shards[self.shard_of(stream_id)]

    # -- the service surface, routed ----------------------------------------

    def register(self, stream_id: str, state) -> None:
        self._shard(stream_id).register(stream_id, state)

    def enqueue(self, stream_id: str, a, b) -> tuple[int, int]:
        """Route + admit one rank-1 event; returns its fleet-level
        visibility token ``(shard, token)`` (see ``poll``)."""
        sh = self.shard_of(stream_id)
        return (sh, self.shards[sh].enqueue(stream_id, a, b))

    def enqueue_op(self, stream_id: str, op) -> tuple[int, int]:
        sh = self.shard_of(stream_id)
        return (sh, self.shards[sh].enqueue_op(stream_id, op))

    def state(self, stream_id: str) -> SvdState:
        return self._shard(stream_id).service.state(stream_id)

    def evict(self, stream_id: str) -> SvdState:
        return self._shard(stream_id).service.evict(stream_id)

    def pending(self) -> int:
        return sum(s.pending() for s in self.shards)

    def pump(self) -> int:
        """One admission pass over every shard (the fleet event loop tick);
        returns events dispatched."""
        return sum(s.pump() for s in self.shards)

    def poll(self) -> list[tuple[int, int]]:
        """Newly visible fleet tokens ``(shard, token)`` across all shards."""
        out = []
        for i, s in enumerate(self.shards):
            out.extend((i, t) for t in s.poll())
        return out

    def flush(self) -> int:
        return sum(s.flush() for s in self.shards)

    def drain(self) -> int:
        return sum(s.drain() for s in self.shards)

    def stats(self) -> SvdServiceStats:
        """Fleet-aggregate counters (sum over shards; ``max_*`` fields max).

        With ``repro.obs`` enabled the aggregate is also published as
        ``fleet_<field>`` gauges — the rollup view over the per-shard
        ``serve_<field>{shard=i}`` series each shard publishes on flush.
        """
        agg = SvdServiceStats()
        for s in self.shards:
            st = s.service.stats
            for f in dataclasses.fields(SvdServiceStats):
                if f.name.startswith("max_") or f.name.endswith("_peak"):
                    setattr(agg, f.name,
                            max(getattr(agg, f.name), getattr(st, f.name)))
                else:
                    setattr(agg, f.name,
                            getattr(agg, f.name) + getattr(st, f.name))
        if _obs.enabled():
            reg = _obs.registry()
            for f in dataclasses.fields(SvdServiceStats):
                reg.gauge(f"fleet_{f.name}").set(getattr(agg, f.name))
        return agg

    # -- query-time cross-shard composition ---------------------------------

    def settle(self, stream_ids) -> list[SvdState]:
        """Per-stream settled states in ``stream_ids`` order (each shard
        applies its own members' queues; no cross-shard traffic)."""
        by_shard: dict[int, list[str]] = {}
        for sid in stream_ids:
            by_shard.setdefault(self.shard_of(sid), []).append(sid)
        settled: dict[str, SvdState] = {}
        for sh, sids in by_shard.items():
            for sid, st in zip(sids, self.shards[sh].service.settle(sids)):
                settled[sid] = st
        return [settled[sid] for sid in stream_ids]

    def query(self, stream_ids, *, rank: int | None = None) -> SvdState:
        """Truncated SVD of the row-concatenation of the named streams
        (``stream_ids`` order), wherever they live: settle on the owning
        shards, then ONE hierarchical merge (``dist.merge.merge_tree``) —
        the only point where shards compose, and it moves just the
        ``(m + n + 1) * r`` factor floats per stream."""
        states = self.settle(stream_ids)
        return merge_tree(states, rank=rank, policy=self.policy)

    def merge_streams(
        self,
        stream_ids,
        *,
        target: str | None = None,
        rank: int | None = None,
    ) -> SvdState:
        """Service-compatible alias of ``query``; with ``target`` the merged
        state registers as a new stream on ITS hashed shard."""
        merged = self.query(stream_ids, rank=rank)
        if target is not None:
            self.register(target, merged)
        return merged

    # -- checkpointing -------------------------------------------------------

    def snapshot(self) -> FleetSnapshot:
        """Barrier + capture every shard (consistent per shard; shards are
        independent, so the fleet snapshot is the tuple of shard points)."""
        return FleetSnapshot(
            shards=tuple(s.snapshot() for s in self.shards),
            version=FLEET_SNAPSHOT_VERSION,
            placement=self.placement,
            config=tuple((f, getattr(self, f)) for f in _CONFIG_FIELDS),
        )

    def save(self, ckpt_dir, step: int, *, keep: int = 3):
        return self.snapshot().save(ckpt_dir, step, keep=keep)

    @classmethod
    def from_snapshot(
        cls,
        snap: FleetSnapshot,
        *,
        mesh=None,
        policy: UpdatePolicy | None = None,
        devices=None,
    ) -> "SvdFleet":
        """Rebuild a fleet from a snapshot (same shard count as ``snap`` —
        re-place first via ``snap.regrouped`` for an elastic restore).

        Per-shard services rebuild through ``SvdService.from_snapshot``,
        including the eager warmed-geometry ``api.warmup`` replay; combined
        with a persistent ``cache_dir`` (see ``restore``) that replay
        compiles nothing.
        """
        cfg = dict(snap.config)
        n = len(snap.shards)
        proto_policy = policy
        services = [
            SvdService.from_snapshot(s, mesh=mesh, policy=policy)
            for s in snap.shards
        ]
        fleet = cls.__new__(cls)
        fleet.placement = snap.placement
        fleet.policy = (services[0].policy if services else
                        (proto_policy if proto_policy is not None
                         else UpdatePolicy(mesh=mesh)))
        fleet.continuous = bool(cfg.get("continuous", True))
        fleet.max_depth = int(cfg.get("max_depth", 8))
        fleet.max_backlog = cfg.get("max_backlog")
        if devices == "auto":
            devices = plan_devices(n, mesh=fleet.policy.mesh)
        elif devices is None:
            devices = (None,) * max(n, 1)
        fleet.shards = tuple(
            FleetShard(
                i,
                continuous=fleet.continuous,
                max_depth=fleet.max_depth,
                max_backlog=fleet.max_backlog,
                device=devices[i % len(devices)],
                service=services[i],
            )
            for i in range(n)
        )
        return fleet

    @classmethod
    def restore(
        cls,
        ckpt_dir,
        *,
        step: int | None = None,
        num_shards: int | str | None = None,
        mesh=None,
        policy: UpdatePolicy | None = None,
        devices=None,
        cache_dir=None,
    ) -> tuple[int, "SvdFleet"]:
        """Load the latest (or ``step``-th) fleet snapshot and rebuild.

        ``num_shards``: None keeps the recorded shard count; an int
        re-places every stream under ``placement.replaced(num_shards)``
        (elastic restore — bitwise per stream, tests/test_fleet.py);
        ``"auto"`` asks ``train.elastic.plan_shard_count`` to size the
        fleet to the devices actually alive (the failover path).
        ``cache_dir`` enables the persistent compilation cache BEFORE the
        warmed-set replay, so a warm cache restores with zero recompiles.
        """
        if cache_dir is not None:
            from repro.api import enable_compilation_cache

            enable_compilation_cache(cache_dir)
        step, snap = FleetSnapshot.load(ckpt_dir, step)
        if num_shards == "auto":
            from repro.train.elastic import plan_shard_count

            num_shards = plan_shard_count()
        if num_shards is not None and num_shards != len(snap.shards):
            snap = snap.regrouped(int(num_shards))
        return step, cls.from_snapshot(snap, mesh=mesh, policy=policy,
                                       devices=devices)
