"""One fleet shard: a ``SvdService`` partition plus its admission frontend.

A shard is the unit of ownership: every stream hashed to shard ``i``
(``placement.shard_of``) lives in shard ``i``'s service — its state, its
FIFO, its flush rounds, its in-flight buffer are all private to the shard.
Shards therefore flush **independently**: shard ``i`` sealing a round never
waits on shard ``j``'s device work, and the per-shard bucket rounds keep
each shard's plan-cache geometry set as small as a standalone service's.
Cross-shard composition happens only at query time (``fleet.SvdFleet``
merges settled states through ``dist.merge``).
"""

from __future__ import annotations

from repro.api import UpdatePolicy
from repro.fleet.frontend import ContinuousBatcher
from repro.serve.svd_service import SvdService

__all__ = ["FleetShard"]


class FleetShard:
    """Shard ``index``: one ``SvdService`` + one ``ContinuousBatcher``.

    The shard's service is a COMPLETE standalone service (snapshot,
    restore, merge, eviction all work per shard); the shard wrapper adds
    identity, device pinning and the admission frontend.
    """

    def __init__(
        self,
        index: int,
        *,
        policy: UpdatePolicy | None = None,
        max_batch: int = 64,
        pad_to_bucket: bool = True,
        max_in_flight: int = 2,
        continuous: bool = True,
        max_depth: int = 8,
        max_backlog: int | None = None,
        device=None,
        service: SvdService | None = None,
    ):
        self.index = index
        self.device = device
        self.service = service if service is not None else SvdService(
            max_batch=max_batch,
            pad_to_bucket=pad_to_bucket,
            max_in_flight=max_in_flight,
            policy=policy,
        )
        # per-shard series in the obs registry: every serve_* gauge and
        # health_* probe this shard publishes carries shard=<index>, and
        # registry().aggregate(...) rolls them into fleet totals
        self.service._obs_labels = {"shard": str(index)}
        self.frontend = ContinuousBatcher(
            self.service,
            max_depth=max_depth,
            max_backlog=max_backlog,
            device=device,
            continuous=continuous,
        )

    # thin delegation — the fleet routes per stream, shards do the work

    def register(self, stream_id: str, state) -> None:
        self.service.register(stream_id, state)

    def enqueue(self, stream_id: str, a, b) -> int:
        return self.frontend.admit(stream_id, a, b)

    def enqueue_op(self, stream_id: str, op) -> int:
        return self.frontend.admit_op(stream_id, op)

    def pending(self) -> int:
        return self.service.pending()

    def poll(self) -> list[int]:
        return self.frontend.poll()

    def pump(self) -> int:
        return self.frontend.pump()

    def flush(self) -> int:
        return self.service.flush()

    def drain(self) -> int:
        # through the frontend: it seals maximally deep/wide rounds first,
        # then runs the service's blocking barrier
        return self.frontend.drain()

    def snapshot(self):
        return self.service.snapshot()
