"""AdamW with global-norm clipping (pure pytree functions, no optax)."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "global_norm"]


class AdamWState(NamedTuple):
    step: jax.Array
    m: object   # pytree like params
    v: object


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr,
    betas=(0.9, 0.95),
    eps=1e-8,
    weight_decay=0.1,
    grad_clip=1.0,
):
    b1, b2 = betas
    step = state.step + 1

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-12)) if grad_clip else 1.0

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / (1 - b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v), gnorm
