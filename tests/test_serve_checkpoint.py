"""Checkpointable streams: ``ServiceSnapshot`` round-trips and the DESIGN §9
restore-exactness contract.

Covers: snapshot round-trips under truncated, batched and mesh-sharded
policies (8 fake devices), restore-after-partial-flush, the async
double-buffer (async == sync bitwise, bounded in-flight), snapshot
versioning, and the kill-and-resume acceptance test where save and restore
happen in DIFFERENT processes and the resumed run must be bitwise identical
(rtol=0/atol=0, f64) to an uninterrupted one.
"""

import dataclasses
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import SvdState, UpdatePolicy
from repro.core.svd_update import TruncatedSvd
from repro.serve import SNAPSHOT_VERSION, ServiceSnapshot, SvdService
from repro.train import checkpoint as ckpt

REPO = Path(__file__).resolve().parent.parent
RNG = np.random.default_rng(5)


def _fresh(m, n, r, rng=RNG):
    return TruncatedSvd(
        jnp.asarray(np.linalg.qr(rng.normal(size=(m, r)))[0]),
        jnp.asarray(np.sort(np.abs(rng.normal(size=r)))[::-1].copy()),
        jnp.asarray(np.linalg.qr(rng.normal(size=(n, r)))[0]),
    )


def _traffic(n_events, streams, m, n, rng):
    return [
        (f"s{i % streams}",
         jnp.asarray(rng.normal(size=m)), jnp.asarray(rng.normal(size=n)))
        for i in range(n_events)
    ]


def _feed(svc, events):
    for sid, a, b in events:
        svc.enqueue(sid, a, b)


def _exact_states(svc_a, svc_b, stream_ids):
    for sid in stream_ids:
        for f in ("u", "s", "v"):
            np.testing.assert_allclose(
                np.asarray(getattr(svc_a.state(sid), f)),
                np.asarray(getattr(svc_b.state(sid), f)),
                rtol=0, atol=0,
            )


# ---------------------------------------------------------------------------
# checkpoint-layer primitives the snapshot relies on
# ---------------------------------------------------------------------------


def test_checkpoint_aux_roundtrip_and_flat_restore(tmp_path):
    """aux payloads are persisted, checksummed and returned; tree_like=None
    hands leaves back uncast and bitwise."""
    tree = {"a": np.arange(6.0).reshape(2, 3), "b": np.float32([1.5, -2.5])}
    aux = {"kind": "demo", "ids": ["x", "y"], "n": 2}
    ckpt.save(tmp_path, 3, tree, aux=aux)
    step, got = ckpt.load_aux(tmp_path)
    assert (step, got) == (3, aux)
    step, leaves = ckpt.restore(tmp_path, None)
    assert step == 3 and len(leaves) == 2
    # flat order follows the pytree flatten order; dtypes/bits preserved
    flat = jax.tree.leaves(tree)
    for lv, ref in zip(leaves, flat):
        assert lv.dtype == ref.dtype
        np.testing.assert_array_equal(lv, ref)
    # checkpoints without aux report None
    ckpt.save(tmp_path, 4, tree)
    assert ckpt.load_aux(tmp_path, 4) == (4, None)


# ---------------------------------------------------------------------------
# snapshot round-trips (in-process; fresh-process is the subprocess test)
# ---------------------------------------------------------------------------


def test_snapshot_roundtrip_truncated_policy(tmp_path):
    """Default truncated policy: snapshot mid-run (pending FIFOs non-empty),
    restore into a fresh service, finish — bitwise vs uninterrupted."""
    m, n, r, streams = 8, 10, 3, 4
    rng = np.random.default_rng(0)
    init = [_fresh(m, n, r, rng) for _ in range(streams)]
    events = _traffic(19, streams, m, n, rng)
    ids = [f"s{i}" for i in range(streams)]

    ref = SvdService(max_batch=streams)
    for sid, t in zip(ids, init):
        ref.register(sid, t)
    _feed(ref, events)
    ref.drain()

    svc = SvdService(max_batch=streams)
    for sid, t in zip(ids, init):
        svc.register(sid, t)
    split = 10
    _feed(svc, events[:split])
    assert svc.pending() > 0          # mid-run: unflushed pairs exist
    svc.save(tmp_path, step=split)

    step, restored = SvdService.restore(tmp_path)
    assert step == split
    assert restored.pending() == svc.pending()
    assert restored.stats.applied == svc.stats.applied
    _feed(restored, events[split:])
    restored.drain()
    _exact_states(ref, restored, ids)


def test_snapshot_roundtrip_batched_mixed_geometry(tmp_path):
    """Batched flush rounds across two geometries; snapshot + resume stays
    bitwise, per geometry group."""
    rng = np.random.default_rng(1)
    geos = [(8, 10, 3)] * 3 + [(12, 9, 4)] * 3
    ids = [f"g{i}" for i in range(len(geos))]
    init = [_fresh(m, n, r, rng) for (m, n, r) in geos]
    events = []
    for round_i in range(5):
        for sid, (m, n, _) in zip(ids, geos):
            events.append((sid, jnp.asarray(rng.normal(size=m)),
                           jnp.asarray(rng.normal(size=n))))

    def build():
        svc = SvdService(max_batch=4)     # auto-flush kicks in mid-round
        for sid, t in zip(ids, init):
            svc.register(sid, t)
        return svc

    ref = build()
    _feed(ref, events)
    ref.drain()
    assert ref.stats.max_batch >= 4       # batching actually happened

    svc = build()
    split = 17
    _feed(svc, events[:split])
    svc.save(tmp_path, step=split)
    _, restored = SvdService.restore(tmp_path)
    _feed(restored, events[split:])
    restored.drain()
    _exact_states(ref, restored, ids)


def test_restore_after_partial_flush(tmp_path):
    """Snapshot taken when some pairs flushed and others still queued: the
    states must reflect exactly the flushed prefix, the FIFOs exactly the
    unflushed suffix."""
    m, n, r, streams = 8, 9, 3, 4
    rng = np.random.default_rng(2)
    init = [_fresh(m, n, r, rng) for _ in range(streams)]
    ids = [f"s{i}" for i in range(streams)]

    svc = SvdService(max_batch=streams)   # one auto-flush per full round
    for sid, t in zip(ids, init):
        svc.register(sid, t)
    full_round = _traffic(streams, streams, m, n, rng)
    _feed(svc, full_round)                # round 1: auto-flushed
    assert svc.stats.flushes == 1
    tail = _traffic(2, streams, m, n, rng)
    _feed(svc, tail)                      # s0, s1 queue a second pair
    assert svc.pending() == 2

    svc.save(tmp_path, step=1)
    _, restored = SvdService.restore(tmp_path)
    assert restored.pending("s0") == 1 and restored.pending("s1") == 1
    assert restored.pending("s2") == 0 and restored.pending("s3") == 0
    # flushed prefix is already in the restored states...
    _exact_states(svc, restored, ids)
    # ...and the queued suffix replays identically on both sides
    assert svc.flush() == restored.flush() == 2
    _exact_states(svc, restored, ids)


def test_snapshot_version_guard(tmp_path):
    svc = SvdService(max_batch=2)
    svc.register("x", _fresh(6, 7, 2))
    snap = svc.snapshot()
    assert snap.version == SNAPSHOT_VERSION
    future = dataclasses.replace(snap, version=SNAPSHOT_VERSION + 1)
    future.save(tmp_path, step=1)
    with pytest.raises(ValueError, match="newer"):
        ServiceSnapshot.load(tmp_path)
    # a non-snapshot checkpoint is refused up front
    ckpt.save(tmp_path, 2, {"w": np.ones(3)})
    with pytest.raises(ValueError, match="not a ServiceSnapshot"):
        ServiceSnapshot.load(tmp_path, 2)


def test_snapshot_is_a_barrier_and_preserves_stats(tmp_path):
    m, n, r, streams = 8, 10, 3, 4
    rng = np.random.default_rng(3)
    svc = SvdService(max_batch=streams, max_in_flight=4)
    for i in range(streams):
        svc.register(f"s{i}", _fresh(m, n, r, rng))
    _feed(svc, _traffic(streams * 3, streams, m, n, rng))
    snap = svc.snapshot()
    assert svc.in_flight() == 0           # barrier retired everything
    stats = dict(snap.stats)
    assert stats["applied"] == streams * 3
    assert stats["flushes"] == svc.stats.flushes
    # restored service continues the counters, not resets them
    restored = SvdService.from_snapshot(snap)
    assert restored.stats.applied == streams * 3


# ---------------------------------------------------------------------------
# snapshot v2: structured pending events + the warmed-geometry set
# ---------------------------------------------------------------------------


def test_snapshot_v2_roundtrips_structured_events_bitwise(tmp_path):
    """A FIFO holding a rank-k bucket, a decay fold, an append, and a
    post-append pair must survive save/load and drain bitwise (ISSUE 5
    acceptance)."""
    from repro.updates import AppendRows, Compose, Decay, RankK

    m, n, r = 8, 10, 3

    def build():
        rng = np.random.default_rng(21)
        svc = SvdService(max_batch=16)
        svc.register("x", _fresh(m, n, r, np.random.default_rng(20)))
        svc.enqueue("x", jnp.asarray(rng.normal(size=m)),
                    jnp.asarray(rng.normal(size=n)))
        svc.enqueue_op("x", RankK(jnp.asarray(rng.normal(size=(m, 2))),
                                  jnp.asarray(rng.normal(size=(n, 2)))))
        svc.enqueue_op("x", Compose((
            Decay(0.9), AppendRows(jnp.asarray(rng.normal(size=(2, n)))),
        )))
        svc.enqueue("x", jnp.asarray(rng.normal(size=m + 2)),
                    jnp.asarray(rng.normal(size=n)))
        return svc

    ref = build()
    svc = build()
    snap = svc.snapshot()
    assert snap.version == SNAPSHOT_VERSION
    assert "o" in "".join(snap.pending_order)      # structured events present
    svc.save(tmp_path, step=1)
    _, restored = SvdService.restore(tmp_path)
    assert restored.pending("x") == ref.pending("x")

    ref.drain()
    restored.drain()
    assert restored.state("x").shape == (m + 2, n)  # append took effect
    _exact_states(ref, restored, ["x"])
    assert restored.stats.ops_applied == ref.stats.ops_applied > 0


def test_snapshot_v1_aux_skeleton_compat():
    """v1 aux specs (no pending_ops/pending_order/warmed) build a skeleton
    whose leaf list matches the v1 layout — the in-place upgrade path."""
    aux_v1 = {
        "format": "repro.serve.ServiceSnapshot",
        "version": 1,
        "stream_ids": ["a", "b"],
        "policy": {"method": "direct", "fmm_p": 20, "sign_fix": True,
                   "deflate_rtol": None, "precision": None,
                   "batch_axis": "data", "truncate_to": None,
                   "had_mesh": False},
        "max_batch": 8,
        "pad_to_bucket": True,
        "max_in_flight": 2,
        "stats": {"enqueued": 3, "applied": 1},
    }
    skel = ServiceSnapshot.skeleton(aux_v1)
    # 3 state leaves + 2 pending leaves per stream, nothing from v2 fields
    assert len(jax.tree.leaves(skel)) == 2 * 5
    assert skel.pending_ops == ((), ())
    assert skel.pending_order == ()
    assert skel.warmed == ()
    # all-pair reconstruction: order=None means "p" * len(pending)
    svc = SvdService.from_snapshot(
        ServiceSnapshot(
            states=tuple(
                SvdState(*_fresh(6, 7, 2, np.random.default_rng(s)))
                for s in (0, 1)
            ),
            pending_a=(np.zeros((2, 6)), np.zeros((0, 6))),
            pending_b=(np.zeros((2, 7)), np.zeros((0, 7))),
            pending_ops=((), ()),
            stream_ids=("a", "b"),
            policy_spec=tuple(aux_v1["policy"].items()),
            stats=tuple(aux_v1["stats"].items()),
            pending_order=(),
        )
    )
    assert svc.pending("a") == 2 and svc.pending("b") == 0


def test_snapshot_v3_sparse_pending_bitwise(tmp_path):
    """A queued ``Sparse`` op rides the snapshot WHOLE — its COO leaves sit
    bitwise in ``pending_ops`` — and the post-restore drain matches the
    uninterrupted service bitwise (the trace-time sketch constants make the
    flush-time expansion deterministic).  ISSUE 7 acceptance."""
    from repro.updates import Sparse

    m, n, r, nnz = 8, 10, 3, 7
    coo_rng = np.random.default_rng(31)
    rows = coo_rng.integers(0, 2, nnz).astype(np.int32)   # rank(S) <= 2
    cols = coo_rng.integers(0, n, nnz).astype(np.int32)
    vals = coo_rng.normal(size=nnz)

    def build():
        rng = np.random.default_rng(32)
        svc = SvdService(max_batch=16)
        svc.register("x", _fresh(m, n, r, np.random.default_rng(30)))
        svc.enqueue("x", jnp.asarray(rng.normal(size=m)),
                    jnp.asarray(rng.normal(size=n)))
        svc.enqueue_op("x", Sparse(rows, cols, vals, rank=2))
        svc.enqueue("x", jnp.asarray(rng.normal(size=m)),
                    jnp.asarray(rng.normal(size=n)))
        return svc

    ref = build()
    svc = build()
    snap = svc.snapshot()
    assert snap.version == SNAPSHOT_VERSION
    assert "o" in "".join(snap.pending_order)
    # the COO value vector is carried bitwise as a pending_ops leaf
    assert any(
        np.asarray(leaf).shape == (nnz,)
        and np.array_equal(np.asarray(leaf), vals)
        for leaf in jax.tree.leaves(snap.pending_ops)
    )
    svc.save(tmp_path, step=1)
    _, restored = SvdService.restore(tmp_path)
    assert restored.pending("x") == ref.pending("x")

    ref.drain()
    restored.drain()
    _exact_states(ref, restored, ["x"])
    # the Sparse op expanded into rank pairs at the flush head on both sides
    assert restored.stats.ops_applied == ref.stats.ops_applied == 1
    assert restored.stats.applied == ref.stats.applied


def test_snapshot_v2_policy_spec_back_compat():
    """A v2-era policy spec (no sketch fields) restores with the
    ``UpdatePolicy`` defaults — pre-sketch checkpoints keep loading."""
    spec_v2 = {"method": "direct", "fmm_p": 20, "sign_fix": True,
               "deflate_rtol": None, "precision": None, "storage_dtype": None,
               "batch_axis": "data", "truncate_to": None, "had_mesh": False}
    svc = SvdService.from_snapshot(
        ServiceSnapshot(
            states=(SvdState(*_fresh(6, 7, 2, np.random.default_rng(0))),),
            pending_a=(np.zeros((0, 6)),),
            pending_b=(np.zeros((0, 7)),),
            pending_ops=((),),
            stream_ids=("a",),
            policy_spec=tuple(spec_v2.items()),
            stats=(("enqueued", 0), ("applied", 0)),
            pending_order=("",),
        )
    )
    assert svc.policy.sketch_oversample == 8
    assert svc.policy.sketch_power_iters == 1


_RESTORE_WARM_SCRIPT = textwrap.dedent("""
    import json, sys
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np, jax.numpy as jnp
    from repro.core.svd_update import TruncatedSvd
    from repro.serve import SvdService

    mode, ckpt_dir = sys.argv[1:3]
    rng = np.random.default_rng(13)
    M, N, R, S = 8, 10, 3, 4
    streams = [TruncatedSvd(
        jnp.asarray(np.linalg.qr(rng.normal(size=(M, R)))[0]),
        jnp.asarray(np.sort(np.abs(rng.normal(size=R)))[::-1].copy()),
        jnp.asarray(np.linalg.qr(rng.normal(size=(N, R)))[0]),
    ) for _ in range(S)]

    def feed_round(svc):
        for i in range(S):
            svc.enqueue(f"s{i}", jnp.asarray(rng.normal(size=M)),
                        jnp.asarray(rng.normal(size=N)))

    if mode == "save":
        svc = SvdService(max_batch=S)
        for i, t in enumerate(streams):
            svc.register(f"s{i}", t)
        feed_round(svc)          # auto-flush warms the (S, M, N, R) geometry
        svc.drain()
        snap = svc.snapshot()
        assert len(snap.warmed) >= 1, snap.warmed
        svc.save(ckpt_dir, step=1)
        print(json.dumps({"warmed": [list(w) for w in snap.warmed]}))
        sys.exit(0)

    # resume phase: restore must eagerly AOT-warm the recorded geometries so
    # the FIRST post-restore flush never compiles (ROADMAP cold-start item)
    step, svc = SvdService.restore(ckpt_dir)
    eng = svc._engine_for(R)
    info0 = eng.cache_info()
    assert info0.entries >= 1, info0          # warmup populated the cache
    feed_round(svc)
    svc.drain()                               # first flush after restore
    info1 = eng.cache_info()
    print(json.dumps({
        "entries_before": info0.entries, "misses_before": info0.misses,
        "misses_after": info1.misses, "hits_gained": info1.hits - info0.hits,
    }))
""")


def test_restore_then_first_flush_does_not_recompile(tmp_path):
    """ServiceSnapshot records the warmed (kind, geometry) set; restore in a
    FRESH process api.warmup's it eagerly, so the first flush is a pure plan
    cache hit — zero new compiles under traffic."""
    env = {
        "PYTHONPATH": str(REPO / "src"),
        "PATH": "/usr/bin:/bin",
        "JAX_PLATFORMS": "cpu",
        "HOME": "/tmp",
    }
    save = subprocess.run(
        [sys.executable, "-c", _RESTORE_WARM_SCRIPT, "save", str(tmp_path)],
        capture_output=True, text=True, timeout=420, env=env,
    )
    assert save.returncode == 0, f"save stderr:\n{save.stderr[-4000:]}"
    warmed = json.loads(save.stdout.strip().splitlines()[-1])["warmed"]
    assert any(w[0] == "trunc_batch" for w in warmed)

    resume = subprocess.run(
        [sys.executable, "-c", _RESTORE_WARM_SCRIPT, "resume", str(tmp_path)],
        capture_output=True, text=True, timeout=420, env=env,
    )
    assert resume.returncode == 0, f"resume stderr:\n{resume.stderr[-4000:]}"
    out = json.loads(resume.stdout.strip().splitlines()[-1])
    assert out["misses_after"] == out["misses_before"]   # no recompile
    assert out["hits_gained"] >= 1                       # traffic hit the cache


# ---------------------------------------------------------------------------
# the async double buffer
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("max_in_flight", [0, 1, 4])
def test_async_modes_bitwise_equal(max_in_flight):
    """Sync (0), single-buffer (1) and deep async (4) pipelines are the same
    computation — results must be bitwise identical."""
    m, n, r, streams = 8, 10, 3, 4
    rng = np.random.default_rng(4)
    init = [_fresh(m, n, r, rng) for _ in range(streams)]
    events = _traffic(16, streams, m, n, rng)
    ids = [f"s{i}" for i in range(streams)]

    def run(mif):
        svc = SvdService(max_batch=streams, max_in_flight=mif)
        for sid, t in zip(ids, init):
            svc.register(sid, t)
        _feed(svc, events)
        svc.drain()
        return svc

    ref = run(0)                          # fully synchronous baseline
    got = run(max_in_flight)
    assert got.stats.in_flight_peak <= max(max_in_flight, 0)
    _exact_states(ref, got, ids)


def test_backpressure_bounds_in_flight():
    m, n, r, streams = 8, 10, 3, 4
    rng = np.random.default_rng(6)
    svc = SvdService(max_batch=streams, max_in_flight=1)
    for i in range(streams):
        svc.register(f"s{i}", _fresh(m, n, r, rng))
    _feed(svc, _traffic(streams * 6, streams, m, n, rng))
    svc.drain()
    assert svc.stats.in_flight_peak <= 1
    assert svc.in_flight() == 0
    with pytest.raises(ValueError, match="max_in_flight"):
        SvdService(max_in_flight=-1)


# ---------------------------------------------------------------------------
# kill-and-resume: save and restore in DIFFERENT processes (acceptance)
# ---------------------------------------------------------------------------

_KILL_RESUME_SCRIPT = textwrap.dedent("""
    import json, sys
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np, jax.numpy as jnp
    from repro.api import UpdatePolicy
    from repro.core.svd_update import TruncatedSvd
    from repro.serve import SvdService

    mode, ckpt_dir, out_npz, sharded = sys.argv[1:5]
    sharded = sharded == "1"
    mesh = jax.make_mesh((8,), ("data",)) if sharded else None
    policy = UpdatePolicy(method="direct", mesh=mesh, batch_axis="data")

    rng = np.random.default_rng(7)
    M, N, R, S, E, SPLIT = 8, 10, 3, 4, 22, 11
    streams = [TruncatedSvd(
        jnp.asarray(np.linalg.qr(rng.normal(size=(M, R)))[0]),
        jnp.asarray(np.sort(np.abs(rng.normal(size=R)))[::-1].copy()),
        jnp.asarray(np.linalg.qr(rng.normal(size=(N, R)))[0]),
    ) for _ in range(S)]
    traffic = [(f"s{i % S}", rng.normal(size=M), rng.normal(size=N))
               for i in range(E)]

    def feed(svc, evts):
        for sid, a, b in evts:
            svc.enqueue(sid, jnp.asarray(a), jnp.asarray(b))

    if mode == "resume":
        step, svc = SvdService.restore(ckpt_dir, mesh=mesh)
        assert step == SPLIT
        feed(svc, traffic[SPLIT:])
        svc.drain()
    else:
        svc = SvdService(max_batch=S, max_in_flight=2, policy=policy)
        for i, t in enumerate(streams):
            svc.register(f"s{i}", t)
        if mode == "save":
            feed(svc, traffic[:SPLIT])
            pend = svc.pending()
            svc.save(ckpt_dir, step=SPLIT)
            print(json.dumps({"pending_at_snapshot": pend}))
            sys.exit(0)
        feed(svc, traffic)
        svc.drain()

    np.savez(out_npz, **{f"s{i}_{f}": np.asarray(getattr(svc.state(f"s{i}"), f))
                         for i in range(S) for f in ("u", "s", "v")})
    print(json.dumps({"ok": True, "devices": jax.device_count()}))
""")


def _run_phase(mode, ckpt_dir, out_npz, sharded):
    env = {
        "PYTHONPATH": str(REPO / "src"),
        "PATH": "/usr/bin:/bin",
        "JAX_PLATFORMS": "cpu",
        "HOME": "/tmp",
    }
    if sharded:
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run(
        [sys.executable, "-c", _KILL_RESUME_SCRIPT,
         mode, str(ckpt_dir), str(out_npz), "1" if sharded else "0"],
        capture_output=True, text=True, timeout=420, env=env,
    )
    assert proc.returncode == 0, f"{mode} stderr:\n{proc.stderr[-4000:]}"
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("sharded", [False, True], ids=["default", "mesh-sharded"])
def test_kill_and_resume_bitwise(tmp_path, sharded):
    """A stream snapshotted mid-run and restored in a FRESH process produces
    bitwise-identical (rtol=0/atol=0, f64) factors to an uninterrupted run —
    under the default and the mesh-sharded (8 fake devices) policy."""
    full_npz = tmp_path / "full.npz"
    resumed_npz = tmp_path / "resumed.npz"
    ckpt_dir = tmp_path / "ckpt"

    out_full = _run_phase("full", ckpt_dir, full_npz, sharded)
    save_info = _run_phase("save", ckpt_dir, full_npz, sharded)
    assert save_info["pending_at_snapshot"] > 0     # snapshot taken mid-stream
    out_res = _run_phase("resume", ckpt_dir, resumed_npz, sharded)
    if sharded:
        assert out_full["devices"] == out_res["devices"] == 8

    a, b = np.load(full_npz), np.load(resumed_npz)
    assert sorted(a.files) == sorted(b.files)
    for k in a.files:
        np.testing.assert_allclose(a[k], b[k], rtol=0, atol=0)
        assert a[k].dtype == np.float64


# ---------------------------------------------------------------------------
# snapshot v5: pending downdates (RemoveRows / RemoveCols / Window)
# ---------------------------------------------------------------------------


def test_snapshot_v5_downdate_pending_bitwise(tmp_path):
    """Queued Remove/Window ops ride the snapshot whole — Remove ops are
    pure metadata (zero array leaves; indices live in the aux spec), Window
    carries only its ``lam`` leaf — and the post-restore drain matches the
    uninterrupted service bitwise.  ISSUE 9 acceptance."""
    from repro.updates import RemoveCols, RemoveRows, Window

    m, n, r = 8, 10, 3

    def build():
        rng = np.random.default_rng(41)
        svc = SvdService(max_batch=16)
        svc.register("x", _fresh(m, n, r, np.random.default_rng(40)))
        svc.enqueue("x", jnp.asarray(rng.normal(size=m)),
                    jnp.asarray(rng.normal(size=n)))
        svc.enqueue_op("x", RemoveRows((0, 5)))
        svc.enqueue_op("x", RemoveCols(2))
        svc.enqueue_op("x", Window(5, lam=0.9))
        # a post-shrink pair: the snapshot wraps it as a k=1 RankK leaf
        svc.enqueue("x", jnp.asarray(rng.normal(size=5)),
                    jnp.asarray(rng.normal(size=n - 1)))
        return svc

    ref = build()
    svc = build()
    assert svc._effective_shape("x") == (5, n - 1)
    snap = svc.snapshot()
    assert snap.version == SNAPSHOT_VERSION == 7
    assert "".join(snap.pending_order) == "pooo" + "o"
    # downdate indices live in the aux spec (metadata), not in array leaves
    specs = json.dumps(snap.aux())
    assert "remove_rows" in specs and "window" in specs
    svc.save(tmp_path, step=1)
    _, restored = SvdService.restore(tmp_path)
    assert restored.pending("x") == ref.pending("x")
    assert restored._effective_shape("x") == (5, n - 1)

    ref.drain()
    restored.drain()
    assert restored.state("x").shape == (5, n - 1)
    _exact_states(ref, restored, ["x"])
    assert restored.stats.ops_applied == ref.stats.ops_applied == 3


def test_snapshot_v3_loads_as_v5():
    """Pre-downdate (v3) snapshots still load: the downdate bump added no
    structural change, so a v3-stamped snapshot restores unchanged."""
    from repro.updates import Decay

    svc = SvdService(max_batch=4)
    svc.register("x", _fresh(6, 7, 2))
    svc.enqueue("x", jnp.zeros(6), jnp.zeros(7))
    svc.enqueue_op("x", Decay(0.9))
    old = dataclasses.replace(svc.snapshot(), version=3)
    restored = SvdService.from_snapshot(old)
    assert restored.pending("x") == 2
    restored.drain()
    np.testing.assert_allclose(
        np.asarray(restored.state("x").s),
        0.9 * np.asarray(svc.state("x").s), rtol=0, atol=0)


def test_snapshot_v3_aux_refuses_v5_and_loads_older(tmp_path):
    """Version discipline on disk: a v3-stamped file loads (<= 7), a
    v8-stamped ServiceSnapshot is refused — the fleet owns v8."""
    svc = SvdService(max_batch=4)
    svc.register("x", _fresh(6, 7, 2))
    old = dataclasses.replace(svc.snapshot(), version=3)
    old.save(tmp_path / "v3", step=1)
    _, loaded = ServiceSnapshot.load(tmp_path / "v3")
    assert loaded.states[0].shape == (6, 7)
    fleet_stamped = dataclasses.replace(svc.snapshot(), version=8)
    fleet_stamped.save(tmp_path / "v8", step=1)
    with pytest.raises(ValueError, match="newer"):
        ServiceSnapshot.load(tmp_path / "v8")


_DOWNDATE_KILL_RESUME_SCRIPT = textwrap.dedent("""
    import json, sys
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np, jax.numpy as jnp
    from repro.core.svd_update import TruncatedSvd
    from repro.serve import SvdService
    from repro.updates import RemoveRows, Window

    mode, ckpt_dir, out_npz = sys.argv[1:4]

    rng = np.random.default_rng(9)
    M, N, R, S = 8, 10, 3, 3
    streams = [TruncatedSvd(
        jnp.asarray(np.linalg.qr(rng.normal(size=(M, R)))[0]),
        jnp.asarray(np.sort(np.abs(rng.normal(size=R)))[::-1].copy()),
        jnp.asarray(np.linalg.qr(rng.normal(size=(N, R)))[0]),
    ) for _ in range(S)]
    pre = [rng.normal(size=(S, M)), rng.normal(size=(S, N))]
    post = [rng.normal(size=(S, 5)), rng.normal(size=(S, N))]

    def feed_pre(svc):
        for i in range(S):
            svc.enqueue(f"s{i}", jnp.asarray(pre[0][i]), jnp.asarray(pre[1][i]))
            svc.enqueue_op(f"s{i}", RemoveRows((1, 6)))
            svc.enqueue_op(f"s{i}", Window(5, lam=0.95))

    def feed_post(svc):
        for i in range(S):
            svc.enqueue(f"s{i}", jnp.asarray(post[0][i]), jnp.asarray(post[1][i]))

    if mode == "resume":
        step, svc = SvdService.restore(ckpt_dir)
        assert svc.pending() == 3 * S          # deletions still queued
        feed_post(svc)
        svc.drain()
    else:
        # max_batch > S: enqueue never autoflushes, so the save-mode snapshot
        # really does carry every downdate still PENDING in the FIFOs
        svc = SvdService(max_batch=64, max_in_flight=2)
        for i, t in enumerate(streams):
            svc.register(f"s{i}", t)
        feed_pre(svc)
        if mode == "save":
            svc.save(ckpt_dir, step=1)         # downdates pending, unflushed
            print(json.dumps({"pending": svc.pending()}))
            sys.exit(0)
        feed_post(svc)
        svc.drain()

    np.savez(out_npz, **{f"s{i}_{f}": np.asarray(getattr(svc.state(f"s{i}"), f))
                         for i in range(S) for f in ("u", "s", "v")})
    print(json.dumps({"ok": True, "shape": list(svc.state("s0").shape)}))
""")


def test_downdate_kill_and_resume_bitwise_across_processes(tmp_path):
    """Snapshot taken with Remove/Window ops still PENDING, restored in a
    fresh process: the resumed run (which flushes the deletions and then
    post-shrink traffic) is bitwise identical to an uninterrupted one."""
    env = {
        "PYTHONPATH": str(REPO / "src"),
        "PATH": "/usr/bin:/bin",
        "JAX_PLATFORMS": "cpu",
        "HOME": "/tmp",
    }

    def run(mode, out):
        proc = subprocess.run(
            [sys.executable, "-c", _DOWNDATE_KILL_RESUME_SCRIPT,
             mode, str(tmp_path / "ckpt"), str(out)],
            capture_output=True, text=True, timeout=420, env=env,
        )
        assert proc.returncode == 0, f"{mode} stderr:\n{proc.stderr[-4000:]}"
        return json.loads(proc.stdout.strip().splitlines()[-1])

    out_full = run("full", tmp_path / "full.npz")
    assert out_full["shape"] == [5, 10]        # deletions took effect
    save_info = run("save", tmp_path / "full.npz")
    assert save_info["pending"] == 9           # 3 events x 3 streams queued
    out_res = run("resume", tmp_path / "resumed.npz")
    assert out_res["shape"] == [5, 10]

    a = np.load(tmp_path / "full.npz")
    b = np.load(tmp_path / "resumed.npz")
    assert sorted(a.files) == sorted(b.files)
    for k in a.files:
        np.testing.assert_allclose(a[k], b[k], rtol=0, atol=0)
        assert a[k].dtype == np.float64
