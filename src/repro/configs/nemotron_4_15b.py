"""nemotron-4-15b [dense] — 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000 — GQA, squared-ReLU MLP. [arXiv:2402.16819; unverified]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b", family="dense",
        n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=24576, vocab_size=256000,
        mlp_type="relu2", norm_type="layernorm",
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="nemotron-4-15b-smoke", n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
        d_ff=192, vocab_size=512, vocab_pad_to=64,
        compute_dtype="float32", remat=False,
    )
