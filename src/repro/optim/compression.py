"""Low-rank gradient compression for data-parallel all-reduce.

PowerSGD-shaped compressed DP with the paper's streaming-SVD twist: each 2-D
gradient is compressed against a rank-r right basis V_r maintained by the
rank-1 SVD update core (driven through ``repro.api``), with error feedback so
compression error accumulates into the next step instead of being lost.

Per layer and step (inside shard_map over the data axis):
  1. G_fb = G + E                                 (error feedback)
  2. P = G_fb V_r           (m, r)                local projection
  3. P <- psum(P)/n_data                          ONLY P crosses the wire
  4. Q = G_fb^T P_hat       (n, r); Q <- psum(Q)  second factor (PowerSGD step)
  5. G_hat = P_hat Q^T;  E <- G_fb - G_hat        new error feedback
  6. V_r tracker updated via rank-1 SVD update with (u1, v1) from G_hat

Wire bytes per layer: r (m + n) * 4 instead of m n * 4 — the compression
ratio reported in EXPERIMENTS.md. The all-reduce itself uses jax.lax.psum
under shard_map, so the dry-run HLO shows the small collectives.

Tracker containers are preserved: a ``CompressionState`` built with a
``TruncatedSvd`` tracker (e.g. a hand-written shard_map spec tree) keeps
that pytree structure through every update; new code should use
``api.SvdState``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.api import UpdatePolicy, as_state, update as api_update
from repro.api.policy import policy_from_legacy as _policy_for
from repro.api.state import like_container as _like
from repro.core.engine import (
    SvdEngine,
    group_indices,
    stack_trees,
    truncated_geometry,
    unstack_tree,
)
from repro.dist import merge as dist_merge

__all__ = [
    "CompressionState",
    "agree_basis",
    "agree_tracker",
    "compression_init",
    "compress_decompress",
    "compress_decompress_batch",
    "compressed_allreduce",
    "refresh_basis",
    "wire_bytes",
]

from repro.api import SvdState
from repro.dist import collectives


class CompressionState(NamedTuple):
    v_basis: jax.Array     # (n, r) right basis (orthonormal-ish)
    error: jax.Array       # (m, n) error feedback buffer
    tracker: SvdState      # streaming SVD keeping the basis fresh


def compression_init(key, m: int, n: int, rank: int, dtype=jnp.float32) -> CompressionState:
    kv, ku = jax.random.split(key)
    v0, _ = jnp.linalg.qr(jax.random.normal(kv, (n, rank), dtype))
    u0, _ = jnp.linalg.qr(jax.random.normal(ku, (m, rank), dtype))
    return CompressionState(
        v_basis=v0,
        error=jnp.zeros((m, n), dtype),
        tracker=SvdState(u=u0, s=jnp.zeros((rank,), dtype), v=v0),
    )


def _orthonormalize(p):
    q, _ = jnp.linalg.qr(p)
    return q


def compress_decompress(state: CompressionState, grad: jax.Array, *, axis_name=None,
                        update_basis: bool = True, method: str = "direct",
                        policy: UpdatePolicy | None = None,
                        tracker_rank: int = 1):
    """Returns (g_hat, new_state). With ``axis_name`` the two factors are
    psum-averaged across the DP axis (call under shard_map).

    Thin wrapper over the B=1 batched path — one algorithm, one tuning."""
    s_stack = jax.tree.map(lambda x: x[None], state)
    gh, s2 = compress_decompress_batch(
        s_stack, grad[None], axis_name=axis_name, update_basis=update_basis,
        method=method, policy=policy, tracker_rank=tracker_rank,
    )
    return gh[0], unstack_tree(s2, 0)


def compress_decompress_batch(
    states: CompressionState,
    grads: jax.Array,
    *,
    axis_name=None,
    update_basis: bool = True,
    engine: SvdEngine | None = None,
    method: str = "direct",
    policy: UpdatePolicy | None = None,
    tracker_rank: int = 1,
):
    """Batched ``compress_decompress``: stacked states + grads of shape
    (B, m, n), one batched api dispatch for all B tracker updates.

    The projections/orthonormalizations are batched einsums/QR; the
    collectives still cross only ``axis_name`` (the batch axis stays local),
    so this composes with shard_map exactly like the single-leaf version.
    ``engine`` (legacy) overrides the policy-derived engine.

    ``tracker_rank > 1`` absorbs the top-``tracker_rank`` components of the
    compressed gradient per step as ONE planned ``repro.updates.RankK``
    update (k batched rank-1 dispatches through the schedule-cached planner)
    instead of the single dominant component — faster subspace tracking for
    mini-batch streams at the same per-dispatch cost.
    """
    pol = _policy_for(policy, method)
    g = grads.astype(states.error.dtype) + states.error           # (B, m, n)

    # the ONLY wire traffic: two factor pmeans (dist.collectives) — never
    # the dense (B, m, n) gradient
    p = jnp.einsum("bmn,bnr->bmr", g, states.v_basis)
    p = collectives.pmean_factor(p, axis_name)
    p_hat = _orthonormalize(p)                                     # batched QR

    q = jnp.einsum("bmn,bmr->bnr", g, p_hat)
    q = collectives.pmean_factor(q, axis_name)

    g_hat = jnp.einsum("bmr,bnr->bmn", p_hat, q)
    err = g - g_hat

    tracker = states.tracker
    v_basis = states.v_basis
    if update_basis:
        # short-horizon adaptation: PowerSGD warm start (one power-iteration
        # step per optimizer step — V tracks the current gradient subspace)
        v_basis = _orthonormalize(q)
        # long-horizon memory: the paper's streaming SVD absorbs the dominant
        # rank-1 of each step's compressed gradient (or the top-k under
        # ``tracker_rank``). Exposed via ``refresh_basis`` (periodic reset)
        # and spectral diagnostics — this is where the rank-1 update core is
        # load-bearing in the compressor.
        decayed = as_state(tracker).replace(s=tracker.s * 0.99)
        k = min(tracker_rank, q.shape[-1])
        if k > 1:
            # exact top-k of g_hat = p_hat @ qᵀ through the sketch module's
            # factored core (updates.sketch): no dense product, no LAPACK
            # SVD — the same no-svd path every delta lowering runs on
            from repro.updates.sketch import factored_svd

            uc, sig, vc = factored_svd(p_hat, jnp.swapaxes(q, -1, -2), k)
            root = jnp.sqrt(sig)[:, None, :]                       # (B, 1, k)
            uk = uc * root                                         # (B, m, k)
            vk = vc * root                                         # (B, n, k)
            if engine is not None:
                from repro.core.svd_update import TruncatedSvd

                t2 = TruncatedSvd(decayed.u, decayed.s, decayed.v)
                for i in range(k):
                    t2 = engine.update_truncated_batch(
                        t2, uk[:, :, i], vk[:, :, i]
                    )
            else:
                from repro.updates import RankK
                from repro.updates.planner import apply as planned_apply

                t2 = planned_apply(decayed, RankK(uk, vk), pol)
        else:
            sigma = jnp.linalg.norm(q[:, :, 0], axis=1)            # (B,)
            u1 = p_hat[:, :, 0]                                    # (B, m)
            v1 = q[:, :, 0] / (sigma + 1e-30)[:, None]             # (B, n)
            scale = jnp.sqrt(sigma)[:, None]
            if engine is not None:
                from repro.core.svd_update import TruncatedSvd

                t2 = engine.update_truncated_batch(
                    TruncatedSvd(decayed.u, decayed.s, decayed.v),
                    u1 * scale, v1 * scale,
                )
            else:
                t2 = api_update(decayed, u1 * scale, v1 * scale, pol)
        tracker = _like(tracker, t2.u, t2.s, t2.v)

    return g_hat, CompressionState(v_basis=v_basis, error=err, tracker=tracker)


def refresh_basis(state: CompressionState) -> CompressionState:
    """Reset the working basis from the streaming-SVD tracker (long-horizon
    memory; call every ~100 steps to escape warm-start cycling)."""
    return CompressionState(v_basis=state.tracker.v, error=state.error,
                            tracker=state.tracker)


def agree_tracker(tracker, *, axis_name, rank: int | None = None,
                  policy: UpdatePolicy | None = None, method: str = "direct",
                  engine: SvdEngine | None = None):
    """Consensus form of a per-worker streaming-SVD tracker (call under
    shard_map; ``axis_name=None`` degrades to a local re-factorization).

    Treats worker trackers as SVDs of the row-stacked per-worker sketches,
    all_gathers the small factors, log-depth merges them (``dist.merge``),
    then restricts the merged factors to this worker's row block and
    re-factorizes (QR of the block + r x r SVD, both O(m r^2)) so the
    returned tracker keeps the orthonormal-basis invariant the Brand
    truncated update requires.  Returns ``(consensus_tracker, merged)``:
    the per-worker tracker (same container type as the input) and the full
    merged SVD (its ``v`` is the consensus right basis).
    """
    pol = _policy_for(policy, method)
    tr = as_state(tracker)
    m = tr.m
    merged = dist_merge.distributed_merge(tracker, axis_name, rank=rank,
                                          policy=pol, engine=engine)
    if axis_name is None:
        u_block = merged.u
    else:
        idx = jax.lax.axis_index(axis_name)
        u_block = jax.lax.dynamic_slice_in_dim(merged.u, idx * m, m, axis=0)
    # local row block: M_w ~ u_block diag(s) v^T with u_block NOT orthonormal
    # (its columns carry only this worker's share of the mass) and v possibly
    # drifted off orthonormality by a long stream of f32 Brand updates.
    # Re-factorize BOTH: u_block = Qu Ru, v = Qv Rv;
    # Ru diag(s) Rv^T = P Sigma W^T  =>  M_w ~ (Qu P) Sigma (Qv W)^T.
    qu, ru = jnp.linalg.qr(u_block)
    qv, rv = jnp.linalg.qr(merged.v)
    p, sigma, wt = jnp.linalg.svd((ru * merged.s[None, :]) @ rv.T,
                                  full_matrices=False)
    return _like(tracker, qu @ p, sigma, qv @ wt.T), merged


def agree_basis(state: CompressionState, *, axis_name, rank: int | None = None,
                engine: SvdEngine | None = None,
                method: str = "direct",
                policy: UpdatePolicy | None = None) -> CompressionState:
    """Cross-DP basis agreement (call under shard_map, alongside
    ``refresh_basis``'s cadence).

    Workers' trackers drift apart between refreshes (error feedback is
    per-worker).  ``agree_tracker`` merges all per-worker trackers into a
    consensus; every worker ends with the SAME ``v_basis`` (the merged right
    basis — the span that matters for compression), while the tracker
    becomes the worker's own slice of the consensus.  Under shard_map this
    makes ``tracker.u`` PER-WORKER (spec it like the error buffer);
    ``tracker.s``/``tracker.v`` and ``v_basis`` stay replicated only when
    workers' row blocks happen to match — treat the whole post-agreement
    tracker as per-worker state.  An explicit ``engine`` overrides the
    policy-derived one (legacy callers keep their numerics).
    """
    tracker, merged = agree_tracker(
        state.tracker, axis_name=axis_name, rank=rank, policy=policy,
        method=method, engine=engine,
    )
    return CompressionState(v_basis=merged.v, error=state.error, tracker=tracker)


def compressed_allreduce(states, grads, *, axis_name, method: str = "direct",
                         engine: SvdEngine | None = None,
                         policy: UpdatePolicy | None = None,
                         tracker_rank: int = 1):
    """Tree version: 2-D leaves are compressed; others psum densely.

    Compressible leaves sharing a geometry (m, n, rank, dtype) are stacked
    and pushed through ONE ``compress_decompress_batch`` — all their tracker
    updates ride a single batched api dispatch instead of a Python loop of
    per-layer rank-1 updates.  ``tracker_rank > 1`` upgrades each group's
    tracker update to a planned rank-k absorb (one ``repro.updates.RankK``
    schedule — k batched dispatches — instead of k sequential per-layer
    calls).
    """
    pol = _policy_for(policy, method)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(states)

    keys = [
        (g.shape, s.error.dtype) + truncated_geometry(s.tracker)
        if s is not None and g.ndim == 2
        else None
        for g, s in zip(flat_g, flat_s)
    ]

    out_g: list = list(flat_g)
    out_s: list = list(flat_s)
    for i, (g, s) in enumerate(zip(flat_g, flat_s)):
        if keys[i] is None:
            out_g[i] = jax.lax.pmean(g, axis_name)

    for key, idxs in group_indices(keys).items():
        if key is None:
            continue
        s_stack = stack_trees([flat_s[i] for i in idxs])
        g_stack = jnp.stack([flat_g[i] for i in idxs])
        gh, s2 = compress_decompress_batch(
            s_stack, g_stack, axis_name=axis_name, engine=engine, policy=pol,
            tracker_rank=tracker_rank,
        )
        for j, i in enumerate(idxs):
            out_g[i] = gh[j].astype(flat_g[i].dtype)
            out_s[i] = unstack_tree(s2, j)
    return jax.tree.unflatten(treedef, out_g), jax.tree.unflatten(treedef, out_s)


def wire_bytes(m: int, n: int, rank: int, dense_dtype_bytes: int = 4) -> dict:
    dense = m * n * dense_dtype_bytes
    comp = rank * (m + n) * dense_dtype_bytes
    return {"dense": dense, "compressed": comp, "ratio": dense / comp}
