"""``UpdatePolicy`` — every tuning knob of a rank-1 SVD update, in one frozen
hashable object (DESIGN.md §8).

Before this layer, callers hand-threaded ``method=``, ``fmm_p=``, ``mesh=``,
``batch_axis=`` and truncation decisions through optim, serve, dist and
train.  A policy captures all of them once; ``repro.api.update`` dispatches
from *state geometry + policy*, and the policy's numerics fields fold into
the engine plan-cache key (``core.engine.default_engine``), so policy-equal
calls share one compiled plan — equal policies can never recompile.

Hashability is load-bearing: policies are dict keys for engine lookup and
legal ``static_argnums`` for jitted consumers.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.core.eigh_update import _FMM_MIN_N  # auto-resolution matches core's floor

__all__ = ["UpdatePolicy", "METHODS", "policy_from_legacy"]

# "pallas" is the public name for the Pallas Cauchy-kernel route (engine name
# "kernel" is kept as an alias).  "fused" is the single-kernel megakernel
# route (kernels.fused_update): the whole update resident per batch element —
# auto prefers it whenever the geometry fits its VMEM budget.  "fast"
# (Gerasoulis FAST, core.fast) is part of the enum for completeness but is a
# host-side numpy benchmark baseline — it cannot run inside the jitted engine
# and dispatch rejects it with a pointer to benchmarks/framework_bench.py.
METHODS = ("auto", "direct", "fmm", "fast", "pallas", "kernel", "fused")


@dataclasses.dataclass(frozen=True)
class UpdatePolicy:
    """Declarative description of HOW a rank-1 update should run.

    Numerics:
      method        auto | direct | fmm | pallas | fused (| kernel alias | fast: bench only)
      fmm_p         Chebyshev interpolation order of the FMM route
      sign_fix      reconcile left/right singular-vector signs (paper gap)
      deflate_rtol  deflation tolerance override (None = core default)
      precision     jax matmul precision for the update ("highest", ...; None = default)
      storage_dtype keep SvdState factors in this dtype (e.g. jnp.bfloat16);
                    16-bit storage computes in f32 inside the engine — the
                    mixed-precision mode, error budget in DESIGN.md §11

    Sketching (the randomized range-finder every DenseDelta/Sparse lowering
    runs through — ``updates.sketch``, DESIGN.md §12):
      sketch_oversample   extra sample columns beyond the target rank; the
                          sketch is exact when rank + oversample covers the
                          delta's true rank
      sketch_power_iters  subspace (power) iterations sharpening truncating
                          DENSE sketches (a dense pass is a cheap GEMM); the
                          sparse single-pass path has no power iterations by
                          design — its accuracy lever is sketch_oversample

    Placement:
      mesh         jax.sharding.Mesh to spread a batched update over (None = local)
      batch_axis   mesh axis name carrying the batch

    Truncation rule:
      truncate_to  keep only the top-r triplets of every result (None = keep all)

    Observability (``repro.obs``, DESIGN.md §15):
      health_every  sample the numerical-health probes every N flush rounds
                    in the serve/fleet tiers (None = never).  Purely a
                    monitoring cadence — probes run OUTSIDE the update's
                    traced path, so this knob is deliberately NOT part of
                    ``engine_key``: it can never cause a recompile or
                    change a result.

    Policies are plain frozen dataclasses — build once, ``replace`` to vary:

    >>> from repro.api import UpdatePolicy
    >>> pol = UpdatePolicy(method="fmm", fmm_p=12)
    >>> pol.replace(truncate_to=8).truncate_to
    8
    >>> hash(pol) == hash(UpdatePolicy(method="fmm", fmm_p=12))
    True
    >>> UpdatePolicy(method="svd")
    Traceback (most recent call last):
        ...
    ValueError: unknown method 'svd'; one of ('auto', 'direct', 'fmm', 'fast', 'pallas', 'kernel', 'fused')
    """

    method: str = "auto"
    fmm_p: int = 20
    sign_fix: bool = True
    deflate_rtol: float | None = None
    precision: str | None = None
    storage_dtype: Any = None
    sketch_oversample: int = 8
    sketch_power_iters: int = 1
    mesh: Any = None
    batch_axis: str = "data"
    truncate_to: int | None = None
    health_every: int | None = None

    def __post_init__(self):
        if self.method not in METHODS:
            raise ValueError(f"unknown method {self.method!r}; one of {METHODS}")
        if self.truncate_to is not None and self.truncate_to < 1:
            raise ValueError(f"truncate_to must be >= 1; got {self.truncate_to}")
        if self.sketch_oversample < 0:
            raise ValueError(
                f"sketch_oversample must be >= 0; got {self.sketch_oversample}"
            )
        if self.sketch_power_iters < 0:
            raise ValueError(
                f"sketch_power_iters must be >= 0; got {self.sketch_power_iters}"
            )
        if self.health_every is not None and self.health_every < 1:
            raise ValueError(
                f"health_every must be >= 1 or None; got {self.health_every}"
            )
        if self.storage_dtype is not None:
            # canonicalize to np.dtype: hashable, comparable, serializable
            object.__setattr__(self, "storage_dtype", np.dtype(self.storage_dtype))

    def replace(self, **kw) -> "UpdatePolicy":
        return dataclasses.replace(self, **kw)

    # -- engine folding -----------------------------------------------------

    def resolve_method(self, problem_n: int, *, m: int | None = None,
                       n: int | None = None, rank: int | None = None) -> str:
        """Concrete engine method for a problem of secular size ``problem_n``
        (``n`` for full updates, ``rank + 1`` for truncated ones).

        ``auto`` prefers the fused megakernel whenever enough geometry is
        known (``m``, plus ``n``/``rank`` where they differ from
        ``problem_n``) and it fits the kernel's VMEM budget; otherwise it
        falls back to the FMM-above-the-tree-floor rule.  Callers without
        geometry get the pre-fused behavior unchanged:

        >>> from repro.api import UpdatePolicy
        >>> UpdatePolicy(method="fmm").resolve_method(problem_n=256)
        'fmm'
        >>> UpdatePolicy().resolve_method(problem_n=9)  # auto: below FMM floor
        'direct'
        >>> UpdatePolicy(method="pallas").resolve_method(64)  # public kernel name
        'kernel'
        >>> UpdatePolicy().resolve_method(48, m=32)  # auto + geometry: fused
        'fused'
        """
        if self.method == "fast":
            raise NotImplementedError(
                "method='fast' (Gerasoulis FAST) is the host-side numpy "
                "benchmark baseline — see benchmarks/framework_bench.py; it "
                "is not a jittable engine route. Use auto/direct/fmm/pallas/fused."
            )
        if self.method == "pallas":
            return "kernel"
        if self.method == "auto":
            if m is not None:
                from repro.kernels.fused_update import fused_supported

                dt = self.storage_dtype if self.storage_dtype is not None else np.float32
                if fused_supported(m, n if n is not None else problem_n,
                                   rank, dtype=dt):
                    return "fused"
            # FMM pays off only above the tree floor; tiny problems (incl.
            # every truncated (r+1)-sized core) run the stable direct route.
            return "fmm" if problem_n >= _FMM_MIN_N else "direct"
        return self.method

    def engine_key(self, problem_n: int, *, m: int | None = None,
                   n: int | None = None, rank: int | None = None) -> tuple:
        """The (method, fmm_p, sign_fix, deflate_rtol, precision,
        storage_dtype, sketch_oversample, sketch_power_iters) tuple that
        keys compiled artifacts — the policy's full numerics fold.  The
        first six select ``core.engine.default_engine`` (the rank-1 plan
        cache); the sketch fields key the planner's schedule cache + the
        jitted ``updates.sketch`` executables (the engine body itself is
        sketch-independent)."""
        return (
            self.resolve_method(problem_n, m=m, n=n, rank=rank),
            self.fmm_p,
            self.sign_fix,
            self.deflate_rtol,
            self.precision,
            self.storage_dtype,
            self.sketch_oversample,
            self.sketch_power_iters,
        )

    @property
    def sketch_params(self) -> tuple[int, int]:
        """(oversample, power_iters) — the schedule-cache fold of the
        range-finder knobs (``updates.planner.lower``)."""
        return (self.sketch_oversample, self.sketch_power_iters)


def policy_from_legacy(
    policy: UpdatePolicy | None,
    method: str = "direct",
    mesh: Any = None,
    batch_axis: str = "data",
) -> UpdatePolicy:
    """Back-compat fold: consumers that still accept the pre-api ``method=``
    / ``mesh=`` / ``batch_axis=`` kwargs turn them into a policy here — one
    definition of the legacy-to-policy mapping for every layer."""
    if policy is not None:
        return policy
    return UpdatePolicy(method=method, mesh=mesh, batch_axis=batch_axis)
