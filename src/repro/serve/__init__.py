"""Serving engine: prefill/decode with KV caches."""
