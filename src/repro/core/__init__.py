"""Core numerics: the paper's contribution (fast rank-1 SVD update).

Layers (bottom-up):
  cheb         — Chebyshev nodes / Lagrange operators (paper App. D.1)
  secular      — secular equation solver + deflation + Loewner weights (§3.1)
  cauchy       — direct (stable) Cauchy products (§3.2.1, Trummer's problem)
  fmm          — TPU-native batched Chebyshev FMM (§5, App. D)
  fast         — Gerasoulis FAST baseline (§4, App. C)
  eigh_update  — symmetric diag+rank-1 eigen-update (Algorithm 6.2)
  svd_update   — full rank-1 SVD update (Algorithm 6.1) + streaming truncated
  engine       — batch-first plan-cached update engine (SvdEngine, DESIGN.md §4)
"""

from repro.core.cauchy import (
    cauchy_matmul,
    cauchy_matmul_stable,
    cauchy_matrix,
    cauchy_matvec,
)
from repro.core.eigh_update import (
    EighUpdatePlan,
    apply_update,
    apply_update_batch,
    eigenvalues,
    eigh_update,
    make_plan,
    make_plan_batch,
    materialize_q,
)
from repro.core.engine import (
    EngineCacheInfo,
    SvdEngine,
    default_engine,
)
from repro.core.fmm import FmmPlan, build_plan, fmm_apply, fmm_error_bound, fmm_matvec
from repro.core.secular import deflate, loewner_zhat, secular_solve
from repro.core.svd_update import (
    SvdUpdateResult,
    TruncatedSvd,
)

__all__ = [
    "cauchy_matmul",
    "cauchy_matmul_stable",
    "cauchy_matrix",
    "cauchy_matvec",
    "EighUpdatePlan",
    "apply_update",
    "apply_update_batch",
    "eigenvalues",
    "eigh_update",
    "make_plan",
    "make_plan_batch",
    "materialize_q",
    "EngineCacheInfo",
    "SvdEngine",
    "default_engine",
    "FmmPlan",
    "build_plan",
    "fmm_apply",
    "fmm_error_bound",
    "fmm_matvec",
    "deflate",
    "loewner_zhat",
    "secular_solve",
    "SvdUpdateResult",
    "TruncatedSvd",
]
