"""Paper Table 2 / Fig. 4: rank-1 SVD update accuracy vs matrix size.

Paper setup: square matrices, values U[1,9], n in {10..50}; error metric
Eq. 32. Paper reports 0.141 -> 0.046; ours floors at fp64 thanks to the
Gu-Eisenstat corrections (the comparison is recorded in EXPERIMENTS.md).
CSV: table2/n=<n>,us,<our_error>|paper=<paper_error>
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.engine import default_engine


def svd_update(u, s, v, a, b, *, method):
    return default_engine(method).update(u, s, v, a, b)

PAPER = {10: 0.141245710607176, 20: 0.0837837759946002, 30: 0.0559656608985486,
         40: 0.0623799282154490, 50: 0.0464500903310721}
EXTRA = [100, 200, 400, 800]


def run() -> None:
    rng = np.random.default_rng(0)
    for n in list(PAPER) + EXTRA:
        a_mat = rng.uniform(1, 9, size=(n, n))
        a = rng.normal(size=n)
        b = rng.normal(size=n)
        u, s, vt = np.linalg.svd(a_mat)
        a_hat = a_mat + np.outer(a, b)
        smax = np.linalg.svd(a_hat, compute_uv=False)[0]
        args = (jnp.asarray(u), jnp.asarray(s), jnp.asarray(vt.T),
                jnp.asarray(a), jnp.asarray(b))
        res = svd_update(*args, method="fmm")
        recon = np.asarray(res.u) @ np.diag(np.asarray(res.s)) @ np.asarray(res.v)[:, :n].T
        err = np.max(np.abs(a_hat - recon)) / smax
        us = time_fn(lambda *xs: svd_update(*xs, method="fmm"), *args)
        paper = f"|paper={PAPER[n]:.3f}" if n in PAPER else ""
        emit(f"table2/n={n}", us, f"eq32_error={err:.3e}{paper}")


if __name__ == "__main__":
    run()
