"""Deterministic, shardable synthetic token stream.

Restart-exact: batch contents are a pure function of (seed, step, position),
so resuming from a checkpoint at step k reproduces the exact remaining
stream with no reader state. Host-sharded: each data-parallel rank
materializes only its slice.

The stream is a mixture of a hash-noise channel and a structured channel
(integer sequences with skip patterns) so small models have learnable signal
(used by examples/train_lm.py to show decreasing loss).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["batch_for_step", "host_slice_for_step"]


def _hash_u32(x: jax.Array) -> jax.Array:
    """xorshift-mult avalanche over uint32."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


@partial(jax.jit, static_argnames=("batch", "seq", "vocab"))
def batch_for_step(seed, step, *, batch: int, seq: int, vocab: int):
    """Global batch for ``step``: {"tokens", "labels"} of (batch, seq)."""
    rows = jnp.arange(batch, dtype=jnp.uint32)[:, None]
    cols = jnp.arange(seq + 1, dtype=jnp.uint32)[None, :]
    base = (
        _hash_u32(rows * jnp.uint32(2_654_435_761) + jnp.uint32(seed))
        + jnp.uint32(step).astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
    )
    noise = _hash_u32(base + cols * jnp.uint32(0x85EBCA6B))

    # structured channel: arithmetic token walks (learnable)
    stride = (_hash_u32(base) % jnp.uint32(7)) + jnp.uint32(1)
    start = _hash_u32(base + jnp.uint32(13))
    walk = (start + cols * stride) % jnp.uint32(max(vocab - 1, 1))

    use_noise = (_hash_u32(base + cols) % jnp.uint32(4)) == 0  # 25% noise
    toks = jnp.where(use_noise, noise % jnp.uint32(max(vocab - 1, 1)), walk)
    toks = toks.astype(jnp.int32)
    return {"tokens": toks[:, :seq], "labels": toks[:, 1:]}


def host_slice_for_step(seed, step, *, batch, seq, vocab, rank, world):
    """Only this host's rows (rank-sliced global batch)."""
    full = batch_for_step(seed, step, batch=batch, seq=seq, vocab=vocab)
    per = batch // world
    sl = slice(rank * per, (rank + 1) * per)
    return jax.tree.map(lambda a: a[sl], full)
