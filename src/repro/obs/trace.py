"""Nestable span tracing → Chrome ``trace_event`` JSON (DESIGN.md §15).

``span("flush_round")`` wraps a region of host-side control flow; spans nest
naturally (reap inside flush inside pump), are thread-safe (one buffer,
per-thread ``tid``), and run on the monotonic clock (``perf_counter_ns`` —
immune to wall-clock steps).  Each completed span is one Chrome complete
event (``"ph": "X"``, ``ts``/``dur`` in microseconds) so
``chrome://tracing`` / Perfetto render the flush/merge timeline directly.

Contract with the rest of the library:

* When tracing is off (the default) ``span()`` returns a shared no-op
  context manager — no clock read, no allocation, no lock.
* Spans are HOST spans: they bracket dispatch/compile/reap control flow,
  never the inside of a jitted function, so tracing cannot perturb jaxprs.
* On span exit the duration is also fed to the metrics registry as a
  ``span_duration_us`` histogram labeled by span name (when metrics are
  enabled), so Prometheus sees the same taxonomy the trace file does.
"""

from __future__ import annotations

import json
import threading
import time

from repro.obs import metrics as _metrics

__all__ = [
    "span",
    "start_tracing",
    "stop_tracing",
    "tracing",
    "trace_events",
    "clear_trace",
    "save_chrome_trace",
    "chrome_trace",
]

_lock = threading.Lock()
_events: list[dict] = []
_tracing = False
_MAX_EVENTS = 200_000          # drop (and count) beyond this — bounded memory


def tracing() -> bool:
    """True while span collection is on."""
    return _tracing


def start_tracing() -> None:
    global _tracing
    _tracing = True


def stop_tracing() -> None:
    global _tracing
    _tracing = False


def clear_trace() -> None:
    with _lock:
        _events.clear()


def trace_events() -> list[dict]:
    """A copy of the collected Chrome events."""
    with _lock:
        return list(_events)


class _Span:
    """Live span: records ts on enter, emits one 'X' event on exit.

    ``set(key=value)`` attaches args visible in the trace viewer (merge
    levels attach pair counts and wire bytes this way).
    """

    __slots__ = ("name", "args", "_t0")

    def __init__(self, name: str, args: dict):
        self.name = name
        self.args = args

    def set(self, **kw) -> "_Span":
        self.args.update(kw)
        return self

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter_ns()
        ts_us = self._t0 / 1e3
        dur_us = (t1 - self._t0) / 1e3
        ev = {
            "name": self.name,
            "ph": "X",
            "ts": ts_us,
            "dur": dur_us,
            "pid": 1,
            "tid": threading.get_ident() & 0xFFFFFFFF,
        }
        if self.args:
            ev["args"] = dict(self.args)
        with _lock:
            if len(_events) < _MAX_EVENTS:
                _events.append(ev)
        from repro import obs as _obs
        if _obs.enabled():
            _span_histogram(self.name).observe(dur_us)


_hist_cache: dict = {"key": None, "by_name": {}}


def _span_histogram(name: str):
    """Per-span-name ``span_duration_us`` handle, cached across the hot
    path (invalidated when the registry is swapped or reset)."""
    reg = _metrics.registry()
    key = (reg, reg.generation)
    if _hist_cache["key"] != key:
        _hist_cache["key"] = key
        _hist_cache["by_name"] = {}
    by_name = _hist_cache["by_name"]
    h = by_name.get(name)
    if h is None:
        h = by_name[name] = reg.histogram("span_duration_us", span=name)
    return h


class _NoopSpan:
    """Shared do-nothing span — the disabled-path singleton."""

    __slots__ = ()

    def set(self, **kw) -> "_NoopSpan":
        return self

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None


_NOOP = _NoopSpan()


def span(name: str, **args):
    """Context manager bracketing one named region.

    >>> from repro import obs
    >>> obs.start_tracing()
    >>> with obs.span("flush_round", batch=4) as sp:
    ...     _ = sp.set(depth=1)
    >>> obs.stop_tracing()
    >>> [e["name"] for e in obs.trace_events()]
    ['flush_round']
    """
    if not _tracing:
        return _NOOP
    return _Span(name, args)


def chrome_trace() -> str:
    """The collected spans as a Chrome ``trace_event`` JSON document."""
    with _lock:
        evs = list(_events)
    return json.dumps({"traceEvents": evs, "displayTimeUnit": "ms"})


def save_chrome_trace(path) -> str:
    """Write the Chrome trace JSON to ``path``; returns the path written."""
    doc = chrome_trace()
    with open(path, "w") as f:
        f.write(doc)
    return str(path)
